#!/usr/bin/env python
"""Markdown link checker for the docs CI job.

Usage: ``python tools/check_links.py PATH [PATH ...]`` where each PATH is a
markdown file or a directory (scanned recursively for ``*.md``). For every
inline link ``[text](target)``:

* external schemes (http/https/mailto) are skipped — CI must not depend on
  the network;
* relative file targets must exist (resolved against the containing file);
* fragment targets (``#anchor``, ``file.md#anchor``) must match a heading
  in the target file, using GitHub's slugification (lowercase, punctuation
  stripped, spaces to hyphens).

Exits non-zero listing every broken link.
"""
from __future__ import annotations

import os
import re
import sys
from typing import List, Tuple

LINK_RE = re.compile(r"(?<!\!)\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
HEADING_RE = re.compile(r"^#{1,6}\s+(.*)$", re.MULTILINE)
CODE_FENCE_RE = re.compile(r"```.*?```", re.DOTALL)


def slugify(heading: str) -> str:
    """GitHub-style anchor slug: drop markdown emphasis/code markers and
    punctuation, lowercase, spaces to hyphens."""
    h = re.sub(r"[`*_]", "", heading.strip().lower())
    h = re.sub(r"[^\w\- ]", "", h)
    return h.replace(" ", "-")


def headings(md_path: str) -> set:
    with open(md_path, encoding="utf-8") as f:
        text = CODE_FENCE_RE.sub("", f.read())
    return {slugify(m.group(1)) for m in HEADING_RE.finditer(text)}


def check_file(md_path: str) -> List[Tuple[str, str]]:
    with open(md_path, encoding="utf-8") as f:
        text = CODE_FENCE_RE.sub("", f.read())
    base = os.path.dirname(os.path.abspath(md_path))
    broken = []
    for m in LINK_RE.finditer(text):
        target = m.group(1)
        if re.match(r"^[a-z][a-z0-9+.-]*:", target):   # http:, mailto:, ...
            continue
        path_part, _, frag = target.partition("#")
        if path_part:
            resolved = os.path.normpath(os.path.join(base, path_part))
            if not os.path.exists(resolved):
                broken.append((target, "file not found"))
                continue
            frag_file = resolved
        else:
            frag_file = md_path
        if frag:
            if not frag_file.endswith(".md") or not os.path.isfile(frag_file):
                continue                    # anchors into non-md: skip
            if slugify(frag) not in headings(frag_file):
                broken.append((target, f"anchor #{frag} not found"))
    return broken


def collect(paths: List[str]) -> List[str]:
    out = []
    for p in paths:
        if os.path.isdir(p):
            for root, _, files in os.walk(p):
                out.extend(os.path.join(root, f) for f in sorted(files)
                           if f.endswith(".md"))
        elif p.endswith(".md"):
            out.append(p)
        else:
            print(f"warning: skipping non-markdown arg {p}", file=sys.stderr)
    return out


def main(argv: List[str]) -> int:
    files = collect(argv or ["."])
    n_links = 0
    rc = 0
    for f in files:
        broken = check_file(f)
        with open(f, encoding="utf-8") as fh:
            n_links += len(LINK_RE.findall(CODE_FENCE_RE.sub("", fh.read())))
        for target, why in broken:
            print(f"BROKEN {f}: ({target}) — {why}", file=sys.stderr)
            rc = 1
    print(f"checked {len(files)} file(s), {n_links} link(s)"
          + ("" if rc == 0 else " — FAILURES above"))
    return rc


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
