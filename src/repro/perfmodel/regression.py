"""ML-assisted runtime prediction (paper §III-E1), in JAX.

The paper fits polynomial regression over ~58K real datapoints (DGX-H100 +
vLLM + LLaMA2-70B): decode runtime as a polynomial in (batch, past tokens),
prefill runtime in (past tokens, prefill tokens, batch, tokens^2). We
implement closed-form ridge regression (normal equations solved in fp64-ish
fp32 JAX) plus jit/vmap batched prediction — this is what gives the paper's
20-50x speedup over re-running the analytical model per event.

Datapoints come either from a real-trace CSV or from ``analytical.py``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.perfmodel import analytical as ana
from repro.perfmodel.hardware import ClusterSpec, LinkSpec


def _poly_features_decode(batch, past):
    b = batch.astype(jnp.float32)
    p = past.astype(jnp.float32)
    return jnp.stack([jnp.ones_like(b), b, p, b * p, b * b, p * p], axis=-1)


def _poly_features_prefill(past, new, batch):
    p = past.astype(jnp.float32)
    n = new.astype(jnp.float32)
    b = batch.astype(jnp.float32)
    return jnp.stack([jnp.ones_like(p), p, n, b, n * n, p * n, b * n], axis=-1)


@dataclass
class FittedModel:
    weights: jnp.ndarray
    feature_fn: Callable
    mse: float

    def predict(self, *args) -> jnp.ndarray:
        x = self.feature_fn(*[jnp.asarray(a) for a in args])
        return x @ self.weights


def ridge_fit(X: jnp.ndarray, y: jnp.ndarray, lam: float = 1e-6) -> jnp.ndarray:
    XtX = X.T @ X + lam * jnp.eye(X.shape[1])
    Xty = X.T @ y
    return jnp.linalg.solve(XtX, Xty)


def fit_decode_model(cfg: ModelConfig, cluster: ClusterSpec,
                     batches: Sequence[int] = (1, 2, 4, 8, 16, 32, 64, 128),
                     contexts: Sequence[int] = (128, 512, 1024, 2048, 4096, 8192),
                     ) -> FittedModel:
    bs, ps, ys = [], [], []
    for b in batches:
        for c in contexts:
            t = ana.decode_step_time(cfg, cluster, b, c).time
            bs.append(b); ps.append(c); ys.append(t)
    b = jnp.asarray(bs); p = jnp.asarray(ps); y = jnp.asarray(ys, jnp.float32)
    X = _poly_features_decode(b, p)
    w = ridge_fit(X, y)
    mse = float(jnp.mean((X @ w - y) ** 2))
    return FittedModel(w, _poly_features_decode, mse)


def fit_prefill_model(cfg: ModelConfig, cluster: ClusterSpec,
                      pasts: Sequence[int] = (0, 512, 2048, 8192),
                      news: Sequence[int] = (64, 128, 256, 512, 1024, 2048, 4096),
                      batches: Sequence[int] = (1, 2, 4, 8),
                      ) -> FittedModel:
    ps, ns, bs, ys = [], [], [], []
    for p_ in pasts:
        for n_ in news:
            for b_ in batches:
                t = ana.prefill_time(cfg, cluster, n_, b_, past_tokens=p_).time
                ps.append(p_); ns.append(n_); bs.append(b_); ys.append(t)
    p = jnp.asarray(ps); n = jnp.asarray(ns); b = jnp.asarray(bs)
    y = jnp.asarray(ys, jnp.float32)
    X = _poly_features_prefill(p, n, b)
    w = ridge_fit(X, y)
    mse = float(jnp.mean((X @ w - y) ** 2))
    return FittedModel(w, _poly_features_prefill, mse)


def fit_from_trace(rows: np.ndarray, kind: str = "decode") -> FittedModel:
    """rows: (N, 3) [batch, past, time] for decode or (N, 4)
    [past, new, batch, time] for prefill — real-hardware trace ingest."""
    rows = jnp.asarray(rows, jnp.float32)
    if kind == "decode":
        X = _poly_features_decode(rows[:, 0], rows[:, 1])
        y = rows[:, 2]
        fn = _poly_features_decode
    else:
        X = _poly_features_prefill(rows[:, 0], rows[:, 1], rows[:, 2])
        y = rows[:, 3]
        fn = _poly_features_prefill
    w = ridge_fit(X, y)
    return FittedModel(w, fn, float(jnp.mean((X @ w - y) ** 2)))


def fit_link_spec(samples: Sequence[Tuple[float, float]],
                  name: str = "measured") -> LinkSpec:
    """Alpha-beta fit of timed transfers: least-squares ``time = alpha +
    nbytes / beta`` over (nbytes, seconds) samples, returned as a
    ``LinkSpec(latency=alpha, bandwidth=beta)`` ready for
    ``Network.override_link``. This closes the measure->calibrate->replay
    loop: ``benchmarks/engine_disagg.py`` times real KV-page handoffs and
    feeds the fit back into the simulator's link pricing.

    Alpha is clamped to >= 0 (a negative fitted intercept just means the
    latency term is below measurement noise); the slope is clamped to a tiny
    positive value so beta stays finite. Needs >= 2 samples with distinct
    sizes for a meaningful slope — with fewer, the fit degenerates to
    bandwidth through the origin."""
    arr = np.asarray(list(samples), dtype=np.float64)
    if arr.ndim != 2 or arr.shape[0] == 0:
        raise ValueError("fit_link_spec needs (nbytes, seconds) samples")
    nbytes, secs = arr[:, 0], arr[:, 1]
    if arr.shape[0] < 2 or float(np.ptp(nbytes)) == 0.0:
        bw = float(np.sum(nbytes) / max(np.sum(secs), 1e-12))
        return LinkSpec(name, max(bw, 1e-9), 0.0)
    slope, alpha = np.polyfit(nbytes, secs, 1)
    slope = max(float(slope), 1e-18)          # beta = 1/slope stays finite
    return LinkSpec(name, 1.0 / slope, max(float(alpha), 0.0))


@jax.jit
def _batched_predict(w, X):
    return X @ w


def batched_decode_predict(model: FittedModel, batch_arr, past_arr):
    """vmap/jit fast path used by the simulator hot loop."""
    X = _poly_features_decode(jnp.asarray(batch_arr), jnp.asarray(past_arr))
    return _batched_predict(model.weights, X)
