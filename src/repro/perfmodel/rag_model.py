"""IVF-PQ retrieval + rerank cost model (paper §III-E2, after RAGO/Chameleon).

Stages priced on the retrieval cluster:
  1. query -> centroid distances (nlist x d fp32 matvec, compute-bound)
  2. LUT construction for probed lists (nprobe x K x dsub)
  3. ADC scan over nprobe x points_per_probe codes (memory-bound byte stream —
     this is the loop the ``pq_scan`` Pallas kernel implements on TPU)
  4. top-k + rerank of k docs
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.perfmodel.hardware import ClusterSpec
from repro.perfmodel.analytical import StageCost


@dataclass(frozen=True)
class IVFPQConfig:
    n_centroids: int = 4_000_000     # paper §IV-B: 4M centroids
    n_probe: int = 50
    points_per_probe: int = 5_000
    pq_m: int = 16                   # subquantizers per vector
    pq_k: int = 256
    dim: int = 768
    top_k: int = 20
    doc_tokens: int = 512


def retrieval_time(cfg: IVFPQConfig, cluster: ClusterSpec) -> StageCost:
    chip = cluster.chip
    # 1. coarse quantizer matvec
    fl_coarse = 2.0 * cfg.n_centroids * cfg.dim
    # 2. LUT build: K centroids per subquantizer, dsub dims
    dsub = cfg.dim // cfg.pq_m
    fl_lut = 2.0 * cfg.pq_m * cfg.pq_k * dsub
    # 3. ADC scan: one byte per (point, subquantizer) + LUT adds
    n_points = cfg.n_probe * cfg.points_per_probe
    scan_bytes = float(n_points * cfg.pq_m)
    fl_scan = float(n_points * cfg.pq_m)       # adds
    # 4. top-k selection ~ n_points log2(k)
    fl_topk = n_points * 5.0

    fl = fl_coarse + fl_lut + fl_scan + fl_topk
    by = (cfg.n_centroids * cfg.dim * 4.0      # coarse centroids (streamed)
          + scan_bytes)
    t_comp = fl / (cluster.total_flops * chip.mfu_prefill)
    t_mem = by / (cluster.total_bw * chip.mbu_decode)
    t = max(t_comp, t_mem)
    bound = "compute" if t_comp >= t_mem else "memory"
    return StageCost(t, t * chip.power * cluster.n_chips * 0.6, fl, by, bound)


def rerank_time(cfg: IVFPQConfig, cluster: ClusterSpec) -> StageCost:
    """Lightweight cross-scoring of top-k candidate docs."""
    fl = 2.0 * cfg.top_k * cfg.doc_tokens * cfg.dim
    by = cfg.top_k * cfg.doc_tokens * cfg.dim * 2.0
    t = max(fl / (cluster.total_flops * cluster.chip.mfu_prefill),
            by / (cluster.total_bw * cluster.chip.mbu_decode))
    return StageCost(t, t * cluster.chip.power * cluster.n_chips * 0.6, fl, by,
                     "memory")
