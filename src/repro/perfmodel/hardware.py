"""Hardware specs for the analytical client models (paper §III-E, §IV-B, §V).

Numbers follow the paper's experimental setups: H100/A100 NPUs, Grace-inspired
large CPU, Sapphire-Rapids-inspired small CPU, plus the TPU v5e target used by
the roofline analysis (197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple


@dataclass(frozen=True)
class ChipSpec:
    name: str
    flops: float              # peak FLOP/s (bf16 for NPUs, fp32 for CPUs)
    mem_bw: float             # bytes/s
    mem_cap: float            # bytes
    power: float              # watts (board TDP)
    idle_power_frac: float = 0.3
    mfu_prefill: float = 0.55  # achievable fraction of peak in compute-bound
    mbu_decode: float = 0.70   # achievable fraction of peak HBM bw


@dataclass(frozen=True)
class LinkSpec:
    name: str
    bandwidth: float          # bytes/s
    latency: float            # seconds per message


H100 = ChipSpec("H100", 989e12, 3.35e12, 80e9, 700.0)
A100 = ChipSpec("A100", 312e12, 2.039e12, 80e9, 400.0)
TPU_V5E = ChipSpec("TPUv5e", 197e12, 819e9, 16e9, 250.0)
# paper §IV-B CPU configs
GRACE_CPU = ChipSpec("GraceCPU", 14.2e12, 768e9, 1e12, 500.0, mfu_prefill=0.7)
SPR_CPU = ChipSpec("SPR-CPU", 6.27e12, 307.2e9, 4e12, 350.0, mfu_prefill=0.7)
# generic memory-node "chip" for cache tiers
MEM_NODE = ChipSpec("MemNode", 1e12, 128e9, 4e12, 150.0)

CHIPS: Dict[str, ChipSpec] = {c.name: c for c in
                              (H100, A100, TPU_V5E, GRACE_CPU, SPR_CPU, MEM_NODE)}

NVLINK = LinkSpec("NVLink", 450e9, 2e-6)
ICI = LinkSpec("ICI", 50e9, 1e-6)
PCIE4_X4 = LinkSpec("PCIe4x4", 32e9, 5e-6)      # paper §IV-B figure
PCIE5 = LinkSpec("PCIe5x16", 64e9, 5e-6)
ETH_RACK = LinkSpec("RackEth", 128e9, 20e-6)
DCN = LinkSpec("DCN", 128e9, 20e-3)             # paper §V-B: ~20 ms link latency

LINKS: Dict[str, LinkSpec] = {l.name: l for l in
                              (NVLINK, ICI, PCIE4_X4, PCIE5, ETH_RACK, DCN)}


@dataclass(frozen=True)
class ClusterSpec:
    """A hardware cluster backing one client: n chips with TP within."""
    chip: ChipSpec
    n_chips: int = 1
    tp: int = 1
    intra_link: LinkSpec = NVLINK

    @property
    def total_mem(self) -> float:
        return self.chip.mem_cap * self.n_chips

    @property
    def total_flops(self) -> float:
        return self.chip.flops * self.n_chips

    @property
    def total_bw(self) -> float:
        return self.chip.mem_bw * self.n_chips


@dataclass(frozen=True)
class CacheTierSpec:
    """One level of the KV-retrieval memory hierarchy (paper Eq. 1)."""
    name: str
    capacity: float           # bytes
    lookup_latency: float     # seconds
    bandwidth: float          # bytes/s
    hit_rate: float           # stationary hit probability

    def transfer_time(self, nbytes: float) -> float:
        """The Eq. 1 hit term (``T_lookup_n + Size_KV / BW_n``) — the single
        source for pricing one deterministic traversal of this tier."""
        return self.lookup_latency + nbytes / self.bandwidth


# paper §V-B storage tiers
TIER_LOCAL_LPDDR = CacheTierSpec("per-client-LPDDR", 1e12, 100e-9, 128e9, 0.60)
TIER_PLATFORM = CacheTierSpec("platform-shared", 4e12, 1e-6, 32e9, 0.80)
TIER_RACK = CacheTierSpec("rack-shared", 32e12, 10e-6, 2e9, 0.95)

# spill tiers for the on-device paged KV allocator (HBM → host DRAM →
# remote pool). ``hit_rate`` is 1.0: a swapped page is deterministically
# where the block table says it is — only the Eq. 1 hit *term*
# (lookup + bytes/BW) prices the traversal.
TIER_HOST_DRAM = CacheTierSpec("host-DRAM", 2e12, 1e-6, PCIE5.bandwidth, 1.0)
TIER_REMOTE_POOL = CacheTierSpec("remote-pool", 64e12, ETH_RACK.latency,
                                 ETH_RACK.bandwidth, 1.0)
DEFAULT_SWAP_TIERS: Tuple[CacheTierSpec, ...] = (TIER_HOST_DRAM,
                                                 TIER_REMOTE_POOL)
