"""Client runtime estimation: analytical roofline + JAX polynomial regression."""
