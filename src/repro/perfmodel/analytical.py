"""GenZ-style analytical runtime model for LLM inference stages.

This is the "external analytical simulator" of paper §III-E1: it prices a
prefill / decode / embedding forward pass on a ClusterSpec from first
principles (FLOPs vs HBM bytes vs TP-collective time). The polynomial
regression of ``regression.py`` is trained on datapoints generated here (or on
real traces), mirroring the paper's ML-assisted modeling pipeline.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Optional

from repro.configs.base import ModelConfig
from repro.perfmodel.hardware import CacheTierSpec, ChipSpec, ClusterSpec

BYTES_PER_PARAM = 2.0  # bf16 weights
BYTES_KV = 2.0         # bf16 KV cache


def kv_bytes_per_token(cfg: ModelConfig) -> float:
    """KV-cache bytes per token (whole model)."""
    if cfg.attn_type == "mla":
        per_layer = cfg.mla.kv_lora_rank + cfg.mla.qk_rope_head_dim
    elif cfg.attn_type == "gqa":
        per_layer = 2 * cfg.num_kv_heads * cfg.resolved_head_dim
    else:
        per_layer = 0
    n_attn = cfg.num_layers
    if cfg.family == "hybrid":
        n_attn = cfg.num_layers // max(1, cfg.shared_attn_every)
    if cfg.family == "ssm":
        n_attn = 0
    return BYTES_KV * per_layer * n_attn


def ssm_state_bytes(cfg: ModelConfig) -> float:
    """Per-request recurrent state bytes (SSM/hybrid archs)."""
    total = 0.0
    if cfg.ssm is not None:
        d_in = cfg.ssm.expand * cfg.d_model
        nh = d_in // cfg.ssm.head_dim
        total += cfg.num_layers * (nh * cfg.ssm.state_dim * cfg.ssm.head_dim * 4
                                   + (d_in + 2 * cfg.ssm.state_dim)
                                   * (cfg.ssm.conv_width - 1) * 2)
    if cfg.xlstm is not None:
        d_in = int(cfg.xlstm.proj_factor_mlstm * cfg.d_model)
        hd = d_in // cfg.num_heads
        total += cfg.num_layers * cfg.num_heads * hd * hd * 4
    return total


def flops_per_token(cfg: ModelConfig, context: int = 0) -> float:
    """Forward FLOPs per token: 2*N_active + attention term."""
    base = 2.0 * cfg.active_param_count()
    if cfg.attn_type != "none" and context > 0:
        n_attn = cfg.num_layers
        if cfg.family == "hybrid":
            n_attn = cfg.num_layers // max(1, cfg.shared_attn_every)
        qk_dim = (cfg.mla.qk_nope_head_dim + cfg.mla.qk_rope_head_dim
                  if cfg.attn_type == "mla" else cfg.resolved_head_dim)
        v_dim = (cfg.mla.v_head_dim if cfg.attn_type == "mla"
                 else cfg.resolved_head_dim)
        base += 2.0 * n_attn * cfg.num_heads * context * (qk_dim + v_dim)
    return base


def _tp_collective_time(cluster: ClusterSpec, tokens: int, d_model: int,
                        n_layers: int) -> float:
    """2 all-reduces per layer (attn + mlp out) under TP, ring algorithm."""
    if cluster.tp <= 1:
        return 0.0
    bytes_per_ar = 2.0 * (cluster.tp - 1) / cluster.tp * tokens * d_model * 2
    t_bw = bytes_per_ar / cluster.intra_link.bandwidth
    t_lat = 2 * (cluster.tp - 1) * cluster.intra_link.latency
    return 2 * n_layers * (t_bw + t_lat)


@dataclass(frozen=True)
class StageCost:
    time: float
    energy: float
    flops: float
    bytes: float
    bound: str  # "compute" | "memory" | "network"


def prefill_time(cfg: ModelConfig, cluster: ClusterSpec, prefill_tokens: int,
                 batch: int = 1, past_tokens: int = 0,
                 chunk: Optional[int] = None) -> StageCost:
    """Time for one prefill pass of ``prefill_tokens`` per request."""
    toks = prefill_tokens * batch
    avg_ctx = past_tokens + prefill_tokens / 2
    fl = flops_per_token(cfg, context=int(avg_ctx)) * toks
    w_bytes = cfg.param_count() * BYTES_PER_PARAM
    kv_b = kv_bytes_per_token(cfg) * (past_tokens + prefill_tokens) * batch
    by = w_bytes + kv_b
    t_comp = fl / (cluster.total_flops * cluster.chip.mfu_prefill)
    t_mem = by / (cluster.total_bw * cluster.chip.mbu_decode)
    t_net = _tp_collective_time(cluster, toks, cfg.d_model, cfg.num_layers)
    t = max(t_comp, t_mem) + t_net
    bound = ("compute" if t_comp >= t_mem else "memory")
    if t_net > max(t_comp, t_mem):
        bound = "network"
    energy = t * cluster.chip.power * cluster.n_chips * (
        1.0 if bound == "compute" else 0.75)
    return StageCost(t, energy, fl, by, bound)


def decode_step_time(cfg: ModelConfig, cluster: ClusterSpec, batch: int,
                     avg_context: int) -> StageCost:
    """Time for ONE decode step of a batch (one token per request)."""
    fl = flops_per_token(cfg, context=avg_context) * batch
    w_bytes = cfg.param_count() * BYTES_PER_PARAM
    kv_b = (kv_bytes_per_token(cfg) * avg_context + ssm_state_bytes(cfg)) * batch
    by = w_bytes + kv_b
    t_comp = fl / (cluster.total_flops * cluster.chip.mfu_prefill)
    t_mem = by / (cluster.total_bw * cluster.chip.mbu_decode)
    t_net = _tp_collective_time(cluster, batch, cfg.d_model, cfg.num_layers)
    t = max(t_comp, t_mem) + t_net
    bound = "compute" if t_comp >= t_mem else "memory"
    if t_net > max(t_comp, t_mem):
        bound = "network"
    energy = t * cluster.chip.power * cluster.n_chips * (
        1.0 if bound == "compute" else 0.55)
    return StageCost(t, energy, fl, by, bound)


def chunked_step_time(cfg: ModelConfig, cluster: ClusterSpec,
                      chunk_tokens: int, decode_batch: int,
                      avg_context: int) -> StageCost:
    """Sarathi-style piggybacked step: chunk of prefill + decode batch."""
    pre = prefill_time(cfg, cluster, chunk_tokens, 1, past_tokens=avg_context)
    # weights are read once for the fused step, decode adds only KV traffic
    kv_b = (kv_bytes_per_token(cfg) * avg_context + ssm_state_bytes(cfg)) * decode_batch
    fl = flops_per_token(cfg, context=avg_context) * decode_batch
    t_extra = max(fl / (cluster.total_flops * cluster.chip.mfu_prefill),
                  kv_b / (cluster.total_bw * cluster.chip.mbu_decode))
    t = pre.time + t_extra
    energy = t * cluster.chip.power * cluster.n_chips * 0.9
    return StageCost(t, energy, pre.flops + fl, pre.bytes + kv_b, pre.bound)


def embedding_time(embed_cfg: ModelConfig, cluster: ClusterSpec,
                   query_tokens: int) -> StageCost:
    return prefill_time(embed_cfg, cluster, query_tokens, 1)


def idle_stall_energy(t: float, cluster: ClusterSpec) -> float:
    """Energy burned while the engine stalls (KV swaps, bubble time)."""
    return t * cluster.chip.power * cluster.n_chips * \
        cluster.chip.idle_power_frac


def kv_swap_cost(nbytes: float, tier: CacheTierSpec,
                 cluster: ClusterSpec) -> StageCost:
    """One KV page-swap traversal of a spill-tier boundary (paper Eq. 1 hit
    term). The engine idles while pages move, so energy is the stall at
    idle power. Composes the two shared primitives the scheduler also uses
    (``CacheTierSpec.transfer_time`` + ``idle_stall_energy``)."""
    t = tier.transfer_time(nbytes)
    return StageCost(t, idle_stall_energy(t, cluster), 0.0, nbytes, "network")


def expected_accepted_tokens(k: int, alpha) -> float:
    """Expected tokens committed per speculative step (draft k, verify once,
    always >= 1 thanks to the bonus token).

    ``alpha`` is either a scalar (i.i.d. per-position acceptance — the
    classic geometric closed form ``(1 - alpha^(k+1)) / (1 - alpha)``) or a
    per-position sequence of CONDITIONAL rates ``[a_0, .., a_{k-1}]`` with
    ``a_i = P(accept position i | accepted 0..i-1)``, as measured by the
    engine (``spec_stats()['conditional_acceptance_per_position']`` — NOT
    the marginal ``acceptance_per_position``, which is already a cumulative
    product): acceptance stops at the first rejection, so
    E[tokens] = 1 + sum_j prod_{i<=j} a_i. A sequence longer than ``k`` is
    truncated; shorter ones extend with their last value (rates flatten past
    the measured horizon)."""
    if isinstance(alpha, (int, float)):
        a = float(alpha)
        return float(k + 1) if a >= 1 else (1 - a ** (k + 1)) / (1 - a)
    rates = [float(a) for a in alpha][:k]
    if rates and len(rates) < k:
        rates.extend([rates[-1]] * (k - len(rates)))
    total, run = 1.0, 1.0
    for a in rates:
        run *= a
        total += run
    return total


def speculative_decode_step(target: ModelConfig, draft: ModelConfig,
                            cluster: ClusterSpec, batch: int, avg_context: int,
                            k: int = 4, alpha=0.8):
    """Speculative decoding (paper §III-E1's optimization list): draft k
    tokens with the small model, verify in one target pass.

    Returns (StageCost for one spec step, expected accepted tokens/step).
    ``alpha`` may be a scalar (geometric acceptance) or a measured
    per-position distribution — see ``expected_accepted_tokens``."""
    draft_cost = decode_step_time(draft, cluster, batch, avg_context)
    # verification: target forward over k+1 positions per request ~ a tiny
    # chunked prefill (weights read once, k+1 tokens of compute)
    verify = prefill_time(target, cluster, k + 1, batch,
                          past_tokens=avg_context)
    t = draft_cost.time * k + verify.time
    expected = expected_accepted_tokens(k, alpha)
    cost = StageCost(t, draft_cost.energy * k + verify.energy,
                     draft_cost.flops * k + verify.flops,
                     draft_cost.bytes * k + verify.bytes, verify.bound)
    return cost, expected
