"""Deterministic synthetic LM data pipeline for the training driver.

Generates a mixture of structured sequences (copy / arithmetic-progression /
Markov n-gram text) so a ~100M model has real signal to learn in a few hundred
steps; shard-aware batching keeps per-host slices disjoint and restart-stable
(the stream is a pure function of (seed, step), so resuming from a checkpoint
replays the exact same batches).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int = 512
    seq_len: int = 256
    global_batch: int = 32
    seed: int = 0
    host_id: int = 0
    n_hosts: int = 1


def _markov_rows(rng: np.random.Generator, n: int, s: int, vocab: int):
    """Order-1 Markov chains with a per-row random phase — learnable."""
    trans_seed = rng.integers(0, 2 ** 31)
    trng = np.random.default_rng(trans_seed)
    next_tok = trng.integers(0, vocab, size=vocab)           # deterministic map
    rows = np.empty((n, s), np.int32)
    rows[:, 0] = rng.integers(0, vocab, size=n)
    for t in range(1, s):
        noisy = rng.random(n) < 0.1
        rows[:, t] = np.where(noisy, rng.integers(0, vocab, size=n),
                              next_tok[rows[:, t - 1]])
    return rows


def batch_at(cfg: DataConfig, step: int) -> Dict[str, np.ndarray]:
    """The global batch for ``step`` (pure function; host-sliced)."""
    rng = np.random.default_rng((cfg.seed, step))
    b, s = cfg.global_batch, cfg.seq_len + 1
    kind = rng.random(b)
    rows = _markov_rows(rng, b, s, cfg.vocab_size)
    # 30% copy task: second half repeats the first
    copy_mask = kind < 0.3
    half = s // 2
    rows[copy_mask, half:half * 2] = rows[copy_mask, :half]
    per_host = b // cfg.n_hosts
    lo = cfg.host_id * per_host
    sl = rows[lo: lo + per_host]
    return {"tokens": sl[:, :-1].astype(np.int32),
            "labels": sl[:, 1:].astype(np.int32)}


def stream(cfg: DataConfig, start_step: int = 0) -> Iterator[Dict[str, np.ndarray]]:
    step = start_step
    while True:
        yield batch_at(cfg, step)
        step += 1
