"""Core machinery of the real-execution serving engine.

Historically ``engine/runner.py`` held one monolithic ``Engine`` class —
admission, chunked prefill, decode, speculative decoding, preemption and
block-table bookkeeping all interleaved. This module is the refactor of that
class into composable layers:

* ``EngineCore`` — everything role-agnostic: request/record types, the paged
  ``PagedKVStore`` + physical cache pool, block-table row maintenance,
  admission paths (whole, chunked, swap-in), preemption (swap/recompute),
  growth, and the two forward passes as separately callable units —
  ``_decode_pass`` (one ``(b, 1)`` decode over decode-phase rows) and
  ``_chunk_pass`` (one ``(b, chunk_size)`` chunked-prefill advance).
* ``Engine(EngineCore)`` — the single-device engine: ``run()`` drives mixed
  iterations (decode + chunk), legacy whole-prefill iterations, or
  speculative iterations over the shared core. Public behavior is unchanged;
  ``engine/runner.py`` re-exports it so existing imports keep working.
* ``PrefillWorker`` / ``DecodeWorker`` / ``DisaggEngine``
  (``engine/workers.py``) — disaggregated serving: each worker is an
  ``EngineCore`` that runs ONLY its role's pass; finished prefills hand
  their KV pages to a decode worker through a real transfer path
  (``PagedKVStore.export_pages`` / ``import_pages``).
* ``SlotEngine`` — the original dense per-slot engine, kept verbatim as the
  bit-exactness oracle (``tests/test_paged_engine.py``).

Interface contract (paged ``Engine``)
-------------------------------------
* Geometry: ``max_len`` must be a multiple of ``block_tokens``;
  ``max_blocks = max_len // block_tokens``; the physical pool holds
  ``num_blocks`` allocatable pages plus one *trash page* (index
  ``num_blocks``). ``num_blocks`` defaults to ``max_batch * max_blocks``
  (no memory pressure); shrink it to exercise preemption for real.
* Block-table layout: row ``i`` of the ``(max_batch, max_blocks)`` table
  maps logical token position ``p`` to physical page
  ``table[i, p // block_tokens]``, slot ``p % block_tokens``. Dead rows
  (no active request) point every entry at the trash page with length 0 —
  their decode output is garbage the engine ignores, exactly like the dense
  engine's stale slots, and their masked writes land in the trash page so
  they can never corrupt a live page.
* Length-masking: the model sees ``lengths`` per row and masks
  ``pos >= length`` to probability exactly 0, so stale page content (prior
  occupants, trash) cannot leak into live rows.
* Admission reserves ``ceil(context / block_tokens)`` pages; full
  block-aligned *prompt* blocks register in the store's radix index, and a
  later admission whose prompt shares the block-aligned prefix maps the same
  physical pages (refcount bump — real dedup, visible in
  ``Engine.kv_stats()``).
* Speculative decoding (``EngineConfig(draft_cfg=..., spec_k=...)``): each
  iteration drafts up to ``spec_k`` greedy tokens per row with a small draft
  model (its own paged pool), COW-forks the target block tables
  (``PagedKVStore.fork_table``), scores draft + bonus positions in ONE
  target pass (``paged_verify_attention``), and commits the longest
  agreeing prefix — rejected KV rolls back via ``abort``/trim, so greedy
  streams stay bit-identical to plain decode while emitting up to
  ``spec_k + 1`` tokens per target pass.
* Preemption (``preemption="swap" | "recompute"``) is *real*:
  swap moves the victim's pages device -> host (``jax.device_get`` of the
  gathered pages; ``jax.device_put`` scatters them back on resume) and
  recompute drops the pages and re-prefills ``prompt + generated[:-1]`` on
  re-admission. Both keep every token generated so far. Victims requeue
  FIFO-fairly (by original submit order), and a shared-page victim degrades
  from swap to recompute — the same composition rule the simulator uses.

Cross-link: ``docs/architecture.md`` ("Paged real-execution engine" and
"Disaggregated engine") maps this module against the simulator stack layer
by layer.
"""
from __future__ import annotations

import bisect
import contextlib
import functools
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.engine.paged_kv import PagedKVStore, prefix_chain
from repro.models import steps
from repro.models import transformer as tf


@dataclass
class EngineConfig:
    """Scheduling policy for the paged ``Engine`` — the TTFT-vs-ITL knob.

    ``chunk_size == 0`` keeps the legacy whole-prompt admission path (one
    blocking prefill per admission). With ``chunk_size > 0`` every scheduler
    iteration becomes a MIXED iteration: running decodes take their normal
    ``(b, 1)`` step AND waiting/partial prefills advance by up to one
    ``(b, chunk_size)`` chunked-prefill pass in the same iteration, so a
    long prompt never stalls running decodes for its whole length.

    * ``chunk_size`` — prompt tokens per request per iteration. Smaller
      chunks bound the per-iteration prefill work (better ITL for running
      decodes), larger chunks finish prompts in fewer passes (better TTFT).
    * ``token_budget`` — total forward tokens an iteration may spend across
      both passes; 0 defaults to ``max_batch + chunk_size`` (all decodes
      plus one full chunk).
    * ``decode_share`` — fraction of ``token_budget`` reserved for decode
      rows while any are running; the leftover is the chunk budget. 0 keeps
      the default reservation (exactly the running decodes); 1.0 starves
      prefill completely until every running decode finishes (max-ITL
      extreme of the knob).
    * ``max_context`` — logical KV tokens a single request may span; 0
      defaults to ``max_len``. Raising it (multiple of ``block_tokens``)
      lets the chunked engine serve prompts far beyond ``max_len`` — the
      per-pass working set stays ``chunk_size`` wide regardless.

    Speculative decoding (``draft_cfg`` + ``spec_k``, requires
    ``chunk_size == 0``): every iteration runs a small draft model for up
    to ``spec_k`` greedy tokens per row, verifies them in ONE target pass
    (``paged_verify_attention``), and commits the longest matching prefix
    plus the bonus token — up to ``spec_k + 1`` tokens per target pass
    instead of 1, with greedy streams bit-identical to plain decode.

    * ``draft_cfg`` — ModelConfig of the draft model (gqa-family, same
      vocab as the target). None disables speculation.
    * ``spec_k`` — draft tokens proposed per iteration (0 disables).
    * ``draft_seed`` — init seed for the draft params when the engine is
      not handed ``draft_params`` explicitly.
    """
    chunk_size: int = 0
    token_budget: int = 0
    decode_share: float = 0.0
    max_context: int = 0
    draft_cfg: Optional[ModelConfig] = None
    spec_k: int = 0
    draft_seed: int = 1


@dataclass
class EngineRequest:
    rid: int
    prompt: np.ndarray                       # (p,) int32
    max_new_tokens: int = 32
    eos_id: Optional[int] = None
    submit_time: float = 0.0
    first_token_time: Optional[float] = None
    finish_time: Optional[float] = None
    tokens: List[int] = field(default_factory=list)
    token_times: List[float] = field(default_factory=list)
    slot: Optional[int] = None
    # new | running | swapped | preempted | handoff | done
    # ("handoff": prefill complete, KV pages in flight to a decode worker —
    # disaggregated serving only, see engine/workers.py)
    state: str = "new"
    preemptions: int = 0
    # chunked-prefill continuation state: ``ctx`` is the full context this
    # admission must write to KV (prompt, or prompt + generated[:-1] on a
    # recompute resume) and ``prefilled`` counts how much of it is written.
    # ``prefilled == len(ctx)`` marks the request decode-phase.
    ctx: Optional[np.ndarray] = None
    prefilled: int = 0

    @property
    def itl(self) -> List[float]:
        """Inter-token latencies (seconds) between consecutive streamed
        tokens — the per-request tail-latency surface the chunked scheduler
        is tuned against."""
        return [b - a for a, b in zip(self.token_times, self.token_times[1:])]

    @property
    def ttft(self):
        return (self.first_token_time - self.submit_time
                if self.first_token_time else None)

    @property
    def tpot(self):
        if self.finish_time is None or self.first_token_time is None:
            return None
        return ((self.finish_time - self.first_token_time)
                / max(1, len(self.tokens) - 1))


class EngineCore:
    """Role-agnostic core of the paged engine: store + cache pool + block
    tables + admission/preemption/growth + the decode and chunk passes as
    separately callable units. ``Engine`` composes every pass on one
    device; the disaggregated workers (``engine/workers.py``) each run only
    their role's pass. ``device`` pins this core's pool (and every pass it
    runs) to one jax device — None keeps the default device, which is also
    the host-staged fallback for single-device hosts."""

    def __init__(self, cfg: ModelConfig, params=None, max_batch: int = 4,
                 max_len: int = 512, seed: int = 0, block_tokens: int = 16,
                 num_blocks: Optional[int] = None, preemption: str = "swap",
                 trace_occupancy: bool = False,
                 config: Optional[EngineConfig] = None, draft_params=None,
                 device=None):
        assert max_len % block_tokens == 0, \
            "max_len must be a multiple of block_tokens (bit-exact parity " \
            "with the dense engine needs identical logical cache length)"
        assert preemption in ("swap", "recompute")
        self.config = config or EngineConfig()
        self.chunk_size = self.config.chunk_size
        assert self.chunk_size >= 0
        max_context = self.config.max_context or max_len
        assert self.chunk_size or max_context == max_len, \
            "max_context > max_len needs chunked prefill (chunk_size > 0): " \
            "the whole-prompt path prefills through a (1, max_len) cache"
        assert max_context % block_tokens == 0 and max_context >= max_len, \
            "max_context must be a multiple of block_tokens and >= max_len"
        self.cfg = cfg
        self.max_batch = max_batch
        self.max_len = max_len
        self.max_context = max_context
        # generation stop bound AND eager-validation bound for submit():
        # chunked rows may span max_context, whole-prefill rows cap at
        # max_len exactly like the dense oracle
        self._len_limit = max_context if self.chunk_size else max_len
        self.block_tokens = block_tokens
        self.max_blocks = max_context // block_tokens
        self.num_blocks = (max_batch * self.max_blocks if num_blocks is None
                           else num_blocks)
        self.preemption = preemption
        self.device = device
        with self._dev_scope():
            if params is None:
                params, _ = tf.init_model(cfg, jax.random.PRNGKey(seed))
            elif device is not None:
                params = jax.device_put(params, device)
            self.params = params
            self.store = PagedKVStore(self.num_blocks, block_tokens)
            self.caches = tf.init_paged_cache(cfg, max_batch, self.num_blocks,
                                              block_tokens, self.max_blocks)
        trash = self.store.trash_block
        self._tables_np = np.full((max_batch, self.max_blocks), trash,
                                  np.int32)
        self._lengths_np = np.zeros((max_batch,), np.int32)
        self.active: List[Optional[EngineRequest]] = [None] * max_batch
        self.waiting: List[EngineRequest] = []
        self.finished: List[EngineRequest] = []
        self.steps = 0
        self._next_rid = 0
        self._admit_seq = 0
        self._admit_order: Dict[int, int] = {}   # rid -> admit seq
        self.trace_occupancy = trace_occupancy
        self.occupancy: List[Dict] = []          # per-step block occupancy

        bt, mb = self.block_tokens, self.max_blocks

        @jax.jit
        def _prefill_one(params, tokens):
            return steps.prefill_step(params, {"tokens": tokens}, cfg, max_len)

        @jax.jit
        def _decode(params, tokens, caches):
            return steps.serve_step(params, tokens, caches, cfg)

        @jax.jit
        def _chunk(params, tokens, q_valid, caches):
            return steps.chunk_step(params, tokens, q_valid, caches, cfg)

        self._prefill_one = _prefill_one
        self._decode = _decode
        self._chunk = _chunk
        # pure page-movement kernels live in models/steps.py so the single
        # engine, the disaggregated workers and the spec-decode path all
        # share one implementation
        self._write_prefill = jax.jit(functools.partial(
            steps.write_prefill_pages, max_blocks=mb, block_tokens=bt))
        self._gather_pages = jax.jit(steps.gather_pages)
        self._scatter_pages = jax.jit(steps.scatter_pages)

        # -- speculative decoding (draft model + verify pass) ----------
        self.spec_k = self.config.spec_k
        self.draft_cfg = self.config.draft_cfg
        self.spec = self.draft_cfg is not None and self.spec_k > 0
        if self.spec:
            assert self.chunk_size == 0, \
                "speculative decoding needs the whole-prefill path " \
                "(EngineConfig.chunk_size == 0)"
            assert paged_supported(self.draft_cfg), \
                "draft model must serve through the paged cache path"
            assert self.draft_cfg.vocab_size == cfg.vocab_size, \
                "draft and target must share a vocabulary"
            dcfg = self.draft_cfg
            with self._dev_scope():
                if draft_params is None:
                    draft_params, _ = tf.init_model(
                        dcfg, jax.random.PRNGKey(self.config.draft_seed))
                self.draft_params = draft_params
                # the draft pool is sized so it can NEVER hit pressure:
                # capacity planning stays a target-pool problem and draft
                # admission is infallible (a draft page is kvh*hd of a tiny
                # model — cheap)
                self.draft_store = PagedKVStore(max_batch * self.max_blocks,
                                                block_tokens)
                self.draft_caches = tf.init_paged_cache(
                    dcfg, max_batch, self.draft_store.num_blocks, block_tokens,
                    self.max_blocks)
            self._draft_tables_np = np.full(
                (max_batch, self.max_blocks), self.draft_store.trash_block,
                np.int32)
            self._draft_lengths_np = np.zeros((max_batch,), np.int32)
            # rid -> number of leading draft-cache positions whose KV matches
            # the request's true token stream (rewind point for re-drafting)
            self._draft_valid: Dict[int, int] = {}
            # acceptance accounting for calibration (spec_stats())
            self.spec_iters = 0
            self.spec_row_steps = 0
            self.spec_emitted = 0
            self._spec_pos_proposed = np.zeros((self.spec_k,), np.int64)
            self._spec_pos_accepted = np.zeros((self.spec_k,), np.int64)

            @jax.jit
            def _draft_prefill(params, tokens):
                return steps.prefill_step(params, {"tokens": tokens}, dcfg,
                                          max_len)

            @jax.jit
            def _draft_decode(params, tokens, caches):
                return steps.serve_step(params, tokens, caches, dcfg)

            @jax.jit
            def _verify(params, tokens, q_valid, caches):
                return steps.verify_step(params, tokens, q_valid, caches, cfg)

            self._draft_prefill = _draft_prefill
            self._draft_decode = _draft_decode
            self._verify = _verify
            self._copy_pages = jax.jit(steps.copy_pages)

    def _dev_scope(self):
        """Ambient-device context for this core's array work: a no-op on the
        default device, ``jax.default_device(self.device)`` when the core is
        pinned (disaggregated workers) so freshly created arrays colocate
        with the pool."""
        return (jax.default_device(self.device) if self.device is not None
                else contextlib.nullcontext())

    # ------------------------------------------------------------------
    def _validate_submit(self, prompt: np.ndarray, max_new_tokens: int):
        """Eager admission validation: a prompt must leave room for at least
        one generated token under the stop bound (p + t >= limit - 1), else
        it would only fail deep inside prefill/table maintenance."""
        limit = self._len_limit
        if len(prompt) > limit - 2:
            if self.chunk_size:
                raise ValueError(
                    f"prompt of {len(prompt)} tokens exceeds max_context - 2 "
                    f"= {limit - 2}; raise EngineConfig.max_context")
            raise ValueError(
                f"prompt of {len(prompt)} tokens exceeds max_len - 2 = "
                f"{limit - 2}; enable chunked prefill "
                f"(EngineConfig(chunk_size=..., max_context=...)) to serve "
                f"prompts past max_len")
        need = self.store.blocks_for_tokens(
            min(len(prompt) + max_new_tokens, limit - 1))
        if need > self.num_blocks:
            raise ValueError(
                f"request needs {need} blocks but the pool holds only "
                f"{self.num_blocks}; raise num_blocks or shrink the request")

    def submit(self, prompt: np.ndarray, max_new_tokens: int = 32,
               eos_id: Optional[int] = None) -> EngineRequest:
        prompt = np.asarray(prompt, np.int32)
        self._validate_submit(prompt, max_new_tokens)
        r = EngineRequest(rid=self._next_rid, prompt=prompt,
                          max_new_tokens=max_new_tokens, eos_id=eos_id,
                          submit_time=time.monotonic())
        self._next_rid += 1
        self.waiting.append(r)
        return r

    def enqueue(self, r: EngineRequest):
        """Queue an externally constructed request FIFO-fairly (by rid).
        Disaggregated serving routes requests between workers with this —
        the orchestrator owns rid assignment, so worker-local ``submit`` is
        bypassed."""
        rids = [w.rid for w in self.waiting]
        self.waiting.insert(bisect.bisect_left(rids, r.rid), r)

    # -- block-table row maintenance -----------------------------------
    def _pad_ids(self, blocks: List[int]) -> np.ndarray:
        ids = np.full((self.max_blocks,), self.store.trash_block, np.int32)
        ids[:len(blocks)] = blocks
        return ids

    def _set_row(self, slot: int, blocks: List[int], length: int):
        self._tables_np[slot] = self._pad_ids(blocks)
        self._lengths_np[slot] = length

    def _clear_row(self, slot: int):
        self._tables_np[slot] = self.store.trash_block
        self._lengths_np[slot] = 0

    def _push_rows(self, tables: Optional[np.ndarray] = None,
                   lengths: Optional[np.ndarray] = None):
        """Sync block-table/length rows into every cache group (identical
        across layers — the indirection is per-request). Defaults to the
        host mirrors; mixed iterations push per-pass VIEWS instead (chunk
        rows appear as trash/0 to the decode pass so its structural write
        at position ``length`` can never land in a live page)."""
        tabs = jnp.asarray(self._tables_np if tables is None else tables)
        lens = jnp.asarray(self._lengths_np if lengths is None else lengths)
        for g in self.caches.values():
            L = g["block_tables"].shape[0]
            g["block_tables"] = jnp.broadcast_to(tabs[None], (L, *tabs.shape))
            g["length"] = jnp.broadcast_to(lens[None], (L, *lens.shape))

    def _push_draft_rows(self, tables: Optional[np.ndarray] = None,
                         lengths: Optional[np.ndarray] = None):
        """Same as ``_push_rows`` for the draft model's cache groups."""
        tabs = jnp.asarray(self._draft_tables_np if tables is None else tables)
        lens = jnp.asarray(self._draft_lengths_np if lengths is None
                           else lengths)
        for g in self.draft_caches.values():
            L = g["block_tables"].shape[0]
            g["block_tables"] = jnp.broadcast_to(tabs[None], (L, *tabs.shape))
            g["length"] = jnp.broadcast_to(lens[None], (L, *lens.shape))

    # -- admission ------------------------------------------------------
    def _resume_ctx(self, r: EngineRequest) -> np.ndarray:
        """Context a (re-)admission must cover in KV: the prompt plus every
        token generated so far but the last — the cache then spans positions
        [0, p + t - 1) and decode continues by feeding tokens[-1]. Nothing
        generated is lost."""
        return np.concatenate([r.prompt, np.asarray(r.tokens[:-1], np.int32)]) \
            if r.tokens else r.prompt

    def _place(self, slot: int, r: EngineRequest):
        """Admission tail shared by every path (including the decode
        worker's page-import path): bind request to slot, stamp the admit
        order, (re-)prefill the draft model when speculating."""
        r.slot = slot
        r.state = "running"
        self._admit_order[r.rid] = self._admit_seq
        self._admit_seq += 1
        self.active[slot] = r
        if self.spec:
            self._admit_draft(r)

    def _admit_one(self, slot: int, r: EngineRequest) -> bool:
        """Try to place ``r`` in ``slot``; False when KV capacity blocks it
        (head-of-line: the caller stops admitting, keeping FIFO order)."""
        if r.state == "swapped":
            blocks = self.store.swap_in(r.rid)
            if blocks is None:
                return False
            t = self.store.tables[r.rid]
            ids = jnp.asarray(np.asarray(blocks, np.int32))
            self.caches = self._scatter_pages(
                self.caches,
                jax.device_put(t.host_pages, self.device), ids)
            t.host_pages = None
            self._set_row(slot, blocks, t.tokens)
            # mid-prefill swap victims resume chunking where the fill front
            # stopped; mid-decode victims have prefilled == len(ctx)
            r.ctx = self._resume_ctx(r)
            r.prefilled = t.tokens
        elif self.chunk_size:
            # chunked admission: reserve KV for the FIRST chunk only (plus
            # any resident matched prefix — free dedup); the mixed step
            # prefills chunk by chunk, growing the table at the fill front.
            # No forward pass happens here, so admission never stalls
            # running decodes.
            ctx = self._resume_ctx(r)
            chain = prefix_chain(r.prompt, self.block_tokens)
            got = self.store.allocate(r.rid, min(self.chunk_size, len(ctx)),
                                      chain, filled=0,
                                      context_tokens=len(ctx))
            if got is None:
                return False
            blocks, _ = got
            r.ctx = ctx
            r.prefilled = 0
            self._set_row(slot, blocks, 0)
        else:
            ctx = self._resume_ctx(r)
            chain = prefix_chain(r.prompt, self.block_tokens)
            got = self.store.allocate(r.rid, len(ctx), chain)
            if got is None:
                return False
            blocks, _ = got
            logits, dense = self._prefill_one(self.params, ctx[None, :])
            ids = jnp.asarray(self._pad_ids(blocks))
            # matched prefix blocks are rewritten with bit-identical content
            # (same tokens at same positions => same K/V); only the table
            # aliasing dedups memory, not the prefill compute
            self.caches = self._write_prefill(self.caches, dense, ids)
            if r.state == "new":
                tok = int(jnp.argmax(logits, -1)[0])
                r.first_token_time = time.monotonic()
                r.tokens.append(tok)
                r.token_times.append(r.first_token_time)
            self._set_row(slot, blocks, len(ctx))
            r.ctx = ctx
            r.prefilled = len(ctx)
        self._place(slot, r)
        return True

    def _admit_draft(self, r: EngineRequest):
        """(Re-)prefill the DRAFT model over ``r``'s resume context. Runs at
        every admission path — fresh, recompute resume, swap-in — because
        draft KV is never swapped: it is dropped at preemption and rebuilt
        here (a small-model prefill is cheaper than round-tripping its
        pages, and it keeps host memory accounting target-only)."""
        ctx = r.ctx
        got = self.draft_store.allocate(r.rid, len(ctx), ())
        assert got is not None, "draft pool is sized to never run out"
        blocks, _ = got
        _, dense = self._draft_prefill(self.draft_params,
                                       jnp.asarray(ctx[None, :]))
        dids = np.full((self.max_blocks,), self.draft_store.trash_block,
                       np.int32)
        dids[:len(blocks)] = blocks
        self.draft_caches = self._write_prefill(self.draft_caches, dense,
                                                jnp.asarray(dids))
        self._draft_tables_np[r.slot] = dids
        self._draft_lengths_np[r.slot] = len(ctx)
        self._draft_valid[r.rid] = len(ctx)

    def _admit(self):
        for slot in range(self.max_batch):
            if self.active[slot] is not None or not self.waiting:
                continue
            if not self._admit_one(slot, self.waiting[0]):
                break
            self.waiting.pop(0)

    # -- preemption -----------------------------------------------------
    def preempt_slot(self, slot: int, policy: Optional[str] = None):
        """Evict the request in ``slot`` and requeue it FIFO-fairly (ordered
        by original submit rid, not pushed to the queue head). ``swap``
        moves its pages to host memory; ``recompute`` drops them. Either
        way the tokens generated so far are kept."""
        r = self.active[slot]
        if r is None:
            return
        policy = policy or self.preemption
        rid = r.rid
        if self.spec:
            # a mid-step victim may hold a speculative fork: roll the target
            # table back to its committed base before swap/drop, and drop the
            # draft KV outright (rebuilt by _admit_draft on resume)
            if rid in self.store.forks:
                self.store.abort_fork(rid)
            if rid in self.draft_store.tables:
                self.draft_store.free(rid)
            self._draft_valid.pop(rid, None)
            self._draft_tables_np[slot] = self.draft_store.trash_block
            self._draft_lengths_np[slot] = 0
        if policy == "swap":
            blocks = self.store.swap_out(rid)
            if blocks is None:                 # shared pages: degrade
                policy = "recompute"
            else:
                # gather exactly the victim's pages (not the trash-padded
                # table): host memory and the device->host transfer scale
                # with the request, not with max_blocks
                ids = jnp.asarray(np.asarray(blocks, np.int32))
                pages = self._gather_pages(self.caches, ids)
                self.store.tables[rid].host_pages = jax.device_get(pages)
                r.state = "swapped"
        if policy == "recompute":
            self.store.drop(rid)
            r.state = "preempted"
        r.preemptions += 1
        self.active[slot] = None
        r.slot = None
        self._clear_row(slot)
        self.enqueue(r)

    def _make_room(self, for_rid: int) -> bool:
        """Free blocks by preempting the most-recently-admitted other active
        request (the simulator's coldest-victim rule)."""
        victims = [r for r in self.active
                   if r is not None and r.rid != for_rid]
        if not victims:
            return False
        v = max(victims, key=lambda r: self._admit_order[r.rid])
        self.preempt_slot(v.slot)
        return True

    # -- decode ---------------------------------------------------------
    def _is_decoding(self, r: EngineRequest) -> bool:
        """Decode-phase rows have their whole context in KV; chunk-phase
        rows are still filling it (chunked mode only)."""
        return r.prefilled >= len(r.ctx)

    def _grow_active(self):
        """Fault in pages so every active DECODE row's table covers the KV
        slot its next decode write lands in; exhaustion preempts victims."""
        for slot in range(self.max_batch):
            r = self.active[slot]      # re-read: _make_room may evict slots
            if r is None or not self._is_decoding(r) \
                    or not self.store.needs_block(r.rid):
                continue
            while True:
                b = self.store.grow(r.rid)
                if b is not None:
                    self._tables_np[r.slot,
                                    len(self.store.tables[r.rid].blocks) - 1] = b
                    break
                if not self._make_room(r.rid):
                    raise RuntimeError(
                        "KV pool exhausted with no preemptable victim")

    def _grow_to(self, r: EngineRequest, target_tokens: int):
        """Fault pages until ``r``'s table covers ``target_tokens`` KV slots
        (chunk-phase growth at the fill front); exhaustion preempts victims
        — never ``r`` itself."""
        t = self.store.tables[r.rid]
        while len(t.blocks) * self.block_tokens < target_tokens:
            b = self.store.grow(r.rid)
            if b is not None:
                self._tables_np[r.slot, len(t.blocks) - 1] = b
                continue
            if not self._make_room(r.rid):
                raise RuntimeError(
                    "KV pool exhausted with no preemptable victim")

    def _finish(self, r: EngineRequest, now: float):
        r.finish_time = now
        r.state = "done"
        if self.spec:
            if r.rid in self.draft_store.tables:
                self.draft_store.free(r.rid)
            self._draft_valid.pop(r.rid, None)
            self._draft_tables_np[r.slot] = self.draft_store.trash_block
            self._draft_lengths_np[r.slot] = 0
        self.store.free(r.rid)
        del self._admit_order[r.rid]       # rids never reuse: don't leak
        self.finished.append(r)
        self.active[r.slot] = None
        self._clear_row(r.slot)
        r.slot = None

    def _trace_step(self):
        self.steps += 1
        if self.trace_occupancy:
            st = self.store
            self.occupancy.append({
                "step": self.steps, "used_blocks": st.used_blocks,
                "free_blocks": st.free_blocks,
                "cached_blocks": st.cached_blocks,
                "active": sum(a is not None for a in self.active),
            })

    def _decode_bookkeeping(self, new_tok: np.ndarray):
        """Per-row accounting after a decode pass: stream the token, advance
        the store, finish rows that hit a stop condition."""
        now = time.monotonic()
        for s, r in enumerate(self.active):
            if r is None or not self._is_decoding(r):
                continue
            self.store.advance(r.rid)
            self._lengths_np[s] = min(self._lengths_np[s] + 1,
                                      self._len_limit - 1)
            t = int(new_tok[s])
            r.tokens.append(t)
            r.token_times.append(now)
            done = (len(r.tokens) >= r.max_new_tokens
                    or (r.eos_id is not None and t == r.eos_id)
                    or len(r.prompt) + len(r.tokens) >= self._len_limit - 1)
            if done:
                self._finish(r, now)

    def _decode_pass(self):
        """One ``(b, 1)`` decode pass over the decode-phase rows, with
        chunk-phase rows viewed as trash/0 so the pass's structural KV write
        at position ``length`` can never land in a live page. No-op when no
        row is decode-phase."""
        dec = [r for r in self.active
               if r is not None and self._is_decoding(r)]
        if not dec:
            return
        tabs = self._tables_np.copy()
        lens = self._lengths_np.copy()
        for r in self.active:
            if r is not None and not self._is_decoding(r):
                tabs[r.slot] = self.store.trash_block
                lens[r.slot] = 0
        last = np.zeros((self.max_batch, 1), np.int32)
        for r in dec:
            last[r.slot, 0] = r.tokens[-1]
        self._push_rows(tabs, lens)
        new_tok, _, self.caches = self._decode(
            self.params, jnp.asarray(last), self.caches)
        self._decode_bookkeeping(np.asarray(new_tok))

    # -- chunked prefill pass -------------------------------------------
    def _chunk_budget(self, n_dec: int) -> int:
        """Chunk tokens this iteration may spend, after the decode
        reservation (the TTFT-vs-ITL split of the token budget)."""
        budget = self.config.token_budget or (self.max_batch + self.chunk_size)
        if n_dec == 0:
            return max(budget, 1)
        reserved = max(n_dec,
                       int(np.ceil(self.config.decode_share * budget)))
        return max(0, budget - reserved)

    def _chunk_pass(self):
        """One ``(b, chunk_size)`` chunked-prefill pass advancing each
        chunk-phase row's fill front by up to ``chunk_size`` tokens within
        the iteration's token budget. A prompt completing its last chunk
        streams its first token from that pass (bit-identical to whole
        prefill's last-position logits).

        Chunk scheduling: admit-order fairness, shared token budget.
        ``_grow_to`` may preempt victims (most-recently-admitted), including
        rows already scheduled this pass — takes are re-validated after."""
        chunkers = sorted(
            (r for r in self.active
             if r is not None and not self._is_decoding(r)),
            key=lambda r: self._admit_order[r.rid])
        budget = self._chunk_budget(sum(1 for r in self.active
                                        if r is not None
                                        and self._is_decoding(r)))
        takes: Dict[int, int] = {}
        for r in chunkers:
            if r.slot is None or self.active[r.slot] is not r:
                continue                       # evicted by a peer's growth
            take = min(self.chunk_size, len(r.ctx) - r.prefilled, budget)
            if take <= 0:
                continue
            self._grow_to(r, r.prefilled + take)
            takes[r.rid] = take
            budget -= take
        alive = {r.rid for r in self.active if r is not None}
        takes = {rid: tk for rid, tk in takes.items() if rid in alive}
        if takes:
            toks = np.zeros((self.max_batch, self.chunk_size), np.int32)
            q_valid = np.zeros((self.max_batch,), np.int32)
            rows = [r for r in self.active
                    if r is not None and r.rid in takes]
            for r in rows:
                tk = takes[r.rid]
                toks[r.slot, :tk] = r.ctx[r.prefilled:r.prefilled + tk]
                q_valid[r.slot] = tk
            self._push_rows()                  # real tables for every row
            new_tok, _, self.caches = self._chunk(
                self.params, jnp.asarray(toks), jnp.asarray(q_valid),
                self.caches)
            new_tok = np.asarray(new_tok)
            now = time.monotonic()
            for r in rows:
                tk = takes[r.rid]
                self.store.advance(r.rid, tk)
                r.prefilled += tk
                self._lengths_np[r.slot] = r.prefilled
                if r.prefilled == len(r.ctx) and not r.tokens:
                    # prompt complete: stream the first token (resumes keep
                    # their stream and re-enter decode by feeding tokens[-1])
                    tok = int(new_tok[r.slot])
                    r.first_token_time = now
                    r.tokens.append(tok)
                    r.token_times.append(now)
        self._trace_step()

    def kv_stats(self) -> Dict[str, float]:
        return self.store.stats()


class Engine(EngineCore):
    """Continuous-batching engine over paged KV: every serving pass on one
    device (see module docstring). ``engine/workers.py`` builds the
    disaggregated prefill/decode split from the same ``EngineCore``."""

    def _step_decode(self):
        """Legacy whole-prefill iteration: one (b, 1) decode pass."""
        self._grow_active()
        self._decode_pass()
        self._trace_step()

    def _step_mixed(self):
        """One mixed iteration: (a) the decode pass for decode-phase rows —
        identical in shape and numerics to the legacy iteration — then (b)
        the chunked-prefill pass for chunk-phase rows, sharing the
        iteration's token budget."""
        self._grow_active()
        self._decode_pass()
        self._chunk_pass()

    # -- speculative iteration (draft k, verify in one target pass) -----
    def _step_spec(self):
        """One speculative iteration over the active (decode-phase) rows:

        1. DRAFT — rewind each row's draft cache to its last
           stream-consistent position, catch it up on the true stream, then
           roll the draft forward for up to ``k_eff`` greedy tokens (batched
           ``(b, 1)`` passes; rows done drafting sit out as trash/0).
        2. FORK — COW-fork each row's target block table
           (``PagedKVStore.fork_table``) so the verify pass may write KV at
           positions ``L .. L + k_eff`` without touching committed pages;
           capacity faults preempt peers exactly like ``_grow_active``.
        3. VERIFY — one ``(b, spec_k + 1)`` target pass feeds the last
           committed token plus the draft tokens; ``greedy[:, j]`` is
           bit-identical to what sequential decode would emit at that
           position (``paged_verify_attention`` contract).
        4. ACCEPT — per row, emit greedy tokens while they confirm the
           draft, plus the bonus token, applying the stop conditions
           token-by-token; ``commit_fork`` keeps KV for what was emitted and
           rolls back the rest.

        Streams are bit-identical to ``_step_decode`` because verify
        reproduces sequential numerics exactly and acceptance only decides
        how MANY of those tokens commit per pass (1..k_eff+1, never 0)."""
        live = [r for r in self.active if r is not None]
        limit = self._len_limit
        k_eff: Dict[int, int] = {}
        for r in live:
            # k_eff caps so the verify feed never proposes past the stop
            # bounds: at most max_new - 1 further tokens ride behind the
            # guaranteed bonus token, and writes stay inside the table
            L = int(self._lengths_np[r.slot])
            k_eff[r.rid] = max(0, min(self.spec_k,
                                      r.max_new_tokens - len(r.tokens) - 1,
                                      limit - 1 - L))

        # -- 1. draft phase --------------------------------------------
        drafts: Dict[int, List[int]] = {r.rid: [] for r in live}
        queues: Dict[int, List[int]] = {}
        part = [r for r in live if k_eff[r.rid] > 0]
        for r in part:
            dv = self._draft_valid[r.rid]
            L = int(self._lengths_np[r.slot])
            stream = np.concatenate([r.ctx, np.asarray(r.tokens, np.int32)])
            # feeding stream[dv..L] rewrites draft KV at positions dv..L
            # (overwriting any rejected-draft garbage) and the LAST feed's
            # output is the first draft token
            queues[r.rid] = [int(t) for t in stream[dv:L + 1]]
            self._draft_lengths_np[r.slot] = dv
        while part:
            feed = np.zeros((self.max_batch, 1), np.int32)
            tabs = np.full_like(self._draft_tables_np,
                                self.draft_store.trash_block)
            lens = np.zeros_like(self._draft_lengths_np)
            for r in part:
                q = queues[r.rid]
                feed[r.slot, 0] = q.pop(0) if q else drafts[r.rid][-1]
                D = int(self._draft_lengths_np[r.slot])
                dt = self.draft_store.tables[r.rid]
                while len(dt.blocks) * self.block_tokens <= D:
                    b = self.draft_store.grow(r.rid)
                    assert b is not None, "draft pool sized to never run out"
                    self._draft_tables_np[r.slot, len(dt.blocks) - 1] = b
                tabs[r.slot] = self._draft_tables_np[r.slot]
                lens[r.slot] = D
            self._push_draft_rows(tabs, lens)
            out, _, self.draft_caches = self._draft_decode(
                self.draft_params, jnp.asarray(feed), self.draft_caches)
            out = np.asarray(out)
            nxt = []
            for r in part:
                D = int(self._draft_lengths_np[r.slot])
                dt = self.draft_store.tables[r.rid]
                if D + 1 > dt.tokens:      # store tracks the high-water mark
                    self.draft_store.advance(r.rid, D + 1 - dt.tokens)
                self._draft_lengths_np[r.slot] = D + 1
                if not queues[r.rid]:
                    drafts[r.rid].append(int(out[r.slot]))
                if queues[r.rid] or len(drafts[r.rid]) < k_eff[r.rid]:
                    nxt.append(r)
            part = nxt

        # -- 2. fork target tables -------------------------------------
        for r in live:
            if r.slot is None or self.active[r.slot] is not r:
                continue                   # evicted by a peer's fork below
            while True:
                f = self.store.fork_table(r.rid, k_eff[r.rid] + 1)
                if f is not None:
                    break
                if not self._make_room(r.rid):
                    raise RuntimeError(
                        "KV pool exhausted with no preemptable victim")
            self._tables_np[r.slot] = self._pad_ids(
                self.store.tables[r.rid].blocks)
            if f.cow:
                # device-copy the COW'd pages so the fork's private copies
                # hold the shared prefix content the verify pass reads
                src = jnp.asarray(np.asarray([o for _, o, _ in f.cow],
                                             np.int32))
                dst = jnp.asarray(np.asarray([n for _, _, n in f.cow],
                                             np.int32))
                self.caches = self._copy_pages(self.caches, src, dst)

        # -- 3. verify pass --------------------------------------------
        live = [r for r in live
                if r.slot is not None and self.active[r.slot] is r]
        if not live:
            self._trace_step()
            return
        toks = np.zeros((self.max_batch, self.spec_k + 1), np.int32)
        q_valid = np.zeros((self.max_batch,), np.int32)
        for r in live:
            k = k_eff[r.rid]
            toks[r.slot, 0] = r.tokens[-1]
            toks[r.slot, 1:1 + k] = drafts[r.rid][:k]
            q_valid[r.slot] = k + 1
        self._push_rows()
        greedy, _, self.caches = self._verify(
            self.params, jnp.asarray(toks), jnp.asarray(q_valid), self.caches)
        greedy = np.asarray(greedy)

        # -- 4. accept, emit, commit -----------------------------------
        now = time.monotonic()
        for r in live:
            k = k_eff[r.rid]
            d = drafts[r.rid]
            a = 0
            while a < k and d[a] == int(greedy[r.slot, a]):
                a += 1
            self._spec_pos_proposed[:k] += 1
            self._spec_pos_accepted[:a] += 1
            L = int(self._lengths_np[r.slot])
            m, done = 0, False
            for j in range(a + 1):
                t = int(greedy[r.slot, j])
                r.tokens.append(t)
                r.token_times.append(now)
                m += 1
                if (len(r.tokens) >= r.max_new_tokens
                        or (r.eos_id is not None and t == r.eos_id)
                        or len(r.prompt) + len(r.tokens) >= limit - 1):
                    done = True
                    break
            self.store.commit_fork(r.rid, m)
            self._tables_np[r.slot] = self._pad_ids(
                self.store.tables[r.rid].blocks)
            self._lengths_np[r.slot] = min(L + m, limit - 1)
            self.spec_emitted += m
            self.spec_row_steps += 1
            if done:
                self._finish(r, now)
            elif k:
                # draft KV is valid through the accepted prefix (positions
                # L+1..L+min(k-1, a, m) hold confirmed draft tokens), capped
                # at L+m so the next catch-up re-feeds at least the newest
                # token
                self._draft_valid[r.rid] = min(L + m,
                                               L + 1 + min(k - 1, a, m))
        self.spec_iters += 1
        self._trace_step()

    def spec_stats(self) -> Dict[str, object]:
        """Acceptance telemetry for calibration: the measured per-position
        CONDITIONAL acceptance distribution feeds
        ``perfmodel.speculative_decode_step`` and the simulator's SPEC_DECODE
        pricing instead of an assumed geometric alpha
        (``benchmarks/spec_decode.py`` closes the loop).

        ``acceptance_per_position[i]`` is the *marginal* P(draft positions
        0..i all accepted) — acceptance stops at the first rejection, so the
        raw accepted/proposed ratio is already a cumulative product.
        ``conditional_acceptance_per_position[i]`` divides out the previous
        position's marginal to recover P(accept i | accepted 0..i-1) — the
        alpha_i sequence ``expected_accepted_tokens`` compounds."""
        prop = self._spec_pos_proposed
        acc = self._spec_pos_accepted
        marginal = [float(a) / p if p else 0.0 for a, p in zip(acc, prop)]
        cond, prev = [], 1.0
        for m in marginal:
            cond.append(min(1.0, m / prev) if prev > 0 else 0.0)
            prev = m
        return {
            "spec_k": self.spec_k,
            "iterations": self.spec_iters,
            "row_steps": self.spec_row_steps,
            "emitted": self.spec_emitted,
            # mean tokens a row commits per target pass it takes part in —
            # the direct analogue of 1.0 for plain decode
            "tokens_per_step": (self.spec_emitted / self.spec_row_steps
                                if self.spec_row_steps else 0.0),
            "proposed_per_position": [int(x) for x in prop],
            "accepted_per_position": [int(x) for x in acc],
            "acceptance_per_position": marginal,
            "conditional_acceptance_per_position": cond,
        }

    def run(self, max_steps: int = 100_000) -> List[EngineRequest]:
        if self.spec:
            step = self._step_spec
        else:
            step = self._step_mixed if self.chunk_size else self._step_decode
        while (self.waiting or any(a is not None for a in self.active)) \
                and self.steps < max_steps:
            self._admit()
            if any(a is not None for a in self.active):
                step()
        return self.finished


def paged_supported(cfg: ModelConfig) -> bool:
    """Can this config serve through the paged ``Engine``? Paging covers
    attention KV only: MLA's latent cache and hybrid/ssm recurrent state are
    not paged yet (see ROADMAP open items)."""
    return (cfg.family in ("dense", "vlm", "audio", "moe")
            and cfg.attn_type != "mla")


def make_engine(cfg: ModelConfig, **kw):
    """Engine factory: the paged ``Engine`` when the config supports paged
    attention caches, else the dense ``SlotEngine`` (which serves every
    decode-capable family). Paged-only kwargs are dropped for the dense
    fallback."""
    if paged_supported(cfg):
        return Engine(cfg, **kw)
    for k in ("block_tokens", "num_blocks", "preemption", "trace_occupancy",
              "config", "draft_params", "device"):
        kw.pop(k, None)
    return SlotEngine(cfg, **kw)


# ---------------------------------------------------------------------------
# dense slot engine (the parity oracle)
# ---------------------------------------------------------------------------

class SlotEngine:
    """The original dense-KV engine: one contiguous ``(max_len, kvh, hd)``
    cache row per decode slot, no paging. Kept as the bit-exactness oracle
    for the paged ``Engine`` (same admission policy, same greedy decode, so
    token streams must match) and as the simplest reference driver. Its
    preemption keeps the seed behavior — it *discards* progress past the
    first streamed token — which is exactly the deficiency the paged engine
    removes; don't use it for preemption studies."""

    def __init__(self, cfg: ModelConfig, params=None, max_batch: int = 4,
                 max_len: int = 512, seed: int = 0):
        self.cfg = cfg
        self.max_batch = max_batch
        self.max_len = max_len
        if params is None:
            params, _ = tf.init_model(cfg, jax.random.PRNGKey(seed))
        self.params = params
        self.caches = tf.init_cache(cfg, max_batch, max_len)
        self.active = [None] * max_batch        # slot -> EngineRequest
        self.waiting: List[EngineRequest] = []
        self.finished: List[EngineRequest] = []
        self.steps = 0
        self._next_rid = 0

        @jax.jit
        def _prefill_one(params, tokens):
            return steps.prefill_step(params, {"tokens": tokens}, cfg, max_len)

        @jax.jit
        def _decode(params, tokens, caches):
            return steps.serve_step(params, tokens, caches, cfg)

        self._prefill_one = _prefill_one
        self._decode = _decode

    # ------------------------------------------------------------------
    def submit(self, prompt: np.ndarray, max_new_tokens: int = 32,
               eos_id: Optional[int] = None) -> EngineRequest:
        r = EngineRequest(rid=self._next_rid,
                          prompt=np.asarray(prompt, np.int32),
                          max_new_tokens=max_new_tokens, eos_id=eos_id,
                          submit_time=time.monotonic())
        self._next_rid += 1
        self.waiting.append(r)
        return r

    def _write_slot(self, slot: int, req_cache):
        """Copy a single-request cache into batch slot ``slot``."""
        def put(full, one):
            return full.at[:, slot].set(one[:, 0].astype(full.dtype)) \
                if full.ndim >= 2 else full
        self.caches = jax.tree.map(put, self.caches, req_cache)

    def _admit(self):
        for slot in range(self.max_batch):
            if self.active[slot] is not None or not self.waiting:
                continue
            r = self.waiting.pop(0)
            logits, cache1 = self._prefill_one(self.params, r.prompt[None, :])
            tok = int(jnp.argmax(logits, -1)[0])
            now = time.monotonic()
            r.first_token_time = now
            r.tokens.append(tok)
            r.token_times.append(now)
            r.slot = slot
            self._write_slot(slot, cache1)
            self.active[slot] = r

    def _step_decode(self):
        last = np.zeros((self.max_batch, 1), np.int32)
        for s, r in enumerate(self.active):
            if r is not None:
                last[s, 0] = r.tokens[-1]
        new_tok, _, self.caches = self._decode(self.params,
                                               jnp.asarray(last), self.caches)
        new_tok = np.asarray(new_tok)
        now = time.monotonic()
        for s, r in enumerate(self.active):
            if r is None:
                continue
            t = int(new_tok[s])
            r.tokens.append(t)
            r.token_times.append(now)
            done = (len(r.tokens) >= r.max_new_tokens
                    or (r.eos_id is not None and t == r.eos_id)
                    or len(r.prompt) + len(r.tokens) >= self.max_len - 1)
            if done:
                r.finish_time = now
                self.finished.append(r)
                self.active[s] = None
        self.steps += 1

    def run(self, max_steps: int = 100_000) -> List[EngineRequest]:
        while (self.waiting or any(a is not None for a in self.active)) \
                and self.steps < max_steps:
            self._admit()
            if any(a is not None for a in self.active):
                self._step_decode()
        return self.finished

    # --- fault tolerance: preempt & requeue (client-failure analogue) ----
    def preempt_slot(self, slot: int):
        r = self.active[slot]
        if r is None:
            return
        r.tokens = r.tokens[:1]           # keep the streamed first token
        r.token_times = r.token_times[:1]
        self.active[slot] = None
        self.waiting.insert(0, r)
