"""Disaggregated prefill/decode serving over the shared ``EngineCore``.

The paper's §II-B claim — prefill and decode want different hardware and
batching regimes, so production serving splits them across workers and ships
the KV cache between them — has so far only been *priced* by the simulator
(``core/system.py`` "disaggregated" strategy, ``benchmarks/disaggregation``).
This module makes it real:

* ``PrefillWorker`` — an ``EngineCore`` that runs ONLY admission + prefill
  (whole-prompt or chunked). A request whose context is fully written
  becomes a handoff: the worker gathers its filled KV pages
  (``PagedKVStore.export_pages``), frees the table (registered prompt
  blocks park as evictable cache, so prefill-side prefix hits survive the
  handoff) and places ``(request, export, pages)`` in its outbox.
* ``DecodeWorker`` — an ``EngineCore`` that runs ONLY the decode pass.
  ``ingest`` queues a handoff FIFO-fairly; admission imports the pages into
  the worker's own pool (``PagedKVStore.import_pages`` — resident chain
  prefixes are aliased, and only unmatched pages are scattered) and decode
  continues from the streamed first token. Swap preemption stays local
  (host round-trip against this worker's pool); recompute preemption
  surfaces the victim in ``evicted`` — only a prefill worker can rebuild
  its KV, so the orchestrator routes it back (§II-B's "decode node cannot
  re-prefill" asymmetry, made concrete).
* ``DisaggEngine`` — the orchestrator: ``n_prefill`` x ``n_decode`` workers
  paired per the simulator's disaggregation modes ("local" = fixed
  prefill->decode pairing, "global" = any-to-any, deterministic
  least-loaded) with the KV handoff as a REAL page transfer:
  device-to-device ``jax.device_put`` when the host gives each role its own
  device (``launch.mesh.handoff_devices``), host-staged ``jax.device_get``
  otherwise. ``granularity="full"`` moves the whole table in one timed
  transfer; ``"layerwise"`` moves it layer by layer (paper §III-B2) — the
  exposed stall is then ~one layer (the rest overlaps pipelined compute),
  while total wire bytes are identical. Every handoff is timed;
  ``transfer_stats()`` feeds ``benchmarks/engine_disagg.py``, which fits
  ``LinkSpec`` constants from the samples and backfills the simulator's
  ``core/comm.py`` pricing (the measure->calibrate->replay loop).

Bit-equality oracle: under greedy decoding the disaggregated path must emit
token streams bit-identical to the single-device ``Engine`` — prefill
numerics, the handoff (pages move verbatim; aliased pages hold equal bits by
the hash-chain contract), and per-row decode numerics are all unchanged, and
every scheduling difference (worker pairing, admission order, preemption)
only reorders WHEN tokens are computed, never WHAT they are
(``tests/test_disagg_engine.py``).

Wire-dedup note: the transfer always moves the full filled page range; a
decode-resident chain prefix saves the pool *write* and is reported as
``import_dedup_blocks`` — the bytes a pinned-dedup wire protocol could have
skipped, which is exactly what the simulator's coordinator prices as
``kv_transfer_dedup_bytes``.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.engine.core import Engine, EngineConfig, EngineCore, EngineRequest
from repro.engine.paged_kv import PageExport
from repro.launch.mesh import handoff_devices
from repro.models import transformer as tf


@dataclass
class KVHandoff:
    """One prefill->decode KV handoff in flight: the request (stream and
    timing state ride along), its export snapshot fields, the staged page
    payload (on the decode worker's device, or host numpy when staged
    through the host), and the timed transfer record."""
    req: EngineRequest
    ctx: np.ndarray
    tokens: int
    chain: List[int]
    pages: Dict
    record: Dict


def _page_slice(pages, start: int):
    """Tail-slice a gathered page payload along the page axis (drop the
    leading ``start`` pages — the ones the importing store aliased)."""
    return {name: {"k": g["k"][:, start:], "v": g["v"][:, start:]}
            for name, g in pages.items()}


def move_pages(pages, device, granularity: str) -> Tuple[Dict, Dict]:
    """Physically move a gathered page payload to ``device`` (None =
    host-staged: ``jax.device_get`` to numpy), timing the transfer.

    ``full`` moves the whole payload as one transfer. ``layerwise`` moves
    one layer of one cache group per transfer (paper §III-B2): total wire
    bytes are identical, but the *exposed* stall is the slowest single
    layer — every other layer overlaps the consumer's layerwise compute,
    exactly how the simulator's ``Network._exposed`` prices it.

    Returns ``(staged_pages, record)`` where ``record`` carries
    ``bytes / pages / layers / granularity / total_s / exposed_s`` and the
    raw ``samples`` list of ``(bytes, seconds)`` per timed transfer — the
    points ``benchmarks/engine_disagg.py`` fits ``LinkSpec`` constants
    from."""
    assert granularity in ("full", "layerwise")
    leaves = jax.tree_util.tree_leaves(pages)
    for x in leaves:
        x.block_until_ready()              # exclude producer compute
    nbytes = int(sum(x.nbytes for x in leaves))
    n_pages = int(leaves[0].shape[1]) if leaves else 0
    n_layers = int(sum(g["k"].shape[0] for g in pages.values()))
    samples: List[Tuple[int, float]] = []
    if granularity == "full":
        t0 = time.perf_counter()
        if device is not None:
            staged = jax.device_put(pages, device)
            jax.block_until_ready(staged)
        else:
            staged = jax.device_get(pages)
        dt = time.perf_counter() - t0
        samples.append((nbytes, dt))
        total = exposed = dt
    else:
        staged = {}
        total, exposed = 0.0, 0.0
        for name, g in pages.items():
            ks, vs = [], []
            for layer in range(g["k"].shape[0]):
                sk, sv = g["k"][layer], g["v"][layer]
                sk.block_until_ready()
                sv.block_until_ready()
                lbytes = int(sk.nbytes + sv.nbytes)
                t0 = time.perf_counter()
                if device is not None:
                    ok = jax.device_put(sk, device)
                    ov = jax.device_put(sv, device)
                    jax.block_until_ready((ok, ov))
                else:
                    ok = jax.device_get(sk)
                    ov = jax.device_get(sv)
                dt = time.perf_counter() - t0
                samples.append((lbytes, dt))
                total += dt
                exposed = max(exposed, dt)
                ks.append(ok)
                vs.append(ov)
            # reassemble the layer axis on the destination side (pipeline
            # plumbing, not wire time — excluded from the samples)
            if device is not None:
                with jax.default_device(device):
                    staged[name] = {"k": jnp.stack(ks), "v": jnp.stack(vs)}
            else:
                staged[name] = {"k": np.stack(ks), "v": np.stack(vs)}
    record = {
        "bytes": nbytes,
        "pages": n_pages,
        "layers": n_layers,
        "granularity": granularity,
        "staged": "device" if device is not None else "host",
        "total_s": total,
        "exposed_s": exposed,
        "samples": samples,
    }
    return staged, record


class PrefillWorker(EngineCore):
    """Prefill-only role: admission + (whole or chunked) prefill, then
    export. Never decodes — a request whose context is fully in KV leaves
    through the outbox the same step it completes."""

    def __init__(self, *args, **kw):
        super().__init__(*args, **kw)
        assert not self.spec, \
            "speculative decoding is a single-engine feature (the draft " \
            "rides the decode pass, which this role never runs)"
        self.outbox: List[Tuple[EngineRequest, PageExport, Dict]] = []

    def step(self) -> bool:
        """One prefill iteration: admit (whole-prompt admission prefills
        inline), advance chunk-phase rows one chunked pass, export every
        row whose context completed. Returns True when any work happened."""
        with self._dev_scope():
            self._admit()
            worked = False
            if self.chunk_size and any(
                    r is not None and not self._is_decoding(r)
                    for r in self.active):
                self._chunk_pass()
                worked = True
            return bool(self._export_ready()) or worked

    def _export_ready(self) -> int:
        n = 0
        for slot in range(self.max_batch):
            r = self.active[slot]
            if r is None or not self._is_decoding(r):
                continue
            exp = self.store.export_pages(r.rid)
            ids = jnp.asarray(np.asarray(exp.blocks, np.int32))
            pages = self._gather_pages(self.caches, ids)
            jax.block_until_ready(pages)
            # free AFTER the gather: registered prompt blocks park as
            # evictable cache, so later prompts sharing the prefix still
            # alias them (prefill-side prefix hits survive the handoff)
            self.store.free(r.rid)
            del self._admit_order[r.rid]
            self.active[slot] = None
            self._clear_row(slot)
            r.slot = None
            r.state = "handoff"
            self.outbox.append((r, exp, pages))
            n += 1
        return n


class DecodeWorker(EngineCore):
    """Decode-only role: imports handed-off KV pages into its own pool and
    continues the stream. Swap preemption round-trips pages against THIS
    worker's pool; recompute preemption cannot be satisfied here (no
    prefill pass) — victims surface in ``evicted`` for the orchestrator to
    route back to a prefill worker."""

    def __init__(self, *args, **kw):
        super().__init__(*args, **kw)
        assert not self.spec, \
            "speculative decoding is a single-engine feature for now"
        self._handoffs: Dict[int, KVHandoff] = {}
        self.evicted: List[EngineRequest] = []

    def ingest(self, h: KVHandoff):
        """Queue a transferred handoff FIFO-fairly (by rid, merged with any
        swap victims awaiting re-admission). The staged pages wait with it;
        admission scatters them when a slot and pool capacity open up."""
        assert h.req.state == "handoff"
        self._handoffs[h.req.rid] = h
        self.enqueue(h.req)

    def _admit_one(self, slot: int, r: EngineRequest) -> bool:
        if r.state != "handoff":
            assert r.state == "swapped", \
                f"decode worker cannot admit a {r.state!r} request (only " \
                "handoffs and its own swap victims)"
            return super()._admit_one(slot, r)
        h = self._handoffs[r.rid]
        got = self.store.import_pages(r.rid, h.tokens, h.chain)
        if got is None:
            return False                   # head-of-line wait, like any path
        blocks, n_matched = got
        if n_matched < len(blocks):
            ids = jnp.asarray(np.asarray(blocks[n_matched:], np.int32))
            self.caches = self._scatter_pages(
                self.caches, _page_slice(h.pages, n_matched), ids)
        self._set_row(slot, blocks, h.tokens)
        r.ctx = h.ctx
        r.prefilled = h.tokens
        del self._handoffs[r.rid]
        self._place(slot, r)
        return True

    def step(self) -> bool:
        """One decode iteration: admit (imports + swap-ins), grow, decode.
        Returns True when a decode pass ran."""
        with self._dev_scope():
            self._admit()
            worked = False
            if any(a is not None for a in self.active):
                self._grow_active()
                self._decode_pass()
                self._trace_step()
                worked = True
            # recompute victims need a prefill worker to rebuild their KV
            out = [r for r in self.waiting if r.state == "preempted"]
            if out:
                self.waiting = [r for r in self.waiting
                                if r.state != "preempted"]
                self.evicted.extend(out)
            return worked


class DisaggEngine:
    """Disaggregated serving orchestrator: ``Engine``-compatible
    ``submit``/``run`` over prefill and decode worker fleets with a real
    KV-page handoff between them (see module docstring).

    * ``mode`` — "local" pins prefill worker ``i`` to decode worker
      ``i % n_decode`` (the simulator's fixed fast-pair wiring); "global"
      routes every handoff to the least-loaded decode worker (any-to-any,
      deterministic).
    * ``granularity`` — "full" | "layerwise" KV transfer (§III-B2).
    * ``prefill_blocks`` / ``decode_blocks`` — per-role pool sizes (None =
      pressure-free default); shrink them to exercise preemption on either
      side of the handoff.
    * ``devices`` — optional ``(prefill_devices, decode_devices)`` lists;
      default asks ``launch.mesh.handoff_devices`` (real cross-device
      ``jax.device_put`` when the host has >= 2 devices, host-staged
      otherwise).
    """

    def __init__(self, cfg: ModelConfig, params=None, *,
                 n_prefill: int = 1, n_decode: int = 1, mode: str = "local",
                 granularity: str = "full", max_batch: int = 4,
                 max_len: int = 512, seed: int = 0, block_tokens: int = 16,
                 prefill_blocks: Optional[int] = None,
                 decode_blocks: Optional[int] = None,
                 preemption: str = "swap",
                 config: Optional[EngineConfig] = None,
                 trace_occupancy: bool = False, devices=None):
        assert mode in ("local", "global")
        assert granularity in ("full", "layerwise")
        assert n_prefill >= 1 and n_decode >= 1
        self.cfg = cfg
        self.mode = mode
        self.granularity = granularity
        config = config or EngineConfig()
        assert config.draft_cfg is None and config.spec_k == 0, \
            "speculative decoding is a single-engine feature for now"
        if params is None:
            params, _ = tf.init_model(cfg, jax.random.PRNGKey(seed))
        if devices is None:
            devices = handoff_devices(n_prefill, n_decode)
        pdevs, ddevs = devices
        kw = dict(max_batch=max_batch, max_len=max_len,
                  block_tokens=block_tokens, preemption=preemption,
                  config=config, trace_occupancy=trace_occupancy)
        self.prefill = [PrefillWorker(cfg, params, num_blocks=prefill_blocks,
                                      device=pdevs[i], **kw)
                        for i in range(n_prefill)]
        self.decode = [DecodeWorker(cfg, params, num_blocks=decode_blocks,
                                    device=ddevs[j], **kw)
                       for j in range(n_decode)]
        self._next_rid = 0
        self._rr = 0
        self._home: Dict[int, int] = {}    # rid -> prefill worker index
        self.finished: List[EngineRequest] = []
        self.transfers: List[Dict] = []    # one timed record per handoff
        self.steps = 0

    # ------------------------------------------------------------------
    def submit(self, prompt: np.ndarray, max_new_tokens: int = 32,
               eos_id: Optional[int] = None) -> EngineRequest:
        prompt = np.asarray(prompt, np.int32)
        # a request must fit BOTH roles' geometry: it prefills (and may
        # re-prefill after a decode-side recompute) on a prefill worker and
        # decodes to its stop bound on a decode worker
        self.prefill[0]._validate_submit(prompt, max_new_tokens)
        self.decode[0]._validate_submit(prompt, max_new_tokens)
        r = EngineRequest(rid=self._next_rid, prompt=prompt,
                          max_new_tokens=max_new_tokens, eos_id=eos_id,
                          submit_time=time.monotonic())
        self._next_rid += 1
        idx = self._rr % len(self.prefill)
        self._rr += 1
        self._home[r.rid] = idx
        self.prefill[idx].waiting.append(r)
        return r

    def _route(self, src_idx: int) -> int:
        if self.mode == "local":
            return src_idx % len(self.decode)
        # global: deterministic least-loaded (queued + staged + active)
        return min(range(len(self.decode)),
                   key=lambda j: (len(self.decode[j].waiting)
                                  + len(self.decode[j]._handoffs)
                                  + sum(a is not None
                                        for a in self.decode[j].active)))

    def _pending(self) -> bool:
        for w in self.prefill:
            if w.waiting or w.outbox or any(a is not None for a in w.active):
                return True
        for w in self.decode:
            if (w.waiting or w._handoffs or w.evicted
                    or any(a is not None for a in w.active)):
                return True
        return False

    def run(self, max_steps: int = 100_000) -> List[EngineRequest]:
        while self._pending() and self.steps < max_steps:
            self.steps += 1
            progress = False
            for i, pw in enumerate(self.prefill):
                if pw.step():
                    progress = True
                while pw.outbox:
                    r, exp, pages = pw.outbox.pop(0)
                    j = self._route(i)
                    dw = self.decode[j]
                    staged, rec = move_pages(pages, dw.device,
                                             self.granularity)
                    rec.update(rid=r.rid, src=f"prefill{i}",
                               dst=f"decode{j}")
                    self.transfers.append(rec)
                    dw.ingest(KVHandoff(req=r, ctx=r.ctx, tokens=exp.tokens,
                                        chain=exp.chain, pages=staged,
                                        record=rec))
                    progress = True
            for j, dw in enumerate(self.decode):
                if dw.step():
                    progress = True
                if dw.finished:
                    self.finished.extend(dw.finished)
                    dw.finished = []
                while dw.evicted:
                    r = dw.evicted.pop(0)
                    self.prefill[self._home[r.rid]].enqueue(r)
                    progress = True
            if not progress and self._pending():
                raise RuntimeError(
                    "disaggregated engine stalled: a queued request cannot "
                    "be admitted on any worker (pool too small for the "
                    "handoff?)")
        return self.finished

    # ------------------------------------------------------------------
    def transfer_stats(self) -> Dict[str, object]:
        """Aggregated handoff telemetry: wire bytes/pages moved, total and
        exposed transfer seconds, the raw ``(bytes, seconds)`` fit samples,
        and decode-side dedup (pool writes skipped for resident prefixes)."""
        recs = self.transfers
        return {
            "granularity": self.granularity,
            "mode": self.mode,
            "handoffs": len(recs),
            "bytes": int(sum(r["bytes"] for r in recs)),
            "pages": int(sum(r["pages"] for r in recs)),
            "total_s": float(sum(r["total_s"] for r in recs)),
            "exposed_s": float(sum(r["exposed_s"] for r in recs)),
            "samples": [s for r in recs for s in r["samples"]],
            "dedup_blocks": int(sum(w.store.import_dedup_blocks
                                    for w in self.decode)),
            "cross_device": any(r["staged"] == "device" for r in recs),
        }

    def kv_stats(self) -> Dict[str, Dict[str, float]]:
        return {
            **{f"prefill{i}": w.kv_stats()
               for i, w in enumerate(self.prefill)},
            **{f"decode{j}": w.kv_stats()
               for j, w in enumerate(self.decode)},
        }


def oracle_engine(cfg: ModelConfig, params=None, **kw) -> Engine:
    """The single-device ``Engine`` with the same geometry kwargs
    ``DisaggEngine`` takes — convenience for parity harnesses that build
    both sides from one kwarg dict."""
    kw.pop("n_prefill", None)
    kw.pop("n_decode", None)
    kw.pop("mode", None)
    kw.pop("granularity", None)
    kw.pop("devices", None)
    kw.pop("prefill_blocks", None)
    kw.pop("decode_blocks", None)
    return Engine(cfg, params, **kw)
