"""Real-execution serving engine: HERMES scheduling semantics (continuous
batching, slot-based KV cache, admission control) driving ACTUAL JAX
prefill/decode on a model — the e2e serving driver for examples/.

The simulator (repro.core) predicts this engine's behaviour; the fidelity
benchmark replays the same request schedule through both and compares.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import steps
from repro.models import transformer as tf


@dataclass
class EngineRequest:
    rid: int
    prompt: np.ndarray                       # (p,) int32
    max_new_tokens: int = 32
    eos_id: Optional[int] = None
    submit_time: float = 0.0
    first_token_time: Optional[float] = None
    finish_time: Optional[float] = None
    tokens: List[int] = field(default_factory=list)
    slot: Optional[int] = None

    @property
    def ttft(self):
        return (self.first_token_time - self.submit_time
                if self.first_token_time else None)

    @property
    def tpot(self):
        if self.finish_time is None or self.first_token_time is None:
            return None
        return ((self.finish_time - self.first_token_time)
                / max(1, len(self.tokens) - 1))


class Engine:
    """Continuous-batching engine with fixed decode slots."""

    def __init__(self, cfg: ModelConfig, params=None, max_batch: int = 4,
                 max_len: int = 512, seed: int = 0):
        self.cfg = cfg
        self.max_batch = max_batch
        self.max_len = max_len
        if params is None:
            params, _ = tf.init_model(cfg, jax.random.PRNGKey(seed))
        self.params = params
        self.caches = tf.init_cache(cfg, max_batch, max_len)
        self.active = [None] * max_batch        # slot -> EngineRequest
        self.waiting: List[EngineRequest] = []
        self.finished: List[EngineRequest] = []
        self.steps = 0

        @jax.jit
        def _prefill_one(params, tokens):
            return steps.prefill_step(params, {"tokens": tokens}, cfg, max_len)

        @jax.jit
        def _decode(params, tokens, caches):
            return steps.serve_step(params, tokens, caches, cfg)

        self._prefill_one = _prefill_one
        self._decode = _decode

    # ------------------------------------------------------------------
    def submit(self, prompt: np.ndarray, max_new_tokens: int = 32,
               eos_id: Optional[int] = None) -> EngineRequest:
        r = EngineRequest(rid=len(self.waiting) + len(self.finished)
                          + sum(a is not None for a in self.active),
                          prompt=np.asarray(prompt, np.int32),
                          max_new_tokens=max_new_tokens, eos_id=eos_id,
                          submit_time=time.monotonic())
        self.waiting.append(r)
        return r

    def _write_slot(self, slot: int, req_cache):
        """Copy a single-request cache into batch slot ``slot``."""
        def put(full, one):
            return full.at[:, slot].set(one[:, 0].astype(full.dtype)) \
                if full.ndim >= 2 else full
        self.caches = jax.tree.map(put, self.caches, req_cache)

    def _admit(self):
        for slot in range(self.max_batch):
            if self.active[slot] is not None or not self.waiting:
                continue
            r = self.waiting.pop(0)
            logits, cache1 = self._prefill_one(self.params, r.prompt[None, :])
            tok = int(jnp.argmax(logits, -1)[0])
            now = time.monotonic()
            r.first_token_time = now
            r.tokens.append(tok)
            r.slot = slot
            self._write_slot(slot, cache1)
            self.active[slot] = r

    def _step_decode(self):
        last = np.zeros((self.max_batch, 1), np.int32)
        for s, r in enumerate(self.active):
            if r is not None:
                last[s, 0] = r.tokens[-1]
        new_tok, _, self.caches = self._decode(self.params,
                                               jnp.asarray(last), self.caches)
        new_tok = np.asarray(new_tok)
        now = time.monotonic()
        for s, r in enumerate(self.active):
            if r is None:
                continue
            t = int(new_tok[s])
            r.tokens.append(t)
            done = (len(r.tokens) >= r.max_new_tokens
                    or (r.eos_id is not None and t == r.eos_id)
                    or len(r.prompt) + len(r.tokens) >= self.max_len - 1)
            if done:
                r.finish_time = now
                self.finished.append(r)
                self.active[s] = None
        self.steps += 1

    def run(self, max_steps: int = 100_000) -> List[EngineRequest]:
        while (self.waiting or any(a is not None for a in self.active)) \
                and self.steps < max_steps:
            self._admit()
            if any(a is not None for a in self.active):
                self._step_decode()
        return self.finished

    # --- fault tolerance: preempt & requeue (client-failure analogue) ----
    def preempt_slot(self, slot: int):
        r = self.active[slot]
        if r is None:
            return
        r.tokens = r.tokens[:1]           # keep the streamed first token
        self.active[slot] = None
        self.waiting.insert(0, r)
