"""Real-execution serving engine — compatibility facade.

The monolithic ``Engine`` that used to live here was split into composable
layers (ISSUE 9's disaggregation refactor):

* ``engine/core.py`` — ``EngineCore`` (shared machinery: paged store,
  block tables, admission, preemption, the decode/chunk passes) plus the
  single-device ``Engine``, the dense ``SlotEngine`` oracle and the
  ``make_engine`` factory.
* ``engine/workers.py`` — ``PrefillWorker`` / ``DecodeWorker`` /
  ``DisaggEngine``: disaggregated prefill/decode serving with a real
  KV-page handoff (``PagedKVStore.export_pages`` / ``import_pages``).

Every public name keeps importing from here; existing tests and benchmarks
run unmodified.
"""
from repro.engine.core import (      # noqa: F401
    Engine,
    EngineConfig,
    EngineCore,
    EngineRequest,
    SlotEngine,
    make_engine,
    paged_supported,
)

__all__ = [
    "Engine",
    "EngineConfig",
    "EngineCore",
    "EngineRequest",
    "SlotEngine",
    "make_engine",
    "paged_supported",
]
