"""Physical paged-KV bookkeeping for the real-execution engine.

``repro.core.memory.PagedKVAllocator`` models paged KV for the *simulator* —
block tables over a virtual byte pool. This module is its real-execution
twin: the same allocator semantics (fixed-size blocks, free list, refcounted
prefix sharing, cached refcount-0 radix blocks with LRU leaf-first reclaim,
swap/recompute preemption), but the blocks here index actual device arrays —
the pooled ``(num_pages, block_tokens, kvh, hd)`` K/V tensors built by
``models.transformer.init_paged_cache``. The store tracks *which* physical
page holds *what*; the ``Engine`` in ``runner.py`` owns the JAX arrays and
performs the actual scatter/gather/device-transfers the store's decisions
imply.

Mirrored semantics (kept deliberately parallel to ``core/memory.py`` so the
fidelity benchmark compares like with like — see ``docs/architecture.md``):

* **Admission** reserves ``ceil(tokens / block_tokens)`` whole blocks; blocks
  whose block-aligned prompt-content hash chain is already resident are
  *shared* (refcount bump, no new page) and the rest come off the free list.
* **Growth** faults one block in at a time; exhaustion first reclaims cached
  radix blocks (LRU, leaf-first), then reports failure so the engine can
  preempt a victim.
* **Release** decrefs; registered blocks whose refcount hits 0 stay resident
  as evictable cache, everything else returns to the free list.
* **Swap-out** only moves refcount-1 tables (a shared page cannot leave the
  device without stranding its other owners — shared victims degrade to
  recompute), cascade-unregisters the chain so cached descendants never
  survive as orphans, and hands the engine the block list whose pages must
  move device → host.
* **Recompute drop** releases everything; the engine re-prefills on
  re-admission (keeping the tokens generated so far — the resume prompt is
  ``prompt + generated[:-1]``).
* **Speculative forks** (``fork_table`` / ``commit_fork`` / ``abort_fork``)
  extend a table by k tentative KV slots behind a copy-on-write boundary:
  shared or radix-registered pages in the write range are swapped for
  private copies and fresh pages are grown, so the draft-and-verify engine
  can reject speculation without ever having written a page someone else
  can see — the real-execution twin of the simulator's PR-2 radix COW.

Unlike the simulator allocator there is no overcommit: a physical pool
cannot hold more pages than it has, so an allocation that cannot be met even
after preemption is the caller's error (the engine sizes ``max_len`` against
the pool at submit).
"""
from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np


def prefix_chain(tokens: Sequence[int], block_tokens: int) -> List[int]:
    """Block-aligned content-hash chain over a prompt: one hash per *full*
    block, each chained over its parent so equal chains imply equal
    block-aligned prefixes (the same scheme the simulator's workload layer
    feeds ``PagedKVAllocator``). The partial tail block never registers."""
    out: List[int] = []
    h = 0
    n_full = len(tokens) // block_tokens
    for i in range(n_full):
        blk = tuple(int(t) for t in
                    tokens[i * block_tokens:(i + 1) * block_tokens])
        h = hash((h, blk))
        out.append(h)
    return out


class _Node:
    __slots__ = ("hash", "block", "parent", "children")

    def __init__(self, h: int, block: int, parent: Optional["_Node"]):
        self.hash = h
        self.block = block
        self.parent = parent
        self.children: Dict[int, "_Node"] = {}


@dataclass
class PagedTable:
    """Per-request physical page map."""
    rid: int
    blocks: List[int] = field(default_factory=list)
    tokens: int = 0                    # KV slots actually filled
    hashes: List[int] = field(default_factory=list)  # registered chain prefix
    chain: List[int] = field(default_factory=list)   # full prompt hash chain
    on_device: bool = True
    host_pages: Optional[Dict] = None  # leaf-path -> np.ndarray when swapped


@dataclass
class PageExport:
    """A prefill-side handoff snapshot (``PagedKVStore.export_pages``): the
    filled physical blocks (table order), fill length, and the prompt hash
    chain the importing store dedups against."""
    rid: int
    blocks: List[int]
    tokens: int
    chain: List[int]


@dataclass
class Fork:
    """An in-flight speculative extension of one table (``fork_table``).

    Holds everything needed to abort back to the pre-fork state: the
    original block list / fill length / registered-chain prefix, which
    shared-or-registered blocks were COW'd out (``(index, old, new)``), and
    which fresh blocks were grown past the original table. COW'd-out
    original blocks stay refcounted by the fork itself until commit/abort
    resolves who keeps them."""
    rid: int
    base_blocks: List[int]
    base_tokens: int
    base_hashes: List[int]
    cow: List[Tuple[int, int, int]] = field(default_factory=list)
    grown: List[int] = field(default_factory=list)


class PagedKVStore:
    """Free list + refcounts + radix prefix index over a physical page pool.

    ``num_blocks`` allocatable pages (the engine's pool additionally carries
    one trash page at index ``num_blocks``, which this store never hands
    out)."""

    def __init__(self, num_blocks: int, block_tokens: int):
        assert num_blocks >= 1 and block_tokens >= 1
        self.num_blocks = int(num_blocks)
        self.block_tokens = int(block_tokens)
        self.trash_block = self.num_blocks      # engine's sentinel page id
        self._free: List[int] = list(range(self.num_blocks - 1, -1, -1))
        self.tables: Dict[int, PagedTable] = {}
        self.forks: Dict[int, Fork] = {}        # rid -> active fork
        self.refcount: Dict[int, int] = {}
        self.nodes: Dict[int, _Node] = {}       # chain hash -> node
        self.by_block: Dict[int, int] = {}      # block -> chain hash
        self._cached: "OrderedDict[int, None]" = OrderedDict()  # rc-0, LRU
        # counters (mirrors of the simulator allocator's stats surface)
        self.page_faults = 0
        self.admission_failures = 0
        self.swap_outs = 0
        self.swap_ins = 0
        self.recompute_drops = 0
        self.radix_evictions = 0
        self.prefix_hit_blocks = 0
        self.prefix_hit_tokens = 0
        self.block_refs_total = 0
        self.blocks_allocated_total = 0
        self.peak_blocks = 0
        # disaggregated handoff accounting (export_pages / import_pages)
        self.exports = 0
        self.exported_blocks = 0
        self.imports = 0
        self.imported_blocks = 0
        self.import_dedup_blocks = 0

    # -- capacity ------------------------------------------------------------
    @property
    def free_blocks(self) -> int:
        return len(self._free)

    @property
    def cached_blocks(self) -> int:
        return len(self._cached)

    @property
    def available_blocks(self) -> int:
        return len(self._free) + len(self._cached)

    @property
    def used_blocks(self) -> int:
        return self.num_blocks - len(self._free) - len(self._cached)

    def blocks_for_tokens(self, tokens: int) -> int:
        return max(0, -(-int(tokens) // self.block_tokens))

    # -- radix index ---------------------------------------------------------
    def match(self, chain: Sequence[int]) -> List[int]:
        out: List[int] = []
        for h in chain:
            node = self.nodes.get(h)
            if node is None:
                break
            out.append(node.block)
        return out

    def _register(self, h: int, block: int, parent_hash: Optional[int]) -> bool:
        if h in self.nodes:
            return False                       # collision: chain ends here
        parent = self.nodes.get(parent_hash) if parent_hash is not None else None
        node = _Node(h, block, parent)
        self.nodes[h] = node
        self.by_block[block] = h
        if parent is not None:
            parent.children[h] = node
        return True

    def _unregister(self, block: int):
        h = self.by_block.pop(block, None)
        if h is None:
            return
        node = self.nodes.pop(h)
        self._cached.pop(block, None)
        if node.parent is not None:
            node.parent.children.pop(h, None)

    def _unregister_subtree(self, block: int) -> List[int]:
        """Unregister a block's node and every registered descendant (swap-out
        path). Returns cached descendant blocks that must return to the free
        list — they lost their only reason to stay resident."""
        h = self.by_block.get(block)
        if h is None:
            return []
        freed: List[int] = []
        stack = list(self.nodes[h].children.values())
        while stack:
            node = stack.pop()
            stack.extend(node.children.values())
            del self.nodes[node.hash]
            del self.by_block[node.block]
            if node.block in self._cached:
                del self._cached[node.block]
                freed.append(node.block)
        self._unregister(block)
        return freed

    def _evict_one(self) -> Optional[int]:
        """Reclaim the LRU cached *leaf* (a parent may not go before its
        registered children, so chains never get holes)."""
        for block in self._cached:             # insertion order == LRU
            if not self.nodes[self.by_block[block]].children:
                self._unregister(block)
                return block
        return None

    def _reclaim(self, n: int):
        while len(self._free) < n:
            b = self._evict_one()
            if b is None:
                break
            self._free.append(b)
            self.radix_evictions += 1

    # -- refcounts -----------------------------------------------------------
    def _incref(self, b: int):
        rc = self.refcount.get(b, 0) + 1
        self.refcount[b] = rc
        self.block_refs_total += 1
        if rc == 1:
            self._cached.pop(b, None)          # cached -> live

    def _decref(self, b: int):
        rc = self.refcount[b] - 1
        if rc > 0:
            self.refcount[b] = rc
            return
        del self.refcount[b]
        if b in self.by_block:
            self._cached[b] = None             # live -> cached (MRU end)
            self._cached.move_to_end(b)
        else:
            self._free.append(b)

    def _take(self, n: int) -> List[int]:
        self._reclaim(n)
        assert len(self._free) >= n, "PagedKVStore._take past capacity"
        got = [self._free.pop() for _ in range(n)]
        for b in got:
            self._incref(b)
        self.blocks_allocated_total += len(got)
        self.peak_blocks = max(self.peak_blocks, self.used_blocks)
        return got

    # -- admission / growth / release ----------------------------------------
    def _room_for(self, need_total: int, matched: Sequence[int]) -> bool:
        """Can ``need_total - len(matched)`` new blocks be taken once the
        matched blocks are revived? Matched blocks that are currently cached
        leave the evictable pool on revival, so they cannot also serve the
        unmatched remainder."""
        matched_cached = sum(1 for b in matched if b in self._cached)
        return (need_total - len(matched)
                <= self.available_blocks - matched_cached)

    def can_admit(self, tokens: int, chain: Sequence[int] = ()) -> bool:
        need_total = self.blocks_for_tokens(tokens)
        matched = self.match(chain)[:need_total]
        return self._room_for(need_total, matched)

    def allocate(self, rid: int, tokens: int, chain: Sequence[int] = (),
                 *, filled: Optional[int] = None,
                 context_tokens: Optional[int] = None,
                 count_hits: bool = True
                 ) -> Optional[Tuple[List[int], int]]:
        """Admission. Returns ``(blocks, n_matched)`` — the leading
        ``n_matched`` blocks are shared resident prefix pages the engine
        need not rewrite — or None when the pool (free + evictable cached)
        cannot cover the unmatched remainder.

        Whole-prompt path (defaults): reserve ``blocks_for(tokens)`` and
        declare all ``tokens`` filled (the engine writes them immediately).

        Chunked path: ``tokens`` covers only the FIRST chunk, ``filled=0``
        (nothing written yet — the mixed step fills and ``advance``s chunk
        by chunk, faulting later blocks in via ``grow``), and
        ``context_tokens`` is the full eventual context length. Matched
        prefix blocks are still claimed up to ``blocks_for(context_tokens)``
        — aliasing resident content is free, and it keeps prefix-hit
        accounting identical to the whole-prompt path.

        ``count_hits=False`` claims matched blocks without counting them as
        prefix hits — the decode-side page-import path uses this so handoff
        dedup (wire bytes saved) never inflates the prefix-cache hit rate,
        mirroring the simulator's ``PagedKVAllocator`` convention."""
        assert rid not in self.tables, f"double allocation for rid={rid}"
        context_tokens = int(tokens if context_tokens is None else context_tokens)
        need_chunk = self.blocks_for_tokens(tokens)
        cap = self.blocks_for_tokens(context_tokens)
        matched = self.match(chain)[:cap]
        need_fresh = max(0, need_chunk - len(matched))
        if not self._room_for(len(matched) + need_fresh, matched):
            self.admission_failures += 1
            return None
        for b in matched:
            self._incref(b)
        blocks = matched + self._take(need_fresh)
        t = PagedTable(rid, blocks, int(tokens if filled is None else filled))
        t.chain = list(chain)
        n_reg = min(len(chain), len(blocks))
        for i in range(len(matched), n_reg):
            if not self._register(chain[i], blocks[i],
                                  chain[i - 1] if i else None):
                n_reg = i
                break
        t.hashes = list(chain[:n_reg])
        self.tables[rid] = t
        if matched and count_hits:
            self.prefix_hit_blocks += len(matched)
            self.prefix_hit_tokens += min(context_tokens,
                                          len(matched) * self.block_tokens)
        self.peak_blocks = max(self.peak_blocks, self.used_blocks)
        return blocks, len(matched)

    def needs_block(self, rid: int) -> bool:
        """Would writing one more KV slot require faulting in a page?"""
        t = self.tables[rid]
        return t.tokens >= len(t.blocks) * self.block_tokens

    def grow(self, rid: int) -> Optional[int]:
        """Fault one block in for ``rid``. Returns the new physical block, or
        None (counting a page fault) when nothing is free or evictable — the
        engine then preempts a victim and retries.

        Chain-aware: if the next block's prompt-content hash is resident
        (another request registered it since this one's admission — e.g.
        concurrent chunked prefills of a shared prefix), the resident page is
        aliased (refcount bump, no free block consumed). Fresh blocks whose
        chain position is known register as they are faulted in, so a
        chunked prefill publishes its prefix block by block exactly like a
        whole prefill publishes at admission."""
        assert rid not in self.forks, \
            f"rid={rid}: grow during an active fork (fork_table sizes growth)"
        t = self.tables[rid]
        assert t.on_device
        i = len(t.blocks)
        if i < len(t.chain):
            node = self.nodes.get(t.chain[i])
            if node is not None and (i == 0 or self.by_block.get(
                    t.blocks[i - 1]) == t.chain[i - 1]):
                self._incref(node.block)
                t.blocks.append(node.block)
                if i == len(t.hashes):
                    t.hashes.append(t.chain[i])
                self.prefix_hit_blocks += 1
                self.prefix_hit_tokens += self.block_tokens
                self.peak_blocks = max(self.peak_blocks, self.used_blocks)
                return node.block
        if self.available_blocks < 1:
            self.page_faults += 1
            return None
        (b,) = self._take(1)
        t.blocks.append(b)
        if i == len(t.hashes) and i < len(t.chain):
            if self._register(t.chain[i], b, t.chain[i - 1] if i else None):
                t.hashes.append(t.chain[i])
        return b

    def advance(self, rid: int, n: int = 1):
        assert rid not in self.forks, \
            f"rid={rid}: advance during an active fork (use commit_fork)"
        t = self.tables[rid]
        t.tokens += n
        assert t.tokens <= len(t.blocks) * self.block_tokens, \
            f"rid={rid} wrote past its block table"

    # -- speculative forks ---------------------------------------------------
    def fork_table(self, rid: int, extra_tokens: int) -> Optional[Fork]:
        """Open a copy-on-write fork covering ``extra_tokens`` speculative
        KV slots past the table's fill front.

        Any block in the speculative write range (block index
        ``>= tokens // block_tokens``) that is shared (refcount > 1) or
        registered in the radix index is COW'd out: the table row gets a
        fresh private page (the engine device-copies the old page's content
        into it before writing) and the original keeps its refcount — held
        by the fork — so shared owners and the prefix cache can never see a
        speculative write, accepted or not. Fresh blocks are then grown so
        the table covers ``tokens + extra_tokens`` slots. Exactly one of
        ``commit_fork`` / ``abort_fork`` must follow.

        Returns None (counting a page fault) when the pool cannot supply
        the fresh pages — nothing is mutated; the engine preempts a victim
        and retries, the same contract as ``grow``."""
        t = self.tables[rid]
        assert t.on_device, "cannot fork a swapped table"
        assert rid not in self.forks, f"rid={rid} already has an active fork"
        assert extra_tokens >= 0
        need_total = self.blocks_for_tokens(t.tokens + extra_tokens)
        first_write = t.tokens // self.block_tokens
        cow_idx = [i for i in range(first_write, len(t.blocks))
                   if self.refcount.get(t.blocks[i], 1) > 1
                   or t.blocks[i] in self.by_block]
        n_fresh = max(0, need_total - len(t.blocks)) + len(cow_idx)
        self._reclaim(n_fresh)
        if len(self._free) < n_fresh:
            self.page_faults += 1
            return None
        fork = Fork(rid, list(t.blocks), t.tokens, list(t.hashes))
        fresh = self._take(n_fresh)
        for i, nb in zip(cow_idx, fresh[:len(cow_idx)]):
            fork.cow.append((i, t.blocks[i], nb))
            t.blocks[i] = nb
        if cow_idx:
            # the table's blocks no longer follow the registered chain past
            # the first COW point (the replacement page is unregistered)
            t.hashes = t.hashes[:cow_idx[0]]
        fork.grown = fresh[len(cow_idx):]
        t.blocks.extend(fork.grown)
        self.forks[rid] = fork
        self.peak_blocks = max(self.peak_blocks, self.used_blocks)
        return fork

    def commit_fork(self, rid: int, n_tokens: int):
        """Accept ``n_tokens`` speculative tokens: the forked layout becomes
        the table's real state. COW'd-out originals are released (shared
        owners / the radix cache keep them alive); grown blocks beyond the
        committed fill front return to the free list — but never blocks the
        table already held before the fork."""
        f = self.forks.pop(rid)
        t = self.tables[rid]
        assert n_tokens >= 0
        t.tokens = f.base_tokens + n_tokens
        assert t.tokens <= len(t.blocks) * self.block_tokens, \
            f"rid={rid} committed past its forked table"
        for _, old, _ in f.cow:
            self._decref(old)
        keep = max(self.blocks_for_tokens(t.tokens), len(f.base_blocks))
        for b in reversed(t.blocks[keep:]):
            self._decref(b)
        del t.blocks[keep:]

    def abort_fork(self, rid: int):
        """Reject the speculation entirely: restore the pre-fork table.
        COW replacement pages and grown pages are released; the originals
        (kept alive by the fork's refcounts) return to the table row. The
        fill front is untouched, so shared-prefix content is exactly as it
        was — speculative writes only ever landed in pages this fork owned
        privately."""
        f = self.forks.pop(rid)
        t = self.tables[rid]
        for b in reversed(f.grown):
            self._decref(b)
        for _, _, new in f.cow:
            self._decref(new)
        t.blocks = list(f.base_blocks)
        t.hashes = list(f.base_hashes)
        t.tokens = f.base_tokens

    def free(self, rid: int):
        """Release every reference (completion). Registered blocks stay
        resident as evictable cache; the rest return to the free list."""
        assert rid not in self.forks, \
            f"rid={rid}: free during an active fork (resolve it first)"
        t = self.tables.pop(rid)
        if not t.on_device:
            t.host_pages = None
            return
        for b in reversed(t.blocks):           # leaf-before-parent LRU aging
            self._decref(b)

    # -- preemption ----------------------------------------------------------
    def swap_out(self, rid: int) -> Optional[List[int]]:
        """Begin swap-out: returns the block ids whose pages the engine must
        gather to host, or None when the table holds shared (refcount > 1)
        pages — those victims degrade to recompute, exactly like the
        simulator's composition rule. The store releases the device blocks;
        the engine stores the gathered pages on the table record."""
        assert rid not in self.forks, \
            f"rid={rid}: swap_out during an active fork (abort it first)"
        t = self.tables[rid]
        assert t.on_device
        keep = self.blocks_for_tokens(t.tokens)
        kept, tail = t.blocks[:keep], t.blocks[keep:]
        if any(self.refcount.get(b, 1) > 1 for b in kept):
            return None
        # Unfilled tail blocks (chunked prefill reserves ahead of the fill
        # front) are simply released, not swapped — there is nothing of this
        # request's in them. A registered tail block someone else still
        # shares keeps its registration; a refcount-1 registered one parks
        # as evictable cache; the rest return to the free list. This runs
        # BEFORE the kept-block unregister walk so cascades see tail blocks
        # in their settled (cached) state.
        for b in reversed(tail):
            self._decref(b)
        t.hashes = t.hashes[:keep]
        for b in kept:
            for fb in self._unregister_subtree(b):
                self._free.append(fb)
                self.radix_evictions += 1
            self._decref(b)
        t.blocks = []
        t.hashes = []
        t.on_device = False
        self.swap_outs += 1
        return kept

    def swap_in(self, rid: int) -> Optional[List[int]]:
        """Allocate fresh device blocks for a swapped table. Returns the new
        block ids (the engine scatters ``host_pages`` into them) or None when
        the pool cannot hold the table yet."""
        t = self.tables[rid]
        assert not t.on_device
        n = self.blocks_for_tokens(t.tokens)
        if n > self.available_blocks:
            return None
        t.blocks = self._take(n)
        t.on_device = True
        self.swap_ins += 1
        return t.blocks

    def drop(self, rid: int):
        """Recompute preemption: discard the table entirely (pages are dead;
        the engine re-prefills from tokens on re-admission)."""
        self.free(rid)
        self.recompute_drops += 1

    # -- disaggregated handoff (export on prefill side, import on decode) ----
    def export_pages(self, rid: int) -> "PageExport":
        """Snapshot the FILLED portion of ``rid``'s table for a
        prefill->decode handoff: the physical block ids the engine must
        gather (in table order — position ``i`` covers tokens
        ``[i*bt, (i+1)*bt)``), the fill length, and the prompt hash chain
        the importing store dedups against. Mirrors the simulator's
        ``PagedKVAllocator.export_chain`` contract, minus the pin: the
        engine gathers the page payload synchronously before releasing the
        table, so nothing can reclaim the pages mid-export."""
        t = self.tables[rid]
        assert t.on_device, "cannot export a swapped table"
        assert rid not in self.forks, \
            f"rid={rid}: export during an active fork (resolve it first)"
        keep = self.blocks_for_tokens(t.tokens)
        self.exports += 1
        self.exported_blocks += keep
        return PageExport(rid=rid, blocks=list(t.blocks[:keep]),
                          tokens=t.tokens, chain=list(t.chain))

    def import_pages(self, rid: int, tokens: int,
                     chain: Sequence[int] = ()
                     ) -> Optional[Tuple[List[int], int]]:
        """Decode-side admission of an exported table: allocate
        ``blocks_for(tokens)`` pages, aliasing any resident chain prefix —
        the engine then scatters ONLY the unmatched pages' payload (matched
        pages already hold bit-identical content by the hash-chain
        contract: equal chains imply equal block-aligned token prefixes
        imply equal K/V). Returns ``(blocks, n_matched)`` or None when the
        pool cannot admit yet (head-of-line wait, like any admission).

        Matched blocks count as ``import_dedup_blocks`` — wire bytes the
        handoff never had to move — NOT as prefix-cache hits, mirroring the
        simulator's decode-side ``count_hits=False`` convention."""
        got = self.allocate(rid, tokens, chain, count_hits=False)
        if got is None:
            return None
        blocks, n_matched = got
        self.imports += 1
        self.imported_blocks += len(blocks) - n_matched
        self.import_dedup_blocks += n_matched
        return got

    # -- reporting -----------------------------------------------------------
    def check_invariants(self):
        from collections import Counter
        expect: Counter = Counter()
        for t in self.tables.values():
            if t.on_device:
                expect.update(t.blocks)
        for f in self.forks.values():
            # COW'd-out originals are held by the fork until commit/abort
            expect.update(old for _, old, _ in f.cow)
        assert dict(expect) == self.refcount, "refcount drift"
        live = sorted(expect)
        cached = sorted(self._cached)
        assert not set(live) & set(cached), "cached block is live"
        assert sorted(self._free + live + cached) == list(range(self.num_blocks)), \
            "block leak or double allocation"
        for b in self.by_block:
            assert b in expect or b in self._cached, \
                "radix entry points at a non-resident block"
        for rid in self.forks:
            assert rid in self.tables and self.tables[rid].on_device, \
                "fork outlived its table"
        for h, node in self.nodes.items():
            if node.parent is not None:
                assert self.nodes.get(node.parent.hash) is node.parent, \
                    "orphaned node"
                assert node.parent.children.get(h) is node, \
                    "parent lost child link"

    def stats(self) -> Dict[str, float]:
        return {
            "num_blocks": self.num_blocks,
            "block_tokens": self.block_tokens,
            "used_blocks": self.used_blocks,
            "free_blocks": self.free_blocks,
            "cached_blocks": self.cached_blocks,
            "peak_blocks": self.peak_blocks,
            "utilization": self.used_blocks / max(1, self.num_blocks),
            "page_faults": self.page_faults,
            "admission_failures": self.admission_failures,
            "swap_outs": self.swap_outs,
            "swap_ins": self.swap_ins,
            "recompute_drops": self.recompute_drops,
            "radix_evictions": self.radix_evictions,
            "prefix_hit_blocks": self.prefix_hit_blocks,
            "prefix_hit_tokens": self.prefix_hit_tokens,
            "block_refs_total": self.block_refs_total,
            "blocks_allocated_total": self.blocks_allocated_total,
            "dedup_ratio": (self.block_refs_total
                            / max(1, self.blocks_allocated_total)),
            "exports": self.exports,
            "exported_blocks": self.exported_blocks,
            "imports": self.imports,
            "imported_blocks": self.imported_blocks,
            "import_dedup_blocks": self.import_dedup_blocks,
        }
