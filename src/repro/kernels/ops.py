"""jit'd public wrappers around the Pallas kernels with platform dispatch.

On TPU the Pallas kernels run compiled; everywhere else (this CPU container,
and any shape the kernel does not support, e.g. MLA prefill where dq != dv)
the pure-jnp reference implements identical semantics. ``FORCE_REF`` /
``FORCE_INTERPRET`` env knobs exist for tests and benchmarks.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref
from repro.kernels import flash_attention as _fa
from repro.kernels import decode_attention as _da
from repro.kernels import paged_attention as _pa
from repro.kernels import pq_scan as _pq


def _mode() -> str:
    if os.environ.get("REPRO_KERNELS", "").lower() == "ref":
        return "ref"
    if os.environ.get("REPRO_KERNELS", "").lower() == "interpret":
        return "interpret"
    return "pallas" if jax.default_backend() == "tpu" else "ref"


def flash_attention(q, k, v, *, causal: bool = True, scale: float | None = None):
    mode = _mode()
    if mode != "ref" and q.shape[-1] == v.shape[-1] and q.shape[-1] % 128 == 0:
        return _fa.flash_attention(q, k, v, causal=causal, scale=scale,
                                   interpret=(mode == "interpret"))
    s, t = q.shape[1], k.shape[1]
    if s * t > 2048 * 2048:
        bq = 2048 if s <= 8192 else 4096
        return _ref.chunked_flash_attention(q, k, v, causal=causal,
                                            scale=scale, block_q=bq, block_k=bq)
    return _ref.flash_attention(q, k, v, causal=causal, scale=scale)


def decode_attention(q, k_cache, v_cache, lengths, *, scale: float | None = None):
    mode = _mode()
    if mode != "ref" and q.shape[-1] == v_cache.shape[-1] and q.shape[-1] % 128 == 0:
        return _da.decode_attention(q, k_cache, v_cache, lengths, scale=scale,
                                    interpret=(mode == "interpret"))
    return _ref.decode_attention(q, k_cache, v_cache, lengths, scale=scale)


def paged_decode_attention(q, k_pool, v_pool, block_tables, lengths, *,
                           scale: float | None = None):
    """Block-table-indexed decode attention over pooled KV pages (see
    ``kernels.paged_attention`` for the layout contract)."""
    mode = _mode()
    if mode != "ref" and q.shape[-1] == v_pool.shape[-1] \
            and q.shape[-1] % 128 == 0:
        return _pa.paged_decode_attention(q, k_pool, v_pool, block_tables,
                                          lengths, scale=scale,
                                          interpret=(mode == "interpret"))
    return _ref.paged_decode_attention(q, k_pool, v_pool, block_tables,
                                       lengths, scale=scale)


def paged_verify_attention(q, k_pool, v_pool, block_tables, lengths, *,
                           scale: float | None = None):
    """Speculative-verify attention: score all s = k+1 draft positions of
    each row in one pass over the block table (query j sits at logical
    position ``lengths + j``). On CPU the reference unrolls into per-position
    ``decode_attention`` calls, which makes each position bit-identical to a
    sequential paged decode at the same position — the property the engine's
    spec-vs-plain stream-equality contract rests on."""
    mode = _mode()
    if mode != "ref" and q.shape[-1] == v_pool.shape[-1] \
            and q.shape[-1] % 128 == 0:
        return _pa.paged_verify_attention(q, k_pool, v_pool, block_tables,
                                          lengths, scale=scale,
                                          interpret=(mode == "interpret"))
    return _ref.paged_verify_attention(q, k_pool, v_pool, block_tables,
                                       lengths, scale=scale)


def paged_chunk_attention(q, k_pool, v_pool, block_tables, lengths, *,
                          scale: float | None = None):
    """Chunked-prefill attention over pooled KV pages: query j of row r sits
    at logical position ``lengths[r] + j`` and attends over every pooled
    position ``<= lengths[r] + j`` (cached context + causal chunk self).

    No Pallas lowering yet — the chunk pass is prefill-shaped (one big
    matmul per layer, not memory-bound like decode), so the jnp reference
    compiles to the same XLA fusions as whole prefill. Numerics match
    ``flash_attention`` bitwise so chunked K/V + logits reproduce the
    whole-prompt prefill exactly.
    """
    return _ref.paged_chunk_attention(q, k_pool, v_pool, block_tables,
                                      lengths, scale=scale)


def pq_scan(codes, lut):
    mode = _mode()
    if mode != "ref":
        return _pq.pq_scan(codes, lut, interpret=(mode == "interpret"))
    return _ref.pq_scan(codes, lut)
