"""Pallas TPU decode-attention kernel (one new token vs. a padded KV cache).

Decode is memory-bound: the kernel streams K/V tiles HBM->VMEM once, keeps the
(tiny) query tile and the fp32 online-softmax state resident in VMEM, and
masks by per-request cache length. Grid: (batch, kv_heads, kv_blocks) with the
kv dimension minor so scratch carries across tiles.

Interface contract
------------------
``decode_attention(q, k_cache, v_cache, lengths)``

* ``q``       — ``(b, 1, nh, d)`` one new query token per request; GQA
                grouping is ``g = nh // kvh`` (``nh % kvh == 0``).
* ``k_cache`` — ``(b, S, kvh, d)`` contiguous per-request key cache, padded
                to a common ``S``; only rows ``[0, lengths[i])`` are live.
* ``v_cache`` — ``(b, S, kvh, dv)``; ``dv`` may differ from ``d`` (MLA-style
                asymmetric heads).
* ``lengths`` — ``(b,) int32`` valid cache tokens per request. The mask is
                ``pos < lengths``: content at or past ``lengths[i]`` (stale
                pages from a previous slot occupant, zero padding) gets
                probability exactly 0 and can never leak into the output.
                Rows must have ``1 <= lengths[i] <= S`` — a zero-length row
                produces an unspecified garbage row (callers mask dead batch
                slots, they don't zero them).

Returns ``(b, 1, nh, dv)`` in ``q.dtype``. Scores/softmax accumulate in fp32
regardless of cache dtype (``preferred_element_type``), matching the jnp
oracle ``ref.decode_attention`` to fp32 tolerance.

``block_s`` tiles the ``S`` dimension; tiles whose start is past ``lengths``
skip compute entirely, so the cost of a short request in a long-padded batch
is proportional to its own length, not to ``S``. The *paged* variant of this
kernel — same online-softmax structure, but K/V gathered through a
``(b, max_blocks)`` block table over a pooled ``(num_blocks, block_tokens,
kvh, d)`` cache — lives in ``kernels/paged_attention.py``; see
``docs/architecture.md`` for how the two relate to the simulator's allocator.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax 0.4.x names this TPUCompilerParams; newer jax renamed it
_CompilerParams = getattr(pltpu, 'CompilerParams', None) or pltpu.TPUCompilerParams

NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
                   *, scale: float, block_s: int):
    si = pl.program_id(2)
    ns = pl.num_programs(2)

    @pl.when(si == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    length = len_ref[0]
    s_start = si * block_s

    @pl.when(s_start < length)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)                 # (g, d)
        k = k_ref[0, :, 0, :].astype(jnp.float32)           # (bs, d)
        v = v_ref[0, :, 0, :].astype(jnp.float32)           # (bs, dv)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        span = s_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(span < length, s, NEG_INF)
        m_prev, l_prev = m_ref[...], l_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_ref[...] = l_prev * alpha + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(si == ns - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / denom[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scale", "block_s", "interpret"))
def decode_attention(q, k_cache, v_cache, lengths, *, scale: float | None = None,
                     block_s: int = 512, interpret: bool = False):
    """q: (b, 1, nh, d); k/v_cache: (b, S, kvh, d); lengths: (b,) int32."""
    b, _, nh, d = q.shape
    S, kvh = k_cache.shape[1], k_cache.shape[2]
    g = nh // kvh
    dv = v_cache.shape[-1]
    scale = d ** -0.5 if scale is None else scale
    block_s = min(block_s, S)
    ns = pl.cdiv(S, block_s)

    qr = q.reshape(b, kvh, g, d)
    grid = (b, kvh, ns)
    out = pl.pallas_call(
        functools.partial(_decode_kernel, scale=scale, block_s=block_s),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda bi, hi, si: (bi,), memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, g, d), lambda bi, hi, si: (bi, hi, 0, 0)),
            pl.BlockSpec((1, block_s, 1, d), lambda bi, hi, si: (bi, si, hi, 0)),
            pl.BlockSpec((1, block_s, 1, dv), lambda bi, hi, si: (bi, si, hi, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, dv), lambda bi, hi, si: (bi, hi, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, kvh, g, dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g, dv), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(lengths.astype(jnp.int32), qr, k_cache, v_cache)
    return out.reshape(b, 1, nh, dv)
