"""Pallas TPU paged decode-attention kernel (block-table-indexed KV pool).

The dense decode kernel (``decode_attention.py``) streams a *contiguous*
``(b, S)`` cache; this one gathers K/V through a page table instead, so a
request's KV can live in scattered fixed-size physical blocks — the
real-execution twin of the simulator's ``PagedKVAllocator`` layout.

Interface contract
------------------
``paged_decode_attention(q, k_pool, v_pool, block_tables, lengths)``

* ``q``            — ``(b, 1, nh, d)`` one new query token per request.
* ``k_pool``       — ``(num_blocks, block_tokens, kvh, d)`` pooled key pages.
* ``v_pool``       — ``(num_blocks, block_tokens, kvh, dv)`` pooled value
                     pages (``dv`` may differ from ``d``).
* ``block_tables`` — ``(b, max_blocks) int32``; row ``i``'s logical cache is
                     the concatenation ``k_pool[block_tables[i, 0]],
                     k_pool[block_tables[i, 1]], ...`` — i.e. logical token
                     position ``p`` lives at ``(block_tables[i, p // bt],
                     p % bt)``. **Every** entry must be a valid pool index
                     (``0 <= e < num_blocks``): entries past the live length
                     are never *read into the softmax* (masked) but are still
                     *gathered*, so engines pad dead entries with a dedicated
                     trash/zero block, never with ``-1``.
* ``lengths``      — ``(b,) int32`` valid cache tokens per request; the mask
                     is ``pos < lengths``. Must be ``>= 1`` per row (a
                     zero-length row's output is an unspecified garbage row —
                     the engine masks dead slots the same way the dense
                     engine does) and ``<= max_blocks * block_tokens``.

Returns ``(b, 1, nh, dv)`` in ``q.dtype``.

Kernel structure
----------------
Grid ``(batch, kv_heads, max_blocks)`` with the block dimension minor so the
fp32 online-softmax scratch (m, l, acc) carries across a request's pages —
identical to the dense kernel's structure; the only difference is that the
K/V BlockSpec index maps read the physical page id from the scalar-prefetched
block table (``pltpu.PrefetchScalarGridSpec``) instead of slicing a
contiguous cache. Pages whose first token is past ``lengths`` skip compute
entirely (``pl.when``); partial tail pages mask per-position. The reference
oracle (``ref.paged_decode_attention``) gathers the pool into a dense cache
and reuses the dense oracle, which makes paged-vs-dense parity exact.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax 0.4.x names this TPUCompilerParams; newer jax renamed it
_CompilerParams = getattr(pltpu, 'CompilerParams', None) or pltpu.TPUCompilerParams

NEG_INF = -1e30


def _paged_decode_kernel(tab_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
                         m_ref, l_ref, acc_ref, *, scale: float,
                         block_tokens: int):
    bi = pl.program_id(0)
    si = pl.program_id(2)
    ns = pl.num_programs(2)

    @pl.when(si == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    length = len_ref[bi]
    s_start = si * block_tokens

    @pl.when(s_start < length)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)                 # (g, d)
        k = k_ref[0, :, 0, :].astype(jnp.float32)           # (bt, d)
        v = v_ref[0, :, 0, :].astype(jnp.float32)           # (bt, dv)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        span = s_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(span < length, s, NEG_INF)
        m_prev, l_prev = m_ref[...], l_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_ref[...] = l_prev * alpha + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(si == ns - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / denom[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scale", "interpret"))
def paged_decode_attention(q, k_pool, v_pool, block_tables, lengths, *,
                           scale: float | None = None,
                           interpret: bool = False):
    """Block-table decode attention; see the module docstring for the full
    shape/masking contract. ``block_tokens`` is implied by ``k_pool.shape[1]``
    and ``max_blocks`` by ``block_tables.shape[1]``."""
    b, _, nh, d = q.shape
    bt, kvh = k_pool.shape[1], k_pool.shape[2]
    g = nh // kvh
    dv = v_pool.shape[-1]
    max_blocks = block_tables.shape[1]
    scale = d ** -0.5 if scale is None else scale

    qr = q.reshape(b, kvh, g, d)
    grid = (b, kvh, max_blocks)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,          # block_tables, lengths
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, g, d),
                         lambda bi, hi, si, tab, lens: (bi, hi, 0, 0)),
            pl.BlockSpec((1, bt, 1, d),
                         lambda bi, hi, si, tab, lens: (tab[bi, si], 0, hi, 0)),
            pl.BlockSpec((1, bt, 1, dv),
                         lambda bi, hi, si, tab, lens: (tab[bi, si], 0, hi, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, dv),
                               lambda bi, hi, si, tab, lens: (bi, hi, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g, dv), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_paged_decode_kernel, scale=scale, block_tokens=bt),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, kvh, g, dv), q.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(block_tables.astype(jnp.int32), lengths.astype(jnp.int32),
      qr, k_pool, v_pool)
    return out.reshape(b, 1, nh, dv)


def _paged_verify_kernel(tab_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
                         m_ref, l_ref, acc_ref, *, scale: float,
                         block_tokens: int, s: int, g: int):
    """Speculative-verify analogue of ``_paged_decode_kernel``.

    Per (batch row, kv head) the query block holds all ``s = k + 1`` draft
    positions flattened with their query-head group into ``s * g`` rows; row
    ``r`` is draft position ``r // g``, which attends causally over pooled
    positions ``<= length + r // g``. One pass over the page axis scores
    every draft position — the online-softmax scratch simply carries
    ``s * g`` lanes instead of ``g``.
    """
    bi = pl.program_id(0)
    si = pl.program_id(2)
    ns = pl.num_programs(2)

    @pl.when(si == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    length = len_ref[bi]
    s_start = si * block_tokens

    # The furthest-ahead draft position attends through pooled position
    # length + s - 1; later pages hold nothing any query row may read.
    @pl.when(s_start < length + s)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)                 # (s*g, d)
        k = k_ref[0, :, 0, :].astype(jnp.float32)           # (bt, d)
        v = v_ref[0, :, 0, :].astype(jnp.float32)           # (bt, dv)
        sc = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32) * scale
        span = s_start + jax.lax.broadcasted_iota(jnp.int32, sc.shape, 1)
        qpos = length + jax.lax.broadcasted_iota(jnp.int32, sc.shape, 0) // g
        sc = jnp.where(span <= qpos, sc, NEG_INF)
        m_prev, l_prev = m_ref[...], l_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(sc, axis=1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(sc - m_new[:, None])
        l_ref[...] = l_prev * alpha + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(si == ns - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / denom[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scale", "interpret"))
def paged_verify_attention(q, k_pool, v_pool, block_tables, lengths, *,
                           scale: float | None = None,
                           interpret: bool = False):
    """Score ``s = k + 1`` draft positions per row in ONE pass over the block
    table: query ``j`` of row ``i`` sits at logical position
    ``lengths[i] + j`` and attends over pooled positions
    ``<= lengths[i] + j``. The draft tokens' K/V must already be scattered
    into the pools at those positions (caller writes before attending).
    Layout/trash-page conventions are identical to ``paged_decode_attention``;
    the table must cover ``lengths[i] + s`` logical positions per live row.
    Returns ``(b, s, nh, dv)``."""
    b, s, nh, d = q.shape
    bt, kvh = k_pool.shape[1], k_pool.shape[2]
    g = nh // kvh
    dv = v_pool.shape[-1]
    max_blocks = block_tables.shape[1]
    scale = d ** -0.5 if scale is None else scale

    # (b, s, nh, d) -> (b, kvh, s*g, d): draft position major, group minor,
    # so kernel row r maps to (position r // g, group r % g).
    qr = q.reshape(b, s, kvh, g, d).transpose(0, 2, 1, 3, 4)
    qr = qr.reshape(b, kvh, s * g, d)
    grid = (b, kvh, max_blocks)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,          # block_tables, lengths
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, s * g, d),
                         lambda bi, hi, si, tab, lens: (bi, hi, 0, 0)),
            pl.BlockSpec((1, bt, 1, d),
                         lambda bi, hi, si, tab, lens: (tab[bi, si], 0, hi, 0)),
            pl.BlockSpec((1, bt, 1, dv),
                         lambda bi, hi, si, tab, lens: (tab[bi, si], 0, hi, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, s * g, dv),
                               lambda bi, hi, si, tab, lens: (bi, hi, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((s * g,), jnp.float32),
            pltpu.VMEM((s * g,), jnp.float32),
            pltpu.VMEM((s * g, dv), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_paged_verify_kernel, scale=scale, block_tokens=bt,
                          s=s, g=g),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, kvh, s * g, dv), q.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(block_tables.astype(jnp.int32), lengths.astype(jnp.int32),
      qr, k_pool, v_pool)
    return out.reshape(b, kvh, s, g, dv).transpose(0, 2, 1, 3, 4) \
              .reshape(b, s, nh, dv)
