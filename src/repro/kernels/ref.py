"""Pure-jnp oracles for every Pallas kernel (the source of truth in tests)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention(q, k, v, *, causal: bool = True, scale: float | None = None):
    """GQA-aware softmax attention.

    q: (b, s, nh, dq)  k: (b, t, kvh, dq)  v: (b, t, kvh, dv); nh % kvh == 0.
    """
    b, s, nh, dq = q.shape
    t, kvh = k.shape[1], k.shape[2]
    g = nh // kvh
    scale = dq ** -0.5 if scale is None else scale
    qr = q.reshape(b, s, kvh, g, dq)
    scores = jnp.einsum("bskgh,btkh->bkgst", qr.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if causal:
        mask = jnp.arange(t)[None, :] <= jnp.arange(s)[:, None]  # (s, t)
        scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkh->bskgh", probs, v.astype(jnp.float32))
    return out.reshape(b, s, nh, v.shape[-1]).astype(q.dtype)


def chunked_flash_attention(q, k, v, *, causal: bool = True,
                            scale: float | None = None,
                            block_q: int = 2048, block_k: int = 2048):
    """Blockwise online-softmax attention in pure jnp (python-unrolled blocks).

    Semantics identical to ``flash_attention``; the working set per step is
    one (block_q x block_k) score tile instead of the full (s x t) matrix.
    This is the XLA-lowerable analogue of the Pallas flash kernel and is what
    the models use for long sequences off-TPU (incl. the dry-run).
    """
    b, s, nh, dq = q.shape
    t, kvh = k.shape[1], k.shape[2]
    g = nh // kvh
    dv = v.shape[-1]
    scale = dq ** -0.5 if scale is None else scale
    qr = q.reshape(b, s, kvh, g, dq)
    out_blocks = []
    for qs in range(0, s, block_q):
        qe = min(qs + block_q, s)
        qb = qr[:, qs:qe].astype(jnp.float32)
        m = jnp.full((b, kvh, g, qe - qs), NEG_INF, jnp.float32)
        l = jnp.zeros((b, kvh, g, qe - qs), jnp.float32)
        acc = jnp.zeros((b, kvh, g, qe - qs, dv), jnp.float32)
        for ks in range(0, t, block_k):
            if causal and ks > qe - 1:
                break
            ke = min(ks + block_k, t)
            kb = k[:, ks:ke].astype(jnp.float32)
            vb = v[:, ks:ke].astype(jnp.float32)
            sc = jnp.einsum("bskgh,btkh->bkgst", qb, kb) * scale
            if causal:
                mask = (jnp.arange(ks, ke)[None, :]
                        <= jnp.arange(qs, qe)[:, None])
                sc = jnp.where(mask[None, None, None], sc, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(sc, axis=-1))
            p = jnp.exp(sc - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l = l * alpha + jnp.sum(p, axis=-1)
            acc = acc * alpha[..., None] + jnp.einsum("bkgst,btkh->bkgsh", p, vb)
            m = m_new
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        out_blocks.append(jnp.moveaxis(out, 3, 1))          # (b,sq,kvh,g,dv)
    full = jnp.concatenate(out_blocks, axis=1)
    return full.reshape(b, s, nh, dv).astype(q.dtype)


def decode_attention(q, k_cache, v_cache, lengths, *, scale: float | None = None):
    """One-token decode attention against a padded cache.

    q: (b, 1, nh, dq); k_cache/v_cache: (b, S, kvh, d*); lengths: (b,) number
    of valid cache entries (mask is ``pos < lengths``).
    """
    b, _, nh, dq = q.shape
    S, kvh = k_cache.shape[1], k_cache.shape[2]
    g = nh // kvh
    scale = dq ** -0.5 if scale is None else scale
    qr = q.reshape(b, kvh, g, dq)
    # bf16 operands + fp32 accumulation (preferred_element_type): avoids
    # materializing an fp32 copy of the whole cache (the MXU-native contract)
    scores = jnp.einsum("bkgh,bSkh->bkgS", qr, k_cache,
                        preferred_element_type=jnp.float32) * scale
    valid = jnp.arange(S)[None, :] < lengths[:, None]        # (b, S)
    scores = jnp.where(valid[:, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgS,bSkh->bkgh", probs.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, 1, nh, v_cache.shape[-1]).astype(q.dtype)


def gather_paged_kv(pool, block_tables):
    """Reassemble dense per-request caches from a paged pool.

    pool: (num_blocks, block_tokens, ...); block_tables: (b, max_blocks)
    int32. Returns (b, max_blocks * block_tokens, ...) — logical token
    position p of request i is pool[block_tables[i, p // bt], p % bt].
    """
    gathered = pool[block_tables]                  # (b, mb, bt, ...)
    b, mb, bt = gathered.shape[:3]
    return gathered.reshape(b, mb * bt, *pool.shape[2:])


def paged_decode_attention(q, k_pool, v_pool, block_tables, lengths, *,
                           scale: float | None = None):
    """Paged decode-attention oracle: gather the pools into dense caches and
    defer to ``decode_attention``. Masked (beyond-``lengths``) positions
    contribute exactly zero probability, so the gather's garbage content in
    dead table entries cannot perturb the result — paged output is
    bit-identical to the dense oracle on the same logical cache."""
    k = gather_paged_kv(k_pool, block_tables)
    v = gather_paged_kv(v_pool, block_tables)
    return decode_attention(q, k, v, lengths, scale=scale)


def paged_chunk_attention(q, k_pool, v_pool, block_tables, lengths, *,
                          scale: float | None = None):
    """Chunked-prefill attention over paged KV (the continuation-state path).

    q: (b, s, nh, dq) — row ``r`` holds a *chunk* of prompt positions whose
    logical offsets are ``lengths[r] + j`` for in-chunk index ``j``; the
    chunk's own K/V must already be written into the pools at those
    positions (the caller scatters before attending). Query ``j`` attends
    over pooled positions ``< lengths[r] + j + 1`` — all previously cached
    context plus the causal part of the chunk itself.

    Numerics deliberately mirror ``flash_attention`` (fp32 score/prob path),
    NOT ``decode_attention``: a chunk position must produce bit-identical
    K/V and logits to the same position inside a whole-prompt prefill, and
    whole-prompt prefill runs through ``flash_attention``. Masked positions
    contribute probability exactly 0 (exp(NEG_INF - m) underflows to 0.0),
    so trash/garbage beyond a row's coverage cannot perturb the output —
    the same exact-zero contract the paged decode oracle relies on.

    Rows beyond their valid chunk (the caller's padding) and dead rows
    produce garbage outputs the caller ignores.
    """
    k = gather_paged_kv(k_pool, block_tables)          # (b, S, kvh, dq)
    v = gather_paged_kv(v_pool, block_tables)          # (b, S, kvh, dv)
    b, s, nh, dq = q.shape
    S, kvh = k.shape[1], k.shape[2]
    g = nh // kvh
    scale = dq ** -0.5 if scale is None else scale
    qr = q.reshape(b, s, kvh, g, dq)
    scores = jnp.einsum("bskgh,btkh->bkgst", qr.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    qpos = lengths[:, None] + jnp.arange(s)[None, :]   # (b, s) logical pos
    mask = jnp.arange(S)[None, None, :] <= qpos[:, :, None]     # (b, s, S)
    scores = jnp.where(mask[:, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkh->bskgh", probs, v.astype(jnp.float32))
    return out.reshape(b, s, nh, v.shape[-1]).astype(q.dtype)


def paged_verify_attention(q, k_pool, v_pool, block_tables, lengths, *,
                           scale: float | None = None):
    """Speculative-verify attention over paged KV: score k draft tokens (plus
    the preceding committed token) in ONE target pass over the block table.

    q: (b, s, nh, dq) with s = k + 1 — query ``j`` of row ``r`` sits at
    logical position ``lengths[r] + j`` and attends over pooled positions
    ``< lengths[r] + j + 1`` (cached context + itself + earlier draft
    positions). The draft tokens' K/V must already be scattered into the
    pools at those positions (the caller writes before attending, exactly
    like the chunk pass).

    Numerics deliberately mirror ``decode_attention``, NOT the chunk path:
    position ``j``'s output must be bit-identical to what a sequential
    one-token decode (``paged_decode_attention`` with ``lengths + j + 1``)
    would produce at the same position, because the engine's bit-equality
    contract compares the speculative stream against plain greedy decode.
    The python unroll over the (small, static) ``s`` makes that exact by
    construction: each position IS the decode oracle. Masked positions
    contribute probability exactly 0, so garbage beyond a row's span
    (trash page, rejected writes from earlier iterations) cannot perturb
    the output.
    """
    k = gather_paged_kv(k_pool, block_tables)
    v = gather_paged_kv(v_pool, block_tables)
    outs = [decode_attention(q[:, j:j + 1], k, v, lengths + j + 1, scale=scale)
            for j in range(q.shape[1])]
    return jnp.concatenate(outs, axis=1)


def pq_scan(codes, lut):
    """IVF-PQ asymmetric-distance scan.

    codes: (N, M) uint8/int32 PQ codes; lut: (M, K) per-subquantizer distance
    table for one query. Returns (N,) float32 total distances.
    """
    codes = codes.astype(jnp.int32)
    gathered = jnp.take_along_axis(lut.astype(jnp.float32).T, codes, axis=0)
    # lut.T: (K, M); take_along_axis over axis 0 with (N, M) indices -> (N, M)
    return jnp.sum(gathered, axis=-1)
