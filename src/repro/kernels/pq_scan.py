"""Pallas TPU IVF-PQ ADC-scan kernel (the RAG retrieval hot loop).

GPU implementations keep the per-query distance LUT in shared memory and
gather per-code — TPUs have no per-lane gather into scratch, so the scan is
reformulated MXU/VPU-natively: codes are expanded against an iota over the
codebook axis and reduced against the LUT, i.e. a masked sum instead of a
gather (DESIGN.md §3). The LUT (M x K fp32, ~16 KB) stays VMEM-resident across
all N tiles; codes stream HBM->VMEM once.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax 0.4.x names this TPUCompilerParams; newer jax renamed it
_CompilerParams = getattr(pltpu, 'CompilerParams', None) or pltpu.TPUCompilerParams


def _pq_kernel(codes_ref, lut_ref, out_ref):
    codes = codes_ref[...].astype(jnp.int32)        # (bn, M)
    lut = lut_ref[...].astype(jnp.float32)          # (M, K)
    K = lut.shape[1]
    # one-hot over the codebook axis; contraction runs on the VPU/MXU instead
    # of a per-element gather.
    onehot = (codes[:, :, None] ==
              jax.lax.broadcasted_iota(jnp.int32, (1, 1, K), 2)).astype(jnp.float32)
    out_ref[...] = jnp.sum(onehot * lut[None], axis=(1, 2))


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def pq_scan(codes, lut, *, block_n: int = 1024, interpret: bool = False):
    """codes: (N, M) integer PQ codes; lut: (M, K) distances. -> (N,) f32."""
    N, M = codes.shape
    K = lut.shape[1]
    block_n = min(block_n, N)
    grid = (pl.cdiv(N, block_n),)
    return pl.pallas_call(
        _pq_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, M), lambda i: (i, 0)),
            pl.BlockSpec((M, K), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_n,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((N,), jnp.float32),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel",),
        ),
        interpret=interpret,
    )(codes.astype(jnp.int32), lut)
