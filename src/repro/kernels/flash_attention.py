"""Pallas TPU flash-attention (prefill) kernel.

TPU adaptation of FlashAttention: HBM->VMEM tiling via BlockSpec, online
softmax with fp32 running max/denominator kept in VMEM scratch across the
minor (kv) grid dimension, MXU-shaped (128-aligned) tiles. GQA is handled in
the index_map (q-head h reads kv-head h // group).

Grid: (batch, q_heads, num_q_blocks, num_kv_blocks) — the kv dimension is the
minor-most so scratch carries across kv steps for a fixed q tile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax 0.4.x names this TPUCompilerParams; newer jax renamed it
_CompilerParams = getattr(pltpu, 'CompilerParams', None) or pltpu.TPUCompilerParams

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
                  *, scale: float, causal: bool, block_q: int, block_k: int,
                  seq_k: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = qi * block_q
    k_start = ki * block_k

    should_run = True
    if causal:
        # skip kv tiles strictly above the causal diagonal
        should_run = k_start <= q_start + block_q - 1

    @pl.when(should_run)
    def _compute():
        q = q_ref[0, :, 0, :].astype(jnp.float32)          # (bq, d)
        k = k_ref[0, :, 0, :].astype(jnp.float32)          # (bk, d)
        v = v_ref[0, :, 0, :].astype(jnp.float32)          # (bk, dv)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        spans_q = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        spans_k = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = spans_k < seq_k
        if causal:
            mask = mask & (spans_k <= spans_q)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]
        l_prev = l_ref[...]
        m_cur = jnp.max(s, axis=1)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_new = l_prev * alpha + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_ref[...] = m_new
        l_ref[...] = l_new

    @pl.when(ki == nk - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, :, 0, :] = (acc_ref[...] / denom[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "scale", "block_q",
                                             "block_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, scale: float | None = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = False):
    """q: (b, s, nh, d), k/v: (b, t, kvh, d). Requires dq == dv."""
    b, s, nh, d = q.shape
    t, kvh = k.shape[1], k.shape[2]
    g = nh // kvh
    scale = d ** -0.5 if scale is None else scale
    block_q = min(block_q, s)
    block_k = min(block_k, t)
    nq = pl.cdiv(s, block_q)
    nk = pl.cdiv(t, block_k)

    grid = (b, nh, nq, nk)
    out = pl.pallas_call(
        functools.partial(_flash_kernel, scale=scale, causal=causal,
                          block_q=block_q, block_k=block_k, seq_k=t),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, 1, d), lambda bi, hi, qi, ki: (bi, qi, hi, 0)),
            pl.BlockSpec((1, block_k, 1, d), lambda bi, hi, qi, ki: (bi, ki, hi // g, 0)),
            pl.BlockSpec((1, block_k, 1, d), lambda bi, hi, qi, ki: (bi, ki, hi // g, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, 1, d), lambda bi, hi, qi, ki: (bi, qi, hi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, s, nh, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(q, k, v)
    return out
