"""Chrome-trace JSON export of request execution (paper §III-F2)."""
from __future__ import annotations

import json
from typing import List

from repro.core.request import Request


def to_chrome_trace(requests: List[Request], path: str):
    events = []
    for r in requests:
        for st in r.stages:
            if st.start_time is None or st.end_time is None:
                continue
            events.append({
                "name": st.kind,
                "cat": "stage",
                "ph": "X",
                "ts": st.start_time * 1e6,
                "dur": max(0.0, (st.end_time - st.start_time)) * 1e6,
                "pid": st.client or "unassigned",
                "tid": r.rid,
                "args": {"input_tokens": r.input_tokens,
                         "output_tokens": r.output_tokens,
                         "branches": r.branches},
            })
        if r.first_token_time is not None:
            events.append({"name": "first_token", "cat": "token", "ph": "i",
                           "ts": r.first_token_time * 1e6, "pid": "tokens",
                           "tid": r.rid, "s": "t"})
    with open(path, "w") as f:
        json.dump({"traceEvents": events, "displayTimeUnit": "ms"}, f)
    return path
