"""Workload generation (paper §III-F1): request sizes from real-trace-shaped
synthetic distributions, injection processes (uniform/normal/poisson/bursty).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Iterator, List, Optional

import numpy as np

from repro.core import request as rq


@dataclass(frozen=True)
class TraceSpec:
    """Token-count distribution. Defaults mirror the AzureLLMInference 2023
    trace statistics the paper uses (Conv: short-in/short-out; Code:
    long-in/short-out)."""
    name: str
    input_mean: float
    input_std: float
    output_mean: float
    output_std: float
    input_max: int = 16_384
    output_max: int = 4_096

    def sample(self, rng: np.random.Generator, n: int):
        ins = np.clip(rng.lognormal(np.log(self.input_mean), self.input_std, n),
                      16, self.input_max).astype(int)
        outs = np.clip(rng.lognormal(np.log(self.output_mean), self.output_std, n),
                       4, self.output_max).astype(int)
        return ins, outs


AZURE_CONV = TraceSpec("azure-conv", input_mean=1020, input_std=0.85,
                       output_mean=210, output_std=0.7)
AZURE_CODE = TraceSpec("azure-code", input_mean=2040, input_std=1.0,
                       output_mean=28, output_std=0.6)


def synthetic_trace(input_mean: float, input_std: float, output_mean: float,
                    output_std: float, name: str = "synthetic") -> TraceSpec:
    """Paper: synthetic traces are normal-shaped with configurable mean/var."""
    return TraceSpec(name, input_mean, input_std, output_mean, output_std)


# ---------------------------------------------------------------------------
# injection processes
# ---------------------------------------------------------------------------

def arrival_times(rng: np.random.Generator, n: int, rate: float,
                  process: str = "poisson", burst_factor: float = 5.0) -> np.ndarray:
    """n arrival timestamps at ``rate`` req/s under the given process."""
    if process == "uniform":
        gaps = np.full(n, 1.0 / rate)
    elif process == "poisson":
        gaps = rng.exponential(1.0 / rate, n)
    elif process == "normal":
        gaps = np.clip(rng.normal(1.0 / rate, 0.3 / rate, n), 1e-6, None)
    elif process == "bursty":
        # alternating hot/cold phases
        gaps = np.where(rng.random(n) < 0.5,
                        rng.exponential(1.0 / (rate * burst_factor), n),
                        rng.exponential(burst_factor / rate, n))
    else:
        raise ValueError(process)
    return np.cumsum(gaps)


@dataclass
class WorkloadConfig:
    trace: TraceSpec = AZURE_CONV
    rate: float = 2.0                       # requests/sec (per system)
    n_requests: int = 200
    process: str = "poisson"
    pipeline: str = "regular"               # regular | rag | kv | reasoning
    disaggregated: bool = False
    model: str = "llama3-70b"
    seed: int = 0
    # pipeline extras
    rag_added_tokens: int = 3_000           # paper §V-A: RAG adds 3K tokens
    kv_cached_tokens: int = 3_000           # paper §V-A: 3K cached context
    reasoning_scale: float = 8.0
    reasoning_branches: int = 1
    postprocess: bool = True
    # shared-prefix knobs (all off by default -> no overlapping prefixes and
    # PR-1-identical behavior). When on, prompts carry ``prefix_segments`` so
    # the radix cache can actually dedup pages across requests:
    shared_prefix_pool: int = 0             # distinct system prompts (0 = off)
    shared_prefix_tokens: int = 512         # tokens per pooled system prompt
    prefix_reuse_rate: float = 1.0          # P(request draws from the pool)
    rag_chunk_pool: int = 0                 # distinct RAG chunks (0 = fiat
    rag_chunk_tokens: int = 500             #   rag_added_tokens, no identity)
    # scale-out scenario knobs: a traffic surge at ``rate_ramp_at`` (the
    # moment an operator would add a replica) — arrivals after it come
    # ``rate_ramp``x faster. The surge is a deterministic time-compression
    # of the same arrival sequence, so sweeps over ramp timing/intensity
    # see the same request population.
    rate_ramp_at: Optional[float] = None
    rate_ramp: float = 1.0
    # multi-phase generalization (diurnal / surge traces for the closed-loop
    # autoscaler): ``((t0, m0), (t1, m1), ...)`` — from wall-clock ``t_i``
    # until the next breakpoint the instantaneous arrival rate is
    # ``rate * m_i`` (multiplier 1.0 before ``t0``). Like ``rate_ramp``,
    # phases are a deterministic time-warp of one base-rate arrival
    # sequence, so every phase schedule sees the same request population.
    # Mutually exclusive with ``rate_ramp_at``.
    rate_phases: Optional[tuple] = None


def warp_times(times: np.ndarray, phases) -> np.ndarray:
    """Deterministically time-warp base-rate arrival times through a
    piecewise-constant rate-multiplier schedule ``((t0, m0), (t1, m1), ...)``
    (breakpoints in warped/wall-clock time, strictly increasing, multipliers
    > 0; multiplier is 1.0 before ``t0``). A base arrival consuming ``s``
    seconds of unit-rate "arrival work" lands at the wall-clock time ``w``
    where the integral of the multiplier over ``[0, w]`` equals ``s``."""
    if not phases:
        return times
    ts = [float(t) for t, _ in phases]
    ms = [float(m) for _, m in phases]
    if any(t1 <= t0 for t0, t1 in zip(ts, ts[1:])):
        raise ValueError(f"rate_phases breakpoints must strictly increase: {ts}")
    if any(m <= 0 for m in ms):
        raise ValueError(f"rate_phases multipliers must be positive: {ms}")
    # base-time ("work") consumed at each breakpoint: before t0 the
    # multiplier is 1, afterwards each phase spends (t_{i+1}-t_i)*m_i
    work = [ts[0]]
    for i in range(len(ts) - 1):
        work.append(work[-1] + (ts[i + 1] - ts[i]) * ms[i])
    idx = np.searchsorted(work, times, side="right") - 1
    out = np.asarray(times, dtype=float).copy()
    pre = idx < 0                       # before the first breakpoint: identity
    post = ~pre
    i = np.clip(idx, 0, len(ts) - 1)
    out[post] = (np.asarray(ts)[i][post]
                 + (times[post] - np.asarray(work)[i][post])
                 / np.asarray(ms)[i][post])
    return out


def generate(cfg: WorkloadConfig) -> List[rq.Request]:
    rng = np.random.default_rng(cfg.seed)
    ins, outs = cfg.trace.sample(rng, cfg.n_requests)
    times = arrival_times(rng, cfg.n_requests, cfg.rate, cfg.process)
    if cfg.rate_phases and cfg.rate_ramp_at is not None:
        raise ValueError("rate_phases and rate_ramp_at are mutually exclusive")
    if cfg.rate_ramp_at is not None and cfg.rate_ramp != 1.0:
        t0 = cfg.rate_ramp_at
        times = np.where(times > t0, t0 + (times - t0) / cfg.rate_ramp, times)
    elif cfg.rate_phases:
        times = warp_times(times, cfg.rate_phases)
    out: List[rq.Request] = []
    for t, i, o in zip(times, ins, outs):
        if cfg.pipeline == "regular":
            stages = rq.regular_pipeline(cfg.disaggregated, cfg.postprocess)
        elif cfg.pipeline == "rag":
            stages = rq.rag_pipeline(cfg.disaggregated, postprocess=cfg.postprocess)
        elif cfg.pipeline == "kv":
            stages = rq.kv_retrieval_pipeline(cfg.disaggregated, cfg.postprocess)
        elif cfg.pipeline == "reasoning":
            stages = rq.regular_pipeline(cfg.disaggregated, cfg.postprocess)
        else:
            raise ValueError(cfg.pipeline)
        r = rq.Request(arrival=float(t), input_tokens=int(i),
                       output_tokens=int(o), stages=stages, model=cfg.model)
        segments: List = []
        if cfg.shared_prefix_pool > 0:
            # pooled system prompt, *prepended* so it is a block-aligned
            # shareable prefix; a (1 - reuse_rate) fraction gets a unique one
            if rng.random() < cfg.prefix_reuse_rate:
                k = int(rng.integers(cfg.shared_prefix_pool))
                seg_id = f"sys{k}"
            else:
                seg_id = f"uniq{r.rid}"
            segments.append((seg_id, cfg.shared_prefix_tokens))
            r.input_tokens += cfg.shared_prefix_tokens
        if cfg.pipeline == "rag":
            if cfg.rag_chunk_pool > 0:
                # retrieved chunks drawn from a shared corpus follow the
                # system prompt, ahead of the unique user query, so repeated
                # chunk sets stay inside the shareable prefix. A retriever
                # returns k *distinct* chunks — sample without replacement so
                # the context size matches fiat mode and the knob measures
                # sharing, not a lighter workload
                n_chunks = max(1, cfg.rag_added_tokens // cfg.rag_chunk_tokens)
                if cfg.rag_chunk_pool < n_chunks:
                    raise ValueError(
                        f"rag_chunk_pool={cfg.rag_chunk_pool} cannot supply "
                        f"{n_chunks} distinct chunks "
                        f"(rag_added_tokens/rag_chunk_tokens); a smaller "
                        f"context would confound sharing sweeps with a "
                        f"lighter workload")
                chunks = sorted(int(c) for c in rng.choice(
                    cfg.rag_chunk_pool, size=n_chunks, replace=False))
                segments.extend((f"doc{c}", cfg.rag_chunk_tokens)
                                for c in chunks)
                r.rag_tokens = n_chunks * cfg.rag_chunk_tokens
            else:
                r.rag_tokens = cfg.rag_added_tokens
        if cfg.pipeline == "kv":
            r.input_tokens += cfg.kv_cached_tokens
            if cfg.shared_prefix_pool > 0:
                # real lookup mode: the cached context is a pooled shared
                # prefix; hits (and the prefill discount) come from the radix
                # cache at admission instead of a fiat cached_tokens grant.
                # The retrieval stage still prices fetching the candidate
                # context (cached_tokens is 0 until the radix hit lands).
                # It follows the system prompt so the most-widely-shared
                # segment stays the leading block-aligned prefix. Note
                # prefix_reuse_rate therefore gates the *entire* prefix: a
                # request that drew a unique system prompt diverges at block
                # 0 and its kv context cannot hit either — exactly how a
                # radix cache behaves when the leading segment differs.
                k = int(rng.integers(cfg.shared_prefix_pool))
                segments.append((f"kvctx{k}", cfg.kv_cached_tokens))
                for st in stages:
                    if st.kind == rq.KV_RETRIEVAL:
                        st.params["candidate_tokens"] = cfg.kv_cached_tokens
            else:
                r.cached_tokens = cfg.kv_cached_tokens
        if cfg.pipeline == "reasoning":
            rq.reasoning_request(r, cfg.reasoning_scale, cfg.reasoning_branches)
        r.prefix_segments = tuple(segments)
        out.append(r)
    return out
