"""Requests and their multi-stage pipelines (paper Fig. 1)."""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

# stage kinds
PREPROCESS = "preprocess"
RAG_EMBED = "rag_embed"
RAG_RETRIEVE = "rag_retrieve"
KV_RETRIEVAL = "kv_retrieval"
LLM = "llm"              # prefill + decode on one client (continuous/chunked)
PREFILL = "prefill"      # disaggregated prefill
DECODE = "decode"        # disaggregated decode
POSTPROCESS = "postprocess"

_rid = itertools.count()


@dataclass
class Stage:
    kind: str
    params: Dict = field(default_factory=dict)
    # bookkeeping filled at runtime
    client: Optional[str] = None
    start_time: Optional[float] = None
    end_time: Optional[float] = None
    dispatch_time: Optional[float] = None


@dataclass
class Request:
    arrival: float
    input_tokens: int
    output_tokens: int
    stages: List[Stage]
    model: str = "llama3-70b"
    rid: int = field(default_factory=lambda: next(_rid))
    branches: int = 1                  # multi-path reasoning thought branches
    cached_tokens: int = 0             # KV tokens recovered by kv_retrieval
    rag_tokens: int = 0                # context tokens added by RAG
    tier: str = "default"              # SLO tier (MetricsCollector.goodput_by_tier)
    # shared-prefix identity: ordered (content_id, n_tokens) segments covering
    # the *leading* part of the prompt (system prompt, reused RAG chunks, ...).
    # Two requests with equal leading segments share a block-aligned KV prefix
    # in the radix cache; everything past the segments is unique content.
    prefix_segments: Tuple[Tuple[str, int], ...] = ()
    _prefix_hash_cache: Dict[int, List[int]] = field(default_factory=dict,
                                                     repr=False)
    # --- runtime state ---
    stage_idx: int = 0
    prefilled_tokens: int = 0
    decoded_tokens: int = 0
    first_token_time: Optional[float] = None
    last_token_time: Optional[float] = None
    completion_time: Optional[float] = None
    token_times: List[float] = field(default_factory=list)
    preemptions: int = 0
    failures: int = 0

    # ------------------------------------------------------------------
    @property
    def current_stage(self) -> Optional[Stage]:
        return self.stages[self.stage_idx] if self.stage_idx < len(self.stages) else None

    @property
    def done(self) -> bool:
        return self.stage_idx >= len(self.stages)

    @property
    def effective_prefill_tokens(self) -> int:
        """Tokens the prefill actually has to compute (prefix-cache aware)."""
        total = self.input_tokens + self.rag_tokens
        return max(0, total - self.cached_tokens)

    @property
    def total_context(self) -> int:
        return self.input_tokens + self.rag_tokens + self.decoded_tokens

    @property
    def remaining_tokens(self) -> int:
        return max(0, self.output_tokens - self.decoded_tokens)

    def prefix_block_hashes(self, block_tokens: int) -> List[int]:
        """Chained content hashes for the full, block-aligned blocks covered
        by ``prefix_segments`` — the keys the radix cache shares pages under.
        Hash i chains over hash i-1, so equal chains imply equal prefixes."""
        if not self.prefix_segments:
            return []
        cached = self._prefix_hash_cache.get(block_tokens)
        if cached is not None:
            return cached
        ids: List[Tuple[str, int]] = []
        for seg, n in self.prefix_segments:
            ids.extend((seg, j) for j in range(n))
        out: List[int] = []
        h = 0
        for i in range(len(ids) // block_tokens):
            h = hash((h, tuple(ids[i * block_tokens:(i + 1) * block_tokens])))
            out.append(h)
        self._prefix_hash_cache[block_tokens] = out
        return out

    def advance_stage(self, now: float):
        st = self.current_stage
        if st is not None:
            st.end_time = now
        self.stage_idx += 1
        if self.done:
            self.completion_time = now

    # --- derived metrics -------------------------------------------------
    @property
    def ttft(self) -> Optional[float]:
        if self.first_token_time is None:
            return None
        return self.first_token_time - self.arrival

    @property
    def tpot(self) -> Optional[float]:
        if self.last_token_time is None or self.first_token_time is None:
            return None
        n = max(1, self.decoded_tokens - 1)
        return (self.last_token_time - self.first_token_time) / n

    @property
    def e2e(self) -> Optional[float]:
        if self.completion_time is None:
            return None
        return self.completion_time - self.arrival


# ---------------------------------------------------------------------------
# pipeline factories (paper Fig. 1 a/b/c)
# ---------------------------------------------------------------------------

def regular_pipeline(disaggregated: bool = False, postprocess: bool = True) -> List[Stage]:
    llm = ([Stage(PREFILL), Stage(DECODE)] if disaggregated else [Stage(LLM)])
    tail = [Stage(POSTPROCESS)] if postprocess else []
    return [Stage(PREPROCESS)] + llm + tail


def rag_pipeline(disaggregated: bool = False, co_located_rag: bool = False,
                 postprocess: bool = True) -> List[Stage]:
    rag = ([Stage(RAG_EMBED, {"co_located": True})] if co_located_rag
           else [Stage(RAG_EMBED), Stage(RAG_RETRIEVE)])
    llm = ([Stage(PREFILL), Stage(DECODE)] if disaggregated else [Stage(LLM)])
    tail = [Stage(POSTPROCESS)] if postprocess else []
    return [Stage(PREPROCESS)] + rag + llm + tail


def kv_retrieval_pipeline(disaggregated: bool = False,
                          postprocess: bool = True) -> List[Stage]:
    llm = ([Stage(PREFILL), Stage(DECODE)] if disaggregated else [Stage(LLM)])
    tail = [Stage(POSTPROCESS)] if postprocess else []
    return [Stage(PREPROCESS), Stage(KV_RETRIEVAL)] + llm + tail


def reasoning_request(req: Request, scale: float = 8.0, branches: int = 1) -> Request:
    """Scale output tokens for reasoning (paper §IV-A: single-path 8-32x,
    multi-path 4-16x with parallel branches sharing the prefill KV)."""
    req.output_tokens = int(req.output_tokens * scale)
    req.branches = branches
    return req
