"""HERMES core: heterogeneous multi-stage LLM inference simulator (the
paper's primary contribution — coordinator, clients, schedulers, batching,
memory hierarchy, comm model, workloads, metrics, fault handling)."""
from repro.core.autoscaler import (Autoscaler, AutoscalerConfig,  # noqa: F401
                                   ClientTemplate, Observation,
                                   TargetTrackingPolicy,
                                   ThresholdHysteresisPolicy, make_policy)
from repro.core.coordinator import Coordinator, CoordinatorConfig  # noqa: F401
from repro.core.metrics import SLO, MetricsCollector  # noqa: F401
from repro.core.system import SystemSpec, build_system  # noqa: F401
from repro.core.workload import (AZURE_CODE, AZURE_CONV, WorkloadConfig,  # noqa: F401
                                 generate)
