"""Clients (paper §III-C): a hardware cluster + scheduler specialized for a
subset of stages. Five types: pre/post-processing, RAG (embed / retrieve),
KV-cache retrieval, and LLM inference (continuous/chunked/static/mixed or a
disaggregated prefill/decode half).
"""
from __future__ import annotations

from bisect import bisect_left
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.configs.base import ModelConfig
from repro.core import request as rq
from repro.core.llm_scheduler import (ClientPerf, LLMScheduler, LLMStep,
                                      SchedulerLimits)
from repro.core.memory import (expected_retrieval_latency,
                               sample_retrieval_latency)
from repro.core.scheduler import BatchedScheduler, SequentialScheduler
from repro.perfmodel import analytical as ana
from repro.perfmodel import rag_model
from repro.perfmodel.hardware import CacheTierSpec, ClusterSpec


class Client:
    """Base client: owns a scheduler and a ClusterSpec."""

    kind = "base"

    def __init__(self, name: str, cluster: ClusterSpec, stages: Sequence[str]):
        self.name = name
        self.cluster = cluster
        self.stages = tuple(stages)
        self.busy = False
        self.failed = False
        self.slowdown = 1.0            # straggler factor (>1 => slower)
        self.total_energy = 0.0
        self.steps_done = 0
        self.served = 0

    # scheduler protocol -------------------------------------------------
    def add(self, req: rq.Request):
        self.scheduler.add(req)

    def has_work(self) -> bool:
        return self.scheduler.has_work()

    def plan_step(self, now: Optional[float] = None,
                  horizon: Optional[float] = None):
        step = self.scheduler.plan_step()
        if step is not None and self.slowdown != 1.0:
            step.duration *= self.slowdown
        return step

    def finish_step(self, step, now: float) -> List[rq.Request]:
        done = self.scheduler.finish_step(step, now)
        # macro-steps carry per-iteration energies; accumulate them in the
        # order the event loop would so the total stays bit-equal
        energies = getattr(step, "step_energies", None)
        for e in (energies if energies is not None
                  else (getattr(step, "energy", 0.0),)):
            self.total_energy += e
        self.steps_done += getattr(step, "n_steps", 1)
        self.served += len(done)
        return done

    def truncate_step(self, step, now: float, inclusive: bool = False):
        """Commit the finished prefix of an in-flight macro-step (fast
        forward invalidation); returns the single-step remainder or None.
        Base clients plan atomic steps only, so there is nothing to cut."""
        return None

    def requeue_step(self, step) -> None:
        """Return the requests of a discarded in-flight step to the queue
        (client fail/remove) so the subsequent ``drain()`` re-dispatches
        them instead of losing them."""
        self.scheduler.requeue_step(step)

    def drain(self) -> List[rq.Request]:
        return self.scheduler.drain()

    # load metrics for routing (paper §III-B1) ---------------------------
    def _window_committed_steps(self, now: Optional[float]) -> int:
        """Decode iterations of an in-flight fast-forward window that have
        finished by ``now`` but are not yet materialized. Load metrics fold
        them in virtually, so routing sees exactly the state a per-step
        execution would — without the coordinator having to cut the window
        of every routing *candidate* (only the chosen client's is cut)."""
        sched = self.scheduler
        w = getattr(sched, "_window", None)
        if w is None or now is None:
            return 0
        if getattr(sched, "strategy", "") == "static":
            return 0      # static batches are invisible to load metrics
        return bisect_left(w.token_times, now)

    def load(self, metric: str = "queue", now: Optional[float] = None) -> float:
        sched = self.scheduler
        waiting = list(getattr(sched, "waiting", []))
        running = (list(getattr(sched, "running", []))
                   + list(getattr(sched, "swapped", [])))
        if metric == "queue":
            return len(waiting) + len(running)
        if metric == "input_len":
            # effective prefill work, not raw prompt length: KV-retrieval /
            # RAG / prefix-cached requests only cost their uncached tokens,
            # so they must not repel the router from the right client
            return sum(r.effective_prefill_tokens for r in waiting + running)
        if metric == "output_len":
            return sum(r.output_tokens for r in waiting + running)
        if metric == "kv_size":
            kv = getattr(sched, "kv", None)
            return kv.used if kv is not None else 0.0
        if metric == "kv_pressure":
            # fragmentation-aware: resident blocks (slack included) plus the
            # block demand parked in the queue, as a fraction of the pool
            kv = getattr(sched, "kv", None)
            if kv is None:
                return float(len(waiting) + len(running))
            queued = sum(kv.blocks_for_tokens(r.input_tokens + r.rag_tokens)
                         for r in waiting)
            return (kv.used_blocks + queued) / max(1, kv.num_blocks)
        if metric == "tokens_remaining":
            total = sum(r.remaining_tokens + max(
                0, r.effective_prefill_tokens - r.prefilled_tokens)
                for r in waiting + running)
            j = self._window_committed_steps(now)
            if j:
                # every window member decoded j more tokens than materialized
                total -= j * len(sched._window.decode)
            return total
        raise ValueError(metric)

    def kv_stats(self) -> Dict:
        """Paged-allocator counters (empty for non-LLM clients)."""
        kv = getattr(self.scheduler, "kv", None)
        return kv.stats() if kv is not None else {}

    def prefix_hit_rate(self) -> float:
        """Fraction of prefix-eligible prompt tokens this client served out
        of its radix cache (migrated pages included). The per-replica
        warm-up signal the prefix-migration benchmark tracks: a freshly
        scaled-out client starts at 0 and converges toward its donor's rate
        as pushed/fetched chains land."""
        kv = getattr(self.scheduler, "kv", None)
        if kv is None or kv.prefix_tokens_seen <= 0:
            return 0.0
        return kv.prefix_hit_tokens / kv.prefix_tokens_seen

    def prefix_hit_tokens(self, req: rq.Request) -> int:
        """Prompt tokens of ``req`` whose KV pages this client's radix cache
        already holds (0 for non-LLM clients or identity-less requests).
        Routers use this for prefix-affinity placement."""
        kv = getattr(self.scheduler, "kv", None)
        if kv is None or not req.prefix_segments:
            return 0
        if not getattr(self.scheduler.limits, "prefix_caching", False):
            return 0
        return kv.peek_prefix_tokens(req.prefix_block_hashes(kv.block_tokens))


class PreprocessClient(Client):
    kind = "preprocess"

    def __init__(self, name: str, cluster: ClusterSpec,
                 per_token_us: float = 0.02, base_us: float = 50.0,
                 n_cores: int = 16):
        super().__init__(name, cluster, (rq.PREPROCESS,))
        fn = lambda r: (base_us + per_token_us * r.input_tokens) * 1e-6
        en = lambda batch, dur: dur * cluster.chip.power * 0.2
        self.scheduler = SequentialScheduler(fn, n_cores=n_cores, energy_fn=en)


class PostprocessClient(Client):
    """Detokenize + safety filters; optionally prices a small (~2B) guard
    model forward pass (paper §III-E4)."""

    kind = "postprocess"

    def __init__(self, name: str, cluster: ClusterSpec,
                 guard_model: Optional[ModelConfig] = None, n_cores: int = 16):
        super().__init__(name, cluster, (rq.POSTPROCESS,))
        self.guard_model = guard_model

        def fn(r: rq.Request) -> float:
            t = 1e-5 + 2e-8 * r.decoded_tokens * r.branches  # word-lookup pass
            if guard_model is not None:
                t += ana.prefill_time(guard_model, cluster,
                                      max(8, r.decoded_tokens)).time
            return t

        en = lambda batch, dur: dur * cluster.chip.power * 0.3
        self.scheduler = SequentialScheduler(fn, n_cores=n_cores, energy_fn=en)


class RAGClient(Client):
    """Embedding and/or retrieval+rerank (paper §III-C2, §IV-B). When
    ``co_located`` it serves both RAG stages on one cluster."""

    kind = "rag"

    def __init__(self, name: str, cluster: ClusterSpec,
                 embed_model: Optional[ModelConfig] = None,
                 ivf: rag_model.IVFPQConfig = rag_model.IVFPQConfig(),
                 serve_embed: bool = True, serve_retrieve: bool = True):
        stages = ([rq.RAG_EMBED] if serve_embed else []) + \
                 ([rq.RAG_RETRIEVE] if serve_retrieve else [])
        super().__init__(name, cluster, stages)
        self.embed_model = embed_model
        self.ivf = ivf
        self.serve_embed = serve_embed
        self.serve_retrieve = serve_retrieve

        def latency(batch: List[rq.Request]) -> float:
            t = 0.0
            for r in batch:
                if self.serve_embed and r.current_stage.kind == rq.RAG_EMBED:
                    if embed_model is not None:
                        t = max(t, ana.prefill_time(embed_model, cluster,
                                                    r.input_tokens).time)
                    if r.current_stage.params.get("co_located"):
                        t += (rag_model.retrieval_time(ivf, cluster).time
                              + rag_model.rerank_time(ivf, cluster).time)
                if self.serve_retrieve and r.current_stage.kind == rq.RAG_RETRIEVE:
                    t += (rag_model.retrieval_time(ivf, cluster).time
                          + rag_model.rerank_time(ivf, cluster).time)
            return t

        en = lambda batch, dur: dur * cluster.chip.power * 0.5
        self.scheduler = BatchedScheduler(latency, energy_fn=en)


class KVRetrievalClient(Client):
    """Multi-level cache retrieval (paper §III-C3/§III-E3, Eq. 1)."""

    kind = "kv_retrieval"

    def __init__(self, name: str, cluster: ClusterSpec,
                 tiers: Sequence[CacheTierSpec],
                 kv_bytes_per_token: float = 160e3,
                 recompute_fn=None, sample: bool = True, seed: int = 0):
        super().__init__(name, cluster, (rq.KV_RETRIEVAL,))
        self.tiers = list(tiers)
        self.kv_bytes_per_token = kv_bytes_per_token
        self.recompute_fn = recompute_fn or (lambda size: 0.2)
        self.rng = np.random.default_rng(seed)
        self.sample = sample

        def latency(batch: List[rq.Request]) -> float:
            t = 0.0
            for r in batch:
                # fiat mode prices the granted cached_tokens; radix real-
                # lookup mode grants 0 up front, so the stage prices the
                # candidate context it probes the tier chain for
                cand = max(r.cached_tokens,
                           r.current_stage.params.get("candidate_tokens", 0))
                size = cand * self.kv_bytes_per_token
                miss = self.recompute_fn(size)
                if self.sample:
                    lt = sample_retrieval_latency(size, self.tiers, miss, self.rng)
                else:
                    lt = expected_retrieval_latency(size, self.tiers, miss)
                t = max(t, lt)
            return t

        en = lambda batch, dur: dur * cluster.chip.power * 0.4
        self.scheduler = BatchedScheduler(latency, energy_fn=en)


class LLMClient(Client):
    kind = "llm"

    def __init__(self, name: str, cluster: ClusterSpec, model_cfg: ModelConfig,
                 strategy: str = "continuous",
                 limits: SchedulerLimits = SchedulerLimits(),
                 packing: str = "fcfs", perf: Optional[ClientPerf] = None,
                 group: Optional[str] = None):
        stage_map = {"prefill_only": (rq.PREFILL,),
                     "decode_only": (rq.DECODE,)}
        stages = stage_map.get(strategy, (rq.LLM,))
        super().__init__(name, cluster, stages)
        self.model_cfg = model_cfg
        self.strategy = strategy
        self.group = group               # local-disaggregation pairing group
        self.scheduler = LLMScheduler(strategy, model_cfg, cluster,
                                      perf=perf, limits=limits, packing=packing)

    def plan_step(self, now: Optional[float] = None,
                  horizon: Optional[float] = None):
        """With the absolute clock, the scheduler may fast-forward a stable
        decode batch into a macro-step; those arrive with the slowdown
        already folded into every per-iteration time, so only plain single
        steps take the legacy scaling path here. ``horizon`` (the next known
        external event) bounds the window so its tail is rarely discarded."""
        step = self.scheduler.plan_step(now=now, slowdown=self.slowdown,
                                        horizon=horizon)
        if step is not None and step.n_steps == 1 and self.slowdown != 1.0:
            step.duration *= self.slowdown
        return step

    def truncate_step(self, step, now: float, inclusive: bool = False):
        if getattr(step, "n_steps", 1) <= 1:
            return None
        rem, committed = self.scheduler.truncate_step(step, now, inclusive)
        for e in committed:
            self.total_energy += e
        self.steps_done += len(committed)
        return rem

    @property
    def kv_transfer_bytes_fn(self):
        per_tok = self.scheduler.kv_per_token
        return lambda req: req.total_context * per_tok
