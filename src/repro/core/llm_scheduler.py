"""vLLM-style LLM scheduler with the paper's five batching strategies
(§III-D1): static, continuous, chunked, mixed, disaggregated (prefill_only /
decode_only halves), plus FCFS / least-work-left packing.

KV memory is managed by the paged allocator (``core/memory.py``): admission
reserves whole-context block tables, decode growth faults in blocks one at a
time, and exhaustion is resolved by a pluggable preemption policy —
``swap`` (offload the coldest request's pages to the next tier, priced with
the Eq. 1 tier term) or ``recompute`` (drop pages, re-enqueue the prefill).

Prefix sharing (``limits.prefix_caching``, on by default): requests carrying
``prefix_segments`` admit against the allocator's radix cache — resident
shared-prefix blocks are mapped instead of re-allocated, and the hit tokens
discount the prefill compute (``Request.cached_tokens`` becomes a *real*
lookup). Multi-branch reasoning requests fork their block table copy-on-write
on the first divergent decode write, so branches share every prefill page.

Decode fast-forward (``limits.fast_forward``, on by default): when the batch
composition is provably stable — nothing waiting or swapped, no pending swap
charges, every decode table on-device with an unshared tail, and the next
``K`` growth steps fit in the free list — ``plan_step`` returns one
*macro-step* covering ``K = min(tokens-to-next-completion,
tokens-to-block-boundary-pressure)`` decode iterations instead of ``K``
events. Pricing is exact summation: the per-step cost is evaluated at every
context in the window (bit-equal with per-step execution; the LRU-memoized
``ClientPerf`` makes repeats cheap), and per-step end times are accumulated
in the same order the event loop would, so token timestamps, energy and
every ``kv_*`` counter are identical with the flag on or off. The
coordinator may *truncate-and-replay* an in-flight window when an external
event lands mid-window (``truncate_step``).
"""
from __future__ import annotations

import itertools
from collections import deque
from heapq import heappop, heappush
from bisect import bisect_left, bisect_right
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Tuple

from repro.configs.base import ModelConfig
from repro.core.memory import PagedKVAllocator, tier_transfer_time
from repro.core.request import Request
from repro.perfmodel import analytical as ana
from repro.perfmodel.hardware import CacheTierSpec, ClusterSpec, \
    DEFAULT_SWAP_TIERS

STRATEGIES = ("static", "continuous", "chunked", "mixed",
              "prefill_only", "decode_only")
PREEMPTION_POLICIES = ("swap", "recompute")


@dataclass(frozen=True)
class SchedulerLimits:
    max_batch: int = 64
    max_prefill_tokens: int = 8192     # prefill token budget per step
    chunk_size: int = 512              # chunked-batching token budget
    # paged KV allocator knobs
    kv_block_tokens: int = 32          # tokens per KV page
    preemption: str = "swap"           # swap | recompute
    kv_capacity_frac: float = 1.0      # scale usable HBM (capacity studies)
    swap_tiers: Tuple[CacheTierSpec, ...] = DEFAULT_SWAP_TIERS
    # shared-prefix radix cache + copy-on-write branch forking. Neutral for
    # workloads without prefix_segments / branches; set False to reproduce
    # the pre-radix (PR 1) allocator behavior exactly.
    prefix_caching: bool = True
    # decode fast-forward: collapse provably-stable decode windows into one
    # macro-step event. Metrics-neutral by construction (see module doc);
    # set False to force one event per decode iteration.
    fast_forward: bool = True
    # speculative decoding: every pure-decode step drafts ``spec_k`` tokens
    # with the ``spec_draft`` model and verifies them in one target pass
    # (priced by ``analytical.speculative_decode_step``). ``spec_acceptance``
    # is either a scalar alpha (geometric acceptance) or a measured
    # per-position CONDITIONAL distribution — e.g. the real engine's
    # ``spec_stats()["conditional_acceptance_per_position"]``, which is how
    # ``benchmarks/spec_decode.py`` calibrates the simulator. Fast-forward
    # is disabled while speculation is on (variable tokens/step break the
    # window invariants).
    spec_k: int = 0
    spec_draft: str = "guard_2b"
    spec_acceptance: object = 0.8      # float | Sequence[float]
    # swap granularity (§III-B2 applied to the swap path): "full" stalls for
    # the whole table crossing the tier boundary; "layerwise" overlaps the
    # move with layer-by-layer compute so only ~one layer group of payload is
    # exposed — the same pricing the disaggregated KV handoff uses
    # (``comm.Network._exposed`` / engine ``move_pages``). Bytes accounting
    # is identical either way. ``swap_layer_groups=0`` means one group per
    # model layer.
    swap_granularity: str = "full"     # full | layerwise
    swap_layer_groups: int = 0         # 0 -> num_layers
    # per-step history retention: None keeps every step dict (seed behavior,
    # fine for small fleets), 0 disables recording entirely, n > 0 keeps a
    # ring buffer of the last n steps. ``step_events`` stays a monotonic
    # counter either way, so ``simulator_stats`` is retention-independent.
    history_limit: Optional[int] = None


@dataclass
class LLMStep:
    kind: str                          # "prefill" | "decode" | "chunked"
    prefill: List[Tuple[Request, int]] = field(default_factory=list)  # (req, tokens)
    decode: List[Request] = field(default_factory=list)
    duration: float = 0.0
    energy: float = 0.0
    flops: float = 0.0
    # KV paging traffic attributed to this step (set at plan/finish time)
    swap_bytes: float = 0.0
    swap_time: float = 0.0
    preemptions: int = 0
    # speculative decode step: expected committed tokens per request this
    # iteration (0.0 = plain decode, one token); finish_step integerizes
    # through the scheduler's carry accumulator
    spec_expected: float = 0.0
    # fast-forward macro-step window (n_steps > 1): absolute per-iteration
    # end times (== token emission times) and the per-iteration cost vectors,
    # all accumulated in event-loop order so truncation replays exactly
    n_steps: int = 1
    end_time: Optional[float] = None   # absolute; None => now + duration
    token_times: Optional[List[float]] = None
    step_durations: Optional[List[float]] = None
    step_energies: Optional[List[float]] = None
    step_flops: Optional[List[float]] = None

    @property
    def n_tokens(self) -> int:
        pre = sum(t for _, t in self.prefill)
        dec = sum(r.branches for r in self.decode)
        return pre + dec * self.n_steps


# SLO-tier admission ranks for the ``slo_tier`` packing: lower ranks admit
# first (interactive traffic has the tightest TTFT SLO, batch the loosest).
# Tiers outside the map take the "default" rank, between the two.
TIER_PRIORITY: Dict[str, int] = {"interactive": 0, "default": 1, "batch": 2}


class WaitQueue:
    """Admission queue for ``LLMScheduler``.

    ``fcfs`` packing is a deque — ``popleft``/``appendleft`` replace the
    O(n) list-head ``pop(0)``/``insert(0)`` churn. ``least_work`` and
    ``slo_tier`` packings are incremental lazy-deletion min-heaps, replacing
    the full re-sort previously done on every ``add``: ``least_work`` keys
    on remaining work at push time; ``slo_tier`` keys on the request's SLO
    tier rank (``TIER_PRIORITY``), FCFS within a tier, so under overload
    interactive-tier requests admit ahead of earlier-arrived batch requests
    (per-tier SLO-aware admission). Preempted victims rejoin their tier's
    tail. Iteration yields live requests in insertion order (heap order only
    matters at the head)."""

    def __init__(self, packing: str = "fcfs"):
        self.packing = packing
        self._heaped = packing in ("least_work", "slo_tier")
        self._dq: deque = deque()
        self._heap: List[Tuple[float, int, Request]] = []
        self._live: Dict[int, Request] = {}    # id(req) -> req (heap mode)
        self._seq = itertools.count()

    @staticmethod
    def _work(r: Request) -> int:
        return r.effective_prefill_tokens + r.remaining_tokens

    @staticmethod
    def _rank(r: Request) -> int:
        return TIER_PRIORITY.get(getattr(r, "tier", "default"),
                                 TIER_PRIORITY["default"])

    def _key(self, r: Request) -> float:
        return self._work(r) if self.packing == "least_work" else self._rank(r)

    def push(self, r: Request):
        if self._heaped:
            heappush(self._heap, (self._key(r), next(self._seq), r))
            self._live[id(r)] = r
        else:
            self._dq.append(r)

    # list-compatible aliases (external drivers/tests enqueue directly)
    append = push

    def requeue(self, r: Request):
        """Preempted victim: back to the head (FCFS) / keyed spot (heap)."""
        if self._heaped:
            self.push(r)
        else:
            self._dq.appendleft(r)

    def _head(self) -> Optional[Request]:
        while self._heap:
            _, _, r = self._heap[0]
            if id(r) in self._live:
                return r
            heappop(self._heap)            # lazily-deleted entry
        return None

    def peek(self) -> Optional[Request]:
        if self._heaped:
            return self._head()
        return self._dq[0] if self._dq else None

    def popleft(self) -> Request:
        if self._heaped:
            r = self._head()
            heappop(self._heap)
            del self._live[id(r)]
            return r
        return self._dq.popleft()

    def remove(self, r: Request) -> bool:
        if self._heaped:
            return self._live.pop(id(r), None) is not None
        try:
            self._dq.remove(r)
            return True
        except ValueError:
            return False

    def clear(self):
        self._dq.clear()
        self._heap.clear()
        self._live.clear()

    def __contains__(self, r: Request) -> bool:
        if self._heaped:
            return id(r) in self._live
        return r in self._dq

    def __iter__(self) -> Iterable[Request]:
        if self._heaped:
            return iter(list(self._live.values()))
        return iter(self._dq)

    def __reversed__(self):
        if self._heaped:
            # the list version was kept sorted by key, so reversed() means
            # worst-candidate-first (heaviest work / lowest-priority tier)
            # — preserve that for victim-selection callers. The sort is
            # stable, so within a tier later arrivals are preempted first.
            return reversed(sorted(self._live.values(), key=self._key))
        return reversed(self._dq)

    def __len__(self) -> int:
        if self._heaped:
            return len(self._live)
        return len(self._dq)

    def __bool__(self) -> bool:
        return len(self) > 0


class ClientPerf:
    """Runtime predictor for a client: fitted regression with analytical
    fallback (paper §III-E1).

    Every entry point is memoized through one bounded LRU keyed on the exact
    argument tuple: identical decode steps — the common case, since a stable
    batch re-prices the same ``(batch, avg_ctx)`` point every iteration and
    sweeps revisit whole scenarios — return the cached ``StageCost``
    (immutable, safely shared) instead of re-running the analytical roofline
    or the regression predict."""

    MEMO_CAPACITY = 8192

    def __init__(self, model_cfg: ModelConfig, cluster: ClusterSpec,
                 use_regression: bool = True):
        self.cfg = model_cfg
        self.cluster = cluster
        self.decode_model = None
        self.prefill_model = None
        self._memo: Dict[Tuple, ana.StageCost] = {}
        self._spec_memo: Dict[Tuple, Tuple[ana.StageCost, float]] = {}
        if use_regression:
            from repro.perfmodel import regression as reg
            self.decode_model = reg.fit_decode_model(model_cfg, cluster)
            self.prefill_model = reg.fit_prefill_model(model_cfg, cluster)

    def _memo_get(self, key: Tuple) -> Optional[ana.StageCost]:
        c = self._memo.pop(key, None)
        if c is not None:
            self._memo[key] = c            # refresh recency
        return c

    def _memo_put(self, key: Tuple, cost: ana.StageCost) -> ana.StageCost:
        if len(self._memo) >= self.MEMO_CAPACITY:
            del self._memo[next(iter(self._memo))]   # evict LRU head
        self._memo[key] = cost
        return cost

    def prefill(self, tokens: int, batch: int, past: int = 0) -> ana.StageCost:
        key = ("p", tokens, batch, past)
        hit = self._memo_get(key)
        if hit is not None:
            return hit
        c = ana.prefill_time(self.cfg, self.cluster, tokens, batch, past)
        if self.prefill_model is not None:
            t = float(self.prefill_model.predict([past], [tokens], [batch])[0])
            if t > 0:
                c = ana.StageCost(t, c.energy * t / max(c.time, 1e-12),
                                  c.flops, c.bytes, c.bound)
        return self._memo_put(key, c)

    def decode(self, batch: int, avg_ctx: int) -> ana.StageCost:
        key = ("d", batch, avg_ctx)
        hit = self._memo_get(key)
        if hit is not None:
            return hit
        c = ana.decode_step_time(self.cfg, self.cluster, batch, avg_ctx)
        if self.decode_model is not None:
            t = float(self.decode_model.predict([batch], [avg_ctx])[0])
            if t > 0:
                c = ana.StageCost(t, c.energy * t / max(c.time, 1e-12),
                                  c.flops, c.bytes, c.bound)
        return self._memo_put(key, c)

    def spec_decode(self, batch: int, avg_ctx: int, draft_cfg: ModelConfig,
                    k: int, alpha) -> Tuple[ana.StageCost, float]:
        """Price one speculative iteration — draft ``k`` tokens with
        ``draft_cfg`` plus one (k+1)-position verify pass on the target —
        and its expected committed tokens. ``alpha`` is a scalar or a
        measured per-position acceptance distribution."""
        akey = alpha if isinstance(alpha, (int, float)) else tuple(alpha)
        key = (batch, avg_ctx, k, akey)
        hit = self._spec_memo.get(key)
        if hit is not None:
            return hit
        out = ana.speculative_decode_step(self.cfg, draft_cfg, self.cluster,
                                          batch, avg_ctx, k=k, alpha=alpha)
        if len(self._spec_memo) >= self.MEMO_CAPACITY:
            del self._spec_memo[next(iter(self._spec_memo))]
        self._spec_memo[key] = out
        return out

    def chunked(self, chunk_tokens: int, decode_batch: int,
                avg_ctx: int) -> ana.StageCost:
        key = ("c", chunk_tokens, decode_batch, avg_ctx)
        hit = self._memo_get(key)
        if hit is not None:
            return hit
        return self._memo_put(key, ana.chunked_step_time(
            self.cfg, self.cluster, chunk_tokens, decode_batch, avg_ctx))


class LLMScheduler:
    def __init__(self, strategy: str, model_cfg: ModelConfig,
                 cluster: ClusterSpec, perf: Optional[ClientPerf] = None,
                 limits: SchedulerLimits = SchedulerLimits(),
                 packing: str = "fcfs"):
        assert strategy in STRATEGIES, strategy
        assert limits.preemption in PREEMPTION_POLICIES, limits.preemption
        self.strategy = strategy
        self.cfg = model_cfg
        self.cluster = cluster
        self.perf = perf or ClientPerf(model_cfg, cluster, use_regression=False)
        self.limits = limits
        self.packing = packing
        self.waiting = WaitQueue(packing)
        self.running: List[Request] = []
        self.swapped: List[Request] = []   # preempted-to-tier, awaiting swap-in
        self.chunk_progress: Dict[int, int] = {}   # rid -> prefilled tokens
        self.static_batch: List[Request] = []
        weights = model_cfg.param_count() * ana.BYTES_PER_PARAM / cluster.tp
        capacity = max(cluster.total_mem - weights * cluster.n_chips / max(
            1, cluster.tp) * cluster.tp, cluster.total_mem * 0.15)
        self.kv_per_token = ana.kv_bytes_per_token(model_cfg) + (
            ana.ssm_state_bytes(model_cfg) / 4096.0)
        self.kv = PagedKVAllocator(
            capacity * limits.kv_capacity_frac, self.kv_per_token,
            block_tokens=limits.kv_block_tokens,
            swap_tiers=limits.swap_tiers)
        # speculative decoding: draft config resolved once; the fractional
        # expected-tokens stream integerizes through a carry accumulator so
        # long-run emitted tokens match the expectation exactly
        self._draft_cfg: Optional[ModelConfig] = None
        self._spec_carry = 0.0
        if limits.spec_k:
            from repro.configs import get_config
            self._draft_cfg = get_config(limits.spec_draft)
        # swap traffic incurred inside finish_step, charged to the NEXT step
        self._pending_swap_bytes = 0.0
        self._pending_swap_time = 0.0
        self._pending_preemptions = 0
        # decode_only victims of recompute preemption: their KV must be
        # re-fetched (a decode replica cannot re-run prefill), priced on
        # re-admission like a swap-in from the first spill tier
        self._needs_refetch: set = set()
        # scheduler-level metrics (paper §III-F2). history_limit bounds the
        # per-step dicts held in memory (None = keep all, 0 = record none,
        # n = ring of last n); step_events counts appends regardless so
        # simulator_stats stays exact at 1000-client scale.
        hl = limits.history_limit
        self.history = ([] if hl is None
                        else deque(maxlen=hl if hl > 0 else 0))
        self.step_events = 0
        self.total_energy = 0.0
        self.total_tokens = 0
        # simulator-cost accounting: engine iterations actually simulated
        # (a macro-step counts n_steps) vs. macro windows planned
        self.micro_steps = 0
        self.macro_windows = 0
        # in-flight fast-forward window, so load metrics can be read
        # against virtually-committed state without cutting the window
        self._window: Optional[LLMStep] = None

    # ------------------------------------------------------------------
    def add(self, req: Request):
        if self.strategy == "decode_only":
            # KV produced by the prefill client arrives with the request
            if self._admit_decode(req):
                self.running.append(req)
            else:
                self.waiting.push(req)
        else:
            self.waiting.push(req)

    # --- prefix sharing -------------------------------------------------
    def _prefix_hashes(self, r: Request) -> List[int]:
        if not self.limits.prefix_caching or not r.prefix_segments:
            return []
        return r.prefix_block_hashes(self.kv.block_tokens)

    def _apply_prefix_discount(self, r: Request) -> List[int]:
        """Turn ``cached_tokens`` into a real radix-cache lookup: the tokens
        whose blocks are already resident need no prefill compute. At least
        one token is always computed (the sampling position). Requests
        without a shared-prefix identity keep their fiat value."""
        hashes = self._prefix_hashes(r)
        if hashes:
            hit = self.kv.peek_prefix_tokens(hashes)
            r.cached_tokens = min(hit, r.input_tokens + r.rag_tokens - 1)
        return hashes

    def _branch_rids(self, r: Request) -> List:
        """Allocator keys for the copy-on-write branch tables of a
        multi-branch reasoning request (the parent keeps ``r.rid``)."""
        if r.branches <= 1 or not self.limits.prefix_caching:
            return []
        return [("br", r.rid, b) for b in range(1, r.branches)]

    def _release_kv(self, r: Request):
        """Free the request's main table plus any forked branch tables."""
        for br in self._branch_rids(r):
            if self.kv.holds(br):
                self.kv.free(br)
        self.kv.free(r.rid)

    def _drop_kv(self, r: Request):
        """Recompute-preemption drop, branch tables included."""
        for br in self._branch_rids(r):
            if self.kv.holds(br):
                self.kv.free(br)
        self.kv.drop(r.rid)

    def _admit_decode(self, req: Request) -> bool:
        # prefix hashes dedup handed-off pages against this client's radix
        # cache, but the hit tokens were already counted at the prefill
        # client — count_hits=False keeps the global counters single-counted
        hashes = self._prefix_hashes(req)
        resident = self.kv.peek_prefix_tokens(hashes) if hashes else 0
        if not self.kv.allocate(req.rid, req.total_context,
                                prefix_hashes=hashes,
                                force=self._oversized(req.total_context),
                                count_hits=False):
            return False
        if req.rid in self._needs_refetch:
            self._needs_refetch.discard(req.rid)
            # pages the radix lookup just mapped locally need no wire fetch
            # — same dedup the coordinator applies to the first handoff
            nbytes = req.total_context * self.kv_per_token
            nbytes -= min(nbytes, resident * self.kv_per_token)
            if nbytes > 0:
                self._pending_swap_bytes += nbytes
                if self.kv.tiers:
                    self._pending_swap_time += tier_transfer_time(
                        nbytes, self.kv.tiers[0].spec)
        if req.decoded_tokens == 0:
            req.decoded_tokens = 1   # disagg prefill emitted token #1
        return True

    def _oversized(self, tokens: int) -> bool:
        """A context bigger than the entire pool can never be admitted by
        backpressure alone — overcommit it (counted) so the system stays
        live, matching real engines' max-model-len escape valves."""
        return self.kv.blocks_for_tokens(tokens) > self.kv.num_blocks

    def has_work(self) -> bool:
        return bool(self.waiting or self.running or self.static_batch
                    or self.swapped)

    # ------------------------------------------------------------------
    def _admit_prefills(self, token_budget: int, batch_budget: int
                        ) -> List[Tuple[Request, int]]:
        """Admit whole-request prefills under budgets + paged KV memory."""
        out = []
        used = 0
        while self.waiting and len(out) < batch_budget:
            r = self.waiting.peek()
            hashes = self._apply_prefix_discount(r)
            toks = r.effective_prefill_tokens
            if out and used + toks > token_budget:
                break
            # decoded_tokens > 0 happens on re-admission after a recompute
            # preemption: the regenerated KV occupies slots again
            ctx = r.input_tokens + r.rag_tokens + r.decoded_tokens
            if not self.kv.allocate(r.rid, ctx, prefix_hashes=hashes,
                                    force=self._oversized(ctx)):
                break
            self.waiting.popleft()
            out.append((r, toks))
            used += toks
        return out

    def plan_step(self, now: Optional[float] = None, slowdown: float = 1.0,
                  horizon: Optional[float] = None) -> Optional[LLMStep]:
        """Plan the next engine step. ``now``/``slowdown`` enable decode
        fast-forward: with the absolute clock known, a stable decode batch is
        expanded into a macro-step whose per-iteration end times are
        pre-accumulated (slowdown applied per iteration, exactly as the event
        loop would). Without ``now`` (direct drivers, non-coordinator use)
        planning stays strictly per-step; single steps are returned unscaled
        and the caller applies slowdown as before."""
        self._try_swap_in()
        s = self.strategy
        if s in ("continuous", "prefill_only", "mixed"):
            step = self._plan_continuous(mixed=(s == "mixed"),
                                         prefill_only=(s == "prefill_only"))
        elif s == "decode_only":
            step = self._plan_decode_only()
        elif s == "chunked":
            step = self._plan_chunked()
        elif s == "static":
            step = self._plan_static()
        else:
            raise ValueError(s)
        if step is not None:
            if now is not None:
                self._maybe_fast_forward(step, now, slowdown, horizon)
            self._attach_pending_swaps(step)
        return step

    # --- decode fast-forward (macro-steps) ------------------------------
    def _ff_groups(self, dec: List[Request]) -> Optional[List[Tuple[List, int]]]:
        """Per-request allocator growth groups for a fast-forward window, or
        None when any request disqualifies the batch: a pending branch fork,
        an off-device or missing table, or a shared partial tail (the next
        write would copy-on-write — let the per-step path take it; one step
        later the tail is private and the window opens)."""
        kv = self.kv
        tables = kv.tables
        # with zero shared blocks device-wide no tail can be shared, so the
        # per-table COW probe is skipped on the (dominant) unshared path
        check_tails = kv._n_shared > 0
        groups: List[Tuple[List, int]] = []
        for r in dec:
            if r.output_tokens <= r.decoded_tokens:
                return None
            brs = self._branch_rids(r)
            if brs and not kv.holds(brs[0]):
                return None                  # fork happens on the next write
            rids = [r.rid] + brs
            for rid in rids:
                t = tables.get(rid)
                if t is None or t.tier != 0:
                    return None
                if check_tails and kv.shared_partial_tail(rid):
                    return None
            groups.append((rids, 1 if brs else r.branches))
        return groups

    def _maybe_fast_forward(self, step: LLMStep, now: float, slowdown: float,
                            horizon: Optional[float] = None):
        """Expand a stable pure-decode step into a macro-step in place.

        Stability invariants (all checked here, so the window can only be cut
        short by an *external* event, which the coordinator handles with
        truncate-and-replay):
        * pure decode — no prefill admissions this step, and none possible
          before the window ends (``waiting`` empty; static batches ignore
          ``waiting`` until they drain, so it may be non-empty there);
        * no swapped-out requests to resume and no pending swap/preemption
          charges to attach;
        * every table grows preemption-free: the worst-case block demand of
          the whole window fits in the free list (``max_growth_steps``), so
          no page fault, radix eviction or victim selection can fire.
        The window length is ``K = min(tokens-to-next-completion,
        tokens-to-block-boundary-pressure)``, additionally cut at the first
        iteration crossing ``horizon`` (the coordinator's next known external
        event — that iteration would be the one in flight when the event
        lands, so pricing past it is work truncate-and-replay would discard).
        Windows of length 1 stay plain steps."""
        if not self.limits.fast_forward or step.n_steps != 1:
            return
        if self.limits.spec_k:
            return   # spec steps emit variable tokens; window invariants
                     # assume exactly one per iteration
        if self.strategy not in ("continuous", "decode_only", "static"):
            return
        if step.kind != "decode" or step.prefill or not step.decode:
            return
        if self.swapped or (self.waiting and self.strategy != "static"):
            return
        if self._pending_swap_bytes or self._pending_swap_time \
                or self._pending_preemptions:
            return
        dec = step.decode
        groups = self._ff_groups(dec)
        if groups is None:
            return
        k_done = min(r.remaining_tokens for r in dec)
        k = self.kv.max_growth_steps(groups, k_done)
        if k <= 1:
            return
        batch = sum(r.branches for r in dec)
        ctx0 = self._avg_ctx(dec)   # grows by exactly 1 per step (stable batch)
        times: List[float] = []
        durs: List[float] = []
        energies: List[float] = []
        flops: List[float] = []
        t = now
        for i in range(k):
            c = self.perf.decode(batch, ctx0 + i)
            d = c.time * slowdown if slowdown != 1.0 else c.time
            t = t + d               # event-loop accumulation order, bit-exact
            times.append(t)
            durs.append(d)
            energies.append(c.energy)
            flops.append(c.flops)
            if horizon is not None and t >= horizon:
                break               # keep the crossing iteration, drop the rest
        k = len(times)
        if k <= 1:
            return
        step.n_steps = k
        step.token_times = times
        step.step_durations = durs
        step.step_energies = energies
        step.step_flops = flops
        step.end_time = times[-1]
        step.duration = times[-1] - now      # reporting only
        step.energy = sum(energies)
        step.flops = sum(flops)
        self.macro_windows += 1
        self._window = step

    def _attach_pending_swaps(self, step: LLMStep):
        """Charge swap traffic (from preemptions and swap-ins) to this step:
        the engine stalls at idle power while pages cross the tier boundary."""
        if self._pending_swap_time > 0 or self._pending_swap_bytes > 0 \
                or self._pending_preemptions:
            step.swap_bytes += self._pending_swap_bytes
            step.swap_time += self._pending_swap_time
            step.duration += self._pending_swap_time
            step.preemptions += self._pending_preemptions
            step.energy += ana.idle_stall_energy(self._pending_swap_time,
                                                 self.cluster)
            self._pending_swap_bytes = 0.0
            self._pending_swap_time = 0.0
            self._pending_preemptions = 0

    def _swap_groups(self) -> int:
        """Layer groups for layerwise swap pricing; 0 = one per layer."""
        n = self.limits.swap_layer_groups
        return n if n > 0 else self.cfg.num_layers

    def _try_swap_in(self):
        """Resume swapped-out requests oldest-first, keeping one block of
        headroom per running request to avoid swap ping-pong. When nothing
        else is active the headroom is waived so the system stays live."""
        while self.swapped:
            r = self.swapped[0]
            need = len(self.kv.tables[r.rid].blocks)
            headroom = len(self.running) if (self.running or self.waiting) else 0
            if need + headroom > self.kv.available_blocks:
                break
            res = self.kv.swap_in(r.rid, self.limits.swap_granularity,
                                  self._swap_groups())
            if res is None:
                break
            nbytes, t = res
            self._pending_swap_bytes += nbytes
            self._pending_swap_time += t
            self.swapped.pop(0)
            if self.strategy == "static":
                self.static_batch.append(r)
            else:
                self.running.append(r)

    # --- preemption ----------------------------------------------------
    def _preemptable(self, exclude: Request) -> Optional[Request]:
        """Coldest victim = the most recently admitted request (LIFO), so the
        oldest request always keeps its pages and the system stays live.
        Finished requests (no pages to reclaim usefully, must not re-enter
        the queues) are never victims."""
        for pool in (self.running, self.static_batch):
            for r in reversed(pool):
                if r is not exclude and r.remaining_tokens > 0 \
                        and self.kv.holds(r.rid):
                    return r
        return None

    def _preempt_one(self, grower: Request) -> bool:
        """Evict one victim to make room for ``grower``. Returns False when
        nobody but ``grower`` holds pages."""
        # a finished static-batch member still holds pages until the batch
        # drains — reclaim those first, in place, so it never lands in
        # swapped/waiting (where a done request would stall _plan_static)
        for r in self.static_batch:
            if r is not grower and r.remaining_tokens <= 0 \
                    and self.kv.holds(r.rid):
                self._release_kv(r)
                return True
        victim = self._preemptable(exclude=grower)
        if victim is None:
            # last resort: a queued chunked request holding partial pages
            for r in reversed(self.waiting):
                if r is not grower and self.kv.holds(r.rid):
                    self._drop_kv(r)
                    r.prefilled_tokens = 0
                    self.chunk_progress.pop(r.rid, None)
                    r.preemptions += 1
                    self._pending_preemptions += 1
                    return True
            return False
        victim.preemptions += 1
        self._pending_preemptions += 1
        if self.limits.preemption == "swap":
            # swap moves physical pages, so it applies only to refcount-1
            # tables; shared-prefix / forked victims return None and degrade
            # to recompute (which merely drops references)
            res = self.kv.swap_out(victim.rid, self.limits.swap_granularity,
                                   self._swap_groups())
            if res is not None:
                nbytes, t = res
                self._pending_swap_bytes += nbytes
                self._pending_swap_time += t
                self._remove_from_pools(victim)
                self.swapped.append(victim)
                return True
            # spill tiers full or pages shared: degrade to recompute
        self._drop_kv(victim)
        victim.prefilled_tokens = 0
        self.chunk_progress.pop(victim.rid, None)
        if self.strategy == "decode_only":
            self._needs_refetch.add(victim.rid)
        self._remove_from_pools(victim)
        self.waiting.requeue(victim)
        return True

    def _remove_from_pools(self, r: Request):
        for pool in (self.running, self.static_batch):
            if r in pool:
                pool.remove(r)

    def _grow(self, r: Request) -> bool:
        """Decode growth with preemption: returns False only when ``r`` was
        itself preempted (recompute) and must not emit a token this step.

        Multi-branch requests (prefix sharing on) grow one token per branch
        across copy-on-write tables forked from the prefill table on the
        first divergent write — branches share every prefill page and own
        only their divergent decode pages. With sharing off, the pre-radix
        behavior (one table growing ``branches`` slots per step) is kept."""
        brs = self._branch_rids(r)
        if brs:
            if not self.kv.holds(brs[0]):     # first divergent decode write
                for br in brs:
                    self.kv.fork(r.rid, br)
            grow = lambda force=False: self.kv.grow_request(
                [r.rid] + brs, 1, force=force)
        else:
            grow = lambda force=False: self.kv.append_tokens(
                r.rid, r.branches, force=force)
        while not grow():
            if not self._preempt_one(r):
                # r alone holds the pool (oversized request): overcommit
                grow(force=True)
                return True
            if not self.kv.holds(r.rid) or not self.kv.tables[r.rid].on_device:
                return False   # r lost its own pages to the policy
        return True

    # --- continuous / mixed / prefill-only ----------------------------
    def _plan_continuous(self, mixed: bool, prefill_only: bool) -> Optional[LLMStep]:
        pre = self._admit_prefills(self.limits.max_prefill_tokens,
                                   self.limits.max_batch)
        if pre:
            step = LLMStep("prefill", prefill=pre)
            toks = sum(t for _, t in pre)
            cost = self.perf.prefill(toks, 1)
            if mixed and self.running:
                dec = self.running[: self.limits.max_batch]
                step.decode = dec
                cost2 = self.perf.chunked(toks, sum(r.branches for r in dec),
                                          self._avg_ctx(dec))
                step.duration, step.energy, step.flops = (cost2.time,
                                                          cost2.energy, cost2.flops)
            else:
                step.duration, step.energy, step.flops = (cost.time, cost.energy,
                                                          cost.flops)
            return step
        if prefill_only or not self.running:
            return None
        return self._decode_step(self.running[: self.limits.max_batch])

    # --- pure decode (disaggregated decode client) ---------------------
    def _plan_decode_only(self) -> Optional[LLMStep]:
        # admit arrivals that found the pool full at add()
        while self.waiting:
            r = self.waiting.peek()
            if not self._admit_decode(r):
                break
            self.waiting.popleft()
            self.running.append(r)
        if not self.running:
            return None
        return self._decode_step(self.running[: self.limits.max_batch])

    # --- chunked (Sarathi) ---------------------------------------------
    def _plan_chunked(self) -> Optional[LLMStep]:
        dec = self.running[: self.limits.max_batch]
        budget = self.limits.chunk_size - sum(r.branches for r in dec)
        pre: List[Tuple[Request, int]] = []
        while budget > 0 and self.waiting:
            r = self.waiting.peek()
            done = self.chunk_progress.get(r.rid, 0)
            if done == 0 and not self.kv.holds(r.rid):
                hashes = self._apply_prefix_discount(r)
                ctx = r.input_tokens + r.rag_tokens + r.decoded_tokens
                if not self.kv.allocate(r.rid, ctx, prefix_hashes=hashes,
                                        force=self._oversized(ctx)):
                    break
            remaining = r.effective_prefill_tokens - done
            take = min(remaining, budget)
            pre.append((r, take))
            self.chunk_progress[r.rid] = done + take
            budget -= take
            if done + take >= r.effective_prefill_tokens:
                self.waiting.popleft()
            else:
                break  # head-of-line request still prefilling
        if not pre and not dec:
            return None
        toks = sum(t for _, t in pre)
        cost = self.perf.chunked(toks, sum(r.branches for r in dec),
                                 self._avg_ctx(dec) if dec else 0)
        return LLMStep("chunked", prefill=pre, decode=dec, duration=cost.time,
                       energy=cost.energy, flops=cost.flops)

    # --- static (FasterTransformers) ------------------------------------
    def _plan_static(self) -> Optional[LLMStep]:
        if not self.static_batch:
            pre = self._admit_prefills(10 ** 12, self.limits.max_batch)
            if not pre:
                return None
            self.static_batch = [r for r, _ in pre]
            toks = sum(t for _, t in pre)
            cost = self.perf.prefill(toks, 1)
            return LLMStep("prefill", prefill=pre, duration=cost.time,
                           energy=cost.energy, flops=cost.flops)
        live = [r for r in self.static_batch if r.remaining_tokens > 0]
        if not live:
            return None
        return self._decode_step(live)

    # ------------------------------------------------------------------
    def _decode_step(self, dec: List[Request]) -> LLMStep:
        """Price a pure-decode iteration. With ``limits.spec_k`` set this is
        a SPEC_DECODE stage — one draft+verify iteration committing
        ``spec_expected`` tokens per request in expectation — otherwise the
        classic one-token decode step."""
        batch = sum(r.branches for r in dec)
        ctx = self._avg_ctx(dec)
        if self.limits.spec_k:
            cost, exp = self.perf.spec_decode(batch, ctx, self._draft_cfg,
                                              self.limits.spec_k,
                                              self.limits.spec_acceptance)
            return LLMStep("decode", decode=dec, duration=cost.time,
                           energy=cost.energy, flops=cost.flops,
                           spec_expected=exp)
        cost = self.perf.decode(batch, ctx)
        return LLMStep("decode", decode=dec, duration=cost.time,
                       energy=cost.energy, flops=cost.flops)

    # ------------------------------------------------------------------
    def _avg_ctx(self, reqs: List[Request]) -> int:
        if not reqs:
            return 0
        return int(sum(r.total_context for r in reqs) / len(reqs))

    # ------------------------------------------------------------------
    def _apply_decode_window(self, step: LLMStep, j: int) -> List[Request]:
        """Commit the first ``j`` iterations of a macro-step: bulk KV growth
        (one allocator call per request instead of one per token), token
        emissions at the pre-accumulated per-iteration times, and energy
        accumulated in the same per-step order the event loop would use.
        Planning reserved the whole window's worst-case block demand out of
        the free list, so growth cannot fail. Completions can only happen at
        the window's final iteration (``K = tokens-to-next-completion``), so
        a truncated commit (``j < n_steps``) never finishes a request."""
        finished: List[Request] = []
        times = step.token_times[:j]
        for e in step.step_energies[:j]:
            self.total_energy += e
        # KV growth is bulk — unless this commit completes a request. A
        # completion's release interleaves with neighbours' growth in the
        # per-step loop, so to keep the transient peak_blocks high-water mark
        # bit-equal the first j-1 iterations grow in bulk (pure monotone
        # growth: order is transparent to the peak) and the final iteration
        # replays the per-step grow-emit-release order request by request.
        def _grow_bulk(r: Request, n: int) -> bool:
            brs = self._branch_rids(r)
            if brs:
                return self.kv.grow_request([r.rid] + brs, n)
            return self.kv.append_tokens(r.rid, n * r.branches)

        completes = any(r.remaining_tokens == j for r in step.decode)
        head = j - 1 if completes else j
        if head > 0:
            for r in step.decode:
                if not _grow_bulk(r, head):   # plan reserved this headroom
                    raise AssertionError(
                        "fast-forward window overran its reserved headroom")
        for r in step.decode:
            if completes and not _grow_bulk(r, 1):
                raise AssertionError(
                    "fast-forward window overran its reserved headroom")
            r.decoded_tokens += j
            if r.first_token_time is None:
                r.first_token_time = times[0]
            r.last_token_time = times[-1]
            r.token_times.extend(times)
            self.total_tokens += r.branches * j
            if r.remaining_tokens <= 0 and self.strategy != "static":
                finished.append(r)
                self._release_kv(r)
                if r in self.running:
                    self.running.remove(r)
        if self.strategy == "static" and self.static_batch and \
                all(r.remaining_tokens <= 0 for r in self.static_batch):
            for r in self.static_batch:
                finished.append(r)
                self._release_kv(r)
            self.static_batch = []
        self.micro_steps += j
        self.step_events += 1
        self.history.append({
            "time": times[-1], "queue": len(self.waiting),
            "running": len(self.running), "swapped": len(self.swapped),
            "mem_used": self.kv.used,
            "kv_util": self.kv.used_blocks / max(1, self.kv.num_blocks),
            "step_tokens": sum(r.branches for r in step.decode) * j,
            "kind": step.kind, "steps": j,
        })
        return finished

    def truncate_step(self, step: LLMStep, now: float,
                      inclusive: bool = False
                      ) -> Tuple[Optional[LLMStep], List[float]]:
        """Macro-step invalidation (truncate-and-replay): an external event
        landed at ``now``, mid-window. Commit the prefix of iterations that
        already finished — strictly before ``now``; ``inclusive`` (horizon
        cut-off) also commits one ending exactly at ``now`` — and return
        ``(remainder, committed_energies)``. The remainder is the iteration
        in flight across ``now``, repackaged as a plain single step ending at
        its original boundary: exactly the step a per-step execution would
        have had in flight, so the replay is bit-equal. ``remainder`` is None
        when the whole window committed (only possible via ``inclusive``)."""
        self._window = None
        cut = bisect_right if inclusive else bisect_left
        j = cut(step.token_times, now)
        if j > 0:
            self._apply_decode_window(step, j)
        if j >= step.n_steps:
            return None, step.step_energies
        rem = LLMStep("decode", decode=list(step.decode),
                      duration=step.step_durations[j],
                      energy=step.step_energies[j],
                      flops=step.step_flops[j])
        rem.end_time = step.token_times[j]
        return rem, step.step_energies[:j]

    def finish_step(self, step: LLMStep, now: float) -> List[Request]:
        """Apply step effects; returns requests whose LLM stage completed."""
        if step.n_steps > 1:
            self._window = None
            return self._apply_decode_window(step, step.n_steps)
        finished: List[Request] = []
        self.total_energy += step.energy
        for r, toks in step.prefill:
            r.prefilled_tokens += toks
            if r.prefilled_tokens >= r.effective_prefill_tokens:
                self.chunk_progress.pop(r.rid, None)
                # prefill emits the first output token
                if r.decoded_tokens == 0:
                    r.decoded_tokens = 1
                    r.first_token_time = now
                    r.last_token_time = now
                    r.token_times.append(now)
                    self.total_tokens += 1
                if self.strategy == "prefill_only":
                    finished.append(r)  # hand off to the decode client
                    # KV ships to the decode client; radix-registered prefix
                    # blocks stay cached for the next same-prefix prefill
                    self._release_kv(r)
                elif r.remaining_tokens <= 0:
                    finished.append(r)
                    self._release_kv(r)
                elif self.strategy != "static":
                    self.running.append(r)
        n_emit = 1
        if step.spec_expected and step.decode:
            # integerize the fractional expectation through the carry so the
            # long-run token stream matches it exactly (expected >= 1 keeps
            # every iteration emitting at least one token)
            self._spec_carry += step.spec_expected
            n_emit = max(1, int(self._spec_carry))
            self._spec_carry -= n_emit
        for r in step.decode:
            if r.remaining_tokens <= 0:
                continue
            if not self.kv.holds(r.rid) or not self.kv.tables[r.rid].on_device:
                continue   # preempted earlier in this very step
            emit = 0
            for _ in range(min(n_emit, r.remaining_tokens)):
                if not self._grow(r):
                    break  # recompute-preempted itself; stop emitting
                emit += 1
            if not emit:
                continue
            r.decoded_tokens += emit
            if r.first_token_time is None:
                r.first_token_time = now
            r.last_token_time = now
            r.token_times.extend([now] * emit)
            self.total_tokens += r.branches * emit
            if r.remaining_tokens <= 0 and self.strategy != "static":
                finished.append(r)
                self._release_kv(r)
                if r in self.running:
                    self.running.remove(r)
        if self.strategy == "static" and self.static_batch and \
                all(r.remaining_tokens <= 0 for r in self.static_batch):
            for r in self.static_batch:
                finished.append(r)
                self._release_kv(r)
            self.static_batch = []
        self.micro_steps += 1
        self.step_events += 1
        self.history.append({
            "time": now, "queue": len(self.waiting), "running": len(self.running),
            "swapped": len(self.swapped), "mem_used": self.kv.used,
            "kv_util": self.kv.used_blocks / max(1, self.kv.num_blocks),
            "step_tokens": step.n_tokens, "kind": step.kind, "steps": 1,
        })
        return finished

    # --- fault tolerance ------------------------------------------------
    def requeue_step(self, step: LLMStep) -> None:
        """An in-flight step is being discarded unfinished (client fail or
        removal). Prefill admission pops requests out of ``waiting`` while
        they only enter ``running`` at ``finish_step`` — inside a discarded
        step they are invisible to ``drain()`` and would be lost outright
        (a straggler deadline then re-arms for them forever). Put them back
        first. Decode members are still in ``running`` and static batches
        in ``static_batch``, both already drain-visible."""
        for r, _ in step.prefill:
            if (r not in self.waiting and r not in self.running
                    and r not in self.static_batch):
                self.waiting.requeue(r)

    def drain(self) -> List[Request]:
        """Client failure: return every in-flight request for re-dispatch.
        KV state is lost; prefill restarts (paper-scale systems re-prefill)."""
        out = (list(self.waiting) + list(self.running)
               + list(self.static_batch) + list(self.swapped))
        for r in out:
            self._release_kv(r)
            r.prefilled_tokens = 0
            if r.decoded_tokens > 1:
                r.decoded_tokens = max(1, r.decoded_tokens)  # keep emitted tokens
            r.failures += 1
        self.waiting.clear()
        self.running, self.static_batch = [], []
        self.swapped = []
        self._window = None
        self.chunk_progress.clear()
        self._needs_refetch.clear()
        self.kv.discard_exports()      # pinned chains died with the device
        self.kv.clear_cache()          # a failed client's radix cache is gone
        self.kv.check_invariants()
        return out

    def remove_waiting(self, r: Request) -> bool:
        """Straggler rescue: pull a queued request and release any pages it
        already holds (chunked admission allocates at first touch). Partial
        prefill progress dies with the pages — the new client restarts it."""
        if r not in self.waiting:
            return False
        self.waiting.remove(r)
        self._release_kv(r)
        self.chunk_progress.pop(r.rid, None)
        self._needs_refetch.discard(r.rid)
        r.prefilled_tokens = 0
        return True
