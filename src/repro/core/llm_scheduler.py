"""vLLM-style LLM scheduler with the paper's five batching strategies
(§III-D1): static, continuous, chunked, mixed, disaggregated (prefill_only /
decode_only halves), plus FCFS / least-work-left packing.

KV memory is managed by the paged allocator (``core/memory.py``): admission
reserves whole-context block tables, decode growth faults in blocks one at a
time, and exhaustion is resolved by a pluggable preemption policy —
``swap`` (offload the coldest request's pages to the next tier, priced with
the Eq. 1 tier term) or ``recompute`` (drop pages, re-enqueue the prefill).

Prefix sharing (``limits.prefix_caching``, on by default): requests carrying
``prefix_segments`` admit against the allocator's radix cache — resident
shared-prefix blocks are mapped instead of re-allocated, and the hit tokens
discount the prefill compute (``Request.cached_tokens`` becomes a *real*
lookup). Multi-branch reasoning requests fork their block table copy-on-write
on the first divergent decode write, so branches share every prefill page.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.configs.base import ModelConfig
from repro.core.memory import PagedKVAllocator, tier_transfer_time
from repro.core.request import Request
from repro.perfmodel import analytical as ana
from repro.perfmodel.hardware import CacheTierSpec, ClusterSpec, \
    DEFAULT_SWAP_TIERS

STRATEGIES = ("static", "continuous", "chunked", "mixed",
              "prefill_only", "decode_only")
PREEMPTION_POLICIES = ("swap", "recompute")


@dataclass(frozen=True)
class SchedulerLimits:
    max_batch: int = 64
    max_prefill_tokens: int = 8192     # prefill token budget per step
    chunk_size: int = 512              # chunked-batching token budget
    # paged KV allocator knobs
    kv_block_tokens: int = 32          # tokens per KV page
    preemption: str = "swap"           # swap | recompute
    kv_capacity_frac: float = 1.0      # scale usable HBM (capacity studies)
    swap_tiers: Tuple[CacheTierSpec, ...] = DEFAULT_SWAP_TIERS
    # shared-prefix radix cache + copy-on-write branch forking. Neutral for
    # workloads without prefix_segments / branches; set False to reproduce
    # the pre-radix (PR 1) allocator behavior exactly.
    prefix_caching: bool = True


@dataclass
class LLMStep:
    kind: str                          # "prefill" | "decode" | "chunked"
    prefill: List[Tuple[Request, int]] = field(default_factory=list)  # (req, tokens)
    decode: List[Request] = field(default_factory=list)
    duration: float = 0.0
    energy: float = 0.0
    flops: float = 0.0
    # KV paging traffic attributed to this step (set at plan/finish time)
    swap_bytes: float = 0.0
    swap_time: float = 0.0
    preemptions: int = 0

    @property
    def n_tokens(self) -> int:
        pre = sum(t for _, t in self.prefill)
        dec = sum(r.branches for r in self.decode)
        return pre + dec


class ClientPerf:
    """Runtime predictor for a client: fitted regression with analytical
    fallback (paper §III-E1)."""

    def __init__(self, model_cfg: ModelConfig, cluster: ClusterSpec,
                 use_regression: bool = True):
        self.cfg = model_cfg
        self.cluster = cluster
        self.decode_model = None
        self.prefill_model = None
        if use_regression:
            from repro.perfmodel import regression as reg
            self.decode_model = reg.fit_decode_model(model_cfg, cluster)
            self.prefill_model = reg.fit_prefill_model(model_cfg, cluster)

    def prefill(self, tokens: int, batch: int, past: int = 0) -> ana.StageCost:
        c = ana.prefill_time(self.cfg, self.cluster, tokens, batch, past)
        if self.prefill_model is not None:
            t = float(self.prefill_model.predict([past], [tokens], [batch])[0])
            if t > 0:
                return ana.StageCost(t, c.energy * t / max(c.time, 1e-12),
                                     c.flops, c.bytes, c.bound)
        return c

    def decode(self, batch: int, avg_ctx: int) -> ana.StageCost:
        c = ana.decode_step_time(self.cfg, self.cluster, batch, avg_ctx)
        if self.decode_model is not None:
            t = float(self.decode_model.predict([batch], [avg_ctx])[0])
            if t > 0:
                return ana.StageCost(t, c.energy * t / max(c.time, 1e-12),
                                     c.flops, c.bytes, c.bound)
        return c

    def chunked(self, chunk_tokens: int, decode_batch: int,
                avg_ctx: int) -> ana.StageCost:
        return ana.chunked_step_time(self.cfg, self.cluster, chunk_tokens,
                                     decode_batch, avg_ctx)


class LLMScheduler:
    def __init__(self, strategy: str, model_cfg: ModelConfig,
                 cluster: ClusterSpec, perf: Optional[ClientPerf] = None,
                 limits: SchedulerLimits = SchedulerLimits(),
                 packing: str = "fcfs"):
        assert strategy in STRATEGIES, strategy
        assert limits.preemption in PREEMPTION_POLICIES, limits.preemption
        self.strategy = strategy
        self.cfg = model_cfg
        self.cluster = cluster
        self.perf = perf or ClientPerf(model_cfg, cluster, use_regression=False)
        self.limits = limits
        self.packing = packing
        self.waiting: List[Request] = []
        self.running: List[Request] = []
        self.swapped: List[Request] = []   # preempted-to-tier, awaiting swap-in
        self.chunk_progress: Dict[int, int] = {}   # rid -> prefilled tokens
        self.static_batch: List[Request] = []
        weights = model_cfg.param_count() * ana.BYTES_PER_PARAM / cluster.tp
        capacity = max(cluster.total_mem - weights * cluster.n_chips / max(
            1, cluster.tp) * cluster.tp, cluster.total_mem * 0.15)
        self.kv_per_token = ana.kv_bytes_per_token(model_cfg) + (
            ana.ssm_state_bytes(model_cfg) / 4096.0)
        self.kv = PagedKVAllocator(
            capacity * limits.kv_capacity_frac, self.kv_per_token,
            block_tokens=limits.kv_block_tokens,
            swap_tiers=limits.swap_tiers)
        # swap traffic incurred inside finish_step, charged to the NEXT step
        self._pending_swap_bytes = 0.0
        self._pending_swap_time = 0.0
        self._pending_preemptions = 0
        # decode_only victims of recompute preemption: their KV must be
        # re-fetched (a decode replica cannot re-run prefill), priced on
        # re-admission like a swap-in from the first spill tier
        self._needs_refetch: set = set()
        # scheduler-level metrics (paper §III-F2)
        self.history: List[Dict] = []
        self.total_energy = 0.0
        self.total_tokens = 0

    # ------------------------------------------------------------------
    def add(self, req: Request):
        if self.strategy == "decode_only":
            # KV produced by the prefill client arrives with the request
            if self._admit_decode(req):
                self.running.append(req)
            else:
                self.waiting.append(req)
        else:
            self.waiting.append(req)
        if self.packing == "least_work":
            self.waiting.sort(key=lambda r: r.effective_prefill_tokens
                              + r.remaining_tokens)

    # --- prefix sharing -------------------------------------------------
    def _prefix_hashes(self, r: Request) -> List[int]:
        if not self.limits.prefix_caching or not r.prefix_segments:
            return []
        return r.prefix_block_hashes(self.kv.block_tokens)

    def _apply_prefix_discount(self, r: Request) -> List[int]:
        """Turn ``cached_tokens`` into a real radix-cache lookup: the tokens
        whose blocks are already resident need no prefill compute. At least
        one token is always computed (the sampling position). Requests
        without a shared-prefix identity keep their fiat value."""
        hashes = self._prefix_hashes(r)
        if hashes:
            hit = self.kv.peek_prefix_tokens(hashes)
            r.cached_tokens = min(hit, r.input_tokens + r.rag_tokens - 1)
        return hashes

    def _branch_rids(self, r: Request) -> List:
        """Allocator keys for the copy-on-write branch tables of a
        multi-branch reasoning request (the parent keeps ``r.rid``)."""
        if r.branches <= 1 or not self.limits.prefix_caching:
            return []
        return [("br", r.rid, b) for b in range(1, r.branches)]

    def _release_kv(self, r: Request):
        """Free the request's main table plus any forked branch tables."""
        for br in self._branch_rids(r):
            if self.kv.holds(br):
                self.kv.free(br)
        self.kv.free(r.rid)

    def _drop_kv(self, r: Request):
        """Recompute-preemption drop, branch tables included."""
        for br in self._branch_rids(r):
            if self.kv.holds(br):
                self.kv.free(br)
        self.kv.drop(r.rid)

    def _admit_decode(self, req: Request) -> bool:
        # prefix hashes dedup handed-off pages against this client's radix
        # cache, but the hit tokens were already counted at the prefill
        # client — count_hits=False keeps the global counters single-counted
        hashes = self._prefix_hashes(req)
        resident = self.kv.peek_prefix_tokens(hashes) if hashes else 0
        if not self.kv.allocate(req.rid, req.total_context,
                                prefix_hashes=hashes,
                                force=self._oversized(req.total_context),
                                count_hits=False):
            return False
        if req.rid in self._needs_refetch:
            self._needs_refetch.discard(req.rid)
            # pages the radix lookup just mapped locally need no wire fetch
            # — same dedup the coordinator applies to the first handoff
            nbytes = req.total_context * self.kv_per_token
            nbytes -= min(nbytes, resident * self.kv_per_token)
            if nbytes > 0:
                self._pending_swap_bytes += nbytes
                if self.kv.tiers:
                    self._pending_swap_time += tier_transfer_time(
                        nbytes, self.kv.tiers[0].spec)
        if req.decoded_tokens == 0:
            req.decoded_tokens = 1   # disagg prefill emitted token #1
        return True

    def _oversized(self, tokens: int) -> bool:
        """A context bigger than the entire pool can never be admitted by
        backpressure alone — overcommit it (counted) so the system stays
        live, matching real engines' max-model-len escape valves."""
        return self.kv.blocks_for_tokens(tokens) > self.kv.num_blocks

    def has_work(self) -> bool:
        return bool(self.waiting or self.running or self.static_batch
                    or self.swapped)

    # ------------------------------------------------------------------
    def _admit_prefills(self, token_budget: int, batch_budget: int
                        ) -> List[Tuple[Request, int]]:
        """Admit whole-request prefills under budgets + paged KV memory."""
        out = []
        used = 0
        while self.waiting and len(out) < batch_budget:
            r = self.waiting[0]
            hashes = self._apply_prefix_discount(r)
            toks = r.effective_prefill_tokens
            if out and used + toks > token_budget:
                break
            # decoded_tokens > 0 happens on re-admission after a recompute
            # preemption: the regenerated KV occupies slots again
            ctx = r.input_tokens + r.rag_tokens + r.decoded_tokens
            if not self.kv.allocate(r.rid, ctx, prefix_hashes=hashes,
                                    force=self._oversized(ctx)):
                break
            self.waiting.pop(0)
            out.append((r, toks))
            used += toks
        return out

    def plan_step(self) -> Optional[LLMStep]:
        self._try_swap_in()
        s = self.strategy
        if s in ("continuous", "prefill_only", "mixed"):
            step = self._plan_continuous(mixed=(s == "mixed"),
                                         prefill_only=(s == "prefill_only"))
        elif s == "decode_only":
            step = self._plan_decode_only()
        elif s == "chunked":
            step = self._plan_chunked()
        elif s == "static":
            step = self._plan_static()
        else:
            raise ValueError(s)
        if step is not None:
            self._attach_pending_swaps(step)
        return step

    def _attach_pending_swaps(self, step: LLMStep):
        """Charge swap traffic (from preemptions and swap-ins) to this step:
        the engine stalls at idle power while pages cross the tier boundary."""
        if self._pending_swap_time > 0 or self._pending_swap_bytes > 0 \
                or self._pending_preemptions:
            step.swap_bytes += self._pending_swap_bytes
            step.swap_time += self._pending_swap_time
            step.duration += self._pending_swap_time
            step.preemptions += self._pending_preemptions
            step.energy += ana.idle_stall_energy(self._pending_swap_time,
                                                 self.cluster)
            self._pending_swap_bytes = 0.0
            self._pending_swap_time = 0.0
            self._pending_preemptions = 0

    def _try_swap_in(self):
        """Resume swapped-out requests oldest-first, keeping one block of
        headroom per running request to avoid swap ping-pong. When nothing
        else is active the headroom is waived so the system stays live."""
        while self.swapped:
            r = self.swapped[0]
            need = len(self.kv.tables[r.rid].blocks)
            headroom = len(self.running) if (self.running or self.waiting) else 0
            if need + headroom > self.kv.available_blocks:
                break
            res = self.kv.swap_in(r.rid)
            if res is None:
                break
            nbytes, t = res
            self._pending_swap_bytes += nbytes
            self._pending_swap_time += t
            self.swapped.pop(0)
            if self.strategy == "static":
                self.static_batch.append(r)
            else:
                self.running.append(r)

    # --- preemption ----------------------------------------------------
    def _preemptable(self, exclude: Request) -> Optional[Request]:
        """Coldest victim = the most recently admitted request (LIFO), so the
        oldest request always keeps its pages and the system stays live.
        Finished requests (no pages to reclaim usefully, must not re-enter
        the queues) are never victims."""
        for pool in (self.running, self.static_batch):
            for r in reversed(pool):
                if r is not exclude and r.remaining_tokens > 0 \
                        and self.kv.holds(r.rid):
                    return r
        return None

    def _preempt_one(self, grower: Request) -> bool:
        """Evict one victim to make room for ``grower``. Returns False when
        nobody but ``grower`` holds pages."""
        # a finished static-batch member still holds pages until the batch
        # drains — reclaim those first, in place, so it never lands in
        # swapped/waiting (where a done request would stall _plan_static)
        for r in self.static_batch:
            if r is not grower and r.remaining_tokens <= 0 \
                    and self.kv.holds(r.rid):
                self._release_kv(r)
                return True
        victim = self._preemptable(exclude=grower)
        if victim is None:
            # last resort: a queued chunked request holding partial pages
            for r in reversed(self.waiting):
                if r is not grower and self.kv.holds(r.rid):
                    self._drop_kv(r)
                    r.prefilled_tokens = 0
                    self.chunk_progress.pop(r.rid, None)
                    r.preemptions += 1
                    self._pending_preemptions += 1
                    return True
            return False
        victim.preemptions += 1
        self._pending_preemptions += 1
        if self.limits.preemption == "swap":
            # swap moves physical pages, so it applies only to refcount-1
            # tables; shared-prefix / forked victims return None and degrade
            # to recompute (which merely drops references)
            res = self.kv.swap_out(victim.rid)
            if res is not None:
                nbytes, t = res
                self._pending_swap_bytes += nbytes
                self._pending_swap_time += t
                self._remove_from_pools(victim)
                self.swapped.append(victim)
                return True
            # spill tiers full or pages shared: degrade to recompute
        self._drop_kv(victim)
        victim.prefilled_tokens = 0
        self.chunk_progress.pop(victim.rid, None)
        if self.strategy == "decode_only":
            self._needs_refetch.add(victim.rid)
        self._remove_from_pools(victim)
        self.waiting.insert(0, victim)
        return True

    def _remove_from_pools(self, r: Request):
        for pool in (self.running, self.static_batch):
            if r in pool:
                pool.remove(r)

    def _grow(self, r: Request) -> bool:
        """Decode growth with preemption: returns False only when ``r`` was
        itself preempted (recompute) and must not emit a token this step.

        Multi-branch requests (prefix sharing on) grow one token per branch
        across copy-on-write tables forked from the prefill table on the
        first divergent write — branches share every prefill page and own
        only their divergent decode pages. With sharing off, the pre-radix
        behavior (one table growing ``branches`` slots per step) is kept."""
        brs = self._branch_rids(r)
        if brs:
            if not self.kv.holds(brs[0]):     # first divergent decode write
                for br in brs:
                    self.kv.fork(r.rid, br)
            grow = lambda force=False: self.kv.grow_request(
                [r.rid] + brs, 1, force=force)
        else:
            grow = lambda force=False: self.kv.append_tokens(
                r.rid, r.branches, force=force)
        while not grow():
            if not self._preempt_one(r):
                # r alone holds the pool (oversized request): overcommit
                grow(force=True)
                return True
            if not self.kv.holds(r.rid) or not self.kv.tables[r.rid].on_device:
                return False   # r lost its own pages to the policy
        return True

    # --- continuous / mixed / prefill-only ----------------------------
    def _plan_continuous(self, mixed: bool, prefill_only: bool) -> Optional[LLMStep]:
        pre = self._admit_prefills(self.limits.max_prefill_tokens,
                                   self.limits.max_batch)
        if pre:
            step = LLMStep("prefill", prefill=pre)
            toks = sum(t for _, t in pre)
            cost = self.perf.prefill(toks, 1)
            if mixed and self.running:
                dec = self.running[: self.limits.max_batch]
                step.decode = dec
                cost2 = self.perf.chunked(toks, sum(r.branches for r in dec),
                                          self._avg_ctx(dec))
                step.duration, step.energy, step.flops = (cost2.time,
                                                          cost2.energy, cost2.flops)
            else:
                step.duration, step.energy, step.flops = (cost.time, cost.energy,
                                                          cost.flops)
            return step
        if prefill_only or not self.running:
            return None
        dec = self.running[: self.limits.max_batch]
        cost = self.perf.decode(sum(r.branches for r in dec), self._avg_ctx(dec))
        return LLMStep("decode", decode=dec, duration=cost.time,
                       energy=cost.energy, flops=cost.flops)

    # --- pure decode (disaggregated decode client) ---------------------
    def _plan_decode_only(self) -> Optional[LLMStep]:
        # admit arrivals that found the pool full at add()
        while self.waiting:
            r = self.waiting[0]
            if not self._admit_decode(r):
                break
            self.waiting.pop(0)
            self.running.append(r)
        if not self.running:
            return None
        dec = self.running[: self.limits.max_batch]
        cost = self.perf.decode(sum(r.branches for r in dec), self._avg_ctx(dec))
        return LLMStep("decode", decode=dec, duration=cost.time,
                       energy=cost.energy, flops=cost.flops)

    # --- chunked (Sarathi) ---------------------------------------------
    def _plan_chunked(self) -> Optional[LLMStep]:
        dec = self.running[: self.limits.max_batch]
        budget = self.limits.chunk_size - sum(r.branches for r in dec)
        pre: List[Tuple[Request, int]] = []
        while budget > 0 and self.waiting:
            r = self.waiting[0]
            done = self.chunk_progress.get(r.rid, 0)
            if done == 0 and not self.kv.holds(r.rid):
                hashes = self._apply_prefix_discount(r)
                ctx = r.input_tokens + r.rag_tokens + r.decoded_tokens
                if not self.kv.allocate(r.rid, ctx, prefix_hashes=hashes,
                                        force=self._oversized(ctx)):
                    break
            remaining = r.effective_prefill_tokens - done
            take = min(remaining, budget)
            pre.append((r, take))
            self.chunk_progress[r.rid] = done + take
            budget -= take
            if done + take >= r.effective_prefill_tokens:
                self.waiting.pop(0)
            else:
                break  # head-of-line request still prefilling
        if not pre and not dec:
            return None
        toks = sum(t for _, t in pre)
        cost = self.perf.chunked(toks, sum(r.branches for r in dec),
                                 self._avg_ctx(dec) if dec else 0)
        return LLMStep("chunked", prefill=pre, decode=dec, duration=cost.time,
                       energy=cost.energy, flops=cost.flops)

    # --- static (FasterTransformers) ------------------------------------
    def _plan_static(self) -> Optional[LLMStep]:
        if not self.static_batch:
            pre = self._admit_prefills(10 ** 12, self.limits.max_batch)
            if not pre:
                return None
            self.static_batch = [r for r, _ in pre]
            toks = sum(t for _, t in pre)
            cost = self.perf.prefill(toks, 1)
            return LLMStep("prefill", prefill=pre, duration=cost.time,
                           energy=cost.energy, flops=cost.flops)
        live = [r for r in self.static_batch if r.remaining_tokens > 0]
        if not live:
            return None
        cost = self.perf.decode(sum(r.branches for r in live), self._avg_ctx(live))
        return LLMStep("decode", decode=live, duration=cost.time,
                       energy=cost.energy, flops=cost.flops)

    # ------------------------------------------------------------------
    def _avg_ctx(self, reqs: List[Request]) -> int:
        if not reqs:
            return 0
        return int(sum(r.total_context for r in reqs) / len(reqs))

    # ------------------------------------------------------------------
    def finish_step(self, step: LLMStep, now: float) -> List[Request]:
        """Apply step effects; returns requests whose LLM stage completed."""
        finished: List[Request] = []
        self.total_energy += step.energy
        for r, toks in step.prefill:
            r.prefilled_tokens += toks
            if r.prefilled_tokens >= r.effective_prefill_tokens:
                self.chunk_progress.pop(r.rid, None)
                # prefill emits the first output token
                if r.decoded_tokens == 0:
                    r.decoded_tokens = 1
                    r.first_token_time = now
                    r.last_token_time = now
                    r.token_times.append(now)
                    self.total_tokens += 1
                if self.strategy == "prefill_only":
                    finished.append(r)  # hand off to the decode client
                    # KV ships to the decode client; radix-registered prefix
                    # blocks stay cached for the next same-prefix prefill
                    self._release_kv(r)
                elif r.remaining_tokens <= 0:
                    finished.append(r)
                    self._release_kv(r)
                elif self.strategy != "static":
                    self.running.append(r)
        for r in step.decode:
            if r.remaining_tokens <= 0:
                continue
            if not self.kv.holds(r.rid) or not self.kv.tables[r.rid].on_device:
                continue   # preempted earlier in this very step
            if not self._grow(r):
                continue   # recompute-preempted itself; token not emitted
            r.decoded_tokens += 1
            if r.first_token_time is None:
                r.first_token_time = now
            r.last_token_time = now
            r.token_times.append(now)
            self.total_tokens += r.branches
            if r.remaining_tokens <= 0 and self.strategy != "static":
                finished.append(r)
                self._release_kv(r)
                if r in self.running:
                    self.running.remove(r)
        if self.strategy == "static" and self.static_batch and \
                all(r.remaining_tokens <= 0 for r in self.static_batch):
            for r in self.static_batch:
                finished.append(r)
                self._release_kv(r)
            self.static_batch = []
        self.history.append({
            "time": now, "queue": len(self.waiting), "running": len(self.running),
            "swapped": len(self.swapped), "mem_used": self.kv.used,
            "kv_util": self.kv.used_blocks / max(1, self.kv.num_blocks),
            "step_tokens": step.n_tokens, "kind": step.kind,
        })
        return finished

    # --- fault tolerance ------------------------------------------------
    def drain(self) -> List[Request]:
        """Client failure: return every in-flight request for re-dispatch.
        KV state is lost; prefill restarts (paper-scale systems re-prefill)."""
        out = (list(self.waiting) + list(self.running)
               + list(self.static_batch) + list(self.swapped))
        for r in out:
            self._release_kv(r)
            r.prefilled_tokens = 0
            if r.decoded_tokens > 1:
                r.decoded_tokens = max(1, r.decoded_tokens)  # keep emitted tokens
            r.failures += 1
        self.waiting, self.running, self.static_batch = [], [], []
        self.swapped = []
        self.chunk_progress.clear()
        self._needs_refetch.clear()
        self.kv.clear_cache()          # a failed client's radix cache is gone
        self.kv.check_invariants()
        return out

    def remove_waiting(self, r: Request) -> bool:
        """Straggler rescue: pull a queued request and release any pages it
        already holds (chunked admission allocates at first touch). Partial
        prefill progress dies with the pages — the new client restarts it."""
        if r not in self.waiting:
            return False
        self.waiting.remove(r)
        self._release_kv(r)
        self.chunk_progress.pop(r.rid, None)
        self._needs_refetch.discard(r.rid)
        r.prefilled_tokens = 0
        return True
