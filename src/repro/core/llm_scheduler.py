"""vLLM-style LLM scheduler with the paper's five batching strategies
(§III-D1): static, continuous, chunked, mixed, disaggregated (prefill_only /
decode_only halves), plus FCFS / least-work-left packing and KV-memory
admission control with preemption.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.configs.base import ModelConfig
from repro.core.memory import MemoryManager
from repro.core.request import Request
from repro.perfmodel import analytical as ana
from repro.perfmodel.hardware import ClusterSpec

STRATEGIES = ("static", "continuous", "chunked", "mixed",
              "prefill_only", "decode_only")


@dataclass(frozen=True)
class SchedulerLimits:
    max_batch: int = 64
    max_prefill_tokens: int = 8192     # prefill token budget per step
    chunk_size: int = 512              # chunked-batching token budget


@dataclass
class LLMStep:
    kind: str                          # "prefill" | "decode" | "chunked"
    prefill: List[Tuple[Request, int]] = field(default_factory=list)  # (req, tokens)
    decode: List[Request] = field(default_factory=list)
    duration: float = 0.0
    energy: float = 0.0
    flops: float = 0.0

    @property
    def n_tokens(self) -> int:
        pre = sum(t for _, t in self.prefill)
        dec = sum(r.branches for r in self.decode)
        return pre + dec


class ClientPerf:
    """Runtime predictor for a client: fitted regression with analytical
    fallback (paper §III-E1)."""

    def __init__(self, model_cfg: ModelConfig, cluster: ClusterSpec,
                 use_regression: bool = True):
        self.cfg = model_cfg
        self.cluster = cluster
        self.decode_model = None
        self.prefill_model = None
        if use_regression:
            from repro.perfmodel import regression as reg
            self.decode_model = reg.fit_decode_model(model_cfg, cluster)
            self.prefill_model = reg.fit_prefill_model(model_cfg, cluster)

    def prefill(self, tokens: int, batch: int, past: int = 0) -> ana.StageCost:
        c = ana.prefill_time(self.cfg, self.cluster, tokens, batch, past)
        if self.prefill_model is not None:
            t = float(self.prefill_model.predict([past], [tokens], [batch])[0])
            if t > 0:
                return ana.StageCost(t, c.energy * t / max(c.time, 1e-12),
                                     c.flops, c.bytes, c.bound)
        return c

    def decode(self, batch: int, avg_ctx: int) -> ana.StageCost:
        c = ana.decode_step_time(self.cfg, self.cluster, batch, avg_ctx)
        if self.decode_model is not None:
            t = float(self.decode_model.predict([batch], [avg_ctx])[0])
            if t > 0:
                return ana.StageCost(t, c.energy * t / max(c.time, 1e-12),
                                     c.flops, c.bytes, c.bound)
        return c

    def chunked(self, chunk_tokens: int, decode_batch: int,
                avg_ctx: int) -> ana.StageCost:
        return ana.chunked_step_time(self.cfg, self.cluster, chunk_tokens,
                                     decode_batch, avg_ctx)


class LLMScheduler:
    def __init__(self, strategy: str, model_cfg: ModelConfig,
                 cluster: ClusterSpec, perf: Optional[ClientPerf] = None,
                 limits: SchedulerLimits = SchedulerLimits(),
                 packing: str = "fcfs"):
        assert strategy in STRATEGIES, strategy
        self.strategy = strategy
        self.cfg = model_cfg
        self.cluster = cluster
        self.perf = perf or ClientPerf(model_cfg, cluster, use_regression=False)
        self.limits = limits
        self.packing = packing
        self.waiting: List[Request] = []
        self.running: List[Request] = []
        self.chunk_progress: Dict[int, int] = {}   # rid -> prefilled tokens
        self.static_batch: List[Request] = []
        self.admitted_bytes: Dict[int, float] = {}  # rid -> KV bytes held
        weights = model_cfg.param_count() * ana.BYTES_PER_PARAM / cluster.tp
        self.memory = MemoryManager(
            capacity=max(cluster.total_mem - weights * cluster.n_chips / max(
                1, cluster.tp) * cluster.tp, cluster.total_mem * 0.15))
        self.kv_per_token = ana.kv_bytes_per_token(model_cfg) + (
            ana.ssm_state_bytes(model_cfg) / 4096.0)
        # scheduler-level metrics (paper §III-F2)
        self.history: List[Dict] = []
        self.total_energy = 0.0
        self.total_tokens = 0

    # ------------------------------------------------------------------
    def add(self, req: Request):
        if self.strategy == "decode_only":
            # KV produced by the prefill client arrives with the request
            nbytes = req.total_context * self.kv_per_token
            self.memory.admit(nbytes)
            self.admitted_bytes[req.rid] = nbytes
            if req.decoded_tokens == 0:
                req.decoded_tokens = 1   # disagg prefill emitted token #1
            self.running.append(req)
        else:
            self.waiting.append(req)
        if self.packing == "least_work":
            self.waiting.sort(key=lambda r: r.effective_prefill_tokens
                              + r.remaining_tokens)

    def has_work(self) -> bool:
        return bool(self.waiting or self.running or self.static_batch)

    # ------------------------------------------------------------------
    def _admit_prefills(self, token_budget: int, batch_budget: int
                        ) -> List[Tuple[Request, int]]:
        """Admit whole-request prefills under budgets + memory."""
        out = []
        used = 0
        while self.waiting and len(out) < batch_budget:
            r = self.waiting[0]
            toks = r.effective_prefill_tokens
            if out and used + toks > token_budget:
                break
            kv = (r.input_tokens + r.rag_tokens) * self.kv_per_token
            if not self.memory.admit(kv):
                break
            self.admitted_bytes[r.rid] = kv
            self.waiting.pop(0)
            out.append((r, toks))
            used += toks
        return out

    def plan_step(self) -> Optional[LLMStep]:
        s = self.strategy
        if s in ("continuous", "prefill_only", "mixed"):
            return self._plan_continuous(mixed=(s == "mixed"),
                                         prefill_only=(s == "prefill_only"))
        if s == "decode_only":
            return self._plan_decode_only()
        if s == "chunked":
            return self._plan_chunked()
        if s == "static":
            return self._plan_static()
        raise ValueError(s)

    # --- continuous / mixed / prefill-only ----------------------------
    def _plan_continuous(self, mixed: bool, prefill_only: bool) -> Optional[LLMStep]:
        pre = self._admit_prefills(self.limits.max_prefill_tokens,
                                   self.limits.max_batch)
        if pre:
            step = LLMStep("prefill", prefill=pre)
            toks = sum(t for _, t in pre)
            cost = self.perf.prefill(toks, 1)
            if mixed and self.running:
                dec = self.running[: self.limits.max_batch]
                step.decode = dec
                cost2 = self.perf.chunked(toks, sum(r.branches for r in dec),
                                          self._avg_ctx(dec))
                step.duration, step.energy, step.flops = (cost2.time,
                                                          cost2.energy, cost2.flops)
            else:
                step.duration, step.energy, step.flops = (cost.time, cost.energy,
                                                          cost.flops)
            return step
        if prefill_only or not self.running:
            return None
        dec = self.running[: self.limits.max_batch]
        cost = self.perf.decode(sum(r.branches for r in dec), self._avg_ctx(dec))
        return LLMStep("decode", decode=dec, duration=cost.time,
                       energy=cost.energy, flops=cost.flops)

    # --- pure decode (disaggregated decode client) ---------------------
    def _plan_decode_only(self) -> Optional[LLMStep]:
        if not self.running:
            return None
        dec = self.running[: self.limits.max_batch]
        cost = self.perf.decode(sum(r.branches for r in dec), self._avg_ctx(dec))
        return LLMStep("decode", decode=dec, duration=cost.time,
                       energy=cost.energy, flops=cost.flops)

    # --- chunked (Sarathi) ---------------------------------------------
    def _plan_chunked(self) -> Optional[LLMStep]:
        dec = self.running[: self.limits.max_batch]
        budget = self.limits.chunk_size - sum(r.branches for r in dec)
        pre: List[Tuple[Request, int]] = []
        while budget > 0 and self.waiting:
            r = self.waiting[0]
            done = self.chunk_progress.get(r.rid, 0)
            if done == 0:
                kv = (r.input_tokens + r.rag_tokens) * self.kv_per_token
                if not self.memory.admit(kv):
                    break
                self.admitted_bytes[r.rid] = kv
            remaining = r.effective_prefill_tokens - done
            take = min(remaining, budget)
            pre.append((r, take))
            self.chunk_progress[r.rid] = done + take
            budget -= take
            if done + take >= r.effective_prefill_tokens:
                self.waiting.pop(0)
            else:
                break  # head-of-line request still prefilling
        if not pre and not dec:
            return None
        toks = sum(t for _, t in pre)
        cost = self.perf.chunked(toks, sum(r.branches for r in dec),
                                 self._avg_ctx(dec) if dec else 0)
        return LLMStep("chunked", prefill=pre, decode=dec, duration=cost.time,
                       energy=cost.energy, flops=cost.flops)

    # --- static (FasterTransformers) ------------------------------------
    def _plan_static(self) -> Optional[LLMStep]:
        if not self.static_batch:
            pre = self._admit_prefills(10 ** 12, self.limits.max_batch)
            if not pre:
                return None
            self.static_batch = [r for r, _ in pre]
            toks = sum(t for _, t in pre)
            cost = self.perf.prefill(toks, 1)
            return LLMStep("prefill", prefill=pre, duration=cost.time,
                           energy=cost.energy, flops=cost.flops)
        live = [r for r in self.static_batch if r.remaining_tokens > 0]
        if not live:
            return None
        cost = self.perf.decode(sum(r.branches for r in live), self._avg_ctx(live))
        return LLMStep("decode", decode=live, duration=cost.time,
                       energy=cost.energy, flops=cost.flops)

    # ------------------------------------------------------------------
    def _avg_ctx(self, reqs: List[Request]) -> int:
        if not reqs:
            return 0
        return int(sum(r.total_context for r in reqs) / len(reqs))

    # ------------------------------------------------------------------
    def finish_step(self, step: LLMStep, now: float) -> List[Request]:
        """Apply step effects; returns requests whose LLM stage completed."""
        finished: List[Request] = []
        self.total_energy += step.energy
        for r, toks in step.prefill:
            r.prefilled_tokens += toks
            if r.prefilled_tokens >= r.effective_prefill_tokens:
                self.chunk_progress.pop(r.rid, None)
                # prefill emits the first output token
                if r.decoded_tokens == 0:
                    r.decoded_tokens = 1
                    r.first_token_time = now
                    r.last_token_time = now
                    r.token_times.append(now)
                    self.total_tokens += 1
                if self.strategy == "prefill_only":
                    finished.append(r)  # hand off to the decode client
                elif r.remaining_tokens <= 0:
                    finished.append(r)
                    self._release(r)
                elif self.strategy != "static":
                    self.running.append(r)
        for r in step.decode:
            if r.remaining_tokens <= 0:
                continue
            r.decoded_tokens += 1
            if r.first_token_time is None:
                r.first_token_time = now
            r.last_token_time = now
            r.token_times.append(now)
            self.total_tokens += r.branches
            self.memory.grow(self.kv_per_token * r.branches)
            self.admitted_bytes[r.rid] = self.admitted_bytes.get(r.rid, 0.0) \
                + self.kv_per_token * r.branches
            if r.remaining_tokens <= 0 and self.strategy != "static":
                finished.append(r)
                self._release(r)
                self.running.remove(r)
        if self.strategy == "static" and self.static_batch and \
                all(r.remaining_tokens <= 0 for r in self.static_batch):
            for r in self.static_batch:
                finished.append(r)
                self._release(r)
            self.static_batch = []
        self.history.append({
            "time": now, "queue": len(self.waiting), "running": len(self.running),
            "mem_used": self.memory.used, "step_tokens": step.n_tokens,
            "kind": step.kind,
        })
        return finished

    def _release(self, r: Request):
        self.memory.release(self.admitted_bytes.pop(r.rid, 0.0))

    # --- fault tolerance ------------------------------------------------
    def drain(self) -> List[Request]:
        """Client failure: return every in-flight request for re-dispatch.
        KV state is lost; prefill restarts (paper-scale systems re-prefill)."""
        out = list(self.waiting) + list(self.running) + list(self.static_batch)
        for r in out:
            r.prefilled_tokens = 0
            if r.decoded_tokens > 1:
                r.decoded_tokens = max(1, r.decoded_tokens)  # keep emitted tokens
            r.failures += 1
        self.waiting, self.running, self.static_batch = [], [], []
        self.chunk_progress.clear()
        self.admitted_bytes.clear()
        self.memory.used = 0.0
        return out
