"""Metrics collection (paper §III-F2): request / scheduler / client / global."""
from __future__ import annotations

from bisect import bisect_left, bisect_right
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.request import Request


def percentile(vals: Sequence[float], p: float) -> float:
    if not len(vals):
        return float("nan")
    return float(np.percentile(np.asarray(vals), p))


def simulator_stats(coord) -> Dict[str, float]:
    """Simulator-cost counters for a finished run: heap events popped, engine
    iterations actually simulated (fast-forward macro-steps count their full
    window), windows planned, and per-client step events. Deliberately kept
    OUT of ``MetricsCollector.summary()`` — the summary is a statement about
    the modeled system and must be bit-identical whether or not the decode
    fast-forward engine collapsed the event stream that produced it."""
    out = {"events_popped": coord.queue.popped,
           "micro_steps": 0, "macro_windows": 0, "step_events": 0}
    for c in coord.clients.values():
        sched = c.scheduler
        out["micro_steps"] += getattr(sched, "micro_steps", 0)
        out["macro_windows"] += getattr(sched, "macro_windows", 0)
        # prefer the monotonic counter: with SchedulerLimits.history_limit
        # the history deque drops old entries (or is disabled outright), so
        # its length undercounts; the counter survives either way
        se = getattr(sched, "step_events", None)
        out["step_events"] += (se if se is not None
                               else len(getattr(sched, "history", ())))
    return out


@dataclass(frozen=True)
class SLO:
    """Paper Table II: slowdowns over baseline TTFT/TPOT; all six must hold."""
    ttft_base: float = 0.250
    tpot_base: float = 0.025
    ttft_mult: Dict[int, float] = field(
        default_factory=lambda: {50: 2.0, 90: 3.0, 99: 6.0})
    tpot_mult: Dict[int, float] = field(
        default_factory=lambda: {50: 1.25, 90: 1.5, 99: 5.0})

    def satisfied(self, ttfts: Sequence[float], tpots: Sequence[float]) -> bool:
        for p, m in self.ttft_mult.items():
            if percentile(ttfts, p) > self.ttft_base * m:
                return False
        for p, m in self.tpot_mult.items():
            if percentile(tpots, p) > self.tpot_base * m:
                return False
        return True


class MetricsCollector:
    def __init__(self):
        self.serviced: List[Request] = []
        self.dropped: List[Request] = []
        self.comm_events: int = 0
        self.comm_bytes: float = 0.0
        # KV paging (paper §III-D admission control + §III-E3 tiering):
        # wire-side swap traffic observed by the coordinator ...
        self.swap_events: int = 0
        self.swap_bytes: float = 0.0
        # prefill->decode KV bytes that never shipped because the decode
        # client's radix cache already held the prefix pages
        self.kv_transfer_dedup_bytes: float = 0.0
        # cross-client radix prefix migrations: completed transfers and the
        # wire bytes they put on Network links (the per-allocator view —
        # blocks imported/refused, hit tokens on migrated pages — folds in
        # from allocator stats below)
        self.kv_migrations: int = 0
        self.kv_migrated_bytes: float = 0.0
        # ... and allocator counters aggregated over clients at run() end
        # (clients retired mid-run fold into _kv_retired so their history
        # survives removal; collect_kv recomputes, so it is idempotent)
        _zero = {"page_faults": 0, "admission_failures": 0, "evictions": 0,
                 "swap_ins": 0, "swap_bytes_out": 0.0, "swap_bytes_in": 0.0,
                 "recompute_drops": 0, "peak_blocks": 0,
                 # shared-prefix radix cache (PR 2)
                 "prefix_hit_tokens": 0, "prefix_hit_blocks": 0,
                 "prefix_tokens_seen": 0,
                 "cow_forks": 0, "cow_copied_blocks": 0,
                 "radix_evictions": 0, "shared_blocks": 0,
                 "block_refs_total": 0, "blocks_allocated_total": 0,
                 # cross-client prefix migration (PR 4)
                 "migrated_out_blocks": 0, "migrated_in_blocks": 0,
                 "migration_refused_blocks": 0, "migration_hit_tokens": 0}
        self.kv: Dict[str, float] = dict(_zero)
        self._kv_retired: Dict[str, float] = dict(_zero)
        # latency arrays memoized on len(serviced): requests are terminal
        # once complete() sees them, and serviced is append-only, so the
        # count is a sufficient cache key. One O(R) pass serves the ~8
        # property reads a summary() used to pay separately for.
        self._lat_key: int = -1
        self._lat: tuple = ([], [], [])
        # completion-time array for the sliding-window views, grown
        # incrementally (append-only, like serviced itself). Events pop in
        # nondecreasing time order and complete() runs at event time, so the
        # array is sorted — window boundaries resolve by bisection. The
        # windowed-metrics regression test recomputes from the raw list to
        # guard both the sort assumption and this cache's invalidation.
        self._ct: List[float] = []

    def complete(self, req: Request):
        self.serviced.append(req)

    def drop(self, req: Request):
        self.dropped.append(req)

    def observe_step_swaps(self, step):
        """Per-step wire traffic from swap/recompute preemptions."""
        nbytes = getattr(step, "swap_bytes", 0.0)
        if nbytes > 0:
            self.swap_events += 1
            self.swap_bytes += nbytes

    # high-water-mark counters fold with max, the rest accumulate
    _KV_PEAKS = ("peak_blocks", "shared_blocks")

    @classmethod
    def _fold_kv(cls, totals: Dict[str, float], stats: Dict):
        for k in totals:
            if k in cls._KV_PEAKS:
                totals[k] = max(totals[k], stats.get(k, 0))
            else:
                totals[k] += stats.get(k, 0)

    def retire_client_kv(self, client):
        """Preserve a removed client's allocator counters before it is
        dropped from the coordinator's client map."""
        stats = client.kv_stats() if hasattr(client, "kv_stats") else {}
        self._fold_kv(self._kv_retired, stats)

    def collect_kv(self, clients):
        """Recompute run totals from retired + live clients (idempotent)."""
        totals = dict(self._kv_retired)
        for c in clients:
            self._fold_kv(totals, c.kv_stats() if hasattr(c, "kv_stats")
                          else {})
        self.kv = totals

    # ------------------------------------------------------------------
    def _latency_arrays(self) -> tuple:
        """(ttfts, tpots, e2es) in one pass over ``serviced``, cached."""
        if self._lat_key != len(self.serviced):
            ttfts: List[float] = []
            tpots: List[float] = []
            e2es: List[float] = []
            for r in self.serviced:
                if r.ttft is not None:
                    ttfts.append(r.ttft)
                if r.tpot is not None and r.decoded_tokens > 1:
                    tpots.append(r.tpot)
                if r.e2e is not None:
                    e2es.append(r.e2e)
            self._lat = (ttfts, tpots, e2es)
            self._lat_key = len(self.serviced)
        return self._lat

    @property
    def ttfts(self) -> List[float]:
        return self._latency_arrays()[0]

    @property
    def tpots(self) -> List[float]:
        return self._latency_arrays()[1]

    @property
    def e2es(self) -> List[float]:
        return self._latency_arrays()[2]

    def total_tokens(self) -> int:
        return sum(r.decoded_tokens * r.branches for r in self.serviced)

    def throughput(self, horizon: float) -> float:
        return self.total_tokens() / max(horizon, 1e-9)

    def goodput(self, slo: SLO, horizon: float) -> float:
        """Tokens/sec from requests individually meeting TTFT-P50&TPOT-P50."""
        tok = 0
        ttft_cap = slo.ttft_base * slo.ttft_mult[50]
        tpot_cap = slo.tpot_base * slo.tpot_mult[50]
        for r in self.serviced:
            if ((r.ttft or 1e9) <= ttft_cap
                    and (r.tpot if r.tpot is not None else 0.0) <= tpot_cap):
                tok += r.decoded_tokens * r.branches
        return tok / max(horizon, 1e-9)

    def goodput_by_tier(self, slos, horizon: float) -> Dict[str, float]:
        """Per-tier goodput: ``slos`` is either one SLO applied to every
        observed ``Request.tier``, or a mapping tier -> SLO (tiers without an
        entry fall back to the mapping's ``"default"`` key, else are skipped).
        One pass over ``serviced``; tiers with no serviced requests do not
        appear."""
        caps: Dict[str, tuple] = {}
        tok: Dict[str, int] = {}
        for r in self.serviced:
            tier = getattr(r, "tier", "default")
            if tier not in caps:
                slo = (slos if isinstance(slos, SLO)
                       else slos.get(tier, slos.get("default")))
                if slo is None:
                    caps[tier] = None
                else:
                    caps[tier] = (slo.ttft_base * slo.ttft_mult[50],
                                  slo.tpot_base * slo.tpot_mult[50])
                tok[tier] = 0
            if caps[tier] is None:
                continue
            ttft_cap, tpot_cap = caps[tier]
            if ((r.ttft or 1e9) <= ttft_cap
                    and (r.tpot if r.tpot is not None else 0.0) <= tpot_cap):
                tok[tier] += r.decoded_tokens * r.branches
        return {t: n / max(horizon, 1e-9)
                for t, n in tok.items() if caps[t] is not None}

    # ------------------------------------------------------------------
    # sliding-window views (closed-loop autoscaler observations): recent,
    # not cumulative, health. A window is the closed completion-time
    # interval [since, until]; ``until=None`` means "everything so far".
    # ------------------------------------------------------------------
    def _completion_times(self) -> List[float]:
        ct = self._ct
        sv = self.serviced
        if len(ct) < len(sv):
            for r in sv[len(ct):]:
                t = r.completion_time
                ct.append(float("inf") if t is None else t)
        return ct

    def window_view(self, since: float,
                    until: Optional[float] = None) -> List[Request]:
        """Requests whose completion time falls in ``[since, until]``, in
        completion order (a contiguous slice of ``serviced``)."""
        ct = self._completion_times()
        lo = bisect_left(ct, since)
        hi = len(ct) if until is None else bisect_right(ct, until)
        return self.serviced[lo:hi]

    @staticmethod
    def _tier_caps(slos, tier: str):
        """P50 (ttft_cap, tpot_cap) for ``tier`` under one SLO or a
        tier->SLO mapping (same fallback rules as ``goodput_by_tier``);
        None when the tier has no SLO."""
        slo = (slos if isinstance(slos, SLO)
               else slos.get(tier, slos.get("default")))
        if slo is None:
            return None
        return (slo.ttft_base * slo.ttft_mult[50],
                slo.tpot_base * slo.tpot_mult[50])

    def window_stats(self, since: float, until: Optional[float] = None,
                     slos=None) -> Dict:
        """One-pass recent-health summary over the ``[since, until]``
        completion window: serviced/token counts, TTFT/TPOT percentiles,
        and — when ``slos`` is given (one SLO or a tier->SLO mapping) —
        per-tier SLO-attainment fractions and windowed goodput. Goodput
        divides by the window span, so ``until`` defaults to the newest
        completion when open-ended. Matches a brute-force recompute over
        the raw ``serviced`` list by contract (regression-tested)."""
        reqs = self.window_view(since, until)
        ttfts: List[float] = []
        tpots: List[float] = []
        tokens = 0
        caps: Dict[str, Optional[tuple]] = {}
        ok: Dict[str, int] = {}
        n_tier: Dict[str, int] = {}
        good_tok: Dict[str, int] = {}
        for r in reqs:
            tokens += r.decoded_tokens * r.branches
            if r.ttft is not None:
                ttfts.append(r.ttft)
            if r.tpot is not None and r.decoded_tokens > 1:
                tpots.append(r.tpot)
            if slos is None:
                continue
            tier = getattr(r, "tier", "default")
            if tier not in caps:
                caps[tier] = self._tier_caps(slos, tier)
                ok[tier] = n_tier[tier] = good_tok[tier] = 0
            if caps[tier] is None:
                continue
            n_tier[tier] += 1
            ttft_cap, tpot_cap = caps[tier]
            if ((r.ttft or 1e9) <= ttft_cap
                    and (r.tpot if r.tpot is not None else 0.0) <= tpot_cap):
                ok[tier] += 1
                good_tok[tier] += r.decoded_tokens * r.branches
        end = until
        if end is None:
            end = max((c for c in (r.completion_time for r in reqs)
                       if c is not None), default=since)
        span = max(end - since, 1e-9)
        out: Dict = {
            "since": since, "until": end, "n": len(reqs), "tokens": tokens,
            "ttft_p50": percentile(ttfts, 50),
            "ttft_p90": percentile(ttfts, 90),
            "tpot_p50": percentile(tpots, 50),
            "tpot_p90": percentile(tpots, 90),
        }
        if slos is not None:
            out["slo_frac_by_tier"] = {
                t: ok[t] / n_tier[t] for t in n_tier if n_tier[t] > 0}
            scored = sum(n_tier.values())
            out["slo_frac"] = (sum(ok.values()) / scored if scored else None)
            out["goodput_by_tier"] = {t: good_tok[t] / span for t in good_tok
                                      if caps[t] is not None}
            out["goodput_tok_s"] = sum(good_tok.values()) / span
        return out

    def summary(self, horizon: Optional[float] = None,
                total_energy: float = 0.0, slo: Optional[SLO] = None) -> Dict:
        ttfts, tpots, e2es = self._latency_arrays()
        horizon = horizon or (max(e2es, default=0.0) + 1e-9)
        s = {
            "n_serviced": len(self.serviced),
            "n_dropped": len(self.dropped),
            "tokens": self.total_tokens(),
            "throughput_tok_s": self.throughput(horizon),
            "ttft_mean": float(np.mean(ttfts)) if ttfts else float("nan"),
            "tpot_mean": float(np.mean(tpots)) if tpots else float("nan"),
            "e2e_mean": float(np.mean(e2es)) if e2es else float("nan"),
        }
        for p in (50, 90, 99):
            s[f"ttft_p{p}"] = percentile(ttfts, p)
            s[f"tpot_p{p}"] = percentile(tpots, p)
            s[f"e2e_p{p}"] = percentile(e2es, p)
        if total_energy > 0:
            s["energy_j"] = total_energy
            s["tok_per_joule"] = s["tokens"] / total_energy
        s["preemptions"] = sum(r.preemptions for r in self.serviced)
        s["swap_events"] = self.swap_events
        s["swap_bytes"] = self.swap_bytes
        s["kv_transfer_dedup_bytes"] = self.kv_transfer_dedup_bytes
        s["kv_migrations"] = self.kv_migrations
        s["kv_migrated_bytes"] = self.kv_migrated_bytes
        for k, v in self.kv.items():
            s[f"kv_{k}"] = v
        # logical block references per physical block allocated (>= 1; 1 means
        # no page was ever shared) — the radix cache's dedup factor
        s["kv_dedup_ratio"] = (self.kv["block_refs_total"]
                               / max(1, self.kv["blocks_allocated_total"]))
        if slo is not None:
            s["slo_ok"] = self.slo_satisfied(slo)
            s["goodput_tok_s"] = self.goodput(slo, horizon)
        return s

    def slo_satisfied(self, slo: SLO) -> bool:
        return slo.satisfied(self.ttfts, self.tpots)
