"""Metrics collection (paper §III-F2): request / scheduler / client / global."""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.request import Request


def percentile(vals: Sequence[float], p: float) -> float:
    if not len(vals):
        return float("nan")
    return float(np.percentile(np.asarray(vals), p))


def simulator_stats(coord) -> Dict[str, float]:
    """Simulator-cost counters for a finished run: heap events popped, engine
    iterations actually simulated (fast-forward macro-steps count their full
    window), windows planned, and per-client step events. Deliberately kept
    OUT of ``MetricsCollector.summary()`` — the summary is a statement about
    the modeled system and must be bit-identical whether or not the decode
    fast-forward engine collapsed the event stream that produced it."""
    out = {"events_popped": coord.queue.popped,
           "micro_steps": 0, "macro_windows": 0, "step_events": 0}
    for c in coord.clients.values():
        sched = c.scheduler
        out["micro_steps"] += getattr(sched, "micro_steps", 0)
        out["macro_windows"] += getattr(sched, "macro_windows", 0)
        out["step_events"] += len(getattr(sched, "history", ()))
    return out


@dataclass(frozen=True)
class SLO:
    """Paper Table II: slowdowns over baseline TTFT/TPOT; all six must hold."""
    ttft_base: float = 0.250
    tpot_base: float = 0.025
    ttft_mult: Dict[int, float] = field(
        default_factory=lambda: {50: 2.0, 90: 3.0, 99: 6.0})
    tpot_mult: Dict[int, float] = field(
        default_factory=lambda: {50: 1.25, 90: 1.5, 99: 5.0})

    def satisfied(self, ttfts: Sequence[float], tpots: Sequence[float]) -> bool:
        for p, m in self.ttft_mult.items():
            if percentile(ttfts, p) > self.ttft_base * m:
                return False
        for p, m in self.tpot_mult.items():
            if percentile(tpots, p) > self.tpot_base * m:
                return False
        return True


class MetricsCollector:
    def __init__(self):
        self.serviced: List[Request] = []
        self.dropped: List[Request] = []
        self.comm_events: int = 0
        self.comm_bytes: float = 0.0
        # KV paging (paper §III-D admission control + §III-E3 tiering):
        # wire-side swap traffic observed by the coordinator ...
        self.swap_events: int = 0
        self.swap_bytes: float = 0.0
        # prefill->decode KV bytes that never shipped because the decode
        # client's radix cache already held the prefix pages
        self.kv_transfer_dedup_bytes: float = 0.0
        # cross-client radix prefix migrations: completed transfers and the
        # wire bytes they put on Network links (the per-allocator view —
        # blocks imported/refused, hit tokens on migrated pages — folds in
        # from allocator stats below)
        self.kv_migrations: int = 0
        self.kv_migrated_bytes: float = 0.0
        # ... and allocator counters aggregated over clients at run() end
        # (clients retired mid-run fold into _kv_retired so their history
        # survives removal; collect_kv recomputes, so it is idempotent)
        _zero = {"page_faults": 0, "admission_failures": 0, "evictions": 0,
                 "swap_ins": 0, "swap_bytes_out": 0.0, "swap_bytes_in": 0.0,
                 "recompute_drops": 0, "peak_blocks": 0,
                 # shared-prefix radix cache (PR 2)
                 "prefix_hit_tokens": 0, "prefix_hit_blocks": 0,
                 "prefix_tokens_seen": 0,
                 "cow_forks": 0, "cow_copied_blocks": 0,
                 "radix_evictions": 0, "shared_blocks": 0,
                 "block_refs_total": 0, "blocks_allocated_total": 0,
                 # cross-client prefix migration (PR 4)
                 "migrated_out_blocks": 0, "migrated_in_blocks": 0,
                 "migration_refused_blocks": 0, "migration_hit_tokens": 0}
        self.kv: Dict[str, float] = dict(_zero)
        self._kv_retired: Dict[str, float] = dict(_zero)

    def complete(self, req: Request):
        self.serviced.append(req)

    def drop(self, req: Request):
        self.dropped.append(req)

    def observe_step_swaps(self, step):
        """Per-step wire traffic from swap/recompute preemptions."""
        nbytes = getattr(step, "swap_bytes", 0.0)
        if nbytes > 0:
            self.swap_events += 1
            self.swap_bytes += nbytes

    # high-water-mark counters fold with max, the rest accumulate
    _KV_PEAKS = ("peak_blocks", "shared_blocks")

    @classmethod
    def _fold_kv(cls, totals: Dict[str, float], stats: Dict):
        for k in totals:
            if k in cls._KV_PEAKS:
                totals[k] = max(totals[k], stats.get(k, 0))
            else:
                totals[k] += stats.get(k, 0)

    def retire_client_kv(self, client):
        """Preserve a removed client's allocator counters before it is
        dropped from the coordinator's client map."""
        stats = client.kv_stats() if hasattr(client, "kv_stats") else {}
        self._fold_kv(self._kv_retired, stats)

    def collect_kv(self, clients):
        """Recompute run totals from retired + live clients (idempotent)."""
        totals = dict(self._kv_retired)
        for c in clients:
            self._fold_kv(totals, c.kv_stats() if hasattr(c, "kv_stats")
                          else {})
        self.kv = totals

    # ------------------------------------------------------------------
    @property
    def ttfts(self) -> List[float]:
        return [r.ttft for r in self.serviced if r.ttft is not None]

    @property
    def tpots(self) -> List[float]:
        return [r.tpot for r in self.serviced
                if r.tpot is not None and r.decoded_tokens > 1]

    @property
    def e2es(self) -> List[float]:
        return [r.e2e for r in self.serviced if r.e2e is not None]

    def total_tokens(self) -> int:
        return sum(r.decoded_tokens * r.branches for r in self.serviced)

    def throughput(self, horizon: float) -> float:
        return self.total_tokens() / max(horizon, 1e-9)

    def goodput(self, slo: SLO, horizon: float) -> float:
        """Tokens/sec from requests individually meeting TTFT-P50&TPOT-P50."""
        ok = [r for r in self.serviced
              if (r.ttft or 1e9) <= slo.ttft_base * slo.ttft_mult[50]
              and (r.tpot if r.tpot is not None else 0.0)
              <= slo.tpot_base * slo.tpot_mult[50]]
        return sum(r.decoded_tokens * r.branches for r in ok) / max(horizon, 1e-9)

    def summary(self, horizon: Optional[float] = None,
                total_energy: float = 0.0, slo: Optional[SLO] = None) -> Dict:
        horizon = horizon or (max(self.e2es, default=0.0) + 1e-9)
        s = {
            "n_serviced": len(self.serviced),
            "n_dropped": len(self.dropped),
            "tokens": self.total_tokens(),
            "throughput_tok_s": self.throughput(horizon),
            "ttft_mean": float(np.mean(self.ttfts)) if self.ttfts else float("nan"),
            "tpot_mean": float(np.mean(self.tpots)) if self.tpots else float("nan"),
            "e2e_mean": float(np.mean(self.e2es)) if self.e2es else float("nan"),
        }
        for p in (50, 90, 99):
            s[f"ttft_p{p}"] = percentile(self.ttfts, p)
            s[f"tpot_p{p}"] = percentile(self.tpots, p)
            s[f"e2e_p{p}"] = percentile(self.e2es, p)
        if total_energy > 0:
            s["energy_j"] = total_energy
            s["tok_per_joule"] = s["tokens"] / total_energy
        s["preemptions"] = sum(r.preemptions for r in self.serviced)
        s["swap_events"] = self.swap_events
        s["swap_bytes"] = self.swap_bytes
        s["kv_transfer_dedup_bytes"] = self.kv_transfer_dedup_bytes
        s["kv_migrations"] = self.kv_migrations
        s["kv_migrated_bytes"] = self.kv_migrated_bytes
        for k, v in self.kv.items():
            s[f"kv_{k}"] = v
        # logical block references per physical block allocated (>= 1; 1 means
        # no page was ever shared) — the radix cache's dedup factor
        s["kv_dedup_ratio"] = (self.kv["block_refs_total"]
                               / max(1, self.kv["blocks_allocated_total"]))
        if slo is not None:
            s["slo_ok"] = self.slo_satisfied(slo)
            s["goodput_tok_s"] = self.goodput(slo, horizon)
        return s

    def slo_satisfied(self, slo: SLO) -> bool:
        return slo.satisfied(self.ttfts, self.tpots)
