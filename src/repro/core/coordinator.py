"""Global Coordinator (paper §III-B, Algorithm 1).

Owns the global event queue + clock, routes request stages to clients,
prices inter-client transfers through the Network, and handles client
fail/recover/add/remove for fault tolerance and elastic scaling.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

from repro.core import events as ev
from repro.core import request as rq
from repro.core.client import Client, LLMClient
from repro.core.comm import Network
from repro.core.metrics import SLO, MetricsCollector
from repro.core.router import Router, RoundRobinRouter


@dataclass
class CoordinatorConfig:
    disaggregation: str = "global"        # global | local (paper §II-B)
    kv_transfer_granularity: str = "layerwise"  # full | layerwise
    straggler_deadline: Optional[float] = None  # re-route if queued longer
    max_sim_time: float = 1e7


class Coordinator:
    def __init__(self, clients: List[Client], router: Optional[Router] = None,
                 network: Optional[Network] = None,
                 cfg: CoordinatorConfig = CoordinatorConfig()):
        self.clients: Dict[str, Client] = {c.name: c for c in clients}
        self.router = router or RoundRobinRouter()
        self.network = network or Network()
        self.cfg = cfg
        self.queue = ev.EventQueue()
        self.metrics = MetricsCollector()
        self._active_step: Dict[str, object] = {}
        self._accepted = 0
        self._dispatch_times: Dict[int, float] = {}

    # ------------------------------------------------------------------
    def submit(self, requests: List[rq.Request]):
        for r in requests:
            self._accepted += 1
            self.queue.push(r.arrival, ev.REQUEST_ARRIVAL, r)

    def schedule_failure(self, client_name: str, at: float,
                         recover_at: Optional[float] = None):
        self.queue.push(at, ev.CLIENT_FAIL, client_name)
        if recover_at is not None:
            self.queue.push(recover_at, ev.CLIENT_RECOVER, client_name)

    def schedule_add_client(self, client: Client, at: float):
        self.queue.push(at, ev.CLIENT_ADD, client)

    def schedule_remove_client(self, client_name: str, at: float):
        self.queue.push(at, ev.CLIENT_REMOVE, client_name)

    # ------------------------------------------------------------------
    # stages that may be absent from a system spec; requests skip them
    _OPTIONAL_STAGES = (rq.PREPROCESS, rq.POSTPROCESS)

    def _candidates(self, req: rq.Request) -> Optional[List[Client]]:
        stage = req.current_stage.kind
        cands = [c for c in self.clients.values()
                 if stage in c.stages and not c.failed]
        if not cands and stage in self._OPTIONAL_STAGES:
            return None
        # local disaggregation: decode must stay in the prefill client's group
        if stage == rq.DECODE and self.cfg.disaggregation == "local":
            prev = next((s.client for s in reversed(req.stages[:req.stage_idx])
                         if s.kind == rq.PREFILL and s.client), None)
            if prev is not None:
                g = getattr(self.clients.get(prev), "group", None)
                if g is not None:
                    grouped = [c for c in cands
                               if getattr(c, "group", None) == g]
                    cands = grouped or cands
        if not cands:
            raise RuntimeError(f"no live client serves stage '{stage}'")
        return cands

    def _dispatch(self, req: rq.Request, now: float):
        """Route current stage to a client (Algorithm 1 'Request-push')."""
        while not req.done and self._candidates(req) is None:
            req.advance_stage(now)     # optional stage with no client: skip
        if req.done:
            self.metrics.complete(req)
            return
        client = self.router.route(req, self._candidates(req), now)
        st = req.current_stage
        st.client = client.name
        st.dispatch_time = now
        st.start_time = now
        self._dispatch_times[req.rid] = now
        client.add(req)
        self._kick(client, now)

    def _kick(self, client: Client, now: float):
        if client.failed or client.name in self._active_step:
            return
        step = client.plan_step()
        if step is None:
            return
        self._active_step[client.name] = step
        self.queue.push(now + step.duration, ev.CLIENT_STEP_DONE,
                        (client.name, step))

    # ------------------------------------------------------------------
    def _account_swap_traffic(self, client: Client, step, now: float):
        """KV-page swap traffic from preemptions (paper §III-E3). The
        engine's stall is already priced inside the step duration (Eq. 1
        tier term); here the bytes are counted in the metrics and recorded
        against the client's dedicated spill link so ``Network.stats()``
        reports per-client swap volume. (The spill link is private to the
        client — host-side contention with other traffic is not modeled.)"""
        nbytes = getattr(step, "swap_bytes", 0.0)
        if nbytes <= 0:
            return
        self.metrics.observe_step_swaps(step)
        if self.network.paths.get((client.name, f"{client.name}:kvpool")):
            self.network.transfer(client.name, f"{client.name}:kvpool",
                                  nbytes, now)

    # ------------------------------------------------------------------
    def _transfer_and_forward(self, req: rq.Request, src: str, now: float):
        """Price inter-stage data movement, then re-enqueue as a new request
        event at the destination (paper §III-B2)."""
        prev_stage = req.stages[req.stage_idx - 1] if req.stage_idx else None
        while not req.done and self._candidates(req) is None:
            req.advance_stage(now)     # optional stage with no client: skip
        nxt = req.current_stage
        if nxt is None:
            self.metrics.complete(req)
            return
        # choose destination now so we can price the wire
        dst_client = self.router.route(req, self._candidates(req), now)
        nbytes, gran, n_layers = 0.0, "full", 1
        if prev_stage is not None and nxt is not None:
            if prev_stage.kind == rq.PREFILL and nxt.kind == rq.DECODE:
                src_c = self.clients.get(src)
                if isinstance(src_c, LLMClient):
                    nbytes = src_c.kv_transfer_bytes_fn(req)
                    # wire-side prefix dedup: pages the destination's radix
                    # cache already holds need not ship (the decode client
                    # maps them at admission instead). Priced at transfer-
                    # schedule time; a page evicted before the request is
                    # admitted still rides for free — real systems pin
                    # matched pages for the transfer window, which we
                    # approximate by not re-checking at admission.
                    hit = dst_client.prefix_hit_tokens(req)
                    if hit > 0:
                        saved = min(nbytes,
                                    hit * src_c.scheduler.kv_per_token)
                        nbytes -= saved
                        self.metrics.kv_transfer_dedup_bytes += saved
                    n_layers = src_c.model_cfg.num_layers
                    gran = self.cfg.kv_transfer_granularity
            elif prev_stage.kind in (rq.RAG_RETRIEVE, rq.RAG_EMBED):
                nbytes = req.rag_tokens * 2.0 * 4  # context ids+embeddings
            elif prev_stage.kind == rq.KV_RETRIEVAL:
                nbytes = 0.0  # priced inside the retrieval stage itself
        arrive = self.network.transfer(src, dst_client.name, nbytes, now,
                                       granularity=gran, n_layers=n_layers)
        self.metrics.comm_events += 1
        self.metrics.comm_bytes += nbytes
        st = req.current_stage
        st.client = dst_client.name
        st.dispatch_time = arrive
        st.start_time = arrive
        self.queue.push(arrive, ev.TRANSFER_DONE, (req, dst_client.name))

    # ------------------------------------------------------------------
    def run(self, until: Optional[float] = None) -> MetricsCollector:
        """Algorithm 1 main loop."""
        horizon = until or self.cfg.max_sim_time
        while len(self.queue):
            if self.queue.peek_time() > horizon:
                break
            event = self.queue.pop()
            now = event.time
            kind = event.kind

            if kind == ev.REQUEST_ARRIVAL:
                self._dispatch(event.payload, now)

            elif kind == ev.TRANSFER_DONE:
                req, dst = event.payload
                client = self.clients.get(dst)
                if client is None or client.failed:
                    self._dispatch(req, now)   # destination died in flight
                else:
                    client.add(req)
                    self._kick(client, now)

            elif kind == ev.CLIENT_STEP_DONE:
                name, step = event.payload
                client = self.clients.get(name)
                if client is None or self._active_step.get(name) is not step:
                    continue  # stale (failed/removed client)
                del self._active_step[name]
                if client.failed:
                    continue
                finished = client.finish_step(step, now)
                self._account_swap_traffic(client, step, now)
                for req in finished:
                    req.advance_stage(now)
                    if req.done:
                        self.metrics.complete(req)
                    else:
                        self._transfer_and_forward(req, name, now)
                self._maybe_rescue_stragglers(now)
                self._kick(client, now)

            elif kind == ev.CLIENT_FAIL:
                self._on_fail(event.payload, now)

            elif kind == ev.CLIENT_RECOVER:
                c = self.clients.get(event.payload)
                if c is not None:
                    c.failed = False
                    self._kick(c, now)

            elif kind == ev.CLIENT_ADD:
                c: Client = event.payload
                self.clients[c.name] = c
                self._kick(c, now)

            elif kind == ev.CLIENT_REMOVE:
                self._on_remove(event.payload, now)

        self.metrics.collect_kv(self.clients.values())
        return self.metrics

    # ------------------------------------------------------------------
    def _on_fail(self, name: str, now: float):
        client = self.clients.get(name)
        if client is None:
            return
        client.failed = True
        self._active_step.pop(name, None)      # in-flight step is lost
        for req in client.drain():             # checkpoint/restart semantics:
            # the stage restarts on another client; decoded tokens already
            # streamed to the user are kept.
            self._dispatch(req, now)

    def _on_remove(self, name: str, now: float):
        client = self.clients.pop(name, None)
        if client is None:
            return
        self.metrics.retire_client_kv(client)
        self._active_step.pop(name, None)
        for req in client.drain():
            self._dispatch(req, now)

    def _maybe_rescue_stragglers(self, now: float):
        """Hedged re-dispatch: requests queued past the deadline at a client
        that has not started them are re-routed (straggler mitigation)."""
        ddl = self.cfg.straggler_deadline
        if ddl is None:
            return
        for client in list(self.clients.values()):
            sched = client.scheduler
            waiting = getattr(sched, "waiting", [])
            stale = [r for r in waiting
                     if now - self._dispatch_times.get(r.rid, now) > ddl]
            for r in stale:
                cands = self._candidates(r) or []
                others = [c for c in cands if c is not client]
                if not others:
                    continue
                if hasattr(sched, "remove_waiting"):
                    sched.remove_waiting(r)   # frees any pages it held
                else:
                    waiting.remove(r)
                r.preemptions += 1
                self._dispatch(r, now)

    # ------------------------------------------------------------------
    @property
    def total_energy(self) -> float:
        return sum(c.total_energy for c in self.clients.values())

    def all_serviced(self) -> bool:
        return len(self.metrics.serviced) >= self._accepted
