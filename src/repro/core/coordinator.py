"""Global Coordinator (paper §III-B, Algorithm 1).

Owns the global event queue + clock, routes request stages to clients,
prices inter-client transfers through the Network, and handles client
fail/recover/add/remove for fault tolerance and elastic scaling.
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

from repro.core import events as ev
from repro.core import request as rq
from repro.core.client import Client, LLMClient
from repro.core.comm import Network
from repro.core.fleet import FleetIndex, StageMembers
from repro.core.metrics import SLO, MetricsCollector
from repro.core.router import Router, RoundRobinRouter


@dataclass
class CoordinatorConfig:
    disaggregation: str = "global"        # global | local (paper §II-B)
    kv_transfer_granularity: str = "layerwise"  # full | layerwise
    straggler_deadline: Optional[float] = None  # re-route if queued longer
    max_sim_time: float = 1e7
    # cross-client radix prefix migration (paper §V-B remote KV retrieval):
    # ship resident KV prefix chains between clients over the Network
    # instead of letting a cold replica recompute them from scratch
    prefix_migration: bool = False
    migration_granularity: Optional[str] = None  # default: kv_transfer_gran.
    warm_on_scale_out: bool = True     # push-mode warming on ADD / RECOVER
    warm_max_blocks: int = 256         # donor block budget per warming push
    # fleet-scale routing indexes (src/repro/core/fleet.py): incremental
    # stage->client / load / root-hash structures replacing the per-request
    # linear scans. Decision-identical to the scan baseline by contract;
    # False keeps the baseline path (the A/B arm the identity checks use).
    fleet_index: bool = True


class Coordinator:
    def __init__(self, clients: List[Client], router: Optional[Router] = None,
                 network: Optional[Network] = None,
                 cfg: Optional[CoordinatorConfig] = None):
        self.clients: Dict[str, Client] = {c.name: c for c in clients}
        self.router = router or RoundRobinRouter()
        self.network = network or Network()
        # a fresh config per coordinator: a shared mutable default would let
        # one simulation's cfg tweak silently leak into every later one
        self.cfg = cfg if cfg is not None else CoordinatorConfig()
        self.queue = ev.EventQueue()
        self.metrics = MetricsCollector()
        self._active_step: Dict[str, object] = {}
        self._accepted = 0
        self._dispatch_times: Dict[int, float] = {}
        # in-flight prefix migrations, keyed (dst, chain): dedup so a burst
        # of same-prefix routing decisions starts one transfer, not many
        self._migrations_inflight: set = set()
        self.fleet: Optional[FleetIndex] = \
            FleetIndex(self) if self.cfg.fleet_index else None
        # closed-loop autoscaler (core/autoscaler.py), ticked on periodic
        # AUTOSCALE_CHECK events; None = open-loop (scripted churn only)
        self.autoscaler = None
        self.router.bind(self)
        # times of pending *external* events (everything but step completions)
        # — the fast-forward planner stops windows at the next one so the
        # priced tail is rarely discarded by truncate-and-replay
        self._ext_times: List[float] = []

    def _push_ext(self, time: float, kind: str, payload=None):
        heapq.heappush(self._ext_times, time)
        return self.queue.push(time, kind, payload)

    def _ff_horizon(self, now: float) -> Optional[float]:
        """Earliest pending external event strictly after ``now`` (advisory:
        a window running past it is still cut correctly by ``_interrupt``)."""
        h = self._ext_times
        while h and h[0] <= now:
            heapq.heappop(h)
        return h[0] if h else None

    # ------------------------------------------------------------------
    def submit(self, requests: List[rq.Request]):
        for r in requests:
            self._accepted += 1
            self._push_ext(r.arrival, ev.REQUEST_ARRIVAL, r)

    def schedule_failure(self, client_name: str, at: float,
                         recover_at: Optional[float] = None):
        self._push_ext(at, ev.CLIENT_FAIL, client_name)
        if recover_at is not None:
            self._push_ext(recover_at, ev.CLIENT_RECOVER, client_name)

    def schedule_add_client(self, client: Client, at: float):
        self._push_ext(at, ev.CLIENT_ADD, client)

    def schedule_remove_client(self, client_name: str, at: float):
        self._push_ext(at, ev.CLIENT_REMOVE, client_name)

    def attach_autoscaler(self, scaler, start_at: Optional[float] = None):
        """Close the scaling loop: tick ``scaler`` every
        ``scaler.cfg.interval`` seconds, starting one interval from now (or
        at ``start_at``). Check events are deliberately NOT external-event
        horizon caps: a check that takes no action must not cut decode
        fast-forward windows, and one that does interrupts its targets
        through the ordinary add/remove paths."""
        self.autoscaler = scaler
        scaler.bind(self, self.queue.now)
        t0 = start_at if start_at is not None \
            else self.queue.now + scaler.cfg.interval
        self.queue.push(t0, ev.AUTOSCALE_CHECK, None)

    # ------------------------------------------------------------------
    # stages that may be absent from a system spec; requests skip them
    _OPTIONAL_STAGES = (rq.PREPROCESS, rq.POSTPROCESS)

    def _candidates(self, req: rq.Request) -> Optional[List[Client]]:
        stage = req.current_stage.kind
        if self.fleet is not None:
            return self._candidates_indexed(req, stage)
        cands = [c for c in self.clients.values()
                 if stage in c.stages and not c.failed]
        if not cands and stage in self._OPTIONAL_STAGES:
            return None
        # local disaggregation: decode must stay in the prefill client's group
        if stage == rq.DECODE and self.cfg.disaggregation == "local":
            prev = next((s.client for s in reversed(req.stages[:req.stage_idx])
                         if s.kind == rq.PREFILL and s.client), None)
            if prev is not None:
                g = getattr(self.clients.get(prev), "group", None)
                if g is not None:
                    grouped = [c for c in cands
                               if getattr(c, "group", None) == g]
                    cands = grouped or cands
        if not cands:
            raise RuntimeError(f"no live client serves stage '{stage}'")
        return cands

    def _candidates_indexed(self, req: rq.Request,
                            stage: str) -> Optional[StageMembers]:
        """Index-backed twin of the linear scan above: same None / raise
        semantics, same candidate iteration order, same group-filter
        fallback (an empty group view falls back to the stage view)."""
        view = self.fleet.candidates(stage)
        if view is None or not view:
            if stage in self._OPTIONAL_STAGES:
                return None
            raise RuntimeError(f"no live client serves stage '{stage}'")
        if stage == rq.DECODE and self.cfg.disaggregation == "local":
            prev = next((s.client for s in reversed(req.stages[:req.stage_idx])
                         if s.kind == rq.PREFILL and s.client), None)
            if prev is not None:
                g = getattr(self.clients.get(prev), "group", None)
                if g is not None:
                    gview = self.fleet.group_candidates(stage, g)
                    if gview:
                        view = gview
        return view

    def _complete(self, req: rq.Request):
        """Terminal bookkeeping: straggler dispatch-time entries die with the
        request (they previously leaked for the whole run)."""
        self._dispatch_times.pop(req.rid, None)
        self.metrics.complete(req)

    def _arm_straggler(self, req: rq.Request, at: float):
        """(Re)arm the per-dispatch rescue deadline. The payload carries the
        arming dispatch time so the deadline guard compares exactly instead
        of reconstructing it from floats. Deliberately NOT an _ext_times
        entry: a deadline check cannot perturb a running decode window (it
        only rescues *queued* requests, and any resulting re-dispatch
        interrupts its target itself), so it must not cap fast-forward
        window lengths."""
        self._dispatch_times[req.rid] = at
        if self.cfg.straggler_deadline is not None:
            self.queue.push(at + self.cfg.straggler_deadline,
                            ev.STRAGGLER_CHECK, (req, at))

    def _dispatch(self, req: rq.Request, now: float):
        """Route current stage to a client (Algorithm 1 'Request-push')."""
        while not req.done and self._candidates(req) is None:
            req.advance_stage(now)     # optional stage with no client: skip
        if req.done:
            self._complete(req)
            return
        cands = self._candidates(req)
        self._sync(cands, now)         # routers must see committed state
        client = self.router.route(req, cands, now)
        st = req.current_stage
        st.client = client.name
        st.dispatch_time = now
        st.start_time = now
        self._arm_straggler(req, now)
        self._interrupt(client.name, now)  # arrival lands mid-window
        client.add(req)
        self._touch(client.name)
        self._kick(client, now)

    def _touch(self, name: str):
        """Dirty-mark a client whose scheduler/allocator state this event
        mutated: its cached load-index values are stale. Every chokepoint
        where the coordinator reaches into a client calls this — missing one
        breaks the decision-identity contract (and is what the churn
        hypothesis test in tests/test_fleet_scale.py hunts for)."""
        if self.fleet is not None:
            self.fleet.touch(name)

    def _kick(self, client: Client, now: float):
        if client.failed or client.name in self._active_step:
            return
        step = client.plan_step(now, self._ff_horizon(now))
        # plan_step itself mutates load-bearing state (admission, swap-ins,
        # preemption) even when it ends up planning nothing
        self._touch(client.name)
        if step is None:
            return
        self._active_step[client.name] = step
        if self.fleet is not None and getattr(step, "n_steps", 1) > 1:
            self.fleet.set_windowed(client.name, True)
        end = getattr(step, "end_time", None)
        self.queue.push(end if end is not None else now + step.duration,
                        ev.CLIENT_STEP_DONE, (client.name, step))

    # --- decode fast-forward invalidation ------------------------------
    def _interrupt(self, name: str, now: float, reschedule: bool = True,
                   inclusive: bool = False):
        """Truncate-and-replay an in-flight macro-step: commit the
        iterations that already finished, put the one spanning ``now`` back
        in flight as a plain step ending at its original boundary (the stale
        macro CLIENT_STEP_DONE is skipped by the identity check), and let the
        discarded tail be re-planned. Single steps are atomic in per-step
        execution too, so they are left untouched."""
        step = self._active_step.get(name)
        if step is None or getattr(step, "n_steps", 1) <= 1:
            return
        client = self.clients.get(name)
        if client is None:
            return
        del self._active_step[name]
        if self.fleet is not None:
            self.fleet.set_windowed(name, False)
            self.fleet.touch(name)         # truncation commits window state
        rem = client.truncate_step(step, now, inclusive)
        if rem is not None and reschedule:
            self._active_step[name] = rem
            self.queue.push(rem.end_time, ev.CLIENT_STEP_DONE, (name, rem))

    # load metrics whose exact value requires materialized KV block state;
    # the rest are either invariant mid-window (queue, input_len, output_len)
    # or folded in virtually by Client.load (tokens_remaining)
    _KV_EXACT_METRICS = ("kv_size", "kv_pressure")

    def _sync(self, clients, now: float):
        """Make routing state exact. Routers reading raw allocator state
        need every candidate's fast-forward window committed up to ``now``;
        for every other metric ``Client.load(metric, now)`` already reports
        the virtually-committed value, so the windows of routing *losers*
        survive untouched (only the chosen client is interrupted, by the
        caller, before the request is enqueued)."""
        if getattr(self.router, "metric", None) not in self._KV_EXACT_METRICS:
            return
        if isinstance(clients, StageMembers):
            # only windowed candidates need cutting — _interrupt is a no-op
            # (and pushes no event) for everyone else, so skipping them
            # pushes the exact event sequence the baseline loop would
            clients = clients.windowed()
        for c in clients:
            self._interrupt(c.name, now)

    # ------------------------------------------------------------------
    def _account_swap_traffic(self, client: Client, step, now: float):
        """KV-page swap traffic from preemptions (paper §III-E3). The
        engine's stall is already priced inside the step duration (Eq. 1
        tier term); here the bytes are counted in the metrics and recorded
        against the client's dedicated spill link so ``Network.stats()``
        reports per-client swap volume. (The spill link is private to the
        client — host-side contention with other traffic is not modeled.)"""
        nbytes = getattr(step, "swap_bytes", 0.0)
        if nbytes <= 0:
            return
        self.metrics.observe_step_swaps(step)
        if self.network.paths.get((client.name, f"{client.name}:kvpool")):
            self.network.transfer(client.name, f"{client.name}:kvpool",
                                  nbytes, now)

    # ------------------------------------------------------------------
    def _transfer_and_forward(self, req: rq.Request, src: str, now: float):
        """Price inter-stage data movement, then re-enqueue as a new request
        event at the destination (paper §III-B2)."""
        prev_stage = req.stages[req.stage_idx - 1] if req.stage_idx else None
        while not req.done and self._candidates(req) is None:
            req.advance_stage(now)     # optional stage with no client: skip
        nxt = req.current_stage
        if nxt is None:
            self._complete(req)
            return
        # choose destination now so we can price the wire
        cands = self._candidates(req)
        self._sync(cands, now)
        dst_client = self.router.route(req, cands, now)
        nbytes, gran, n_layers = 0.0, "full", 1
        if prev_stage is not None and nxt is not None:
            if prev_stage.kind == rq.PREFILL and nxt.kind == rq.DECODE:
                src_c = self.clients.get(src)
                if isinstance(src_c, LLMClient):
                    nbytes = src_c.kv_transfer_bytes_fn(req)
                    # wire-side prefix dedup: pages the destination's radix
                    # cache already holds need not ship (the decode client
                    # maps them at admission instead). Priced at transfer-
                    # schedule time; a page evicted before the request is
                    # admitted still rides for free — real systems pin
                    # matched pages for the transfer window, which we
                    # approximate by not re-checking at admission.
                    hit = dst_client.prefix_hit_tokens(req)
                    if hit > 0:
                        saved = min(nbytes,
                                    hit * src_c.scheduler.kv_per_token)
                        nbytes -= saved
                        self.metrics.kv_transfer_dedup_bytes += saved
                    n_layers = src_c.model_cfg.num_layers
                    gran = self.cfg.kv_transfer_granularity
            elif prev_stage.kind in (rq.RAG_RETRIEVE, rq.RAG_EMBED):
                nbytes = req.rag_tokens * 2.0 * 4  # context ids+embeddings
            elif prev_stage.kind == rq.KV_RETRIEVAL:
                nbytes = 0.0  # priced inside the retrieval stage itself
        arrive = self.network.transfer(src, dst_client.name, nbytes, now,
                                       granularity=gran, n_layers=n_layers)
        self.metrics.comm_events += 1
        self.metrics.comm_bytes += nbytes
        st = req.current_stage
        st.client = dst_client.name
        st.dispatch_time = arrive
        st.start_time = arrive
        # the forwarded stage is a fresh dispatch: refresh the straggler
        # bookkeeping and arm a deadline of its own. Without this, a deadline
        # armed at the PREVIOUS stage's dispatch still matched the stale
        # _dispatch_times entry and could preempt a request legitimately
        # queued at its next stage — and forwarded stages had no straggler
        # protection at all.
        self._arm_straggler(req, arrive)
        self._push_ext(arrive, ev.TRANSFER_DONE, (req, dst_client.name))

    # ------------------------------------------------------------------
    # cross-client radix prefix migration (paper §V-B remote KV retrieval)
    # ------------------------------------------------------------------
    @staticmethod
    def _kv_of(client) -> Optional[object]:
        return getattr(getattr(client, "scheduler", None), "kv", None)

    def maybe_fetch_prefix(self, src: Client, dst: Client, req: rq.Request,
                           now: float) -> bool:
        """Fetch-vs-recompute decision (Eq. 1 tier term vs. the analytical
        prefill model): the router found the warm client overloaded and is
        about to place ``req`` on ``dst`` cold. Ship the prefix when the
        wire fetch is cheaper than re-prefilling the same tokens at the
        destination. Returns True when a migration toward ``dst`` is (now)
        in flight. Deliberately reads only window-invariant allocator state
        (radix residency, link occupancy, pure perf models) so the decision
        is bit-identical with decode fast-forward on or off."""
        if not self.cfg.prefix_migration:
            return False
        src_kv, dst_kv = self._kv_of(src), self._kv_of(dst)
        if src_kv is None or dst_kv is None or not req.prefix_segments:
            return False
        hashes = tuple(req.prefix_block_hashes(src_kv.block_tokens))
        if not hashes:
            return False
        ship = (len(src_kv.radix.match(hashes))
                - len(dst_kv.radix.match(hashes)))
        if ship <= 0:
            return False
        key = (dst.name, hashes)
        if key in self._migrations_inflight:
            return True                    # already warming toward dst
        nbytes = ship * src_kv.block_bytes
        gran = self.cfg.migration_granularity \
            or self.cfg.kv_transfer_granularity
        n_layers = src.model_cfg.num_layers if isinstance(src, LLMClient) else 1
        fetch_t = self.network.estimate(src.name, dst.name, nbytes, now,
                                        granularity=gran, n_layers=n_layers)
        recompute_t = dst.scheduler.perf.prefill(
            ship * src_kv.block_tokens, 1).time
        if fetch_t >= recompute_t:
            return False                   # recompute wins: let dst rebuild
        self._migrations_inflight.add(key)
        self._push_ext(now, ev.PREFIX_MIGRATE,
                       (src.name, dst.name, hashes, key))
        return True

    def _warm_client(self, client: Client, now: float):
        """Push-mode replica warming (CLIENT_ADD / CLIENT_RECOVER): ship the
        hottest resident prefix chains from the warmest compatible peer so a
        scaled-out or recovered client serves prefix hits before organic
        traffic refills it."""
        if not (self.cfg.prefix_migration and self.cfg.warm_on_scale_out):
            return
        if self._kv_of(client) is None:
            return
        donors = [c for c in self.clients.values()
                  if c is not client and not c.failed
                  and self._kv_of(c) is not None
                  and set(c.stages) & set(client.stages)]
        if not donors:
            return
        donor = max(donors,
                    key=lambda c: len(self._kv_of(c).radix.by_block))
        chains = self._kv_of(donor).hot_chains(self.cfg.warm_max_blocks)
        for chain in chains:
            key = (client.name, tuple(chain))
            if key in self._migrations_inflight:
                continue
            self._migrations_inflight.add(key)
            self._push_ext(now, ev.PREFIX_MIGRATE,
                           (donor.name, client.name, tuple(chain), key))

    def _start_migration(self, src_name: str, dst_name: str, hashes, key,
                         now: float):
        """PREFIX_MIGRATE: pin the source chain and put its bytes on the
        wire (layerwise or full granularity, like the prefill→decode
        handoff). MIGRATE_DONE lands as an *external* event so a decode
        fast-forward window at the destination truncates-and-replays
        instead of committing state the import would have changed."""
        src, dst = self.clients.get(src_name), self.clients.get(dst_name)
        src_kv = self._kv_of(src) if src is not None else None
        dst_kv = self._kv_of(dst) if dst is not None else None
        if (src is None or dst is None or src.failed or dst.failed
                or src_kv is None or dst_kv is None):
            self._migrations_inflight.discard(key)
            return
        # pages the destination already holds need not ship (same wire-side
        # dedup the prefill→decode handoff applies)
        skip = len(dst_kv.radix.match(hashes))
        export = src_kv.export_chain(hashes, skip=skip)
        if export is None:
            self._migrations_inflight.discard(key)
            return
        handle, n_resident, nbytes = export
        self._touch(src_name)              # export pins bump refcounts
        gran = self.cfg.migration_granularity \
            or self.cfg.kv_transfer_granularity
        n_layers = src.model_cfg.num_layers if isinstance(src, LLMClient) else 1
        arrive = self.network.transfer(src_name, dst_name, nbytes, now,
                                       granularity=gran, n_layers=n_layers)
        self.metrics.comm_events += 1
        self.metrics.comm_bytes += nbytes
        self._push_ext(arrive, ev.MIGRATE_DONE,
                       (src_name, dst_name, handle,
                        tuple(hashes[:n_resident]), nbytes, key))

    def _finish_migration(self, payload, now: float):
        """MIGRATE_DONE: unpin the source pages and materialize the chain at
        the destination (collision truncation + free-list-only capacity
        backpressure happen inside ``import_chain``)."""
        src_name, dst_name, handle, chain, nbytes, key = payload
        self._migrations_inflight.discard(key)
        src = self.clients.get(src_name)
        src_kv = self._kv_of(src) if src is not None else None
        if src_kv is not None:
            src_kv.release_export(handle)
            self._touch(src_name)
        dst = self.clients.get(dst_name)
        dst_kv = self._kv_of(dst) if dst is not None else None
        if dst is None or dst.failed or dst_kv is None:
            return        # destination died in flight: bytes spent, pages lost
        # the import lands mid-window: commit the finished iterations first
        # so the window's free-list reservation stays exact
        self._interrupt(dst_name, now)
        dst_kv.import_chain(list(chain))
        self._touch(dst_name)
        self.metrics.kv_migrations += 1
        self.metrics.kv_migrated_bytes += nbytes

    # ------------------------------------------------------------------
    def run(self, until: Optional[float] = None) -> MetricsCollector:
        """Algorithm 1 main loop."""
        horizon = until or self.cfg.max_sim_time
        while len(self.queue):
            if self.queue.peek_time() > horizon:
                break
            event = self.queue.pop()
            now = event.time
            kind = event.kind

            if kind == ev.REQUEST_ARRIVAL:
                self._dispatch(event.payload, now)

            elif kind == ev.TRANSFER_DONE:
                req, dst = event.payload
                client = self.clients.get(dst)
                if client is None or client.failed:
                    self._dispatch(req, now)   # destination died in flight
                else:
                    self._interrupt(dst, now)  # arrival lands mid-window
                    client.add(req)
                    self._touch(dst)
                    self._kick(client, now)

            elif kind == ev.CLIENT_STEP_DONE:
                name, step = event.payload
                client = self.clients.get(name)
                if client is None or self._active_step.get(name) is not step:
                    continue  # stale (failed/removed client)
                del self._active_step[name]
                if self.fleet is not None:
                    self.fleet.set_windowed(name, False)
                if client.failed:
                    continue
                self._touch(name)
                finished = client.finish_step(step, now)
                self._account_swap_traffic(client, step, now)
                for req in finished:
                    req.advance_stage(now)
                    if req.done:
                        self._complete(req)
                    else:
                        self._transfer_and_forward(req, name, now)
                self._kick(client, now)

            elif kind == ev.CLIENT_FAIL:
                self._on_fail(event.payload, now)

            elif kind == ev.CLIENT_RECOVER:
                c = self.clients.get(event.payload)
                if c is not None:
                    was_failed = c.failed
                    c.failed = False
                    if self.fleet is not None and was_failed:
                        self.fleet.set_failed(c.name, False)
                    self._warm_client(c, now)  # its device KV died with it
                    self._kick(c, now)

            elif kind == ev.CLIENT_ADD:
                self._on_add(event.payload, now)

            elif kind == ev.CLIENT_REMOVE:
                self._on_remove(event.payload, now)

            elif kind == ev.AUTOSCALE_CHECK:
                if self.autoscaler is not None:
                    self.autoscaler.on_check(self, now)
                    # re-arm while anything remains in flight; when the last
                    # pending event is this check, the loop is allowed to end
                    if len(self.queue):
                        self.queue.push(now + self.autoscaler.cfg.interval,
                                        ev.AUTOSCALE_CHECK, None)

            elif kind == ev.STRAGGLER_CHECK:
                self._check_straggler(*event.payload, now)

            elif kind == ev.PREFIX_MIGRATE:
                self._start_migration(*event.payload, now)

            elif kind == ev.MIGRATE_DONE:
                self._finish_migration(event.payload, now)

        # horizon cut-off: commit in-flight fast-forward windows up to the
        # horizon (iterations ending exactly there included — their events
        # would have fired) so observable state matches per-step execution
        # truncated at the same time; remainders are rescheduled beyond the
        # horizon in case run() is resumed.
        for name in list(self._active_step):
            self._interrupt(name, horizon, inclusive=True)

        if self.autoscaler is not None:     # close the client-seconds
            self.autoscaler.finalize(self, self.queue.now)   # cost integral
        self.metrics.collect_kv(self.clients.values())
        return self.metrics

    # ------------------------------------------------------------------
    def _on_fail(self, name: str, now: float):
        client = self.clients.get(name)
        if client is None:
            return
        # tokens from already-finished window iterations were streamed to the
        # user; commit them before the in-flight (remainder) step is lost
        self._interrupt(name, now, reschedule=False)
        was_failed = client.failed
        client.failed = True
        if self.fleet is not None and not was_failed:
            self.fleet.set_failed(name, True)
        step = self._active_step.pop(name, None)   # in-flight step is lost
        if step is not None:
            # ... but its admitted-not-finished prefills must not be: put
            # them back in the queue so the drain below re-dispatches them
            client.requeue_step(step)
        if self.fleet is not None:
            self.fleet.set_windowed(name, False)
        for req in client.drain():             # checkpoint/restart semantics:
            # the stage restarts on another client; decoded tokens already
            # streamed to the user are kept.
            self._dispatch(req, now)

    def _on_add(self, c: Client, now: float):
        """CLIENT_ADD (scripted schedule or autoscaler scale-out)."""
        self.clients[c.name] = c
        if self.fleet is not None:
            self.fleet.add(c)
        self._warm_client(c, now)              # scaled-out replica is cold
        self._kick(c, now)

    def _on_remove(self, name: str, now: float):
        if name in self.clients:
            self._interrupt(name, now, reschedule=False)
        client = self.clients.pop(name, None)
        if client is None:
            return
        # mid-migration removal: a removed *donor* must not leave its export
        # pins behind (retired kv_stats would count permanently-pinned
        # blocks, and check_invariants on the retired allocator would fail);
        # a removed *recipient* must not leave its in-flight keys behind —
        # a warm-pool replica re-added later under the same name would be
        # refused warming by the stale dedup key. The MIGRATE_DONE events
        # themselves land as no-ops: release against a discarded handle
        # does nothing, and the dst lookup misses (or finds the same-named
        # fresh replica, which the import then legitimately warms).
        kv = self._kv_of(client)
        if kv is not None:
            kv.discard_exports()
        self._migrations_inflight = {
            k for k in self._migrations_inflight if k[0] != name}
        self.metrics.retire_client_kv(client)
        step = self._active_step.pop(name, None)
        if step is not None:
            client.requeue_step(step)
        drained = client.drain()
        if self.fleet is not None:
            self.fleet.remove(name, client)
        for req in drained:
            self._dispatch(req, now)

    def _check_straggler(self, req: rq.Request, armed_at: float, now: float):
        """Hedged re-dispatch (straggler mitigation), armed per dispatch as a
        deadline event instead of rescanning every client's waiting queue on
        every step completion: a request still queued — not started — at the
        client it was dispatched to when its deadline fires is re-routed.
        A request that cannot be rescued yet (running, or no alternative
        client) re-arms for another deadline, covering late stragglers the
        old continuous rescan would have caught (e.g. a preemption dropping
        it back into a slow client's queue after its first check)."""
        ddl = self.cfg.straggler_deadline
        if ddl is None or req.done:
            return
        # re-dispatched since this deadline was armed: a newer one is queued
        if self._dispatch_times.get(req.rid) != armed_at:
            return
        st = req.current_stage
        client = self.clients.get(st.client) if st.client else None
        if client is None:
            return
        rearm = lambda: self.queue.push(now + ddl, ev.STRAGGLER_CHECK,
                                        (req, armed_at))
        if client.failed:
            rearm()                       # fail-drain will re-dispatch it
            return
        sched = client.scheduler
        waiting = getattr(sched, "waiting", ())
        if req not in waiting:
            rearm()                       # running now, may be preempted yet
            return
        cands = self._candidates(req) or []
        if not any(c is not client for c in cands):
            rearm()                       # nowhere else to go (for now)
            return
        if hasattr(sched, "remove_waiting"):
            sched.remove_waiting(req)     # frees any pages it held
        else:
            waiting.remove(req)
        self._touch(client.name)
        req.preemptions += 1
        self._dispatch(req, now)

    # ------------------------------------------------------------------
    @property
    def total_energy(self) -> float:
        return sum(c.total_energy for c in self.clients.values())

    def all_serviced(self) -> bool:
        return len(self.metrics.serviced) >= self._accepted
