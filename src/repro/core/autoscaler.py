"""Closed-loop goodput autoscaler (ROADMAP item 4; paper §VI fleet scaling).

Nothing in the system used to *react* to load: CLIENT_ADD/CLIENT_REMOVE were
only fired from hand-scripted schedules. ``Autoscaler`` closes the loop: the
``Coordinator`` ticks it on a periodic ``AUTOSCALE_CHECK`` event; each tick
it observes a sliding window of recent health (``MetricsCollector.
window_stats``: per-tier SLO attainment, windowed goodput, TTFT percentiles)
plus instantaneous queue depth, asks a pluggable ``AutoscalePolicy`` for the
desired fleet size, and applies the difference as CLIENT_ADD / CLIENT_REMOVE
actions against a warm pool of templated client specs.

Scale-out rides the PR 4 push-mode prefix warming (``CoordinatorConfig.
warm_on_scale_out``: the coordinator ships the donor's hottest radix chains
to the new replica as it lands). Scale-in drains through the PR 8
``requeue_step`` path — ``Coordinator._on_remove`` requeues the removed
client's in-flight admissions and re-dispatches its whole queue — so no
request is ever lost or duplicated across scale events (property-tested in
``tests/test_autoscale.py``).

Decision determinism contract
-----------------------------
Every observation the controller reads is invariant under decode
fast-forward: windowed serviced stats (windows never span a request
completion — the planner's K bound stops at the next completion), ``queue``
depth (windows plan only when nothing is waiting) and ``tokens_remaining``
(``Client.load`` folds the virtually-committed window prefix in). The same
schedule therefore produces the bit-identical action sequence — and summary
— with ``fast_forward`` on or off, which is exactly what the hypothesis
suite asserts. Policies must not read materialized KV state (``kv_size`` /
``kv_pressure``) without the coordinator `_sync`-ing candidates first; the
built-in policies don't.

Flap damping is split between the two layers: *policies* carry hysteresis
bands (scale-in thresholds strictly below scale-out thresholds), the
*controller* enforces cooldowns measured from the last action of either
direction — ``cooldown_out`` must elapse before a scale-out, ``cooldown_in``
before a scale-in. A remove can thus never be chased by an add (or vice
versa) inside the respective cooldown, the no-flap property the hypothesis
suite pins.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

from repro.core import request as rq
from repro.core.client import Client, LLMClient
from repro.core.metrics import SLO


@dataclass
class AutoscalerConfig:
    interval: float = 0.25          # AUTOSCALE_CHECK period (seconds)
    window: float = 1.0             # sliding observation window (seconds)
    min_clients: int = 1            # live-fleet bounds, both inclusive
    max_clients: int = 8
    cooldown_out: float = 0.5       # min gap after any action before scale-out
    cooldown_in: float = 1.5        # min gap after any action before scale-in
    stage: str = rq.LLM             # stage kind this controller manages
    name_prefix: str = "scale"      # warm-pool replica names: scale0, scale1…
    scale_in_metric: str = "tokens_remaining"  # least-loaded pick for drain


@dataclass
class Observation:
    """What a policy sees each tick. Window fields are ``None`` when nothing
    completed inside the window (policies must not treat silence as
    health — an overloaded fleet completing nothing looks exactly like an
    idle one on SLO fractions; queue depth disambiguates)."""
    now: float
    n_live: int
    queue_depth: float              # waiting+running over live stage clients
    queue_per_client: float
    tokens_remaining: float         # virtually-committed, fast-forward-exact
    window_n: int                   # requests completed inside the window
    slo_frac: Optional[float]       # fraction of those meeting P50 SLO caps
    slo_frac_by_tier: Dict[str, float]
    goodput_tok_s: float            # windowed, SLO-gated tokens/sec
    goodput_by_tier: Dict[str, float]
    ttft_p90: float                 # over the window (nan when empty)


class AutoscalePolicy:
    """Maps an ``Observation`` to a desired live-fleet size. Pure: policies
    hold tuning constants, never mutable controller state, so one policy
    object can be shared across arms/runs."""

    name = "base"

    def desired(self, obs: Observation) -> int:
        raise NotImplementedError


class ThresholdHysteresisPolicy(AutoscalePolicy):
    """Classic band controller: scale out when queue depth per client rises
    above ``queue_hi`` or windowed SLO attainment falls below ``slo_lo``;
    scale in only when the queue is below ``queue_lo`` AND attainment is
    above ``slo_hi``. The dead band between the thresholds is the
    hysteresis — a fleet sitting inside it holds steady, so threshold noise
    cannot flap add/remove (cooldowns damp whatever the band lets through).
    """

    name = "threshold"

    def __init__(self, queue_hi: float = 8.0, queue_lo: float = 1.0,
                 slo_lo: float = 0.7, slo_hi: float = 0.9, step_out: int = 1):
        assert queue_lo < queue_hi and slo_lo <= slo_hi
        self.queue_hi = queue_hi
        self.queue_lo = queue_lo
        self.slo_lo = slo_lo
        self.slo_hi = slo_hi
        self.step_out = step_out

    def desired(self, obs: Observation) -> int:
        n = obs.n_live
        slo_bad = obs.slo_frac is not None and obs.slo_frac < self.slo_lo
        if obs.queue_per_client > self.queue_hi or slo_bad:
            return n + self.step_out
        slo_good = obs.slo_frac is None or obs.slo_frac >= self.slo_hi
        if obs.queue_per_client < self.queue_lo and slo_good:
            return n - 1
        return n


class TargetTrackingPolicy(AutoscalePolicy):
    """Proportional controller tracking a queue-depth-per-client setpoint:
    desired = ceil(n * measured / target), clamped to ``max_step`` adds per
    tick. Scale-in waits for measured load to fall below
    ``scale_in_ratio * target`` (the tolerance band playing the hysteresis
    role) and sheds one replica at a time. A windowed SLO-attainment floor
    overrides the proportional term — queue depth can look fine while TTFT
    targets burn (long prompts, warm-up after scale-out)."""

    name = "target_tracking"

    def __init__(self, target_queue: float = 4.0, slo_floor: float = 0.8,
                 scale_in_ratio: float = 0.5, max_step: int = 4):
        assert 0.0 < scale_in_ratio < 1.0
        self.target_queue = target_queue
        self.slo_floor = slo_floor
        self.scale_in_ratio = scale_in_ratio
        self.max_step = max_step

    def desired(self, obs: Observation) -> int:
        n = obs.n_live
        ratio = obs.queue_per_client / max(self.target_queue, 1e-9)
        want = n
        if ratio > 1.0:
            want = min(n + self.max_step, math.ceil(n * ratio))
        elif ratio < self.scale_in_ratio:
            want = n - 1
        if obs.slo_frac is not None and obs.slo_frac < self.slo_floor:
            want = max(want, n + 1)
        return want


def make_policy(name: str, **kw) -> AutoscalePolicy:
    if name == "threshold":
        return ThresholdHysteresisPolicy(**kw)
    if name == "target_tracking":
        return TargetTrackingPolicy(**kw)
    raise ValueError(name)


class ClientTemplate:
    """Templated spec for warm-pool replicas: everything needed to stamp out
    a fresh ``LLMClient`` under a new name. Replicas share the template's
    ``ClientPerf`` (its memo is keyed on pure shapes, safely shared); each
    gets its own scheduler/allocator — a scaled-out replica starts cold and
    is warmed by the coordinator's push-mode prefix migration, not by
    inheriting state."""

    def __init__(self, cluster, model_cfg, strategy: str = "continuous",
                 limits=None, packing: str = "fcfs", perf=None,
                 group: Optional[str] = None):
        from repro.core.llm_scheduler import SchedulerLimits
        self.cluster = cluster
        self.model_cfg = model_cfg
        self.strategy = strategy
        self.limits = limits if limits is not None else SchedulerLimits()
        self.packing = packing
        self.perf = perf
        self.group = group

    @classmethod
    def from_client(cls, c: LLMClient) -> "ClientTemplate":
        return cls(c.cluster, c.model_cfg, c.strategy, c.scheduler.limits,
                   c.scheduler.packing, c.scheduler.perf, c.group)

    def build(self, name: str) -> LLMClient:
        return LLMClient(name, self.cluster, self.model_cfg, self.strategy,
                         self.limits, self.packing, self.perf,
                         group=self.group)


class Autoscaler:
    """The controller the coordinator ticks on AUTOSCALE_CHECK events.

    Tracks its own audit trail: ``actions`` is the exact, ordered
    ``(time, "add"|"remove", name)`` sequence (what the golden scenario test
    pins), ``fleet_trace`` samples ``(time, n_live)`` at every tick and
    action, and ``client_seconds`` integrates provisioned-client time — the
    cost metric the benchmark weighs goodput against. Names of removed
    warm-pool replicas return to a free list and are reused smallest-first,
    so a long diurnal run cycles scale0/scale1 instead of growing the
    namespace without bound."""

    def __init__(self, template: ClientTemplate,
                 policy: Optional[AutoscalePolicy] = None,
                 cfg: Optional[AutoscalerConfig] = None,
                 slos=None):
        self.template = template
        self.policy = policy or TargetTrackingPolicy()
        self.cfg = cfg or AutoscalerConfig()
        assert 1 <= self.cfg.min_clients <= self.cfg.max_clients
        self.slos = slos            # SLO or tier->SLO map for window_stats
        self.actions: List[Tuple[float, str, str]] = []
        self.fleet_trace: List[Tuple[float, int]] = []
        self.client_seconds: float = 0.0
        self.checks: int = 0
        self._last_action = -math.inf
        self._counter = 0
        self._free_names: List[str] = []
        self._cost_t: Optional[float] = None

    # -- fleet views -------------------------------------------------------
    def _stage_clients(self, coord) -> List[Client]:
        """Provisioned clients dedicated to the managed stage, in client-dict
        order (identical with the fleet index on or off — the index preserves
        baseline iteration order by contract). Only single-stage clients are
        eligible: the controller must never remove a client that also serves
        some other stage."""
        return [c for c in coord.clients.values()
                if c.stages == (self.cfg.stage,)]

    def _live(self, coord) -> List[Client]:
        return [c for c in self._stage_clients(coord) if not c.failed]

    # -- cost integral -----------------------------------------------------
    def _advance_cost(self, coord, now: float):
        """client_seconds integrates *provisioned* (failed included — they
        are still paid for) stage clients over time."""
        if self._cost_t is not None and now > self._cost_t:
            self.client_seconds += ((now - self._cost_t)
                                    * len(self._stage_clients(coord)))
        self._cost_t = max(now, self._cost_t or now)

    def bind(self, coord, now: float):
        """Called by ``Coordinator.attach_autoscaler``: opens the cost
        integral and the fleet trace at the initial fleet."""
        self._cost_t = now
        self.fleet_trace.append((now, len(self._live(coord))))

    def finalize(self, coord, now: float):
        """Close the cost integral at the end of a run (idempotent; a
        resumed ``run()`` keeps integrating from here)."""
        self._advance_cost(coord, now)

    # -- observation -------------------------------------------------------
    def observe(self, coord, now: float) -> Observation:
        live = self._live(coord)
        n = len(live)
        queue = sum(c.load("queue", now) for c in live)
        toks = sum(c.load("tokens_remaining", now) for c in live)
        w = coord.metrics.window_stats(now - self.cfg.window, until=now,
                                       slos=self.slos or SLO())
        return Observation(
            now=now, n_live=n, queue_depth=queue,
            queue_per_client=queue / max(n, 1),
            tokens_remaining=toks,
            window_n=w["n"],
            slo_frac=w.get("slo_frac") if w["n"] else None,
            slo_frac_by_tier=w.get("slo_frac_by_tier", {}),
            goodput_tok_s=w.get("goodput_tok_s", 0.0),
            goodput_by_tier=w.get("goodput_by_tier", {}),
            ttft_p90=w["ttft_p90"])

    # -- the tick ----------------------------------------------------------
    def on_check(self, coord, now: float):
        self.checks += 1
        self._advance_cost(coord, now)
        obs = self.observe(coord, now)
        want = max(self.cfg.min_clients,
                   min(self.cfg.max_clients, self.policy.desired(obs)))
        n = obs.n_live
        if want > n and now - self._last_action >= self.cfg.cooldown_out:
            self._scale_out(coord, now, want - n)
        elif want < n and now - self._last_action >= self.cfg.cooldown_in:
            self._scale_in(coord, now)
        self.fleet_trace.append((now, len(self._live(coord))))

    def _next_name(self) -> str:
        if self._free_names:
            return self._free_names.pop(0)
        name = f"{self.cfg.name_prefix}{self._counter}"
        self._counter += 1
        return name

    def _scale_out(self, coord, now: float, k: int):
        for _ in range(k):
            name = self._next_name()
            self._advance_cost(coord, now)   # cost of the larger fleet
            coord._on_add(self.template.build(name), now)  # starts accruing now
            self.actions.append((now, "add", name))
        self._last_action = now

    def _scale_in(self, coord, now: float):
        """Remove the most-drained (least-loaded) live replica — ties break
        on name so the pick is deterministic. ``Coordinator._on_remove``
        requeues its in-flight step and re-dispatches its queue, so the
        drain loses nothing."""
        live = self._live(coord)
        if len(live) <= self.cfg.min_clients:
            return
        victim = min(live, key=lambda c: (c.load(self.cfg.scale_in_metric,
                                                 now), c.name))
        self._advance_cost(coord, now)       # close out the larger fleet
        coord._on_remove(victim.name, now)
        if victim.name.startswith(self.cfg.name_prefix):
            self._free_names.append(victim.name)
            self._free_names.sort()
        self.actions.append((now, "remove", victim.name))
        self._last_action = now
