"""Multi-level KV-cache retrieval hierarchy (paper §III-E3, Eq. 1).

    f(KV, C_n) = Hit_n * (T_lookup_n + Size_KV / BW_n)
               + (1 - Hit_n) * f(KV, C_{n+1})

A miss below the last level falls back to ``miss_cost`` — typically prefill
recomputation (priced by the analytical model) or a DCN fetch.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Sequence

import numpy as np

from repro.perfmodel.hardware import CacheTierSpec


def expected_retrieval_latency(size_bytes: float,
                               tiers: Sequence[CacheTierSpec],
                               miss_cost: float) -> float:
    """Paper Eq. 1, evaluated recursively (expected value)."""
    if not tiers:
        return miss_cost
    t = tiers[0]
    hit_time = t.lookup_latency + size_bytes / t.bandwidth
    return t.hit_rate * hit_time + (1.0 - t.hit_rate) * expected_retrieval_latency(
        size_bytes, tiers[1:], miss_cost)


def sample_retrieval_latency(size_bytes: float, tiers: Sequence[CacheTierSpec],
                             miss_cost: float, rng: np.random.Generator) -> float:
    """Monte-Carlo variant for latency-CDF studies (paper Fig. 15)."""
    lat = 0.0
    for t in tiers:
        lat += t.lookup_latency
        if rng.random() < t.hit_rate:
            return lat + size_bytes / t.bandwidth
    return lat + miss_cost


@dataclass
class MemoryManager:
    """On-device KV memory for an LLM client (paper §III-D: the scheduler
    prevents admission when KV memory is insufficient and evicts on
    completion)."""
    capacity: float
    used: float = 0.0
    peak: float = 0.0
    admission_failures: int = 0

    def can_admit(self, nbytes: float) -> bool:
        return self.used + nbytes <= self.capacity

    def admit(self, nbytes: float) -> bool:
        if not self.can_admit(nbytes):
            self.admission_failures += 1
            return False
        self.used += nbytes
        self.peak = max(self.peak, self.used)
        return True

    def grow(self, nbytes: float):
        self.used += nbytes
        self.peak = max(self.peak, self.used)

    def release(self, nbytes: float):
        self.used = max(0.0, self.used - nbytes)
