"""Paged, tiered KV-cache subsystem (paper §III-D admission control +
§III-E3 multi-level retrieval, Eq. 1) with shared-prefix radix caching.

Three layers live here:

1. **Retrieval pricing (Eq. 1).** ``expected_retrieval_latency`` /
   ``sample_retrieval_latency`` evaluate the paper's recursive cache-lookup
   model over a ``CacheTierSpec`` chain:

       f(KV, C_n) = T_lookup_n + Hit_n * Size_KV / BW_n
                  + (1 - Hit_n) * f(KV, C_{n+1})

   Every *probed* tier charges its lookup latency — hit or miss — so the
   analytical expectation and the Monte-Carlo walk agree on the miss path
   (a probe must pay the directory lookup to learn it missed). A miss below
   the last level falls back to ``miss_cost`` — typically prefill
   recomputation (priced by the analytical model) or a DCN fetch.

2. **On-device allocation (``PagedKVAllocator``).** The same tier specs that
   parameterize Eq. 1 back the on-device allocator's spill hierarchy, so the
   analytical model and the discrete-event scheduler agree on bandwidths:

   * HBM is carved into fixed-size *blocks* of ``block_tokens`` KV slots;
     each request owns a *block table* (ordered list of physical block ids).
     Admission reserves whole blocks; decode growth faults in one block at a
     time; release returns blocks to a free list — O(1) each, no compaction.
   * When decode growth faults with an empty free list, a *preemption policy*
     makes room:
       - ``swap``      — the victim's pages move to the next tier down
                         (host DRAM → remote). The traffic is priced with the
                         tier term of Eq. 1 (``T_lookup + bytes / BW``) and,
                         at the coordinator, occupies ``Network`` links.
       - ``recompute`` — the victim's pages are dropped and its prefill
                         re-enqueued; cost resurfaces as recomputed prefill
                         FLOPs instead of wire bytes.
   * Internal fragmentation (allocated-but-unfilled token slots in each
     request's last block) is tracked and exported through ``stats()`` so
     routers can balance on real, fragmentation-aware KV pressure.

3. **Shared-prefix radix cache (``RadixBlockIndex``).** Physical blocks are
   *refcounted*; a hash chain over block-aligned prompt content maps prefixes
   to resident physical blocks, so

   * requests whose prompts share a block-aligned prefix map the *same*
     physical pages (paper §IV-A reasoning, RAG system-prompt/chunk reuse);
   * a multi-branch reasoning request ``fork``s its block table copy-on-write:
     branches share every prefill page and copy only the partial tail block
     on the first divergent decode write;
   * blocks whose refcount drops to zero stay resident as *cached* and are
     reclaimed leaf-first in LRU order only when the free list runs dry.

   The radix cache composes with the preemption policies above: ``swap_out``
   may only victimize tables whose pages all have refcount 1 (a shared page
   cannot move without stranding its other owners); shared victims degrade to
   ``recompute``, which merely drops references.

   Resident chains can also *migrate* between allocators (cross-client
   replica warming, paper §V-B remote KV retrieval): ``export_chain`` pins a
   source chain for the transfer window, ``import_chain`` materializes it at
   the destination as cached blocks through the same radix-registration
   rules (collision truncation, free-list-only capacity backpressure), and
   ``hot_chains`` enumerates a donor's hottest chains for push-mode warming.
   The coordinator prices the shipped bytes on ``Network`` links.
"""
from __future__ import annotations

import heapq
import itertools
from collections import Counter
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.perfmodel.hardware import CacheTierSpec


def expected_retrieval_latency(size_bytes: float,
                               tiers: Sequence[CacheTierSpec],
                               miss_cost: float) -> float:
    """Paper Eq. 1, evaluated recursively (expected value). Every probed
    tier charges its ``lookup_latency`` unconditionally — the same walk the
    Monte-Carlo ``sample_retrieval_latency`` takes — so the sampled mean
    converges to this expectation on workloads with deep miss chains."""
    if not tiers:
        return miss_cost
    t = tiers[0]
    return (t.lookup_latency + t.hit_rate * (size_bytes / t.bandwidth)
            + (1.0 - t.hit_rate) * expected_retrieval_latency(
                size_bytes, tiers[1:], miss_cost))


def sample_retrieval_latency(size_bytes: float, tiers: Sequence[CacheTierSpec],
                             miss_cost: float, rng: np.random.Generator) -> float:
    """Monte-Carlo variant for latency-CDF studies (paper Fig. 15)."""
    lat = 0.0
    for t in tiers:
        lat += t.lookup_latency
        if rng.random() < t.hit_rate:
            return lat + size_bytes / t.bandwidth
    return lat + miss_cost


def tier_transfer_time(nbytes: float, tier: CacheTierSpec,
                       granularity: str = "full",
                       n_layer_groups: int = 1) -> float:
    """One deterministic traversal of a tier boundary (Eq. 1 hit term).
    Used to price swap-out/swap-in; delegates to the spec so the allocator,
    the analytical model and the retrieval client share one formula.

    ``granularity="layerwise"`` prices a per-layer-group swap pipelined
    against layerwise compute, exactly like the disaggregated KV handoff
    (``Network._exposed``): the wire still carries all ``nbytes``, but the
    EXPOSED stall is one layer group of payload plus one lookup — the other
    groups overlap the consumer's layer-by-layer compute."""
    if granularity == "layerwise":
        return tier.transfer_time(nbytes / max(1, n_layer_groups))
    return tier.transfer_time(nbytes)


# ---------------------------------------------------------------------------
# tier accounting
# ---------------------------------------------------------------------------

DEVICE_TIER = 0   # block-table ``tier`` value for pages resident in HBM


@dataclass
class KVTierState:
    """Mutable byte accounting over one spill level (host DRAM, remote...)."""
    spec: CacheTierSpec
    used: float = 0.0
    peak: float = 0.0

    def has_room(self, nbytes: float) -> bool:
        return self.used + nbytes <= self.spec.capacity

    def reserve(self, nbytes: float):
        self.used += nbytes
        self.peak = max(self.peak, self.used)

    def release(self, nbytes: float):
        self.used = max(0.0, self.used - nbytes)


@dataclass
class BlockTable:
    """Per-request page map: which physical blocks hold this request's KV.
    ``hashes[i]`` (when present) is the radix-registered content hash of
    ``blocks[i]`` — only full, block-aligned prompt-prefix blocks register."""
    rid: object
    blocks: List[int] = field(default_factory=list)
    tokens: int = 0            # KV token slots actually filled
    tier: int = DEVICE_TIER    # DEVICE_TIER, or 1-based index into spill tiers
    hashes: List[int] = field(default_factory=list)

    @property
    def on_device(self) -> bool:
        return self.tier == DEVICE_TIER


# ---------------------------------------------------------------------------
# radix prefix index
# ---------------------------------------------------------------------------

class _RadixNode:
    __slots__ = ("hash", "block", "parent", "children", "is_root")

    def __init__(self, h: int, block: int, parent: Optional["_RadixNode"],
                 is_root: bool = False):
        self.hash = h
        self.block = block
        # direct object links, never hashes: a chain hash can resurface as a
        # *new* node after swap-out/swap-in, and hash-keyed parent accounting
        # would then corrupt the recreated node's child count
        self.parent = parent
        self.children: Dict[int, "_RadixNode"] = {}
        # registered with parent_hash=None, i.e. a chain's first block — the
        # key the fleet-level prefix inverted index tracks. Distinct from
        # ``parent is None``: a node whose parent hash was simply absent at
        # insert (resurfaced interior) is NOT a root.
        self.is_root = is_root


class RadixBlockIndex:
    """Block-granular radix cache: a chain of content hashes (each chained
    over its parent, so equal chains imply equal block-aligned prefixes) maps
    to resident physical blocks. Blocks with refcount 0 stay resident as
    *cached* entries and are evicted leaf-first in LRU order.

    Reclaim is O(log n) amortized: instead of rescanning the cached-LRU head
    for a leaf on every eviction (O(cached²) bulk reclaim), a dedicated
    evictable-leaf heap holds (release-seq, block) candidates. Entries go
    stale lazily — when a cached block gains a registered child, or is
    re-acquired — and are validated at pop; a parent is (re)pushed under its
    original release seq when its last registered child unregisters, so the
    eviction *order* is identical to the old head-scan."""

    def __init__(self):
        self.nodes: Dict[int, _RadixNode] = {}
        self.by_block: Dict[int, int] = {}       # block id -> hash
        self._cached: Dict[int, int] = {}        # rc-0 resident block -> seq
        self._leaf_heap: List[Tuple[int, int]] = []   # (seq, block) candidates
        self._seq = itertools.count()
        # fleet-index hook: called with (hash, added) when a chain-ROOT node
        # registers/unregisters, so a fleet-level hash->clients inverted
        # index can track which clients could serve a prefix hit
        self.on_root_change: Optional[Callable[[int, bool], None]] = None

    # -- lookup ------------------------------------------------------------
    def match(self, chain: Sequence[int]) -> List[int]:
        """Longest resident prefix: physical blocks for the leading hashes."""
        out: List[int] = []
        for h in chain:
            node = self.nodes.get(h)
            if node is None:
                break
            out.append(node.block)
        return out

    # -- registration ------------------------------------------------------
    def insert(self, h: int, block: int, parent_hash: Optional[int]) -> bool:
        """Register a freshly-filled block under its chain hash. A collision
        (the hash resurfacing after a partial unregister) keeps the existing
        entry and leaves the new block private."""
        if h in self.nodes:
            return False
        parent = self.nodes.get(parent_hash) if parent_hash is not None else None
        node = _RadixNode(h, block, parent, is_root=parent_hash is None)
        self.nodes[h] = node
        self.by_block[block] = h
        if parent is not None:
            parent.children[h] = node
        if node.is_root and self.on_root_change is not None:
            self.on_root_change(h, True)
        return True

    def holds_block(self, block: int) -> bool:
        return block in self.by_block

    def unregister(self, block: int):
        """Drop a block's entry (its content is leaving the device). Unlinks
        from the exact parent *object* linked at insert, so a parent hash
        resurfacing under a new node is never touched. A cached parent whose
        last registered child leaves is promoted into the evictable-leaf
        heap under its original release seq."""
        h = self.by_block.pop(block, None)
        if h is None:
            return
        node = self.nodes.pop(h)
        self._cached.pop(block, None)
        if node.is_root and self.on_root_change is not None:
            self.on_root_change(h, False)
        parent = node.parent
        if parent is not None:
            parent.children.pop(h, None)
            if not parent.children:
                seq = self._cached.get(parent.block)
                if seq is not None:
                    heapq.heappush(self._leaf_heap, (seq, parent.block))

    def unregister_subtree(self, block: int) -> List[int]:
        """Unregister a block's node *and every registered descendant* (the
        swap-out path: when a chain's interior leaves the device, cached
        descendants must not survive as orphans). Returns the descendant
        blocks that were cached (refcount 0) — they lost their only reason to
        stay resident and the caller must return them to the free list.
        Non-cached descendants belong to the departing table itself (any
        other live owner would hold the whole prefix, contradicting the
        caller's refcount-1 precondition) and are merely unregistered."""
        h = self.by_block.get(block)
        if h is None:
            return []
        freed: List[int] = []
        stack = list(self.nodes[h].children.values())
        while stack:
            node = stack.pop()
            stack.extend(node.children.values())
            del self.nodes[node.hash]
            del self.by_block[node.block]
            if node.block in self._cached:
                del self._cached[node.block]
                freed.append(node.block)
        self.unregister(block)
        return freed

    # -- refcount transitions ---------------------------------------------
    def acquire(self, block: int):
        """Block went refcount 0 -> 1: it is live again, not evictable."""
        self._cached.pop(block, None)      # heap entry goes stale

    def release(self, block: int):
        """Registered block went refcount 1 -> 0: keep resident as cached."""
        seq = next(self._seq)              # (re)release = most recently used
        self._cached[block] = seq
        if not self.nodes[self.by_block[block]].children:
            heapq.heappush(self._leaf_heap, (seq, block))

    def peek_seq(self, block: int) -> Optional[int]:
        """Current cached-LRU seq of a block (None when live/unregistered)."""
        return self._cached.get(block)

    def restore_seq(self, block: int, seq: int):
        """Roll back a transient ``acquire`` (failed admission): re-cache the
        block under its ORIGINAL recency seq, so a stream of rejected
        admissions cannot keep a prefix artificially hot and perturb the
        eviction order vs. a trace where they never arrived. Re-pushing the
        (seq, block) heap entry may duplicate one already present — stale
        duplicates are skipped at pop, so this is harmless."""
        self._cached[block] = seq
        if not self.nodes[self.by_block[block]].children:
            heapq.heappush(self._leaf_heap, (seq, block))

    # -- eviction ----------------------------------------------------------
    def cached_count(self) -> int:
        return len(self._cached)

    def evict_one(self) -> Optional[int]:
        """Evict the LRU cached *leaf* (a node with registered children may
        not go before them, so chains never get holes). Returns the freed
        physical block id, or None when nothing is evictable. O(log n)
        amortized: pops stale heap entries (re-acquired, re-released under a
        newer seq, or currently interior) until a live leaf surfaces."""
        while self._leaf_heap:
            seq, block = heapq.heappop(self._leaf_heap)
            if self._cached.get(block) != seq:
                continue                   # re-acquired or re-released since
            if self.nodes[self.by_block[block]].children:
                continue                   # gained a child; repushed on unlink
            self.unregister(block)
            return block
        return None


# ---------------------------------------------------------------------------
# paged allocator
# ---------------------------------------------------------------------------

class PagedKVAllocator:
    """Fixed-size-block KV allocator over an HBM pool with spill tiers and a
    shared-prefix radix cache.

    All admission/growth/release in ``LLMScheduler`` goes through this; the
    free list + refcounts are the single source of truth for device KV
    occupancy. Physical blocks are refcounted so block tables may alias:
    prefix-sharing admissions and copy-on-write ``fork``s reference the same
    pages instead of duplicating them.
    """

    def __init__(self, capacity_bytes: float, bytes_per_token: float,
                 block_tokens: int = 32,
                 swap_tiers: Sequence[CacheTierSpec] = ()):
        assert block_tokens >= 1
        self.block_tokens = int(block_tokens)
        self.bytes_per_token = float(bytes_per_token)
        self.block_bytes = self.block_tokens * self.bytes_per_token
        self.num_blocks = max(1, int(capacity_bytes // max(self.block_bytes, 1.0)))
        self.capacity = self.num_blocks * self.block_bytes
        self._free: List[int] = list(range(self.num_blocks - 1, -1, -1))
        self.tables: Dict[object, BlockTable] = {}
        self.tiers: List[KVTierState] = [KVTierState(s) for s in swap_tiers]
        self.refcount: Dict[int, int] = {}
        self.radix = RadixBlockIndex()
        # overcommit escape hatch: requests larger than the whole pool get
        # "overflow" blocks with ids >= num_blocks (counted, never recycled
        # into the free list) so the simulation stays live and the pressure
        # is visible as utilization > 1 instead of a hard failure
        self._next_overflow_id = self.num_blocks
        self._overflow_live = 0
        self.overcommitted_blocks = 0  # cumulative
        # counters (surfaced via stats() -> MetricsCollector)
        self.page_faults = 0           # growth attempts that found no free block
        self.admission_failures = 0
        self.evictions = 0             # swap-out events
        self.swap_ins = 0
        self.swap_bytes_out = 0.0
        self.swap_bytes_in = 0.0
        self.recompute_drops = 0
        self.peak_blocks = 0
        # prefix-sharing counters
        self.prefix_hit_tokens = 0     # prompt tokens served from the radix cache
        self.prefix_hit_blocks = 0
        self.cow_forks = 0             # fork() events (branch table splits)
        self.cow_copied_blocks = 0     # partial tail blocks copied on write
        self.radix_evictions = 0       # cached blocks reclaimed for allocation
        self.block_refs_total = 0      # logical block references ever created
        self.blocks_allocated_total = 0  # physical blocks ever taken
        self._n_shared = 0             # blocks with refcount > 1, now
        self.shared_blocks_peak = 0
        self.prefix_tokens_seen = 0    # prefix-eligible prompt tokens admitted
        # cross-client prefix migration (export pins resident source chains
        # for the transfer window; import materializes them as cached blocks)
        self._exports: Dict[int, List[int]] = {}  # handle -> pinned blocks
        self._export_seq = itertools.count()
        self._migrated_in: set = set()  # resident blocks created by import
        self.migrated_out_blocks = 0
        self.migrated_in_blocks = 0
        self.migration_refused_blocks = 0  # import backpressure + collisions
        self.migration_hit_tokens = 0  # prompt tokens served off migrated pages

    # -- capacity queries ---------------------------------------------------
    @property
    def free_blocks(self) -> int:
        return len(self._free)

    @property
    def cached_blocks(self) -> int:
        """Resident refcount-0 blocks retained by the radix cache."""
        return self.radix.cached_count()

    @property
    def available_blocks(self) -> int:
        """Immediately allocatable: free list + evictable cached blocks."""
        return len(self._free) + self.radix.cached_count()

    @property
    def used_blocks(self) -> int:
        """Blocks referenced by at least one live table (cached excluded)."""
        return (self.num_blocks - len(self._free) - self.radix.cached_count()
                + self._overflow_live)

    @property
    def used(self) -> float:
        """Device bytes held (block-granular, fragmentation included)."""
        return self.used_blocks * self.block_bytes

    def blocks_for_tokens(self, tokens: int) -> int:
        return max(0, -(-int(tokens) // self.block_tokens))

    def can_allocate(self, tokens: int) -> bool:
        return self.blocks_for_tokens(tokens) <= self.available_blocks

    def fragmentation_bytes(self) -> float:
        """Allocated-but-unfilled token slots across resident block tables."""
        slack = 0.0
        for t in self.tables.values():
            if t.on_device:
                slack += len(t.blocks) * self.block_tokens - t.tokens
        return slack * self.bytes_per_token

    def _return_free(self, b: int):
        """Single exit back to the free list: a recycled block id sheds its
        migrated-in identity so a later unrelated occupant cannot count
        migration hits."""
        self._migrated_in.discard(b)
        self._free.append(b)

    # -- refcount plumbing ---------------------------------------------------
    def _incref(self, b: int):
        rc = self.refcount.get(b, 0) + 1
        self.refcount[b] = rc
        self.block_refs_total += 1
        if rc == 1:
            self.radix.acquire(b)          # cached -> live
        elif rc == 2:
            self._n_shared += 1
            self.shared_blocks_peak = max(self.shared_blocks_peak,
                                          self._n_shared)

    def _decref(self, b: int) -> bool:
        """Drop one reference. Returns True when the block returned to the
        free list (registered blocks stay resident as cached instead)."""
        rc = self.refcount[b] - 1
        if rc > 0:
            self.refcount[b] = rc
            if rc == 1:
                self._n_shared -= 1
            return False
        del self.refcount[b]
        if b >= self.num_blocks:           # overflow ids retire, never recycle
            self._overflow_live -= 1
            return False
        if self.radix.holds_block(b):
            self.radix.release(b)          # live -> cached, evictable LRU
            return False
        self._return_free(b)
        return True

    def _unref_matched(self, b: int, orig_seq: Optional[int]):
        """Failed-admission rollback of one matched-block ``_incref``. A
        block that was *cached* before the attempt returns to the cache under
        its ORIGINAL recency seq (``restore_seq``): a rejected admission must
        not refresh LRU order. Blocks that were live keep the plain decref."""
        if orig_seq is not None and self.refcount.get(b) == 1:
            del self.refcount[b]
            self.radix.restore_seq(b, orig_seq)
            return
        self._decref(b)

    # -- allocation / growth / release --------------------------------------
    def _reclaim(self, n: int):
        """Evict cached radix blocks (LRU, leaf-first) until the free list
        holds ``n`` blocks or nothing cached remains evictable."""
        while len(self._free) < n:
            b = self.radix.evict_one()
            if b is None:
                break
            self._return_free(b)
            self.radix_evictions += 1

    def _take(self, n: int, force: bool = False) -> List[int]:
        self._reclaim(n)
        real = min(n, len(self._free))
        got = [self._free.pop() for _ in range(real)]
        if n > real:
            assert force
            got.extend(range(self._next_overflow_id,
                             self._next_overflow_id + n - real))
            self._next_overflow_id += n - real
            self._overflow_live += n - real
            self.overcommitted_blocks += n - real
        for b in got:
            self._incref(b)
        self.blocks_allocated_total += len(got)
        self.peak_blocks = max(self.peak_blocks, self.used_blocks)
        return got

    def peek_prefix_tokens(self, prefix_hashes: Sequence[int]) -> int:
        """Tokens of the chain currently resident (read-only lookup)."""
        if not prefix_hashes:
            return 0
        return len(self.radix.match(prefix_hashes)) * self.block_tokens

    def allocate(self, rid, tokens: int, prefix_hashes: Sequence[int] = (),
                 force: bool = False, count_hits: bool = True) -> bool:
        """Whole-context admission (prefill): reserve ceil(tokens/B) blocks.
        Blocks whose chain hash is resident in the radix cache are *shared*
        (refcount bump, no new page); the rest come off the free list and the
        full prompt-prefix ones register for future admissions to hit.
        ``force`` overcommits instead of failing (requests bigger than the
        entire pool — the caller decides, normal backpressure stays intact).
        ``count_hits=False`` still dedups pages but leaves the prefix-hit
        counters alone (disaggregated decode admission: the same tokens were
        already counted as hits at the prefill client, and the decode-side
        saving is reported as ``kv_transfer_dedup_bytes`` instead).

        Modeling note: blocks register at admission, before the prefill that
        fills them completes, so an immediately-following same-prefix request
        hits in-flight KV (SGLang-style cache-aware scheduling). Real radix
        caches that gate on computed blocks would hit one step later."""
        assert rid not in self.tables, f"double allocation for rid={rid}"
        need_total = self.blocks_for_tokens(tokens)
        matched: List[int] = []
        if prefix_hashes:
            matched = self.radix.match(prefix_hashes)[:need_total]
        need_new = need_total - len(matched)
        # revive matched blocks first: cached ones leave the evictable pool,
        # so the availability check must see the post-match state
        shared_peak0 = self.shared_blocks_peak
        orig_seqs = {b: s for b in matched
                     for s in (self.radix.peek_seq(b),) if s is not None}
        for b in matched:
            self._incref(b)
        if need_new > self.available_blocks and not force:
            for b in matched:
                self._unref_matched(b, orig_seqs.get(b))
            # admission never happened: no logical refs, no sharing peak,
            # and previously-cached blocks keep their original LRU seq
            self.block_refs_total -= len(matched)
            self.shared_blocks_peak = shared_peak0
            self.admission_failures += 1
            return False
        blocks = matched + self._take(need_new, force)
        t = BlockTable(rid, blocks, int(tokens))
        # register the newly-filled full prefix blocks so later admissions hit
        n_reg = min(len(prefix_hashes), need_total)
        for i in range(len(matched), n_reg):
            if blocks[i] >= self.num_blocks:   # never cache overflow pages
                n_reg = i
                break
            if not self.radix.insert(prefix_hashes[i], blocks[i],
                                     prefix_hashes[i - 1] if i else None):
                n_reg = i                      # collision: chain ends here
                break
        t.hashes = list(prefix_hashes[:n_reg])
        self.tables[rid] = t
        if prefix_hashes and count_hits:
            # prefix-eligible tokens this admission presented: the hit-rate
            # denominator (kv_prefix_hit_tokens / kv_prefix_tokens_seen)
            self.prefix_tokens_seen += min(int(tokens),
                                           len(prefix_hashes) * self.block_tokens)
        if matched and count_hits:
            self.prefix_hit_blocks += len(matched)
            self.prefix_hit_tokens += min(int(tokens),
                                          len(matched) * self.block_tokens)
            mig = sum(1 for b in matched if b in self._migrated_in)
            if mig:
                self.migration_hit_tokens += min(int(tokens),
                                                 mig * self.block_tokens)
        self.peak_blocks = max(self.peak_blocks, self.used_blocks)
        return True

    def fork(self, parent_rid, child_rid) -> None:
        """Copy-on-write fork: the child shares every one of the parent's
        pages (refcount bump, zero new blocks). Divergent decode writes copy
        only the partial tail block — see ``grow_request``."""
        pt = self.tables[parent_rid]
        assert pt.on_device, f"forking swapped-out rid={parent_rid}"
        assert child_rid not in self.tables
        for b in pt.blocks:
            self._incref(b)
        ct = BlockTable(child_rid, list(pt.blocks), pt.tokens,
                        hashes=list(pt.hashes))
        self.tables[child_rid] = ct
        self.cow_forks += 1

    def _append_need(self, t: BlockTable, n: int) -> Tuple[int, int]:
        """(new blocks, COW copies) required to append ``n`` token slots."""
        need = self.blocks_for_tokens(t.tokens + n) - len(t.blocks)
        if need < 0:
            need = 0
        cow = 1 if (t.blocks
                    and self.refcount.get(t.blocks[-1], 1) > 1
                    and len(t.blocks) * self.block_tokens > t.tokens) else 0
        return need, cow

    def grow_request(self, rids: Sequence, n: int = 1,
                     force: bool = False) -> bool:
        """Decode growth across one request's tables (the main table plus any
        forked branch tables), appending ``n`` token slots to each. Writing
        into a *shared* partial tail block first copies it (copy-on-write) so
        siblings keep the pre-divergence content. Capacity is checked for the
        whole group up front; on exhaustion nothing is touched, a page fault
        is counted, and the caller resolves it through its preemption policy,
        falling back to ``force`` when no victim exists."""
        tabs = [self.tables[r] for r in rids]
        for t in tabs:
            assert t.on_device, f"growing swapped-out rid={t.rid}"
        if len(tabs) == 1:
            # single-table fast path (the overwhelmingly common decode case):
            # no sibling COW accounting, no Counter
            t = tabs[0]
            need, cow = self._append_need(t, n)
            if not cow:
                if need > self.available_blocks and not force:
                    self.page_faults += 1
                    return False
                if need:
                    t.blocks.extend(self._take(need, force))
                t.tokens += n
                return True
        total = sum(self._append_need(t, n)[0] for t in tabs)
        # COW copies: siblings in this group sharing one tail block need
        # m - 1 copies (the last keeps the original) — m only if someone
        # outside the group also references it
        tails: Counter = Counter(t.blocks[-1] for t in tabs
                                 if self._append_need(t, n)[1])
        for b, m in tails.items():
            total += m if self.refcount[b] > m else m - 1
        if total > self.available_blocks and not force:
            self.page_faults += 1
            return False
        for t in tabs:
            # re-derive per-table: an earlier COW in this group may have
            # dropped the shared tail's refcount to 1 (last sibling keeps it)
            need, cow = self._append_need(t, n)
            if cow:
                old = t.blocks[-1]
                (new,) = self._take(1, force)
                t.blocks[-1] = new
                self._decref(old)
                self.cow_copied_blocks += 1
            if need > 0:
                t.blocks.extend(self._take(need, force))
            t.tokens += n
        return True

    def append_tokens(self, rid, n: int = 1, force: bool = False) -> bool:
        """Decode growth for a single table: extend by ``n`` token slots,
        faulting in new blocks as needed. Returns False (and counts a page
        fault) on exhaustion."""
        return self.grow_request([rid], n, force)

    # -- fast-forward capacity planning --------------------------------------
    def shared_partial_tail(self, rid) -> bool:
        """True when the table's last block is shared *and* partially filled,
        so the next append would copy-on-write it."""
        t = self.tables[rid]
        return bool(t.blocks) and self.refcount.get(t.blocks[-1], 1) > 1 \
            and len(t.blocks) * self.block_tokens > t.tokens

    def max_growth_steps(self, groups: Sequence[Tuple[Sequence, int]],
                         k_max: int) -> int:
        """Largest ``K <= k_max`` such that ``K`` growth steps — each step
        appending ``g`` token slots to every table in ``rids`` for every
        ``(rids, g)`` group — fit in the free list alone: no radix eviction,
        no preemption, no overcommit. The decode fast-forward window uses
        this as its block-boundary-pressure bound; because nothing but the
        free list is touched, committing the window in bulk is counter-exact
        with committing it one step at a time. Callers must have ruled out
        copy-on-write tails (``shared_partial_tail``)."""
        free = len(self._free)
        B = self.block_tokens
        slacks = [(len(t.blocks) * B - t.tokens, g)
                  for rids, g in groups for t in (self.tables[r] for r in rids)]

        def need(k: int) -> int:
            total = 0
            for slack, g in slacks:
                grow = k * g - slack
                if grow > 0:
                    total += -(-grow // B)
                    if total > free:
                        break
            return total

        if need(k_max) <= free:
            return k_max
        lo, hi = 0, k_max          # invariant: need(lo) <= free < need(hi)
        while hi - lo > 1:
            mid = (lo + hi) // 2
            if need(mid) <= free:
                lo = mid
            else:
                hi = mid
        return lo

    def free(self, rid) -> int:
        """Release every reference of a request (completion/drop). Returns
        the number of device blocks returned to the free list; shared blocks
        survive under their other owners and radix-registered blocks stay
        resident as evictable cache."""
        t = self.tables.pop(rid, None)
        if t is None:
            return 0
        if t.on_device:
            freed = 0
            # deepest-first so cached chains age leaf-before-parent in LRU
            for b in reversed(t.blocks):
                if self._decref(b):
                    freed += 1
            return freed
        self.tiers[t.tier - 1].release(len(t.blocks) * self.block_bytes)
        return 0

    def holds(self, rid) -> bool:
        return rid in self.tables

    # -- preemption: swap ----------------------------------------------------
    def swap_out(self, rid, granularity: str = "full",
                 n_layer_groups: int = 1) -> Optional[Tuple[float, float]]:
        """Offload a resident request's pages to the first spill tier with
        room. Returns (bytes_moved, transfer_time) or None when no tier can
        take them (caller falls back to recompute) — or when any page is
        shared (refcount > 1): a shared page cannot move without stranding
        its other owners, so shared victims degrade to recompute.

        ``granularity="layerwise"`` moves the table one layer group at a
        time, overlapped with compute (``SchedulerLimits.swap_granularity``)
        — bytes_moved is unchanged, transfer_time is the exposed stall of
        ~one of ``n_layer_groups`` groups, the same §III-B2 pricing the
        disaggregated handoff uses."""
        t = self.tables[rid]
        assert t.on_device
        if len(t.blocks) > self.num_blocks:
            return None   # could never swap back in; caller recomputes
        if any(self.refcount.get(b, 1) > 1 for b in t.blocks):
            return None   # refcount-1 pages only (radix/fork sharing intact)
        nbytes = len(t.blocks) * self.block_bytes
        for i, tier in enumerate(self.tiers, start=1):
            if tier.has_room(nbytes):
                tier.reserve(nbytes)
                for b in t.blocks:
                    # content leaves the device; cascade so cached descendant
                    # chains cannot survive as orphans under a parent hash
                    # that may later resurface as a different node
                    for fb in self.radix.unregister_subtree(b):
                        self._return_free(fb)
                        self.radix_evictions += 1
                    self._decref(b)
                t.blocks = [-1] * len(t.blocks)   # physical ids are tier-side
                t.tier = i                     # hashes kept: swap_in restores
                self.evictions += 1
                self.swap_bytes_out += nbytes
                return nbytes, tier_transfer_time(nbytes, tier.spec,
                                                  granularity, n_layer_groups)
        return None

    def swap_in(self, rid, granularity: str = "full",
                n_layer_groups: int = 1) -> Optional[Tuple[float, float]]:
        """Bring a swapped request's pages back to HBM. Returns
        (bytes_moved, transfer_time) or None when HBM lacks free blocks.
        ``granularity`` prices the stall exactly like ``swap_out``."""
        t = self.tables[rid]
        assert not t.on_device
        n = len(t.blocks)
        if n > self.available_blocks:
            return None
        tier = self.tiers[t.tier - 1]
        nbytes = n * self.block_bytes
        tier.release(nbytes)
        t.blocks = self._take(n)
        # swap-in resumes existing logical references — it must not dilute
        # dedup_ratio (block_refs_total / blocks_allocated_total) under
        # preemption churn, so back out _take's counter bumps
        self.block_refs_total -= n
        self.blocks_allocated_total -= n
        t.tier = DEVICE_TIER
        # the prefix content is back on device: re-register its chain so
        # future admissions hit again (a collision — the chain resurfaced
        # under another block while we were away — truncates ours there)
        for i, h in enumerate(t.hashes):
            if not self.radix.insert(h, t.blocks[i],
                                     t.hashes[i - 1] if i else None):
                t.hashes = t.hashes[:i]
                break
        self.swap_ins += 1
        self.swap_bytes_in += nbytes
        return nbytes, tier_transfer_time(nbytes, tier.spec,
                                          granularity, n_layer_groups)

    # -- cross-client prefix migration ---------------------------------------
    def export_chain(self, prefix_hashes: Sequence[int], skip: int = 0,
                     max_blocks: Optional[int] = None
                     ) -> Optional[Tuple[int, int, float]]:
        """Pin the resident prefix chain for an outbound migration. The
        pinned blocks (chain positions ``skip`` onward — the part the
        destination does not already hold) take one extra reference for the
        transfer window, so neither radix eviction nor swap-out can move
        their content off-device while it is on the wire. Returns
        ``(handle, n_resident, nbytes)`` — the caller ships
        ``prefix_hashes[:n_resident]`` and ``nbytes`` of KV pages, then
        releases the pin with ``release_export(handle)`` when the transfer
        lands. None when nothing past ``skip`` is resident."""
        matched = self.radix.match(prefix_hashes)
        if max_blocks is not None:
            matched = matched[:skip + max_blocks]
        ship = matched[skip:]
        if not ship:
            return None
        for b in ship:
            self._incref(b)
        # a transfer pin is not a logical reference (dedup_ratio stays
        # comparable with migration off), but it DOES count as sharing for
        # the window: a pinned live page genuinely has two holders, and the
        # refcount>1 rule is exactly what keeps swap_out off it mid-transfer
        self.block_refs_total -= len(ship)
        handle = next(self._export_seq)
        self._exports[handle] = list(ship)
        self.migrated_out_blocks += len(ship)
        return handle, len(matched), len(ship) * self.block_bytes

    def release_export(self, handle: int):
        """Unpin an outbound migration's source pages (transfer landed or
        aborted). Previously-cached blocks re-enter the evictable LRU as
        most-recently-used — the transfer just read them. A handle already
        discarded by ``discard_exports`` (source failure) is a no-op."""
        for b in self._exports.pop(handle, ()):
            self._decref(b)

    def discard_exports(self):
        """Device KV died (client failure/teardown): drop every in-flight
        outbound pin so the pinned content cannot outlive the failure as
        resident cache. Callers follow with ``clear_cache`` — the unpinned
        blocks land there as cached and are purged with everything else;
        the in-flight transfer itself still completes at the destination
        (the bytes were already on the wire)."""
        for handle in list(self._exports):
            self.release_export(handle)

    def import_chain(self, prefix_hashes: Sequence[int]) -> Tuple[int, int]:
        """Materialize a migrated chain as resident *cached* (refcount-0)
        radix blocks, extending whatever prefix of it is already resident.
        Future same-prefix admissions map these pages exactly like locally
        produced ones. Two hard rules:

        * **capacity backpressure** — imports draw on the free list alone:
          a migrated copy never evicts resident cache, preempts a live
          table or overcommits. Blocks that do not fit are refused (the
          leading — most widely shared — part of the chain lands first).
        * **collision truncation** — a chain hash already registered under
          another block ends the import there, exactly like admission-time
          registration (``allocate``) and ``swap_in`` re-registration.

        Returns ``(imported, refused)`` block counts. Imported blocks are
        tracked so later admission hits on them surface as
        ``migration_hit_tokens`` (the fetch actually saved recompute);
        ``blocks_allocated_total`` is deliberately NOT bumped — a migrated
        page is a physical copy of existing content, not logical demand, so
        dedup_ratio stays comparable with migration on or off."""
        matched = self.radix.match(prefix_hashes)
        j = len(matched)
        imported = 0
        for i in range(j, len(prefix_hashes)):
            if not self._free:
                break                      # backpressure: free blocks only
            b = self._free.pop()
            if not self.radix.insert(prefix_hashes[i], b,
                                     prefix_hashes[i - 1] if i else None):
                self._return_free(b)       # collision: chain truncates here
                break
            self.radix.release(b)          # resident as cached, MRU
            self._migrated_in.add(b)
            imported += 1
        refused = max(0, len(prefix_hashes) - j - imported)
        self.migrated_in_blocks += imported
        self.migration_refused_blocks += refused
        return imported, refused

    def hot_chains(self, max_blocks: int) -> List[List[int]]:
        """Root-to-leaf hash chains over the registered radix content,
        hottest leaf first (live leaves, then cached leaves by descending
        recency), truncated to a total budget of ``max_blocks`` distinct
        blocks — the donor side of push-mode replica warming. Chains may
        share prefixes; the budget counts each block once, and a chain that
        overflows it is cut to a (still valid) prefix."""
        idx = self.radix
        leaves = [n for n in idx.nodes.values() if not n.children]

        def hotness(n: _RadixNode):
            s = idx._cached.get(n.block)
            return (0, 0) if s is None else (1, -s)

        leaves.sort(key=hotness)
        chains: List[List[int]] = []
        seen: set = set()
        budget = max_blocks
        for leaf in leaves:
            if budget <= 0:
                break
            chain: List[int] = []
            node: Optional[_RadixNode] = leaf
            while node is not None:
                chain.append(node.hash)
                node = node.parent
            chain.reverse()
            # unseen hashes form a suffix (shared parts are prefixes)
            new = sum(1 for h in chain if h not in seen)
            if new == 0:
                continue
            if new > budget:
                chain = chain[:len(chain) - (new - budget)]
                new = sum(1 for h in chain if h not in seen)
                if new == 0:
                    continue
            seen.update(chain)
            budget -= new
            chains.append(chain)
        return chains

    def clear_cache(self) -> int:
        """Purge every cached (refcount-0) radix block back to the free list
        — client failure/teardown semantics, where device KV is lost."""
        n = 0
        while True:
            b = self.radix.evict_one()
            if b is None:
                break
            self._return_free(b)
            n += 1
        return n

    # -- preemption: recompute ----------------------------------------------
    def drop(self, rid) -> int:
        """Discard a request's references entirely (recompute preemption)."""
        released = self.free(rid)
        self.recompute_drops += 1
        return released

    # -- reporting -----------------------------------------------------------
    def check_invariants(self):
        """Refcounts must equal the number of tables referencing each block;
        free list, live blocks and cached radix blocks must partition
        [0, num_blocks); live overflow ids must match the overflow counter."""
        expect: Counter = Counter()
        for t in self.tables.values():
            if t.on_device:
                expect.update(t.blocks)
        for pinned in self._exports.values():   # outbound-migration pins
            expect.update(pinned)
        assert dict(expect) == self.refcount, "refcount drift"
        live = sorted(b for b in expect if b < self.num_blocks)
        cached = sorted(self.radix._cached)
        assert not set(live) & set(cached), "cached block is live"
        assert sorted(self._free + live + cached) == list(range(self.num_blocks)), \
            "block leak or double allocation"
        overflow = sum(1 for b in expect if b >= self.num_blocks)
        assert overflow == self._overflow_live, "overflow accounting drift"
        for b in self.radix.by_block:
            assert b < self.num_blocks and (b in expect or b in self.radix._cached), \
                "radix entry points at a non-resident block"
        assert self._migrated_in <= set(self.radix.by_block), \
            "migrated-in set holds a non-resident block"
        for h, node in self.radix.nodes.items():
            for ch, cnode in node.children.items():
                assert self.radix.nodes.get(ch) is cnode, \
                    "child link to an unregistered node"
            if node.parent is not None:
                # cascade-unregister guarantees no orphans: a registered
                # node's parent is the *same object* still registered
                assert self.radix.nodes.get(node.parent.hash) is node.parent, \
                    "orphaned node (parent left the index)"
                assert node.parent.children.get(h) is node, \
                    "registered parent lost its child link"
        shared = sum(1 for rc in self.refcount.values() if rc > 1)
        assert shared == self._n_shared, "shared-block counter drift"

    def stats(self) -> Dict[str, float]:
        return {
            "num_blocks": self.num_blocks,
            "used_blocks": self.used_blocks,
            "free_blocks": self.free_blocks,
            "cached_blocks": self.cached_blocks,
            "peak_blocks": self.peak_blocks,
            "block_tokens": self.block_tokens,
            "utilization": self.used_blocks / max(1, self.num_blocks),
            "fragmentation_bytes": self.fragmentation_bytes(),
            "page_faults": self.page_faults,
            "admission_failures": self.admission_failures,
            "evictions": self.evictions,
            "swap_ins": self.swap_ins,
            "swap_bytes_out": self.swap_bytes_out,
            "swap_bytes_in": self.swap_bytes_in,
            "recompute_drops": self.recompute_drops,
            "overflow_blocks": self._overflow_live,
            "overcommitted_blocks": self.overcommitted_blocks,
            "prefix_hit_tokens": self.prefix_hit_tokens,
            "prefix_hit_blocks": self.prefix_hit_blocks,
            "prefix_tokens_seen": self.prefix_tokens_seen,
            "migrated_out_blocks": self.migrated_out_blocks,
            "migrated_in_blocks": self.migrated_in_blocks,
            "migration_refused_blocks": self.migration_refused_blocks,
            "migration_hit_tokens": self.migration_hit_tokens,
            "cow_forks": self.cow_forks,
            "cow_copied_blocks": self.cow_copied_blocks,
            "radix_evictions": self.radix_evictions,
            "shared_blocks": self.shared_blocks_peak,
            "block_refs_total": self.block_refs_total,
            "blocks_allocated_total": self.blocks_allocated_total,
            "dedup_ratio": (self.block_refs_total
                            / max(1, self.blocks_allocated_total)),
            "tier_used_bytes": {t.spec.name: t.used for t in self.tiers},
        }
