"""Paged, tiered KV-cache subsystem (paper §III-D admission control +
§III-E3 multi-level retrieval, Eq. 1).

Two layers live here:

1. **Retrieval pricing (Eq. 1).** ``expected_retrieval_latency`` /
   ``sample_retrieval_latency`` evaluate the paper's recursive cache-lookup
   model over a ``CacheTierSpec`` chain:

       f(KV, C_n) = Hit_n * (T_lookup_n + Size_KV / BW_n)
                  + (1 - Hit_n) * f(KV, C_{n+1})

   A miss below the last level falls back to ``miss_cost`` — typically
   prefill recomputation (priced by the analytical model) or a DCN fetch.

2. **On-device allocation (``PagedKVAllocator``).** The same tier specs that
   parameterize Eq. 1 back the on-device allocator's spill hierarchy, so the
   analytical model and the discrete-event scheduler agree on bandwidths:

   * HBM is carved into fixed-size *blocks* of ``block_tokens`` KV slots;
     each request owns a *block table* (ordered list of physical block ids).
     Admission reserves whole blocks; decode growth faults in one block at a
     time; release returns blocks to a free list — O(1) each, no compaction.
   * When decode growth faults with an empty free list, a *preemption policy*
     makes room:
       - ``swap``      — the victim's pages move to the next tier down
                         (host DRAM → remote). The traffic is priced with the
                         tier term of Eq. 1 (``T_lookup + bytes / BW``) and,
                         at the coordinator, occupies ``Network`` links.
       - ``recompute`` — the victim's pages are dropped and its prefill
                         re-enqueued; cost resurfaces as recomputed prefill
                         FLOPs instead of wire bytes.
   * Internal fragmentation (allocated-but-unfilled token slots in each
     request's last block) is tracked and exported through ``stats()`` so
     routers can balance on real, fragmentation-aware KV pressure.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.perfmodel.hardware import CacheTierSpec


def expected_retrieval_latency(size_bytes: float,
                               tiers: Sequence[CacheTierSpec],
                               miss_cost: float) -> float:
    """Paper Eq. 1, evaluated recursively (expected value)."""
    if not tiers:
        return miss_cost
    t = tiers[0]
    hit_time = t.lookup_latency + size_bytes / t.bandwidth
    return t.hit_rate * hit_time + (1.0 - t.hit_rate) * expected_retrieval_latency(
        size_bytes, tiers[1:], miss_cost)


def sample_retrieval_latency(size_bytes: float, tiers: Sequence[CacheTierSpec],
                             miss_cost: float, rng: np.random.Generator) -> float:
    """Monte-Carlo variant for latency-CDF studies (paper Fig. 15)."""
    lat = 0.0
    for t in tiers:
        lat += t.lookup_latency
        if rng.random() < t.hit_rate:
            return lat + size_bytes / t.bandwidth
    return lat + miss_cost


def tier_transfer_time(nbytes: float, tier: CacheTierSpec) -> float:
    """One deterministic traversal of a tier boundary (Eq. 1 hit term).
    Used to price swap-out/swap-in; delegates to the spec so the allocator,
    the analytical model and the retrieval client share one formula."""
    return tier.transfer_time(nbytes)


# ---------------------------------------------------------------------------
# tier accounting
# ---------------------------------------------------------------------------

DEVICE_TIER = 0   # block-table ``tier`` value for pages resident in HBM


@dataclass
class KVTierState:
    """Mutable byte accounting over one spill level (host DRAM, remote...)."""
    spec: CacheTierSpec
    used: float = 0.0
    peak: float = 0.0

    def has_room(self, nbytes: float) -> bool:
        return self.used + nbytes <= self.spec.capacity

    def reserve(self, nbytes: float):
        self.used += nbytes
        self.peak = max(self.peak, self.used)

    def release(self, nbytes: float):
        self.used = max(0.0, self.used - nbytes)


@dataclass
class BlockTable:
    """Per-request page map: which physical blocks hold this request's KV."""
    rid: int
    blocks: List[int] = field(default_factory=list)
    tokens: int = 0            # KV token slots actually filled
    tier: int = DEVICE_TIER    # DEVICE_TIER, or 1-based index into spill tiers

    @property
    def on_device(self) -> bool:
        return self.tier == DEVICE_TIER


# ---------------------------------------------------------------------------
# paged allocator
# ---------------------------------------------------------------------------

class PagedKVAllocator:
    """Fixed-size-block KV allocator over an HBM pool with spill tiers.

    All admission/growth/release in ``LLMScheduler`` goes through this; the
    free list is the single source of truth for device KV occupancy.
    """

    def __init__(self, capacity_bytes: float, bytes_per_token: float,
                 block_tokens: int = 32,
                 swap_tiers: Sequence[CacheTierSpec] = ()):
        assert block_tokens >= 1
        self.block_tokens = int(block_tokens)
        self.bytes_per_token = float(bytes_per_token)
        self.block_bytes = self.block_tokens * self.bytes_per_token
        self.num_blocks = max(1, int(capacity_bytes // max(self.block_bytes, 1.0)))
        self.capacity = self.num_blocks * self.block_bytes
        self._free: List[int] = list(range(self.num_blocks - 1, -1, -1))
        self.tables: Dict[int, BlockTable] = {}
        self.tiers: List[KVTierState] = [KVTierState(s) for s in swap_tiers]
        # overcommit escape hatch: requests larger than the whole pool get
        # "overflow" blocks with ids >= num_blocks (counted, never recycled
        # into the free list) so the simulation stays live and the pressure
        # is visible as utilization > 1 instead of a hard failure
        self._next_overflow_id = self.num_blocks
        self._overflow_live = 0
        self.overcommitted_blocks = 0  # cumulative
        # counters (surfaced via stats() -> MetricsCollector)
        self.page_faults = 0           # growth attempts that found no free block
        self.admission_failures = 0
        self.evictions = 0             # swap-out events
        self.swap_ins = 0
        self.swap_bytes_out = 0.0
        self.swap_bytes_in = 0.0
        self.recompute_drops = 0
        self.peak_blocks = 0

    # -- capacity queries ---------------------------------------------------
    @property
    def free_blocks(self) -> int:
        return len(self._free)

    @property
    def used_blocks(self) -> int:
        return self.num_blocks - len(self._free) + self._overflow_live

    @property
    def used(self) -> float:
        """Device bytes held (block-granular, fragmentation included)."""
        return self.used_blocks * self.block_bytes

    def blocks_for_tokens(self, tokens: int) -> int:
        return max(0, -(-int(tokens) // self.block_tokens))

    def can_allocate(self, tokens: int) -> bool:
        return self.blocks_for_tokens(tokens) <= len(self._free)

    def fragmentation_bytes(self) -> float:
        """Allocated-but-unfilled token slots across resident block tables."""
        slack = 0.0
        for t in self.tables.values():
            if t.on_device:
                slack += len(t.blocks) * self.block_tokens - t.tokens
        return slack * self.bytes_per_token

    # -- allocation / growth / release --------------------------------------
    def _take(self, n: int, force: bool = False) -> List[int]:
        real = min(n, len(self._free))
        got = [self._free.pop() for _ in range(real)]
        if n > real:
            assert force
            got.extend(range(self._next_overflow_id,
                             self._next_overflow_id + n - real))
            self._next_overflow_id += n - real
            self._overflow_live += n - real
            self.overcommitted_blocks += n - real
        self.peak_blocks = max(self.peak_blocks, self.used_blocks)
        return got

    def _give_back(self, blocks: List[int]) -> int:
        """Return device blocks to the free list; retire overflow ids."""
        real = [b for b in blocks if b < self.num_blocks]
        self._free.extend(real)
        self._overflow_live -= len(blocks) - len(real)
        return len(real)

    def allocate(self, rid: int, tokens: int, force: bool = False) -> bool:
        """Whole-context admission (prefill): reserve ceil(tokens/B) blocks.
        ``force`` overcommits instead of failing (requests bigger than the
        entire pool — the caller decides, normal backpressure stays intact)."""
        assert rid not in self.tables, f"double allocation for rid={rid}"
        need = self.blocks_for_tokens(tokens)
        if need > len(self._free) and not force:
            self.admission_failures += 1
            return False
        self.tables[rid] = BlockTable(rid, self._take(need, force), int(tokens))
        return True

    def append_tokens(self, rid: int, n: int = 1, force: bool = False) -> bool:
        """Decode growth: extend by ``n`` token slots, faulting in new blocks
        as needed. Returns False (and counts a page fault) on exhaustion; the
        caller resolves it through its preemption policy, falling back to
        ``force`` when no victim exists."""
        t = self.tables[rid]
        assert t.on_device, f"growing swapped-out rid={rid}"
        need = self.blocks_for_tokens(t.tokens + n) - len(t.blocks)
        if need > len(self._free) and not force:
            self.page_faults += 1
            return False
        if need > 0:
            t.blocks.extend(self._take(need, force))
        t.tokens += n
        return True

    def free(self, rid: int) -> int:
        """Release every page of a request (completion/drop). Returns the
        number of device blocks returned to the free list."""
        t = self.tables.pop(rid, None)
        if t is None:
            return 0
        if t.on_device:
            return self._give_back(t.blocks)
        self.tiers[t.tier - 1].release(len(t.blocks) * self.block_bytes)
        return 0

    def holds(self, rid: int) -> bool:
        return rid in self.tables

    # -- preemption: swap ----------------------------------------------------
    def swap_out(self, rid: int) -> Optional[Tuple[float, float]]:
        """Offload a resident request's pages to the first spill tier with
        room. Returns (bytes_moved, transfer_time) or None when no tier can
        take them (caller falls back to recompute)."""
        t = self.tables[rid]
        assert t.on_device
        if len(t.blocks) > self.num_blocks:
            return None   # could never swap back in; caller recomputes
        nbytes = len(t.blocks) * self.block_bytes
        for i, tier in enumerate(self.tiers, start=1):
            if tier.has_room(nbytes):
                tier.reserve(nbytes)
                self._give_back(t.blocks)
                t.blocks = [-1] * len(t.blocks)   # physical ids are tier-side
                t.tier = i
                self.evictions += 1
                self.swap_bytes_out += nbytes
                return nbytes, tier_transfer_time(nbytes, tier.spec)
        return None

    def swap_in(self, rid: int) -> Optional[Tuple[float, float]]:
        """Bring a swapped request's pages back to HBM. Returns
        (bytes_moved, transfer_time) or None when HBM lacks free blocks."""
        t = self.tables[rid]
        assert not t.on_device
        n = len(t.blocks)
        if n > len(self._free):
            return None
        tier = self.tiers[t.tier - 1]
        nbytes = n * self.block_bytes
        tier.release(nbytes)
        t.blocks = self._take(n)
        t.tier = DEVICE_TIER
        self.swap_ins += 1
        self.swap_bytes_in += nbytes
        return nbytes, tier_transfer_time(nbytes, tier.spec)

    # -- preemption: recompute ----------------------------------------------
    def drop(self, rid: int) -> int:
        """Discard a request's pages entirely (recompute preemption)."""
        released = self.free(rid)
        self.recompute_drops += 1
        return released

    # -- reporting -----------------------------------------------------------
    def check_invariants(self):
        """Free list and block tables must partition [0, num_blocks); live
        overflow ids must match the overflow counter."""
        held = [b for t in self.tables.values() if t.on_device
                for b in t.blocks if b < self.num_blocks]
        overflow = sum(1 for t in self.tables.values() if t.on_device
                       for b in t.blocks if b >= self.num_blocks)
        all_ids = sorted(self._free + held)
        assert all_ids == list(range(self.num_blocks)), \
            "block leak or double allocation"
        assert overflow == self._overflow_live, "overflow accounting drift"

    def stats(self) -> Dict[str, float]:
        return {
            "num_blocks": self.num_blocks,
            "used_blocks": self.used_blocks,
            "free_blocks": self.free_blocks,
            "peak_blocks": self.peak_blocks,
            "block_tokens": self.block_tokens,
            "utilization": self.used_blocks / max(1, self.num_blocks),
            "fragmentation_bytes": self.fragmentation_bytes(),
            "page_faults": self.page_faults,
            "admission_failures": self.admission_failures,
            "evictions": self.evictions,
            "swap_ins": self.swap_ins,
            "swap_bytes_out": self.swap_bytes_out,
            "swap_bytes_in": self.swap_bytes_in,
            "recompute_drops": self.recompute_drops,
            "overflow_blocks": self._overflow_live,
            "overcommitted_blocks": self.overcommitted_blocks,
            "tier_used_bytes": {t.spec.name: t.used for t in self.tiers},
        }
