"""Hierarchical interconnect model (the astra-sim stand-in, DESIGN.md §3).

Alpha-beta links with per-link contention queues: each transfer occupies every
link on its path serially (store-and-forward at the path level, which upper-
bounds real wormhole behaviour by < the per-hop latency sum). Layerwise
granularity (paper §III-B2) pipelines the KV-cache transfer against prefill so
only ~one layer of exposed latency remains.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.perfmodel.hardware import LinkSpec


@dataclass
class LinkState:
    spec: LinkSpec
    busy_until: float = 0.0
    bytes_moved: float = 0.0
    transfers: int = 0


class Network:
    """Named clients connected through named links."""

    def __init__(self):
        self.links: Dict[str, LinkState] = {}
        self.paths: Dict[Tuple[str, str], List[str]] = {}
        self.default_path: Optional[List[str]] = None

    def add_link(self, name: str, spec: LinkSpec):
        self.links[name] = LinkState(spec)

    def override_link(self, name: str, spec: LinkSpec):
        """Re-price an existing link in place, keeping its traffic counters
        and contention state. This is the calibration hook: fleet builders
        wire topology with catalog LinkSpecs, then a measured fit (e.g.
        ``benchmarks/engine_disagg.py``'s timed KV-page handoffs) swaps in
        observed alpha/beta without rebuilding the Network."""
        self.links[name].spec = spec

    def connect(self, src: str, dst: str, link_names: List[str],
                bidirectional: bool = True):
        self.paths[(src, dst)] = link_names
        if bidirectional:
            self.paths[(dst, src)] = link_names

    def set_default_path(self, link_names: List[str]):
        self.default_path = link_names

    def path_for(self, src: str, dst: str) -> List[str]:
        p = self.paths.get((src, dst))
        if p is None:
            p = self.default_path or []
        return p

    @staticmethod
    def _exposed(link: LinkState, nbytes: float, granularity: str,
                 n_layers: int) -> float:
        """Exposed latency of one link traversal. Layerwise granularity is
        overlapped with producer compute: exposed cost ~ one layer of
        payload + one message latency (Splitwise layerwise mode)."""
        if granularity == "layerwise":
            return nbytes / max(1, n_layers) / link.spec.bandwidth \
                + link.spec.latency
        return nbytes / link.spec.bandwidth + link.spec.latency

    def transfer(self, src: str, dst: str, nbytes: float, now: float,
                 granularity: str = "full", n_layers: int = 1) -> float:
        """Returns the ARRIVAL time of the data at dst (with contention)."""
        path = self.path_for(src, dst)
        if not path or nbytes <= 0 or src == dst:
            return now
        t = now
        for name in path:
            link = self.links[name]
            start = max(t, link.busy_until)
            exposed = self._exposed(link, nbytes, granularity, n_layers)
            if granularity == "layerwise":
                occupy = nbytes / link.spec.bandwidth  # link carries it all
            else:
                occupy = exposed
            link.busy_until = start + occupy
            link.bytes_moved += nbytes
            link.transfers += 1
            t = start + exposed
        return t

    def estimate(self, src: str, dst: str, nbytes: float, now: float = 0.0,
                 granularity: str = "full", n_layers: int = 1) -> float:
        """Read-only exposed latency of a would-be ``transfer`` (same
        pricing, current contention included, NO link occupancy or byte
        accounting). Decision logic — e.g. the router's fetch-vs-recompute
        trade-off — uses this so probing an option never perturbs the
        links it decided against using."""
        path = self.path_for(src, dst)
        if not path or nbytes <= 0 or src == dst:
            return 0.0
        t = now
        for name in path:
            link = self.links[name]
            start = max(t, link.busy_until)
            t = start + self._exposed(link, nbytes, granularity, n_layers)
        return t - now

    def stats(self) -> Dict[str, Dict]:
        return {k: {"bytes": v.bytes_moved, "transfers": v.transfers}
                for k, v in self.links.items()}
