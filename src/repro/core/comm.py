"""Hierarchical interconnect model (the astra-sim stand-in, DESIGN.md §3).

Alpha-beta links with per-link contention queues: each transfer occupies every
link on its path serially (store-and-forward at the path level, which upper-
bounds real wormhole behaviour by < the per-hop latency sum). Layerwise
granularity (paper §III-B2) pipelines the KV-cache transfer against prefill so
only ~one layer of exposed latency remains.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.perfmodel.hardware import LinkSpec


@dataclass
class LinkState:
    spec: LinkSpec
    busy_until: float = 0.0
    bytes_moved: float = 0.0
    transfers: int = 0


class Network:
    """Named clients connected through named links."""

    def __init__(self):
        self.links: Dict[str, LinkState] = {}
        self.paths: Dict[Tuple[str, str], List[str]] = {}
        self.default_path: Optional[List[str]] = None

    def add_link(self, name: str, spec: LinkSpec):
        self.links[name] = LinkState(spec)

    def connect(self, src: str, dst: str, link_names: List[str],
                bidirectional: bool = True):
        self.paths[(src, dst)] = link_names
        if bidirectional:
            self.paths[(dst, src)] = link_names

    def set_default_path(self, link_names: List[str]):
        self.default_path = link_names

    def path_for(self, src: str, dst: str) -> List[str]:
        p = self.paths.get((src, dst))
        if p is None:
            p = self.default_path or []
        return p

    def transfer(self, src: str, dst: str, nbytes: float, now: float,
                 granularity: str = "full", n_layers: int = 1) -> float:
        """Returns the ARRIVAL time of the data at dst (with contention)."""
        path = self.path_for(src, dst)
        if not path or nbytes <= 0 or src == dst:
            return now
        t = now
        for name in path:
            link = self.links[name]
            start = max(t, link.busy_until)
            if granularity == "layerwise":
                # overlapped with producer compute: exposed cost ~ one layer
                # of payload + one message latency (Splitwise layerwise mode)
                exposed = nbytes / max(1, n_layers) / link.spec.bandwidth \
                    + link.spec.latency
                occupy = nbytes / link.spec.bandwidth  # link still carries it all
            else:
                exposed = nbytes / link.spec.bandwidth + link.spec.latency
                occupy = exposed
            link.busy_until = start + occupy
            link.bytes_moved += nbytes
            link.transfers += 1
            t = start + exposed
        return t

    def stats(self) -> Dict[str, Dict]:
        return {k: {"bytes": v.bytes_moved, "transfers": v.transfers}
                for k, v in self.links.items()}
