"""System builder: assembles a full HERMES serving setup (Fig. 4d) from a
compact spec — N LLM clients (any batching strategy, incl. disaggregated
prefill/decode pools), pre/post-processing, RAG and KV-retrieval clients,
wired through a hierarchical network.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.configs import get_config
from repro.configs.base import ModelConfig
from repro.core.client import (Client, KVRetrievalClient, LLMClient,
                               PostprocessClient, PreprocessClient, RAGClient)
from repro.core.comm import Network
from repro.core.coordinator import Coordinator, CoordinatorConfig
from repro.core.llm_scheduler import ClientPerf, SchedulerLimits
from repro.core.router import make_router
from repro.perfmodel import rag_model
from repro.perfmodel.hardware import (CacheTierSpec, ClusterSpec, GRACE_CPU,
                                      H100, LinkSpec, NVLINK, ETH_RACK,
                                      PCIE4_X4, SPR_CPU, TIER_PLATFORM,
                                      TIER_LOCAL_LPDDR, TIER_RACK)


@dataclass
class SystemSpec:
    model: str = "llama3-70b"
    n_llm_clients: int = 4
    strategy: str = "continuous"        # or "disaggregated"
    n_prefill: int = 0                  # used when strategy == "disaggregated"
    n_decode: int = 0
    tp: int = 2
    chips_per_client: int = 2
    chip: str = "H100"
    limits: SchedulerLimits = field(default_factory=SchedulerLimits)
    packing: str = "fcfs"
    router_policy: str = "load_based"
    router_metric: str = "tokens_remaining"
    disaggregation: str = "global"
    kv_transfer_granularity: str = "layerwise"
    with_rag: bool = False
    rag_colocated: bool = False
    rag_embed_on_npu: bool = False
    with_kv_retrieval: bool = False
    kv_tiers: Tuple[CacheTierSpec, ...] = (TIER_PLATFORM, TIER_RACK)
    with_pre_post: bool = True
    use_regression: bool = False
    straggler_deadline: Optional[float] = None
    embed_model: Optional[ModelConfig] = None
    # cross-client radix prefix migration (PR 4)
    prefix_migration: bool = False
    migration_granularity: Optional[str] = None  # default: kv_transfer_gran.
    warm_on_scale_out: bool = True
    warm_max_blocks: int = 256
    # prefix_affinity fetch policy: warm-client overload factor beyond which
    # requests route load-best and the prefix migrates (None = affinity only)
    fetch_load_factor: Optional[float] = None
    # fleet-scale routing indexes (decision-identical to the linear scan);
    # False forces the O(N) baseline — the benchmark's A/B arm
    fleet_index: bool = True


def _embed_model_small() -> ModelConfig:
    """E5-base-class embedding model (paper §IV-B)."""
    from repro.configs.base import ModelConfig as MC
    return MC(name="e5-base", family="dense", num_layers=12, d_model=768,
              num_heads=12, num_kv_heads=12, d_ff=3072, vocab_size=30522,
              mlp_type="gelu", attn_type="gqa", encoder_only=True)


def _guard_model_2b() -> ModelConfig:
    """Kept as a thin alias into the config registry (the canonical home is
    ``configs/guard_2b.py``; simulator callers import it from here)."""
    from repro.configs import get_config
    return get_config("guard_2b")


def build_system(spec: SystemSpec) -> Coordinator:
    from repro.perfmodel.hardware import CHIPS
    chip = CHIPS[spec.chip]
    model_cfg = get_config(spec.model)
    cluster = ClusterSpec(chip, n_chips=spec.chips_per_client, tp=spec.tp,
                          intra_link=NVLINK)
    perf = ClientPerf(model_cfg, cluster, use_regression=spec.use_regression)

    clients: List[Client] = []
    net = Network()
    net.add_link("nvlink", NVLINK)
    net.add_link("rack", ETH_RACK)
    net.add_link("pcie", PCIE4_X4)
    net.set_default_path(["rack"])

    if spec.strategy == "disaggregated":
        n_p = spec.n_prefill or max(1, spec.n_llm_clients // 2)
        n_d = spec.n_decode or max(1, spec.n_llm_clients - n_p)
        n_groups = max(1, min(n_p, n_d))
        for i in range(n_p):
            clients.append(LLMClient(f"prefill{i}", cluster, model_cfg,
                                     "prefill_only", spec.limits, spec.packing,
                                     perf, group=f"g{i % n_groups}"))
        for i in range(n_d):
            clients.append(LLMClient(f"decode{i}", cluster, model_cfg,
                                     "decode_only", spec.limits, spec.packing,
                                     perf, group=f"g{i % n_groups}"))
        # prefill->decode KV rides the rack fabric (local pairs ride nvlink)
        for i in range(n_p):
            for j in range(n_d):
                local = spec.disaggregation == "local" and (i % n_groups) == (j % n_groups)
                net.connect(f"prefill{i}", f"decode{j}",
                            ["nvlink"] if local else ["rack"])
    else:
        for i in range(spec.n_llm_clients):
            clients.append(LLMClient(f"llm{i}", cluster, model_cfg,
                                     spec.strategy, spec.limits, spec.packing,
                                     perf))

    if spec.with_pre_post:
        cpu = ClusterSpec(SPR_CPU, n_chips=1, tp=1)
        clients.append(PreprocessClient("preproc0", cpu))
        clients.append(PostprocessClient("postproc0", cpu))

    if spec.with_rag:
        ivf = rag_model.IVFPQConfig()
        emb = spec.embed_model or _embed_model_small()
        if spec.rag_colocated:
            cpu = ClusterSpec(GRACE_CPU, n_chips=1, tp=1)
            clients.append(RAGClient("rag0", cpu, emb, ivf,
                                     serve_embed=True, serve_retrieve=True))
        else:
            embed_cluster = (ClusterSpec(CHIPS["A100"], 1, 1)
                             if spec.rag_embed_on_npu
                             else ClusterSpec(GRACE_CPU, 1, 1))
            clients.append(RAGClient("rag_embed0", embed_cluster, emb, ivf,
                                     serve_embed=True, serve_retrieve=False))
            clients.append(RAGClient("rag_retrieve0",
                                     ClusterSpec(GRACE_CPU, 1, 1), emb, ivf,
                                     serve_embed=False, serve_retrieve=True))
            net.connect("rag_embed0", "rag_retrieve0", ["pcie"])
        for c in clients:
            if isinstance(c, LLMClient):
                net.connect("rag_retrieve0" if not spec.rag_colocated else "rag0",
                            c.name, ["pcie"])

    if spec.with_kv_retrieval:
        from repro.perfmodel import analytical as ana
        kvb = ana.kv_bytes_per_token(model_cfg)
        recompute = lambda size: ana.prefill_time(
            model_cfg, cluster, max(1, int(size / max(kvb, 1.0)))).time
        clients.append(KVRetrievalClient(
            "kvret0", ClusterSpec(GRACE_CPU, 1, 1), spec.kv_tiers,
            kv_bytes_per_token=kvb, recompute_fn=recompute))

    # each LLM client spills preempted KV pages over its own PCIe path so
    # swap traffic contends with that client's other host-side transfers
    for c in clients:
        if isinstance(c, LLMClient):
            net.add_link(f"pcie:{c.name}", PCIE4_X4)
            net.connect(c.name, f"{c.name}:kvpool", [f"pcie:{c.name}"])

    router_kw = {}
    if spec.router_policy == "prefix_affinity" \
            and spec.fetch_load_factor is not None:
        router_kw["fetch_load_factor"] = spec.fetch_load_factor
    router = make_router(spec.router_policy, spec.router_metric, **router_kw)
    coord = Coordinator(clients, router, net, CoordinatorConfig(
        disaggregation=spec.disaggregation,
        kv_transfer_granularity=spec.kv_transfer_granularity,
        straggler_deadline=spec.straggler_deadline,
        prefix_migration=spec.prefix_migration,
        migration_granularity=spec.migration_granularity,
        warm_on_scale_out=spec.warm_on_scale_out,
        warm_max_blocks=spec.warm_max_blocks,
        fleet_index=spec.fleet_index))
    return coord
