"""Base schedulers for single-step clients (paper §III-D).

* ``BatchedScheduler`` — tasks with reuse (RAG lookup, KV retrieval): all
  queued requests run as one batch per step.
* ``SequentialScheduler`` — no-reuse tasks (padding, truncation, detokenize):
  available cores drain the queue linearly.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Deque, List, Optional

from repro.core.request import Request


@dataclass
class SimpleStep:
    requests: List[Request]
    duration: float
    energy: float = 0.0


def _take_head(waiting: Deque[Request], n: int) -> List[Request]:
    """Pop up to ``n`` requests off the queue head — O(batch), not the
    O(queue) slice-and-copy the list version paid every step."""
    return [waiting.popleft() for _ in range(min(n, len(waiting)))]


class BatchedScheduler:
    def __init__(self, latency_fn: Callable[[List[Request]], float],
                 max_batch: int = 256, energy_fn=None):
        self.latency_fn = latency_fn
        self.energy_fn = energy_fn
        self.max_batch = max_batch
        self.waiting: Deque[Request] = deque()

    def add(self, req: Request):
        self.waiting.append(req)

    def has_work(self) -> bool:
        return bool(self.waiting)

    def plan_step(self) -> Optional[SimpleStep]:
        if not self.waiting:
            return None
        batch = _take_head(self.waiting, self.max_batch)
        dur = self.latency_fn(batch)
        en = self.energy_fn(batch, dur) if self.energy_fn else 0.0
        return SimpleStep(batch, dur, en)

    def finish_step(self, step: SimpleStep, now: float) -> List[Request]:
        return step.requests

    def requeue_step(self, step: SimpleStep) -> None:
        """An in-flight step is being discarded unfinished (client fail or
        removal): planning popped its requests off ``waiting``, so without
        putting them back ``drain()`` would silently lose them."""
        self.waiting.extendleft(reversed(step.requests))

    def drain(self) -> List[Request]:
        out = list(self.waiting)
        self.waiting.clear()
        return out


class SequentialScheduler:
    """n_cores parallel lanes, linear within a lane."""

    def __init__(self, per_request_fn: Callable[[Request], float],
                 n_cores: int = 8, energy_fn=None):
        self.per_request_fn = per_request_fn
        self.energy_fn = energy_fn
        self.n_cores = n_cores
        self.waiting: Deque[Request] = deque()

    def add(self, req: Request):
        self.waiting.append(req)

    def has_work(self) -> bool:
        return bool(self.waiting)

    def plan_step(self) -> Optional[SimpleStep]:
        if not self.waiting:
            return None
        batch = _take_head(self.waiting, self.n_cores)
        dur = max(self.per_request_fn(r) for r in batch)
        en = self.energy_fn(batch, dur) if self.energy_fn else 0.0
        return SimpleStep(batch, dur, en)

    def finish_step(self, step: SimpleStep, now: float) -> List[Request]:
        return step.requests

    def requeue_step(self, step: SimpleStep) -> None:
        self.waiting.extendleft(reversed(step.requests))

    def drain(self) -> List[Request]:
        out = list(self.waiting)
        self.waiting.clear()
        return out
