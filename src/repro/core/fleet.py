"""Fleet-scale routing indexes (ROADMAP item 4 groundwork).

At 100s-1000s of clients the hot per-request path must stop scanning the
fleet: ``Coordinator._dispatch`` rebuilt the candidate list with a linear
scan over every client, ``LoadBasedRouter`` ran an O(N) ``min()`` over
``Client.load``, and ``PrefixAffinityRouter`` probed every candidate's radix
cache per request. ``FleetIndex`` replaces those scans with incrementally
maintained structures:

* **stage -> members** (``StageMembers``): one insertion-ordered member map
  per stage kind (and per ``(stage, group)`` for local disaggregation),
  updated on CLIENT_ADD/REMOVE/FAIL/RECOVER instead of rebuilt per request.
* **incremental load index** (``LoadIndex``): a lazy-deletion min-heap over
  ``Client.load(metric, now)`` per (stage, metric), following the PR 3
  ``WaitQueue`` discipline — entries go stale when the coordinator touches a
  client and are re-validated at pop.
* **root-hash inverted index**: chain-root content hash -> client names,
  fed by ``RadixBlockIndex`` root registration events, so prefix-affinity
  routing probes only clients that can possibly hit.

The hard contract is **decision identity**: with the index on
(``CoordinatorConfig.fleet_index``, default) every router must choose the
same client for every request as the linear-scan baseline, tie-breaks
included. Three invariants carry that:

1. *Iteration order.* ``StageMembers`` preserves the baseline candidate
   order — ``self.clients`` dict insertion order filtered by stage. Member
   maps are append-only per add; a CLIENT_ADD that *replaces* an existing
   name keeps its dict position (Python dict overwrite semantics), so that
   rare churn event triggers a full rebuild in ``self.clients`` order.
2. *Tie-breaks.* The baseline ``min()`` returns the first minimum in
   candidate order. Heap entries are ``(value, insertion_seq, name)``, so
   equal loads resolve to the earliest-inserted live member — the same
   client.
3. *Dirty discipline.* Every load metric is invariant between
   coordinator-mediated mutations (dispatch, step completion, window
   truncation, drain, migration). The coordinator marks the touched client
   dirty at each such chokepoint; the index recomputes exactly the dirty
   set at the next query. ``tokens_remaining`` is the one time-varying
   metric (decode fast-forward windows commit virtually as ``now``
   advances), so clients with an in-flight window are re-read every query.

``tests/test_fleet_scale.py`` drives random churn + mixed stages through
every router x metric and asserts the indexed and naive arms pick identical
client sequences; ``benchmarks/fleet_scale.py --smoke --check`` re-verifies
summary bit-equality at 1000 clients in CI.
"""
from __future__ import annotations

import heapq
import itertools
from bisect import insort
from typing import Dict, Iterable, List, Optional, Set

from repro.core.client import Client


class LoadIndex:
    """Lazy-deletion min-heap over one load metric of one ``StageMembers``.

    ``best(now)`` returns the client the baseline
    ``min(candidates, key=lambda c: c.load(metric, now))`` would return.
    Entries are ``(value, insertion_seq, name)``; an entry is live iff its
    value matches the cached one, its seq matches the member's current seq,
    and the member exists and is not failed. Dirty names are recomputed (and
    re-pushed unconditionally — a recover must restore an entry that a pop
    discarded while the client was failed) at the start of every query.
    """

    __slots__ = ("struct", "metric", "heap", "val", "dirty")

    def __init__(self, struct: "StageMembers", metric: str):
        self.struct = struct
        self.metric = metric
        self.heap: List = []
        self.val: Dict[str, float] = {}
        self.dirty: Set[str] = set(struct.members)

    def touch(self, name: str):
        self.dirty.add(name)

    def drop(self, name: str):
        self.val.pop(name, None)
        self.dirty.discard(name)

    def _compact(self):
        st = self.struct
        self.heap = [(v, st.seq[n], n) for n, v in self.val.items()
                     if n in st.members]
        heapq.heapify(self.heap)

    def best(self, now: Optional[float]) -> Optional[Client]:
        st = self.struct
        if self.metric == "tokens_remaining" and st.fleet.windowed:
            # virtually-committed fast-forward windows make this metric
            # time-varying between events: re-read every windowed member
            for name in st.fleet.windowed:
                if name in st.members:
                    self.dirty.add(name)
        if self.dirty:
            for name in self.dirty:
                c = st.members.get(name)
                if c is None:
                    self.val.pop(name, None)
                    continue
                v = c.load(self.metric, now)
                self.val[name] = v
                heapq.heappush(self.heap, (v, st.seq[name], name))
            self.dirty.clear()
        if len(self.heap) > 16 + 4 * len(st.members):
            self._compact()
        while self.heap:
            v, s, name = self.heap[0]
            c = st.members.get(name)
            if (c is None or c.failed or self.val.get(name) != v
                    or st.seq.get(name) != s):
                heapq.heappop(self.heap)
                continue
            return c
        return None


class StageMembers:
    """All non-removed clients serving one stage kind (or one
    ``(stage, group)`` pair), in ``Coordinator.clients`` insertion order.
    Failed members stay in the map (mirroring the baseline dict, which keeps
    them) but are excluded from iteration, the name-sorted live list and
    load-index answers. Doubles as the candidate view handed to routers."""

    __slots__ = ("fleet", "members", "seq", "n_failed", "_sorted", "load_idx")

    def __init__(self, fleet: "FleetIndex"):
        self.fleet = fleet
        self.members: Dict[str, Client] = {}
        self.seq: Dict[str, int] = {}
        self.n_failed = 0
        self._sorted: List[str] = []       # live member names, name-sorted
        self.load_idx: Dict[str, LoadIndex] = {}

    # -- candidate-view protocol (what routers / the coordinator consume) --
    @property
    def n_live(self) -> int:
        return len(self.members) - self.n_failed

    def __len__(self) -> int:
        return self.n_live

    def __bool__(self) -> bool:
        return self.n_live > 0

    def __iter__(self):
        return (c for c in self.members.values() if not c.failed)

    def sorted_live(self) -> List[Client]:
        return [self.members[n] for n in self._sorted]

    def pick_sorted(self, k: int) -> Client:
        return self.members[self._sorted[k % len(self._sorted)]]

    def load_best(self, metric: str, now: Optional[float]) -> Client:
        li = self.load_idx.get(metric)
        if li is None:
            li = self.load_idx[metric] = LoadIndex(self, metric)
        return li.best(now)

    def windowed(self) -> List[Client]:
        """Live members with an in-flight fast-forward window, in insertion
        order — exactly the candidates whose ``_interrupt`` would not be a
        no-op, so ``_sync`` pushes the same events as the baseline's
        interrupt-everyone loop."""
        w = self.fleet.windowed
        if not w:
            return []
        hits = [(self.seq[n], self.members[n]) for n in w
                if n in self.members and not self.members[n].failed]
        hits.sort()
        return [c for _, c in hits]

    def warm_candidates(self, req) -> List[Client]:
        """Live members whose radix cache holds the root block of ``req``'s
        prefix chain — the only clients whose ``prefix_hit_tokens`` can be
        nonzero — in insertion order."""
        names = self.fleet.warm_names(req)
        if not names:
            return []
        hits = [(self.seq[n], self.members[n]) for n in names
                if n in self.members and not self.members[n].failed]
        hits.sort()
        return [c for _, c in hits]

    # -- incremental maintenance ------------------------------------------
    def add(self, c: Client):
        name = c.name
        self.members[name] = c
        self.seq[name] = self.fleet.next_seq()
        if c.failed:
            self.n_failed += 1
        else:
            insort(self._sorted, name)
        for li in self.load_idx.values():
            li.touch(name)

    def remove(self, name: str):
        c = self.members.pop(name, None)
        if c is None:
            return
        del self.seq[name]
        if c.failed:
            self.n_failed -= 1
        else:
            self._sorted.remove(name)
        for li in self.load_idx.values():
            li.drop(name)

    def set_failed(self, name: str, failed: bool):
        c = self.members.get(name)
        if c is None:
            return
        if failed:
            self.n_failed += 1
            self._sorted.remove(name)
        else:
            self.n_failed -= 1
            insort(self._sorted, name)
        for li in self.load_idx.values():
            li.touch(name)


class FleetIndex:
    """Incrementally maintained routing indexes over a coordinator's fleet.

    Owned by ``Coordinator`` (``self.fleet``); every churn event and every
    client-state mutation chokepoint notifies it. ``None`` (the
    ``fleet_index=False`` config arm) gives the linear-scan baseline the
    decision-identity checks compare against."""

    def __init__(self, coordinator):
        self.coordinator = coordinator
        self.stages: Dict[str, StageMembers] = {}
        self.groups: Dict[tuple, StageMembers] = {}
        # per-client reverse map: name -> the StageMembers containing it
        self._structs: Dict[str, List[StageMembers]] = {}
        self._seq = itertools.count()
        # clients with an in-flight decode fast-forward macro-step
        self.windowed: Set[str] = set()
        # chain-root content hash -> names of clients whose radix holds it
        self.inv: Dict[int, Set[str]] = {}
        self._block_tokens: Dict[str, int] = {}     # per attached client
        self._bt_counts: Dict[int, int] = {}        # distinct block sizes
        for c in coordinator.clients.values():
            self.add(c)

    def next_seq(self) -> int:
        return next(self._seq)

    # -- candidate lookup --------------------------------------------------
    def candidates(self, stage: str) -> Optional[StageMembers]:
        return self.stages.get(stage)

    def group_candidates(self, stage: str, group) -> Optional[StageMembers]:
        return self.groups.get((stage, group))

    # -- churn events ------------------------------------------------------
    def add(self, c: Client):
        if c.name in self._structs:
            # CLIENT_ADD over an existing name keeps its dict position in
            # self.clients; rebuilding in dict order is the only way the
            # per-stage iteration order stays baseline-identical
            self.rebuild()
            return
        structs = []
        for stage in c.stages:
            st = self.stages.get(stage)
            if st is None:
                st = self.stages[stage] = StageMembers(self)
            st.add(c)
            structs.append(st)
            g = getattr(c, "group", None)
            if g is not None:
                gk = (stage, g)
                gst = self.groups.get(gk)
                if gst is None:
                    gst = self.groups[gk] = StageMembers(self)
                gst.add(c)
                structs.append(gst)
        self._structs[c.name] = structs
        self._attach_radix(c)

    def remove(self, name: str, client: Optional[Client] = None):
        for st in self._structs.pop(name, ()):
            st.remove(name)
        self.windowed.discard(name)
        self._detach_radix(name, client)

    def set_failed(self, name: str, failed: bool):
        for st in self._structs.get(name, ()):
            st.set_failed(name, failed)
        if failed:
            self.windowed.discard(name)

    def rebuild(self):
        """Full rebuild from the coordinator's client dict (rare: only a
        CLIENT_ADD replacing an existing name needs it)."""
        for name in list(self._structs):
            self._detach_radix(name)
        self.stages.clear()
        self.groups.clear()
        self._structs.clear()
        self.inv.clear()
        self._block_tokens.clear()
        self._bt_counts.clear()
        live_windows = self.windowed
        self.windowed = set()
        for c in self.coordinator.clients.values():
            self.add(c)
            if c.name in live_windows:
                self.windowed.add(c.name)

    # -- mutation chokepoints ---------------------------------------------
    def touch(self, name: str):
        """Client state changed under the coordinator's hands: cached load
        values are stale until recomputed."""
        for st in self._structs.get(name, ()):
            for li in st.load_idx.values():
                li.touch(name)

    def set_windowed(self, name: str, active: bool):
        if active:
            self.windowed.add(name)
        else:
            self.windowed.discard(name)

    # -- root-hash inverted index -----------------------------------------
    @staticmethod
    def _kv_of(c) -> Optional[object]:
        return getattr(getattr(c, "scheduler", None), "kv", None)

    def _attach_radix(self, c: Client):
        kv = self._kv_of(c)
        radix = getattr(kv, "radix", None) if kv is not None else None
        if radix is None:
            return
        name = c.name
        radix.on_root_change = (
            lambda h, added, _n=name: self._root_change(_n, h, added))
        self._block_tokens[name] = kv.block_tokens
        self._bt_counts[kv.block_tokens] = \
            self._bt_counts.get(kv.block_tokens, 0) + 1
        for node in radix.nodes.values():
            if getattr(node, "is_root", False):
                self.inv.setdefault(node.hash, set()).add(name)

    def _detach_radix(self, name: str, client: Optional[Client] = None):
        bt = self._block_tokens.pop(name, None)
        if bt is None:
            return
        n = self._bt_counts.get(bt, 0) - 1
        if n > 0:
            self._bt_counts[bt] = n
        else:
            self._bt_counts.pop(bt, None)
        c = client if client is not None else self.coordinator.clients.get(name)
        kv = self._kv_of(c) if c is not None else None
        radix = getattr(kv, "radix", None) if kv is not None else None
        if radix is not None and radix.on_root_change is not None:
            radix.on_root_change = None
            for node in radix.nodes.values():
                if getattr(node, "is_root", False):
                    self._root_discard(node.hash, name)
        else:
            # client object already gone: sweep the inverted index
            for h in [h for h, s in self.inv.items() if name in s]:
                self._root_discard(h, name)

    def _root_change(self, name: str, h: int, added: bool):
        if added:
            self.inv.setdefault(h, set()).add(name)
        else:
            self._root_discard(h, name)

    def _root_discard(self, h: int, name: str):
        s = self.inv.get(h)
        if s is not None:
            s.discard(name)
            if not s:
                del self.inv[h]

    def warm_names(self, req) -> Set[str]:
        """Names of clients that hold the root block of ``req``'s prefix
        chain (for any block size present in the fleet) — a superset filter:
        every client outside it has ``prefix_hit_tokens(req) == 0``."""
        if not req.prefix_segments or not self.inv:
            return set()
        names: Set[str] = set()
        for bt in self._bt_counts:
            chain = req.prefix_block_hashes(bt)
            if chain:
                hit = self.inv.get(chain[0])
                if hit:
                    names |= hit
        return names
