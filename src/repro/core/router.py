"""Routing & load balancing (paper §III-B1): Round-Robin, Load-based,
Heavy-Light split and Prefix-Affinity, each parameterizable by load metrics
(input len, output len, KV size, tokens remaining) — the paper's "up to nine
distinct routing strategies". Modular: subclass Router and register.
"""
from __future__ import annotations

import itertools
from typing import Dict, List, Optional

from repro.core.client import Client
from repro.core.request import Request

LOAD_METRICS = ("queue", "input_len", "output_len", "kv_size",
                "kv_pressure", "tokens_remaining")


class Router:
    name = "base"

    def route(self, req: Request, candidates: List[Client], now: float) -> Client:
        raise NotImplementedError


class RoundRobinRouter(Router):
    name = "round_robin"

    def __init__(self):
        self._counters: Dict[str, itertools.count] = {}

    def route(self, req, candidates, now):
        key = req.current_stage.kind
        c = self._counters.setdefault(key, itertools.count())
        return candidates[next(c) % len(candidates)]


class LoadBasedRouter(Router):
    name = "load_based"

    def __init__(self, metric: str = "queue"):
        assert metric in LOAD_METRICS, metric
        self.metric = metric

    def route(self, req, candidates, now):
        return min(candidates, key=lambda c: c.load(self.metric, now))


class HeavyLightRouter(Router):
    """Heavy-light split [26]: long requests go to a dedicated heavy pool so
    short interactive requests never queue behind them."""

    name = "heavy_light"

    def __init__(self, threshold_tokens: int = 4096, heavy_frac: float = 0.25,
                 metric: str = "queue"):
        self.threshold = threshold_tokens
        self.heavy_frac = heavy_frac
        self.metric = metric

    def route(self, req, candidates, now):
        n_heavy = max(1, int(len(candidates) * self.heavy_frac))
        heavy, light = candidates[:n_heavy], candidates[n_heavy:] or candidates
        work = req.input_tokens + req.output_tokens * req.branches
        pool = heavy if work >= self.threshold else light
        return min(pool, key=lambda c: c.load(self.metric, now))


class PrefixAffinityRouter(Router):
    """Cache-aware placement: prefer the client whose radix cache already
    holds the longest prefix of the request's prompt (its pages get mapped,
    not recomputed), tie-breaking — and falling back for identity-less
    requests — on a load metric. Hits below ``min_hit_tokens`` are ignored
    so a stale one-block hit cannot override load balance."""

    name = "prefix_affinity"

    def __init__(self, metric: str = "queue", min_hit_tokens: int = 64):
        assert metric in LOAD_METRICS, metric
        self.metric = metric
        self.min_hit_tokens = min_hit_tokens

    def route(self, req, candidates, now):
        hits = {c.name: c.prefix_hit_tokens(req) for c in candidates}
        best = max(hits.values())
        if best >= self.min_hit_tokens:
            candidates = [c for c in candidates if hits[c.name] == best]
        return min(candidates, key=lambda c: c.load(self.metric, now))


def make_router(policy: str = "round_robin", metric: str = "queue",
                **kw) -> Router:
    if policy == "round_robin":
        return RoundRobinRouter()
    if policy == "load_based":
        return LoadBasedRouter(metric)
    if policy == "heavy_light":
        return HeavyLightRouter(metric=metric, **kw)
    if policy == "prefix_affinity":
        return PrefixAffinityRouter(metric=metric, **kw)
    raise ValueError(policy)
