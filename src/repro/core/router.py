"""Routing & load balancing (paper §III-B1): Round-Robin, Load-based,
Heavy-Light split and Prefix-Affinity, each parameterizable by load metrics
(input len, output len, KV size, tokens remaining) — the paper's "up to nine
distinct routing strategies". Modular: subclass Router and register.
"""
from __future__ import annotations

import itertools
from typing import Dict, List, Optional

from repro.core.client import Client
from repro.core.fleet import StageMembers
from repro.core.request import Request

LOAD_METRICS = ("queue", "input_len", "output_len", "kv_size",
                "kv_pressure", "tokens_remaining")


class Router:
    name = "base"
    coordinator = None           # back-reference, set by Coordinator.bind

    def bind(self, coordinator) -> None:
        """Coordinator back-reference hook. Routers that can trigger
        coordinator actions — e.g. the prefix-affinity fetch policy starting
        a cross-client KV migration — reach it through ``self.coordinator``;
        plain load balancers just ignore it."""
        self.coordinator = coordinator

    def route(self, req: Request, candidates: List[Client], now: float) -> Client:
        raise NotImplementedError


class RoundRobinRouter(Router):
    """Round-robin over the *name-sorted* live candidates. Sorting pins the
    assignment under client churn: the raw candidate list follows client-dict
    order, which a CLIENT_ADD/REMOVE silently reshuffles mid-rotation (the
    same determinism fix HeavyLightRouter got in PR 4). With a fleet index
    the sorted order is maintained incrementally (O(1) pick per route)."""

    name = "round_robin"

    def __init__(self):
        self._counters: Dict[str, itertools.count] = {}

    def route(self, req, candidates, now):
        key = req.current_stage.kind
        c = self._counters.setdefault(key, itertools.count())
        k = next(c)
        if isinstance(candidates, StageMembers):
            return candidates.pick_sorted(k)
        cands = sorted(candidates, key=lambda x: x.name)
        return cands[k % len(cands)]


class LoadBasedRouter(Router):
    name = "load_based"

    def __init__(self, metric: str = "queue"):
        assert metric in LOAD_METRICS, metric
        self.metric = metric

    def route(self, req, candidates, now):
        if isinstance(candidates, StageMembers):
            return candidates.load_best(self.metric, now)
        return min(candidates, key=lambda c: c.load(self.metric, now))


class HeavyLightRouter(Router):
    """Heavy-light split [26]: long requests go to a dedicated heavy pool so
    short interactive requests never queue behind them."""

    name = "heavy_light"

    def __init__(self, threshold_tokens: int = 4096, heavy_frac: float = 0.25,
                 metric: str = "queue"):
        self.threshold = threshold_tokens
        self.heavy_frac = heavy_frac
        self.metric = metric

    def route(self, req, candidates, now):
        # deterministic split: the candidate list follows client-dict order,
        # which a fail/recover/add silently reshuffles — partition a
        # name-sorted view so the heavy pool is stable across churn. The
        # fleet index maintains that view incrementally (no per-route sort);
        # the per-pool min stays O(pool) — pools are load-ordered subsets a
        # single heap cannot serve.
        if isinstance(candidates, StageMembers):
            cands = candidates.sorted_live()
        else:
            cands = sorted(candidates, key=lambda c: c.name)
        n_heavy = max(1, int(len(cands) * self.heavy_frac))
        heavy, light = cands[:n_heavy], cands[n_heavy:] or cands
        work = req.input_tokens + req.output_tokens * req.branches
        pool = heavy if work >= self.threshold else light
        return min(pool, key=lambda c: c.load(self.metric, now))


class PrefixAffinityRouter(Router):
    """Cache-aware placement: prefer the client whose radix cache already
    holds the longest prefix of the request's prompt (its pages get mapped,
    not recomputed), tie-breaking — and falling back for identity-less
    requests — on a load metric. Hits below ``min_hit_tokens`` are ignored
    so a stale one-block hit cannot override load balance.

    Fetch policy (``fetch_load_factor``): affinity alone concentrates hot
    prefixes on one client until it saturates. When the warm client's load
    exceeds ``fetch_load_factor ×`` the load-best candidate's (floored at
    one load unit so an idle fleet is not "overloaded" by a single
    request), the request routes to the load-best client instead — and the
    coordinator is asked to *migrate* the prefix there, shipping the KV
    pages over the Network when the wire fetch prices cheaper than
    recomputing them (``Coordinator.maybe_fetch_prefix``). None disables
    the policy (PR-2 pure-affinity behavior)."""

    name = "prefix_affinity"

    def __init__(self, metric: str = "queue", min_hit_tokens: int = 64,
                 fetch_load_factor: Optional[float] = None):
        assert metric in LOAD_METRICS, metric
        self.metric = metric
        self.min_hit_tokens = min_hit_tokens
        self.fetch_load_factor = fetch_load_factor

    def route(self, req, candidates, now):
        if isinstance(candidates, StageMembers):
            # fleet-level root-hash inverted index: only clients holding the
            # chain's root block can have a nonzero hit, so exact hits are
            # probed on that (usually tiny) warm set instead of the fleet.
            # Decision-identical: everyone else's hit is provably 0, and a
            # best hit of 0 routes load-best — exactly what the full scan
            # concludes when no candidate has a positive hit.
            warm_cands = candidates.warm_candidates(req)
            hits = {c.name: c.prefix_hit_tokens(req) for c in warm_cands}
            best = max(hits.values(), default=0)
            if best < max(self.min_hit_tokens, 1):
                return candidates.load_best(self.metric, now)
            warm = [c for c in warm_cands if hits[c.name] == best]
            load_best_fn = lambda: candidates.load_best(self.metric, now)
        else:
            hits = {c.name: c.prefix_hit_tokens(req) for c in candidates}
            best = max(hits.values())
            if best < self.min_hit_tokens:
                return min(candidates, key=lambda c: c.load(self.metric, now))
            warm = [c for c in candidates if hits[c.name] == best]
            load_best_fn = lambda: min(
                candidates, key=lambda c: c.load(self.metric, now))
        warm_best = min(warm, key=lambda c: c.load(self.metric, now))
        if self.fetch_load_factor is None or self.coordinator is None:
            return warm_best
        load_best = load_best_fn()
        if load_best is warm_best:
            return warm_best
        w_load = warm_best.load(self.metric, now)
        l_load = load_best.load(self.metric, now)
        if w_load <= self.fetch_load_factor * max(l_load, 1.0):
            return warm_best               # affinity wins below the knob
        # warm client overloaded: place on the load-best client and warm it
        # (the fetch-vs-recompute pricing inside decides whether the prefix
        # actually ships or the new home just recomputes it)
        self.coordinator.maybe_fetch_prefix(warm_best, load_best, req, now)
        return load_best


def make_router(policy: str = "round_robin", metric: str = "queue",
                **kw) -> Router:
    if policy == "round_robin":
        return RoundRobinRouter()
    if policy == "load_based":
        return LoadBasedRouter(metric)
    if policy == "heavy_light":
        return HeavyLightRouter(metric=metric, **kw)
    if policy == "prefix_affinity":
        return PrefixAffinityRouter(metric=metric, **kw)
    raise ValueError(policy)
