"""Routing & load balancing (paper §III-B1): Round-Robin, Load-based and
Heavy-Light split, each parameterizable by 4 load metrics (input len, output
len, KV size, tokens remaining) — the paper's "up to nine distinct routing
strategies". Modular: subclass Router and register.
"""
from __future__ import annotations

import itertools
from typing import Dict, List, Optional

from repro.core.client import Client
from repro.core.request import Request

LOAD_METRICS = ("queue", "input_len", "output_len", "kv_size",
                "kv_pressure", "tokens_remaining")


class Router:
    name = "base"

    def route(self, req: Request, candidates: List[Client], now: float) -> Client:
        raise NotImplementedError


class RoundRobinRouter(Router):
    name = "round_robin"

    def __init__(self):
        self._counters: Dict[str, itertools.count] = {}

    def route(self, req, candidates, now):
        key = req.current_stage.kind
        c = self._counters.setdefault(key, itertools.count())
        return candidates[next(c) % len(candidates)]


class LoadBasedRouter(Router):
    name = "load_based"

    def __init__(self, metric: str = "queue"):
        assert metric in LOAD_METRICS, metric
        self.metric = metric

    def route(self, req, candidates, now):
        return min(candidates, key=lambda c: c.load(self.metric))


class HeavyLightRouter(Router):
    """Heavy-light split [26]: long requests go to a dedicated heavy pool so
    short interactive requests never queue behind them."""

    name = "heavy_light"

    def __init__(self, threshold_tokens: int = 4096, heavy_frac: float = 0.25,
                 metric: str = "queue"):
        self.threshold = threshold_tokens
        self.heavy_frac = heavy_frac
        self.metric = metric

    def route(self, req, candidates, now):
        n_heavy = max(1, int(len(candidates) * self.heavy_frac))
        heavy, light = candidates[:n_heavy], candidates[n_heavy:] or candidates
        work = req.input_tokens + req.output_tokens * req.branches
        pool = heavy if work >= self.threshold else light
        return min(pool, key=lambda c: c.load(self.metric))


def make_router(policy: str = "round_robin", metric: str = "queue",
                **kw) -> Router:
    if policy == "round_robin":
        return RoundRobinRouter()
    if policy == "load_based":
        return LoadBasedRouter(metric)
    if policy == "heavy_light":
        return HeavyLightRouter(metric=metric, **kw)
    raise ValueError(policy)
