"""Discrete-event machinery: global clock + ordered event queue."""
from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass, field
from typing import Any, Callable, Optional

# Event kinds (paper §III-B: request events + client events, plus the
# extensions for comm, faults and elastic scaling)
REQUEST_ARRIVAL = "request_arrival"
STAGE_DISPATCH = "stage_dispatch"          # request handed to a client
CLIENT_STEP_DONE = "client_step_done"      # one engine step completed
TRANSFER_DONE = "transfer_done"            # inter-client data transfer done
CLIENT_FAIL = "client_fail"
CLIENT_RECOVER = "client_recover"
CLIENT_ADD = "client_add"                  # elastic scale-out
CLIENT_REMOVE = "client_remove"
STRAGGLER_CHECK = "straggler_check"        # per-dispatch rescue deadline
PREFIX_MIGRATE = "prefix_migrate"          # start shipping a radix KV chain
MIGRATE_DONE = "migrate_done"              # migrated chain landed at dst
AUTOSCALE_CHECK = "autoscale_check"        # periodic closed-loop controller tick


@dataclass(order=True)
class Event:
    time: float
    seq: int
    kind: str = field(compare=False)
    payload: Any = field(compare=False, default=None)


class EventQueue:
    def __init__(self):
        self._heap = []
        self._counter = itertools.count()
        self.now = 0.0
        self.popped = 0     # lifetime pops — the simulator-cost metric

    def push(self, time: float, kind: str, payload=None) -> Event:
        assert time >= self.now - 1e-12, (time, self.now, kind)
        ev = Event(time, next(self._counter), kind, payload)
        heapq.heappush(self._heap, ev)
        return ev

    def pop(self) -> Optional[Event]:
        if not self._heap:
            return None
        ev = heapq.heappop(self._heap)
        # global clock: monotone, no client may run ahead (paper §III-B)
        self.now = max(self.now, ev.time)
        self.popped += 1
        return ev

    def __len__(self):
        return len(self._heap)

    def peek_time(self) -> Optional[float]:
        return self._heap[0].time if self._heap else None
