"""train_step / prefill_step / serve_step + input_specs for every shape.

These are the functions the launcher lowers for the dry-run and the engine
executes for real serving; they are pure and pjit-friendly.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import transformer as tf
from repro.models.optim import OptConfig, adamw_update, init_opt_state
from repro.models.sharding import ShardingRules


def cross_entropy(logits, labels, mask=None):
    """logits (b, s, V); labels (b, s) int32. Reduction always in fp32."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        nll = nll * mask
        return jnp.sum(nll) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)


def loss_fn(params, batch: Dict, cfg: ModelConfig, rules=None, mesh=None):
    kwargs = {}
    if cfg.stub_frontend and "embeds" in batch:
        kwargs["embeds"] = batch["embeds"]
    else:
        kwargs["tokens"] = batch["tokens"]
    logits, _, aux = tf.forward(params, cfg, mode="train", rules=rules,
                                mesh=mesh, **kwargs)
    loss = cross_entropy(logits, batch["labels"], batch.get("mask"))
    return loss + aux, (loss, aux)


def train_step(state: Dict, batch: Dict, cfg: ModelConfig,
               opt: OptConfig = OptConfig(), rules=None, mesh=None):
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)
    (total, (loss, aux)), grads = grad_fn(state["params"], batch, cfg, rules, mesh)
    new_params, new_opt, gnorm = adamw_update(state["params"], grads,
                                              state["opt"], opt)
    new_state = {"params": new_params, "opt": new_opt}
    metrics = {"loss": loss, "aux_loss": aux, "grad_norm": gnorm}
    return new_state, metrics


def prefill_step(params, batch: Dict, cfg: ModelConfig, max_len: int,
                 rules=None, mesh=None):
    """Full-sequence prefill, writes KV caches. Returns (last_logits, caches)."""
    if cfg.encoder_only:
        kwargs = {"embeds": batch["embeds"]} if cfg.stub_frontend else \
                 {"tokens": batch["tokens"]}
        logits, _, _ = tf.forward(params, cfg, mode="train", rules=rules,
                                  mesh=mesh, **kwargs)
        return logits, None
    b = (batch["embeds"].shape[0] if cfg.stub_frontend and "embeds" in batch
         else batch["tokens"].shape[0])
    caches = tf.init_cache(cfg, b, max_len)
    kwargs = {}
    if cfg.stub_frontend and "embeds" in batch:
        kwargs["embeds"] = batch["embeds"]
    else:
        kwargs["tokens"] = batch["tokens"]
    logits, caches, _ = tf.forward(params, cfg, mode="prefill", caches=caches,
                                   rules=rules, mesh=mesh, **kwargs)
    return logits, caches


def serve_step(params, tokens, caches, cfg: ModelConfig, rules=None, mesh=None):
    """One decode step: tokens (b, 1) -> (new_token (b,), logits, caches).

    ``caches`` may be either the dense per-slot pytree (``tf.init_cache``)
    or the paged pool pytree (``tf.init_paged_cache``); the attention layer
    dispatches on the cache structure."""
    logits, caches, _ = tf.forward(params, cfg, tokens=tokens, mode="decode",
                                   caches=caches, rules=rules, mesh=mesh)
    new_token = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return new_token, logits, caches


def chunk_step(params, tokens, q_valid, caches, cfg: ModelConfig,
               rules=None, mesh=None):
    """One chunked-prefill step: tokens (b, s) holds a left-aligned chunk per
    row, q_valid (b,) its valid length (0 for rows not chunking this pass).
    Returns (new_token (b,), logits (b, V), caches) where ``new_token`` is
    the greedy continuation after each row's last valid chunk position —
    meaningful only for rows whose chunk COMPLETES the prompt; the engine
    ignores it otherwise. ``caches`` must be the paged pool pytree."""
    logits, caches, _ = tf.forward(params, cfg, tokens=tokens, mode="chunk",
                                   caches=caches, rules=rules, mesh=mesh,
                                   q_valid=q_valid)
    new_token = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return new_token, logits, caches


def verify_step(params, tokens, q_valid, caches, cfg: ModelConfig,
                rules=None, mesh=None):
    """One speculative-verify step: tokens (b, s) holds a left-aligned feed
    per row — the last committed token followed by its draft continuation —
    with q_valid (b,) the per-row feed length (0 for rows sitting this pass
    out). Returns (greedy (b, s), logits (b, s, V), caches): ``greedy[:, j]``
    is the argmax after feed position j, bit-identical to what sequential
    one-token decode would emit there, so the engine accepts the longest
    prefix of draft tokens matching ``greedy[:, :-1]`` plus the bonus token.
    ``caches`` must be the paged pool pytree with fork-grown tables covering
    ``length + q_valid`` slots per live row."""
    logits, caches, _ = tf.forward(params, cfg, tokens=tokens, mode="verify",
                                   caches=caches, rules=rules, mesh=mesh,
                                   q_valid=q_valid)
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return greedy, logits, caches


# ---------------------------------------------------------------------------
# paged-cache page movement (pure; jitted by the engines)
# ---------------------------------------------------------------------------

def write_prefill_pages(caches, dense, ids, *, max_blocks: int,
                        block_tokens: int):
    """Blockify a dense single-request prefill cache (``(L, 1, S, kvh, hd)``
    leaves, ``S == max_blocks * block_tokens``) and scatter its blocks into
    the paged pools at physical pages ``ids`` (``(max_blocks,)`` int32,
    trash-padded past the request's blocks)."""
    out = {}
    for name, g in caches.items():
        d, gg = dense[name], dict(g)
        for ck, pk in (("k", "k_pool"), ("v", "v_pool")):
            leaf = d[ck]                        # (L, 1, S, kvh, hd)
            L = leaf.shape[0]
            blocks = leaf[:, 0].reshape(L, max_blocks, block_tokens,
                                        *leaf.shape[3:])
            gg[pk] = g[pk].at[:, ids].set(blocks.astype(g[pk].dtype))
        out[name] = gg
    return out


def gather_pages(caches, ids):
    """Pull physical pages ``ids`` out of every paged cache group:
    ``{group: {"k": (L, n, bt, kvh, hd), "v": ...}}`` — the page payload for
    swap-out and for the disaggregated prefill->decode handoff."""
    return {name: {"k": g["k_pool"][:, ids], "v": g["v_pool"][:, ids]}
            for name, g in caches.items()}


def scatter_pages(caches, pages, ids):
    """Inverse of ``gather_pages``: write page payloads back into the pools
    at physical pages ``ids`` (swap-in resume; decode-side page import)."""
    out = {}
    for name, g in caches.items():
        gg = dict(g)
        gg["k_pool"] = g["k_pool"].at[:, ids].set(pages[name]["k"])
        gg["v_pool"] = g["v_pool"].at[:, ids].set(pages[name]["v"])
        out[name] = gg
    return out


def copy_pages(caches, src, dst):
    """Device-copy pages ``src`` onto pages ``dst`` within the same pools
    (COW materialization for speculative forks)."""
    out = {}
    for name, g in caches.items():
        gg = dict(g)
        gg["k_pool"] = g["k_pool"].at[:, dst].set(g["k_pool"][:, src])
        gg["v_pool"] = g["v_pool"].at[:, dst].set(g["v_pool"][:, src])
        out[name] = gg
    return out


def init_train_state(cfg: ModelConfig, key):
    params, _ = tf.init_model(cfg, key)
    return {"params": params, "opt": init_opt_state(params)}


# ---------------------------------------------------------------------------
# abstract inputs for the dry-run (no allocation)
# ---------------------------------------------------------------------------

def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict:
    """ShapeDtypeStruct stand-ins for every model input of a given shape."""
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if shape.kind == "train":
        if cfg.stub_frontend:
            return {"embeds": jax.ShapeDtypeStruct((b, s, cfg.frontend_dim),
                                                   jnp.bfloat16),
                    "labels": jax.ShapeDtypeStruct((b, s), i32)}
        return {"tokens": jax.ShapeDtypeStruct((b, s), i32),
                "labels": jax.ShapeDtypeStruct((b, s), i32)}
    if shape.kind == "prefill":
        if cfg.stub_frontend:
            return {"embeds": jax.ShapeDtypeStruct((b, s, cfg.frontend_dim),
                                                   jnp.bfloat16)}
        return {"tokens": jax.ShapeDtypeStruct((b, s), i32)}
    if shape.kind == "decode":
        return {"tokens": jax.ShapeDtypeStruct((b, 1), i32)}
    raise ValueError(shape.kind)


def batch_axes(cfg: ModelConfig, shape: ShapeConfig) -> Dict:
    """Logical axes for every entry of input_specs."""
    if shape.kind == "train":
        if cfg.stub_frontend:
            return {"embeds": ("batch", "seq", None), "labels": ("batch", "seq")}
        return {"tokens": ("batch", "seq"), "labels": ("batch", "seq")}
    if shape.kind == "prefill":
        if cfg.stub_frontend:
            return {"embeds": ("batch", "seq", None)}
        return {"tokens": ("batch", "seq")}
    return {"tokens": ("batch", None)}
