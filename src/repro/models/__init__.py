"""JAX model zoo: unified transformer covering all assigned architectures."""
