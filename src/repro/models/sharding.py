"""Logical-axis sharding rules -> concrete PartitionSpecs.

Every parameter / activation dimension is tagged with a *logical* axis name at
creation time (see ``layers.Initializer``). This module resolves logical axes
to mesh axes with divisibility-aware fallbacks, so the same model code shards
correctly on the single-pod (16,16) and multi-pod (2,16,16) production meshes
as well as on a 1-device CPU mesh for smoke tests.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# Ordered fallback chains: the first mesh-axis group that (a) exists in the
# mesh and (b) evenly divides the dimension wins. ``None`` => replicate.
# "fsdp" is a virtual mesh-axis group resolved to the data-parallel axes when
# FSDP weight sharding is enabled (large archs / training).
LOGICAL_RULES: Dict[str, Sequence[Optional[Tuple[str, ...]]]] = {
    # activations
    "batch": (("pod", "data"), ("data",)),
    "seq": (None,),                      # seq replicated by default
    "seq_shard": (("pod", "data"), ("data",)),  # long-context: shard sequence
    "embed": (None,),
    "act_ff": (("model",),),
    "act_heads": (("model",),),
    # weights
    "w_embed": (None,),                  # overridden to dp axes under FSDP
    "heads": (("model",),),
    "kv_heads": (("model",),),
    "head_dim": (None,),
    # fallback: if the heads dim could not take "model" (not divisible), the
    # taken-set is free and head_dim takes it instead (MQA / small-head archs)
    "head_dim_shard": (("model",),),
    "ff": (("model",),),
    "vocab": (("model",),),
    "experts": (("model",),),
    "kv_lora": (("model",),),
    "q_lora": (("model",),),
    "ssm_inner": (("model",),),
    "ssm_heads": (("model",),),
    "attn_qseq": (("model",),),          # seq-sharded attention fallback
    # v2 KV-cache layout: grab every free axis for the cache sequence dim
    "cache_seq": (("pod", "data", "model"), ("data", "model"), ("model",), None),
    "state": (None,),
    "conv": (None,),
    "scan": (None,),                     # stacked-layer leading dim
    "norm": (None,),
}


class ShardingRules:
    """Resolves logical axes against a mesh (+ optional FSDP override)."""

    def __init__(self, mesh: Mesh, fsdp: bool = False, seq_sharded: bool = False):
        self.mesh = mesh
        self.axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        self.rules = dict(LOGICAL_RULES)
        if fsdp:
            # ZeRO-3 style: shard the d_model dim of weights over the DP axes.
            self.rules["w_embed"] = (("pod", "data"), ("data",), None)
        if seq_sharded:
            # long-context single-request: batch cannot shard; shard seq.
            self.rules["seq"] = (("pod", "data"), ("data",), None)
            self.rules["batch"] = (None,)

    def _axis_group_size(self, group: Tuple[str, ...]) -> int:
        return math.prod(self.axis_sizes[a] for a in group)

    def _resolve_axis(self, logical: Optional[str], dim: int, taken: set):
        if logical is None:
            return None
        for group in self.rules.get(logical, (None,)):
            if group is None:
                return None
            if not all(a in self.axis_sizes for a in group):
                continue
            if any(a in taken for a in group):
                continue
            if dim % self._axis_group_size(group) != 0:
                continue
            return group if len(group) > 1 else group[0]
        return None

    # primary TP dims claim the mesh axis before fallback dims get a chance,
    # regardless of their position in the shape
    _PRIORITY = {"heads": 0, "kv_heads": 0, "ff": 0, "vocab": 0, "experts": 0,
                 "ssm_inner": 0, "batch": 0, "head_dim_shard": 1,
                 "kv_lora": 1, "q_lora": 1, "attn_qseq": 1, "cache_seq": 1}

    def spec(self, shape: Sequence[int], logical_axes: Sequence[Optional[str]]) -> P:
        assert len(shape) == len(logical_axes), (shape, logical_axes)
        taken: set = set()
        entries: list = [None] * len(shape)
        order = sorted(range(len(shape)),
                       key=lambda i: (self._PRIORITY.get(logical_axes[i], 2), i))
        for i in order:
            r = self._resolve_axis(logical_axes[i], shape[i], taken)
            if r is not None:
                taken.update((r,) if isinstance(r, str) else r)
            entries[i] = r
        return P(*entries)

    def sharding(self, shape, logical_axes) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(shape, logical_axes))


def _is_axes_leaf(x) -> bool:
    return isinstance(x, tuple) and all(isinstance(e, (str, type(None))) for e in x)


def tree_specs(rules: ShardingRules, abstract_params, axes_tree):
    """Map a pytree of ShapeDtypeStructs + parallel axes tree -> PartitionSpecs."""
    return jax.tree.map(
        lambda axes, leaf: rules.spec(leaf.shape, axes),
        axes_tree,
        abstract_params,
        is_leaf=_is_axes_leaf,
    )


def tree_shardings(rules: ShardingRules, abstract_params, axes_tree):
    specs = tree_specs(rules, abstract_params, axes_tree)
    return jax.tree.map(lambda s: NamedSharding(rules.mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


def constrain(x, rules: ShardingRules, logical_axes):
    """with_sharding_constraint by logical axes (no-op off-mesh)."""
    try:
        spec = rules.spec(x.shape, logical_axes)
        return jax.lax.with_sharding_constraint(x, NamedSharding(rules.mesh, spec))
    except Exception:
        return x
