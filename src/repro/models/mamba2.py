"""Mamba2 (SSD) block: chunked parallel scan for train/prefill, O(1)-state
recurrent step for decode. Follows the SSD formulation of Mamba2 (scalar
per-head decay, grouped B/C with ngroups=1).

Chunking keeps prefill sub-quadratic: within-chunk quadratic term + an
inter-chunk recurrent state (b, heads, state, head_dim) carried by lax.scan.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import Initializer, rms_norm


def _dims(cfg: ModelConfig):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    nheads = d_inner // s.head_dim
    return d_inner, nheads, s.state_dim, s.head_dim, s.conv_width


def init_mamba2(init: Initializer, path: str, cfg: ModelConfig) -> Dict:
    d = cfg.d_model
    d_in, nh, n, hd, cw = _dims(cfg)
    conv_dim = d_in + 2 * n
    import numpy as np
    return {
        "in_proj": init.w(f"{path}.in_proj", (d, 2 * d_in + 2 * n + nh),
                          ("w_embed", "ssm_inner")),
        "conv_w": init.w(f"{path}.conv_w", (cw, conv_dim), ("conv", "ssm_inner"),
                         scale=1.0 / cw),
        "conv_b": init.z(f"{path}.conv_b", (conv_dim,), ("ssm_inner",)),
        "A_log": init.const(f"{path}.A_log", np.zeros((nh,)), ("ssm_heads",)),
        "D": init.ones(f"{path}.D", (nh,), ("ssm_heads",)),
        "dt_bias": init.z(f"{path}.dt_bias", (nh,), ("ssm_heads",)),
        "norm": init.z(f"{path}.norm", (d_in,), ("ssm_inner",)),
        "out_proj": init.z(f"{path}.out_proj", (d_in, d), ("ssm_inner", "w_embed")),
    }


def _split_proj(zxbcdt, cfg: ModelConfig):
    d_in, nh, n, hd, _ = _dims(cfg)
    z = zxbcdt[..., :d_in]
    xbc = zxbcdt[..., d_in:d_in + d_in + 2 * n]
    dt = zxbcdt[..., d_in + d_in + 2 * n:]
    return z, xbc, dt


def _causal_conv(xbc, conv_w, conv_b, conv_state=None):
    """Depthwise causal conv. xbc: (b, l, C); conv_w: (w, C).

    If conv_state (b, w-1, C) is given (decode), prepend it; returns also the
    new conv state."""
    w = conv_w.shape[0]
    if conv_state is None:
        pad = jnp.zeros((xbc.shape[0], w - 1, xbc.shape[2]), xbc.dtype)
    else:
        pad = conv_state.astype(xbc.dtype)
    xp = jnp.concatenate([pad, xbc], axis=1)
    out = sum(xp[:, i:i + xbc.shape[1], :] * conv_w[i][None, None] for i in range(w))
    out = jax.nn.silu(out + conv_b[None, None])
    new_state = xp[:, -(w - 1):, :]
    return out, new_state


def _ssd_chunked(xh, dt, B, C, A, chunk: int):
    """SSD core.

    xh: (b, l, h, p); dt: (b, l, h) (post-softplus); B, C: (b, l, n);
    A: (h,) negative. Returns (y (b,l,h,p), final_state (b,h,n,p)).
    """
    b, l, h, p = xh.shape
    n = B.shape[-1]
    chunk = min(chunk, l)
    assert l % chunk == 0, (l, chunk)
    c = l // chunk
    r = lambda t: t.reshape(b, c, chunk, *t.shape[2:])
    xh, dt, B, C = r(xh), r(dt), r(B), r(C)

    la = dt * A[None, None, None]                        # (b,c,q,h) log-decay <= 0
    cum = jnp.cumsum(la, axis=2)                         # inclusive cumsum
    # intra-chunk: M[t,s] = C_t.B_s * exp(cum_t - cum_s) * dt_s   (s <= t)
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # (b,c,t,s,h)
    mask = jnp.tril(jnp.ones((chunk, chunk), bool))
    # mask BEFORE exp: masked (s > t) entries are positive and overflow exp,
    # which would poison gradients through the where.
    seg = jnp.where(mask[None, None, :, :, None], seg, -1e30)
    decay = jnp.exp(seg)
    cb = jnp.einsum("bctn,bcsn->bcts", C, B)
    y_intra = jnp.einsum("bcts,bctsh,bcsh,bcshp->bcthp",
                         cb.astype(jnp.float32), decay,
                         dt.astype(jnp.float32), xh.astype(jnp.float32))

    # chunk summary states: S_c = sum_s exp(cum_Q - cum_s) dt_s B_s (x) x_s
    tail = jnp.exp(cum[:, :, -1:, :] - cum)              # (b,c,q,h)
    S = jnp.einsum("bcsh,bcsh,bcsn,bcshp->bchnp",
                   tail, dt.astype(jnp.float32), B.astype(jnp.float32),
                   xh.astype(jnp.float32))
    chunk_decay = jnp.exp(cum[:, :, -1, :])              # (b,c,h)

    def scan_fn(carry, inp):
        S_c, dec = inp
        new = carry * dec[..., None, None] + S_c
        return new, carry                                 # emit state BEFORE chunk

    init_state = jnp.zeros((b, h, n, p), jnp.float32)
    final, prev_states = jax.lax.scan(
        scan_fn, init_state,
        (jnp.moveaxis(S, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    prev_states = jnp.moveaxis(prev_states, 0, 1)        # (b,c,h,n,p)

    y_inter = jnp.einsum("bctn,bcth,bchnp->bcthp",
                         C.astype(jnp.float32), jnp.exp(cum), prev_states)
    y = (y_intra + y_inter).reshape(b, l, h, p)
    return y, final


def mamba2_forward(params, x, cfg: ModelConfig,
                   return_state: bool = False):
    """x: (b, l, d) -> (y (b, l, d), state dict or None)."""
    d_in, nh, n, hd, cw = _dims(cfg)
    zxbcdt = x @ params["in_proj"]
    z, xbc, dt_raw = _split_proj(zxbcdt, cfg)
    xbc, conv_state = _causal_conv(xbc, params["conv_w"], params["conv_b"])
    xs = xbc[..., :d_in]
    B = xbc[..., d_in:d_in + n]
    C = xbc[..., d_in + n:]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    xh = xs.reshape(*xs.shape[:2], nh, hd)
    y, final = _ssd_chunked(xh, dt, B, C, A, cfg.ssm.chunk_size)
    y = y + params["D"].astype(jnp.float32)[None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(*x.shape[:2], d_in).astype(x.dtype)
    y = y * jax.nn.silu(z)
    y = rms_norm(y, params["norm"], cfg.norm_eps)
    out = y @ params["out_proj"]
    state = None
    if return_state:
        state = {"conv": conv_state, "ssm": final.astype(jnp.float32)}
    return out, state


def mamba2_decode(params, x, cfg: ModelConfig, state: Dict):
    """One-token step. x: (b, 1, d); state: conv (b, w-1, C), ssm (b,h,n,p)."""
    d_in, nh, n, hd, cw = _dims(cfg)
    zxbcdt = x @ params["in_proj"]
    z, xbc, dt_raw = _split_proj(zxbcdt, cfg)
    xbc, conv_state = _causal_conv(xbc, params["conv_w"], params["conv_b"],
                                   conv_state=state["conv"])
    xs = xbc[..., :d_in]
    B = xbc[..., d_in:d_in + n]
    C = xbc[..., d_in + n:]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    xh = xs.reshape(xs.shape[0], 1, nh, hd).astype(jnp.float32)
    decay = jnp.exp(dt[:, 0, :] * A[None])               # (b,h)
    contrib = jnp.einsum("bh,bn,bhp->bhnp", dt[:, 0, :],
                         B[:, 0].astype(jnp.float32), xh[:, 0])
    ssm = state["ssm"] * decay[..., None, None] + contrib
    y = jnp.einsum("bn,bhnp->bhp", C[:, 0].astype(jnp.float32), ssm)
    y = y + params["D"].astype(jnp.float32)[None, :, None] * xh[:, 0]
    y = y.reshape(x.shape[0], 1, d_in).astype(x.dtype)
    y = y * jax.nn.silu(z)
    y = rms_norm(y, params["norm"], cfg.norm_eps)
    out = y @ params["out_proj"]
    return out, {"conv": conv_state, "ssm": ssm}


def mamba2_state_spec(cfg: ModelConfig, batch: int):
    d_in, nh, n, hd, cw = _dims(cfg)
    conv_dim = d_in + 2 * n
    return {
        "conv": jax.ShapeDtypeStruct((batch, cw - 1, conv_dim), jnp.bfloat16),
        "ssm": jax.ShapeDtypeStruct((batch, nh, n, hd), jnp.float32),
    }


def mamba2_state_axes():
    return {"conv": ("batch", None, "ssm_inner"),
            "ssm": ("batch", "ssm_heads", None, None)}


def mamba2_reference(params, x, cfg: ModelConfig):
    """Naive token-by-token recurrence (oracle for tests)."""
    d_in, nh, n, hd, cw = _dims(cfg)
    b, l, _ = x.shape
    state = {"conv": jnp.zeros((b, cw - 1, d_in + 2 * n), jnp.float32),
             "ssm": jnp.zeros((b, nh, n, hd), jnp.float32)}
    outs = []
    for t in range(l):
        o, state = mamba2_decode(params, x[:, t:t + 1], cfg, state)
        outs.append(o)
    return jnp.concatenate(outs, axis=1)
