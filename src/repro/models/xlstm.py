"""xLSTM blocks: mLSTM (matrix memory, chunkwise-parallel) and sLSTM
(stabilized scalar-memory recurrence). Follows arXiv:2405.04517 with the
standard log-space stabilization.

mLSTM prefill uses a chunkwise form (within-chunk parallel quadratic term +
inter-chunk recurrent (C, n, m) state) so prefill stays sub-quadratic and
decode is O(1) per token. sLSTM is inherently sequential and runs as a
lax.scan over time.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import Initializer, rms_norm


def _mlstm_dims(cfg: ModelConfig):
    d_in = int(cfg.xlstm.proj_factor_mlstm * cfg.d_model)
    nh = cfg.num_heads
    hd = d_in // nh
    return d_in, nh, hd


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def init_mlstm(init: Initializer, path: str, cfg: ModelConfig) -> Dict:
    d = cfg.d_model
    d_in, nh, hd = _mlstm_dims(cfg)
    return {
        "up": init.w(f"{path}.up", (d, 2, d_in), ("w_embed", None, "ssm_inner")),
        "wq": init.w(f"{path}.wq", (d_in, d_in), ("ssm_inner", None)),
        "wk": init.w(f"{path}.wk", (d_in, d_in), ("ssm_inner", None)),
        "wv": init.w(f"{path}.wv", (d_in, d_in), ("ssm_inner", None)),
        "wif": init.w(f"{path}.wif", (d_in, 2, nh), ("ssm_inner", None, "ssm_heads"),
                      scale=0.01),
        "b_if": init.const(f"{path}.b_if",
                           __import__("numpy").concatenate(
                               [__import__("numpy").full((1, nh), -3.0),
                                __import__("numpy").full((1, nh), 3.0)]),
                           (None, "ssm_heads")),
        "norm": init.z(f"{path}.norm", (d_in,), ("ssm_inner",)),
        "down": init.z(f"{path}.down", (d_in, d), ("ssm_inner", "w_embed")),
    }


def _mlstm_chunked(q, k, v, li, lf, chunk: int, unroll: bool = False):
    """Stabilized chunkwise mLSTM.

    q,k,v: (b, l, h, p); li (log input gate) / lf (log forget gate): (b, l, h).
    Returns y (b,l,h,p) and final state (C (b,h,p,p), n (b,h,p), m (b,h)).
    """
    b, l, h, p = q.shape
    chunk = min(chunk, l)
    assert l % chunk == 0
    c = l // chunk
    r = lambda t: t.reshape(b, c, chunk, *t.shape[2:])
    q, k, v, li, lf = r(q), r(k), r(v), r(li), r(lf)
    q = q.astype(jnp.float32) * (p ** -0.5)
    k = k.astype(jnp.float32)
    v = v.astype(jnp.float32)

    cum = jnp.cumsum(lf, axis=2)                              # inclusive
    # intra-chunk log weights: w[t,s] = cum_t - cum_s + li_s (s <= t)
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :] + li[:, :, None, :, :]
    mask = jnp.tril(jnp.ones((chunk, chunk), bool))
    seg = jnp.where(mask[None, None, :, :, None], seg, -1e30)

    # chunk-summary (state) log weights: wS[s] = cum_Q - cum_s + li_s
    wS = cum[:, :, -1:, :] - cum + li                          # (b,c,q,h)
    mS_local = jnp.max(wS, axis=2)                             # (b,c,h)

    def scan_fn(carry, inp):
        C_prev, n_prev, m_prev = carry                         # (b,h,p,p),(b,h,p),(b,h)
        seg_c, wS_c, mSl_c, cum_c, q_c, k_c, v_c = inp
        # position-wise stabilizer: intra max vs decayed state stabilizer
        m_intra = jnp.max(seg_c, axis=2)                   # (b,t,h)
        m_state = cum_c + m_prev[:, None, :]                   # (b,t,h)
        m_t = jnp.maximum(m_intra, m_state)
        w_intra = jnp.exp(seg_c - m_t[:, :, None, :])          # (b,t,s,h)
        w_state = jnp.exp(m_state - m_t)                       # (b,t,h)
        scores = jnp.einsum("bthp,bshp->btsh", q_c, k_c)
        num = (jnp.einsum("btsh,btsh,bshp->bthp", scores, w_intra, v_c)
               + jnp.einsum("bthp,bhpx,bth->bthx", q_c, C_prev, w_state))
        den = (jnp.einsum("btsh,btsh->bth", scores, w_intra)
               + jnp.einsum("bthp,bhp,bth->bth", q_c, n_prev, w_state))
        y = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_t))[..., None]
        # state update
        m_new = jnp.maximum(cum_c[:, -1, :] + m_prev, mSl_c)
        wS_st = jnp.exp(wS_c - m_new[:, None, :])              # (b,s,h)
        dec = jnp.exp(cum_c[:, -1, :] + m_prev - m_new)        # (b,h)
        C_new = (C_prev * dec[..., None, None]
                 + jnp.einsum("bsh,bshp,bshx->bhpx", wS_st, k_c, v_c))
        n_new = n_prev * dec[..., None] + jnp.einsum("bsh,bshp->bhp", wS_st, k_c)
        return (C_new, n_new, m_new), y

    mv = lambda t: jnp.moveaxis(t, 1, 0)
    init_state = (jnp.zeros((b, h, p, p), jnp.float32),
                  jnp.zeros((b, h, p), jnp.float32),
                  jnp.full((b, h), -1e30, jnp.float32))
    final, ys = jax.lax.scan(scan_fn, init_state,
                             (mv(seg), mv(wS), mv(mS_local), mv(cum), mv(q),
                              mv(k), mv(v)),
                             unroll=c if unroll else 1)
    y = jnp.moveaxis(ys, 0, 1).reshape(b, l, h, p)
    return y, final


def mlstm_forward(params, x, cfg: ModelConfig, return_state: bool = False,
                  unroll_chunks: bool = False):
    d_in, nh, hd = _mlstm_dims(cfg)
    h2 = jnp.einsum("bld,dgf->blgf", x, params["up"])
    core_in, gate = h2[..., 0, :], h2[..., 1, :]
    q = (core_in @ params["wq"]).reshape(*x.shape[:2], nh, hd)
    k = (core_in @ params["wk"]).reshape(*x.shape[:2], nh, hd)
    v = (core_in @ params["wv"]).reshape(*x.shape[:2], nh, hd)
    if_gates = (jnp.einsum("blf,fgh->blgh", core_in, params["wif"])
                + params["b_if"][None].astype(x.dtype))
    li = if_gates[..., 0, :].astype(jnp.float32)               # log input gate
    lf = jax.nn.log_sigmoid(if_gates[..., 1, :].astype(jnp.float32))
    y, state = _mlstm_chunked(q, k, v, li, lf, cfg.xlstm.chunk_size,
                              unroll=unroll_chunks)
    y = y.reshape(*x.shape[:2], d_in).astype(x.dtype)
    y = rms_norm(y, params["norm"], cfg.norm_eps)
    y = y * jax.nn.silu(gate)
    out = y @ params["down"]
    return out, ({"C": state[0], "n": state[1], "m": state[2]} if return_state else None)


def mlstm_decode(params, x, cfg: ModelConfig, state: Dict):
    d_in, nh, hd = _mlstm_dims(cfg)
    h2 = jnp.einsum("bld,dgf->blgf", x, params["up"])
    core_in, gate = h2[..., 0, :], h2[..., 1, :]
    q = (core_in @ params["wq"]).reshape(-1, nh, hd).astype(jnp.float32) * (hd ** -0.5)
    k = (core_in @ params["wk"]).reshape(-1, nh, hd).astype(jnp.float32)
    v = (core_in @ params["wv"]).reshape(-1, nh, hd).astype(jnp.float32)
    if_gates = (jnp.einsum("blf,fgh->blgh", core_in, params["wif"])
                + params["b_if"][None].astype(x.dtype))
    li = if_gates[:, 0, 0, :].astype(jnp.float32)
    lf = jax.nn.log_sigmoid(if_gates[:, 0, 1, :].astype(jnp.float32))
    C, n, m = state["C"], state["n"], state["m"]
    m_new = jnp.maximum(lf + m, li)
    i_p = jnp.exp(li - m_new)
    f_p = jnp.exp(lf + m - m_new)
    C_new = C * f_p[..., None, None] + jnp.einsum("bh,bhp,bhx->bhpx", i_p, k, v)
    n_new = n * f_p[..., None] + i_p[..., None] * k
    num = jnp.einsum("bhp,bhpx->bhx", q, C_new)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhp,bhp->bh", q, n_new)), jnp.exp(-m_new))
    y = (num / den[..., None]).reshape(x.shape[0], 1, d_in).astype(x.dtype)
    y = rms_norm(y, params["norm"], cfg.norm_eps)
    y = y * jax.nn.silu(gate)
    out = y @ params["down"]
    return out, {"C": C_new, "n": n_new, "m": m_new}


def mlstm_state_spec(cfg: ModelConfig, batch: int):
    d_in, nh, hd = _mlstm_dims(cfg)
    return {"C": jax.ShapeDtypeStruct((batch, nh, hd, hd), jnp.float32),
            "n": jax.ShapeDtypeStruct((batch, nh, hd), jnp.float32),
            "m": jax.ShapeDtypeStruct((batch, nh), jnp.float32)}


def mlstm_state_axes():
    return {"C": ("batch", "ssm_heads", None, None),
            "n": ("batch", "ssm_heads", None),
            "m": ("batch", "ssm_heads")}


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def init_slstm(init: Initializer, path: str, cfg: ModelConfig) -> Dict:
    d = cfg.d_model
    nh = cfg.num_heads
    hd = d // nh
    f_up = int(cfg.xlstm.proj_factor_slstm * d)
    return {
        "wx": init.w(f"{path}.wx", (d, 4, d), ("w_embed", None, "ssm_inner")),
        "r": init.w(f"{path}.r", (nh, hd, 4, hd), ("ssm_heads", None, None, None),
                    scale=hd ** -0.5),
        "b": init.const(f"{path}.b",
                        __import__("numpy").concatenate(
                            [__import__("numpy").zeros((2, nh, hd)),
                             __import__("numpy").full((1, nh, hd), 3.0),
                             __import__("numpy").zeros((1, nh, hd))]),
                        (None, "ssm_heads", None)),
        "norm": init.z(f"{path}.norm", (d,), ("norm",)),
        "ff_wi": init.w(f"{path}.ff_wi", (d, 2, f_up), ("w_embed", None, "ff")),
        "ff_wo": init.z(f"{path}.ff_wo", (f_up, d), ("ff", "w_embed")),
    }


def _slstm_step(params, carry, gx, cfg: ModelConfig):
    """carry: (c, n, h, m) each (b, nh, hd); gx: (b, 4, d) pre-activations."""
    nh = cfg.num_heads
    hd = cfg.d_model // nh
    c, n, h, m = carry
    rec = jnp.einsum("bkh,khgx->bgkx", h, params["r"].astype(jnp.float32))
    g = gx.reshape(gx.shape[0], 4, nh, hd).astype(jnp.float32) + rec \
        + params["b"].astype(jnp.float32)[None]
    z = jnp.tanh(g[:, 0])
    li = g[:, 1]                                           # log input gate
    lf = jax.nn.log_sigmoid(g[:, 2])
    o = jax.nn.sigmoid(g[:, 3])
    m_new = jnp.maximum(lf + m, li)
    i_p = jnp.exp(li - m_new)
    f_p = jnp.exp(lf + m - m_new)
    c_new = f_p * c + i_p * z
    n_new = f_p * n + i_p
    h_new = o * c_new / jnp.maximum(n_new, 1e-6)
    return (c_new, n_new, h_new, m_new)


def slstm_forward(params, x, cfg: ModelConfig, state=None, return_state: bool = False):
    b, l, d = x.shape
    nh = cfg.num_heads
    hd = d // nh
    gx = jnp.einsum("bld,dgf->blgf", x, params["wx"])      # (b,l,4,d)
    if state is None:
        zeros = jnp.zeros((b, nh, hd), jnp.float32)
        carry = (zeros, zeros, zeros, jnp.full((b, nh, hd), -1e30, jnp.float32))
    else:
        carry = (state["c"], state["n"], state["h"], state["m"])

    def step(carry, gx_t):
        new = _slstm_step(params, carry, gx_t, cfg)
        return new, new[2]

    carry, hs = jax.lax.scan(step, carry, jnp.moveaxis(gx, 1, 0))
    y = jnp.moveaxis(hs, 0, 1).reshape(b, l, d).astype(x.dtype)
    y = rms_norm(y, params["norm"], cfg.norm_eps)
    # gated FFN tail (proj_factor_slstm)
    hff = jnp.einsum("bld,dgf->blgf", y, params["ff_wi"])
    y = (jax.nn.gelu(hff[..., 0, :]) * hff[..., 1, :]) @ params["ff_wo"]
    new_state = None
    if return_state or state is not None:
        new_state = {"c": carry[0], "n": carry[1], "h": carry[2], "m": carry[3]}
    return y, new_state


def slstm_state_spec(cfg: ModelConfig, batch: int):
    nh = cfg.num_heads
    hd = cfg.d_model // nh
    sd = jax.ShapeDtypeStruct((batch, nh, hd), jnp.float32)
    return {"c": sd, "n": sd, "h": sd, "m": sd}


def slstm_state_axes():
    a = ("batch", "ssm_heads", None)
    return {"c": a, "n": a, "h": a, "m": a}
