"""Attention: GQA/MQA (rope) and MLA (DeepSeek-V2 latent attention).

Prefill paths are causal (or bidirectional for encoder-only); decode paths
consume a static-length KV cache with per-request lengths. The inner
softmax(QK^T)V is routed through ``repro.kernels.ops`` which picks the Pallas
flash kernel on TPU and the jnp reference elsewhere.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import Initializer, apply_rope, init_norm, apply_norm

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_attention(init: Initializer, path: str, cfg: ModelConfig) -> Dict:
    d = cfg.d_model
    hd = cfg.resolved_head_dim
    if cfg.attn_type == "mla":
        m = cfg.mla
        p: Dict = {}
        if m.q_lora_rank:
            p["wdq"] = init.w(f"{path}.wdq", (d, m.q_lora_rank), ("w_embed", "q_lora"))
            p["q_norm"] = init_norm(init, f"{path}.q_norm", cfg, m.q_lora_rank)
            q_in = m.q_lora_rank
        else:
            q_in = d
        p["wuq"] = init.w(
            f"{path}.wuq",
            (q_in, cfg.num_heads, m.qk_nope_head_dim + m.qk_rope_head_dim),
            ("q_lora" if m.q_lora_rank else "w_embed", "heads", "head_dim"),
        )
        p["wdkv"] = init.w(f"{path}.wdkv", (d, m.kv_lora_rank), ("w_embed", "kv_lora"))
        p["wkr"] = init.w(f"{path}.wkr", (d, m.qk_rope_head_dim), ("w_embed", "head_dim"))
        p["kv_norm"] = init_norm(init, f"{path}.kv_norm", cfg, m.kv_lora_rank)
        p["wuk"] = init.w(f"{path}.wuk", (m.kv_lora_rank, cfg.num_heads, m.qk_nope_head_dim),
                          ("kv_lora", "heads", "head_dim"))
        p["wuv"] = init.w(f"{path}.wuv", (m.kv_lora_rank, cfg.num_heads, m.v_head_dim),
                          ("kv_lora", "heads", "head_dim"))
        p["wo"] = init.z(f"{path}.wo", (cfg.num_heads, m.v_head_dim, d),
                         ("heads", "head_dim", "w_embed"))
        return p
    # GQA / MQA / MHA. Baseline tags head_dim with the "head_dim_shard"
    # fallback (takes "model" only when heads couldn't). v2 drops it: rope's
    # rotate-half splits a head_dim-sharded tensor across shards and triggers
    # involuntary resharding, so v2 replicates the (small) attention weights
    # and relies on qseq/cache_seq sharding for the compute instead.
    hd_ax = "head_dim" if cfg.shard_v2 else "head_dim_shard"
    return {
        "wq": init.w(f"{path}.wq", (d, cfg.num_heads, hd),
                     ("w_embed", "heads", hd_ax)),
        "wk": init.w(f"{path}.wk", (d, cfg.num_kv_heads, hd),
                     ("w_embed", "kv_heads", hd_ax)),
        "wv": init.w(f"{path}.wv", (d, cfg.num_kv_heads, hd),
                     ("w_embed", "kv_heads", hd_ax)),
        "wo": init.z(f"{path}.wo", (cfg.num_heads, hd, d),
                     ("heads", hd_ax, "w_embed")),
    }


# ---------------------------------------------------------------------------
# core softmax attention (prefill, batched full-sequence)
# ---------------------------------------------------------------------------

def _sdpa(q, k, v, causal: bool, scale: float):
    """q: (b,s,nh,dq) k: (b,s,kvh,dq) v: (b,s,kvh,dv). GQA-aware reference."""
    from repro.kernels import ops  # lazy: avoids import cycle at module load

    return ops.flash_attention(q, k, v, causal=causal, scale=scale)


def _heads_shardable(cfg: ModelConfig, rules) -> bool:
    if rules is None:
        return True
    m = rules.axis_sizes.get("model", 1)
    return cfg.num_heads % m == 0


def _qseq_constrain(q, cfg, rules):
    """When heads can't shard over 'model', shard the QUERY sequence instead
    so the O(s*t) score computation still splits across the model axis."""
    if rules is None or _heads_shardable(cfg, rules) or q.shape[1] == 1:
        return q
    from repro.models.sharding import constrain
    return constrain(q, rules, ("batch", "attn_qseq", None, None))


def gqa_prefill(params, x, positions, cfg: ModelConfig,
                cache: Optional[Dict] = None,
                rules=None) -> Tuple[jnp.ndarray, Optional[Dict]]:
    """Full-sequence attention. If ``cache`` is given (pre-allocated), the
    computed K/V are written into it (inference prefill)."""
    hd = cfg.resolved_head_dim
    q = jnp.einsum("bsd,dnh->bsnh", x, params["wq"])
    k = jnp.einsum("bsd,dnh->bsnh", x, params["wk"])
    v = jnp.einsum("bsd,dnh->bsnh", x, params["wv"])
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    q = _qseq_constrain(q, cfg, rules)
    out = _sdpa(q, k, v, causal=not cfg.encoder_only, scale=hd ** -0.5)
    new_cache = None
    if cache is not None:
        S = cache["k"].shape[1]
        s = k.shape[1]
        pad = [(0, 0), (0, S - s), (0, 0), (0, 0)]
        new_cache = {
            "k": jnp.pad(k, pad).astype(cache["k"].dtype),
            "v": jnp.pad(v, pad).astype(cache["v"].dtype),
            "length": jnp.full(cache["length"].shape, s, jnp.int32),
        }
    out = jnp.einsum("bsnh,nhd->bsd", out, params["wo"])
    return out, new_cache


def gqa_decode(params, x, cfg: ModelConfig, cache: Dict) -> Tuple[jnp.ndarray, Dict]:
    """One-token decode against a static-length cache.

    x: (b, 1, d); cache k/v: (b, S, kvh, hd); cache["length"]: (b,) current
    number of valid tokens (the new token is written at that index). A paged
    cache (``k_pool`` present — see ``paged_cache_spec``) routes to
    ``gqa_decode_paged`` instead.
    """
    from repro.kernels import ops

    if "k_pool" in cache:
        return gqa_decode_paged(params, x, cfg, cache)
    hd = cfg.resolved_head_dim
    lengths = cache["length"]
    q = jnp.einsum("bsd,dnh->bsnh", x, params["wq"])
    k = jnp.einsum("bsd,dnh->bsnh", x, params["wk"])
    v = jnp.einsum("bsd,dnh->bsnh", x, params["wv"])
    pos = lengths[:, None]
    q = apply_rope(q, pos, cfg.rope_theta)
    k = apply_rope(k, pos, cfg.rope_theta)

    def upd(buf, new):
        def one(b, n, i):
            return jax.lax.dynamic_update_slice(b, n.astype(b.dtype), (i, 0, 0))
        return jax.vmap(one)(buf, new, lengths)

    k_cache = upd(cache["k"], k)
    v_cache = upd(cache["v"], v)
    out = ops.decode_attention(q, k_cache, v_cache, lengths + 1, scale=hd ** -0.5)
    out = jnp.einsum("bsnh,nhd->bsd", out, params["wo"])
    return out, {"k": k_cache, "v": v_cache, "length": lengths + 1}


def gqa_decode_paged(params, x, cfg: ModelConfig,
                     cache: Dict) -> Tuple[jnp.ndarray, Dict]:
    """One-token decode against a *paged* cache (block-table-indexed pool).

    The per-layer cache (see ``paged_cache_spec``) holds shared physical
    pools ``k_pool``/``v_pool`` of shape ``(num_pages, block_tokens, kvh,
    hd)`` plus per-request indirection: ``block_tables`` ``(b, max_blocks)``
    and ``length`` ``(b,)``. The new token's K/V is written at logical
    position ``length`` — physical slot ``(block_tables[i, length // bt],
    length % bt)`` — so the caller (the paged ``Engine``) must have grown the
    table to cover that position *before* the step, and must guarantee the
    written page is unshared (refcount 1). Dead batch rows follow the same
    contract as the dense path: their table points at the engine's trash page
    and their output row is garbage the caller ignores.
    """
    from repro.kernels import ops

    hd = cfg.resolved_head_dim
    lengths = cache["length"]
    tables = cache["block_tables"]
    k_pool, v_pool = cache["k_pool"], cache["v_pool"]
    bt, mb = k_pool.shape[1], tables.shape[1]
    q = jnp.einsum("bsd,dnh->bsnh", x, params["wq"])
    k = jnp.einsum("bsd,dnh->bsnh", x, params["wk"])
    v = jnp.einsum("bsd,dnh->bsnh", x, params["wv"])
    pos = lengths[:, None]
    q = apply_rope(q, pos, cfg.rope_theta)
    k = apply_rope(k, pos, cfg.rope_theta)

    blk = jnp.take_along_axis(tables,
                              jnp.minimum(lengths // bt, mb - 1)[:, None],
                              axis=1)[:, 0]
    slot = blk * bt + lengths % bt                 # (b,) flat pool row

    def upd(pool, new):
        flat = pool.reshape(-1, *pool.shape[2:])
        flat = flat.at[slot].set(new[:, 0].astype(pool.dtype))
        return flat.reshape(pool.shape)

    k_pool = upd(k_pool, k)
    v_pool = upd(v_pool, v)
    out = ops.paged_decode_attention(q, k_pool, v_pool, tables, lengths + 1,
                                     scale=hd ** -0.5)
    out = jnp.einsum("bsnh,nhd->bsd", out, params["wo"])
    return out, {"k_pool": k_pool, "v_pool": v_pool, "block_tables": tables,
                 "length": lengths + 1}


def gqa_prefill_paged(params, x, cfg: ModelConfig, cache: Dict,
                      q_valid: jnp.ndarray) -> Tuple[jnp.ndarray, Dict]:
    """Prefill a CHUNK of each request against a *paged* cache (the
    continuation-state path of chunked prefill).

    x: (b, s, d) — row ``r`` carries ``q_valid[r]`` valid chunk tokens
    (left-aligned; the rest is padding). The chunk starts at logical
    position ``cache["length"][r]``, i.e. everything before it is already
    written in the pools and serves as attention context. Chunk K/V is
    scattered into the pools first, then each query attends over cached
    context + the causal part of its own chunk via
    ``ops.paged_chunk_attention``.

    Write-safety contract: positions ``j >= q_valid[r]`` (padding, decode
    rows riding along with ``q_valid == 0``, dead rows) are routed to the
    trash page — by convention the LAST pool page — so a mixed iteration
    can never corrupt live pages. Valid positions may target prefix-shared
    pages (refcount > 1): sharers rewrite matched blocks bitwise
    identically (aliasing dedups memory, not compute), so concurrent
    readers of those pages are unperturbed.

    Numerics match whole-prompt ``gqa_prefill`` bitwise: same einsum/rope
    recipe per position, and the chunk attention mirrors
    ``flash_attention``'s fp32 path with exact-zero masked tails.
    """
    from repro.kernels import ops

    hd = cfg.resolved_head_dim
    lengths = cache["length"]
    tables = cache["block_tables"]
    k_pool, v_pool = cache["k_pool"], cache["v_pool"]
    bt, mb = k_pool.shape[1], tables.shape[1]
    b, s, _ = x.shape
    j = jnp.arange(s)[None, :]
    pos = lengths[:, None] + j                       # (b, s) logical pos
    valid_q = j < q_valid[:, None]                   # (b, s)

    q = jnp.einsum("bsd,dnh->bsnh", x, params["wq"])
    k = jnp.einsum("bsd,dnh->bsnh", x, params["wk"])
    v = jnp.einsum("bsd,dnh->bsnh", x, params["wv"])
    q = apply_rope(q, pos, cfg.rope_theta)
    k = apply_rope(k, pos, cfg.rope_theta)

    blk = jnp.take_along_axis(tables, jnp.clip(pos // bt, 0, mb - 1), axis=1)
    trash = k_pool.shape[0] - 1
    slot = jnp.where(valid_q, blk * bt + pos % bt, trash * bt + j % bt)

    def upd(pool, new):
        flat = pool.reshape(-1, *pool.shape[2:])
        flat = flat.at[slot.reshape(-1)].set(
            new.reshape(b * s, *new.shape[2:]).astype(pool.dtype))
        return flat.reshape(pool.shape)

    k_pool = upd(k_pool, k)
    v_pool = upd(v_pool, v)
    out = ops.paged_chunk_attention(q, k_pool, v_pool, tables, lengths,
                                    scale=hd ** -0.5)
    out = jnp.einsum("bsnh,nhd->bsd", out, params["wo"])
    return out, {"k_pool": k_pool, "v_pool": v_pool, "block_tables": tables,
                 "length": lengths + q_valid}


def gqa_verify_paged(params, x, cfg: ModelConfig, cache: Dict,
                     q_valid: jnp.ndarray) -> Tuple[jnp.ndarray, Dict]:
    """Speculative-verify pass against a *paged* cache: row ``r`` carries
    ``q_valid[r]`` feed tokens — the last committed token plus its draft
    continuation — occupying logical positions ``cache["length"][r] + j``.

    Scatter recipe (rope positions, trash-page routing of padding and dead
    rows, flat-slot K/V writes) is exactly ``gqa_prefill_paged``'s; the
    attention is ``ops.paged_verify_attention``, whose position ``j``
    output is bit-identical to a one-token ``gqa_decode_paged`` at the same
    position. That makes the verify logits for position ``j`` — given the
    same committed stream — bitwise equal to sequential decode logits, the
    property the engine's spec-vs-plain stream-equality contract rests on.

    The caller must have fork-grown the table to cover ``length + q_valid``
    slots, with every block in the write range private (refcount 1,
    unregistered) — ``PagedKVStore.fork_table`` guarantees both. Rejected
    positions' writes stay as garbage beyond the committed length; the
    exact-zero mask means no later pass can observe them, and
    ``commit_fork`` trims the pages they rode in on.
    """
    from repro.kernels import ops

    hd = cfg.resolved_head_dim
    lengths = cache["length"]
    tables = cache["block_tables"]
    k_pool, v_pool = cache["k_pool"], cache["v_pool"]
    bt, mb = k_pool.shape[1], tables.shape[1]
    b, s, _ = x.shape
    j = jnp.arange(s)[None, :]
    pos = lengths[:, None] + j                       # (b, s) logical pos
    valid_q = j < q_valid[:, None]                   # (b, s)

    q = jnp.einsum("bsd,dnh->bsnh", x, params["wq"])
    k = jnp.einsum("bsd,dnh->bsnh", x, params["wk"])
    v = jnp.einsum("bsd,dnh->bsnh", x, params["wv"])
    q = apply_rope(q, pos, cfg.rope_theta)
    k = apply_rope(k, pos, cfg.rope_theta)

    blk = jnp.take_along_axis(tables, jnp.clip(pos // bt, 0, mb - 1), axis=1)
    trash = k_pool.shape[0] - 1
    slot = jnp.where(valid_q, blk * bt + pos % bt, trash * bt + j % bt)

    def upd(pool, new):
        flat = pool.reshape(-1, *pool.shape[2:])
        flat = flat.at[slot.reshape(-1)].set(
            new.reshape(b * s, *new.shape[2:]).astype(pool.dtype))
        return flat.reshape(pool.shape)

    k_pool = upd(k_pool, k)
    v_pool = upd(v_pool, v)
    out = ops.paged_verify_attention(q, k_pool, v_pool, tables, lengths,
                                     scale=hd ** -0.5)
    out = jnp.einsum("bsnh,nhd->bsd", out, params["wo"])
    return out, {"k_pool": k_pool, "v_pool": v_pool, "block_tables": tables,
                 "length": lengths + q_valid}


# ---------------------------------------------------------------------------
# MLA
# ---------------------------------------------------------------------------

def _mla_qkv_prefill(params, x, positions, cfg: ModelConfig):
    m = cfg.mla
    if m.q_lora_rank:
        cq = apply_norm(params["q_norm"], x @ params["wdq"], cfg)
    else:
        cq = x
    q = jnp.einsum("bsd,dnh->bsnh", cq, params["wuq"])
    q_nope, q_rope = q[..., : m.qk_nope_head_dim], q[..., m.qk_nope_head_dim:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    c_kv = apply_norm(params["kv_norm"], x @ params["wdkv"], cfg)
    k_rope = apply_rope((x @ params["wkr"])[:, :, None, :], positions, cfg.rope_theta)
    return q_nope, q_rope, c_kv, k_rope


def mla_prefill(params, x, positions, cfg: ModelConfig,
                cache: Optional[Dict] = None,
                rules=None) -> Tuple[jnp.ndarray, Optional[Dict]]:
    m = cfg.mla
    q_nope, q_rope, c_kv, k_rope = _mla_qkv_prefill(params, x, positions, cfg)
    k_nope = jnp.einsum("bsl,lnh->bsnh", c_kv, params["wuk"])
    v = jnp.einsum("bsl,lnh->bsnh", c_kv, params["wuv"])
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope, jnp.broadcast_to(
        k_rope, (*k_nope.shape[:3], m.qk_rope_head_dim))], axis=-1)
    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5
    q = _qseq_constrain(q, cfg, rules)
    out = _sdpa(q, k, v, causal=not cfg.encoder_only, scale=scale)
    new_cache = None
    if cache is not None:
        S = cache["c_kv"].shape[1]
        s = c_kv.shape[1]
        new_cache = {
            "c_kv": jnp.pad(c_kv, [(0, 0), (0, S - s), (0, 0)]).astype(cache["c_kv"].dtype),
            "k_rope": jnp.pad(k_rope[:, :, 0, :], [(0, 0), (0, S - s), (0, 0)]).astype(
                cache["k_rope"].dtype),
            "length": jnp.full(cache["length"].shape, s, jnp.int32),
        }
    out = jnp.einsum("bsnh,nhd->bsd", out, params["wo"])
    return out, new_cache


def mla_decode(params, x, cfg: ModelConfig, cache: Dict) -> Tuple[jnp.ndarray, Dict]:
    """One-token MLA decode. Baseline path re-expands K/V from the latent
    cache; ``cfg.mla.absorb`` switches to the absorbed (latent-space) path,
    which never materializes per-head K/V — the DeepSeek-V2 serving trick."""
    m = cfg.mla
    lengths = cache["length"]
    pos = lengths[:, None]
    if m.q_lora_rank:
        cq = apply_norm(params["q_norm"], x @ params["wdq"], cfg)
    else:
        cq = x
    q = jnp.einsum("bsd,dnh->bsnh", cq, params["wuq"])
    q_nope, q_rope = q[..., : m.qk_nope_head_dim], q[..., m.qk_nope_head_dim:]
    q_rope = apply_rope(q_rope, pos, cfg.rope_theta)

    c_kv_new = apply_norm(params["kv_norm"], x @ params["wdkv"], cfg)  # (b,1,l)
    k_rope_new = apply_rope((x @ params["wkr"])[:, :, None, :], pos, cfg.rope_theta)[:, :, 0, :]

    def upd(buf, new):
        def one(b, n, i):
            return jax.lax.dynamic_update_slice(b, n.astype(b.dtype), (i, 0))
        return jax.vmap(one)(buf, new, lengths)

    c_kv = upd(cache["c_kv"], c_kv_new)          # (b,S,l)
    k_rope = upd(cache["k_rope"], k_rope_new)    # (b,S,r)
    S = c_kv.shape[1]
    valid = jnp.arange(S)[None, :] < (lengths + 1)[:, None]
    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5

    if m.absorb:
        # q_nope -> latent space: (b,1,n,h) x (l,n,h) -> (b,1,n,l)
        q_lat = jnp.einsum("bsnh,lnh->bsnl", q_nope, params["wuk"])
        scores = (jnp.einsum("bsnl,bSl->bnS", q_lat, c_kv)
                  + jnp.einsum("bsnh,bSh->bnS", q_rope, k_rope)) * scale
        scores = jnp.where(valid[:, None, :], scores.astype(jnp.float32), NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1).astype(c_kv.dtype)
        o_lat = jnp.einsum("bnS,bSl->bnl", probs, c_kv)
        out = jnp.einsum("bnl,lnh->bnh", o_lat, params["wuv"])[:, None]
    else:
        k_nope = jnp.einsum("bSl,lnh->bSnh", c_kv, params["wuk"])
        v = jnp.einsum("bSl,lnh->bSnh", c_kv, params["wuv"])
        scores = (jnp.einsum("bsnh,bSnh->bnS", q_nope, k_nope)
                  + jnp.einsum("bsnh,bSh->bnS", q_rope, k_rope)) * scale
        scores = jnp.where(valid[:, None, :], scores.astype(jnp.float32), NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
        out = jnp.einsum("bnS,bSnh->bnh", probs, v)[:, None]
    out = jnp.einsum("bsnh,nhd->bsd", out, params["wo"])
    return out, {"c_kv": c_kv, "k_rope": k_rope, "length": lengths + 1}


# ---------------------------------------------------------------------------
# cache factories (shapes only; used for both allocation and ShapeDtypeStructs)
# ---------------------------------------------------------------------------

def cache_spec(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    """Abstract KV-cache entry for ONE attention layer."""
    if cfg.attn_type == "mla":
        m = cfg.mla
        return {
            "c_kv": jax.ShapeDtypeStruct((batch, max_len, m.kv_lora_rank), dtype),
            "k_rope": jax.ShapeDtypeStruct((batch, max_len, m.qk_rope_head_dim), dtype),
            "length": jax.ShapeDtypeStruct((batch,), jnp.int32),
        }
    hd = cfg.resolved_head_dim
    return {
        "k": jax.ShapeDtypeStruct((batch, max_len, cfg.num_kv_heads, hd), dtype),
        "v": jax.ShapeDtypeStruct((batch, max_len, cfg.num_kv_heads, hd), dtype),
        "length": jax.ShapeDtypeStruct((batch,), jnp.int32),
    }


def paged_cache_spec(cfg: ModelConfig, num_pages: int, block_tokens: int,
                     batch: int, max_blocks: int, dtype=jnp.bfloat16):
    """Abstract *paged* KV-cache entry for ONE attention layer.

    ``num_pages`` counts every physical page in the pool, including any
    sentinel/trash page the engine reserves; block-table entries must index
    into ``[0, num_pages)``. MLA's latent cache is not paged yet (the paged
    engine serves GQA-family models only)."""
    if cfg.attn_type == "mla":
        raise NotImplementedError("paged KV cache supports gqa/mqa/mha only")
    hd = cfg.resolved_head_dim
    return {
        "k_pool": jax.ShapeDtypeStruct(
            (num_pages, block_tokens, cfg.num_kv_heads, hd), dtype),
        "v_pool": jax.ShapeDtypeStruct(
            (num_pages, block_tokens, cfg.num_kv_heads, hd), dtype),
        "block_tables": jax.ShapeDtypeStruct((batch, max_blocks), jnp.int32),
        "length": jax.ShapeDtypeStruct((batch,), jnp.int32),
    }


def cache_axes(cfg: ModelConfig, seq_sharded: bool = False):
    seq_ax = "cache_seq" if cfg.shard_v2 else "seq"
    if cfg.attn_type == "mla":
        return {
            "c_kv": ("batch", seq_ax, "kv_lora"),
            "k_rope": ("batch", seq_ax, None),
            "length": ("batch",),
        }
    hd_ax = None if cfg.shard_v2 else "head_dim_shard"
    return {
        "k": ("batch", seq_ax, "kv_heads", hd_ax),
        "v": ("batch", seq_ax, "kv_heads", hd_ax),
        "length": ("batch",),
    }
