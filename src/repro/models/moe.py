"""Mixture-of-Experts (DeepSeek-V2 style: shared + routed top-k experts).

Two interchangeable implementations:

* ``ragged_ep`` (default): sort-by-expert + ``jax.lax.ragged_dot`` so compiled
  FLOPs track *routed* work only. Expert weights are sharded over the
  ``model`` mesh axis (expert parallelism) via ``shard_map``; each shard
  computes its local experts' contribution for its tokens and the results are
  combined with a single psum — no GShard dispatch einsum, no all_to_all of
  activations.
* ``dispatch_einsum``: the classic GShard capacity-based dispatch/combine
  einsum formulation, kept as the well-trodden baseline for §Perf comparisons.

Both are validated against a dense loop-over-experts oracle in tests.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

try:                                  # jax >= 0.6 top-level export
    from jax import shard_map as _shard_map
    _SHARD_MAP_REP_KW = "check_vma"
except ImportError:                   # jax 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map
    _SHARD_MAP_REP_KW = "check_rep"


def shard_map(f, mesh=None, in_specs=None, out_specs=None, check_vma=False):
    """Version-portable shard_map: newer jax calls the replication-check
    knob ``check_vma``, 0.4.x calls it ``check_rep``."""
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **{_SHARD_MAP_REP_KW: check_vma})

from repro.configs.base import ModelConfig
from repro.models.layers import Initializer, init_mlp, apply_mlp


def init_moe(init: Initializer, path: str, cfg: ModelConfig) -> Dict:
    d = cfg.d_model
    m = cfg.moe
    f = m.expert_d_ff
    glu = cfg.mlp_type in ("swiglu", "geglu")
    p = {
        "router": init.w(f"{path}.router", (d, m.num_experts), ("w_embed", "experts"),
                         scale=d ** -0.5),
        "wi": init.w(f"{path}.wi", (m.num_experts, d, (2 * f if glu else f)),
                     ("experts", "w_embed", "ff")),
        "wo": init.z(f"{path}.wo", (m.num_experts, f, d), ("experts", "ff", "w_embed")),
    }
    if m.num_shared_experts:
        p["shared"] = init_mlp(init, f"{path}.shared", cfg, d_ff=m.shared_d_ff)
    return p


def _activate(h, cfg: ModelConfig):
    if cfg.mlp_type in ("swiglu", "geglu"):
        gate, up = jnp.split(h, 2, axis=-1)
        act = jax.nn.silu(gate) if cfg.mlp_type == "swiglu" else jax.nn.gelu(gate)
        return act * up
    if cfg.mlp_type == "relu2":
        return jnp.square(jax.nn.relu(h))
    return jax.nn.gelu(h)


def _router(params, x2d, cfg: ModelConfig):
    """x2d: (T, d) -> (weights (T,k), idx (T,k), aux_loss scalar)."""
    m = cfg.moe
    logits = (x2d.astype(jnp.float32) @ params["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    weights, idx = jax.lax.top_k(probs, m.top_k)
    weights = weights / jnp.maximum(weights.sum(-1, keepdims=True), 1e-9)
    # switch-style load-balancing aux loss
    density = jnp.mean(jax.nn.one_hot(idx, m.num_experts, dtype=jnp.float32), axis=(0, 1))
    mean_probs = jnp.mean(probs, axis=0)
    aux = m.num_experts * jnp.sum(density * mean_probs) * m.aux_loss_coef
    return weights, idx, aux


# ---------------------------------------------------------------------------
# ragged_dot implementation (per-shard local compute)
# ---------------------------------------------------------------------------

def _moe_local(x2d, wi, wo, weights, idx, cfg: ModelConfig,
               expert_offset: int, num_local: int, capacity: int):
    """Contribution of experts [offset, offset+num_local) to all tokens.

    x2d: (T, d); wi: (num_local, d, F); wo: (num_local, f, d);
    weights/idx: (T, k). Returns (T, d).
    """
    T, d = x2d.shape
    k = idx.shape[1]
    rows = T * k
    eid = idx.reshape(rows)
    tok = jnp.repeat(jnp.arange(T, dtype=jnp.int32), k)
    w = weights.reshape(rows)

    local = (eid >= expert_offset) & (eid < expert_offset + num_local)
    local_eid = jnp.where(local, eid - expert_offset, num_local)
    order = jnp.argsort(local_eid, stable=True)          # local rows first, by expert
    capacity = min(capacity, rows)
    take = order[:capacity]
    e_sel = local_eid[take]
    x_sel = x2d[tok[take]]
    w_sel = w[take]

    counts = jnp.bincount(local_eid, length=num_local + 1)[:num_local]
    # cap overflow: experts later in the sort may exceed capacity
    cum = jnp.cumsum(counts)
    gs = jnp.clip(counts - jnp.maximum(cum - capacity, 0), 0, None)
    valid_rows = jnp.arange(capacity) < jnp.sum(gs)

    h = jax.lax.ragged_dot(x_sel, wi, gs.astype(jnp.int32))
    h = _activate(h, cfg)
    y = jax.lax.ragged_dot(h, wo, gs.astype(jnp.int32))
    y = jnp.where(valid_rows[:, None], y, 0.0) * w_sel[:, None].astype(y.dtype)
    out = jnp.zeros((T, d), y.dtype).at[tok[take]].add(y)
    return out


def _capacity(tokens: int, k: int, num_experts: int, num_local: int, slack: float) -> int:
    expected = tokens * k * num_local / max(1, num_experts)
    cap = int(math.ceil(expected * slack))
    cap = max(cap, k)
    return min(max(cap, 8), tokens * k)


def moe_ragged(params, x, cfg: ModelConfig, mesh=None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (..., d) -> (same shape, aux_loss). EP over 'model' if present."""
    m = cfg.moe
    shape = x.shape
    x2d = x.reshape(-1, shape[-1])
    T = x2d.shape[0]

    ep = (mesh is not None and "model" in mesh.axis_names
          and mesh.shape["model"] > 1 and m.num_experts % mesh.shape["model"] == 0)
    if not ep:
        weights, idx, aux = _router(params, x2d, cfg)
        cap = _capacity(T, m.top_k, m.num_experts, m.num_experts, m.capacity_slack)
        out = _moe_local(x2d, params["wi"], params["wo"], weights, idx, cfg,
                         0, m.num_experts, cap)
        return out.reshape(shape).astype(x.dtype), aux

    n_model = mesh.shape["model"]
    num_local = m.num_experts // n_model
    dp_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    T_local = T // math.prod(mesh.shape[a] for a in dp_axes) if dp_axes else T
    cap = _capacity(max(T_local, 1), m.top_k, m.num_experts, num_local, m.capacity_slack)

    def shard_fn(x_l, router_w, wi_l, wo_l):
        midx = jax.lax.axis_index("model")
        weights, idx, aux = _router({"router": router_w}, x_l, cfg)
        out = _moe_local(x_l, wi_l, wo_l, weights, idx, cfg,
                         midx * num_local, num_local, cap)
        out = jax.lax.psum(out, "model")
        aux = jax.lax.pmean(aux, "model")
        return out, aux

    xs = P(dp_axes if dp_axes else None, None)
    out, aux = shard_map(
        shard_fn, mesh=mesh,
        in_specs=(xs, P(None, None), P("model", None, None), P("model", None, None)),
        out_specs=(xs, P()),
        check_vma=False,
    )(x2d, params["router"], params["wi"], params["wo"])
    return out.reshape(shape).astype(x.dtype), jnp.mean(aux)


# ---------------------------------------------------------------------------
# GShard dispatch-einsum implementation (baseline)
# ---------------------------------------------------------------------------

def moe_dispatch_einsum(params, x, cfg: ModelConfig, mesh=None,
                        group_size: int = 4096) -> Tuple[jnp.ndarray, jnp.ndarray]:
    m = cfg.moe
    shape = x.shape
    x2d = x.reshape(-1, shape[-1])
    T, d = x2d.shape
    weights, idx, aux = _router(params, x2d, cfg)

    g_sz = min(group_size, T)
    n_groups = T // g_sz if T % g_sz == 0 else 1
    if T % g_sz != 0:
        g_sz = T
    xg = x2d.reshape(n_groups, g_sz, d)
    wg = weights.reshape(n_groups, g_sz, m.top_k)
    ig = idx.reshape(n_groups, g_sz, m.top_k)

    mean_load = g_sz * m.top_k / m.num_experts
    cap_per_e = min(max(int(math.ceil(mean_load * m.capacity_slack)), 4),
                    g_sz * m.top_k)

    # assignment granularity: a = (s, k) flattened so slots never collide
    a_sz = g_sz * m.top_k
    onehot = jax.nn.one_hot(ig.reshape(n_groups, a_sz), m.num_experts,
                            dtype=jnp.float32)                   # (g,a,e)
    pos = jnp.cumsum(onehot, axis=1) - onehot                    # slot per expert
    posidx = jnp.sum(pos * onehot, axis=-1)                      # (g,a)
    keep = (posidx < cap_per_e).astype(jnp.float32)
    slot = jax.nn.one_hot(posidx, cap_per_e, dtype=jnp.float32)  # (g,a,c)
    disp_a = onehot[:, :, :, None] * slot[:, :, None, :] * keep[:, :, None, None]
    disp_a = disp_a.reshape(n_groups, g_sz, m.top_k, m.num_experts, cap_per_e)
    dispatch = jnp.sum(disp_a, axis=2)                           # (g,s,e,c)
    combine = jnp.einsum("gskec,gsk->gsec", disp_a, wg.astype(jnp.float32))

    xd = jnp.einsum("gsec,gsd->gecd", dispatch.astype(x.dtype), xg)
    h = jnp.einsum("gecd,edf->gecf", xd, params["wi"])
    h = _activate(h, cfg)
    y = jnp.einsum("gecf,efd->gecd", h, params["wo"])
    out = jnp.einsum("gsec,gecd->gsd", combine.astype(y.dtype), y)
    return out.reshape(shape).astype(x.dtype), aux


def apply_moe(params, x, cfg: ModelConfig, mesh=None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    if cfg.moe.impl == "dispatch_einsum":
        out, aux = moe_dispatch_einsum(params, x, cfg, mesh)
    else:
        out, aux = moe_ragged(params, x, cfg, mesh)
    if cfg.moe.num_shared_experts:
        out = out + apply_mlp(params["shared"], x, cfg)
    return out, aux


def moe_reference(params, x, cfg: ModelConfig) -> jnp.ndarray:
    """Dense loop-over-experts oracle (no capacity drops). Tests only."""
    m = cfg.moe
    shape = x.shape
    x2d = x.reshape(-1, shape[-1]).astype(jnp.float32)
    weights, idx, _ = _router(params, x2d, cfg)
    out = jnp.zeros_like(x2d)
    for e in range(m.num_experts):
        h = x2d @ params["wi"][e].astype(jnp.float32)
        h = _activate(h, cfg)
        y = h @ params["wo"][e].astype(jnp.float32)
        w_e = jnp.sum(jnp.where(idx == e, weights, 0.0), axis=-1)
        out = out + y * w_e[:, None]
    if m.num_shared_experts:
        out = out + apply_mlp(params["shared"], x2d.astype(x.dtype), cfg).astype(jnp.float32)
    return out.reshape(shape).astype(x.dtype)
