"""Minimal AdamW + LR schedules (no external deps)."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


def lr_at(opt: OptConfig, step):
    step = step.astype(jnp.float32)
    warm = opt.lr * (step + 1) / max(1, opt.warmup_steps)
    t = jnp.clip((step - opt.warmup_steps)
                 / max(1, opt.total_steps - opt.warmup_steps), 0.0, 1.0)
    cos = opt.lr * 0.5 * (1.0 + jnp.cos(jnp.pi * t))
    return jnp.where(step < opt.warmup_steps, warm, cos)


def init_opt_state(params) -> Dict:
    zeros = lambda p: jax.tree.map(lambda t: jnp.zeros(t.shape, jnp.float32), p)
    return {"m": zeros(params), "v": zeros(params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree):
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def adamw_update(params, grads, opt_state, opt: OptConfig):
    step = opt_state["step"] + 1
    lr = lr_at(opt, step)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, opt.grad_clip / (gnorm + 1e-9))

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = opt.b1 * m + (1 - opt.b1) * g
        v = opt.b2 * v + (1 - opt.b2) * jnp.square(g)
        mhat = m / (1 - opt.b1 ** step.astype(jnp.float32))
        vhat = v / (1 - opt.b2 ** step.astype(jnp.float32))
        delta = mhat / (jnp.sqrt(vhat) + opt.eps) + opt.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    out = jax.tree.map(upd, params, grads, opt_state["m"], opt_state["v"])
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_params, {"m": new_m, "v": new_v, "step": step}, gnorm
