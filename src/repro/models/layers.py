"""Shared layers: initializer/axes recorder, norms, RoPE, MLP variants."""
from __future__ import annotations

import hashlib
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig


def _path_key(root_key, path: str):
    h = int.from_bytes(hashlib.sha256(path.encode()).digest()[:4], "little")
    return jax.random.fold_in(root_key, h)


class Initializer:
    """Creates parameters and records their logical sharding axes.

    The same code path builds both real parameters (under ``init``) and
    abstract ones (under ``jax.eval_shape``); the axes dict is a Python-side
    effect so it is populated either way.
    """

    def __init__(self, cfg: ModelConfig, key):
        self.cfg = cfg
        self.key = key
        self.dtype = jnp.dtype(cfg.param_dtype)
        self.axes: Dict[str, Tuple] = {}

    def w(self, path: str, shape, axes, scale: Optional[float] = None):
        """Dense weight, truncated-normal fan-in init."""
        assert len(shape) == len(axes), (path, shape, axes)
        self.axes[path] = tuple(axes)
        if scale is None:
            fan_in = shape[0] if len(shape) >= 2 else shape[-1]
            scale = 1.0 / np.sqrt(max(1, fan_in))
        k = _path_key(self.key, path)
        return (jax.random.truncated_normal(k, -2.0, 2.0, shape, jnp.float32)
                * scale).astype(self.dtype)

    def z(self, path: str, shape, axes):
        """Zero-init weight (output projections, biases)."""
        self.axes[path] = tuple(axes)
        return jnp.zeros(shape, self.dtype)

    def ones(self, path: str, shape, axes):
        self.axes[path] = tuple(axes)
        return jnp.ones(shape, self.dtype)

    def const(self, path: str, value: np.ndarray, axes):
        self.axes[path] = tuple(axes)
        return jnp.asarray(value, self.dtype)


def stack_inits(fn, n: int):
    """Build ``n`` stacked copies of a per-layer param subtree (for lax.scan).

    ``fn(i)`` must return the subtree for layer ``i``; all layers share the
    same structure. Leading axis is tagged "scan" by the caller's Initializer
    convention (we just stack here).
    """
    trees = [fn(i) for i in range(n)]
    return jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *trees)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rms_norm(x, gamma, eps: float):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + gamma.astype(jnp.float32))).astype(dt)


def layer_norm(x, gamma, beta, eps: float):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    out = (x - mu) * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + gamma.astype(jnp.float32)) + beta.astype(jnp.float32)).astype(dt)


def init_norm(init: Initializer, path: str, cfg: ModelConfig, dim: int):
    if cfg.norm_type == "layernorm":
        return {"gamma": init.z(f"{path}.gamma", (dim,), ("norm",)),
                "beta": init.z(f"{path}.beta", (dim,), ("norm",))}
    return {"gamma": init.z(f"{path}.gamma", (dim,), ("norm",))}


def apply_norm(params, x, cfg: ModelConfig):
    if cfg.norm_type == "layernorm":
        return layer_norm(x, params["gamma"], params["beta"], cfg.norm_eps)
    return rms_norm(x, params["gamma"], cfg.norm_eps)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float):
    exponent = np.arange(0, head_dim, 2, dtype=np.float64) / head_dim
    return jnp.asarray(1.0 / (theta ** exponent), jnp.float32)


def apply_rope(x, positions, theta: float):
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    head_dim = x.shape[-1]
    inv_freq = rope_frequencies(head_dim, theta)
    angles = positions[..., :, None].astype(jnp.float32) * inv_freq  # (..., s, hd/2)
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP variants
# ---------------------------------------------------------------------------

def init_mlp(init: Initializer, path: str, cfg: ModelConfig, d_ff: Optional[int] = None):
    d, f = cfg.d_model, (d_ff or cfg.d_ff)
    p = {}
    if cfg.mlp_type in ("swiglu", "geglu"):
        p["wi"] = init.w(f"{path}.wi", (d, 2, f), ("w_embed", None, "ff"))
        p["wo"] = init.z(f"{path}.wo", (f, d), ("ff", "w_embed"))
    else:  # relu2 | gelu
        p["wi"] = init.w(f"{path}.wi", (d, f), ("w_embed", "ff"))
        p["wo"] = init.z(f"{path}.wo", (f, d), ("ff", "w_embed"))
    return p


def apply_mlp(params, x, cfg: ModelConfig):
    if cfg.mlp_type in ("swiglu", "geglu"):
        h = jnp.einsum("...d,dgf->...gf", x, params["wi"])
        gate, up = h[..., 0, :], h[..., 1, :]
        act = jax.nn.silu(gate) if cfg.mlp_type == "swiglu" else jax.nn.gelu(gate)
        h = act * up
    elif cfg.mlp_type == "relu2":
        h = jnp.square(jax.nn.relu(x @ params["wi"]))
    else:  # gelu
        h = jax.nn.gelu(x @ params["wi"])
    return h @ params["wo"]


def mlp_flops(cfg: ModelConfig, d_ff: Optional[int] = None) -> int:
    """FLOPs per token for one MLP block (fwd)."""
    f = d_ff or cfg.d_ff
    mult = 3 if cfg.mlp_type in ("swiglu", "geglu") else 2
    return 2 * mult * cfg.d_model * f


def softcap(logits, cap: float):
    if not cap:
        return logits
    return jnp.tanh(logits / cap) * cap
