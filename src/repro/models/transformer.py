"""Unified model: wires attention/MLP/MoE/Mamba2/xLSTM blocks per ModelConfig.

Parameters are plain nested dicts; repeated layers are stacked on a leading
"scan" axis and traversed with lax.scan so HLO size stays O(1) in depth.
Forward modes:
  * "train"/"encode": full-sequence logits (b, s, vocab)
  * "prefill": last-position logits + initialized caches
  * "decode": one-token logits + updated caches (serve_step body)
  * "chunk": chunked-prefill continuation over paged caches, last-valid logits
  * "verify": speculative draft verification over paged caches, full logits
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import mamba2 as m2
from repro.models import xlstm as xl
from repro.models.layers import (Initializer, apply_mlp, apply_norm, init_mlp,
                                 init_norm, softcap)
from repro.models.moe import apply_moe, init_moe
from repro.models.sharding import ShardingRules, constrain


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _ssm_layout(cfg: ModelConfig):
    """(n_groups, mlstm_per_group, n_slstm). slstm_every == 0 => pure mLSTM
    (used by the dry-run's shallow cost probes)."""
    if not cfg.xlstm.slstm_every:
        return 1, cfg.num_layers, 0
    n_groups = cfg.num_layers // cfg.xlstm.slstm_every
    return n_groups, cfg.xlstm.slstm_every - 1, n_groups


def _init_block(init: Initializer, prefix: str, cfg: ModelConfig, moe_layer: bool):
    p = {
        "ln1": init_norm(init, f"{prefix}.ln1", cfg, cfg.d_model),
        "attn": attn.init_attention(init, f"{prefix}.attn", cfg),
        "ln2": init_norm(init, f"{prefix}.ln2", cfg, cfg.d_model),
    }
    if moe_layer:
        p["moe"] = init_moe(init, f"{prefix}.moe", cfg)
    else:
        p["mlp"] = init_mlp(init, f"{prefix}.mlp", cfg)
    return p


def _stacked(cfg: ModelConfig, key, build, n: int):
    """Stack ``n`` copies of ``build(init)`` on a leading scan axis; returns
    (params, flat-axes-with-scan-prefix)."""
    axes = {}
    trees = []
    for i in range(n):
        ini = Initializer(cfg, jax.random.fold_in(key, i))
        trees.append(build(ini))
        axes = ini.axes
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs, 0), *trees)
    axes = {k: ("scan",) + tuple(v) for k, v in axes.items()}
    return stacked, axes


def init_model(cfg: ModelConfig, key) -> Tuple[Dict, Dict[str, tuple]]:
    """Returns (params, flat axes dict path->logical axes)."""
    init = Initializer(cfg, jax.random.fold_in(key, 0xE0))
    flat_axes: Dict[str, tuple] = {}
    params: Dict = {}

    d = cfg.d_model
    # N(0, 1/d) embeddings + sqrt(d) input scaling (gemma-style): keeps the
    # residual stream ~unit variance AND tied-head logits ~unit variance.
    params["embed"] = init.w("embed", (cfg.vocab_size, d), ("vocab", "w_embed"),
                             scale=d ** -0.5)
    if cfg.stub_frontend:
        params["frontend_proj"] = init.w("frontend_proj", (cfg.frontend_dim, d),
                                         (None, "w_embed"))
    params["final_norm"] = init_norm(init, "final_norm", cfg, d)
    if not cfg.tie_embeddings:
        params["head"] = init.w("head", (d, cfg.vocab_size), ("w_embed", "vocab"),
                                scale=d ** -0.5)
    flat_axes.update(init.axes)

    if cfg.family in ("dense", "vlm", "audio"):
        params["layers"], ax = _stacked(
            cfg, jax.random.fold_in(key, 1),
            lambda ini: _init_block(ini, "layers", cfg, False), cfg.num_layers)
        flat_axes.update(ax)
    elif cfg.family == "moe":
        kd = cfg.moe.first_k_dense
        params["dense_layers"], ax = _stacked(
            cfg, jax.random.fold_in(key, 1),
            lambda ini: _init_block(ini, "dense_layers", cfg, False), kd)
        flat_axes.update(ax)
        params["layers"], ax = _stacked(
            cfg, jax.random.fold_in(key, 2),
            lambda ini: _init_block(ini, "layers", cfg, True), cfg.num_layers - kd)
        flat_axes.update(ax)
    elif cfg.family == "hybrid":
        params["mamba"], ax = _stacked(
            cfg, jax.random.fold_in(key, 1),
            lambda ini: m2.init_mamba2(ini, "mamba", cfg), cfg.num_layers)
        flat_axes.update(ax)
        ini = Initializer(cfg, jax.random.fold_in(key, 2))
        params["shared"] = _init_block(ini, "shared", cfg, False)
        flat_axes.update(ini.axes)
    elif cfg.family == "ssm":
        n_groups, n_m_per, n_slstm = _ssm_layout(cfg)
        params["mlstm"], ax = _stacked(
            cfg, jax.random.fold_in(key, 1),
            lambda ini: xl.init_mlstm(ini, "mlstm", cfg), n_groups * n_m_per)
        flat_axes.update(ax)
        if n_slstm:
            params["slstm"], ax = _stacked(
                cfg, jax.random.fold_in(key, 2),
                lambda ini: xl.init_slstm(ini, "slstm", cfg), n_slstm)
            flat_axes.update(ax)
    else:
        raise ValueError(cfg.family)
    return params, flat_axes


def axes_tree(params, flat_axes):
    """Nested axes tree mirroring the params structure."""
    def lookup(kp, _leaf):
        path = ".".join(str(k.key) for k in kp)
        return tuple(flat_axes[path])
    return jax.tree_util.tree_map_with_path(lookup, params)


def abstract_model(cfg: ModelConfig, key=None):
    key = jax.random.PRNGKey(0) if key is None else key
    flat_holder = {}

    def go(k):
        p, ax = init_model(cfg, k)
        flat_holder.update(ax)
        return p

    params = jax.eval_shape(go, key)
    return params, flat_holder


# ---------------------------------------------------------------------------
# blocks (apply)
# ---------------------------------------------------------------------------

def _block_fwd(p, x, positions, cfg: ModelConfig, mode: str, cache, rules,
               moe_layer: bool, mesh=None, q_valid=None):
    """Standard (attention + mlp/moe) block. Returns (x, new_cache, aux)."""
    h = apply_norm(p["ln1"], x, cfg)
    if (cfg.attn_in_seqshard and rules is not None
            and mode not in ("decode", "chunk")
            and cfg.num_heads % rules.axis_sizes.get("model", 1) != 0):
        # enter sequence-parallel attention at d_model width (cheap) instead
        # of resharding the nh*hd-wide Q tensor inside attention
        from repro.models.sharding import constrain as _constrain
        h = _constrain(h, rules, ("batch", "attn_qseq", "embed"))
    if mode == "decode":
        if cfg.attn_type == "mla":
            a, new_cache = attn.mla_decode(p["attn"], h, cfg, cache)
        else:
            a, new_cache = attn.gqa_decode(p["attn"], h, cfg, cache)
    elif mode == "chunk":
        if cfg.attn_type == "mla":
            raise NotImplementedError("chunked prefill supports gqa-family "
                                      "attention only (paged KV)")
        a, new_cache = attn.gqa_prefill_paged(p["attn"], h, cfg, cache, q_valid)
    elif mode == "verify":
        if cfg.attn_type == "mla":
            raise NotImplementedError("speculative verify supports gqa-family "
                                      "attention only (paged KV)")
        a, new_cache = attn.gqa_verify_paged(p["attn"], h, cfg, cache, q_valid)
    else:
        if cfg.attn_type == "mla":
            a, new_cache = attn.mla_prefill(p["attn"], h, positions, cfg,
                                            cache, rules=rules)
        else:
            a, new_cache = attn.gqa_prefill(p["attn"], h, positions, cfg,
                                            cache, rules=rules)
    x = x + a
    h = apply_norm(p["ln2"], x, cfg)
    aux = jnp.zeros((), jnp.float32)
    if moe_layer:
        mo, aux = apply_moe(p["moe"], h, cfg, mesh)
        x = x + mo
    else:
        x = x + apply_mlp(p["mlp"], h, cfg)
    if rules is not None:
        x = constrain(x, rules, ("batch", "seq", "embed"))
    return x, new_cache, aux


def _remat(fn, cfg: ModelConfig, mode: str):
    if mode != "train" or cfg.remat == "none":
        return fn
    policy = (jax.checkpoint_policies.nothing_saveable if cfg.remat == "full"
              else jax.checkpoint_policies.dots_saveable)
    return jax.checkpoint(fn, policy=policy)


def _maybe_scan(body, init, xs, scan: bool):
    """lax.scan, or a python unroll when ``scan`` is False.

    The unrolled form is used by the dry-run: XLA's cost_analysis counts a
    while-loop body ONCE regardless of trip count, so roofline terms from a
    scanned model would be ~L x too small (verified empirically).
    """
    if scan:
        return jax.lax.scan(body, init, xs)
    n = jax.tree.leaves(xs)[0].shape[0]
    carry = init
    ys = []
    for i in range(n):
        carry, y = body(carry, jax.tree.map(lambda t: t[i], xs))
        ys.append(y)
    stacked = jax.tree.map(lambda *zs: jnp.stack(zs, 0), *ys)
    return carry, stacked


def _scan_blocks(params_stack, x, positions, cfg, mode, caches, rules,
                 moe_layer, mesh, q_valid=None):
    """lax.scan over stacked blocks; caches (optional) are stacked on the
    same leading axis."""
    has_cache = caches is not None

    def body(carry, xs):
        x, aux = carry
        if has_cache:
            p, cache = xs
        else:
            p, cache = xs, None
        x, new_cache, a = _block_fwd(p, x, positions, cfg, mode, cache, rules,
                                     moe_layer, mesh, q_valid=q_valid)
        if not has_cache:
            new_cache = jnp.zeros((), jnp.int32)
        return (x, aux + a), new_cache

    body = _remat(body, cfg, mode)
    xs = (params_stack, caches) if has_cache else params_stack
    (x, aux), new_caches = _maybe_scan(body, (x, jnp.zeros((), jnp.float32)),
                                       xs, cfg.scan_layers)
    return x, (new_caches if has_cache else None), aux


def _no_cache(n: int):
    return None


# ---------------------------------------------------------------------------
# full forward
# ---------------------------------------------------------------------------

def forward(params, cfg: ModelConfig, *, tokens=None, embeds=None,
            mode: str = "train", caches=None, rules: Optional[ShardingRules] = None,
            mesh=None, q_valid=None):
    """Returns (logits, new_caches, aux_loss).

    mode="chunk" is the chunked-prefill pass: ``tokens`` (b, s) holds one
    left-aligned chunk per row, ``q_valid`` (b,) its per-row valid token
    count, and ``caches`` must be paged — each chunk continues from the
    request's cached context at position ``cache["length"]``. Logits are
    taken at each row's LAST VALID chunk position (the whole-prefill
    analogue of "last position"); rows with ``q_valid == 0`` produce
    garbage logits the caller ignores.

    mode="verify" is the speculative draft-and-verify pass: like "chunk" the
    ``tokens`` row is a left-aligned continuation (last committed token +
    draft tokens, ``q_valid`` valid per row) written through the paged
    cache, but the logits come back UN-sliced — ``(b, s, vocab)`` — because
    acceptance needs the argmax at *every* draft position, and position j's
    logits are bit-identical to what sequential one-token decode would
    produce there.
    """
    compute = jnp.dtype(cfg.compute_dtype)
    if embeds is not None:
        x = embeds.astype(compute) @ params["frontend_proj"].astype(compute)
        b, s = embeds.shape[:2]
    else:
        x = params["embed"].astype(compute)[tokens]
        x = x * jnp.asarray(cfg.d_model ** 0.5, compute)
        b, s = tokens.shape
    if mode in ("decode", "chunk", "verify"):
        positions = None  # per-request positions come from cache lengths
    else:
        positions = jnp.arange(s, dtype=jnp.int32)[None, :]
    if rules is not None:
        x = constrain(x, rules, ("batch", "seq", "embed"))

    aux_total = jnp.zeros((), jnp.float32)
    new_caches = {}

    if cfg.family in ("dense", "vlm", "audio"):
        c = caches["attn"] if caches is not None else None
        x, nc, aux = _scan_blocks(params["layers"], x, positions, cfg, mode,
                                  c, rules, False, mesh, q_valid=q_valid)
        new_caches = None if caches is None else {"attn": nc}
        aux_total += aux

    elif cfg.family == "moe":
        cd = caches["dense_attn"] if caches is not None else None
        cm = caches["attn"] if caches is not None else None
        x, ncd, aux1 = _scan_blocks(params["dense_layers"], x, positions, cfg,
                                    mode, cd, rules, False, mesh,
                                    q_valid=q_valid)
        x, ncm, aux2 = _scan_blocks(params["layers"], x, positions, cfg, mode,
                                    cm, rules, True, mesh, q_valid=q_valid)
        aux_total += aux1 + aux2
        new_caches = (None if caches is None else {"dense_attn": ncd, "attn": ncm})

    elif cfg.family == "hybrid":
        n_apps = (cfg.num_layers // cfg.shared_attn_every
                  if cfg.shared_attn_every else 0)
        per = cfg.shared_attn_every or (cfg.num_layers + 1)
        mstate = caches["mamba"] if caches is not None else None
        attn_c = caches.get("attn") if caches is not None else None
        want_state = caches is not None
        new_mstate, new_attn_c = [], []

        def mamba_span(lo, hi, x, mstate_slice):
            p_slice = jax.tree.map(lambda t: t[lo:hi], params["mamba"])
            if mode == "decode":
                def body(xc, xs):
                    p, st = xs
                    y, new_st = m2.mamba2_decode(p, xc, cfg, st)
                    return xc + y, new_st
                x, new_st = _maybe_scan(body, x, (p_slice, mstate_slice),
                                        cfg.scan_layers)
                return x, new_st
            def body(xc, p):
                y, st = m2.mamba2_forward(p, xc, cfg, return_state=want_state)
                if not want_state:
                    st = jnp.zeros((), jnp.int32)
                return xc + y, st
            body = _remat(body, cfg, mode)
            x, sts = _maybe_scan(body, x, p_slice, cfg.scan_layers)
            return x, sts

        idx = 0
        for g in range(n_apps):
            ms = None if mstate is None else jax.tree.map(
                lambda t: t[idx:idx + per], mstate)
            x, st = mamba_span(idx, idx + per, x, ms)
            if want_state:
                new_mstate.append(st)
            ac = None if attn_c is None else jax.tree.map(lambda t: t[g], attn_c)
            x, nac, _ = _block_fwd(params["shared"], x, positions, cfg, mode,
                                   ac, rules, False, mesh)
            if want_state and nac is not None:
                new_attn_c.append(nac)
            idx += per
        if idx < cfg.num_layers:
            ms = None if mstate is None else jax.tree.map(
                lambda t: t[idx:], mstate)
            x, st = mamba_span(idx, cfg.num_layers, x, ms)
            if want_state:
                new_mstate.append(st)
        if want_state:
            new_caches = {
                "mamba": jax.tree.map(lambda *xs: jnp.concatenate(xs, 0), *new_mstate),
            }
            if new_attn_c:
                new_caches["attn"] = jax.tree.map(
                    lambda *xs: jnp.stack(xs, 0), *new_attn_c)
        else:
            new_caches = None

    elif cfg.family == "ssm":
        n_groups, n_m_per, n_slstm = _ssm_layout(cfg)
        want_state = caches is not None
        mstate = caches["mlstm"] if caches is not None else None
        sstate = caches.get("slstm") if caches is not None else None
        new_m, new_s = [], []
        for g in range(n_groups):
            lo = g * n_m_per
            p_slice = jax.tree.map(lambda t: t[lo:lo + n_m_per], params["mlstm"])
            ms = None if mstate is None else jax.tree.map(
                lambda t: t[lo:lo + n_m_per], mstate)
            if mode == "decode":
                def body(xc, xs):
                    p, st = xs
                    y, new_st = xl.mlstm_decode(p, xc, cfg, st)
                    return xc + y, new_st
                x, sts = _maybe_scan(body, x, (p_slice, ms), cfg.scan_layers)
            else:
                def body(xc, p):
                    # NOTE: mLSTM chunk scan stays a lax.scan even in the
                    # dry-run's unrolled probes — its in-scan intra-chunk cost
                    # is ~3% of the block (see EXPERIMENTS caveats); unrolling
                    # 128 chunk bodies makes SPMD compile time explode.
                    y, st = xl.mlstm_forward(p, xc, cfg,
                                             return_state=want_state,
                                             unroll_chunks=False)
                    if not want_state:
                        st = jnp.zeros((), jnp.int32)
                    return xc + y, st
                body = _remat(body, cfg, mode)
                x, sts = _maybe_scan(body, x, p_slice, cfg.scan_layers)
            if want_state:
                new_m.append(sts)
            if n_slstm:
                sp = jax.tree.map(lambda t: t[g], params["slstm"])
                ss = None if sstate is None else jax.tree.map(
                    lambda t: t[g], sstate)
                y, new_ss = xl.slstm_forward(sp, x, cfg, state=ss,
                                             return_state=want_state)
                x = x + y
                if want_state:
                    new_s.append(new_ss)
        if want_state:
            new_caches = {
                "mlstm": jax.tree.map(lambda *xs: jnp.concatenate(xs, 0), *new_m),
            }
            if new_s:
                new_caches["slstm"] = jax.tree.map(
                    lambda *xs: jnp.stack(xs, 0), *new_s)
        else:
            new_caches = None
    else:
        raise ValueError(cfg.family)

    x = apply_norm(params["final_norm"], x, cfg)
    head = (params["embed"].T if cfg.tie_embeddings else params["head"])
    if mode in ("prefill",):
        x = x[:, -1:, :]
    elif mode == "chunk":
        # per-row last VALID chunk position (q_valid == 0 rows read position
        # 0 and produce garbage the caller ignores)
        idx = jnp.maximum(q_valid - 1, 0).astype(jnp.int32)[:, None, None]
        x = jnp.take_along_axis(x, idx, axis=1)
    logits = (x @ head.astype(x.dtype)).astype(jnp.dtype(cfg.logits_dtype))
    logits = softcap(logits, cfg.logits_softcap)
    if mode in ("prefill", "decode", "chunk"):
        logits = logits[:, -1, :]
    return logits, new_caches, aux_total


# ---------------------------------------------------------------------------
# cache factories
# ---------------------------------------------------------------------------

def init_cache_spec(cfg: ModelConfig, batch: int, max_len: int):
    """Abstract cache pytree (ShapeDtypeStructs) + matching logical axes."""
    def stack_spec(spec, n):
        return jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((n, *s.shape), s.dtype), spec)

    def stack_axes(ax, extra=("scan",)):
        return jax.tree.map(lambda a: tuple(extra) + tuple(a), ax,
                            is_leaf=lambda x: isinstance(x, tuple) and all(
                                isinstance(e, (str, type(None))) for e in x))

    if cfg.family in ("dense", "vlm", "audio", "moe"):
        base = attn.cache_spec(cfg, batch, max_len)
        ax = attn.cache_axes(cfg)
        if cfg.family == "moe":
            kd = cfg.moe.first_k_dense
            spec = {"dense_attn": stack_spec(base, kd),
                    "attn": stack_spec(base, cfg.num_layers - kd)}
            axes = {"dense_attn": stack_axes(ax), "attn": stack_axes(ax)}
        else:
            spec = {"attn": stack_spec(base, cfg.num_layers)}
            axes = {"attn": stack_axes(ax)}
        return spec, axes
    if cfg.family == "hybrid":
        n_apps = (cfg.num_layers // cfg.shared_attn_every
                  if cfg.shared_attn_every else 0)
        spec = {"mamba": stack_spec(m2.mamba2_state_spec(cfg, batch), cfg.num_layers)}
        axes = {"mamba": stack_axes(m2.mamba2_state_axes())}
        if n_apps:
            spec["attn"] = stack_spec(attn.cache_spec(cfg, batch, max_len), n_apps)
            axes["attn"] = stack_axes(attn.cache_axes(cfg))
        return spec, axes
    if cfg.family == "ssm":
        n_groups, n_m_per, n_slstm = _ssm_layout(cfg)
        spec = {"mlstm": stack_spec(xl.mlstm_state_spec(cfg, batch),
                                    n_groups * n_m_per)}
        axes = {"mlstm": stack_axes(xl.mlstm_state_axes())}
        if n_slstm:
            spec["slstm"] = stack_spec(xl.slstm_state_spec(cfg, batch), n_slstm)
            axes["slstm"] = stack_axes(xl.slstm_state_axes())
        return spec, axes
    raise ValueError(cfg.family)


def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    spec, _ = init_cache_spec(cfg, batch, max_len)
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), spec)


def init_paged_cache(cfg: ModelConfig, batch: int, num_blocks: int,
                     block_tokens: int, max_blocks: int):
    """Paged-cache pytree for the real-execution engine: same layer grouping
    as ``init_cache`` (``attn`` / ``dense_attn`` stacks on a leading scan
    axis) but each layer holds a pooled page array plus per-request block
    tables instead of a contiguous ``(b, S)`` cache.

    The pool gets ``num_blocks + 1`` physical pages: page ``num_blocks`` is
    the engine's *trash page* — dead batch rows' tables point at it (every
    block-table entry must be a valid pool index for the gather), and it is
    where their masked decode writes land. Block tables start all-trash and
    lengths at 0. Only attention-cache families page; recurrent state
    (hybrid/ssm) has no pages to share."""
    if cfg.family not in ("dense", "vlm", "audio", "moe"):
        raise NotImplementedError(
            f"paged KV cache is attention-only (family={cfg.family})")
    trash = num_blocks

    def stack(n):
        base = attn.paged_cache_spec(cfg, num_blocks + 1, block_tokens,
                                     batch, max_blocks)
        one = {k: (jnp.full(s.shape, trash, jnp.int32)
                   if k == "block_tables" else jnp.zeros(s.shape, s.dtype))
               for k, s in base.items()}
        return jax.tree.map(lambda t: jnp.stack([t] * n, 0), one)

    if cfg.family == "moe":
        kd = cfg.moe.first_k_dense
        return {"dense_attn": stack(kd), "attn": stack(cfg.num_layers - kd)}
    return {"attn": stack(cfg.num_layers)}
