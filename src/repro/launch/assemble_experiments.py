"""Merge dry-run JSON shards and render the EXPERIMENTS.md tables in place."""
from __future__ import annotations

import argparse
import json
import os

from repro.configs import SHAPES_BY_NAME, applicable_shapes, get_config, ARCH_IDS
from repro.launch.roofline_report import render, render_dryrun


def merge(paths):
    seen = {}
    for p in paths:
        if not os.path.exists(p):
            continue
        for row in json.load(open(p)):
            key = (row["arch"], row["shape"], row["mesh"])
            # later files win (re-runs supersede recovered log rows)
            if key not in seen or not row.get("from_log"):
                seen[key] = row
    return list(seen.values())


def skip_table() -> str:
    rows = ["| arch | skipped shape | reason |", "|---|---|---|"]
    for a in ARCH_IDS:
        if a == "llama3_70b":
            continue
        cfg = get_config(a)
        live = {s.name for s in applicable_shapes(cfg)}
        for s in SHAPES_BY_NAME.values():
            if s.name in live:
                continue
            reason = ("encoder-only: no autoregressive decode"
                      if not cfg.supports_decode and s.kind == "decode"
                      else "needs sub-quadratic attention (full-attention arch)")
            rows.append(f"| {a} | {s.name} | {reason} |")
    return "\n".join(rows)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--jsons", nargs="+", required=True)
    ap.add_argument("--md", default="EXPERIMENTS.md")
    ap.add_argument("--out-json", default="dryrun_results.json")
    args = ap.parse_args()
    rows = merge(args.jsons)
    rows.sort(key=lambda r: (r["arch"], r["shape"], r["mesh"]))
    with open(args.out_json, "w") as f:
        json.dump(rows, f, indent=1)
    md = open(args.md).read()
    n_ok = sum(1 for r in rows if "error" not in r)
    summary = (f"\n**{n_ok}/{len(rows)} cells compiled OK** "
               f"(31 live cells x 2 meshes expected; skips below).\n\n"
               + skip_table() + "\n\n")
    md = md.replace("<!-- DRYRUN_TABLE -->",
                    summary + render_dryrun(rows))
    md = md.replace("<!-- ROOFLINE_TABLE -->", render(rows))
    open(args.md, "w").write(md)
    print(f"assembled {len(rows)} rows -> {args.out_json}, {args.md}")


if __name__ == "__main__":
    main()
