"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell and
extract roofline terms from the compiled artifact. No device allocation —
everything flows through ShapeDtypeStructs.

MUST set XLA_FLAGS before any jax import (jax locks device count on first
init), hence the first two lines.
"""
import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

import argparse
import functools
import json
import re
import time
from typing import Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_IDS, SHAPES_BY_NAME, applicable_shapes, get_config
from repro.configs.base import ModelConfig, ShapeConfig
from repro.launch.mesh import make_production_mesh
from repro.models import steps
from repro.models import transformer as tf
from repro.models.optim import OptConfig
from repro.models.sharding import ShardingRules, tree_specs

# TPU v5e roofline constants (per chip)
PEAK_FLOPS = 197e12       # bf16
HBM_BW = 819e9            # bytes/s
ICI_BW = 50e9             # bytes/s per link

_DTYPE_BYTES = {"f64": 8, "s64": 8, "u64": 8, "f32": 4, "s32": 4, "u32": 4,
                "bf16": 2, "f16": 2, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _tensor_bytes(dtype: str, dims: str) -> float:
    b = _DTYPE_BYTES.get(dtype)
    if b is None:
        return 0.0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return float(n * b)


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Sum payload bytes per collective kind from HLO text. For each
    collective instruction we take the largest tensor shape on the line as
    the payload (robust to tuple-shaped async start ops)."""
    out = {k: 0.0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        for kind in _COLLECTIVES:
            # match op invocation, including async -start variants; skip -done
            if (f" {kind}(" in stripped or f" {kind}-start(" in stripped):
                sizes = [_tensor_bytes(d, dims)
                         for d, dims in _SHAPE_RE.findall(stripped)]
                if sizes:
                    out[kind] += max(sizes)
                break
    return out


def wire_bytes(cb: Dict[str, float]) -> float:
    """Approximate bytes-on-the-wire: ring all-reduce moves ~2x payload,
    others ~1x."""
    return (2.0 * cb["all-reduce"] + cb["all-gather"] + cb["reduce-scatter"]
            + cb["all-to-all"] + cb["collective-permute"])


# ---------------------------------------------------------------------------

def attn_score_bytes(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """Analytic GLOBAL HBM bytes of materialized attention score/prob tiles.

    XLA-CPU streams these through memory, but the TPU flash kernel keeps them
    VMEM-resident — so the honest TPU memory term subtracts them. fwd ~12
    B/elem (fp32 write + softmax pass + PV read), train ~3x for backward."""
    if cfg.attn_type == "none":
        return 0.0
    n_attn = cfg.num_layers
    if cfg.family == "hybrid":
        n_attn = cfg.num_layers // max(1, cfg.shared_attn_every)
    if shape.kind == "decode":
        elems = float(shape.global_batch) * cfg.num_heads * shape.seq_len * n_attn
        return 8.0 * elems
    causal = 0.5 if not cfg.encoder_only else 1.0
    elems = (causal * float(shape.seq_len) ** 2 * cfg.num_heads
             * shape.global_batch * n_attn)
    per_elem = 36.0 if shape.kind == "train" else 12.0
    return per_elem * elems


def _abstract_opt_state(abstract_params):
    f32 = lambda t: jax.ShapeDtypeStruct(t.shape, jnp.float32)
    return {"m": jax.tree.map(f32, abstract_params),
            "v": jax.tree.map(f32, abstract_params),
            "step": jax.ShapeDtypeStruct((), jnp.int32)}


def _sharding_tree(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def build_cell(cfg: ModelConfig, shape: ShapeConfig, mesh,
               fsdp: Optional[bool] = None):
    """Returns (fn, args_abstract, in_shardings) ready for jit().lower()."""
    if fsdp is None:
        fsdp = shape.kind == "train" and cfg.param_count() > 30e9
    seq_sharded = shape.kind == "decode" and shape.global_batch == 1
    rules = ShardingRules(mesh, fsdp=fsdp, seq_sharded=seq_sharded)

    abstract_params, flat_axes = tf.abstract_model(cfg)
    p_axes = tf.axes_tree(abstract_params, flat_axes)
    p_specs = tree_specs(rules, abstract_params, p_axes)
    p_shard = _sharding_tree(mesh, p_specs)

    batch_abs = steps.input_specs(cfg, shape)
    b_axes = steps.batch_axes(cfg, shape)
    b_specs = {k: rules.spec(batch_abs[k].shape, b_axes[k]) for k in batch_abs}
    b_shard = {k: NamedSharding(mesh, b_specs[k]) for k in batch_abs}

    if shape.kind == "train":
        state_abs = {"params": abstract_params,
                     "opt": _abstract_opt_state(abstract_params)}
        opt_shard = {"m": p_shard, "v": p_shard,
                     "step": NamedSharding(mesh, P())}
        state_shard = {"params": p_shard, "opt": opt_shard}
        opt = OptConfig()
        fn = functools.partial(steps.train_step, cfg=cfg, opt=opt, rules=rules,
                               mesh=mesh)
        return fn, (state_abs, batch_abs), (state_shard, b_shard)

    if shape.kind == "prefill":
        fn = functools.partial(steps.prefill_step, cfg=cfg,
                               max_len=shape.seq_len + 8, rules=rules, mesh=mesh)
        return fn, (abstract_params, batch_abs), (p_shard, b_shard)

    # decode
    cache_abs, cache_axes = tf.init_cache_spec(cfg, shape.global_batch,
                                               shape.seq_len + 8)
    c_specs = tree_specs(rules, cache_abs, cache_axes)
    c_shard = _sharding_tree(mesh, c_specs)
    fn = functools.partial(serve_wrapper, cfg=cfg, rules=rules, mesh=mesh)
    return fn, (abstract_params, batch_abs["tokens"], cache_abs), \
        (p_shard, b_shard["tokens"], c_shard)


def serve_wrapper(params, tokens, caches, cfg, rules, mesh):
    return steps.serve_step(params, tokens, caches, cfg, rules, mesh)


def _compile_cell(cfg: ModelConfig, shape: ShapeConfig, mesh,
                  donate: bool = True, fsdp=None, donate_cache: bool = False):
    fn, args, in_sh = build_cell(cfg, shape, mesh, fsdp=fsdp)
    donate_argnums = (0,) if (donate and shape.kind == "train") else ()
    if donate_cache and shape.kind == "decode":
        donate_argnums = (2,)   # in-place KV-cache update
    from repro.launch.mesh import mesh_context
    with mesh_context(mesh):
        jitted = jax.jit(fn, in_shardings=in_sh,
                         donate_argnums=donate_argnums)
        lowered = jitted.lower(*args)
        compiled = lowered.compile()
    return compiled


def _cost_of(cfg, shape, mesh, fsdp=None, donate_cache=False) -> Dict[str, float]:
    """Per-device (flops, bytes, collective wire bytes) of one UNROLLED
    compile at a reduced depth."""
    compiled = _compile_cell(cfg.replace(scan_layers=False), shape, mesh,
                             fsdp=fsdp, donate_cache=donate_cache)
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):   # jax 0.4.x wraps it in a list
        ca = ca[0] if ca else {}
    cb = collective_bytes(compiled.as_text())
    return {"flops": float(ca.get("flops", 0.0)),
            "bytes": float(ca.get("bytes accessed", 0.0)),
            "wire": wire_bytes(cb),
            "collectives": cb}


def _axpy(base, per, n):
    out = {k: base[k] + n * per[k] for k in ("flops", "bytes", "wire")}
    out["collectives"] = {k: base["collectives"].get(k, 0.0)
                          + n * per["collectives"].get(k, 0.0)
                          for k in set(base["collectives"]) | set(per["collectives"])}
    return out


def _diff(c2, c1, denom):
    out = {k: (c2[k] - c1[k]) / denom for k in ("flops", "bytes", "wire")}
    out["collectives"] = {k: (c2["collectives"].get(k, 0.0)
                              - c1["collectives"].get(k, 0.0)) / denom
                          for k in set(c2["collectives"]) | set(c1["collectives"])}
    return out


def extrapolated_cost(cfg: ModelConfig, shape: ShapeConfig, mesh,
                      fsdp=None, donate_cache=False) -> Dict:
    """Exact-by-affinity cost extrapolation: per-layer costs measured from two
    reduced-depth UNROLLED lowers, scaled to the full depth. Needed because
    XLA cost_analysis counts a scanned (while-loop) body once regardless of
    trip count — a full unrolled compile of a 96-layer model is too slow, but
    cost is affine in the per-type layer counts, so two points suffice."""
    L = cfg.num_layers
    if cfg.family in ("dense", "vlm", "audio"):
        c2 = _cost_of(cfg.replace(num_layers=2), shape, mesh, fsdp, donate_cache)
        c4 = _cost_of(cfg.replace(num_layers=4), shape, mesh, fsdp, donate_cache)
        per = _diff(c4, c2, 2)
        base = _axpy(c2, per, -2)
        return _axpy(base, per, L)
    if cfg.family == "moe":
        kd = cfg.moe.first_k_dense
        cA = _cost_of(cfg.replace(num_layers=kd + 2), shape, mesh, fsdp, donate_cache)
        cB = _cost_of(cfg.replace(num_layers=kd + 4), shape, mesh, fsdp, donate_cache)
        per = _diff(cB, cA, 2)           # per MoE layer
        base = _axpy(cA, per, -2)        # includes the kd dense layers
        return _axpy(base, per, L - kd)
    if cfg.family == "hybrid":
        # all probe lowers stay <= 4 layers: deep unrolled hybrids make the
        # SPMD partitioner crawl on the 5-D SSD decay tensors.
        n_apps = L // cfg.shared_attn_every
        cM2 = _cost_of(cfg.replace(num_layers=2, shared_attn_every=0), shape, mesh, fsdp, donate_cache)
        cM4 = _cost_of(cfg.replace(num_layers=4, shared_attn_every=0), shape, mesh, fsdp, donate_cache)
        per_m = _diff(cM4, cM2, 2)       # per mamba layer
        base = _axpy(cM2, per_m, -2)
        cS1 = _cost_of(cfg.replace(num_layers=2, shared_attn_every=2), shape, mesh, fsdp, donate_cache)
        cS2 = _cost_of(cfg.replace(num_layers=4, shared_attn_every=2), shape, mesh, fsdp, donate_cache)
        # cS2-cS1 = 2 mamba layers + 1 shared app  =>  shared = diff - 2*per_m
        shared = _axpy(_diff(cS2, cS1, 1), per_m, -2)
        out = _axpy(base, per_m, L)
        return _axpy(out, shared, n_apps)
    if cfg.family == "ssm":
        import dataclasses as _dc
        g = cfg.xlstm.slstm_every
        n_groups = L // g
        pure_m = _dc.replace(cfg.xlstm, slstm_every=0)
        mixed = _dc.replace(cfg.xlstm, slstm_every=2)
        cM2 = _cost_of(cfg.replace(num_layers=2, xlstm=pure_m), shape, mesh, fsdp, donate_cache)
        cM4 = _cost_of(cfg.replace(num_layers=4, xlstm=pure_m), shape, mesh, fsdp, donate_cache)
        per_m = _diff(cM4, cM2, 2)       # per mLSTM block
        base = _axpy(cM2, per_m, -2)
        cS2 = _cost_of(cfg.replace(num_layers=2, xlstm=mixed), shape, mesh, fsdp, donate_cache)
        cS4 = _cost_of(cfg.replace(num_layers=4, xlstm=mixed), shape, mesh, fsdp, donate_cache)
        # cS4-cS2 = one (1 mLSTM + 1 sLSTM) group  =>  per_s = diff - per_m
        per_s = _axpy(_diff(cS4, cS2, 1), per_m, -1)
        out = _axpy(base, per_m, n_groups * (g - 1))
        return _axpy(out, per_s, n_groups)
    raise ValueError(cfg.family)


def run_cell(arch: str, shape_name: str, mesh, multi_pod: bool,
             verbose: bool = True, donate: bool = True,
             cfg_override=None, with_cost: bool = True, fsdp=None,
             donate_cache: bool = False) -> Dict:
    cfg = cfg_override or get_config(arch)
    shape = SHAPES_BY_NAME[shape_name]
    # resolve FSDP on the FULL config: the reduced-depth cost probes must use
    # the same weight-sharding mode as the production compile
    if fsdp is None:
        fsdp = shape.kind == "train" and cfg.param_count() > 30e9
    t0 = time.time()
    # full-depth production compile (scan over layers): proof + memory
    compiled = _compile_cell(cfg, shape, mesh, donate, fsdp=fsdp,
                             donate_cache=donate_cache)
    t_compile = time.time() - t0

    ma = compiled.memory_analysis()
    n_chips = mesh.devices.size
    if with_cost:
        cost = extrapolated_cost(cfg, shape, mesh, fsdp=fsdp,
                                 donate_cache=donate_cache)
    else:
        ca = compiled.cost_analysis() or {}
        if isinstance(ca, (list, tuple)):   # jax 0.4.x wraps it in a list
            ca = ca[0] if ca else {}
        cost = {"flops": float(ca.get("flops", 0.0)),
                "bytes": float(ca.get("bytes accessed", 0.0)),
                "wire": wire_bytes(collective_bytes(compiled.as_text())),
                "collectives": {}}
    flops_per_dev = cost["flops"]
    bytes_per_dev = cost["bytes"]
    wire = cost["wire"]
    cb = cost["collectives"]

    compute_term = flops_per_dev / PEAK_FLOPS
    memory_term = bytes_per_dev / HBM_BW
    # flash-adjusted: score tiles stay in VMEM on TPU (Pallas kernel)
    adj_bytes = max(bytes_per_dev - attn_score_bytes(cfg, shape) / n_chips,
                    0.05 * bytes_per_dev)
    memory_term_flash = adj_bytes / HBM_BW
    collective_term = wire / ICI_BW

    n_params = cfg.param_count()
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        model_flops = 6.0 * n_active * shape.tokens
    elif shape.kind == "prefill":
        model_flops = 2.0 * n_active * shape.tokens
    else:
        model_flops = 2.0 * n_active * shape.global_batch
    hlo_flops_global = flops_per_dev * n_chips
    useful_ratio = model_flops / hlo_flops_global if hlo_flops_global else 0.0

    dominant = max((("compute", compute_term),
                    ("memory", memory_term_flash),
                    ("collective", collective_term)), key=lambda kv: kv[1])[0]
    res = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_chips": int(n_chips),
        "compile_s": round(t_compile, 1),
        "flops_per_dev": flops_per_dev,
        "bytes_per_dev": bytes_per_dev,
        "wire_bytes_per_dev": wire,
        "collectives": {k: round(v, 1) for k, v in cb.items() if v},
        "compute_term_s": compute_term,
        "memory_term_s": memory_term,
        "memory_term_flash_s": memory_term_flash,
        "collective_term_s": collective_term,
        "dominant": dominant,
        "model_flops": model_flops,
        "useful_flops_ratio": useful_ratio,
        "params_b": n_params / 1e9,
        "active_params_b": n_active / 1e9,
        "arg_bytes_per_dev": int(ma.argument_size_in_bytes),
        "temp_bytes_per_dev": int(ma.temp_size_in_bytes),
        "out_bytes_per_dev": int(ma.output_size_in_bytes),
    }
    if verbose:
        print(f"[dryrun] {arch:22s} {shape_name:12s} mesh={res['mesh']:8s} "
              f"compile={t_compile:6.1f}s dom={dominant:10s} "
              f"C={compute_term*1e3:9.3f}ms M={memory_term*1e3:9.3f}ms "
              f"Mf={memory_term_flash*1e3:9.3f}ms "
              f"N={collective_term*1e3:9.3f}ms useful={useful_ratio:5.2f} "
              f"args/dev={ma.argument_size_in_bytes/1e9:6.2f}GB "
              f"temp/dev={ma.temp_size_in_bytes/1e9:6.2f}GB", flush=True)
    return res


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    results = []
    meshes = []
    if args.both_meshes:
        meshes = [(False, make_production_mesh(multi_pod=False)),
                  (True, make_production_mesh(multi_pod=True))]
    else:
        meshes = [(args.multi_pod, make_production_mesh(multi_pod=args.multi_pod))]

    arch_list = [a for a in ARCH_IDS if a != "llama3_70b"] if args.all \
        else args.arch.split(",")

    def _flush():
        if args.out:
            with open(args.out, "w") as f:
                json.dump(results, f, indent=1)

    for arch in arch_list:
        cfg = get_config(arch)
        shapes = ([SHAPES_BY_NAME[args.shape]] if args.shape
                  else applicable_shapes(cfg))
        for sh in shapes:
            for mp, mesh in meshes:
                try:
                    # roofline cost terms are single-pod only (DESIGN.md);
                    # the multi-pod pass proves the "pod" axis shards.
                    results.append(run_cell(arch, sh.name, mesh, mp,
                                            with_cost=not mp))
                except Exception as e:  # a failing cell is a bug — surface it
                    print(f"[dryrun] FAIL {arch} {sh.name} "
                          f"{'2x16x16' if mp else '16x16'}: {type(e).__name__}: {e}",
                          flush=True)
                    results.append({"arch": arch, "shape": sh.name,
                                    "mesh": "2x16x16" if mp else "16x16",
                                    "error": f"{type(e).__name__}: {e}"})
                _flush()  # incremental: survive a killed sweep
    n_fail = sum(1 for r in results if "error" in r)
    print(f"[dryrun] {len(results) - n_fail}/{len(results)} cells OK")
    return 0 if n_fail == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
