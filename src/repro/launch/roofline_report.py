"""Render EXPERIMENTS.md roofline tables from dryrun_results.json."""
from __future__ import annotations

import argparse
import json
from typing import Dict, List


def fmt_s(x: float) -> str:
    if x >= 1.0:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.1f}ms"
    return f"{x*1e6:.0f}us"


HINTS = {
    ("compute",): "raise MXU utilization: larger per-device tiles, fewer remat "
                  "recomputes, bf16 logits",
    ("memory",): "cut HBM traffic: fuse attention (flash), bf16 intermediates, "
                 "larger microbatch to amortize weight reads",
    ("collective",): "re-shard to cut wire bytes: FSDP gather granularity, "
                     "EP instead of dispatch, overlap collectives with compute",
}


def render(results: List[Dict]) -> str:
    rows = []
    header = ("| arch | shape | mesh | compute | memory | memory(flash-adj) | "
              "collective | dominant | MODEL_FLOPS | useful ratio | "
              "args/dev | temp/dev |")
    sep = "|" + "---|" * 12
    rows.append(header)
    rows.append(sep)
    for r in results:
        if "error" in r:
            rows.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                        f"ERROR: {r['error']} |" + " |" * 8)
            continue
        if r["mesh"] != "16x16":
            continue  # roofline table is single-pod only
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {fmt_s(r['compute_term_s'])} "
            f"| {fmt_s(r['memory_term_s'])} "
            f"| {fmt_s(r.get('memory_term_flash_s', r['memory_term_s']))} "
            f"| {fmt_s(r['collective_term_s'])} "
            f"| **{r['dominant']}** "
            f"| {r['model_flops']:.2e} "
            f"| {r['useful_flops_ratio']:.2f} "
            f"| {r['arg_bytes_per_dev']/1e9:.1f}GB "
            f"| {r['temp_bytes_per_dev']/1e9:.1f}GB |")
    return "\n".join(rows)


def render_dryrun(results: List[Dict]) -> str:
    rows = ["| arch | shape | mesh | compile | flops/dev | bytes/dev | "
            "wire/dev | collective mix |", "|" + "---|" * 8]
    for r in results:
        if "error" in r:
            rows.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                        f"ERROR {r['error']} |" + " |" * 4)
            continue
        mix = ", ".join(f"{k}:{v/1e9:.2f}GB" for k, v in
                        r.get("collectives", {}).items()) or "-"
        rows.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} "
                    f"| {r['compile_s']}s | {r['flops_per_dev']:.2e} "
                    f"| {r['bytes_per_dev']:.2e} "
                    f"| {r['wire_bytes_per_dev']:.2e} | {mix} |")
    return "\n".join(rows)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="dryrun_results.json")
    ap.add_argument("--what", default="roofline",
                    choices=["roofline", "dryrun"])
    args = ap.parse_args()
    with open(args.json) as f:
        results = json.load(f)
    print(render(results) if args.what == "roofline"
          else render_dryrun(results))


if __name__ == "__main__":
    main()
