"""Production mesh factory + jax-version compat shims.

Factories are FUNCTIONS (not module-level constants) so importing this module
never touches jax device state. Single pod: (16, 16) = 256 chips
("data", "model"). Multi-pod: (2, 16, 16) = 512 chips ("pod", "data",
"model").

``compat_make_mesh`` / ``mesh_context`` paper over jax API drift:
* jax >= 0.5 ``jax.make_mesh`` takes ``axis_types``; 0.4.x does not.
* jax >= 0.5 activates a mesh with ``jax.set_mesh``; on 0.4.x the Mesh
  object itself is the context manager.
"""
from __future__ import annotations

from typing import Sequence

import jax


def compat_make_mesh(shape: Sequence[int], axes: Sequence[str], *,
                     shrink: bool = False):
    """``jax.make_mesh`` across jax versions. With ``shrink=True`` axis
    sizes are halved (largest-first) until the mesh fits the available
    device count — so single-host CPU runs still exercise the sharded
    code paths on a smaller mesh instead of failing the size assertion."""
    shape = list(shape)
    if shrink:
        n = jax.device_count()
        while _prod(shape) > n:
            i = max(range(len(shape)), key=lambda j: shape[j])
            if shape[i] == 1:
                break
            shape[i] = max(1, shape[i] // 2)
    try:
        return jax.make_mesh(tuple(shape), tuple(axes),
                             axis_types=(jax.sharding.AxisType.Auto,)
                             * len(axes))
    except (TypeError, AttributeError):   # jax 0.4.x: no axis_types kwarg
        return jax.make_mesh(tuple(shape), tuple(axes))


def mesh_context(mesh):
    """Context manager that makes ``mesh`` the ambient mesh."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh   # 0.4.x: Mesh is itself a context manager


def _prod(xs) -> int:
    out = 1
    for x in xs:
        out *= int(x)
    return out


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat_make_mesh(shape, axes)


def make_local_mesh():
    """Whatever devices exist locally, as a 1-D 'data' mesh (smoke tests)."""
    n = len(jax.devices())
    return compat_make_mesh((n,), ("data",))


def handoff_devices(n_prefill: int, n_decode: int):
    """Assign local jax devices to disaggregated worker roles
    (``engine/workers.py``): prefill workers take the first half of the
    device list, decode workers the rest, round-robin within each role — so
    the prefill->decode KV handoff is a real cross-device ``jax.device_put``
    whenever the host has >= 2 devices. With a single device both lists are
    all-None, which the workers treat as "host-staged": pages ride through
    host memory (``jax.device_get`` then scatter), the same degradation the
    single-device engine's swap path uses."""
    devs = jax.devices()
    if len(devs) < 2:
        return [None] * n_prefill, [None] * n_decode
    split = max(1, min(len(devs) - 1, len(devs) // 2))
    pd, dd = devs[:split], devs[split:]
    return ([pd[i % len(pd)] for i in range(n_prefill)],
            [dd[i % len(dd)] for i in range(n_decode)])
