"""Production mesh factory + jax-version compat shims.

Factories are FUNCTIONS (not module-level constants) so importing this module
never touches jax device state. Single pod: (16, 16) = 256 chips
("data", "model"). Multi-pod: (2, 16, 16) = 512 chips ("pod", "data",
"model").

``compat_make_mesh`` / ``mesh_context`` paper over jax API drift:
* jax >= 0.5 ``jax.make_mesh`` takes ``axis_types``; 0.4.x does not.
* jax >= 0.5 activates a mesh with ``jax.set_mesh``; on 0.4.x the Mesh
  object itself is the context manager.
"""
from __future__ import annotations

from typing import Sequence

import jax


def compat_make_mesh(shape: Sequence[int], axes: Sequence[str], *,
                     shrink: bool = False):
    """``jax.make_mesh`` across jax versions. With ``shrink=True`` axis
    sizes are halved (largest-first) until the mesh fits the available
    device count — so single-host CPU runs still exercise the sharded
    code paths on a smaller mesh instead of failing the size assertion."""
    shape = list(shape)
    if shrink:
        n = jax.device_count()
        while _prod(shape) > n:
            i = max(range(len(shape)), key=lambda j: shape[j])
            if shape[i] == 1:
                break
            shape[i] = max(1, shape[i] // 2)
    try:
        return jax.make_mesh(tuple(shape), tuple(axes),
                             axis_types=(jax.sharding.AxisType.Auto,)
                             * len(axes))
    except (TypeError, AttributeError):   # jax 0.4.x: no axis_types kwarg
        return jax.make_mesh(tuple(shape), tuple(axes))


def mesh_context(mesh):
    """Context manager that makes ``mesh`` the ambient mesh."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh   # 0.4.x: Mesh is itself a context manager


def _prod(xs) -> int:
    out = 1
    for x in xs:
        out *= int(x)
    return out


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat_make_mesh(shape, axes)


def make_local_mesh():
    """Whatever devices exist locally, as a 1-D 'data' mesh (smoke tests)."""
    n = len(jax.devices())
    return compat_make_mesh((n,), ("data",))
