"""Perf-iteration driver (§Perf): compare named config variants of one
(arch x shape) cell and print the roofline-term deltas.

    PYTHONPATH=src python -m repro.launch.perf_iter --arch minicpm3_4b \
        --shape train_4k --variants baseline,mla_absorb,bf16_logits
"""
import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

import argparse
import dataclasses
import json

from repro.configs import get_config
from repro.configs.base import ModelConfig
from repro.launch.dryrun import run_cell
from repro.launch.mesh import make_production_mesh


def variant(cfg: ModelConfig, name: str) -> ModelConfig:
    """Named beyond-baseline optimizations (the hillclimb moves)."""
    if name == "baseline":
        return cfg
    if name == "mla_absorb":
        return cfg.replace(mla=dataclasses.replace(cfg.mla, absorb=True))
    if name == "bf16_logits":
        return cfg.replace(logits_dtype="bfloat16")
    if name == "moe_dispatch":
        return cfg.replace(moe=dataclasses.replace(cfg.moe,
                                                   impl="dispatch_einsum"))
    if name == "moe_ragged":
        return cfg.replace(moe=dataclasses.replace(cfg.moe, impl="ragged_ep"))
    if name == "shard_v2":
        return cfg.replace(shard_v2=True)
    if name == "shard_v2_bf16":
        return cfg.replace(shard_v2=True, logits_dtype="bfloat16")
    if name == "attn_in_seqshard":
        return cfg.replace(attn_in_seqshard=True)
    if name == "remat_dots":
        return cfg.replace(remat="dots")
    if name == "remat_none":
        return cfg.replace(remat="none")
    if name == "chunk512":
        if cfg.ssm:
            cfg = cfg.replace(ssm=dataclasses.replace(cfg.ssm, chunk_size=512))
        if cfg.xlstm:
            cfg = cfg.replace(xlstm=dataclasses.replace(cfg.xlstm,
                                                        chunk_size=512))
        return cfg
    raise ValueError(name)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--variants", default="baseline")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    mesh = make_production_mesh(multi_pod=args.multi_pod)
    results = []
    base = get_config(args.arch)
    for vname in args.variants.split(","):
        vname = vname.strip()
        mods = vname.split("+")
        fsdp = False if "no_fsdp" in mods else None
        donate_cache = "donate" in mods
        cfg = base
        for m in mods:
            if m not in ("no_fsdp", "donate"):
                cfg = variant(cfg, m)
        res = run_cell(args.arch, args.shape, mesh, args.multi_pod,
                       verbose=False, cfg_override=cfg, fsdp=fsdp,
                       donate_cache=donate_cache)
        res["variant"] = vname
        results.append(res)
        print(f"[perf] {args.arch} {args.shape} {vname:14s} "
              f"dom={res['dominant']:10s} "
              f"C={res['compute_term_s']*1e3:9.2f}ms "
              f"M={res['memory_term_s']*1e3:9.2f}ms "
              f"Mf={res['memory_term_flash_s']*1e3:9.2f}ms "
              f"N={res['collective_term_s']*1e3:9.2f}ms "
              f"useful={res['useful_flops_ratio']:.2f}", flush=True)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)


if __name__ == "__main__":
    main()
