"""Reconstruct dryrun result rows from the printed log (for cells whose JSON
was lost to an interrupted sweep). Terms are inverted from the printed
roofline numbers; the collective per-kind mix is not recoverable from the log
and is left empty."""
from __future__ import annotations

import argparse
import json
import re

from repro.configs import SHAPES_BY_NAME, get_config
from repro.launch.dryrun import HBM_BW, ICI_BW, PEAK_FLOPS

LINE = re.compile(
    r"\[dryrun\] (\S+)\s+(\S+)\s+mesh=(\S+)\s+compile=\s*([\d.]+)s "
    r"dom=(\S+)\s+C=\s*([\d.]+)ms M=\s*([\d.]+)ms (?:Mf=\s*([\d.]+)ms )?"
    r"N=\s*([\d.]+)ms useful=\s*([\d.]+) args/dev=\s*([\d.]+)GB "
    r"temp/dev=\s*([\d.]+)GB")


def parse(path: str):
    rows = []
    for line in open(path):
        m = LINE.search(line)
        if not m:
            continue
        (arch, shape, mesh, comp, dom, c, mm, mf, n, useful, args_gb,
         temp_gb) = m.groups()
        cfg = get_config(arch)
        sh = SHAPES_BY_NAME[shape]
        n_active = cfg.active_param_count()
        if sh.kind == "train":
            model_flops = 6.0 * n_active * sh.tokens
        elif sh.kind == "prefill":
            model_flops = 2.0 * n_active * sh.tokens
        else:
            model_flops = 2.0 * n_active * sh.global_batch
        c, mm, n = float(c) / 1e3, float(mm) / 1e3, float(n) / 1e3
        mf_s = float(mf) / 1e3 if mf else mm
        rows.append({
            "arch": arch, "shape": shape, "mesh": mesh,
            "n_chips": 512 if mesh == "2x16x16" else 256,
            "compile_s": float(comp),
            "flops_per_dev": c * PEAK_FLOPS,
            "bytes_per_dev": mm * HBM_BW,
            "wire_bytes_per_dev": n * ICI_BW,
            "collectives": {},
            "compute_term_s": c, "memory_term_s": mm,
            "memory_term_flash_s": mf_s, "collective_term_s": n,
            "dominant": dom,
            "model_flops": model_flops,
            "useful_flops_ratio": float(useful),
            "params_b": cfg.param_count() / 1e9,
            "active_params_b": n_active / 1e9,
            "arg_bytes_per_dev": int(float(args_gb) * 1e9),
            "temp_bytes_per_dev": int(float(temp_gb) * 1e9),
            "out_bytes_per_dev": 0,
            "from_log": True,
        })
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--log", required=True)
    ap.add_argument("--out", required=True)
    args = ap.parse_args()
    rows = parse(args.log)
    with open(args.out, "w") as f:
        json.dump(rows, f, indent=1)
    print(f"recovered {len(rows)} rows")
