"""Serving driver: real-execution continuous-batching engine on a small model.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma_2b --requests 12
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.configs import get_reduced_config
from repro.engine.runner import make_engine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma_2b")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_reduced_config(args.arch)
    if not cfg.supports_decode:
        raise SystemExit(f"{args.arch} is encoder-only; no serving path")
    eng = make_engine(cfg, max_batch=args.max_batch, max_len=args.max_len,
                      seed=args.seed)
    rng = np.random.default_rng(args.seed)
    t0 = time.monotonic()
    for i in range(args.requests):
        plen = int(rng.integers(8, 48))
        eng.submit(rng.integers(0, cfg.vocab_size, plen), args.max_new)
    done = eng.run()
    wall = time.monotonic() - t0
    toks = sum(len(r.tokens) for r in done)
    ttfts = [r.ttft for r in done if r.ttft is not None]
    tpots = [r.tpot for r in done if r.tpot is not None]
    print(f"[serve] arch={args.arch} requests={len(done)} tokens={toks} "
          f"wall={wall:.2f}s thpt={toks/wall:.1f} tok/s")
    print(f"[serve] ttft_mean={np.mean(ttfts)*1e3:.1f}ms "
          f"tpot_mean={np.mean(tpots)*1e3:.1f}ms engine_steps={eng.steps}")
    return done


if __name__ == "__main__":
    main()
