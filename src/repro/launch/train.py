"""Training driver: real execution on local devices with checkpoint/restart.

    PYTHONPATH=src python -m repro.launch.train --arch gemma_2b --steps 50 \
        --reduced --ckpt-dir /tmp/ckpt

Distribution notes (1000+-node posture): the step function is pjit'd against
whatever mesh exists — on the production mesh the same code path shards DP
over ("pod","data") and TP over "model" exactly as the dry-run proves; here it
runs on the local CPU mesh. Restart resumes from the newest complete
checkpoint and replays the deterministic data stream from that step.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import ckpt
from repro.configs import get_config, get_reduced_config
from repro.data.pipeline import DataConfig, batch_at
from repro.models import steps
from repro.models.optim import OptConfig


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma_2b")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--reduced", action="store_true",
                    help="use the smoke-scale config (CPU-friendly)")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--param-dtype", default="float32")
    args = ap.parse_args(argv)

    cfg = (get_reduced_config(args.arch) if args.reduced
           else get_config(args.arch))
    cfg = cfg.replace(param_dtype=args.param_dtype,
                      compute_dtype=args.param_dtype, remat="none")
    opt = OptConfig(lr=args.lr, warmup_steps=max(2, args.steps // 10),
                    total_steps=args.steps)
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                    global_batch=args.batch)

    state = steps.init_train_state(cfg, jax.random.PRNGKey(0))
    start = 0
    if args.ckpt_dir:
        last = ckpt.latest_step(args.ckpt_dir)
        if last is not None:
            state, man = ckpt.restore(args.ckpt_dir, state)
            start = man["step"]
            print(f"[train] restored step {start} from {args.ckpt_dir}")

    step_fn = jax.jit(lambda st, b: steps.train_step(st, b, cfg, opt))

    t0 = time.time()
    losses = []
    for i in range(start, args.steps):
        batch = {k: jnp.asarray(v) for k, v in batch_at(dc, i).items()}
        state, metrics = step_fn(state, batch)
        losses.append(float(metrics["loss"]))
        if (i + 1) % args.log_every == 0 or i == args.steps - 1:
            dt = time.time() - t0
            print(f"[train] step {i+1:5d} loss={losses[-1]:.4f} "
                  f"gnorm={float(metrics['grad_norm']):.3f} "
                  f"({dt/max(1,len(losses)):.2f}s/step)", flush=True)
        if args.ckpt_dir and (i + 1) % args.ckpt_every == 0:
            ckpt.save(args.ckpt_dir, i + 1, state)
    if len(losses) > 10:
        print(f"[train] loss first10={np.mean(losses[:10]):.4f} "
              f"last10={np.mean(losses[-10:]):.4f}")
    return losses


if __name__ == "__main__":
    main()
