"""Fault-tolerant checkpointing: step-atomic msgpack + manifest.

Layout:  <dir>/step_<N>/arrays.msgpack  +  <dir>/step_<N>/MANIFEST.json
A checkpoint directory only becomes visible once fully written (tmp-dir
rename), so a mid-save crash never corrupts the restore path. ``restore``
picks the newest complete step; older steps are garbage-collected with
``keep`` retention.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import msgpack
import numpy as np


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for kp, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in kp)
        flat[key] = np.asarray(leaf)
    return flat


def _pack_array(a: np.ndarray) -> Dict:
    return {"dtype": str(a.dtype), "shape": list(a.shape),
            "data": a.tobytes()}


def _unpack_array(d: Dict) -> np.ndarray:
    dt = d["dtype"]
    # numpy can't parse 'bfloat16'; round-trip through uint16 view
    if dt == "bfloat16":
        raw = np.frombuffer(d["data"], np.uint16).reshape(d["shape"])
        return jnp.asarray(raw.view(jnp.bfloat16.dtype) if hasattr(
            jnp.bfloat16, "dtype") else raw, dtype=jnp.bfloat16)
    return np.frombuffer(d["data"], dt).reshape(d["shape"])


def save(ckpt_dir: str, step: int, tree: Any, extra: Optional[Dict] = None,
         keep: int = 3) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    flat = _flatten(tree)
    payload = {k: _pack_array(v) for k, v in flat.items()}
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = tempfile.mkdtemp(dir=ckpt_dir, prefix=".tmp_")
    try:
        with open(os.path.join(tmp, "arrays.msgpack"), "wb") as f:
            f.write(msgpack.packb(payload))
        manifest = {"step": step, "n_arrays": len(flat),
                    "bytes": sum(v.nbytes for v in flat.values()),
                    "extra": extra or {}}
        with open(os.path.join(tmp, "MANIFEST.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)            # atomic publish
    except Exception:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    _gc(ckpt_dir, keep)
    return final


def _gc(ckpt_dir: str, keep: int):
    steps = sorted(d for d in os.listdir(ckpt_dir) if d.startswith("step_"))
    for d in steps[:-keep] if keep else []:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    best = None
    for d in sorted(os.listdir(ckpt_dir)):
        if not d.startswith("step_"):
            continue
        if os.path.exists(os.path.join(ckpt_dir, d, "MANIFEST.json")):
            best = int(d.split("_")[1])
    return best


def restore(ckpt_dir: str, like: Any, step: Optional[int] = None
            ) -> Tuple[Any, Dict]:
    """Restore into the structure of ``like`` (a pytree of arrays or
    ShapeDtypeStructs). Returns (tree, manifest)."""
    step = latest_step(ckpt_dir) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "arrays.msgpack"), "rb") as f:
        payload = msgpack.unpackb(f.read())
    with open(os.path.join(path, "MANIFEST.json")) as f:
        manifest = json.load(f)
    flat_like = _flatten(jax.tree.map(
        lambda t: np.zeros((0,)) if isinstance(t, jax.ShapeDtypeStruct) else t,
        like))
    keys = list(flat_like.keys())
    missing = [k for k in keys if k not in payload]
    if missing:
        raise KeyError(f"checkpoint missing arrays: {missing[:5]}...")
    arrays = {k: _unpack_array(payload[k]) for k in keys}
    leaves_kp, treedef = jax.tree_util.tree_flatten_with_path(like)
    new_leaves = []
    for kp, leaf in leaves_kp:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in kp)
        a = arrays[key]
        want_dtype = leaf.dtype if hasattr(leaf, "dtype") else a.dtype
        new_leaves.append(jnp.asarray(a, dtype=want_dtype))
    return jax.tree_util.tree_unflatten(treedef, new_leaves), manifest
