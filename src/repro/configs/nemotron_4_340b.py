"""Nemotron-4-340B [arXiv:2402.16819]. Dense GQA (kv=8), squared-ReLU MLP."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-340b",
    family="dense",
    num_layers=96,
    d_model=18432,
    num_heads=96,
    num_kv_heads=8,
    d_ff=73728,
    vocab_size=256_000,
    mlp_type="relu2",
    attn_type="gqa",
    rope_theta=10_000.0,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="nemotron-4-340b-smoke",
        num_layers=2,
        d_model=64,
        num_heads=8,
        num_kv_heads=2,
        head_dim=8,
        d_ff=256,
        vocab_size=512,
    )
