"""Pixtral-12B [hf:mistralai/Pixtral-12B-2409]. VLM: pixtral-ViT frontend
(STUB — ``input_specs()`` provides precomputed patch embeddings) feeding a
Mistral-NeMo-style dense GQA decoder."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="pixtral-12b",
    family="vlm",
    num_layers=40,
    d_model=5120,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=131_072,
    mlp_type="swiglu",
    attn_type="gqa",
    stub_frontend=True,
    frontend_dim=1024,  # pixtral ViT hidden size; projected into d_model
    rope_theta=1_000_000.0,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="pixtral-12b-smoke",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=512,
        frontend_dim=32,
    )
