"""InternLM2-20B [arXiv:2403.17297]. Dense GQA (kv=8), SwiGLU."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internlm2-20b",
    family="dense",
    num_layers=48,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=16384,
    vocab_size=92_544,
    mlp_type="swiglu",
    attn_type="gqa",
    rope_theta=1_000_000.0,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="internlm2-20b-smoke",
        num_layers=2,
        d_model=64,
        num_heads=8,
        num_kv_heads=2,
        head_dim=8,
        d_ff=256,
        vocab_size=512,
    )
