"""Gemma-2B [arXiv:2403.08295]. MQA (kv=1), GeGLU, head_dim=256."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma-2b",
    family="dense",
    num_layers=18,
    d_model=2048,
    num_heads=8,
    num_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab_size=256_000,
    mlp_type="geglu",
    attn_type="gqa",
    tie_embeddings=True,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="gemma-2b-smoke",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=1,
        head_dim=16,
        d_ff=256,
        vocab_size=512,
    )
