"""xLSTM-1.3B [arXiv:2405.04517]. Stacked mLSTM blocks with periodic sLSTM
blocks (7:1 ratio). d_ff=0: the up/down projections live inside the blocks."""
from repro.configs.base import ModelConfig, XLSTMConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b",
    family="ssm",
    num_layers=48,
    d_model=2048,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=50_304,
    mlp_type="gelu",
    attn_type="none",
    xlstm=XLSTMConfig(slstm_every=8, proj_factor_mlstm=2.0, conv_width=4),
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="xlstm-1.3b-smoke",
        num_layers=4,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        vocab_size=512,
        xlstm=XLSTMConfig(slstm_every=2, proj_factor_mlstm=2.0, conv_width=4, chunk_size=32),
    )
