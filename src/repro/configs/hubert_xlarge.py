"""HuBERT-XLarge [arXiv:2106.07447]. Encoder-only audio transformer
(wav2vec2-style backbone). The CNN feature extractor is a STUB —
``input_specs()`` provides precomputed frame embeddings. vocab=504 is the
masked-prediction codebook."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge",
    family="audio",
    num_layers=48,
    d_model=1280,
    num_heads=16,
    num_kv_heads=16,
    d_ff=5120,
    vocab_size=504,
    mlp_type="gelu",
    attn_type="gqa",
    norm_type="layernorm",
    encoder_only=True,
    stub_frontend=True,
    frontend_dim=512,  # conv feature-extractor output dim
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="hubert-xlarge-smoke",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        d_ff=128,
        vocab_size=128,
        frontend_dim=32,
    )
