"""Config schema for the repro framework.

Every assigned architecture is expressed as a ``ModelConfig``; input shapes as
``ShapeConfig``. Configs are plain dataclasses so they can be constructed,
reduced (for smoke tests) and serialized without any framework machinery.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class MLAConfig:
    """Multi-head Latent Attention (DeepSeek-V2 / MiniCPM3) parameters."""

    kv_lora_rank: int = 512
    q_lora_rank: int = 0  # 0 => no query compression
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128
    # Decode-time weight absorption (DeepSeek-V2 §"absorb"): attend directly in
    # the compressed latent space instead of re-expanding K/V each step.
    absorb: bool = False


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 0
    num_shared_experts: int = 0
    top_k: int = 0
    expert_d_ff: int = 0
    first_k_dense: int = 1          # leading dense layers (DeepSeek style)
    shared_d_ff: int = 0            # d_ff of the shared experts (total)
    router_noise: float = 0.0
    capacity_slack: float = 2.0     # EP static-capacity multiplier
    impl: str = "ragged_ep"         # "ragged_ep" | "dispatch_einsum"
    aux_loss_coef: float = 0.001


@dataclass(frozen=True)
class SSMConfig:
    """Mamba2 (SSD) block parameters."""

    state_dim: int = 64
    head_dim: int = 64
    expand: int = 2
    conv_width: int = 4
    chunk_size: int = 256


@dataclass(frozen=True)
class XLSTMConfig:
    slstm_every: int = 8            # every k-th block is sLSTM, rest mLSTM
    proj_factor_mlstm: float = 2.0
    proj_factor_slstm: float = 1.333
    conv_width: int = 4
    chunk_size: int = 256


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | hybrid | ssm | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0               # 0 => d_model // num_heads
    mlp_type: str = "swiglu"        # swiglu | geglu | relu2 | gelu
    attn_type: str = "gqa"          # gqa | mla | none
    mla: Optional[MLAConfig] = None
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    xlstm: Optional[XLSTMConfig] = None
    # hybrid (zamba2): a shared attention block applied every k SSM layers
    shared_attn_every: int = 0
    encoder_only: bool = False
    stub_frontend: bool = False     # vlm/audio: inputs are precomputed embeddings
    frontend_dim: int = 0           # embedding dim delivered by the stub frontend
    rope_theta: float = 10_000.0
    norm_type: str = "rmsnorm"      # rmsnorm | layernorm
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    logits_softcap: float = 0.0
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    logits_dtype: str = "float32"   # perf knob: bf16 halves lm-head traffic
    remat: str = "full"             # none | full | dots  (activation ckpt policy)
    scan_layers: bool = True
    # §Perf sharding profile: v2 shards the KV-cache SEQUENCE over "model"
    # (flash-decode style) instead of head_dim, avoiding the rope-split
    # resharding storms the baseline exhibits when heads % model != 0.
    shard_v2: bool = False
    # §Perf: seq-shard the attention INPUT (d_model wide) instead of
    # resharding the much wider Q tensor per layer (heads-not-divisible case)
    attn_in_seqshard: bool = False

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.num_heads)

    @property
    def is_subquadratic(self) -> bool:
        """Whether the arch supports 500k-token decode (SSM/hybrid/linear)."""
        return self.family in ("hybrid", "ssm")

    @property
    def supports_decode(self) -> bool:
        return not self.encoder_only

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def param_count(self) -> int:
        """Approximate parameter count (used by the perf model and roofline)."""
        d, L = self.d_model, self.num_layers
        hd = self.resolved_head_dim
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        if self.stub_frontend:
            emb = self.vocab_size * d + (self.frontend_dim or d) * d
        per_layer = 0
        if self.attn_type == "mla":
            m = self.mla
            q_in = m.q_lora_rank or d
            per_layer += (d * m.q_lora_rank if m.q_lora_rank else 0)
            per_layer += q_in * self.num_heads * (m.qk_nope_head_dim + m.qk_rope_head_dim)
            per_layer += d * (m.kv_lora_rank + m.qk_rope_head_dim)
            per_layer += m.kv_lora_rank * self.num_heads * (m.qk_nope_head_dim + m.v_head_dim)
            per_layer += self.num_heads * m.v_head_dim * d
        elif self.attn_type == "gqa":
            per_layer += d * self.num_heads * hd                      # Q
            per_layer += 2 * d * self.num_kv_heads * hd               # K,V
            per_layer += self.num_heads * hd * d                      # O
        n_mult = {"swiglu": 3, "geglu": 3, "relu2": 2, "gelu": 2}[self.mlp_type]
        if self.moe and self.moe.num_experts:
            dense_layers = self.moe.first_k_dense
            moe_layers = L - dense_layers
            per_layer_moe = (
                self.moe.num_experts * n_mult * d * self.moe.expert_d_ff
                + n_mult * d * (self.moe.shared_d_ff or 0)
                + d * self.moe.num_experts
            )
            mlp_total = dense_layers * n_mult * d * self.d_ff + moe_layers * per_layer_moe
        elif self.family == "ssm" and self.xlstm is not None:
            mlp_total = 0  # folded into block accounting below
        else:
            mlp_total = L * n_mult * d * self.d_ff
        total = emb + L * per_layer + mlp_total
        if self.ssm is not None:
            d_inner = self.ssm.expand * d
            nheads = d_inner // self.ssm.head_dim
            per_ssm = d * (2 * d_inner + 2 * self.ssm.state_dim + nheads) + d_inner * d
            total = emb + L * per_ssm
            if self.shared_attn_every:
                total += d * self.num_heads * hd * 2 + 2 * d * self.num_kv_heads * hd
                total += 3 * d * self.d_ff
        if self.xlstm is not None:
            pf_m = self.xlstm.proj_factor_mlstm
            d_in = int(pf_m * d)
            per_m = d * d_in * 2 + d_in * d + 3 * d_in * self.num_heads + d_in * d_in // max(1, self.num_heads)
            total = emb + L * per_m
        return int(total)

    def active_param_count(self) -> int:
        """Active params per token (= param_count for dense archs)."""
        if not (self.moe and self.moe.num_experts):
            return self.param_count()
        full = self.param_count()
        n_mult = {"swiglu": 3, "geglu": 3, "relu2": 2, "gelu": 2}[self.mlp_type]
        moe_layers = self.num_layers - self.moe.first_k_dense
        all_exp = moe_layers * self.moe.num_experts * n_mult * self.d_model * self.moe.expert_d_ff
        act_exp = moe_layers * self.moe.top_k * n_mult * self.d_model * self.moe.expert_d_ff
        return int(full - all_exp + act_exp)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                       # train | prefill | decode

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


SHAPES: Tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", 4096, 256, "train"),
    ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    ShapeConfig("decode_32k", 32_768, 128, "decode"),
    ShapeConfig("long_500k", 524_288, 1, "decode"),
)

SHAPES_BY_NAME = {s.name: s for s in SHAPES}


def applicable_shapes(cfg: ModelConfig):
    """The shapes a given architecture actually runs (skips per DESIGN.md §4)."""
    out = []
    for s in SHAPES:
        if s.kind == "decode" and not cfg.supports_decode:
            continue  # encoder-only: no autoregressive decode
        if s.name == "long_500k" and not cfg.is_subquadratic:
            continue  # needs sub-quadratic attention
        out.append(s)
    return out
