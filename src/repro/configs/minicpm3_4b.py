"""MiniCPM3-4B [hf:openbmb/MiniCPM3-4B]. Dense with MLA attention."""
from repro.configs.base import MLAConfig, ModelConfig

CONFIG = ModelConfig(
    name="minicpm3-4b",
    family="dense",
    num_layers=62,
    d_model=2560,
    num_heads=40,
    num_kv_heads=40,
    d_ff=6400,
    vocab_size=73_448,
    mlp_type="swiglu",
    attn_type="mla",
    mla=MLAConfig(
        kv_lora_rank=256,
        q_lora_rank=768,
        qk_nope_head_dim=64,
        qk_rope_head_dim=32,
        v_head_dim=64,
    ),
    tie_embeddings=True,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="minicpm3-4b-smoke",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        d_ff=128,
        vocab_size=512,
        mla=MLAConfig(
            kv_lora_rank=32,
            q_lora_rank=48,
            qk_nope_head_dim=16,
            qk_rope_head_dim=8,
            v_head_dim=16,
        ),
    )
