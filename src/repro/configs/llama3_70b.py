"""Llama-3-70B class config — the model the paper's case studies serve
(Figs. 6/8/10–13, Table III). Not part of the assigned 10; used by the
simulator benchmarks and the perf model."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama3-70b",
    family="dense",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=28672,
    vocab_size=128_256,
    mlp_type="swiglu",
    attn_type="gqa",
    rope_theta=500_000.0,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="llama3-70b-smoke",
        num_layers=2,
        d_model=64,
        num_heads=8,
        num_kv_heads=2,
        head_dim=8,
        d_ff=256,
        vocab_size=512,
    )
