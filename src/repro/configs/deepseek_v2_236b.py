"""DeepSeek-V2-236B [arXiv:2405.04434]. MLA (kv_lora=512) + MoE:
160 routed experts top-6 + 2 shared, first layer dense."""
from repro.configs.base import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    family="moe",
    num_layers=60,
    d_model=5120,
    num_heads=128,
    num_kv_heads=128,
    d_ff=12288,               # dense (first_k_dense) layer FFN width
    vocab_size=102_400,
    mlp_type="swiglu",
    attn_type="mla",
    mla=MLAConfig(
        kv_lora_rank=512,
        q_lora_rank=1536,
        qk_nope_head_dim=128,
        qk_rope_head_dim=64,
        v_head_dim=128,
    ),
    moe=MoEConfig(
        num_experts=160,
        num_shared_experts=2,
        top_k=6,
        expert_d_ff=1536,
        shared_d_ff=2 * 1536,
        first_k_dense=1,
    ),
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="deepseek-v2-236b-smoke",
        num_layers=3,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        d_ff=256,
        vocab_size=512,
        mla=MLAConfig(
            kv_lora_rank=32,
            q_lora_rank=48,
            qk_nope_head_dim=16,
            qk_rope_head_dim=8,
            v_head_dim=16,
        ),
        moe=MoEConfig(
            num_experts=8,
            num_shared_experts=2,
            top_k=2,
            expert_d_ff=64,
            shared_d_ff=128,
            first_k_dense=1,
        ),
    )
