"""DeepSeek-V2-Lite-16B [arXiv:2405.04434]. MLA (kv_lora=512, no q compression)
+ MoE: 64 routed experts top-6 + 2 shared, first layer dense."""
from repro.configs.base import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    num_layers=27,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=10944,               # dense (first_k_dense) layer FFN width
    vocab_size=102_400,
    mlp_type="swiglu",
    attn_type="mla",
    mla=MLAConfig(
        kv_lora_rank=512,
        q_lora_rank=0,        # lite variant: no query compression
        qk_nope_head_dim=128,
        qk_rope_head_dim=64,
        v_head_dim=128,
    ),
    moe=MoEConfig(
        num_experts=64,
        num_shared_experts=2,
        top_k=6,
        expert_d_ff=1408,
        shared_d_ff=2 * 1408,
        first_k_dense=1,
    ),
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="deepseek-v2-lite-16b-smoke",
        num_layers=3,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        d_ff=256,
        vocab_size=512,
        mla=MLAConfig(
            kv_lora_rank=32,
            q_lora_rank=0,
            qk_nope_head_dim=16,
            qk_rope_head_dim=8,
            v_head_dim=16,
        ),
        moe=MoEConfig(
            num_experts=8,
            num_shared_experts=2,
            top_k=2,
            expert_d_ff=64,
            shared_d_ff=128,
            first_k_dense=1,
        ),
    )
