"""Architecture config registry.

Each assigned architecture lives in its own module (``<arch>.py``) exposing
``CONFIG`` (the exact published config) and ``reduced()`` (a tiny same-family
config for CPU smoke tests).
"""
from __future__ import annotations

import importlib
from typing import Dict

from repro.configs.base import (  # noqa: F401
    MLAConfig,
    MoEConfig,
    ModelConfig,
    SHAPES,
    SHAPES_BY_NAME,
    ShapeConfig,
    SSMConfig,
    XLSTMConfig,
    applicable_shapes,
)

ARCH_IDS = (
    "nemotron_4_340b",
    "minicpm3_4b",
    "gemma_2b",
    "internlm2_20b",
    "zamba2_7b",
    "pixtral_12b",
    "xlstm_1_3b",
    "deepseek_v2_236b",
    "deepseek_v2_lite_16b",
    "hubert_xlarge",
    # the paper's own case-study model (Llama-3-70B class)
    "llama3_70b",
    # guard/draft-class small model (pipeline safety stage + spec-decode draft)
    "guard_2b",
)


def _norm(arch: str) -> str:
    return arch.replace("-", "_").replace(".", "_")


def get_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_norm(arch)}")
    return mod.CONFIG


def get_reduced_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_norm(arch)}")
    return mod.reduced()


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}
