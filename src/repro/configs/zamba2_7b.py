"""Zamba2-7B [arXiv:2411.15242]. Hybrid: Mamba2 backbone + shared attention
block applied periodically (weights shared across applications)."""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    num_layers=81,
    d_model=3584,
    num_heads=32,
    num_kv_heads=32,
    d_ff=14336,
    vocab_size=32_000,
    mlp_type="swiglu",
    attn_type="gqa",
    ssm=SSMConfig(state_dim=64, head_dim=64, expand=2, conv_width=4),
    shared_attn_every=13,  # 6 shared-block applications over 81 mamba layers
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="zamba2-7b-smoke",
        num_layers=4,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        head_dim=16,
        d_ff=128,
        vocab_size=512,
        ssm=SSMConfig(state_dim=16, head_dim=16, expand=2, conv_width=4, chunk_size=32),
        shared_attn_every=2,
    )
