"""Llama-Guard-2B-class safety/draft model (paper §IV-C pipeline stage).

Used by the simulator as the guard stage of safety-checked pipelines and by
the serving stack as the DRAFT model for speculative decoding — a dense
GQA config an order of magnitude under the target models it rides with.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="guard-2b",
    family="dense",
    num_layers=18,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=8192,
    vocab_size=32000,
    mlp_type="gelu",
    attn_type="gqa",
)


def reduced() -> ModelConfig:
    """Tiny same-family config for CPU smoke tests. Deliberately shares the
    512-token vocabulary of ``gemma_2b.reduced()`` so it can serve as that
    config's speculative-decoding draft in engine tests and benchmarks."""
    return CONFIG.replace(
        name="guard-2b-smoke",
        num_layers=1,
        d_model=32,
        num_heads=2,
        num_kv_heads=2,
        head_dim=16,
        d_ff=64,
        vocab_size=512,
    )
