"""Reproduce the paper's core recommendation study (Table III, compact):
sweep batching strategies x injection rates on one trace and print which
strategy wins each objective.

    PYTHONPATH=src python examples/batching_study.py
"""
from repro.core import SLO, SystemSpec, WorkloadConfig, build_system, generate
from repro.core.workload import AZURE_CODE


def run_cell(strategy: str, rate: float):
    if strategy == "disaggregated":
        spec = SystemSpec(strategy="disaggregated", n_prefill=2, n_decode=2,
                          with_pre_post=False)
    else:
        spec = SystemSpec(n_llm_clients=4, strategy=strategy,
                          with_pre_post=False)
    coord = build_system(spec)
    wl = WorkloadConfig(trace=AZURE_CODE, rate=rate, n_requests=60,
                        disaggregated=(strategy == "disaggregated"),
                        postprocess=False, seed=1)
    coord.submit(generate(wl))
    m = coord.run()
    horizon = max(r.completion_time for r in m.serviced)
    s = m.summary(horizon=horizon, total_energy=coord.total_energy, slo=SLO())
    return s


def main():
    print(f"{'strategy':15s} {'rate':>5s} {'ttft_p50':>9s} {'tpot_p50':>9s} "
          f"{'thpt':>8s} {'tok/J':>7s} {'SLO':>5s}")
    results = {}
    for strategy in ("static", "continuous", "chunked", "disaggregated"):
        for rate in (1.0, 3.0, 6.0):
            s = run_cell(strategy, rate)
            results[(strategy, rate)] = s
            print(f"{strategy:15s} {rate:5.1f} "
                  f"{s['ttft_p50']*1e3:8.0f}ms {s['tpot_p50']*1e3:8.1f}ms "
                  f"{s['throughput_tok_s']:8.0f} "
                  f"{s.get('tok_per_joule', 0):7.4f} "
                  f"{str(s.get('slo_ok')):>5s}")
    # Table-III style recommendation
    for rate in (1.0, 3.0, 6.0):
        cells = {k[0]: v for k, v in results.items() if k[1] == rate}
        print(f"rate={rate}: best TTFT={min(cells, key=lambda k: cells[k]['ttft_p50'])}, "
              f"best thpt={max(cells, key=lambda k: cells[k]['throughput_tok_s'])}, "
              f"best tok/J={max(cells, key=lambda k: cells[k].get('tok_per_joule', 0))}")


if __name__ == "__main__":
    main()
