"""Fault-tolerant training demo: train a small LM, kill it mid-run, restart
from the newest atomic checkpoint, and verify the loss curve continues
seamlessly (the deterministic data pipeline replays from the restored step).

    PYTHONPATH=src python examples/train_ft.py
"""
import shutil
import tempfile

from repro.launch import train


def main():
    ckpt_dir = tempfile.mkdtemp(prefix="repro_ckpt_")
    try:
        print("== phase 1: train 30 steps, checkpoint every 10 ==")
        args = ["--arch", "gemma_2b", "--reduced", "--steps", "30",
                "--batch", "4", "--seq", "64",
                "--ckpt-dir", ckpt_dir, "--ckpt-every", "10"]
        losses1 = train.main(args)

        print("== phase 2: 'crash' and restart; resumes from step 30 ==")
        args2 = ["--arch", "gemma_2b", "--reduced", "--steps", "50",
                 "--batch", "4", "--seq", "64",
                 "--ckpt-dir", ckpt_dir, "--ckpt-every", "10"]
        losses2 = train.main(args2)
        assert len(losses2) == 20, "restart should only run steps 30..50"
        print(f"resumed cleanly: phase1 end loss={losses1[-1]:.4f}, "
              f"phase2 end loss={losses2[-1]:.4f}")
        assert losses2[-1] < losses1[0], "loss should improve across restart"
    finally:
        shutil.rmtree(ckpt_dir, ignore_errors=True)


if __name__ == "__main__":
    main()
