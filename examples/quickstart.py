"""Quickstart: simulate a 4-client Llama-3-70B serving system under a
conversational workload and print the latency/throughput summary.

    PYTHONPATH=src python examples/quickstart.py
"""
import json

from repro.core import (SLO, SystemSpec, WorkloadConfig, build_system,
                        generate)
from repro.core.tracing import to_chrome_trace


def main():
    # 1. describe the serving system (paper Fig. 4d)
    spec = SystemSpec(
        model="llama3_70b",
        n_llm_clients=4,          # 4 clients x (2xH100, TP2)
        strategy="continuous",    # vLLM-style batching
        router_policy="load_based",
        router_metric="tokens_remaining",
    )
    coord = build_system(spec)

    # 2. describe the workload (Azure-conv-shaped, poisson arrivals)
    wl = WorkloadConfig(rate=2.0, n_requests=100, pipeline="regular", seed=0)
    coord.submit(generate(wl))

    # 3. run the discrete-event simulation
    metrics = coord.run()

    # 4. inspect
    print(json.dumps(metrics.summary(total_energy=coord.total_energy,
                                     slo=SLO()), indent=2, default=str))
    path = to_chrome_trace(metrics.serviced, "/tmp/hermes_trace.json")
    print(f"chrome trace written to {path} (open in chrome://tracing)")


if __name__ == "__main__":
    main()
