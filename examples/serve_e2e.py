"""End-to-end driver: REAL JAX serving of a small model with batched requests
(continuous batching + KV-cache slots), then the SAME schedule replayed in the
HERMES simulator — the fidelity loop of the paper, closed on a live engine.

    PYTHONPATH=src python examples/serve_e2e.py
"""
import time

import numpy as np

from repro.configs import get_reduced_config
from repro.core import SystemSpec, WorkloadConfig, build_system, generate
from repro.engine.runner import Engine


def main():
    arch = "gemma_2b"
    cfg = get_reduced_config(arch)
    print(f"[1] real execution: {cfg.name} "
          f"({sum(np.prod(s) for s in [(cfg.vocab_size, cfg.d_model)])/1e6:.1f}M+ params)")
    eng = Engine(cfg, max_batch=4, max_len=256)
    rng = np.random.default_rng(0)
    n_requests = 10
    t0 = time.monotonic()
    for _ in range(n_requests):
        eng.submit(rng.integers(0, cfg.vocab_size, int(rng.integers(8, 40))),
                   max_new_tokens=16)
    done = eng.run()
    wall = time.monotonic() - t0
    toks = sum(len(r.tokens) for r in done)
    print(f"    served {len(done)} requests, {toks} tokens in {wall:.2f}s "
          f"({toks/wall:.1f} tok/s, {eng.steps} engine steps)")
    ttfts = [r.ttft for r in done]
    print(f"    ttft mean={np.mean(ttfts)*1e3:.0f}ms  "
          f"tpot mean={np.mean([r.tpot for r in done if r.tpot])*1e3:.1f}ms")

    print("[2] simulator replay of an equivalent system")
    coord = build_system(SystemSpec(n_llm_clients=1, with_pre_post=False))
    wl = WorkloadConfig(rate=100.0, n_requests=n_requests, seed=0,
                        postprocess=False)
    coord.submit(generate(wl))
    m = coord.run()
    s = m.summary()
    print(f"    simulated {s['n_serviced']} requests "
          f"ttft_p50={s['ttft_p50']*1e3:.0f}ms tpot_p50={s['tpot_p50']*1e3:.1f}ms")
    print("    (absolute times differ: sim models 2xH100, real run is this CPU;"
          " the SCHEDULE structure matches)")


if __name__ == "__main__":
    main()
