"""RAG pipeline study (paper §IV-B): embedding-model placement and the
retrieval memory hierarchy, plus a live run of the PQ-scan math on CPU.

    PYTHONPATH=src python examples/rag_pipeline.py
"""
import numpy as np

from repro.core import SystemSpec, WorkloadConfig, build_system, generate


def main():
    print("== RAG placement (Fig. 9 compact) ==")
    for on_npu in (False, True):
        coord = build_system(SystemSpec(
            n_llm_clients=1, with_rag=True, rag_embed_on_npu=on_npu,
            with_pre_post=False))
        wl = WorkloadConfig(rate=0.5, n_requests=15, pipeline="rag",
                            postprocess=False, seed=2)
        coord.submit(generate(wl))
        m = coord.run()
        s = m.summary()
        where = "A100 NPU" if on_npu else "Grace CPU"
        print(f"  embed on {where:9s}: ttft_p50={s['ttft_p50']*1e3:7.0f}ms "
              f"e2e_p50={s['e2e_p50']:.2f}s")

    print("== live IVF-PQ ADC scan (the RAG retrieval hot loop) ==")
    import jax
    from repro.kernels import ops
    rng = np.random.default_rng(0)
    N, M, K = 200_000, 16, 256
    codes = rng.integers(0, K, (N, M)).astype(np.int32)
    lut = rng.random((M, K)).astype(np.float32)
    dist = np.asarray(ops.pq_scan(jax.numpy.asarray(codes),
                                  jax.numpy.asarray(lut)))
    top = np.argsort(dist)[:5]
    print(f"  scanned {N} codes x {M} subquantizers; top-5 ids={top.tolist()}")


if __name__ == "__main__":
    main()
