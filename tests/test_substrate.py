"""Substrate tests: checkpointing, data pipeline, optimizer, engine, tracing."""
import json
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.checkpoint import ckpt
from repro.configs import get_reduced_config
from repro.data.pipeline import DataConfig, batch_at
from repro.engine.runner import Engine
from repro.models import steps
from repro.models.optim import OptConfig, adamw_update, init_opt_state, lr_at

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------

def _tree():
    return {"a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
            "b": {"c": jnp.ones((2, 2), jnp.bfloat16),
                  "d": jnp.array(7, jnp.int32)}}


def test_checkpoint_roundtrip():
    t = _tree()
    with tempfile.TemporaryDirectory() as d:
        ckpt.save(d, 5, t, extra={"note": "hi"})
        got, man = ckpt.restore(d, t)
        assert man["step"] == 5 and man["extra"]["note"] == "hi"
        for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(got)):
            np.testing.assert_array_equal(np.asarray(a, np.float32),
                                          np.asarray(b, np.float32))


def test_checkpoint_latest_and_gc():
    t = _tree()
    with tempfile.TemporaryDirectory() as d:
        for s in (1, 2, 3, 4, 5):
            ckpt.save(d, s, t, keep=2)
        assert ckpt.latest_step(d) == 5
        kept = sorted(x for x in os.listdir(d) if x.startswith("step_"))
        assert len(kept) == 2
        _, man = ckpt.restore(d, t)
        assert man["step"] == 5


def test_checkpoint_atomicity_tmp_dirs_ignored():
    t = _tree()
    with tempfile.TemporaryDirectory() as d:
        ckpt.save(d, 1, t)
        os.makedirs(os.path.join(d, ".tmp_partial"), exist_ok=True)  # fake crash
        assert ckpt.latest_step(d) == 1


def test_train_state_checkpoint_roundtrip():
    cfg = get_reduced_config("gemma_2b").replace(param_dtype="float32",
                                                 compute_dtype="float32")
    state = steps.init_train_state(cfg, KEY)
    with tempfile.TemporaryDirectory() as d:
        ckpt.save(d, 3, state)
        got, _ = ckpt.restore(d, state)
        for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(got)):
            np.testing.assert_array_equal(np.asarray(a, np.float32),
                                          np.asarray(b, np.float32))


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_data_deterministic_and_restartable():
    dc = DataConfig(vocab_size=128, seq_len=32, global_batch=8)
    b1 = batch_at(dc, 7)
    b2 = batch_at(dc, 7)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = batch_at(dc, 8)
    assert not np.array_equal(b1["tokens"], b3["tokens"])


def test_data_host_slices_disjoint():
    base = DataConfig(vocab_size=128, seq_len=16, global_batch=8, n_hosts=2)
    a = batch_at(DataConfig(**{**base.__dict__, "host_id": 0}), 0)
    b = batch_at(DataConfig(**{**base.__dict__, "host_id": 1}), 0)
    full = batch_at(DataConfig(**{**base.__dict__, "n_hosts": 1}), 0)
    np.testing.assert_array_equal(np.concatenate([a["tokens"], b["tokens"]]),
                                  full["tokens"])


def test_labels_are_shifted_tokens():
    dc = DataConfig(vocab_size=64, seq_len=16, global_batch=4)
    b = batch_at(dc, 0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------

def test_adamw_decreases_quadratic():
    w = {"w": jnp.array([5.0, -3.0])}
    opt = OptConfig(lr=0.1, warmup_steps=1, total_steps=200, weight_decay=0.0)
    st_ = init_opt_state(w)
    for _ in range(100):
        g = {"w": 2 * w["w"]}
        w, st_, _ = adamw_update(w, g, st_, opt)
    assert float(jnp.sum(jnp.abs(w["w"]))) < 0.5


@given(st.integers(0, 5000))
@settings(max_examples=30, deadline=None)
def test_lr_schedule_bounded(step):
    opt = OptConfig(lr=1e-3, warmup_steps=100, total_steps=5000)
    lr = float(lr_at(opt, jnp.array(step)))
    assert 0.0 <= lr <= opt.lr + 1e-12


def test_grad_clipping_bounds_update():
    opt = OptConfig(lr=0.1, grad_clip=1.0, warmup_steps=1, weight_decay=0.0)
    w = {"w": jnp.zeros(4)}
    st_ = init_opt_state(w)
    g = {"w": jnp.full(4, 1e6)}
    w2, _, gnorm = adamw_update(w, g, st_, opt)
    assert float(gnorm) > 1e5
    assert float(jnp.max(jnp.abs(w2["w"]))) <= 0.2  # lr * O(1)


# ---------------------------------------------------------------------------
# real-execution engine
# ---------------------------------------------------------------------------

def test_engine_serves_batched_requests():
    cfg = get_reduced_config("gemma_2b")
    eng = Engine(cfg, max_batch=2, max_len=96)
    rng = np.random.default_rng(0)
    for _ in range(5):
        eng.submit(rng.integers(0, cfg.vocab_size, 12), max_new_tokens=6)
    done = eng.run()
    assert len(done) == 5
    for r in done:
        assert len(r.tokens) == 6
        assert r.ttft is not None and r.ttft > 0


def test_engine_matches_sequential_decode():
    """Batched slot decoding must equal decoding each request alone."""
    cfg = get_reduced_config("gemma_2b")
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, cfg.vocab_size, 10),
               rng.integers(0, cfg.vocab_size, 17)]
    eng = Engine(cfg, max_batch=2, max_len=64, seed=3)
    for p in prompts:
        eng.submit(p, max_new_tokens=5)
    batched = {tuple(r.prompt.tolist()): r.tokens for r in eng.run()}
    for p in prompts:
        solo = Engine(cfg, max_batch=1, max_len=64, seed=3)
        solo.submit(p, max_new_tokens=5)
        (r,) = solo.run()
        assert batched[tuple(p.tolist())] == r.tokens


def test_engine_preemption_requeues():
    cfg = get_reduced_config("gemma_2b")
    eng = Engine(cfg, max_batch=1, max_len=64)
    eng.submit(np.arange(8) % cfg.vocab_size, max_new_tokens=6)
    eng._admit()
    eng._step_decode()
    eng.preempt_slot(0)
    done = eng.run()
    assert len(done) == 1 and len(done[0].tokens) == 6


# ---------------------------------------------------------------------------
# tracing
# ---------------------------------------------------------------------------

def test_chrome_trace_valid_json():
    from repro.core import SystemSpec, WorkloadConfig, build_system, generate
    from repro.core.tracing import to_chrome_trace
    coord = build_system(SystemSpec(n_llm_clients=1))
    coord.submit(generate(WorkloadConfig(n_requests=5, rate=5.0)))
    m = coord.run()
    with tempfile.TemporaryDirectory() as d:
        p = to_chrome_trace(m.serviced, os.path.join(d, "t.json"))
        with open(p) as f:
            data = json.load(f)
        assert len(data["traceEvents"]) >= 5 * 3
