"""Paged real-execution engine: kernel parity (paged vs dense decode
attention in interpret mode), PagedKVStore allocator semantics, and
paged-Engine-vs-seed-SlotEngine token-stream equality under greedy decoding
— including preemption mid-stream (swap and recompute both keep every
generated token and must not change the stream)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs import get_reduced_config
from repro.engine.paged_kv import PagedKVStore, prefix_chain
from repro.engine.runner import Engine, SlotEngine, make_engine
from repro.kernels import ref
from repro.kernels.paged_attention import paged_decode_attention

KEY = jax.random.PRNGKey(7)


def _pool_case(rnd_key, b, kvh, g, d, dv, bt, mb):
    """Random pool + a permutation block table (every row's pages scattered
    arbitrarily through the pool) + ragged lengths >= 1."""
    n_pages = b * mb + 3
    q = jax.random.normal(jax.random.fold_in(rnd_key, 0), (b, 1, kvh * g, d))
    kp = jax.random.normal(jax.random.fold_in(rnd_key, 1), (n_pages, bt, kvh, d))
    vp = jax.random.normal(jax.random.fold_in(rnd_key, 2), (n_pages, bt, kvh, dv))
    tab = jax.random.permutation(jax.random.fold_in(rnd_key, 3),
                                 n_pages)[:b * mb].reshape(b, mb)
    lens = jax.random.randint(jax.random.fold_in(rnd_key, 4), (b,), 1,
                              mb * bt + 1)
    return q, kp, vp, tab.astype(jnp.int32), lens.astype(jnp.int32)


# ---------------------------------------------------------------------------
# kernel parity: paged (interpret) vs dense oracle
# ---------------------------------------------------------------------------

@settings(max_examples=12, deadline=None)
@given(b=st.integers(1, 3), kvh=st.sampled_from([1, 2]),
       g=st.sampled_from([1, 2, 4]), d=st.sampled_from([16, 32, 64]),
       bt=st.sampled_from([8, 16, 32]), mb=st.integers(1, 6),
       seed=st.integers(0, 2 ** 16))
def test_paged_kernel_matches_dense_ref(b, kvh, g, d, bt, mb, seed):
    """Hypothesis sweep over (batch, lengths, block_tokens, table layout):
    the Pallas paged kernel (interpret mode) must match the dense jnp oracle
    evaluated on the gathered logical cache to fp32 tolerance."""
    q, kp, vp, tab, lens = _pool_case(jax.random.fold_in(KEY, seed),
                                      b, kvh, g, d, d, bt, mb)
    out = paged_decode_attention(q, kp, vp, tab, lens, interpret=True)
    dense_k = ref.gather_paged_kv(kp, tab)
    dense_v = ref.gather_paged_kv(vp, tab)
    want = ref.decode_attention(q, dense_k, dense_v, lens)
    np.testing.assert_allclose(out, want, atol=2e-5, rtol=2e-5)


def test_paged_kernel_asymmetric_dv():
    q, kp, vp, tab, lens = _pool_case(jax.random.fold_in(KEY, 99),
                                      2, 2, 2, 32, 16, 8, 4)
    out = paged_decode_attention(q, kp, vp, tab, lens, interpret=True)
    want = ref.paged_decode_attention(q, kp, vp, tab, lens)
    np.testing.assert_allclose(out, want, atol=2e-5, rtol=2e-5)


def test_paged_ref_ignores_dead_table_entries():
    """Garbage in pages referenced only by masked (beyond-length) table
    entries must not leak into the output — the trash-page contract."""
    q, kp, vp, tab, lens = _pool_case(jax.random.fold_in(KEY, 5),
                                      2, 1, 4, 32, 32, 8, 4)
    lens = jnp.array([9, 17], jnp.int32)          # partial coverage
    out1 = ref.paged_decode_attention(q, kp, vp, tab, lens)
    # scribble every page, then restore only the live slots' content
    live_k = ref.gather_paged_kv(kp, tab)
    live_v = ref.gather_paged_kv(vp, tab)
    kp2 = kp.at[...].set(1e4)
    vp2 = vp.at[...].set(-1e4)
    bt = kp.shape[1]
    for i in range(2):
        for p in range(int(lens[i])):
            blk, off = int(tab[i, p // bt]), p % bt
            kp2 = kp2.at[blk, off].set(live_k[i, p])
            vp2 = vp2.at[blk, off].set(live_v[i, p])
    out2 = ref.paged_decode_attention(q, kp2, vp2, tab, lens)
    np.testing.assert_allclose(out1, out2, atol=1e-6)


# ---------------------------------------------------------------------------
# PagedKVStore allocator semantics
# ---------------------------------------------------------------------------

def test_store_prefix_dedup_and_cached_reclaim():
    st_ = PagedKVStore(num_blocks=8, block_tokens=4)
    prompt = list(range(12))                       # 3 full blocks
    chain = prefix_chain(prompt, 4)
    b0, m0 = st_.allocate(0, 12, chain)
    assert m0 == 0 and len(b0) == 3
    b1, m1 = st_.allocate(1, 14, chain)            # same prefix + tail
    assert m1 == 3 and b1[:3] == b0[:3]            # physical aliasing
    assert st_.refcount[b0[0]] == 2
    st_.free(0)
    st_.free(1)
    # registered blocks stay resident as cache and are reclaimed on demand
    assert st_.cached_blocks == 3 and st_.used_blocks == 0
    b2, m2 = st_.allocate(2, 12, chain)
    assert m2 == 3                                 # hit the cached chain
    st_.free(2)
    got = st_.allocate(3, 8 * 4)                   # whole pool: evicts cache
    assert got is not None and st_.radix_evictions == 3
    st_.check_invariants()


def test_store_swap_roundtrip_and_shared_degrade():
    st_ = PagedKVStore(num_blocks=6, block_tokens=4)
    chain = prefix_chain(list(range(8)), 4)
    st_.allocate(0, 8, chain)
    st_.allocate(1, 8, chain)                      # shares both blocks
    assert st_.swap_out(0) is None                 # shared pages: degrade
    st_.free(1)
    blocks = st_.swap_out(0)                       # now refcount-1
    assert blocks is not None and not st_.tables[0].on_device
    assert st_.used_blocks == 0                    # device side released
    back = st_.swap_in(0)
    assert back is not None and st_.tables[0].on_device
    assert st_.tables[0].tokens == 8
    st_.check_invariants()


@settings(max_examples=15, deadline=None)
@given(ops=st.lists(st.tuples(st.integers(0, 4), st.integers(1, 30)),
                    min_size=1, max_size=40),
       nb=st.integers(4, 12), bt=st.sampled_from([2, 4, 8]))
def test_store_invariants_random_walk(ops, nb, bt):
    st_ = PagedKVStore(num_blocks=nb, block_tokens=bt)
    live = []
    rid = 0
    for op, arg in ops:
        if op == 0:                                # allocate
            toks = arg
            chain = prefix_chain(list(range(min(toks, 3 * bt))), bt)
            if st_.allocate(rid, toks, chain) is not None:
                live.append(rid)
            rid += 1
        elif op == 1 and live:                     # grow/advance one token
            r = live[arg % len(live)]
            if st_.tables[r].on_device:
                if st_.needs_block(r):
                    if st_.grow(r) is None:
                        continue
                st_.advance(r)
        elif op == 2 and live:                     # free
            r = live.pop(arg % len(live))
            st_.free(r)
        elif op == 3 and live:                     # swap out (maybe degrade)
            r = live[arg % len(live)]
            if st_.tables[r].on_device:
                if st_.swap_out(r) is None:
                    live.remove(r)
                    st_.drop(r)
        elif op == 4 and live:                     # swap in
            r = live[arg % len(live)]
            if not st_.tables[r].on_device:
                st_.swap_in(r)
        st_.check_invariants()
    for r in live:
        st_.free(r)
    st_.check_invariants()
    assert st_.used_blocks == 0


# ---------------------------------------------------------------------------
# engine parity vs the seed slot engine
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def cfg():
    return get_reduced_config("gemma_2b")


@pytest.fixture(scope="module")
def prompts(cfg):
    rng = np.random.default_rng(3)
    # two distinct lengths only: every fresh prompt length retraces the
    # prefill jit, and parity doesn't need a length sweep here (the kernel
    # sweep above covers raggedness)
    return [rng.integers(0, cfg.vocab_size, n) for n in (12, 17, 12, 17, 12)]


def test_paged_engine_matches_slot_engine(cfg, prompts):
    slot = SlotEngine(cfg, max_batch=2, max_len=64, seed=3)
    paged = Engine(cfg, max_batch=2, max_len=64, seed=3, block_tokens=16)
    for p in prompts:
        slot.submit(p, max_new_tokens=5)
        paged.submit(p, max_new_tokens=5)
    want = {tuple(r.prompt.tolist()): r.tokens for r in slot.run()}
    got = {tuple(r.prompt.tolist()): r.tokens for r in paged.run()}
    assert got == want
    paged.store.check_invariants()
    assert paged.store.used_blocks == 0            # everything released


@pytest.mark.parametrize("policy", ["swap", "recompute"])
def test_pressured_engine_stream_parity(cfg, prompts, policy):
    """A pool too small for both requests forces real mid-stream preemption
    (device->host page movement for swap; drop + re-prefill for recompute);
    the token streams must still equal the unpressured engine's."""
    ample = Engine(cfg, max_batch=2, max_len=64, seed=5, block_tokens=8)
    tight = Engine(cfg, max_batch=2, max_len=64, seed=5, block_tokens=8,
                   num_blocks=5, preemption=policy)
    for p in prompts[:2]:
        ample.submit(p, max_new_tokens=12)
        tight.submit(p, max_new_tokens=12)
    want = {tuple(r.prompt.tolist()): r.tokens for r in ample.run()}
    got = {tuple(r.prompt.tolist()): r.tokens for r in tight.run()}
    assert got == want
    st_ = tight.kv_stats()
    assert st_["page_faults"] >= 1                 # pressure actually fired
    if policy == "swap":
        assert st_["swap_outs"] >= 1 and st_["swap_ins"] >= 1
    else:
        assert st_["recompute_drops"] >= 1
    assert any(r.preemptions for r in tight.finished)
    tight.store.check_invariants()


def test_manual_preempt_keeps_tokens_and_requeues_fifo(cfg):
    rng = np.random.default_rng(9)
    eng = Engine(cfg, max_batch=1, max_len=64, seed=0, block_tokens=16)
    first = eng.submit(rng.integers(0, cfg.vocab_size, 8), max_new_tokens=6)
    eng._admit()
    eng._step_decode()
    eng._step_decode()
    generated = list(first.tokens)
    assert len(generated) == 3
    later = eng.submit(rng.integers(0, cfg.vocab_size, 8), max_new_tokens=4)
    eng.preempt_slot(0)
    # FIFO-fair: the preempted request resumes BEFORE the later submission
    # (seed engine would also put it first here, but by unconditional
    # insert(0) — the distinction is covered below)
    assert [r.rid for r in eng.waiting] == [first.rid, later.rid]
    done = eng.run()
    assert len(done) == 2
    assert done[0] is first
    assert first.tokens[:len(generated)] == generated   # nothing discarded
    assert len(first.tokens) == 6


def test_preempt_requeue_is_fifo_fair_not_queue_head(cfg):
    """A preempted LATER request must not jump ahead of earlier waiters."""
    rng = np.random.default_rng(11)
    eng = Engine(cfg, max_batch=2, max_len=64, seed=0, block_tokens=16)
    a = eng.submit(rng.integers(0, cfg.vocab_size, 8), max_new_tokens=4)
    b = eng.submit(rng.integers(0, cfg.vocab_size, 8), max_new_tokens=4)
    c = eng.submit(rng.integers(0, cfg.vocab_size, 8), max_new_tokens=4)
    eng._admit()                                   # a, b running; c waiting
    eng._step_decode()
    eng.preempt_slot(b.slot)
    assert [r.rid for r in eng.waiting] == [b.rid, c.rid]
    eng.preempt_slot(a.slot)
    assert [r.rid for r in eng.waiting] == [a.rid, b.rid, c.rid]
    done = eng.run()
    assert len(done) == 3 and all(len(r.tokens) == 4 for r in done)


def test_submit_rids_unique_after_completion(cfg):
    """Seed bug: rids were recomputed from queue sizes, so they collided
    after requests finished. They must be unique for the life of the
    engine (the store keys tables by rid)."""
    rng = np.random.default_rng(13)
    eng = Engine(cfg, max_batch=2, max_len=64, seed=0, block_tokens=16)
    r1 = eng.submit(rng.integers(0, cfg.vocab_size, 8), max_new_tokens=3)
    eng.run()
    r2 = eng.submit(rng.integers(0, cfg.vocab_size, 8), max_new_tokens=3)
    r3 = eng.submit(rng.integers(0, cfg.vocab_size, 8), max_new_tokens=3)
    eng.run()
    rids = [r1.rid, r2.rid, r3.rid]
    assert len(set(rids)) == 3
    slot = SlotEngine(cfg, max_batch=1, max_len=64)
    s1 = slot.submit(rng.integers(0, cfg.vocab_size, 8), max_new_tokens=3)
    slot.run()
    s2 = slot.submit(rng.integers(0, cfg.vocab_size, 8), max_new_tokens=3)
    assert s1.rid != s2.rid


def test_engine_prefix_sharing_dedups_physical_blocks(cfg):
    rng = np.random.default_rng(17)
    sysp = rng.integers(0, cfg.vocab_size, 32)     # 2 full blocks of 16
    eng = Engine(cfg, max_batch=4, max_len=64, seed=2, block_tokens=16)
    for _ in range(4):
        eng.submit(np.concatenate([sysp, rng.integers(0, cfg.vocab_size, 5)]),
                   max_new_tokens=3)
    eng.run()
    st_ = eng.kv_stats()
    assert st_["prefix_hit_blocks"] >= 6           # 3 sharers x 2 blocks
    assert st_["dedup_ratio"] > 1.0
    eng.store.check_invariants()


def test_make_engine_falls_back_for_unpaged_families(cfg):
    """MLA (latent cache) and recurrent families are not paged yet; the
    factory must hand them the dense SlotEngine instead of crashing."""
    assert isinstance(make_engine(cfg, max_batch=1, max_len=64,
                                  block_tokens=16), Engine)
    mla = get_reduced_config("deepseek_v2_lite_16b")
    eng = make_engine(mla, max_batch=1, max_len=64, block_tokens=16)
    assert isinstance(eng, SlotEngine)
    ssm = get_reduced_config("xlstm_1_3b")
    assert isinstance(make_engine(ssm, max_batch=1, max_len=64), SlotEngine)


def test_init_paged_cache_lengths_zero_when_batch_equals_max_blocks(cfg):
    """Regression: the block-table leaf was picked by *shape*, so a (batch,)
    length array with batch == max_blocks got initialized to the trash id."""
    from repro.models import transformer as tf
    caches = tf.init_paged_cache(cfg, batch=4, num_blocks=16,
                                 block_tokens=16, max_blocks=4)
    g = caches["attn"]
    assert np.all(np.asarray(g["length"]) == 0)
    assert np.all(np.asarray(g["block_tables"]) == 16)


def test_engine_geometry_guards(cfg):
    with pytest.raises(AssertionError):
        Engine(cfg, max_batch=1, max_len=60, block_tokens=16)  # not divisible
    eng = Engine(cfg, max_batch=1, max_len=64, block_tokens=16, num_blocks=2)
    with pytest.raises(ValueError):
        eng.submit(np.arange(30, dtype=np.int32), max_new_tokens=30)
