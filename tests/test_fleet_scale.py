"""Fleet-scale routing indexes (src/repro/core/fleet.py): decision-identity
against the linear-scan baseline, index maintenance under churn, round-robin
determinism, bounded step history, and the metrics fast paths."""
import math

import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (SLO, SystemSpec, WorkloadConfig, build_system,
                        generate)
from repro.core.client import LLMClient
from repro.core.llm_scheduler import SchedulerLimits
from repro.core.metrics import MetricsCollector, simulator_stats
from repro.core.request import LLM, Request, regular_pipeline
from repro.core.router import LOAD_METRICS, Router
from repro.core.workload import synthetic_trace


# ---------------------------------------------------------------------------
# decision identity: indexed vs linear-scan candidate + routing path
# ---------------------------------------------------------------------------

class RecordingRouter(Router):
    """Wraps any router and logs every (stage, chosen-client) decision."""

    def __init__(self, inner, log):
        self.inner = inner
        self.log = log

    @property
    def metric(self):
        # _sync dispatches on the router's metric attribute
        return getattr(self.inner, "metric", None)

    def bind(self, coordinator):
        self.coordinator = coordinator
        self.inner.bind(coordinator)

    def route(self, req, candidates, now):
        c = self.inner.route(req, candidates, now)
        self.log.append((req.current_stage.kind, c.name))
        return c


def _clone(base: LLMClient, name: str) -> LLMClient:
    return LLMClient(name, base.cluster, base.model_cfg, base.strategy,
                     base.scheduler.limits, perf=base.scheduler.perf,
                     group=base.group)


def _apply_churn(coord, churn, allow_add: bool):
    names = list(coord.clients)
    n_added = 0
    for kind, tgt, tfrac in churn:
        t = 0.2 + 2.0 * tfrac
        target = names[tgt % len(names)]
        if kind == "add":
            if not allow_add:
                continue
            spare = _clone(coord.clients[names[0]], f"extra{n_added}")
            n_added += 1
            coord.schedule_add_client(spare, t)
        elif kind == "fail":
            coord.schedule_failure(target, t)
        elif kind == "fail_recover":
            coord.schedule_failure(target, t, recover_at=t + 0.4)
        elif kind == "remove":
            coord.schedule_remove_client(target, t)


def _run_arm(indexed, policy, metric, churn, *, disagg=False, straggler=False,
             migration=False, n_requests=30, seed=3):
    spec = SystemSpec(
        n_llm_clients=4,
        strategy="disaggregated" if disagg else "continuous",
        disaggregation="local" if disagg else "global",
        router_policy=policy, router_metric=metric,
        limits=SchedulerLimits(max_batch=8),
        with_pre_post=False,
        straggler_deadline=0.05 if straggler else None,
        prefix_migration=migration,
        fetch_load_factor=1.5 if migration else None,
        fleet_index=indexed)
    coord = build_system(spec)
    log = []
    coord.router = RecordingRouter(coord.router, log)
    coord.router.bind(coord)
    trace = synthetic_trace(input_mean=192, input_std=0.4, output_mean=24,
                            output_std=0.2, name="t")
    coord.submit(generate(WorkloadConfig(
        trace=trace, rate=40.0, n_requests=n_requests, process="poisson",
        postprocess=False, seed=seed, disaggregated=disagg,
        shared_prefix_pool=4, shared_prefix_tokens=128)))
    _apply_churn(coord, churn, allow_add=not disagg)
    err = None
    try:
        coord.run()
    except RuntimeError as e:       # churn can legally empty a stage pool;
        err = str(e)                # both arms must then fail identically
    return log, err, coord.metrics.summary()


def _summaries_equal(a, b):
    if set(a) != set(b):
        return False
    for k in a:
        x, y = a[k], b[k]
        if x != y and not (isinstance(x, float) and isinstance(y, float)
                           and math.isnan(x) and math.isnan(y)):
            return False
    return True


def _assert_identical(policy, metric, churn, **kw):
    log_i, err_i, s_i = _run_arm(True, policy, metric, churn, **kw)
    log_s, err_s, s_s = _run_arm(False, policy, metric, churn, **kw)
    assert log_i == log_s, (
        f"{policy}/{metric}: indexed and scan arms diverge at decision "
        f"{next(i for i, (a, b) in enumerate(zip(log_i, log_s)) if a != b) if log_i != log_s else '?'}")
    assert err_i == err_s
    if err_i is None:
        assert _summaries_equal(s_i, s_s)


# every router x load metric, under a fixed churn schedule hitting all four
# event kinds (fail without recover excluded here so no arm ever empties a
# stage pool; the hypothesis sweep below covers that path)
FIXED_CHURN = [("add", 0, 0.1), ("fail_recover", 1, 0.2),
               ("remove", 2, 0.6), ("fail_recover", 0, 0.8)]
CASES = ([("round_robin", "queue")]
         + [("load_based", m) for m in LOAD_METRICS]
         + [("heavy_light", m) for m in ("queue", "kv_size",
                                         "tokens_remaining")]
         + [("prefix_affinity", m) for m in ("queue", "kv_pressure",
                                             "tokens_remaining")])


@pytest.mark.parametrize("policy,metric", CASES)
def test_indexed_routing_identical_under_churn(policy, metric):
    _assert_identical(policy, metric, FIXED_CHURN)


def test_indexed_routing_identical_disaggregated_local():
    # mixed prefill/decode stages + the local-disaggregation group filter
    churn = [("fail_recover", 1, 0.3), ("fail_recover", 2, 0.7)]
    _assert_identical("load_based", "queue", churn, disagg=True)
    _assert_identical("round_robin", "queue", churn, disagg=True)


def test_indexed_routing_identical_with_straggler_and_migration():
    _assert_identical("prefix_affinity", "queue", FIXED_CHURN,
                      straggler=True, migration=True)


_churn_events = st.lists(
    st.tuples(st.sampled_from(("add", "fail", "fail_recover", "remove")),
              st.integers(min_value=0, max_value=3),
              st.floats(min_value=0.0, max_value=1.0)),
    min_size=0, max_size=4)


@settings(max_examples=15, deadline=None)
@given(policy=st.sampled_from(("round_robin", "load_based", "heavy_light",
                               "prefix_affinity")),
       metric=st.sampled_from(LOAD_METRICS),
       churn=_churn_events,
       seed=st.integers(min_value=0, max_value=10))
def test_indexed_routing_identical_random_churn(policy, metric, churn, seed):
    _assert_identical(policy, metric, churn, seed=seed, n_requests=20)


# ---------------------------------------------------------------------------
# index maintenance corner cases
# ---------------------------------------------------------------------------

def test_readd_same_name_preserves_candidate_order():
    # CLIENT_ADD over an existing name keeps its dict slot: the index must
    # rebuild so per-stage iteration order stays baseline-identical
    coord = build_system(SystemSpec(n_llm_clients=3, with_pre_post=False))
    clone = _clone(coord.clients["llm1"], "llm1")
    coord.schedule_add_client(clone, 0.0)
    coord.run()
    assert coord.clients["llm1"] is clone
    view = coord.fleet.candidates(LLM)
    assert [c.name for c in view] == ["llm0", "llm1", "llm2"]


def test_inverted_index_tracks_radix_roots():
    spec = SystemSpec(n_llm_clients=3, with_pre_post=False,
                      router_policy="prefix_affinity", router_metric="queue")
    coord = build_system(spec)
    coord.submit(generate(WorkloadConfig(
        rate=30.0, n_requests=40, postprocess=False, seed=5,
        shared_prefix_pool=3, shared_prefix_tokens=256)))
    coord.run()
    inv = coord.fleet.inv
    assert inv, "prefix workload should register chain roots"
    for c in coord.clients.values():
        radix = getattr(getattr(c.scheduler, "kv", None), "radix", None)
        if radix is None:
            continue
        roots = {n.hash for n in radix.nodes.values() if n.is_root}
        listed = {h for h, s in inv.items() if c.name in s}
        assert roots == listed
    # removing a client sweeps its entries out of the inverted index
    name = next(iter(coord.clients))
    coord.schedule_remove_client(name, coord.queue.now + 1.0)
    coord.run()
    assert all(name not in s for s in coord.fleet.inv.values())


# ---------------------------------------------------------------------------
# round-robin determinism under candidate-order churn (PR 4 heavy-light fix)
# ---------------------------------------------------------------------------

class _Stub:
    kind = "llm"

    def __init__(self, name):
        self.name = name
        self.failed = False


def test_round_robin_invariant_to_candidate_order():
    from repro.core.router import RoundRobinRouter
    req = Request(arrival=0.0, input_tokens=8, output_tokens=8,
                  stages=regular_pipeline(False, False))
    a, b, c = _Stub("a"), _Stub("b"), _Stub("c")
    r1, r2 = RoundRobinRouter(), RoundRobinRouter()
    # same rotation regardless of the order the candidate list arrives in —
    # a CLIENT_ADD/REMOVE reshuffling dict order must not reshuffle the
    # assignment sequence
    seq1 = [r1.route(req, [a, b, c], 0.0).name for _ in range(6)]
    seq2 = [r2.route(req, [c, a, b], 0.0).name for _ in range(6)]
    assert seq1 == seq2 == ["a", "b", "c", "a", "b", "c"]


# ---------------------------------------------------------------------------
# bounded step history + step_events counter
# ---------------------------------------------------------------------------

def _small_run(history_limit):
    spec = SystemSpec(n_llm_clients=2, with_pre_post=False,
                      limits=SchedulerLimits(max_batch=8,
                                             history_limit=history_limit))
    coord = build_system(spec)
    coord.submit(generate(WorkloadConfig(rate=20.0, n_requests=20,
                                         postprocess=False, seed=7)))
    coord.run()
    return coord


def test_history_ring_buffer_and_counter():
    full = _small_run(None)
    ring = _small_run(4)
    off = _small_run(0)
    stats = {k: simulator_stats(c) for k, c in
             (("full", full), ("ring", ring), ("off", off))}
    # retention must not change what was simulated, only what is retained
    assert stats["full"] == stats["ring"] == stats["off"]
    for c in ring.clients.values():
        assert len(c.scheduler.history) <= 4
        assert c.scheduler.step_events >= len(c.scheduler.history)
    for c in off.clients.values():
        assert len(c.scheduler.history) == 0
    total = sum(c.scheduler.step_events for c in full.clients.values())
    assert stats["full"]["step_events"] == total > 0
    # unbounded mode: counter agrees with the retained list
    for c in full.clients.values():
        assert c.scheduler.step_events == len(c.scheduler.history)


# ---------------------------------------------------------------------------
# metrics fast paths
# ---------------------------------------------------------------------------

def _fake_req(ttft, tpot_span, n_tokens, tier="default"):
    r = Request(arrival=0.0, input_tokens=8, output_tokens=n_tokens,
                stages=regular_pipeline(False, False), tier=tier)
    r.first_token_time = ttft
    r.decoded_tokens = n_tokens
    r.last_token_time = ttft + tpot_span
    r.completion_time = r.last_token_time
    return r


def test_latency_cache_invalidates_on_complete():
    m = MetricsCollector()
    m.complete(_fake_req(0.1, 0.5, 10))
    assert m.ttfts == [pytest.approx(0.1)]
    first = m._latency_arrays()
    assert m._latency_arrays() is first          # cached between appends
    m.complete(_fake_req(0.3, 0.5, 10))
    assert len(m.ttfts) == 2                     # append invalidates
    assert len(m.tpots) == 2 and len(m.e2es) == 2


def test_goodput_by_tier():
    m = MetricsCollector()
    slo = SLO()
    fast = slo.ttft_base  # well under the P50 multiplier
    m.complete(_fake_req(fast, 0.1, 100, tier="interactive"))
    m.complete(_fake_req(50.0, 0.1, 100, tier="interactive"))  # misses TTFT
    m.complete(_fake_req(fast, 0.1, 200, tier="batch"))
    by = m.goodput_by_tier(slo, horizon=10.0)
    assert by == {"interactive": pytest.approx(10.0),
                  "batch": pytest.approx(20.0)}
    # per-tier SLOs: an impossible batch SLO zeroes only that tier
    strict = SLO(ttft_base=0.0, tpot_base=0.0,
                 ttft_mult={50: 0.0, 90: 0.0, 99: 0.0},
                 tpot_mult={50: 0.0, 90: 0.0, 99: 0.0})
    by = m.goodput_by_tier({"interactive": slo, "batch": strict}, 10.0)
    assert by["interactive"] == pytest.approx(10.0)
    assert by["batch"] == 0.0
    # total goodput equals the single-SLO sum over tiers
    assert (m.goodput(slo, 10.0)
            == pytest.approx(sum(m.goodput_by_tier(slo, 10.0).values())))
