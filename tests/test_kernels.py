"""Per-kernel validation: Pallas (interpret mode) vs pure-jnp oracle across
shape/dtype sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_attention import flash_attention
from repro.kernels.pq_scan import pq_scan

KEY = jax.random.PRNGKey(0)


def _qkv(b, s, nh, kvh, d, dv=None, dtype=jnp.float32, t=None):
    t = t or s
    dv = dv or d
    q = jax.random.normal(jax.random.fold_in(KEY, 1), (b, s, nh, d), dtype)
    k = jax.random.normal(jax.random.fold_in(KEY, 2), (b, t, kvh, d), dtype)
    v = jax.random.normal(jax.random.fold_in(KEY, 3), (b, t, kvh, dv), dtype)
    return q, k, v


@pytest.mark.parametrize("b,s,nh,kvh,d", [
    (1, 128, 4, 4, 64),      # MHA
    (2, 256, 8, 2, 64),      # GQA
    (2, 192, 8, 1, 32),      # MQA, non-pow2 seq
    (1, 512, 16, 4, 128),    # larger head_dim
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_matches_ref(b, s, nh, kvh, d, causal):
    q, k, v = _qkv(b, s, nh, kvh, d)
    out = flash_attention(q, k, v, causal=causal, interpret=True,
                          block_q=64, block_k=64)
    want = ref.flash_attention(q, k, v, causal=causal)
    np.testing.assert_allclose(out, want, atol=2e-5, rtol=2e-5)


def test_flash_attention_bf16():
    q, k, v = _qkv(2, 128, 8, 2, 64, dtype=jnp.bfloat16)
    out = flash_attention(q, k, v, causal=True, interpret=True,
                          block_q=64, block_k=64)
    want = ref.flash_attention(q, k, v, causal=True)
    np.testing.assert_allclose(out.astype(np.float32),
                               want.astype(np.float32), atol=3e-2, rtol=3e-2)


@pytest.mark.parametrize("b,S,nh,kvh,d,block", [
    (2, 300, 8, 2, 64, 128),
    (1, 1024, 4, 1, 32, 256),
    (3, 257, 16, 16, 64, 64),
])
def test_decode_attention_matches_ref(b, S, nh, kvh, d, block):
    q, k, v = _qkv(b, 1, nh, kvh, d, t=S)
    lengths = jax.random.randint(jax.random.fold_in(KEY, 9), (b,), 1, S)
    out = decode_attention(q, k, v, lengths, interpret=True, block_s=block)
    want = ref.decode_attention(q, k, v, lengths)
    np.testing.assert_allclose(out, want, atol=2e-5, rtol=2e-5)


def test_decode_attention_masks_beyond_length():
    """Garbage in the cache past `length` must not affect the output."""
    b, S, nh, kvh, d = 1, 128, 4, 4, 32
    q, k, v = _qkv(b, 1, nh, kvh, d, t=S)
    lengths = jnp.array([40], jnp.int32)
    k2 = k.at[:, 40:].set(1e4)
    v2 = v.at[:, 40:].set(-1e4)
    o1 = decode_attention(q, k, v, lengths, interpret=True, block_s=64)
    o2 = decode_attention(q, k2, v2, lengths, interpret=True, block_s=64)
    np.testing.assert_allclose(o1, o2, atol=1e-6)


@pytest.mark.parametrize("N,M,K,block", [
    (1000, 16, 256, 256),
    (4096, 8, 256, 1024),
    (513, 32, 64, 128),
])
def test_pq_scan_matches_ref(N, M, K, block):
    codes = jax.random.randint(jax.random.fold_in(KEY, 4), (N, M), 0, K)
    lut = jax.random.normal(jax.random.fold_in(KEY, 5), (M, K), jnp.float32)
    out = pq_scan(codes, lut, interpret=True, block_n=block)
    want = ref.pq_scan(codes, lut)
    np.testing.assert_allclose(out, want, atol=1e-4, rtol=1e-5)


@pytest.mark.parametrize("bq,bk", [(64, 64), (128, 96), (1024, 1024)])
def test_chunked_flash_matches_ref(bq, bk):
    q, k, v = _qkv(2, 333, 8, 2, 32, dv=16)
    for causal in (True, False):
        o1 = ref.chunked_flash_attention(q, k, v, causal=causal,
                                         block_q=bq, block_k=bk)
        o2 = ref.flash_attention(q, k, v, causal=causal)
        np.testing.assert_allclose(o1, o2, atol=2e-5, rtol=2e-5)
