"""Model-zoo correctness: block oracles, prefill/decode equivalence, MoE."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_reduced_config
from repro.models import mamba2 as m2
from repro.models import moe as moe_mod
from repro.models import steps
from repro.models import transformer as tf
from repro.models import xlstm as xl
from repro.models.layers import Initializer

KEY = jax.random.PRNGKey(0)


def _fp32(cfg):
    return cfg.replace(param_dtype="float32", compute_dtype="float32")


def _randomize(p, key, scale=0.1):
    leaves, treedef = jax.tree.flatten(p)
    ks = jax.random.split(key, len(leaves))
    return jax.tree.unflatten(
        treedef, [l + jax.random.normal(k, l.shape, l.dtype) * scale
                  for l, k in zip(leaves, ks)])


# ---------------------------------------------------------------------------
# block-level oracles
# ---------------------------------------------------------------------------

def test_mamba2_chunked_matches_recurrent():
    cfg = _fp32(get_reduced_config("zamba2_7b"))
    p = _randomize(m2.init_mamba2(Initializer(cfg, KEY), "m", cfg),
                   jax.random.fold_in(KEY, 7))
    x = jax.random.normal(jax.random.fold_in(KEY, 1),
                          (2, 64, cfg.d_model), jnp.float32) * 0.5
    y1, st = m2.mamba2_forward(p, x, cfg, return_state=True)
    y2 = m2.mamba2_reference(p, x, cfg)
    np.testing.assert_allclose(y1, y2, atol=1e-4, rtol=1e-4)
    assert np.isfinite(np.asarray(st["ssm"])).all()


def test_mlstm_chunked_matches_recurrent():
    cfg = _fp32(get_reduced_config("xlstm_1_3b"))
    p = _randomize(xl.init_mlstm(Initializer(cfg, KEY), "m", cfg),
                   jax.random.fold_in(KEY, 8))
    x = jax.random.normal(jax.random.fold_in(KEY, 2),
                          (2, 64, cfg.d_model), jnp.float32) * 0.5
    ych, st_c = xl.mlstm_forward(p, x, cfg, return_state=True)
    d_in, nh, hd = xl._mlstm_dims(cfg)
    state = {"C": jnp.zeros((2, nh, hd, hd)), "n": jnp.zeros((2, nh, hd)),
             "m": jnp.full((2, nh), -1e30)}
    outs = []
    for t in range(x.shape[1]):
        o, state = xl.mlstm_decode(p, x[:, t:t + 1], cfg, state)
        outs.append(o)
    yrec = jnp.concatenate(outs, 1)
    np.testing.assert_allclose(ych, yrec, atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(st_c["C"], state["C"], atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("impl", ["ragged_ep", "dispatch_einsum"])
def test_moe_matches_dense_reference(impl):
    cfg = _fp32(get_reduced_config("deepseek_v2_lite_16b"))
    cfg = cfg.replace(moe=dataclasses.replace(cfg.moe, capacity_slack=8.0,
                                              impl=impl))
    p = _randomize(moe_mod.init_moe(Initializer(cfg, KEY), "moe", cfg),
                   jax.random.fold_in(KEY, 9))
    x = jax.random.normal(jax.random.fold_in(KEY, 3), (4, 16, cfg.d_model))
    want = moe_mod.moe_reference(p, x, cfg)
    got, aux = moe_mod.apply_moe(p, x, cfg, mesh=None)
    np.testing.assert_allclose(got, want, atol=1e-4, rtol=1e-4)
    assert float(aux) >= 0.0


def test_moe_grads_finite():
    cfg = _fp32(get_reduced_config("deepseek_v2_lite_16b"))
    p = _randomize(moe_mod.init_moe(Initializer(cfg, KEY), "moe", cfg),
                   jax.random.fold_in(KEY, 10))
    x = jax.random.normal(jax.random.fold_in(KEY, 4), (2, 8, cfg.d_model))

    def loss(p):
        y, aux = moe_mod.apply_moe(p, x, cfg, mesh=None)
        return jnp.sum(y ** 2) + aux

    g = jax.grad(loss)(p)
    assert all(bool(jnp.all(jnp.isfinite(l))) for l in jax.tree.leaves(g))


# ---------------------------------------------------------------------------
# prefill/decode equivalence: decoding token-by-token from a prefix must match
# the full forward pass logits
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["gemma_2b", "minicpm3_4b", "zamba2_7b",
                                  "xlstm_1_3b", "deepseek_v2_lite_16b"])
def test_prefill_decode_consistency(arch):
    cfg = _fp32(get_reduced_config(arch))
    params, _ = tf.init_model(cfg, KEY)
    params = _randomize(params, jax.random.fold_in(KEY, 11), scale=0.02)
    b, p_len, extra = 2, 24, 4
    toks = jax.random.randint(jax.random.fold_in(KEY, 12),
                              (b, p_len + extra), 0, cfg.vocab_size)
    # full forward logits at each position
    full_logits, _, _ = tf.forward(params, cfg, tokens=toks, mode="train")
    # prefill on the prefix, then step
    logits_p, caches = steps.prefill_step(params, {"tokens": toks[:, :p_len]},
                                          cfg, max_len=p_len + extra + 4)
    np.testing.assert_allclose(logits_p, full_logits[:, p_len - 1],
                               atol=2e-3, rtol=2e-3)
    for i in range(extra):
        nt, logits_d, caches = steps.serve_step(
            params, toks[:, p_len + i:p_len + i + 1], caches, cfg)
        np.testing.assert_allclose(logits_d, full_logits[:, p_len + i],
                                   atol=2e-3, rtol=2e-3)


# ---------------------------------------------------------------------------
# MLA absorbed decode == naive decode
# ---------------------------------------------------------------------------

def test_mla_absorb_equivalence():
    cfg = _fp32(get_reduced_config("minicpm3_4b"))
    params, _ = tf.init_model(cfg, KEY)
    params = _randomize(params, jax.random.fold_in(KEY, 13), scale=0.02)
    toks = jax.random.randint(jax.random.fold_in(KEY, 14), (2, 16), 0,
                              cfg.vocab_size)
    _, caches = steps.prefill_step(params, {"tokens": toks}, cfg, max_len=24)
    step_tok = toks[:, -1:]
    cfg_abs = cfg.replace(mla=dataclasses.replace(cfg.mla, absorb=True))
    _, l1, _ = steps.serve_step(params, step_tok, caches, cfg)
    _, l2, _ = steps.serve_step(params, step_tok, caches, cfg_abs)
    np.testing.assert_allclose(l1, l2, atol=2e-3, rtol=2e-3)


# ---------------------------------------------------------------------------
# scan vs unrolled layers must be numerically identical (dry-run soundness)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["internlm2_20b", "zamba2_7b", "xlstm_1_3b",
                                  "deepseek_v2_lite_16b"])
def test_scan_vs_unroll_equivalence(arch):
    cfg = _fp32(get_reduced_config(arch))
    params, _ = tf.init_model(cfg, KEY)
    params = _randomize(params, jax.random.fold_in(KEY, 15), scale=0.02)
    toks = jax.random.randint(jax.random.fold_in(KEY, 16), (2, 32), 0,
                              cfg.vocab_size)
    l1, _, _ = tf.forward(params, cfg, tokens=toks, mode="train")
    l2, _, _ = tf.forward(params, cfg.replace(scan_layers=False),
                          tokens=toks, mode="train")
    np.testing.assert_allclose(l1, l2, atol=2e-3, rtol=2e-3)


# ---------------------------------------------------------------------------
# per-arch smoke: one train step, output shapes, no NaNs
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", [a for a in ARCH_IDS])
def test_arch_smoke_train_step(arch):
    cfg = _fp32(get_reduced_config(arch))
    state = steps.init_train_state(cfg, KEY)
    b, s = 2, 32
    if cfg.stub_frontend:
        batch = {"embeds": jax.random.normal(
            KEY, (b, s, cfg.frontend_dim), jnp.float32),
            "labels": jax.random.randint(jax.random.fold_in(KEY, 1),
                                         (b, s), 0, cfg.vocab_size)}
    else:
        batch = {"tokens": jax.random.randint(KEY, (b, s), 0, cfg.vocab_size),
                 "labels": jax.random.randint(jax.random.fold_in(KEY, 1),
                                              (b, s), 0, cfg.vocab_size)}
    new_state, metrics = steps.train_step(state, batch, cfg)
    assert np.isfinite(float(metrics["loss"]))
    assert float(metrics["loss"]) > 0.0
    # params actually changed
    delta = sum(float(jnp.sum(jnp.abs(a - b))) for a, b in zip(
        jax.tree.leaves(new_state["params"]), jax.tree.leaves(state["params"])))
    assert delta > 0.0
    # logits shape check
    if cfg.stub_frontend:
        logits, _, _ = tf.forward(new_state["params"], cfg,
                                  embeds=batch["embeds"], mode="train")
    else:
        logits, _, _ = tf.forward(new_state["params"], cfg,
                                  tokens=batch["tokens"], mode="train")
    assert logits.shape == (b, s, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("arch", [a for a in ARCH_IDS
                                  if a != "hubert_xlarge"])
def test_arch_smoke_decode(arch):
    cfg = _fp32(get_reduced_config(arch))
    params, _ = tf.init_model(cfg, KEY)
    b = 2
    toks = jax.random.randint(KEY, (b, 16), 0, cfg.vocab_size)
    _, caches = steps.prefill_step(params, {"tokens": toks}, cfg, max_len=32)
    nt, logits, caches = steps.serve_step(params, toks[:, -1:], caches, cfg)
    assert nt.shape == (b,)
    assert logits.shape == (b, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
