"""Chunked prefill & continuous batching: bit-exact parity of the mixed-
iteration engine against whole-prefill oracles.

Three layers of evidence, mirroring the engine's layering:

* Model layer — a prompt prefilled chunk-by-chunk through the paged pool
  (``mode="chunk"`` / ``gqa_prefill_paged``) must produce bitwise-identical
  last-position logits AND pool K/V to a single whole-prompt prefill.
* Store layer — chunked allocation (first-chunk reservation + fill-front
  growth + mid-chunk swap with tail trim) keeps every PagedKVStore
  invariant, and its prefix/accounting counters equal the whole-prompt
  path's when unpressured.
* Engine layer — greedy token streams from the chunked ``Engine`` equal the
  dense ``SlotEngine`` oracle across chunk size x prompt length x prefix
  sharing x preemption (swap and recompute, including mid-chunk), and a
  prompt far beyond ``max_len`` completes bit-identically against an oracle
  sized to ``max_context`` while the whole-prefill engine rejects it
  eagerly.
"""
import jax
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs import get_reduced_config
from repro.engine.paged_kv import PagedKVStore, prefix_chain
from repro.engine.runner import Engine, EngineConfig, SlotEngine
from repro.models import steps
from repro.models import transformer as tf

MAX_LEN = 96
BT = 16


@pytest.fixture(scope="module")
def cfg():
    return get_reduced_config("gemma_2b")


@pytest.fixture(scope="module")
def params(cfg):
    p, _ = tf.init_model(cfg, jax.random.PRNGKey(0))
    return p


# oracle streams are deterministic: cache them across hypothesis examples so
# repeated prompt sets don't re-run (and re-jit) the dense engine
_ORACLE: dict = {}


def _oracle_streams(cfg, params, prompts, max_new, max_len=MAX_LEN):
    key = (tuple(tuple(p.tolist()) for p in prompts), max_new, max_len)
    if key not in _ORACLE:
        slot = SlotEngine(cfg, params=params, max_batch=2, max_len=max_len)
        for p in prompts:
            slot.submit(p, max_new_tokens=max_new)
        _ORACLE[key] = {tuple(r.prompt.tolist()): list(r.tokens)
                        for r in slot.run()}
    return _ORACLE[key]


# ---------------------------------------------------------------------------
# model layer: chunked == whole prefill, bitwise
# ---------------------------------------------------------------------------

def test_chunk_passes_match_whole_prefill_bitwise(cfg, params):
    """Drive chunk_step manually over a paged cache and compare against one
    whole-prompt prefill: last-position logits and every written K/V slot
    must be bit-identical (the foundation the engine parity rests on)."""
    rng = np.random.default_rng(0)
    P = 40
    prompt = rng.integers(1, cfg.vocab_size, P).astype(np.int32)
    logits_w, dense = steps.prefill_step(
        params, {"tokens": jax.numpy.asarray(prompt[None])}, cfg, MAX_LEN)
    logits_w = np.asarray(logits_w)
    mb, num_blocks = MAX_LEN // BT, 2 * (MAX_LEN // BT)
    for chunk in (8, 13, 40):                  # unaligned + whole-in-one
        caches = tf.init_paged_cache(cfg, 2, num_blocks, BT, mb)
        tables = np.full((2, mb), num_blocks, np.int32)
        tables[0] = np.arange(mb)
        for g in caches.values():
            L = g["block_tables"].shape[0]
            g["block_tables"] = jax.numpy.broadcast_to(
                jax.numpy.asarray(tables)[None], (L, 2, mb))
        got = 0
        while got < P:
            take = min(chunk, P - got)
            toks = np.zeros((2, chunk), np.int32)
            toks[0, :take] = prompt[got:got + take]
            qv = np.array([take, 0], np.int32)
            _, logits_c, caches = steps.chunk_step(
                params, jax.numpy.asarray(toks), jax.numpy.asarray(qv),
                caches, cfg)
            got += take
        assert np.array_equal(np.asarray(logits_c)[0], logits_w[0]), chunk
        kp = np.asarray(caches["attn"]["k_pool"])
        kd = np.asarray(dense["attn"]["k"])
        kg = kp[:, tables[0]].reshape(kp.shape[0], mb * BT, *kp.shape[3:])
        assert np.array_equal(kg[:, :P], kd[:, 0, :P]), chunk


# ---------------------------------------------------------------------------
# store layer: chunked allocation semantics
# ---------------------------------------------------------------------------

def test_store_chunked_allocate_grow_advance():
    st_ = PagedKVStore(num_blocks=8, block_tokens=4)
    chain = prefix_chain(list(range(16)), 4)       # 4 full blocks
    blocks, m = st_.allocate(0, 4, chain, filled=0, context_tokens=16)
    assert m == 0 and len(blocks) == 1             # first chunk only
    assert st_.tables[0].tokens == 0
    st_.advance(0, 4)                              # chunk 1 written
    for _ in range(3):                             # fill front growth
        b = st_.grow(0)
        assert b is not None
        st_.advance(0, 4)
    assert st_.tables[0].tokens == 16
    assert st_.tables[0].hashes == chain           # registered as it filled
    st_.check_invariants()
    # a second chunked admission of the same prompt aliases all 4 blocks up
    # front (matched prefix claimed to the full context, not just chunk 1)
    blocks2, m2 = st_.allocate(1, 4, chain, filled=0, context_tokens=16)
    assert m2 == 4 and blocks2 == st_.tables[0].blocks
    st_.free(0)
    st_.free(1)
    st_.check_invariants()


def test_store_grow_aliases_chain_registered_after_admission():
    """Concurrent chunked prefills of a shared prefix: the later request's
    fill-front growth must alias blocks the earlier one registered AFTER
    the later one was admitted."""
    st_ = PagedKVStore(num_blocks=8, block_tokens=4)
    chain = prefix_chain(list(range(12)), 4)
    st_.allocate(0, 4, chain, filled=0, context_tokens=12)   # A: chunk 1
    st_.allocate(1, 4, chain[:1], filled=0, context_tokens=12)
    # B admitted seeing only A's first registration; A fills onward
    st_.tables[1].chain = list(chain)              # same prompt, full chain
    st_.advance(0, 4)
    st_.grow(0)
    st_.advance(0, 4)                              # A registered chain[1]
    st_.advance(1, 4)
    b = st_.grow(1)                                # B's fill front at block 1
    assert b == st_.tables[0].blocks[1]            # aliased, not fresh
    assert st_.refcount[b] == 2
    st_.free(0)
    st_.free(1)
    st_.check_invariants()


def test_store_swap_out_trims_unfilled_tail():
    st_ = PagedKVStore(num_blocks=8, block_tokens=4)
    chain = prefix_chain(list(range(16)), 4)
    st_.allocate(0, 4, chain, filled=0, context_tokens=16)
    st_.advance(0, 4)
    st_.grow(0)                                    # reserved ahead of fill
    st_.advance(0, 2)                              # mid-chunk: 6 filled
    st_.grow(0)                                    # one fully unfilled block
    assert len(st_.tables[0].blocks) == 3
    kept = st_.swap_out(0)
    assert kept is not None and len(kept) == 2     # blocks_for(6) == 2
    st_.check_invariants()
    back = st_.swap_in(0)
    assert len(back) == 2 and st_.tables[0].tokens == 6
    st_.free(0)
    st_.check_invariants()


@settings(max_examples=15, deadline=None)
@given(ops=st.lists(st.tuples(st.integers(0, 4), st.integers(1, 30)),
                    min_size=1, max_size=40),
       nb=st.integers(4, 12), bt=st.sampled_from([2, 4]),
       chunk=st.integers(1, 6))
def test_store_invariants_random_walk_chunked(ops, nb, bt, chunk):
    """The allocator random walk of test_paged_engine, rerun through the
    CHUNKED admission path (first-chunk reservation, fill-front growth in
    chunk-sized strides, mid-fill swap with tail trim)."""
    st_ = PagedKVStore(num_blocks=nb, block_tokens=bt)
    live, goal, rid = [], {}, 0
    for op, arg in ops:
        if op == 0:                                # chunked admission
            toks = arg
            chain = prefix_chain(list(range(min(toks, 3 * bt))), bt)
            if st_.allocate(rid, min(chunk * bt, toks), chain, filled=0,
                            context_tokens=toks) is not None:
                live.append(rid)
                goal[rid] = toks
            rid += 1
        elif op == 1 and live:                     # advance the fill front
            r = live[arg % len(live)]
            t = st_.tables[r]
            if t.on_device and t.tokens < goal[r]:
                take = min(chunk, goal[r] - t.tokens)
                ok = True
                while len(t.blocks) * bt < t.tokens + take:
                    if st_.grow(r) is None:
                        ok = False
                        break
                if ok:
                    st_.advance(r, take)
        elif op == 2 and live:                     # free
            st_.free(live.pop(arg % len(live)))
        elif op == 3 and live:                     # swap out (maybe degrade)
            r = live[arg % len(live)]
            if st_.tables[r].on_device:
                if st_.swap_out(r) is None:
                    live.remove(r)
                    st_.drop(r)
        elif op == 4 and live:                     # swap in
            r = live[arg % len(live)]
            if not st_.tables[r].on_device:
                st_.swap_in(r)
        st_.check_invariants()
    for r in live:
        st_.free(r)
    st_.check_invariants()
    assert st_.used_blocks == 0


# ---------------------------------------------------------------------------
# engine layer: stream parity across the scheduling space
# ---------------------------------------------------------------------------

def _prompts(lengths, share, vocab, seed=3):
    rng = np.random.default_rng(seed)
    shared = rng.integers(1, vocab, 2 * BT).astype(np.int32)
    out = []
    for n in lengths:
        body = rng.integers(1, vocab, n).astype(np.int32)
        if share and n > 2 * BT:
            body[:2 * BT] = shared
        out.append(body)
    return out


@settings(max_examples=8, deadline=None)
@given(chunk=st.sampled_from([4, 16, 32, 96]),
       lengths=st.lists(st.sampled_from([12, 33, 50]), min_size=2,
                        max_size=4),
       share=st.booleans(),
       policy=st.sampled_from(["swap", "recompute"]),
       tight=st.booleans())
def test_chunked_stream_parity_sweep(cfg, params, chunk, lengths, share,
                                     policy, tight):
    """chunk size x prompt length x prefix sharing x preemption: greedy
    streams from the chunked engine must be bit-identical to the dense
    whole-prefill oracle. ``tight`` shrinks the pool so growth preempts
    victims mid-stream (and mid-chunk) for real."""
    prompts = _prompts(lengths, share, cfg.vocab_size)
    want = _oracle_streams(cfg, params, prompts, max_new=8)
    nb = 7 if tight else None
    eng = Engine(cfg, params=params, max_batch=2, max_len=MAX_LEN,
                 block_tokens=BT, num_blocks=nb, preemption=policy,
                 config=EngineConfig(chunk_size=chunk))
    for p in prompts:
        eng.submit(p, max_new_tokens=8)
    done = eng.run(max_steps=5000)
    got = {tuple(r.prompt.tolist()): list(r.tokens) for r in done}
    assert got == want
    eng.store.check_invariants()
    assert eng.store.used_blocks == 0              # everything released


def test_mid_chunk_preemption_swap_and_recompute(cfg, params):
    """Preempt a request whose prefill is mid-flight (0 < prefilled < ctx):
    swap must round-trip the partial fill front through host memory,
    recompute must restart it — both without perturbing the stream."""
    rng = np.random.default_rng(21)
    long_p = rng.integers(1, cfg.vocab_size, 60).astype(np.int32)
    want = _oracle_streams(cfg, params, [long_p], max_new=6)
    for policy in ("swap", "recompute"):
        eng = Engine(cfg, params=params, max_batch=2, max_len=MAX_LEN,
                     block_tokens=BT, preemption=policy,
                     config=EngineConfig(chunk_size=12))
        r = eng.submit(long_p, max_new_tokens=6)
        eng._admit()
        eng._step_mixed()
        eng._step_mixed()
        assert r.prefilled == 24                   # mid-prefill, mid-BLOCK
        eng.preempt_slot(r.slot)
        assert r.state == ("swapped" if policy == "swap" else "preempted")
        done = eng.run()
        assert {tuple(q.prompt.tolist()): list(q.tokens)
                for q in done} == want, policy
        assert r.preemptions == 1
        eng.store.check_invariants()


def test_chunked_accounting_matches_whole_path(cfg, params):
    """Unpressured + prefix-shared: the chunked engine's dedup/allocation
    counters must equal the whole-prefill engine's (same prompts, same
    physical sharing — chunking changes the schedule, not the memory
    story), and chunked peak occupancy can only be lower."""
    prompts = _prompts([50, 50, 33, 40], share=True, vocab=cfg.vocab_size)
    stats = {}
    for mode, kw in (("whole", {}),
                     ("chunk", {"config": EngineConfig(chunk_size=16)})):
        eng = Engine(cfg, params=params, max_batch=2, max_len=MAX_LEN,
                     block_tokens=BT, **kw)
        for p in prompts:
            eng.submit(p, max_new_tokens=6)
        eng.run()
        stats[mode] = eng.kv_stats()
        eng.store.check_invariants()
    for k in ("prefix_hit_blocks", "prefix_hit_tokens",
              "blocks_allocated_total"):
        assert stats["chunk"][k] == stats["whole"][k], k
    assert stats["chunk"]["prefix_hit_blocks"] > 0  # sharing actually fired
    assert stats["chunk"]["peak_blocks"] <= stats["whole"]["peak_blocks"]


def test_long_context_prompt_beyond_max_len(cfg, params):
    """A prompt ~3x max_len completes through the chunked engine with
    bit-identical greedy tokens to a dense oracle sized to max_context;
    the whole-prefill engine rejects the same prompt eagerly."""
    rng = np.random.default_rng(7)
    prompt = rng.integers(1, cfg.vocab_size, 300).astype(np.int32)
    want = _oracle_streams(cfg, params, [prompt], max_new=6, max_len=384)
    eng = Engine(cfg, params=params, max_batch=2, max_len=MAX_LEN,
                 block_tokens=BT,
                 config=EngineConfig(chunk_size=32, max_context=384))
    eng.submit(prompt, max_new_tokens=6)
    done = eng.run()
    assert len(done) == 1
    assert list(done[0].tokens) == want[tuple(prompt.tolist())]
    whole = Engine(cfg, params=params, max_batch=2, max_len=MAX_LEN,
                   block_tokens=BT)
    with pytest.raises(ValueError, match="chunked prefill"):
        whole.submit(prompt)


def test_submit_validates_eagerly(cfg, params):
    eng = Engine(cfg, params=params, max_batch=1, max_len=MAX_LEN,
                 block_tokens=BT)
    eng.submit(np.arange(MAX_LEN - 2, dtype=np.int32))     # boundary: fits
    with pytest.raises(ValueError, match="max_len"):
        eng.submit(np.arange(MAX_LEN - 1, dtype=np.int32))
    chunked = Engine(cfg, params=params, max_batch=1, max_len=MAX_LEN,
                     block_tokens=BT,
                     config=EngineConfig(chunk_size=16, max_context=192))
    chunked.submit(np.arange(MAX_LEN + 10, dtype=np.int32))  # past max_len ok
    with pytest.raises(ValueError, match="max_context"):
        chunked.submit(np.arange(191, dtype=np.int32))
    # max_context without chunking is a config error, caught at construction
    with pytest.raises(AssertionError):
        Engine(cfg, params=params, max_batch=1, max_len=MAX_LEN,
               block_tokens=BT, config=EngineConfig(max_context=192))


def test_decode_share_knob_starves_or_feeds_prefill(cfg, params):
    """decode_share is the ITL extreme of the knob: at 1.0 a running decode
    monopolizes the budget and a waiting prompt makes no prefill progress;
    at 0.0 the same iteration advances the prompt by a full chunk."""
    rng = np.random.default_rng(31)
    short = rng.integers(1, cfg.vocab_size, 12).astype(np.int32)
    long_p = rng.integers(1, cfg.vocab_size, 60).astype(np.int32)
    for share, expect_progress in ((1.0, 0), (0.0, 16)):
        eng = Engine(cfg, params=params, max_batch=2, max_len=MAX_LEN,
                     block_tokens=BT,
                     config=EngineConfig(chunk_size=16, decode_share=share))
        a = eng.submit(short, max_new_tokens=30)
        eng._admit()
        while not eng._is_decoding(a):             # finish a's prefill
            eng._step_mixed()
        b = eng.submit(long_p, max_new_tokens=4)
        eng._admit()
        n_tok = len(a.tokens)
        eng._step_mixed()
        assert len(a.tokens) == n_tok + 1          # decode always advances
        assert b.prefilled == expect_progress, share
