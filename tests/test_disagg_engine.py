"""Disaggregated prefill/decode engine (paper §II-B made real): worker-role
split over the shared ``EngineCore``, the KV-page export/import handoff, the
single-engine bit-equality oracle (across transfer granularities, pairing
modes, chunked prefill, and preemption on either side of the handoff), plus
the simulator-side pricing this PR calibrates: ``Network`` estimate/transfer
consistency on multi-link paths, layerwise swap granularity in
``PagedKVAllocator``, and the measured-link alpha-beta fit."""
import jax
import numpy as np
import pytest

from repro.configs import get_reduced_config
from repro.core.comm import Network
from repro.core.llm_scheduler import LLMScheduler, SchedulerLimits
from repro.core.memory import PagedKVAllocator, tier_transfer_time
from repro.core.request import LLM, Request, Stage
from repro.engine.core import EngineConfig, EngineCore
from repro.engine.paged_kv import PagedKVStore, prefix_chain
from repro.engine.workers import DisaggEngine, move_pages, oracle_engine
from repro.launch.mesh import handoff_devices
from repro.models import transformer as tf
from repro.perfmodel.hardware import (ClusterSpec, H100, LinkSpec,
                                      TIER_HOST_DRAM)
from repro.perfmodel.regression import fit_link_spec

BLOCK_TOKENS = 16
OUT_TOKENS = 8
GEOM = dict(max_batch=2, max_len=96, block_tokens=BLOCK_TOKENS)


@pytest.fixture(scope="module")
def cfg():
    return get_reduced_config("gemma_2b")


@pytest.fixture(scope="module")
def params(cfg):
    return tf.init_model(cfg, jax.random.PRNGKey(3))[0]


@pytest.fixture(scope="module")
def prompts(cfg):
    """Shared 32-token (2-block) system prefix + short unique tails, two
    distinct total lengths to bound jit retraces."""
    rng = np.random.default_rng(5)
    sysp = rng.integers(0, cfg.vocab_size, 32)
    return [np.concatenate([sysp, rng.integers(0, cfg.vocab_size, n)])
            .astype(np.int32) for n in (6, 11, 6, 11)]


@pytest.fixture(scope="module")
def oracle_streams(cfg, params, prompts):
    eng = oracle_engine(cfg, params, **GEOM)
    hs = [eng.submit(p, max_new_tokens=OUT_TOKENS) for p in prompts]
    eng.run()
    return [h.tokens for h in hs]


def _disagg_streams(cfg, params, prompts, **kw):
    eng = DisaggEngine(cfg, params, **{**GEOM, **kw})
    hs = [eng.submit(p, max_new_tokens=OUT_TOKENS) for p in prompts]
    eng.run()
    for w in eng.prefill + eng.decode:
        w.store.check_invariants()
    assert all(h.state == "done" for h in hs)
    return [h.tokens for h in hs], eng


# ---------------------------------------------------------------------------
# bit-equality oracle: granularity x pairing mode x chunking x preemption
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["local", "global"])
@pytest.mark.parametrize("gran", ["full", "layerwise"])
def test_disagg_streams_match_oracle(cfg, params, prompts, oracle_streams,
                                     mode, gran):
    got, eng = _disagg_streams(cfg, params, prompts, n_prefill=1, n_decode=2,
                               mode=mode, granularity=gran)
    assert got == oracle_streams
    ts = eng.transfer_stats()
    assert ts["handoffs"] == len(prompts)
    assert ts["bytes"] > 0 and ts["total_s"] > 0
    assert ts["exposed_s"] <= ts["total_s"] + 1e-12


def test_disagg_chunked_prefill_parity(cfg, params, prompts, oracle_streams):
    """Chunked prefill on the prefill workers (budgeted passes, first token
    streamed from the final chunk) must not change any stream."""
    got, eng = _disagg_streams(cfg, params, prompts, n_prefill=2, n_decode=1,
                               mode="global", granularity="layerwise",
                               config=EngineConfig(chunk_size=8))
    assert got == oracle_streams
    assert eng.transfer_stats()["handoffs"] == len(prompts)


@pytest.fixture(scope="module")
def pressure_prompts(cfg):
    """No shared prefix (so swap preemption is never degraded by shared
    pages) and lengths that cross a block boundary mid-decode — two rows
    together overflow a 6-block decode pool exactly when one grows."""
    rng = np.random.default_rng(23)
    return [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
            for n in (44, 46, 44, 46)]


@pytest.fixture(scope="module")
def pressure_oracle(cfg, params, pressure_prompts):
    eng = oracle_engine(cfg, params, **GEOM)
    hs = [eng.submit(p, max_new_tokens=OUT_TOKENS) for p in pressure_prompts]
    eng.run()
    return [h.tokens for h in hs]


@pytest.mark.parametrize("policy", ["swap", "recompute"])
def test_disagg_preemption_parity(cfg, params, pressure_prompts,
                                  pressure_oracle, policy):
    """Pools too small for the full working set force preemption on the
    decode side of the handoff; streams stay bit-identical. Recompute
    victims on a decode worker cannot re-prefill there — they must
    round-trip through their home prefill worker and hand off again; swap
    victims round-trip against the decode worker's own pool."""
    got, eng = _disagg_streams(cfg, params, pressure_prompts,
                               n_prefill=1, n_decode=1,
                               mode="local", granularity="full",
                               preemption=policy, decode_blocks=6)
    assert got == pressure_oracle
    kv = eng.kv_stats()
    faults = sum(w["page_faults"] for w in kv.values())
    assert faults >= 1                        # pressure actually fired
    if policy == "swap":
        assert any(w["swap_outs"] >= 1 for w in kv.values())
        assert eng.transfer_stats()["handoffs"] == len(pressure_prompts)
    else:
        assert any(w["recompute_drops"] >= 1 for w in kv.values())
        # at least one victim re-prefilled and handed off a second time
        assert eng.transfer_stats()["handoffs"] > len(pressure_prompts)


def test_disagg_prefix_dedup_on_decode_side(cfg, params, prompts):
    """Same-prefix handoffs into one decode worker alias the resident chain:
    the import skips the pool write for matched pages and reports them as
    wire bytes a pinned-dedup protocol could have saved."""
    _, eng = _disagg_streams(cfg, params, prompts, n_prefill=1, n_decode=1,
                             mode="local", granularity="full")
    ts = eng.transfer_stats()
    assert ts["dedup_blocks"] >= 2            # the 2-block shared prefix
    # wire dedup, not a prefix-cache hit (count_hits=False convention)
    assert eng.decode[0].store.prefix_hit_blocks == 0


# ---------------------------------------------------------------------------
# store export/import handoff contract
# ---------------------------------------------------------------------------

def test_export_import_roundtrip_and_dedup():
    src = PagedKVStore(num_blocks=8, block_tokens=4)
    toks = list(range(12))
    chain = prefix_chain(toks, 4)
    src.allocate(1, 12, chain)
    exp = src.export_pages(1)
    assert exp.tokens == 12 and len(exp.blocks) == 3
    assert list(exp.chain) == list(chain)
    assert src.exports == 1 and src.exported_blocks == 3

    dst = PagedKVStore(num_blocks=8, block_tokens=4)
    blocks, matched = dst.import_pages(2, exp.tokens, exp.chain)
    assert len(blocks) == 3 and matched == 0  # cold pool: scatter everything
    dst.free(2)                               # registered blocks park cached
    blocks2, matched2 = dst.import_pages(3, exp.tokens, exp.chain)
    assert matched2 == 3                      # resident chain fully aliased
    assert dst.import_dedup_blocks == 3
    assert dst.prefix_hit_blocks == 0         # count_hits=False convention
    dst.check_invariants()


def test_export_refuses_forked_tables():
    st_ = PagedKVStore(num_blocks=8, block_tokens=4)
    st_.allocate(1, 8)
    st_.fork_table(1, 4)
    with pytest.raises(AssertionError):
        st_.export_pages(1)


def test_move_pages_host_staged_counts_bytes(cfg, params):
    from repro.models import steps
    import jax.numpy as jnp
    caches = tf.init_paged_cache(cfg, batch=1, num_blocks=4,
                                 block_tokens=BLOCK_TOKENS, max_blocks=4)
    pages = steps.gather_pages(caches, jnp.asarray([0, 2], jnp.int32))
    for gran in ("full", "layerwise"):
        staged, rec = move_pages(pages, None, gran)
        want = sum(x.nbytes for x in jax.tree_util.tree_leaves(pages))
        assert rec["bytes"] == want and rec["pages"] == 2
        assert rec["staged"] == "host"
        assert rec["exposed_s"] <= rec["total_s"] + 1e-12
        assert sum(b for b, _ in rec["samples"]) == want
        for name, g in staged.items():
            np.testing.assert_array_equal(np.asarray(g["k"]),
                                          np.asarray(pages[name]["k"]))


# ---------------------------------------------------------------------------
# runner facade: the public API survives the core/workers split
# ---------------------------------------------------------------------------

def test_runner_facade_reexports(cfg):
    from repro.engine import runner
    assert runner.Engine is not None and runner.SlotEngine is not None
    assert issubclass(runner.Engine, EngineCore)
    assert runner.EngineConfig is EngineConfig
    eng = runner.make_engine(cfg, max_batch=1, max_len=32,
                             block_tokens=16, device=None)
    assert isinstance(eng, runner.Engine)


# ---------------------------------------------------------------------------
# device assignment helper
# ---------------------------------------------------------------------------

def test_handoff_devices_roles_partition():
    pd, dd = handoff_devices(2, 3)
    assert len(pd) == 2 and len(dd) == 3
    if len(jax.devices()) < 2:
        assert all(d is None for d in pd + dd)
    else:
        assert not (set(pd) & set(dd))        # roles never share a device


# ---------------------------------------------------------------------------
# simulator pricing: estimate/transfer consistency + layerwise swap
# ---------------------------------------------------------------------------

def _two_hop_net():
    net = Network()
    net.add_link("a", LinkSpec("a", 1e9, 1e-5))
    net.add_link("b", LinkSpec("b", 4e8, 3e-5))
    net.connect("src", "dst", ["a", "b"])
    return net


@pytest.mark.parametrize("gran", ["full", "layerwise"])
def test_network_estimate_matches_transfer_under_contention(gran):
    """On a multi-link path, ``estimate`` must price a would-be ``transfer``
    exactly (same contention state) and in particular never under-price it —
    a router that trusts the estimate can never be surprised by the move."""
    net = _two_hop_net()
    rng = np.random.default_rng(17)
    now = 0.0
    for _ in range(25):
        nbytes = float(rng.integers(1, 1 << 22))
        est = net.estimate("src", "dst", nbytes, now, gran, n_layers=6)
        arrive = net.transfer("src", "dst", nbytes, now, gran, n_layers=6)
        assert arrive - now <= est + 1e-9
        assert arrive - now == pytest.approx(est, abs=1e-12)
        now += float(rng.random()) * 1e-3


def test_layerwise_occupies_full_bytes_despite_small_exposure():
    """Layerwise exposes ~one layer of latency but the link still carries
    every byte: a second transfer right behind it queues on the full
    occupancy, and estimate sees that contention too."""
    net = _two_hop_net()
    nbytes = 8e6
    t1 = net.transfer("src", "dst", nbytes, 0.0, "layerwise", n_layers=8)
    assert t1 - 0.0 < nbytes / 1e9            # exposed: far less than full
    est2 = net.estimate("src", "dst", nbytes, 0.0, "layerwise", n_layers=8)
    t2 = net.transfer("src", "dst", nbytes, 0.0, "layerwise", n_layers=8)
    assert est2 == pytest.approx(t2)
    assert t2 > nbytes / 1e9                  # queued behind full occupancy


def test_override_link_repices_in_place():
    net = _two_hop_net()
    net.transfer("src", "dst", 1e6, 0.0)
    moved = net.links["a"].bytes_moved
    busy = net.links["a"].busy_until
    net.override_link("a", LinkSpec("measured", 2e9, 0.0))
    assert net.links["a"].bytes_moved == moved     # counters survive
    assert net.links["a"].busy_until == busy       # contention survives
    est = net.estimate("src", "dst", 2e9, busy)
    assert est == pytest.approx(2e9 / 2e9 + 2e9 / 4e8 + 3e-5)


def test_tier_transfer_time_layerwise_prices_one_group():
    tier = TIER_HOST_DRAM
    nb = 1e8
    full = tier_transfer_time(nb, tier)
    lw = tier_transfer_time(nb, tier, "layerwise", 8)
    assert lw == pytest.approx(tier.transfer_time(nb / 8))
    assert lw < full
    assert tier_transfer_time(nb, tier, "layerwise", 1) == pytest.approx(full)


def test_allocator_layerwise_swap_same_bytes_smaller_stall():
    kv = PagedKVAllocator(capacity_bytes=64.0, bytes_per_token=1.0,
                          block_tokens=4, swap_tiers=(TIER_HOST_DRAM,))
    kv.allocate(1, 16)
    nb_full, t_full = kv.swap_out(1)
    nb_lw, t_lw = kv.swap_in(1, "layerwise", 8)
    assert nb_lw == nb_full                   # the wire carries it all
    assert t_lw < t_full                      # only one group is exposed
    kv.check_invariants()


def test_scheduler_layerwise_swap_cuts_stall_keeps_bytes():
    """End-to-end through ``SchedulerLimits``: the same pressured schedule
    swaps the same bytes under both granularities, but layerwise exposes a
    strictly smaller total stall (and every request still finishes)."""
    from repro.configs import get_config
    cfg = get_config("llama3_70b")
    cluster = ClusterSpec(H100, n_chips=2, tp=2)
    totals = {}
    for gran in ("full", "layerwise"):
        sched = LLMScheduler(
            "continuous", cfg, cluster,
            limits=SchedulerLimits(max_batch=8, kv_capacity_frac=0.0125,
                                   preemption="swap", swap_granularity=gran))
        reqs = [Request(arrival=0.0, input_tokens=400, output_tokens=120,
                        stages=[Stage(LLM)]) for _ in range(6)]
        for r in reqs:
            sched.add(r)
        now, finished, swap_t, swap_b = 0.0, [], 0.0, 0.0
        while sched.has_work():
            step = sched.plan_step()
            assert step is not None
            now += step.duration
            finished += sched.finish_step(step, now)
            swap_t += step.swap_time
            swap_b += step.swap_bytes
        assert len(finished) == 6
        assert sched.kv.swap_bytes_out > 0    # pressure actually swapped
        totals[gran] = (swap_b, swap_t)
    assert totals["layerwise"][0] == pytest.approx(totals["full"][0])
    assert totals["layerwise"][1] < totals["full"][1]


# ---------------------------------------------------------------------------
# measured-link fit (the calibration half of the loop)
# ---------------------------------------------------------------------------

def test_fit_link_spec_recovers_alpha_beta():
    alpha, bw = 2e-4, 5e8
    samples = [(b, alpha + b / bw) for b in (1e4, 1e5, 1e6, 4e6)]
    spec = fit_link_spec(samples)
    assert spec.latency == pytest.approx(alpha, rel=1e-6)
    assert spec.bandwidth == pytest.approx(bw, rel=1e-6)


def test_fit_link_spec_degenerate_cases():
    one = fit_link_spec([(1e6, 1e-3)])
    assert one.latency == 0.0
    assert one.bandwidth == pytest.approx(1e9)
    neg = fit_link_spec([(1e4, 5e-3), (1e6, 1e-3)])   # noisy negative slope
    assert neg.bandwidth > 0 and np.isfinite(neg.bandwidth)
    assert neg.latency >= 0.0
    with pytest.raises(ValueError):
        fit_link_spec([])
