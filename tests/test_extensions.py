"""Tests for the beyond-paper extensions: speculative decoding model,
chunk-size trade-off, sharding profiles."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_config, get_reduced_config
from repro.core import SystemSpec, WorkloadConfig, build_system, generate
from repro.core.llm_scheduler import SchedulerLimits
from repro.core.workload import AZURE_CODE
from repro.perfmodel import analytical as ana
from repro.perfmodel.hardware import ClusterSpec, H100


def test_spec_decode_speedup_monotone_in_alpha():
    target = get_config("llama3_70b")
    draft = get_config("guard_2b")
    cluster = ClusterSpec(H100, 2, 2)
    base = ana.decode_step_time(target, cluster, 16, 2048).time
    prev = 0.0
    for alpha in (0.5, 0.7, 0.9):
        cost, accepted = ana.speculative_decode_step(target, draft, cluster,
                                                     16, 2048, k=4, alpha=alpha)
        speedup = base / (cost.time / accepted)
        assert speedup > prev
        prev = speedup
    assert prev > 1.5  # high-acceptance spec decode must beat plain decode


def test_spec_decode_expected_tokens_formula():
    target = get_config("llama3_70b")
    draft = get_config("guard_2b")
    cluster = ClusterSpec(H100, 2, 2)
    _, acc = ana.speculative_decode_step(target, draft, cluster, 8, 1024,
                                         k=3, alpha=0.5)
    assert np.isclose(acc, (1 - 0.5 ** 4) / 0.5)


def test_chunk_size_tpot_tradeoff():
    """Sarathi trade-off: larger chunks worsen tail TPOT (decode stalls
    behind bigger prefill chunks)."""
    def tpot_p90(chunk):
        spec = SystemSpec(n_llm_clients=2, strategy="chunked",
                          limits=SchedulerLimits(chunk_size=chunk),
                          with_pre_post=False)
        coord = build_system(spec)
        wl = WorkloadConfig(trace=AZURE_CODE, rate=2.0, n_requests=40,
                            postprocess=False, seed=41)
        coord.submit(generate(wl))
        return coord.run().summary()["tpot_p90"]

    assert tpot_p90(2048) > tpot_p90(256)


def test_shard_v2_smoke():
    """shard_v2 profile must not change single-device numerics."""
    import jax.numpy as jnp
    from repro.models import steps, transformer as tf
    cfg = get_reduced_config("internlm2_20b").replace(
        param_dtype="float32", compute_dtype="float32")
    params, _ = tf.init_model(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                              cfg.vocab_size)
    _, c1 = steps.prefill_step(params, {"tokens": toks}, cfg, max_len=24)
    _, l1, _ = steps.serve_step(params, toks[:, -1:], c1, cfg)
    cfg2 = cfg.replace(shard_v2=True)
    params2, _ = tf.init_model(cfg2, jax.random.PRNGKey(0))
    _, c2 = steps.prefill_step(params2, {"tokens": toks}, cfg2, max_len=24)
    _, l2, _ = steps.serve_step(params2, toks[:, -1:], c2, cfg2)
    np.testing.assert_allclose(l1, l2, atol=1e-5)


def test_attn_in_seqshard_smoke():
    import jax.numpy as jnp
    from repro.models import transformer as tf
    cfg = get_reduced_config("minicpm3_4b").replace(
        param_dtype="float32", compute_dtype="float32",
        attn_in_seqshard=True)
    params, _ = tf.init_model(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                              cfg.vocab_size)
    logits, _, _ = tf.forward(params, cfg, tokens=toks, mode="train")
    assert bool(jnp.all(jnp.isfinite(logits)))
