"""Miniature stand-in for ``hypothesis`` so the property tests still collect
and run (as seeded random sweeps) on machines without the real package.

Installed into ``sys.modules['hypothesis']`` by ``conftest.py`` ONLY when the
real library is missing. Supports exactly the surface this repo's tests use:
``given`` (positional and keyword strategies), ``settings(max_examples=,
deadline=)``, and ``strategies.{integers,floats,booleans,lists,sampled_from,
tuples,just}``. No shrinking — a failing example is reported verbatim.
"""
from __future__ import annotations

import functools
import inspect
import random
import types
import zlib

DEFAULT_MAX_EXAMPLES = 25


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def draw(self, rnd: random.Random):
        return self._draw(rnd)

    def map(self, fn):
        return _Strategy(lambda rnd: fn(self._draw(rnd)))

    def filter(self, pred, _tries: int = 1000):
        def draw(rnd):
            for _ in range(_tries):
                v = self._draw(rnd)
                if pred(v):
                    return v
            raise ValueError("filter predicate never satisfied")
        return _Strategy(draw)


def integers(min_value=-(2 ** 31), max_value=2 ** 31 - 1):
    return _Strategy(lambda rnd: rnd.randint(int(min_value), int(max_value)))


def floats(min_value=0.0, max_value=1.0, allow_nan=False, allow_infinity=False,
           width=64):
    lo, hi = float(min_value), float(max_value)
    return _Strategy(lambda rnd: rnd.uniform(lo, hi))


def booleans():
    return _Strategy(lambda rnd: rnd.random() < 0.5)


def sampled_from(elements):
    seq = list(elements)
    return _Strategy(lambda rnd: seq[rnd.randrange(len(seq))])


def just(value):
    return _Strategy(lambda rnd: value)


def lists(elements: _Strategy, min_size=0, max_size=None, unique=False):
    cap = max_size if max_size is not None else min_size + 10

    def draw(rnd):
        n = rnd.randint(min_size, cap)
        if not unique:
            return [elements.draw(rnd) for _ in range(n)]
        seen, out = set(), []
        for _ in range(50 * max(n, 1)):
            v = elements.draw(rnd)
            if v not in seen:
                seen.add(v)
                out.append(v)
            if len(out) == n:
                break
        return out
    return _Strategy(draw)


def tuples(*strategies):
    return _Strategy(lambda rnd: tuple(s.draw(rnd) for s in strategies))


def settings(max_examples: int = DEFAULT_MAX_EXAMPLES, deadline=None, **_kw):
    def deco(fn):
        fn._hyp_settings = {"max_examples": max_examples}
        return fn
    return deco


def given(*arg_strategies, **kw_strategies):
    def deco(fn):
        conf = getattr(fn, "_hyp_settings", {})
        n_examples = conf.get("max_examples", DEFAULT_MAX_EXAMPLES)
        seed = zlib.crc32(fn.__qualname__.encode())

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            rnd = random.Random(seed)
            for i in range(n_examples):
                drawn_args = tuple(s.draw(rnd) for s in arg_strategies)
                drawn_kw = {k: s.draw(rnd) for k, s in kw_strategies.items()}
                try:
                    fn(*args, *drawn_args, **kwargs, **drawn_kw)
                except Exception:
                    print(f"[hypothesis-fallback] failing example #{i}: "
                          f"args={drawn_args} kwargs={drawn_kw}")
                    raise
        # hide strategy-supplied parameters from pytest's fixture resolution
        params = list(inspect.signature(fn).parameters.values())
        if arg_strategies:
            params = params[:-len(arg_strategies)] if not kw_strategies \
                else [p for p in params if p.name not in kw_strategies][
                    :-len(arg_strategies)]
        else:
            params = [p for p in params if p.name not in kw_strategies]
        wrapper.__signature__ = inspect.Signature(params)
        wrapper.hypothesis = types.SimpleNamespace(inner_test=fn)
        return wrapper
    return deco


def build_module() -> types.ModuleType:
    """Assemble a module object mimicking ``hypothesis``'s public layout."""
    strategies = types.ModuleType("hypothesis.strategies")
    for name in ("integers", "floats", "booleans", "sampled_from", "just",
                 "lists", "tuples"):
        setattr(strategies, name, globals()[name])
    mod = types.ModuleType("hypothesis")
    mod.given = given
    mod.settings = settings
    mod.strategies = strategies
    mod.__version__ = "0.0-fallback"
    mod.HealthCheck = types.SimpleNamespace(too_slow=None, filter_too_much=None)
    return mod
