"""HERMES simulator: unit + integration + hypothesis property tests."""
import math

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.core import (SLO, SystemSpec, WorkloadConfig, build_system,
                        generate)
from repro.core.comm import Network
from repro.core.events import EventQueue
from repro.core.llm_scheduler import ClientPerf, LLMScheduler, SchedulerLimits
from repro.core.memory import (PagedKVAllocator, expected_retrieval_latency,
                               sample_retrieval_latency)
from repro.core.request import Request, Stage, LLM, regular_pipeline
from repro.core.workload import AZURE_CONV, arrival_times
from repro.perfmodel import analytical as ana
from repro.perfmodel.hardware import (CacheTierSpec, ClusterSpec, H100,
                                      LinkSpec)

MODEL = get_config("llama3_70b")
CLUSTER = ClusterSpec(H100, n_chips=2, tp=2)


# ---------------------------------------------------------------------------
# events
# ---------------------------------------------------------------------------

@given(st.lists(st.floats(min_value=0, max_value=1e6,
                          allow_nan=False), min_size=1, max_size=50))
def test_event_queue_monotone(times):
    q = EventQueue()
    for t in times:
        q.push(t, "x")
    popped = []
    while len(q):
        popped.append(q.pop().time)
    assert popped == sorted(popped)
    assert q.now == max(times)


# ---------------------------------------------------------------------------
# memory hierarchy Eq. 1
# ---------------------------------------------------------------------------

def _tier(hit, lat=1e-6, bw=1e9, cap=1e12):
    return CacheTierSpec("t", cap, lat, bw, hit)


def test_eq1_closed_form_two_levels():
    # every probed tier charges its lookup (hit or miss) — the walk the
    # Monte-Carlo sampler takes, so the two agree on the miss path
    t1, t2 = _tier(0.6, 1e-6, 1e9), _tier(0.9, 1e-5, 1e8)
    size, miss = 1e6, 0.5
    want = (1e-6 + 0.6 * size / 1e9
            + 0.4 * (1e-5 + 0.9 * size / 1e8 + 0.1 * miss))
    got = expected_retrieval_latency(size, [t1, t2], miss)
    assert math.isclose(got, want, rel_tol=1e-12)


@given(h1=st.floats(0.01, 0.99), h2=st.floats(0.01, 0.99),
       size=st.floats(1e3, 1e9))
@settings(max_examples=50, deadline=None)
def test_eq1_monotone_in_hit_rate(h1, h2, size):
    """Higher L1 hit rate can never increase expected latency (L1 faster)."""
    lo, hi = sorted([h1, h2])
    t2 = _tier(0.9, 1e-5, 1e8)
    miss = 1.0
    a = expected_retrieval_latency(size, [_tier(lo, 1e-7, 1e10), t2], miss)
    b = expected_retrieval_latency(size, [_tier(hi, 1e-7, 1e10), t2], miss)
    assert b <= a + 1e-12


@given(size=st.floats(1e3, 1e8))
@settings(max_examples=30, deadline=None)
def test_eq1_sample_mean_converges(size):
    rng = np.random.default_rng(0)
    tiers = [_tier(0.5, 1e-6, 1e9), _tier(0.8, 1e-5, 1e8)]
    samples = [sample_retrieval_latency(size, tiers, 0.3, rng)
               for _ in range(4000)]
    want = expected_retrieval_latency(size, tiers, 0.3)
    assert abs(np.mean(samples) - want) / want < 0.15


# ---------------------------------------------------------------------------
# paged KV allocator
# ---------------------------------------------------------------------------

@given(st.lists(st.integers(1, 100), min_size=1, max_size=40))
def test_allocator_never_exceeds_capacity_on_admit(sizes):
    kv = PagedKVAllocator(capacity_bytes=500.0, bytes_per_token=1.0,
                          block_tokens=4)
    admitted = []
    for rid, s in enumerate(sizes):
        if kv.allocate(rid, s):
            admitted.append((rid, s))
    assert kv.used_blocks <= kv.num_blocks
    assert kv.used_blocks == sum(kv.blocks_for_tokens(s) for _, s in admitted)
    kv.check_invariants()
    for rid, _ in admitted:
        kv.free(rid)
    assert kv.used == 0.0
    kv.check_invariants()


# ---------------------------------------------------------------------------
# network / comm
# ---------------------------------------------------------------------------

def test_network_contention_serializes():
    net = Network()
    net.add_link("l", LinkSpec("l", 1e9, 1e-3))
    net.connect("a", "b", ["l"])
    t1 = net.transfer("a", "b", 1e9, now=0.0)        # 1s + 1ms
    t2 = net.transfer("a", "b", 1e9, now=0.0)        # queued behind first
    assert math.isclose(t1, 1.001, rel_tol=1e-6)
    assert t2 >= t1 + 1.0


def test_layerwise_transfer_cheaper_than_full():
    net = Network()
    net.add_link("l", LinkSpec("l", 1e9, 1e-3))
    net.connect("a", "b", ["l"])
    t_full = net.transfer("a", "b", 8e8, now=0.0, granularity="full")
    net2 = Network()
    net2.add_link("l", LinkSpec("l", 1e9, 1e-3))
    net2.connect("a", "b", ["l"])
    t_layer = net2.transfer("a", "b", 8e8, now=0.0, granularity="layerwise",
                            n_layers=80)
    assert t_layer < t_full


# ---------------------------------------------------------------------------
# analytical perf model sanity
# ---------------------------------------------------------------------------

def test_prefill_compute_bound_decode_memory_bound():
    pre = ana.prefill_time(MODEL, CLUSTER, 2048, 1)
    dec = ana.decode_step_time(MODEL, CLUSTER, 8, 2048)
    assert pre.bound == "compute"
    assert dec.bound == "memory"
    assert pre.time > dec.time


def test_decode_time_increases_with_batch_and_context():
    t1 = ana.decode_step_time(MODEL, CLUSTER, 1, 1024).time
    t2 = ana.decode_step_time(MODEL, CLUSTER, 64, 1024).time
    t3 = ana.decode_step_time(MODEL, CLUSTER, 64, 8192).time
    assert t1 <= t2 <= t3


def test_regression_matches_analytical():
    from repro.perfmodel import regression as reg
    m = reg.fit_decode_model(MODEL, CLUSTER)
    for b, c in [(4, 1024), (32, 2048), (100, 5000)]:
        want = ana.decode_step_time(MODEL, CLUSTER, b, c).time
        got = float(m.predict([b], [c])[0])
        assert abs(got - want) / want < 0.25, (b, c, got, want)


# ---------------------------------------------------------------------------
# LLM scheduler semantics
# ---------------------------------------------------------------------------

def _mk_requests(n, in_tok=512, out_tok=8):
    return [Request(arrival=0.0, input_tokens=in_tok, output_tokens=out_tok,
                    stages=[Stage(LLM)]) for _ in range(n)]


@pytest.mark.parametrize("strategy", ["static", "continuous", "chunked",
                                      "mixed"])
def test_scheduler_completes_all_requests(strategy):
    sched = LLMScheduler(strategy, MODEL, CLUSTER,
                         limits=SchedulerLimits(max_batch=4, chunk_size=256))
    reqs = _mk_requests(9)
    for r in reqs:
        sched.add(r)
    now, finished, guard = 0.0, [], 0
    while sched.has_work() and guard < 10_000:
        step = sched.plan_step()
        assert step is not None, "work pending but no step planned"
        now += step.duration
        finished += sched.finish_step(step, now)
        guard += 1
    assert len(finished) == 9
    for r in finished:
        assert r.decoded_tokens == r.output_tokens
        assert r.first_token_time is not None
        assert r.token_times == sorted(r.token_times)


def test_scheduler_memory_conservation():
    sched = LLMScheduler("continuous", MODEL, CLUSTER,
                         limits=SchedulerLimits(max_batch=8))
    for r in _mk_requests(6, in_tok=1024, out_tok=5):
        sched.add(r)
    now = 0.0
    while sched.has_work():
        step = sched.plan_step()
        now += step.duration
        sched.finish_step(step, now)
        # free list + live block tables always partition the pool, and
        # every allocated block is attributable to a live request
        sched.kv.check_invariants()
        live = sum(len(t.blocks) for t in sched.kv.tables.values()
                   if t.on_device)
        assert sched.kv.used_blocks == live
    assert sched.kv.used == 0.0


def test_chunked_interleaves_prefill_and_decode():
    sched = LLMScheduler("chunked", MODEL, CLUSTER,
                         limits=SchedulerLimits(max_batch=8, chunk_size=128))
    for r in _mk_requests(4, in_tok=1000, out_tok=20):
        sched.add(r)
    kinds = set()
    now = 0.0
    for _ in range(200):
        if not sched.has_work():
            break
        step = sched.plan_step()
        if step.prefill and step.decode:
            kinds.add("both")
        now += step.duration
        sched.finish_step(step, now)
    assert "both" in kinds, "chunked batching never piggybacked decodes"


# ---------------------------------------------------------------------------
# end-to-end conservation + integration (hypothesis over workloads)
# ---------------------------------------------------------------------------

@given(n=st.integers(5, 25), rate=st.floats(0.5, 8.0),
       process=st.sampled_from(["poisson", "uniform", "bursty"]),
       strategy=st.sampled_from(["continuous", "chunked", "static", "mixed"]))
@settings(max_examples=12, deadline=None)
def test_request_conservation(n, rate, process, strategy):
    coord = build_system(SystemSpec(n_llm_clients=2, strategy=strategy))
    reqs = generate(WorkloadConfig(n_requests=n, rate=rate, process=process,
                                   seed=42))
    coord.submit(reqs)
    m = coord.run()
    assert len(m.serviced) == n              # injected == serviced
    for r in m.serviced:
        assert r.done
        assert r.e2e is not None and r.e2e > 0
        assert r.decoded_tokens == r.output_tokens
        # stage times are causally ordered
        ends = [s.end_time for s in r.stages]
        assert ends == sorted(ends)


def test_disaggregated_conservation_and_kv_transfer():
    coord = build_system(SystemSpec(strategy="disaggregated", n_prefill=2,
                                    n_decode=2))
    reqs = generate(WorkloadConfig(n_requests=20, rate=2.0, seed=7,
                                   disaggregated=True))
    coord.submit(reqs)
    m = coord.run()
    assert len(m.serviced) == 20
    assert m.comm_bytes > 0, "disaggregation must transfer KV caches"


def test_arrival_times_rate():
    rng = np.random.default_rng(0)
    t = arrival_times(rng, 5000, rate=10.0, process="poisson")
    assert abs(t[-1] - 500.0) / 500.0 < 0.1


def test_fault_recovery_no_request_lost():
    coord = build_system(SystemSpec(n_llm_clients=3))
    coord.schedule_failure("llm0", at=1.0, recover_at=30.0)
    coord.schedule_failure("llm1", at=5.0)
    reqs = generate(WorkloadConfig(n_requests=30, rate=3.0, seed=11))
    coord.submit(reqs)
    m = coord.run()
    assert len(m.serviced) == 30


def test_elastic_scale_out_helps():
    def run(scale_out: bool):
        coord = build_system(SystemSpec(n_llm_clients=1))
        if scale_out:
            from repro.core.client import LLMClient
            c0 = next(iter(coord.clients.values()))
            extra = LLMClient("llm_extra", c0.cluster, c0.model_cfg,
                              "continuous")
            coord.schedule_add_client(extra, at=1.0)
        reqs = generate(WorkloadConfig(n_requests=30, rate=4.0, seed=13))
        coord.submit(reqs)
        m = coord.run()
        assert len(m.serviced) == 30
        return np.mean(m.e2es)

    assert run(True) < run(False)


def test_straggler_rerouting():
    coord = build_system(SystemSpec(n_llm_clients=2,
                                    straggler_deadline=0.5,
                                    router_policy="round_robin"))
    # make llm0 a 100x straggler
    coord.clients["llm0"].slowdown = 100.0
    reqs = generate(WorkloadConfig(n_requests=20, rate=4.0, seed=17))
    coord.submit(reqs)
    m = coord.run()
    assert len(m.serviced) == 20
    assert sum(r.preemptions for r in m.serviced) > 0, \
        "straggler deadline never triggered a re-route"
