"""Paged KV allocator + preemption: property, regression and integration
tests for the tiered memory subsystem (paper §III-D / §III-E3)."""
import math

import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.core import SystemSpec, WorkloadConfig, build_system, generate
from repro.core.llm_scheduler import LLMScheduler, SchedulerLimits
from repro.core.memory import PagedKVAllocator
from repro.core.request import DECODE, LLM, Request, Stage
from repro.core.workload import TraceSpec
from repro.perfmodel.hardware import (CacheTierSpec, ClusterSpec, H100,
                                      TIER_HOST_DRAM)

MODEL = get_config("llama3_70b")
CLUSTER = ClusterSpec(H100, n_chips=2, tp=2)

TIGHT = dict(max_batch=8, kv_capacity_frac=0.0125)   # ~28 blocks of 32 tokens
PRESSURE_REQS = dict(in_tok=400, out_tok=120, n=6)

SMALL_TRACE = TraceSpec("t", input_mean=400, input_std=0.3, output_mean=96,
                        output_std=0.3, input_max=800, output_max=192)


def _mk_requests(n, in_tok, out_tok, stage=LLM):
    return [Request(arrival=0.0, input_tokens=in_tok, output_tokens=out_tok,
                    stages=[Stage(stage)]) for _ in range(n)]


def _drive(sched, reqs, guard=50_000):
    for r in reqs:
        sched.add(r)
    now, finished, steps = 0.0, [], 0
    while sched.has_work() and steps < guard:
        step = sched.plan_step()
        assert step is not None, "work pending but no step planned"
        now += step.duration
        finished += sched.finish_step(step, now)
        steps += 1
    return finished


# ---------------------------------------------------------------------------
# allocator properties (hypothesis)
# ---------------------------------------------------------------------------

@given(ops=st.lists(st.tuples(st.integers(0, 3), st.integers(0, 9),
                              st.integers(1, 120)),
                    min_size=1, max_size=120),
       block_tokens=st.sampled_from([1, 4, 16, 64]))
@settings(max_examples=40, deadline=None)
def test_allocator_random_ops_never_leak_or_double_allocate(ops, block_tokens):
    """Random allocate/append/free/swap sequences: blocks are never double
    allocated, used <= capacity, and freeing everything refills the pool."""
    kv = PagedKVAllocator(capacity_bytes=100.0 * block_tokens,
                          bytes_per_token=1.0, block_tokens=block_tokens,
                          swap_tiers=(TIER_HOST_DRAM,))
    live = set()
    swapped = set()
    for op, rid, amount in ops:
        if op == 0 and rid not in live:
            if kv.allocate(rid, amount):
                live.add(rid)
        elif op == 1 and rid in live and rid not in swapped:
            kv.append_tokens(rid, amount)
        elif op == 2 and rid in live:
            kv.free(rid)
            live.discard(rid)
            swapped.discard(rid)
        elif op == 3 and rid in live:
            if rid in swapped:
                if kv.swap_in(rid) is not None:
                    swapped.discard(rid)
            elif kv.swap_out(rid) is not None:
                swapped.add(rid)
        assert kv.used_blocks <= kv.num_blocks
        kv.check_invariants()           # free list + tables partition pool
    for rid in list(live):
        kv.free(rid)
    assert kv.used == 0.0
    assert kv.free_blocks == kv.num_blocks
    assert all(t.used == 0.0 for t in kv.tiers)
    kv.check_invariants()


def test_allocator_rejects_double_allocation():
    kv = PagedKVAllocator(100.0, 1.0, block_tokens=4)
    assert kv.allocate(1, 10)
    with pytest.raises(AssertionError):
        kv.allocate(1, 10)


def test_allocator_swap_roundtrip_prices_tier_bandwidth():
    tier = CacheTierSpec("t", 1e9, 1e-3, 1e6, 1.0)
    kv = PagedKVAllocator(1000.0, 1.0, block_tokens=10, swap_tiers=(tier,))
    assert kv.allocate(7, 100)
    nbytes, t = kv.swap_out(7)
    assert nbytes == 100.0 and math.isclose(t, 1e-3 + 100.0 / 1e6)
    assert kv.used == 0.0 and kv.tiers[0].used == 100.0
    nbytes2, t2 = kv.swap_in(7)
    assert nbytes2 == 100.0 and kv.tiers[0].used == 0.0
    assert kv.used == 100.0
    # allocator-side pricing must agree with the analytical model's Eq. 1 term
    from repro.perfmodel import analytical as ana
    cost = ana.kv_swap_cost(nbytes, tier, CLUSTER)
    assert math.isclose(cost.time, t)
    assert cost.energy > 0 and cost.bound == "network"


# ---------------------------------------------------------------------------
# scheduler drain/failure returns every page
# ---------------------------------------------------------------------------

@given(strategy=st.sampled_from(["continuous", "chunked", "static", "mixed"]),
       policy=st.sampled_from(["swap", "recompute"]),
       n_steps=st.integers(0, 40))
@settings(max_examples=20, deadline=None)
def test_drain_returns_every_page(strategy, policy, n_steps):
    sched = LLMScheduler(strategy, MODEL, CLUSTER,
                         limits=SchedulerLimits(preemption=policy, **TIGHT))
    for r in _mk_requests(6, 400, 60):
        sched.add(r)
    now = 0.0
    for _ in range(n_steps):
        if not sched.has_work():
            break
        step = sched.plan_step()
        if step is None:
            break
        now += step.duration
        sched.finish_step(step, now)
    sched.drain()                       # asserts check_invariants internally
    assert sched.kv.used == 0.0
    assert sched.kv.free_blocks == sched.kv.num_blocks
    assert all(t.used == 0.0 for t in sched.kv.tiers)


# ---------------------------------------------------------------------------
# regression: paging is behavior-neutral when capacity never binds
# ---------------------------------------------------------------------------

def _timeline(strategy, stage, **limit_kw):
    sched = LLMScheduler(strategy, MODEL, CLUSTER,
                         limits=SchedulerLimits(max_batch=4, chunk_size=256,
                                                **limit_kw))
    reqs = _mk_requests(9, 512, 8, stage=stage)
    finished = _drive(sched, reqs)
    assert len(finished) == 9
    assert sched.kv.used == 0.0
    # key by creation order (rids ascend as requests are constructed)
    return {i: list(r.token_times)
            for i, r in enumerate(sorted(finished, key=lambda r: r.rid))}


@pytest.mark.parametrize("strategy,stage", [("chunked", LLM),
                                            ("decode_only", DECODE)])
def test_unconstrained_timelines_invariant_to_paging_knobs(strategy, stage):
    """With capacity unconstrained, block size and preemption policy must not
    change a single token timestamp (pure-refactor regression vs the old
    byte-counter scheduler)."""
    base = _timeline(strategy, stage, kv_block_tokens=32, preemption="swap")
    for knobs in (dict(kv_block_tokens=1, preemption="swap"),
                  dict(kv_block_tokens=4096, preemption="swap"),
                  dict(kv_block_tokens=32, preemption="recompute")):
        got = _timeline(strategy, stage, **knobs)
        for k in base:
            assert got[k] == pytest.approx(base[k]), (knobs, k)


# ---------------------------------------------------------------------------
# preemption policies actually fire and conserve requests
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("policy", ["swap", "recompute"])
@pytest.mark.parametrize("strategy", ["continuous", "chunked", "static",
                                      "mixed", "decode_only"])
def test_preemption_under_pressure_completes_all(strategy, policy):
    sched = LLMScheduler(strategy, MODEL, CLUSTER,
                         limits=SchedulerLimits(preemption=policy, **TIGHT))
    stage = DECODE if strategy == "decode_only" else LLM
    reqs = _mk_requests(PRESSURE_REQS["n"], PRESSURE_REQS["in_tok"],
                        PRESSURE_REQS["out_tok"], stage=stage)
    finished = _drive(sched, reqs)
    assert len(finished) == PRESSURE_REQS["n"]
    s = sched.kv.stats()
    for r in finished:
        assert r.decoded_tokens == r.output_tokens
        assert r.token_times == sorted(r.token_times)
    sched.kv.check_invariants()
    assert sched.kv.used == 0.0
    if strategy == "continuous":   # the canonical pressure case must fault
        assert s["page_faults"] > 0, "capacity never bound: test is vacuous"
        if policy == "swap":
            assert s["evictions"] > 0 and s["swap_ins"] > 0
            assert s["swap_bytes_out"] > 0
        else:
            assert s["recompute_drops"] > 0


def test_decode_only_recompute_charges_kv_refetch():
    """A decode replica cannot re-run prefill: recompute-preempted KV must
    be re-fetched, showing up as swap traffic on re-admission."""
    sched = LLMScheduler("decode_only", MODEL, CLUSTER,
                         limits=SchedulerLimits(preemption="recompute",
                                                **TIGHT))
    for r in _mk_requests(PRESSURE_REQS["n"], PRESSURE_REQS["in_tok"],
                          PRESSURE_REQS["out_tok"], stage=DECODE):
        sched.add(r)
    now, refetch_bytes, finished = 0.0, 0.0, []
    while sched.has_work():
        step = sched.plan_step()
        assert step is not None
        now += step.duration
        refetch_bytes += step.swap_bytes
        finished += sched.finish_step(step, now)
    assert len(finished) == PRESSURE_REQS["n"]
    assert sched.kv.stats()["recompute_drops"] > 0
    assert refetch_bytes > 0, "dropped decode KV was regenerated for free"


def test_swap_time_charged_to_steps():
    sched = LLMScheduler("continuous", MODEL, CLUSTER,
                         limits=SchedulerLimits(preemption="swap", **TIGHT))
    for r in _mk_requests(PRESSURE_REQS["n"], PRESSURE_REQS["in_tok"],
                          PRESSURE_REQS["out_tok"]):
        sched.add(r)
    now, swap_time, swap_bytes = 0.0, 0.0, 0.0
    while sched.has_work():
        step = sched.plan_step()
        now += step.duration
        swap_time += step.swap_time
        swap_bytes += step.swap_bytes
        sched.finish_step(step, now)
    assert swap_bytes > 0 and swap_time > 0
    # the analytical stall must match the Eq. 1 tier term for the traffic
    assert swap_time >= swap_bytes / sched.kv.tiers[0].spec.bandwidth


# ---------------------------------------------------------------------------
# end-to-end: coordinator counters + routing on kv pressure
# ---------------------------------------------------------------------------

def test_end_to_end_summary_exposes_paging_counters():
    limits = SchedulerLimits(max_batch=16, kv_capacity_frac=0.02,
                             preemption="swap")
    spec = SystemSpec(n_llm_clients=2, limits=limits, with_pre_post=False,
                      router_policy="load_based", router_metric="kv_pressure")
    coord = build_system(spec)
    reqs = generate(WorkloadConfig(trace=SMALL_TRACE, n_requests=25, rate=4.0,
                                   seed=3, postprocess=False))
    coord.submit(reqs)
    m = coord.run()
    assert len(m.serviced) == 25          # preemption loses no requests
    s = m.summary()
    assert s["kv_page_faults"] > 0
    assert s["kv_evictions"] > 0
    assert s["swap_bytes"] > 0            # coordinator-observed wire traffic
    for c in coord.clients.values():
        st_ = c.kv_stats()
        if st_:
            assert st_["used_blocks"] == 0


def test_remove_waiting_resets_partial_prefill_progress():
    """Straggler rescue of a half-prefilled chunked request must reset its
    progress: its KV dies at the old client, so the new client re-prefills
    from scratch (otherwise it ends up in waiting AND running at once)."""
    sched = LLMScheduler("chunked", MODEL, CLUSTER,
                         limits=SchedulerLimits(max_batch=4, chunk_size=256))
    (r,) = _mk_requests(1, 512, 8)
    sched.add(r)
    step = sched.plan_step()
    sched.finish_step(step, 0.1)          # one 256-token chunk done
    assert r.prefilled_tokens == 256 and r in sched.waiting
    assert sched.remove_waiting(r)
    assert r.prefilled_tokens == 0
    assert sched.kv.used == 0.0
    # fresh scheduler (the rescue destination) completes it normally
    sched2 = LLMScheduler("chunked", MODEL, CLUSTER,
                          limits=SchedulerLimits(max_batch=4, chunk_size=256))
    finished = _drive(sched2, [r])
    assert len(finished) == 1 and r.decoded_tokens == r.output_tokens
    assert r not in sched2.waiting and r not in sched2.running


def test_removed_client_kv_counters_survive_in_summary():
    limits = SchedulerLimits(max_batch=16, kv_capacity_frac=0.02,
                             preemption="swap")
    spec = SystemSpec(n_llm_clients=2, limits=limits, with_pre_post=False)
    coord = build_system(spec)
    reqs = generate(WorkloadConfig(trace=SMALL_TRACE, n_requests=25, rate=6.0,
                                   seed=3, postprocess=False))
    coord.submit(reqs)
    coord.schedule_remove_client("llm1", at=2.0)   # mid-run scale-down
    m = coord.run()
    assert len(m.serviced) == 25
    total_faults = m.kv["page_faults"]
    assert total_faults > 0
    # idempotent: a second collect over the survivors must not change totals
    m.collect_kv([c for c in coord.clients.values()])
    assert m.kv["page_faults"] == total_faults


def test_client_kv_pressure_metric_counts_queue_demand():
    limits = SchedulerLimits(kv_capacity_frac=0.02)
    spec = SystemSpec(n_llm_clients=1, limits=limits, with_pre_post=False)
    coord = build_system(spec)
    (client,) = [c for c in coord.clients.values()]
    assert client.load("kv_pressure") == 0.0
    for r in _mk_requests(4, 600, 8):
        client.scheduler.waiting.append(r)
    assert client.load("kv_pressure") > 0.0
