"""Multi-device distribution tests (8 fake host devices via subprocess, since
device count locks at first jax init)."""
import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# Every snippet builds its mesh through compat_make_mesh(..., shrink=True):
# works across jax versions (no axis_types on 0.4.x) and shrinks the mesh
# instead of tripping the "mesh requires N devices" assertion when the
# subprocess ends up with fewer devices than requested (single-host CPU).
_PRELUDE = """
    import jax
    from repro.launch.mesh import compat_make_mesh, mesh_context
"""


def _run(code: str, devices: int = 8, timeout: int = 560) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env["JAX_PLATFORMS"] = "cpu"
    p = subprocess.run([sys.executable, "-c",
                        textwrap.dedent(_PRELUDE) + textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=timeout,
                       env=env)
    assert p.returncode == 0, f"stdout={p.stdout}\nstderr={p.stderr}"
    return p.stdout


def test_moe_ep_matches_single_device():
    """Expert-parallel shard_map MoE == single-device MoE numerics."""
    out = _run("""
        import jax.numpy as jnp, dataclasses, numpy as np
        from repro.configs import get_reduced_config
        from repro.models import moe as moe_mod
        from repro.models.layers import Initializer
        mesh = compat_make_mesh((2, 4), ("data", "model"), shrink=True)
        key = jax.random.PRNGKey(0)
        cfg = get_reduced_config("deepseek_v2_lite_16b").replace(
            param_dtype="float32", compute_dtype="float32")
        cfg = cfg.replace(moe=dataclasses.replace(cfg.moe, capacity_slack=8.0))
        p = moe_mod.init_moe(Initializer(cfg, key), "moe", cfg)
        leaves, td = jax.tree.flatten(p)
        ks = jax.random.split(key, len(leaves))
        p = jax.tree.unflatten(td, [l + jax.random.normal(k, l.shape) * 0.1
                                    for l, k in zip(leaves, ks)])
        x = jax.random.normal(jax.random.fold_in(key, 3), (8, 16, cfg.d_model))
        y1, _ = moe_mod.apply_moe(p, x, cfg, mesh=None)
        y2, _ = jax.jit(lambda p, x: moe_mod.apply_moe(p, x, cfg, mesh=mesh))(p, x)
        err = float(jnp.max(jnp.abs(y1 - y2)))
        assert err < 2e-3, err
        print("EP_OK", err)
    """)
    assert "EP_OK" in out


def test_sharded_train_step_runs_and_matches():
    """pjit'd train step on a (2,2,2) pod mesh == single-device step."""
    out = _run("""
        import jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import get_reduced_config, SHAPES_BY_NAME
        from repro.models import steps, transformer as tf
        from repro.models.sharding import ShardingRules, tree_specs
        cfg = get_reduced_config("internlm2_20b").replace(
            param_dtype="float32", compute_dtype="float32", remat="none")
        mesh = compat_make_mesh((2, 2, 2), ("pod", "data", "model"),
                                shrink=True)
        rules = ShardingRules(mesh)
        key = jax.random.PRNGKey(0)
        state = steps.init_train_state(cfg, key)
        batch = {"tokens": jax.random.randint(key, (8, 32), 0, cfg.vocab_size),
                 "labels": jax.random.randint(jax.random.fold_in(key, 1),
                                              (8, 32), 0, cfg.vocab_size)}
        _, m1 = steps.train_step(state, batch, cfg)
        with mesh_context(mesh):
            fn = jax.jit(lambda s, b: steps.train_step(s, b, cfg, rules=rules,
                                                       mesh=mesh))
            _, m2 = fn(state, batch)
        d = abs(float(m1["loss"]) - float(m2["loss"]))
        assert d < 1e-3, (float(m1["loss"]), float(m2["loss"]))
        print("TRAIN_OK", d)
    """)
    assert "TRAIN_OK" in out


def test_dryrun_single_cell_on_small_mesh():
    """The dry-run machinery end-to-end on an 8-device (2,2,2) mesh."""
    out = _run("""
        from repro.launch import dryrun
        mesh = compat_make_mesh((2, 2, 2), ("pod", "data", "model"),
                                shrink=True)
        from repro.configs import get_reduced_config
        cfg = get_reduced_config("internlm2_20b")
        res = dryrun.run_cell("internlm2_20b", "train_4k", mesh, True,
                              verbose=False, cfg_override=cfg.replace(
                                  num_layers=4))
        assert res["flops_per_dev"] > 0
        assert res["compute_term_s"] > 0
        print("DRYRUN_OK", res["dominant"])
    """, devices=8)
    assert "DRYRUN_OK" in out


def test_mesh_shrinks_to_fit_device_count():
    """shrink=True never requests more devices than exist (1-device run)."""
    out = _run("""
        mesh = compat_make_mesh((2, 4), ("data", "model"), shrink=True)
        assert mesh.devices.size <= jax.device_count(), mesh.shape
        print("SHRINK_OK", dict(mesh.shape))
    """, devices=1)
    assert "SHRINK_OK" in out


def test_collective_bytes_parser():
    from repro.launch.dryrun import collective_bytes, wire_bytes
    hlo = """
      %all-reduce.1 = f32[8,128]{1,0} all-reduce(f32[8,128]{1,0} %x)
      %ag = bf16[16,256]{1,0} all-gather(bf16[2,256]{1,0} %y), dimensions={0}
      %cp = f32[4]{0} collective-permute(f32[4]{0} %z)
      %notacollective = f32[8]{0} add(f32[8]{0} %a, f32[8]{0} %b)
    """
    cb = collective_bytes(hlo)
    assert cb["all-reduce"] == 8 * 128 * 4
    assert cb["all-gather"] == 16 * 256 * 2
    assert cb["collective-permute"] == 16
    assert wire_bytes(cb) == 2 * 8 * 128 * 4 + 16 * 256 * 2 + 16
