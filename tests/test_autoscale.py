"""Closed-loop autoscaler (src/repro/core/autoscaler.py) and the elastic
paths it rides: windowed metrics vs brute-force recompute, multi-phase rate
warps, slo_tier admission packing, policy units, a golden 2->4->2 threshold
scenario, CLIENT_REMOVE mid-prefix-migration regressions, and hypothesis
property suites over random traffic phases x policies x tiers (no lost or
duplicated requests, fleet bounds, cooldown no-flap, fast-forward on/off
bit-identical summaries and action sequences)."""
import math

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import SLO, SystemSpec, WorkloadConfig, build_system, generate
from repro.core.autoscaler import (Autoscaler, AutoscalerConfig,
                                   ClientTemplate, Observation,
                                   TargetTrackingPolicy,
                                   ThresholdHysteresisPolicy, make_policy)
from repro.core.client import LLMClient
from repro.core.llm_scheduler import TIER_PRIORITY, SchedulerLimits, WaitQueue
from repro.core.metrics import MetricsCollector, percentile
from repro.core.request import LLM, Request, regular_pipeline
from repro.core.workload import synthetic_trace, warp_times

TIER_SLOS = {"interactive": SLO(),
             "batch": SLO(ttft_base=2.0, tpot_base=0.100)}


# ---------------------------------------------------------------------------
# multi-phase rate schedules (WorkloadConfig.rate_phases / warp_times)
# ---------------------------------------------------------------------------

def test_warp_times_identity_and_monotonic():
    t = np.array([0.1, 0.5, 0.9, 1.5, 3.0, 7.0])
    out = warp_times(t, ((1.0, 4.0), (2.0, 0.5)))
    # identity before the first breakpoint
    assert np.allclose(out[:3], t[:3])
    # strictly increasing input stays strictly increasing
    assert np.all(np.diff(out) > 0)
    # empty schedule is the identity
    assert np.array_equal(warp_times(t, ()), t)


def test_warp_times_matches_single_ramp():
    # one phase ((t0, m),) is exactly the legacy rate_ramp compression
    t = np.array([0.2, 0.8, 1.4, 2.6, 5.0])
    t0, m = 1.0, 3.0
    out = warp_times(t, ((t0, m),))
    expect = np.where(t > t0, t0 + (t - t0) / m, t)
    assert np.allclose(out, expect)


def test_warp_times_validation_and_exclusivity():
    t = np.array([1.0, 2.0])
    with pytest.raises(ValueError):
        warp_times(t, ((2.0, 1.5), (1.0, 2.0)))     # non-increasing breaks
    with pytest.raises(ValueError):
        warp_times(t, ((1.0, 0.0),))                # non-positive multiplier
    with pytest.raises(ValueError):
        generate(WorkloadConfig(n_requests=4, rate_ramp_at=1.0, rate_ramp=2.0,
                                rate_phases=((1.0, 2.0),)))


def test_rate_phases_preserve_request_population():
    base = generate(WorkloadConfig(n_requests=40, rate=5.0, seed=3,
                                   postprocess=False))
    warped = generate(WorkloadConfig(n_requests=40, rate=5.0, seed=3,
                                     postprocess=False,
                                     rate_phases=((0.5, 4.0), (1.5, 0.25))))
    # the warp is a pure time change: same token population, same order
    assert ([(r.input_tokens, r.output_tokens) for r in base]
            == [(r.input_tokens, r.output_tokens) for r in warped])
    ta = [r.arrival for r in base]
    tb = [r.arrival for r in warped]
    assert tb == sorted(tb)
    # arrivals inside the 4x phase land earlier, tail of the 0.25x phase later
    assert any(b < a for a, b in zip(ta, tb))
    assert any(b > a for a, b in zip(ta, tb))


# ---------------------------------------------------------------------------
# windowed metrics views vs brute-force recompute
# ---------------------------------------------------------------------------

def _fake_req(ttft, tpot_span, n_tokens, tier="default", arrival=0.0):
    r = Request(arrival=arrival, input_tokens=8, output_tokens=n_tokens,
                stages=regular_pipeline(False, False), tier=tier)
    r.first_token_time = arrival + ttft
    r.decoded_tokens = n_tokens
    r.last_token_time = r.first_token_time + tpot_span
    r.completion_time = r.last_token_time
    return r


def test_window_view_inclusive_bounds_and_incremental_cache():
    m = MetricsCollector()
    for t in (1.0, 2.0, 3.0):
        m.complete(_fake_req(t, 0.0, 4))
    assert [r.completion_time for r in m.window_view(1.0, 2.0)] == [1.0, 2.0]
    assert len(m.window_view(0.0)) == 3           # open-ended
    assert m.window_view(3.5) == []
    # cache extends incrementally as later completions land
    m.complete(_fake_req(4.0, 0.0, 4))
    assert [r.completion_time for r in m.window_view(2.5)] == [3.0, 4.0]


def _brute_force_stats(reqs, since, until, slos):
    sel = [r for r in reqs
           if since <= r.completion_time
           and (until is None or r.completion_time <= until)]
    ttfts = [r.ttft for r in sel if r.ttft is not None]
    tpots = [r.tpot for r in sel if r.tpot is not None and r.decoded_tokens > 1]
    end = until if until is not None else max(
        (r.completion_time for r in sel), default=since)
    span = max(end - since, 1e-9)
    ok, n_tier, good = {}, {}, {}
    for r in sel:
        slo = slos if isinstance(slos, SLO) else \
            slos.get(r.tier, slos.get("default"))
        if slo is None:
            continue
        n_tier[r.tier] = n_tier.get(r.tier, 0) + 1
        hit = ((r.ttft or 1e9) <= slo.ttft_base * slo.ttft_mult[50]
               and (r.tpot or 0.0) <= slo.tpot_base * slo.tpot_mult[50])
        ok[r.tier] = ok.get(r.tier, 0) + hit
        good[r.tier] = good.get(r.tier, 0) + (r.decoded_tokens if hit else 0)
    return {
        "n": len(sel),
        "tokens": sum(r.decoded_tokens for r in sel),
        "ttft_p50": percentile(ttfts, 50), "ttft_p90": percentile(ttfts, 90),
        "tpot_p50": percentile(tpots, 50), "tpot_p90": percentile(tpots, 90),
        "slo_frac": (sum(ok.values()) / sum(n_tier.values())
                     if n_tier else None),
        "slo_frac_by_tier": {t: ok[t] / n_tier[t] for t in n_tier},
        "goodput_by_tier": {t: good[t] / span for t in good},
        "goodput_tok_s": sum(good.values()) / span,
    }


_req_params = st.tuples(
    st.floats(min_value=0.0, max_value=4.0),      # ttft
    st.floats(min_value=0.0, max_value=2.0),      # decode span
    st.integers(min_value=1, max_value=64),       # tokens
    st.sampled_from(("interactive", "batch", "default")))


@settings(max_examples=40, deadline=None)
@given(reqs=st.lists(_req_params, min_size=0, max_size=20),
       since=st.floats(min_value=-1.0, max_value=7.0),
       width=st.floats(min_value=0.0, max_value=7.0),
       open_ended=st.booleans(),
       tiered=st.booleans())
def test_window_stats_matches_bruteforce(reqs, since, width, open_ended,
                                         tiered):
    m = MetricsCollector()
    made = sorted((_fake_req(*p) for p in reqs),
                  key=lambda r: r.completion_time)
    for r in made:                 # serviced is completion-ordered by contract
        m.complete(r)
    until = None if open_ended else since + width
    slos = TIER_SLOS if tiered else SLO()
    got = m.window_stats(since, until, slos=slos)
    want = _brute_force_stats(made, since, until, slos)
    for k, v in want.items():
        g = got[k]
        if isinstance(v, dict):
            assert set(g) == set(v)
            for t in v:
                assert g[t] == pytest.approx(v[t])
        elif v is None or (isinstance(v, float) and math.isnan(v)):
            assert g is None or (isinstance(g, float) and math.isnan(g))
        else:
            assert g == pytest.approx(v)


# ---------------------------------------------------------------------------
# slo_tier admission packing
# ---------------------------------------------------------------------------

def _tier_req(tier, tokens=8):
    return Request(arrival=0.0, input_tokens=tokens, output_tokens=tokens,
                   stages=regular_pipeline(False, False), tier=tier)


def test_slo_tier_packing_admission_order():
    q = WaitQueue("slo_tier")
    b0, d0, i0, i1, b1 = (_tier_req("batch"), _tier_req("default"),
                          _tier_req("interactive"), _tier_req("interactive"),
                          _tier_req("batch"))
    for r in (b0, d0, i0, i1, b1):
        q.push(r)
    # interactive admits first, FCFS inside a tier, unknown tiers rank default
    assert [q.popleft() for _ in range(5)] == [i0, i1, d0, b0, b1]
    assert TIER_PRIORITY["interactive"] < TIER_PRIORITY["default"] \
        < TIER_PRIORITY["batch"]


def test_slo_tier_preemption_victims_and_requeue():
    q = WaitQueue("slo_tier")
    i0, b0 = _tier_req("interactive"), _tier_req("batch")
    q.push(i0)
    q.push(b0)
    # victim scan (reversed) offers the batch request first
    assert next(iter(reversed(q))) is b0
    # a preempted victim (admitted, then pushed back) rejoins its tier's
    # tail, not the global head
    assert q.popleft() is i0
    i1 = _tier_req("interactive")
    q.push(i1)
    q.requeue(i0)
    assert q.popleft() is i1 and q.popleft() is i0 and q.popleft() is b0


def test_slo_tier_end_to_end_favors_interactive():
    spec = SystemSpec(n_llm_clients=1, with_pre_post=False, packing="slo_tier",
                      limits=SchedulerLimits(max_batch=4))
    coord = build_system(spec)
    trace = synthetic_trace(input_mean=512, input_std=0.3, output_mean=32,
                            output_std=0.2, name="t")
    reqs = generate(WorkloadConfig(trace=trace, rate=60.0, n_requests=40,
                                   postprocess=False, seed=9))
    for i, r in enumerate(reqs):
        r.tier = "interactive" if i % 2 else "batch"
    coord.submit(reqs)
    m = coord.run()
    ttft = {"interactive": [], "batch": []}
    for r in m.serviced:
        ttft[r.tier].append(r.ttft)
    assert len(m.serviced) == 40
    # overload backlog: the interactive tier jumps the queue
    assert (percentile(ttft["interactive"], 50)
            < percentile(ttft["batch"], 50))


# ---------------------------------------------------------------------------
# policy units (pure Observation -> desired size)
# ---------------------------------------------------------------------------

def _obs(n=2, queue=0.0, slo=None):
    return Observation(now=1.0, n_live=n, queue_depth=queue * n,
                       queue_per_client=queue, tokens_remaining=0.0,
                       window_n=0 if slo is None else 10, slo_frac=slo,
                       slo_frac_by_tier={}, goodput_tok_s=0.0,
                       goodput_by_tier={}, ttft_p90=float("nan"))


def test_threshold_policy_hysteresis_band():
    p = ThresholdHysteresisPolicy(queue_hi=8.0, queue_lo=1.0,
                                  slo_lo=0.7, slo_hi=0.9, step_out=2)
    assert p.desired(_obs(n=2, queue=10.0, slo=0.95)) == 4   # queue trips
    assert p.desired(_obs(n=2, queue=2.0, slo=0.5)) == 4     # SLO trips
    assert p.desired(_obs(n=2, queue=4.0, slo=0.8)) == 2     # dead band holds
    assert p.desired(_obs(n=2, queue=0.5, slo=0.8)) == 2     # slo below hi
    assert p.desired(_obs(n=2, queue=0.5, slo=0.95)) == 1    # both clear
    assert p.desired(_obs(n=2, queue=0.5, slo=None)) == 1    # idle fleet


def test_target_tracking_policy_proportional():
    p = TargetTrackingPolicy(target_queue=4.0, slo_floor=0.8,
                             scale_in_ratio=0.5, max_step=4)
    assert p.desired(_obs(n=2, queue=8.0, slo=0.9)) == 4     # ceil(2 * 2)
    assert p.desired(_obs(n=2, queue=40.0, slo=0.9)) == 6    # max_step clamp
    assert p.desired(_obs(n=2, queue=3.0, slo=0.9)) == 2     # tolerance band
    assert p.desired(_obs(n=2, queue=1.9, slo=0.9)) == 1     # under ratio
    assert p.desired(_obs(n=2, queue=3.0, slo=0.5)) == 3     # SLO floor
    with pytest.raises(ValueError):
        make_policy("nope")


# ---------------------------------------------------------------------------
# controller mechanics
# ---------------------------------------------------------------------------

def _llm_template(coord) -> ClientTemplate:
    base = next(c for c in coord.clients.values() if c.stages == (LLM,))
    return ClientTemplate.from_client(base)


def test_attach_idle_fleet_terminates_and_integrates_cost():
    coord = build_system(SystemSpec(n_llm_clients=2, with_pre_post=False))
    scaler = Autoscaler(_llm_template(coord), policy=make_policy("threshold"),
                        cfg=AutoscalerConfig(interval=0.25, min_clients=2))
    coord.attach_autoscaler(scaler, start_at=0.25)
    coord.run()
    # the lone pending check fires once and does not re-arm an empty queue
    assert scaler.checks == 1
    assert scaler.fleet_trace[0] == (0.0, 2)
    assert scaler.fleet_trace[1][0] == 0.25
    assert scaler.client_seconds == pytest.approx(2 * 0.25)


def test_client_seconds_tracks_steady_fleet():
    class Hold(ThresholdHysteresisPolicy):
        def desired(self, obs):
            return obs.n_live
    coord = build_system(SystemSpec(n_llm_clients=2, with_pre_post=False))
    scaler = Autoscaler(_llm_template(coord), policy=Hold(),
                        cfg=AutoscalerConfig(interval=0.25))
    coord.attach_autoscaler(scaler)
    coord.submit(generate(WorkloadConfig(rate=10.0, n_requests=10,
                                         postprocess=False, seed=2)))
    coord.run()
    assert scaler.actions == []
    assert scaler.client_seconds == pytest.approx(2 * coord.queue.now)


def test_warm_pool_name_recycling():
    coord = build_system(SystemSpec(n_llm_clients=1, with_pre_post=False))
    scaler = Autoscaler(_llm_template(coord),
                        cfg=AutoscalerConfig(min_clients=1, max_clients=4))
    scaler.bind(coord, 0.0)
    scaler._scale_out(coord, 0.0, 2)               # scale0, scale1
    scaler._scale_in(coord, 1.0)                   # ties: llm0 goes first
    scaler._scale_in(coord, 2.0)                   # then scale0, recycled
    scaler._scale_out(coord, 3.0, 1)               # reuses the freed name
    assert [a[1:] for a in scaler.actions] == [
        ("add", "scale0"), ("add", "scale1"), ("remove", "llm0"),
        ("remove", "scale0"), ("add", "scale0")]
    assert set(coord.clients) == {"scale0", "scale1"}


# ---------------------------------------------------------------------------
# golden scripted scenario: threshold policy scales 2 -> 4 -> 2
# ---------------------------------------------------------------------------

class _AuditScaler(Autoscaler):
    """Snapshots per-client load at each scale-in so the test can verify the
    victim really was the most-drained replica."""

    def __init__(self, *a, **kw):
        super().__init__(*a, **kw)
        self.scale_in_loads = []

    def _scale_in(self, coord, now):
        live = self._live(coord)
        if len(live) > self.cfg.min_clients:
            self.scale_in_loads.append(
                (now, {c.name: c.load(self.cfg.scale_in_metric, now)
                       for c in live}))
        super()._scale_in(coord, now)


def _golden_run():
    spec = SystemSpec(n_llm_clients=2, with_pre_post=False,
                      limits=SchedulerLimits(max_batch=4))
    coord = build_system(spec)
    # queue-band-only threshold policy (slo thresholds at 0 disable the SLO
    # trigger): the golden schedule is a pure function of backlog depth
    scaler = _AuditScaler(
        _llm_template(coord),
        policy=ThresholdHysteresisPolicy(queue_hi=3.0, queue_lo=1.0,
                                         slo_lo=0.0, slo_hi=0.0, step_out=2),
        cfg=AutoscalerConfig(interval=0.5, window=1.0, min_clients=2,
                             max_clients=4, cooldown_out=0.5, cooldown_in=1.0))
    coord.attach_autoscaler(scaler, start_at=0.5)
    burst = generate(WorkloadConfig(
        trace=synthetic_trace(input_mean=384, input_std=0.3, output_mean=48,
                              output_std=0.2, name="burst"),
        rate=400.0, n_requests=30, process="uniform", postprocess=False,
        seed=21))
    # a light trickle after the burst keeps the event loop (and its checks)
    # alive while the backlog drains, so the scale-in legs can fire
    tail = generate(WorkloadConfig(
        trace=synthetic_trace(input_mean=96, input_std=0.2, output_mean=8,
                              output_std=0.2, name="tail"),
        rate=1.5, n_requests=12, process="uniform", postprocess=False,
        seed=22))
    for r in tail:
        r.arrival += 3.0
    coord.submit(burst + tail)
    coord.run()
    return coord, scaler


def test_golden_threshold_scales_2_4_2():
    coord, scaler = _golden_run()
    assert len(coord.metrics.serviced) == 42
    # the burst lands at t=0..0.075; the t=0.5 check sees queue/client > 3
    # and jumps 2 -> 4 in one step_out=2 action pair; the backlog drains
    # under the low band by t=7.0 and two cooldown_in-spaced removes bring
    # the fleet back to the floor (ties pick lexicographically smallest)
    assert scaler.actions == [
        (0.5, "add", "scale0"), (0.5, "add", "scale1"),
        (7.0, "remove", "llm0"), (8.0, "remove", "llm1")]
    sizes = [n for _, n in scaler.fleet_trace]
    assert max(sizes) == 4 and sizes[0] == 2 and sizes[-1] == 2
    assert set(coord.clients) == {"scale0", "scale1"}


def test_golden_scale_in_picks_least_loaded():
    _, scaler = _golden_run()
    removed = [name for _, kind, name in scaler.actions if kind == "remove"]
    assert len(removed) == len(scaler.scale_in_loads)
    for victim, (_, loads) in zip(removed, scaler.scale_in_loads):
        assert loads[victim] == min(loads.values())


# ---------------------------------------------------------------------------
# CLIENT_REMOVE mid-prefix-migration (donor and recipient)
# ---------------------------------------------------------------------------

def _migration_system():
    spec = SystemSpec(n_llm_clients=2, with_pre_post=False,
                      prefix_migration=True, router_policy="load_based",
                      router_metric="queue")
    coord = build_system(spec)
    # populate radix caches with shared prefixes so warming has chains to ship
    coord.submit(generate(WorkloadConfig(
        rate=30.0, n_requests=30, postprocess=False, seed=6,
        shared_prefix_pool=3, shared_prefix_tokens=512)))
    coord.run()
    donor = max((c for c in coord.clients.values() if c.stages == (LLM,)),
                key=lambda c: len(c.scheduler.kv.radix.by_block))
    return coord, donor


def _clone(base: LLMClient, name: str) -> LLMClient:
    return LLMClient(name, base.cluster, base.model_cfg, base.strategy,
                     base.scheduler.limits, perf=base.scheduler.perf,
                     group=base.group)


def test_remove_donor_mid_migration_releases_export_pins():
    coord, donor = _migration_system()
    t = coord.queue.now + 1.0
    coord.schedule_add_client(_clone(donor, "fresh"), t)
    # the warm-push PREFIX_MIGRATE pins the donor's chains at t; remove the
    # donor before any MIGRATE_DONE can land
    coord.schedule_remove_client(donor.name, t + 1e-6)
    coord.run()
    # the removed donor left no pinned exports behind (they would sit in the
    # retired allocator forever: MIGRATE_DONE's release path can't find it)
    assert donor.scheduler.kv._exports == {}
    assert donor.name not in coord.clients
    assert coord._migrations_inflight == set()
    coord.clients["fresh"].scheduler.kv.check_invariants()


def test_remove_recipient_mid_migration_allows_rewarm():
    coord, donor = _migration_system()
    base_migrations = coord.metrics.kv_migrations
    t = coord.queue.now + 1.0
    coord.schedule_add_client(_clone(donor, "fresh"), t)
    # recipient disappears before its warming transfers land ...
    coord.schedule_remove_client("fresh", t + 1e-6)
    # ... and a same-named warm-pool replica joins later: the stale inflight
    # dedup keys must not refuse warming the new one
    coord.schedule_add_client(_clone(donor, "fresh"), t + 2.0)
    coord.run()
    assert coord._migrations_inflight == set()
    # in-flight MIGRATE_DONE against the removed replica was a no-op, and the
    # re-added replica actually got warmed
    assert coord.metrics.kv_migrations > base_migrations
    fresh_kv = coord.clients["fresh"].scheduler.kv
    assert len(fresh_kv.radix.by_block) > 0
    fresh_kv.check_invariants()
    donor.scheduler.kv.check_invariants()


# ---------------------------------------------------------------------------
# hypothesis property suites: random phases x policies x tiers
# ---------------------------------------------------------------------------

_phase_schedules = st.lists(
    st.tuples(st.floats(min_value=0.1, max_value=1.0),    # breakpoint gap
              st.floats(min_value=0.25, max_value=4.0)),  # rate multiplier
    min_size=0, max_size=3).map(
        lambda gaps: tuple(
            (round(sum(g for g, _ in gaps[:i + 1]), 3), m)
            for i, (_, m) in enumerate(gaps)) or None)

_ACFG = AutoscalerConfig(interval=0.2, window=0.6, min_clients=1,
                         max_clients=4, cooldown_out=0.4, cooldown_in=0.8)


def _autoscaled_run(policy, seed, phases, tiered, fast_forward=True):
    spec = SystemSpec(n_llm_clients=2, with_pre_post=False,
                      limits=SchedulerLimits(max_batch=8,
                                             fast_forward=fast_forward))
    coord = build_system(spec)
    trace = synthetic_trace(input_mean=192, input_std=0.4, output_mean=24,
                            output_std=0.2, name="t")
    reqs = generate(WorkloadConfig(trace=trace, rate=30.0, n_requests=24,
                                   process="poisson", postprocess=False,
                                   seed=seed, rate_phases=phases))
    if tiered:
        for i, r in enumerate(reqs):
            r.tier = "interactive" if i % 2 else "batch"
    scaler = Autoscaler(_llm_template(coord), policy=make_policy(policy),
                        cfg=_ACFG, slos=TIER_SLOS if tiered else None)
    coord.attach_autoscaler(scaler)
    coord.submit(reqs)
    coord.run()
    return coord, scaler, reqs


@settings(max_examples=15, deadline=None)
@given(policy=st.sampled_from(("threshold", "target_tracking")),
       seed=st.integers(min_value=0, max_value=10),
       phases=_phase_schedules,
       tiered=st.booleans())
def test_autoscale_invariants_random(policy, seed, phases, tiered):
    coord, scaler, reqs = _autoscaled_run(policy, seed, phases, tiered)
    # no request lost, none duplicated, across every scale event
    assert sorted(r.rid for r in coord.metrics.serviced) \
        == sorted(r.rid for r in reqs)
    assert len(coord.metrics.dropped) == 0
    # the live fleet never leaves [min_clients, max_clients]
    assert all(_ACFG.min_clients <= n <= _ACFG.max_clients
               for _, n in scaler.fleet_trace)
    # cooldowns forbid opposite-direction flapping
    prev = None
    for t, kind, _ in scaler.actions:
        if prev is not None and kind != prev[1]:
            gap = _ACFG.cooldown_out if kind == "add" else _ACFG.cooldown_in
            assert t - prev[0] >= gap - 1e-9, \
                f"{prev} chased by ({t}, {kind}) inside its cooldown"
        prev = (t, kind)
    # cost integral is consistent with the provisioned-fleet bounds
    assert 0.0 <= scaler.client_seconds <= 2 + _ACFG.max_clients * coord.queue.now


def _summaries_equal(a, b):
    if set(a) != set(b):
        return False
    for k in a:
        x, y = a[k], b[k]
        if x != y and not (isinstance(x, float) and isinstance(y, float)
                           and math.isnan(x) and math.isnan(y)):
            return False
    return True


@settings(max_examples=10, deadline=None)
@given(policy=st.sampled_from(("threshold", "target_tracking")),
       seed=st.integers(min_value=0, max_value=8),
       phases=_phase_schedules)
def test_autoscale_fast_forward_bit_identity(policy, seed, phases):
    c_ff, s_ff, _ = _autoscaled_run(policy, seed, phases, tiered=True,
                                    fast_forward=True)
    c_st, s_st, _ = _autoscaled_run(policy, seed, phases, tiered=True,
                                    fast_forward=False)
    # closed-loop decisions observe only fast-forward-invariant state: the
    # action sequence and the end-to-end summary are bit-identical
    assert s_ff.actions == s_st.actions
    assert s_ff.fleet_trace == s_st.fleet_trace
    assert _summaries_equal(c_ff.metrics.summary(), c_st.metrics.summary())
    assert s_ff.client_seconds == pytest.approx(s_st.client_seconds)
