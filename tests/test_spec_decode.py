"""Speculative decoding end-to-end: verify-kernel parity (Pallas interpret
vs per-position decode oracle), COW fork/rollback random walks in
`PagedKVStore`, verify-mode model parity, speculative-Engine-vs-plain-Engine
greedy stream equality (spec_k x prompt length x prefix sharing x
preemption), and the per-position acceptance distribution in
`perfmodel.speculative_decode_step` pinned against Monte-Carlo."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs import get_reduced_config
from repro.engine.paged_kv import PagedKVStore, prefix_chain
from repro.kernels import ref
from repro.kernels.paged_attention import paged_verify_attention
from repro.models import steps
from repro.models import transformer as tf

KEY = jax.random.PRNGKey(11)


@pytest.fixture(scope="module")
def cfg():
    return get_reduced_config("gemma_2b")


@pytest.fixture(scope="module")
def params(cfg):
    p, _ = tf.init_model(cfg, jax.random.PRNGKey(0))
    return p


def _pool_case(rnd_key, b, s, kvh, g, d, dv, bt, mb):
    """Random pool + permutation block table; lengths leave >= s slots of
    headroom so every draft position lands inside the table's coverage."""
    nb = b * mb
    q = jax.random.normal(jax.random.fold_in(rnd_key, 0), (b, s, kvh * g, d))
    kp = jax.random.normal(jax.random.fold_in(rnd_key, 1), (nb, bt, kvh, d))
    vp = jax.random.normal(jax.random.fold_in(rnd_key, 2), (nb, bt, kvh, dv))
    tab = jax.random.permutation(jax.random.fold_in(rnd_key, 3),
                                 nb).reshape(b, mb)
    lens = jax.random.randint(jax.random.fold_in(rnd_key, 4), (b,), 1,
                              mb * bt - s + 1)
    return q, kp, vp, tab.astype(jnp.int32), lens.astype(jnp.int32)


# ---------------------------------------------------------------------------
# verify kernel: ref oracle vs sequential decode, Pallas interpret vs ref
# ---------------------------------------------------------------------------

def test_verify_ref_positions_bitwise_equal_sequential_decode():
    """Position j of the verify oracle must be BIT-identical to a one-token
    paged decode at the same position — the numeric foundation of the
    engine's spec-vs-plain stream-equality contract."""
    q, kp, vp, tab, lens = _pool_case(jax.random.fold_in(KEY, 0),
                                      3, 4, 2, 2, 32, 32, 8, 6)
    out = ref.paged_verify_attention(q, kp, vp, tab, lens)
    for j in range(q.shape[1]):
        want = ref.paged_decode_attention(q[:, j:j + 1], kp, vp, tab,
                                          lens + j + 1)
        np.testing.assert_array_equal(np.asarray(out[:, j:j + 1]),
                                      np.asarray(want))


@settings(max_examples=10, deadline=None)
@given(b=st.integers(1, 3), s=st.integers(1, 5), kvh=st.sampled_from([1, 2]),
       g=st.sampled_from([1, 2, 4]), d=st.sampled_from([16, 32]),
       bt=st.sampled_from([8, 16]), mb=st.integers(2, 6),
       seed=st.integers(0, 2 ** 16))
def test_verify_kernel_matches_ref(b, s, kvh, g, d, bt, mb, seed):
    """Hypothesis sweep: the one-pass Pallas verify kernel (interpret mode)
    must match the per-position unrolled oracle to fp32 tolerance across
    (batch, draft width, lengths, block size, table layout)."""
    q, kp, vp, tab, lens = _pool_case(jax.random.fold_in(KEY, seed),
                                      b, s, kvh, g, d, d, bt, mb)
    out = paged_verify_attention(q, kp, vp, tab, lens, interpret=True)
    want = ref.paged_verify_attention(q, kp, vp, tab, lens)
    np.testing.assert_allclose(out, want, atol=2e-5, rtol=2e-5)


def test_verify_kernel_asymmetric_dv():
    q, kp, vp, tab, lens = _pool_case(jax.random.fold_in(KEY, 99),
                                      2, 3, 2, 2, 32, 16, 8, 4)
    out = paged_verify_attention(q, kp, vp, tab, lens, interpret=True)
    want = ref.paged_verify_attention(q, kp, vp, tab, lens)
    np.testing.assert_allclose(out, want, atol=2e-5, rtol=2e-5)


def test_verify_ref_ignores_garbage_beyond_span():
    """Pool content past a row's causal span (draft positions not yet
    written, trash page, rejected writes from earlier iterations) must not
    perturb any verify output — masked lanes carry probability exactly 0."""
    q, kp, vp, tab, lens = _pool_case(jax.random.fold_in(KEY, 7),
                                      2, 3, 1, 4, 32, 32, 8, 6)
    s = q.shape[1]
    out1 = ref.paged_verify_attention(q, kp, vp, tab, lens)
    live_k = ref.gather_paged_kv(kp, tab)
    live_v = ref.gather_paged_kv(vp, tab)
    kp2 = kp.at[...].set(1e4)
    vp2 = vp.at[...].set(-1e4)
    bt = kp.shape[1]
    for i in range(2):
        for p in range(int(lens[i]) + s):       # position s-1 reads slots
            blk, off = int(tab[i, p // bt]), p % bt      # 0 .. lens+s-1
            kp2 = kp2.at[blk, off].set(live_k[i, p])
            vp2 = vp2.at[blk, off].set(live_v[i, p])
    out2 = ref.paged_verify_attention(q, kp2, vp2, tab, lens)
    np.testing.assert_allclose(out1, out2, atol=1e-6)


# ---------------------------------------------------------------------------
# model layer: verify_step == sequential decode, bitwise
# ---------------------------------------------------------------------------

def test_verify_step_matches_sequential_decode_bitwise(cfg, params):
    """Feed an arbitrary (not necessarily greedy) draft continuation through
    one verify pass and through s sequential one-token decode steps: the
    per-position logits and argmaxes must be bit-identical — the model-layer
    foundation of the engine's spec-vs-plain stream equality."""
    rng = np.random.default_rng(2)
    P, s, bt, max_len = 40, 4, 16, 96
    mb, num_blocks = max_len // bt, 2 * (max_len // bt)
    prompt = rng.integers(1, cfg.vocab_size, P).astype(np.int32)
    draft = rng.integers(1, cfg.vocab_size, s).astype(np.int32)

    def fresh_caches():
        caches = tf.init_paged_cache(cfg, 2, num_blocks, bt, mb)
        tables = np.full((2, mb), num_blocks, np.int32)
        tables[0] = np.arange(mb)             # row 0 live, row 1 dead/trash
        for g in caches.values():
            L = g["block_tables"].shape[0]
            g["block_tables"] = jnp.broadcast_to(
                jnp.asarray(tables)[None], (L, 2, mb))
        toks = np.zeros((2, P), np.int32)
        toks[0] = prompt
        qv = jnp.asarray(np.array([P, 0], np.int32))
        _, _, caches = steps.chunk_step(params, jnp.asarray(toks), qv,
                                        caches, cfg)
        return caches

    # sequential arm: one-token decodes, collecting per-position logits
    caches = fresh_caches()
    seq_logits = []
    for j in range(s):
        t = np.zeros((2, 1), np.int32)
        t[0, 0] = draft[j]
        _, logits, caches = steps.serve_step(params, jnp.asarray(t),
                                             caches, cfg)
        seq_logits.append(np.asarray(logits))

    # verify arm: all s positions in one pass
    caches = fresh_caches()
    feed = np.zeros((2, s), np.int32)
    feed[0] = draft
    qv = jnp.asarray(np.array([s, 0], np.int32))
    greedy, logits, _ = steps.verify_step(params, jnp.asarray(feed), qv,
                                          caches, cfg)
    greedy, logits = np.asarray(greedy), np.asarray(logits)
    for j in range(s):
        assert np.array_equal(logits[0, j], seq_logits[j][0]), j
        assert greedy[0, j] == int(np.argmax(seq_logits[j][0])), j


# ---------------------------------------------------------------------------
# PagedKVStore: COW fork/commit/abort random walk
# ---------------------------------------------------------------------------

def test_fork_cow_protects_shared_registered_block():
    """Two tables share a registered block; one forks with its fill front
    midway into it (the chunked-admission shape). The fork must COW the
    shared page out of the write range, commit must release the original to
    its other owner, and an abort must restore the exact pre-fork state."""
    bt = 4
    st_ = PagedKVStore(num_blocks=12, block_tokens=bt)
    chain = prefix_chain(list(range(2 * bt)), bt)
    a, _ = st_.allocate(1, 2 * bt, chain)
    b, n_matched = st_.allocate(2, 2 * bt, chain, filled=bt + 1,
                                context_tokens=2 * bt)
    assert n_matched == 2 and b == a          # fully shared
    base = (list(st_.tables[2].blocks), st_.tables[2].tokens,
            list(st_.tables[2].hashes), dict(st_.refcount))
    f = st_.fork_table(2, extra_tokens=bt)    # write range starts in blk 1
    assert f is not None and len(f.cow) == 1
    idx, old, new = f.cow[0]
    assert idx == 1 and old == a[1] and st_.tables[2].blocks[1] == new
    t = st_.tables[2]
    for i in range(t.tokens // bt, len(t.blocks)):
        blk = t.blocks[i]
        assert st_.refcount[blk] == 1 and blk not in st_.by_block
    st_.check_invariants()
    st_.abort_fork(2)
    assert (list(t.blocks), t.tokens, list(t.hashes),
            dict(st_.refcount)) == base
    st_.check_invariants()
    # fork again and commit: rid 1 must still own the original page
    f = st_.fork_table(2, extra_tokens=bt)
    st_.commit_fork(2, 3)
    assert t.tokens == bt + 1 + 3
    assert st_.tables[1].blocks == a and st_.refcount[a[1]] == 1
    st_.check_invariants()
    st_.free(1)
    st_.free(2)
    st_.check_invariants()
    assert st_.used_blocks == 0


@settings(max_examples=30, deadline=None)
@given(ops=st.lists(st.tuples(st.integers(0, 7), st.integers(0, 30)),
                    min_size=1, max_size=50),
       nb=st.integers(4, 14), bt=st.sampled_from([2, 4]))
def test_fork_random_walk_invariants(ops, nb, bt):
    """fork/commit/abort interleaved with admission, fill-front growth,
    swap_out/swap_in/free and cache reclaim: store invariants hold after
    every op, every fork's write range is private (refcount-1,
    unregistered), and an aborted fork restores table + refcounts exactly."""
    st_ = PagedKVStore(num_blocks=nb, block_tokens=bt)
    live, goal, rid = [], {}, 0
    snaps = {}                                 # rid -> (pre-fork state, extra)
    for op, arg in ops:
        if op == 0:                            # admission, shared prefixes
            toks = 1 + arg % (4 * bt)
            fill = max(1, arg % (toks + 1))
            chain = prefix_chain(list(range(min(toks, 3 * bt))), bt)
            if st_.allocate(rid, toks, chain, filled=fill,
                            context_tokens=toks) is not None:
                live.append(rid)
                goal[rid] = toks
            rid += 1
        elif op == 1 and live:                 # open a fork
            r = live[arg % len(live)]
            t = st_.tables[r]
            if t.on_device and r not in st_.forks:
                extra = 1 + arg % (2 * bt)
                snap = (list(t.blocks), t.tokens, list(t.hashes),
                        dict(st_.refcount))
                if st_.fork_table(r, extra) is not None:
                    snaps[r] = (snap, extra)
                    for i in range(t.tokens // bt, len(t.blocks)):
                        blk = t.blocks[i]
                        assert st_.refcount[blk] == 1
                        assert blk not in st_.by_block
        elif op == 2 and st_.forks:            # commit
            r = sorted(st_.forks)[arg % len(st_.forks)]
            _, extra = snaps.pop(r)
            base_tokens = st_.forks[r].base_tokens
            n = arg % (extra + 1)
            st_.commit_fork(r, n)
            t = st_.tables[r]
            assert t.tokens == base_tokens + n
            assert len(t.blocks) * bt >= t.tokens
        elif op == 3 and st_.forks:            # abort: exact restore
            r = sorted(st_.forks)[arg % len(st_.forks)]
            (blocks, tokens, hashes, _), _ = snaps.pop(r)
            f = st_.forks[r]
            released = [new for _, _, new in f.cow] + list(f.grown)
            st_.abort_fork(r)
            t = st_.tables[r]
            assert (list(t.blocks), t.tokens, list(t.hashes)) \
                == (blocks, tokens, hashes)
            for blk in released:               # fork-private pages all gone
                assert blk not in st_.refcount
        elif op == 4 and live:                 # plain fill-front growth
            r = live[arg % len(live)]
            t = st_.tables[r]
            if t.on_device and r not in st_.forks and t.tokens < goal[r]:
                ok = True
                while len(t.blocks) * bt < t.tokens + 1:
                    if st_.grow(r) is None:
                        ok = False
                        break
                if ok:
                    st_.advance(r, 1)
        elif op == 5 and live:                 # free (forks resolve first)
            r = live.pop(arg % len(live))
            if r in st_.forks:
                st_.abort_fork(r)
                snaps.pop(r)
            st_.free(r)
        elif op == 6 and live:                 # swap out (maybe degrade)
            r = live[arg % len(live)]
            t = st_.tables[r]
            if t.on_device:
                if r in st_.forks:
                    st_.abort_fork(r)
                    snaps.pop(r)
                if st_.swap_out(r) is None:
                    live.remove(r)
                    st_.drop(r)
        elif op == 7 and live:                 # swap in
            r = live[arg % len(live)]
            if not st_.tables[r].on_device:
                st_.swap_in(r)
        st_.check_invariants()
    for r in live:
        if r in st_.forks:
            st_.abort_fork(r)
        st_.free(r)
    st_.check_invariants()
    assert st_.used_blocks == 0


# ---------------------------------------------------------------------------
# engine: speculative streams bit-identical to plain decode
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def draft_cfg():
    return get_reduced_config("guard_2b")


@pytest.fixture(scope="module")
def draft_params(draft_cfg):
    p, _ = tf.init_model(draft_cfg, jax.random.PRNGKey(5))
    return p


_STREAMS = {}


def _engine_streams(cfg, params, prompts, *, spec_k=0, draft_cfg=None,
                    draft_params=None, num_blocks=None, preemption="swap",
                    max_new=10, key=None):
    """Run an Engine over ``prompts`` and return {rid: tokens}. Non-spec
    baselines memoize on ``key`` (the oracle never changes across cases)."""
    from repro.engine.runner import Engine, EngineConfig
    if key is not None and key in _STREAMS:
        return _STREAMS[key]
    conf = EngineConfig(draft_cfg=draft_cfg, spec_k=spec_k)
    eng = Engine(cfg, params=params, max_batch=3, max_len=64, block_tokens=8,
                 num_blocks=num_blocks, preemption=preemption, config=conf,
                 draft_params=draft_params)
    for p in prompts:
        eng.submit(p, max_new_tokens=max_new)
    fin = eng.run()
    assert len(fin) == len(prompts)
    eng.store.check_invariants()
    assert not eng.store.forks          # every fork committed or aborted
    out = {r.rid: list(r.tokens) for r in fin}
    if key is not None:
        _STREAMS[key] = out
    return eng if key is None else out


def _case_prompts(share, lens):
    rng = np.random.default_rng(10_000 * share + sum(lens))
    shared = rng.integers(1, 512, size=16).astype(np.int32)
    out = []
    for n in lens:
        tail = rng.integers(1, 512, size=n).astype(np.int32)
        out.append(np.concatenate([shared, tail]) if share else tail)
    return out


@settings(max_examples=8, deadline=None)
@given(spec_k=st.integers(1, 5), share=st.booleans(),
       lens=st.lists(st.integers(1, 40), min_size=2, max_size=5),
       preemption=st.sampled_from(["swap", "recompute"]),
       tight=st.booleans())
def test_spec_engine_stream_parity(cfg, params, draft_cfg, draft_params,
                                   spec_k, share, lens, preemption, tight):
    """The tentpole invariant: for every (spec_k, prompt-length mix, prefix
    sharing, pool pressure, preemption policy) the speculative engine's
    greedy streams are BIT-IDENTICAL to the plain paged engine's. A tight
    pool forces mid-speculation preemption (fork aborts, draft rebuilds);
    shared prefixes force real COW forks over registered pages."""
    prompts = _case_prompts(share, lens)
    nb = 12 if tight else None
    base = _engine_streams(cfg, params, prompts, num_blocks=nb,
                           preemption=preemption,
                           key=("base", share, tuple(lens), preemption, nb))
    eng = _engine_streams(cfg, params, prompts, spec_k=spec_k,
                          draft_cfg=draft_cfg, draft_params=draft_params,
                          num_blocks=nb, preemption=preemption)
    got = {r.rid: list(r.tokens) for r in eng.finished}
    assert got == base
    st_ = eng.spec_stats()
    assert st_["emitted"] == sum(len(t) - 1 for t in base.values())


def test_spec_engine_perfect_draft_accepts_everything(cfg, params):
    """Draft == target: every draft token must be accepted (acceptance 1.0
    per position) and rows commit k+1 tokens per step away from stop
    boundaries — the mechanism's upper bound, and a direct check that
    acceptance logic compares the right positions."""
    prompts = _case_prompts(0, [5, 17, 9])
    eng = _engine_streams(cfg, params, prompts, spec_k=3, draft_cfg=cfg,
                          draft_params=params, max_new=13)
    base = _engine_streams(cfg, params, prompts, max_new=13,
                           key=("perfect-base",))
    assert {r.rid: list(r.tokens) for r in eng.finished} == base
    st_ = eng.spec_stats()
    assert st_["acceptance_per_position"] == [1.0, 1.0, 1.0]
    assert st_["conditional_acceptance_per_position"] == [1.0, 1.0, 1.0]
    assert st_["tokens_per_step"] > 2.0


def test_spec_engine_partial_acceptance_telemetry(cfg, params):
    """Draft = target weights + noise: acceptance is strictly partial, and
    the telemetry must be self-consistent. ``acceptance_per_position`` is a
    MARGINAL (accept stops at the first rejection, so accepted/proposed is
    already a cumulative product); the conditional sequence divides that
    out, so compounding it back (``expected_accepted_tokens``) must equal
    1 + sum(marginals) — the identity E[accepted] = sum_i P(accept through
    i). Feeding the marginals instead would double-compound (the bug this
    test pins)."""
    import math

    from repro.perfmodel.analytical import expected_accepted_tokens

    leaves, tree = jax.tree.flatten(params)
    keys = jax.random.split(jax.random.PRNGKey(3), len(leaves))
    noisy = jax.tree.unflatten(tree, [
        l + 0.1 * jax.random.normal(k, l.shape, l.dtype)
        if jnp.issubdtype(l.dtype, jnp.floating) else l
        for l, k in zip(leaves, keys)])
    prompts = _case_prompts(0, [5, 17, 9])
    eng = _engine_streams(cfg, params, prompts, spec_k=4, draft_cfg=cfg,
                          draft_params=noisy, max_new=13)
    base = _engine_streams(cfg, params, prompts, max_new=13,
                           key=("partial-base",))
    assert {r.rid: list(r.tokens) for r in eng.finished} == base
    st_ = eng.spec_stats()
    marg = st_["acceptance_per_position"]
    cond = st_["conditional_acceptance_per_position"]
    assert all(m <= c + 1e-12 for m, c in zip(marg, cond))
    # the identity is exact when marginals decay monotonically (k_eff
    # clamping can wiggle the tail, hence the small tolerance); the
    # double-compounding bug would miss by ~sum(marg) - sum(cumprods)
    pred = expected_accepted_tokens(4, cond)
    assert math.isclose(pred, 1.0 + sum(marg), rel_tol=0.05)
    # measured tokens/step only deviates from the prediction through stop
    # boundaries (rows finishing mid-run), so it stays in a loose band
    assert abs(pred - st_["tokens_per_step"]) / pred < 0.5


def test_spec_engine_eos_mid_acceptance(cfg, params, draft_cfg, draft_params):
    """EOS inside an accepted run must truncate the stream exactly where
    sequential decode would stop."""
    prompts = _case_prompts(0, [7, 21])
    base = _engine_streams(cfg, params, prompts, max_new=16,
                           key=("eos-base",))
    eos = base[0][min(3, len(base[0]) - 1)]     # a token the stream emits
    from repro.engine.runner import Engine, EngineConfig
    outs = []
    for k in (0, 4):
        conf = EngineConfig(draft_cfg=draft_cfg if k else None, spec_k=k)
        eng = Engine(cfg, params=params, max_batch=3, max_len=64,
                     block_tokens=8, config=conf,
                     draft_params=draft_params if k else None)
        for p in prompts:
            eng.submit(p, max_new_tokens=16, eos_id=int(eos))
        fin = eng.run()
        outs.append({r.rid: list(r.tokens) for r in fin})
    assert outs[0] == outs[1]


# ---------------------------------------------------------------------------
# analytical model: expected accepted tokens
# ---------------------------------------------------------------------------

def test_expected_accepted_tokens_matches_monte_carlo():
    """The per-position closed form E = 1 + sum_j prod_{i<=j} a_i must match
    a direct Monte-Carlo of the acceptance process (accept position j iff
    every earlier position accepted and its own coin lands)."""
    from repro.perfmodel.analytical import expected_accepted_tokens
    rng = np.random.default_rng(0)
    dist = [0.9, 0.7, 0.5, 0.2]
    k = len(dist)
    runs = np.cumprod(rng.random((200_000, k)) < np.asarray(dist), axis=1)
    mc = float((1 + runs.sum(axis=1)).mean())
    assert abs(expected_accepted_tokens(k, dist) - mc) < 0.01
    # scalar alpha keeps the geometric closed form
    assert np.isclose(expected_accepted_tokens(4, 0.8),
                      (1 - 0.8 ** 5) / (1 - 0.8))
    # a short distribution extends with its last value
    assert np.isclose(expected_accepted_tokens(4, [0.5]),
                      expected_accepted_tokens(4, 0.5))
    # degenerate bounds: never-accept -> bonus token only; always -> k+1
    assert expected_accepted_tokens(4, 0.0) == 1.0
    assert expected_accepted_tokens(4, [1.0, 1.0, 1.0, 1.0]) == 5.0


def test_sim_spec_decode_stage():
    """SPEC_DECODE in the simulator: speculative decode steps commit
    multiple tokens per iteration, so decode-bound TPOT must drop vs the
    plain scheduler; a measured per-position distribution prices between
    its geometric envelopes."""
    from repro.core import (SystemSpec, WorkloadConfig, build_system,
                            generate)
    from repro.core.llm_scheduler import SchedulerLimits
    from repro.core.workload import AZURE_CODE

    def tpot(limits):
        spec = SystemSpec(n_llm_clients=2, strategy="continuous",
                          limits=limits, with_pre_post=False)
        coord = build_system(spec)
        wl = WorkloadConfig(trace=AZURE_CODE, rate=2.0, n_requests=30,
                            postprocess=False, seed=41)
        coord.submit(generate(wl))
        return coord.run().summary()["tpot_p50"]

    base = tpot(SchedulerLimits())
    spec = tpot(SchedulerLimits(spec_k=4, spec_acceptance=0.8))
    dist = tpot(SchedulerLimits(spec_k=4,
                                spec_acceptance=(0.9, 0.8, 0.5, 0.3)))
    assert spec < base
    assert dist < base
