"""Decode fast-forward engine: macro-step equivalence (the summary must be
bit-identical with the engine on or off), truncate-and-replay invalidation,
the evictable-leaf radix LRU, WaitQueue semantics and the ClientPerf memo."""
import math

import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.core import SystemSpec, WorkloadConfig, build_system, generate
from repro.core.llm_scheduler import (ClientPerf, LLMScheduler,
                                      SchedulerLimits, WaitQueue)
from repro.core.memory import PagedKVAllocator, RadixBlockIndex
from repro.core.metrics import simulator_stats
from repro.core.request import LLM, Request, Stage
from repro.core.workload import synthetic_trace
from repro.perfmodel.hardware import ClusterSpec, H100

MODEL = get_config("llama3_70b")
CLUSTER = ClusterSpec(H100, n_chips=2, tp=2)


def _summaries_equal(a, b):
    if set(a) != set(b):
        return False, "key sets differ"
    for k in a:
        x, y = a[k], b[k]
        if x == y:
            continue
        if isinstance(x, float) and isinstance(y, float) \
                and math.isnan(x) and math.isnan(y):
            continue
        return False, (k, x, y)
    return True, None


def _run(fast_forward, spec_kw=None, wl_kw=None, limits_kw=None, fail_at=None):
    limits = SchedulerLimits(fast_forward=fast_forward, **(limits_kw or {}))
    coord = build_system(SystemSpec(limits=limits, **(spec_kw or {})))
    if fail_at is not None:
        name = next(n for n in coord.clients
                    if n.startswith(("llm", "decode", "prefill")))
        coord.schedule_failure(name, at=fail_at, recover_at=fail_at + 15.0)
    coord.submit(generate(WorkloadConfig(**(wl_kw or {}))))
    metrics = coord.run()
    return coord, metrics


def _assert_equivalent(spec_kw=None, wl_kw=None, limits_kw=None,
                       fail_at=None):
    c_on, m_on = _run(True, spec_kw, wl_kw, limits_kw, fail_at)
    c_off, m_off = _run(False, spec_kw, wl_kw, limits_kw, fail_at)
    ok, diff = _summaries_equal(m_on.summary(), m_off.summary())
    assert ok, f"summary diverged: {diff}"
    # request-level: completion times, token counts and emission timestamps
    assert len(m_on.serviced) == len(m_off.serviced)
    for a, b in zip(sorted(m_on.serviced, key=lambda r: r.arrival),
                    sorted(m_off.serviced, key=lambda r: r.arrival)):
        assert a.completion_time == b.completion_time
        assert a.decoded_tokens == b.decoded_tokens
        assert a.token_times == b.token_times
        assert a.preemptions == b.preemptions
    assert c_on.total_energy == c_off.total_energy
    return c_on, c_off


# ---------------------------------------------------------------------------
# equivalence: property sweep over strategies x preemption x prefix workloads
# ---------------------------------------------------------------------------

@given(strategy=st.sampled_from(["continuous", "static", "chunked", "mixed"]),
       preemption=st.sampled_from(["swap", "recompute"]),
       frac=st.sampled_from([1.0, 0.04]),
       prefix_pool=st.sampled_from([0, 2]),
       branches=st.sampled_from([1, 3]),
       n=st.integers(6, 14), rate=st.floats(1.0, 6.0),
       seed=st.integers(0, 50))
@settings(max_examples=10, deadline=None)
def test_fast_forward_equivalence_property(strategy, preemption, frac,
                                           prefix_pool, branches, n, rate,
                                           seed):
    wl = dict(n_requests=n, rate=rate, seed=seed,
              shared_prefix_pool=prefix_pool)
    if branches > 1:
        wl.update(pipeline="reasoning", reasoning_branches=branches,
                  reasoning_scale=3.0)
    _assert_equivalent(
        spec_kw=dict(n_llm_clients=2, strategy=strategy),
        limits_kw=dict(preemption=preemption, kv_capacity_frac=frac),
        wl_kw=wl)


def test_fast_forward_equivalence_disaggregated():
    _assert_equivalent(
        spec_kw=dict(strategy="disaggregated", n_prefill=2, n_decode=2),
        wl_kw=dict(n_requests=18, rate=2.0, seed=7, disaggregated=True))


def test_fast_forward_equivalence_under_failure():
    _assert_equivalent(spec_kw=dict(n_llm_clients=3),
                       wl_kw=dict(n_requests=18, rate=3.0, seed=11),
                       fail_at=2.0)


def test_fast_forward_equivalence_with_stragglers():
    def run(ff):
        coord = build_system(SystemSpec(
            n_llm_clients=2, straggler_deadline=0.5,
            router_policy="round_robin",
            limits=SchedulerLimits(fast_forward=ff)))
        coord.clients["llm0"].slowdown = 100.0      # 100x straggler
        coord.submit(generate(WorkloadConfig(n_requests=15, rate=4.0,
                                             seed=17)))
        return coord, coord.run()
    c_on, m_on = run(True)
    c_off, m_off = run(False)
    ok, diff = _summaries_equal(m_on.summary(), m_off.summary())
    assert ok, diff
    # the deadline-event rescue path must actually fire in this scenario
    assert sum(r.preemptions for r in m_on.serviced) > 0


@pytest.mark.parametrize("metric", ["queue", "tokens_remaining",
                                    "kv_pressure", "kv_size"])
def test_fast_forward_equivalence_per_router_metric(metric):
    """kv_* metrics force candidate-window sync; the rest read virtually
    committed load — both must stay bit-equal with per-step execution."""
    _assert_equivalent(
        spec_kw=dict(n_llm_clients=3, router_metric=metric),
        wl_kw=dict(n_requests=15, rate=4.0, seed=5))


def test_fast_forward_actually_engages_and_cuts_events():
    """Decode-heavy fleet: the engine must plan real macro windows and pop
    several times fewer heap events, not just agree on the metrics."""
    trace = synthetic_trace(128, 0.3, 400, 0.15)
    wl = dict(trace=trace, rate=32.0, n_requests=32, postprocess=False,
              seed=9)
    c_on, m_on = _run(True, dict(n_llm_clients=1, with_pre_post=False),
                      wl)
    c_off, m_off = _run(False, dict(n_llm_clients=1, with_pre_post=False),
                        wl)
    ok, diff = _summaries_equal(m_on.summary(), m_off.summary())
    assert ok, diff
    st_on, st_off = simulator_stats(c_on), simulator_stats(c_off)
    assert st_on["macro_windows"] > 0
    assert st_on["micro_steps"] == st_off["micro_steps"]
    assert st_on["events_popped"] * 3 < st_off["events_popped"]


def test_fast_forward_window_invalidation_mid_flight():
    """An arrival landing mid-window truncates it: the committed prefix and
    the replayed remainder must reproduce per-step token timestamps."""
    trace = synthetic_trace(256, 0.2, 300, 0.1)
    # second wave lands while the first is deep in a decode window
    wl = dict(trace=trace, rate=1.2, n_requests=10, postprocess=False, seed=3)
    c_on, m_on = _run(True, dict(n_llm_clients=1, with_pre_post=False), wl)
    c_off, m_off = _run(False, dict(n_llm_clients=1, with_pre_post=False), wl)
    assert simulator_stats(c_on)["macro_windows"] > 0
    for a, b in zip(m_on.serviced, m_off.serviced):
        assert a.token_times == b.token_times
    ok, diff = _summaries_equal(m_on.summary(), m_off.summary())
    assert ok, diff


def test_fast_forward_run_horizon_cutoff():
    """run(until=...) must leave both modes in the same observable state even
    when the cut lands inside an in-flight window."""
    trace = synthetic_trace(128, 0.2, 500, 0.1)
    wl = WorkloadConfig(trace=trace, rate=32.0, n_requests=16,
                        postprocess=False, seed=9)
    outs = []
    for ff in (True, False):
        coord = build_system(SystemSpec(
            n_llm_clients=1, with_pre_post=False,
            limits=SchedulerLimits(fast_forward=ff)))
        coord.submit(generate(wl))
        m = coord.run(until=5.0)
        sched = next(c for c in coord.clients.values()
                     if c.kind == "llm").scheduler
        outs.append((sorted(r.decoded_tokens for r in sched.running),
                     sorted(len(r.token_times) for r in sched.running),
                     sched.total_tokens, len(m.serviced)))
    assert outs[0] == outs[1]


# ---------------------------------------------------------------------------
# WaitQueue
# ---------------------------------------------------------------------------

def _req(i, out=8):
    return Request(arrival=float(i), input_tokens=64 + i,
                   output_tokens=out, stages=[Stage(LLM)])


def test_waitqueue_fcfs_order_and_requeue():
    q = WaitQueue("fcfs")
    a, b, c = _req(0), _req(1), _req(2)
    for r in (a, b, c):
        q.push(r)
    assert q.peek() is a and len(q) == 3 and b in q
    assert q.popleft() is a
    q.requeue(a)                      # preempted victim returns to the head
    assert q.peek() is a
    assert q.remove(b) and not q.remove(b)
    assert list(q) == [a, c]
    assert list(reversed(q)) == [c, a]
    q.clear()
    assert not q and q.peek() is None


def test_waitqueue_least_work_orders_by_remaining_work():
    q = WaitQueue("least_work")
    heavy, light, mid = _req(0, out=500), _req(1, out=5), _req(2, out=80)
    for r in (heavy, light, mid):
        q.push(r)
    assert q.peek() is light
    assert q.popleft() is light
    assert q.remove(mid)
    assert q.popleft() is heavy and len(q) == 0


def test_waitqueue_least_work_lazy_deletion_skips_removed_head():
    q = WaitQueue("least_work")
    light, heavy = _req(0, out=5), _req(1, out=500)
    q.push(light)
    q.push(heavy)
    assert q.remove(light)            # head removed lazily
    assert q.peek() is heavy and len(q) == 1


def test_scheduler_least_work_completes_without_resort():
    sched = LLMScheduler("continuous", MODEL, CLUSTER, packing="least_work",
                         limits=SchedulerLimits(max_batch=4))
    reqs = [_req(i, out=4 + (7 * i) % 13) for i in range(9)]
    for r in reqs:
        sched.add(r)
    now, finished = 0.0, []
    for _ in range(500):
        if not sched.has_work():
            break
        step = sched.plan_step()
        now += step.duration
        finished += sched.finish_step(step, now)
    assert len(finished) == 9


# ---------------------------------------------------------------------------
# radix evictable-leaf LRU
# ---------------------------------------------------------------------------

def _chain(tag, n):
    h, out = hash(tag), []
    for i in range(n):
        h = hash((h, i))
        out.append(h)
    return out


def test_radix_leaf_heap_matches_lru_leaf_first_order():
    """Eviction must pick the least-recently-cached block whose node is a
    leaf — the old head-scan semantics: a chain freed deepest-first evicts
    leaf-to-root in exactly that order."""
    B = 4
    kv = PagedKVAllocator(capacity_bytes=100.0 * B, bytes_per_token=1.0,
                          block_tokens=B)
    hashes = _chain("a", 3)
    assert kv.allocate("a", 3 * B, prefix_hashes=hashes)
    chain_blocks = list(kv.tables["a"].blocks)
    kv.free("a")    # released deepest-first: leaf is oldest cached
    evicted = [kv.radix.evict_one() for _ in range(3)]
    assert evicted == list(reversed(chain_blocks))
    assert kv.radix.evict_one() is None
    kv._free.extend(evicted)          # return pages the index handed back
    kv.check_invariants()


def test_radix_parent_promoted_when_last_child_unregisters():
    B = 4
    idx = RadixBlockIndex()
    idx.insert(1, 10, None)
    idx.insert(2, 11, 1)
    idx.release(10)                   # cached interior: not evictable yet
    idx.release(11)
    assert idx.evict_one() == 11      # leaf goes first
    assert idx.evict_one() == 10      # parent promoted after child left
    assert idx.evict_one() is None


def test_radix_reacquired_block_not_evicted_via_stale_heap_entry():
    idx = RadixBlockIndex()
    idx.insert(1, 10, None)
    idx.release(10)
    idx.acquire(10)                   # revived: stale heap entry must not fire
    assert idx.evict_one() is None
    idx.release(10)
    assert idx.evict_one() == 10


def test_bulk_reclaim_is_linear_in_evictions():
    """Reclaiming a deep cached chain must not rescan the cached head per
    eviction (the old O(cached^2) bulk-reclaim path)."""
    B = 4
    n_chain = 200
    kv = PagedKVAllocator(capacity_bytes=(n_chain + 50.0) * B,
                          bytes_per_token=1.0, block_tokens=B)
    assert kv.allocate("deep", n_chain * B,
                       prefix_hashes=_chain("deep", n_chain))
    kv.free("deep")
    assert kv.cached_blocks == n_chain
    import heapq
    pops = {"n": 0}
    orig = heapq.heappop

    def counting_pop(h):
        pops["n"] += 1
        return orig(h)
    heapq.heappop = counting_pop
    try:
        freed = kv.clear_cache()
    finally:
        heapq.heappop = orig
    assert freed == n_chain
    assert pops["n"] <= 3 * n_chain + 10    # amortized O(1) per eviction


# ---------------------------------------------------------------------------
# ClientPerf memoization
# ---------------------------------------------------------------------------

def test_clientperf_memo_returns_identical_costs_and_is_bounded():
    perf = ClientPerf(MODEL, CLUSTER, use_regression=False)
    a = perf.decode(8, 1024)
    assert perf.decode(8, 1024) is a          # cached object, not recomputed
    b = perf.prefill(512, 1, 0)
    assert perf.prefill(512, 1, 0) is b
    c = perf.chunked(256, 4, 2048)
    assert perf.chunked(256, 4, 2048) is c
    for i in range(ClientPerf.MEMO_CAPACITY + 100):
        perf.decode(1, i)
    assert len(perf._memo) <= ClientPerf.MEMO_CAPACITY
    # evicted keys recompute to equal values
    a2 = perf.decode(8, 1024)
    assert a2.time == a.time and a2.energy == a.energy
