"""Cross-client radix prefix migration (export/import over the Network) plus
the PR's correctness-fix regressions: retrieval-latency convergence, stale
straggler deadlines across stage transitions, failed-admission radix-LRU
perturbation, and deterministic heavy-light partitioning."""
import math

import numpy as np
import pytest

from repro.configs import get_config
from repro.core import (Coordinator, CoordinatorConfig, SystemSpec,
                        WorkloadConfig, build_system, generate)
from repro.core.client import LLMClient
from repro.core.llm_scheduler import SchedulerLimits
from repro.core.memory import (PagedKVAllocator, expected_retrieval_latency,
                               sample_retrieval_latency)
from repro.core.metrics import simulator_stats
from repro.core.request import LLM, PREPROCESS, Request, Stage
from repro.core.router import HeavyLightRouter
from repro.core.workload import TraceSpec
from repro.core import events as ev
from repro.perfmodel.hardware import (CacheTierSpec, ClusterSpec, H100,
                                      PCIE4_X4)

MODEL = get_config("llama3_70b")
CLUSTER = ClusterSpec(H100, n_chips=2, tp=2)
B = 4          # small block size for allocator-level tests


def _chain(tag, n):
    out, h = [], 0
    for i in range(n):
        h = hash((h, tag, i))
        out.append(h)
    return out


def _kv(blocks=100.0):
    return PagedKVAllocator(capacity_bytes=blocks * B, bytes_per_token=1.0,
                            block_tokens=B)


def _summaries_equal(a, b):
    if set(a) != set(b):
        return False, "key sets differ"
    for k in a:
        x, y = a[k], b[k]
        if x == y:
            continue
        if isinstance(x, float) and isinstance(y, float) \
                and math.isnan(x) and math.isnan(y):
            continue
        return False, (k, x, y)
    return True, None


# ---------------------------------------------------------------------------
# allocator-level export / import
# ---------------------------------------------------------------------------

def test_migrate_then_hit_admission():
    """Export a cached chain, import it elsewhere: the next same-prefix
    admission at the destination maps the migrated pages and the hit is
    attributed to the migration."""
    src, dst = _kv(), _kv()
    hs = _chain("a", 5)
    assert src.allocate("r", 5 * B, prefix_hashes=hs)
    src.free("r")                      # chain stays resident as cached
    handle, n_res, nbytes = src.export_chain(hs)
    assert n_res == 5 and nbytes == 5 * src.block_bytes
    imported, refused = dst.import_chain(hs[:n_res])
    assert (imported, refused) == (5, 0)
    src.release_export(handle)
    src.check_invariants()
    dst.check_invariants()
    assert dst.allocate("x", 5 * B, prefix_hashes=hs)
    assert dst.prefix_hit_tokens == 5 * B
    assert dst.migration_hit_tokens == 5 * B
    # physical copies are not logical demand: dedup accounting untouched
    assert dst.stats()["dedup_ratio"] >= 1.0
    dst.check_invariants()


def test_import_backpressure_never_evicts_resident_cache():
    """Imports draw on the free list alone: a destination whose pool is
    full of its own cached content refuses the migrated blocks instead of
    evicting warm local cache (or stealing from live tables)."""
    dst = _kv(blocks=4)
    own = _chain("own", 4)
    assert dst.allocate("d", 4 * B, prefix_hashes=own)
    dst.free("d")
    assert dst.cached_blocks == 4 and dst.free_blocks == 0
    imported, refused = dst.import_chain(_chain("mig", 3))
    assert (imported, refused) == (0, 3)
    assert dst.cached_blocks == 4          # local cache untouched
    assert dst.migration_refused_blocks == 3
    dst.check_invariants()
    # partial room: only the leading (most-shared) part of the chain lands
    dst2 = _kv(blocks=4)
    assert dst2.allocate("d", 2 * B)       # no prefix: 2 blocks live
    imported, refused = dst2.import_chain(_chain("mig", 3))
    assert (imported, refused) == (2, 1)
    dst2.check_invariants()


def test_import_collision_truncates_chain():
    """A chain hash already registered under another block ends the import
    there — same truncation rule as admission-time registration."""
    dst = _kv()
    hs = _chain("m", 4)
    # hs[1] resurfaces at the destination under an unrelated root block
    assert dst.allocate("other", 1 * B, prefix_hashes=[hs[1]])
    imported, refused = dst.import_chain(hs)
    assert imported == 1                   # hs[0] landed
    assert refused == 3 - 1 + 1            # hs[1] collided, hs[2:] refused
    assert dst.migration_refused_blocks == 3
    dst.check_invariants()


def test_export_pin_survives_reclaim_and_releases_evictable():
    """Pinned source pages cannot be reclaimed while the chain is on the
    wire; releasing the pin returns them to the evictable cache."""
    src = _kv(blocks=6)
    hs = _chain("p", 3)
    assert src.allocate("r", 3 * B, prefix_hashes=hs)
    src.free("r")
    handle, _, _ = src.export_chain(hs)
    assert src.clear_cache() == 0          # all cached blocks are pinned
    assert src.cached_blocks == 0
    src.check_invariants()
    src.release_export(handle)
    assert src.cached_blocks == 3
    assert src.clear_cache() == 3          # evictable again after release
    src.check_invariants()


def test_export_skip_ships_only_the_suffix():
    src = _kv()
    hs = _chain("s", 6)
    assert src.allocate("r", 6 * B, prefix_hashes=hs)
    handle, n_res, nbytes = src.export_chain(hs, skip=4)
    assert n_res == 6 and nbytes == 2 * src.block_bytes
    src.release_export(handle)
    assert src.export_chain(hs, skip=6) is None
    src.free("r")
    src.check_invariants()


def test_hot_chains_budget_and_validity():
    src = _kv()
    for tag, n in (("a", 5), ("b", 3)):
        assert src.allocate(tag, n * B, prefix_hashes=_chain(tag, n))
    src.free("b")                          # b is cached, a is live (hotter)
    chains = src.hot_chains(max_blocks=6)
    assert sum(len(c) for c in chains) <= 6 + 2  # shared prefixes only
    assert chains[0] == _chain("a", 5)     # live leaf first, full chain
    assert chains[1] == _chain("b", 3)[:1]  # budget cut to a valid prefix
    # every returned chain must be matchable (a resident prefix)
    for c in chains:
        assert len(src.radix.match(c)) == len(c)


# ---------------------------------------------------------------------------
# coordinator end-to-end migration
# ---------------------------------------------------------------------------

MIG_TRACE = TraceSpec("mig", input_mean=384, input_std=0.3, output_mean=160,
                      output_std=0.2, input_max=600, output_max=320)


def _scaled_out_system(fast_forward=True, migration=True, scale_at=4.0,
                       n_requests=40):
    limits = SchedulerLimits(max_batch=32, fast_forward=fast_forward)
    spec = SystemSpec(n_llm_clients=1, strategy="continuous", limits=limits,
                      with_pre_post=False, router_policy="prefix_affinity",
                      prefix_migration=migration, fetch_load_factor=1.5)
    coord = build_system(spec)
    warm = coord.clients["llm0"]
    cold = LLMClient("llm1", warm.cluster, warm.model_cfg, "continuous",
                     limits, "fcfs", warm.scheduler.perf)
    coord.network.add_link("pcie:llm1", PCIE4_X4)
    coord.network.connect("llm1", "llm1:kvpool", ["pcie:llm1"])
    coord.schedule_add_client(cold, at=scale_at)
    wl = WorkloadConfig(trace=MIG_TRACE, rate=4.0, n_requests=n_requests,
                        seed=3, shared_prefix_pool=4,
                        shared_prefix_tokens=512, prefix_reuse_rate=1.0,
                        postprocess=False, rate_ramp_at=scale_at,
                        rate_ramp=2.0)
    coord.submit(generate(wl))
    return coord, coord.run()


def test_scale_out_push_warming_recovers_cold_replica():
    coord, m = _scaled_out_system()
    s = m.summary()
    assert s["kv_migrations"] > 0
    assert s["kv_migrated_bytes"] > 0
    assert s["kv_migrated_in_blocks"] > 0
    # migration traffic rides the Network (rack fabric)
    assert coord.network.stats()["rack"]["bytes"] >= s["kv_migrated_bytes"]
    warm = coord.clients["llm0"].prefix_hit_rate()
    cold = coord.clients["llm1"].prefix_hit_rate()
    assert warm > 0 and cold >= 0.8 * warm
    # migrated pages actually served admissions
    assert s["kv_migration_hit_tokens"] > 0
    for c in coord.clients.values():
        kv = getattr(c.scheduler, "kv", None)
        if kv is not None:
            kv.check_invariants()
            assert not kv._exports          # every pin released


def test_migration_mid_window_truncates_fast_forward_bit_equally():
    """MIGRATE_DONE lands as an external event: in-flight decode windows at
    the destination truncate-and-replay, so summaries, token timestamps and
    energy stay bit-identical with fast-forward on or off."""
    c_on, m_on = _scaled_out_system(fast_forward=True)
    c_off, m_off = _scaled_out_system(fast_forward=False)
    assert simulator_stats(c_on)["macro_windows"] > 0
    assert m_on.summary()["kv_migrations"] > 0
    ok, diff = _summaries_equal(m_on.summary(), m_off.summary())
    assert ok, f"summary diverged: {diff}"
    for a, b in zip(sorted(m_on.serviced, key=lambda r: r.arrival),
                    sorted(m_off.serviced, key=lambda r: r.arrival)):
        assert a.token_times == b.token_times
        assert a.completion_time == b.completion_time
    assert c_on.total_energy == c_off.total_energy


def test_fetch_policy_migrates_without_scale_out():
    """The prefix-affinity fetch policy alone (no CLIENT_ADD warming) must
    spread an overloaded warm client's prefix to the load-best client."""
    limits = SchedulerLimits(max_batch=8)
    spec = SystemSpec(n_llm_clients=2, strategy="continuous", limits=limits,
                      with_pre_post=False, router_policy="prefix_affinity",
                      prefix_migration=True, warm_on_scale_out=False,
                      fetch_load_factor=1.2)
    coord = build_system(spec)
    wl = WorkloadConfig(trace=MIG_TRACE, rate=16.0, n_requests=40, seed=5,
                        shared_prefix_pool=2, shared_prefix_tokens=512,
                        prefix_reuse_rate=1.0, postprocess=False)
    coord.submit(generate(wl))
    m = coord.run()
    s = m.summary()
    assert s["kv_migrations"] > 0
    assert s["kv_migration_hit_tokens"] > 0
    assert coord.clients["llm1"].prefix_hit_rate() > 0


def test_source_failure_discards_pins_instead_of_resurrecting_kv():
    """A donor that fails mid-transfer loses its device KV — including the
    pinned chain. The pins are discarded at drain (so the purge covers
    them) and the late MIGRATE_DONE release is a harmless no-op; the bytes
    already on the wire still land at the destination."""
    coord = build_system(SystemSpec(n_llm_clients=2, strategy="continuous",
                                    with_pre_post=False,
                                    prefix_migration=True))
    src = coord.clients["llm0"]
    src_kv = src.scheduler.kv
    hs = _chain("f", 3)
    assert src_kv.allocate("r", 3 * src_kv.block_tokens, prefix_hashes=hs)
    src_kv.free("r")
    handle, n_res, nbytes = src_kv.export_chain(hs)
    src.drain()                            # client failed mid-transfer
    assert not src_kv._exports
    assert src_kv.cached_blocks == 0       # pinned content died with it
    src_kv.check_invariants()
    coord._finish_migration(("llm0", "llm1", handle, tuple(hs[:n_res]),
                             nbytes, ("llm1", tuple(hs))), now=1.0)
    dst_kv = coord.clients["llm1"].scheduler.kv
    assert dst_kv.migrated_in_blocks == 3  # wire data still lands
    src_kv.check_invariants()
    dst_kv.check_invariants()


def test_migration_to_failed_destination_releases_source_pin():
    spec = SystemSpec(n_llm_clients=2, strategy="continuous",
                      with_pre_post=False, prefix_migration=True)
    coord = build_system(spec)
    src_kv = coord.clients["llm0"].scheduler.kv
    hs = _chain("x", 3)
    assert src_kv.allocate("r", 3 * src_kv.block_tokens, prefix_hashes=hs)
    src_kv.free("r")
    handle, n_res, nbytes = src_kv.export_chain(hs)
    coord.clients["llm1"].failed = True
    coord._finish_migration(("llm0", "llm1", handle, tuple(hs[:n_res]),
                             nbytes, ("llm1", tuple(hs))), now=1.0)
    assert not src_kv._exports             # pin released even on abort
    assert coord.clients["llm1"].scheduler.kv.migrated_in_blocks == 0
    src_kv.check_invariants()


# ---------------------------------------------------------------------------
# bugfix: Eq. 1 analytical/Monte-Carlo reconciliation
# ---------------------------------------------------------------------------

def test_retrieval_sample_mean_converges_to_expectation():
    """The sampled walk pays every probed tier's lookup before missing; the
    analytical recursion must charge the same — within 2% at 10k samples on
    a miss-heavy chain (hit rates 0.3 / 0.5)."""
    tiers = [CacheTierSpec("l1", 1e12, 1e-6, 1e9, 0.3),
             CacheTierSpec("l2", 1e12, 1e-5, 1e8, 0.5)]
    size, miss = 2e6, 0.25
    rng = np.random.default_rng(7)
    samples = [sample_retrieval_latency(size, tiers, miss, rng)
               for _ in range(10_000)]
    want = expected_retrieval_latency(size, tiers, miss)
    assert abs(np.mean(samples) - want) / want < 0.02


# ---------------------------------------------------------------------------
# bugfix: stale straggler deadlines across stage transitions
# ---------------------------------------------------------------------------

def test_stale_straggler_deadline_does_not_fire_at_next_stage():
    """A deadline armed at a previous stage's dispatch must not preempt the
    request while it is legitimately queued at its *next* stage's client."""
    coord = build_system(SystemSpec(n_llm_clients=2, with_pre_post=False,
                                    straggler_deadline=1.0))
    req = Request(arrival=0.0, input_tokens=64, output_tokens=8,
                  stages=[Stage(LLM)])
    req.current_stage.client = "llm0"
    coord.clients["llm0"].scheduler.waiting.push(req)
    coord._dispatch_times[req.rid] = 5.0   # re-armed at transfer arrival
    coord._check_straggler(req, 0.0, now=1.0)   # stale prefill-era deadline
    assert req.preemptions == 0
    assert req in coord.clients["llm0"].scheduler.waiting
    # the deadline armed at the forwarded dispatch still protects the stage
    coord._check_straggler(req, 5.0, now=6.0)
    assert req.preemptions == 1
    assert req not in coord.clients["llm0"].scheduler.waiting
    assert coord._dispatch_times[req.rid] == 6.0   # rescue re-armed


def test_transfer_arrival_rearms_straggler_deadline():
    """_transfer_and_forward must refresh _dispatch_times and arm a fresh
    deadline for the forwarded stage (previously neither happened)."""
    coord = build_system(SystemSpec(n_llm_clients=1,
                                    straggler_deadline=2.0))
    req = Request(arrival=0.0, input_tokens=64, output_tokens=8,
                  stages=[Stage(PREPROCESS), Stage(LLM)])
    req.advance_stage(1.0)                 # preprocess finished at t=1
    coord._transfer_and_forward(req, "preproc0", 1.0)
    arrive = coord._dispatch_times[req.rid]
    assert arrive >= 1.0
    checks = [e for e in coord.queue._heap if e.kind == ev.STRAGGLER_CHECK]
    assert any(e.payload == (req, arrive) and e.time == arrive + 2.0
               for e in checks)


def test_dispatch_times_do_not_leak_after_completion():
    coord = build_system(SystemSpec(
        strategy="disaggregated", n_prefill=1, n_decode=2,
        straggler_deadline=0.5))
    coord.submit(generate(WorkloadConfig(n_requests=12, rate=4.0, seed=2,
                                         disaggregated=True)))
    coord.run()
    assert coord.all_serviced()
    assert coord._dispatch_times == {}     # previously an unbounded leak


# ---------------------------------------------------------------------------
# bugfix: failed admission must not perturb radix LRU order
# ---------------------------------------------------------------------------

def test_failed_admission_preserves_radix_lru_order():
    """A stream of rejected admissions matching an old cached chain must not
    keep it artificially hot: eviction order stays what it would have been
    had they never arrived."""
    kv = _kv(blocks=4)
    ha, hc = _chain("A", 2), _chain("C", 2)
    assert kv.allocate("a", 2 * B, prefix_hashes=ha)
    kv.free("a")                           # A cached (older)
    assert kv.allocate("c", 2 * B, prefix_hashes=hc)
    kv.free("c")                           # C cached (newer)
    a_blocks = kv.radix.match(ha)
    # rejected admissions repeatedly match chain A (too big to admit)
    for _ in range(3):
        assert not kv.allocate("huge", 10 * B, prefix_hashes=ha)
    kv.check_invariants()
    # LRU leaf-first eviction must still take A's leaf (oldest), not C's
    assert kv.radix.evict_one() == a_blocks[1]
    assert kv.radix.evict_one() == a_blocks[0]
    kv._free.extend(a_blocks[::-1])
    kv.check_invariants()


def test_failed_admission_rollback_keeps_counters_clean():
    kv = _kv(blocks=4)
    ha = _chain("A", 2)
    assert kv.allocate("a", 2 * B, prefix_hashes=ha)
    kv.free("a")
    before = kv.stats()
    assert not kv.allocate("huge", 10 * B, prefix_hashes=ha)
    after = kv.stats()
    assert after["admission_failures"] == before["admission_failures"] + 1
    for k in ("block_refs_total", "shared_blocks", "prefix_hit_tokens",
              "prefix_tokens_seen", "cached_blocks"):
        assert after[k] == before[k], k
    kv.check_invariants()


# ---------------------------------------------------------------------------
# bugfix: deterministic heavy-light split + per-instance coordinator config
# ---------------------------------------------------------------------------

class _StubClient:
    def __init__(self, name, load):
        self.name = name
        self._load = load

    def load(self, metric, now):
        return self._load


def test_heavy_light_split_invariant_to_candidate_order():
    router = HeavyLightRouter(threshold_tokens=100, heavy_frac=0.5,
                              metric="queue")
    clients = [_StubClient(f"c{i}", load=i) for i in range(4)]
    heavy_req = Request(arrival=0.0, input_tokens=200, output_tokens=8,
                        stages=[Stage(LLM)])
    light_req = Request(arrival=0.0, input_tokens=10, output_tokens=8,
                        stages=[Stage(LLM)])
    import itertools
    for perm in itertools.permutations(clients):
        # heavy pool = name-sorted prefix {c0, c1}; c0 has the least load
        assert router.route(heavy_req, list(perm), 0.0).name == "c0"
        # light pool = {c2, c3}; c2 has the least load
        assert router.route(light_req, list(perm), 0.0).name == "c2"


def test_coordinator_config_default_is_not_shared():
    c1 = Coordinator([])
    c1.cfg.straggler_deadline = 123.0
    c1.cfg.prefix_migration = True
    c2 = Coordinator([])
    assert c2.cfg.straggler_deadline is None
    assert c2.cfg.prefix_migration is False
    assert CoordinatorConfig().straggler_deadline is None
