"""Directional checks of the paper's headline claims (§I contributions,
§IV-A, §V-A). These are qualitative — the simulator must reproduce the
paper's orderings, not its absolute numbers."""
import numpy as np
import pytest

from repro.core import SLO, SystemSpec, WorkloadConfig, build_system, generate
from repro.core.workload import AZURE_CODE, AZURE_CONV


def _run(strategy: str, rate: float, n=60, trace=AZURE_CONV, **wl_kw):
    if strategy == "disaggregated":
        spec = SystemSpec(strategy="disaggregated", n_prefill=2, n_decode=2,
                          with_pre_post=False)
    else:
        spec = SystemSpec(n_llm_clients=4, strategy=strategy,
                          with_pre_post=False)
    coord = build_system(spec)
    wl = WorkloadConfig(trace=trace, rate=rate, n_requests=n,
                        disaggregated=(strategy == "disaggregated"),
                        postprocess=False, seed=21, **wl_kw)
    coord.submit(generate(wl))
    m = coord.run()
    horizon = max(r.completion_time for r in m.serviced)
    return m.summary(horizon=horizon, total_energy=coord.total_energy,
                     slo=SLO())


def test_static_batching_has_worst_ttft():
    s_static = _run("static", 3.0)
    s_cont = _run("continuous", 3.0)
    assert s_static["ttft_p50"] > 5 * s_cont["ttft_p50"]


def test_continuous_best_ttft_at_low_rate():
    """Paper: 'Continuous batching is optimal for TTFT in most cases'."""
    s = {k: _run(k, 1.0) for k in ("continuous", "chunked", "static")}
    best = min(s, key=lambda k: s[k]["ttft_p50"])
    assert best == "continuous" or (
        s["continuous"]["ttft_p50"] <= 1.1 * s[best]["ttft_p50"])


def test_disaggregated_best_throughput_per_energy():
    """Paper key observation i): disaggregated gives highest thpt/energy in
    most cases (decode-only clients are memory-bound, burn less power)."""
    s = {k: _run(k, 3.0) for k in ("continuous", "chunked", "disaggregated")}
    best = max(s, key=lambda k: s[k].get("tok_per_joule", 0.0))
    assert best == "disaggregated", {k: v.get("tok_per_joule") for k, v in s.items()}


def test_chunked_sustains_higher_injection():
    """Paper key observation ii): chunked sustains higher injection rates
    (throughput holds up under load) at the cost of TTFT."""
    lo = _run("chunked", 2.0, trace=AZURE_CODE)
    hi = _run("chunked", 8.0, trace=AZURE_CODE)
    hi_cont = _run("continuous", 8.0, trace=AZURE_CODE)
    assert hi["throughput_tok_s"] >= 0.95 * lo["throughput_tok_s"]
    assert hi["throughput_tok_s"] >= hi_cont["throughput_tok_s"] * 0.95


def test_reasoning_inflates_memory_and_latency():
    """§IV-A: multi-path reasoning multiplies KV demand and token load."""
    plain = _run("continuous", 1.0)
    reason = _run("continuous", 1.0, pipeline="reasoning",
                  reasoning_scale=4.0, reasoning_branches=8)
    assert reason["tokens"] > 4 * plain["tokens"]
    assert reason["e2e_p50"] > plain["e2e_p50"]


def test_rag_needs_looser_ttft_slo():
    """RAG adds embed+retrieve before prefill -> paper uses a 1000ms TTFT
    baseline instead of 250ms."""
    coord = build_system(SystemSpec(n_llm_clients=2, with_rag=True,
                                    rag_embed_on_npu=True,
                                    with_pre_post=False))
    wl = WorkloadConfig(rate=1.0, n_requests=30, pipeline="rag",
                        postprocess=False, seed=23)
    coord.submit(generate(wl))
    m = coord.run()
    plain = _run("continuous", 1.0, n=30)
    assert m.summary()["ttft_p50"] > plain["ttft_p50"]


def test_recompute_competitive_for_short_kv_only():
    """§V-B: recomputation is viable for short KV, prohibitive for long."""
    from repro.configs import get_config
    from repro.perfmodel import analytical as ana
    from repro.perfmodel.hardware import ClusterSpec, H100, TIER_RACK
    from repro.core.memory import expected_retrieval_latency
    model = get_config("llama3_70b")
    cluster = ClusterSpec(H100, 2, 2)
    kvb = ana.kv_bytes_per_token(model)
    for tokens, expect_retrieval_wins in ((4_000, False), (24_000, True)):
        recompute = ana.prefill_time(model, cluster, tokens).time
        retrieve = expected_retrieval_latency(tokens * kvb, [TIER_RACK],
                                              miss_cost=recompute)
        if expect_retrieval_wins:
            assert recompute > retrieve
        # short-KV: recompute within ~2x of rack retrieval => competitive
        else:
            assert recompute < 2.0 * retrieve


def test_embedding_on_npu_beats_small_cpu():
    """§IV-B Fig. 9: offloading a large embed model to an NPU cuts TTFT."""
    res = {}
    for npu in (False, True):
        coord = build_system(SystemSpec(
            n_llm_clients=1, with_rag=True, rag_colocated=not npu,
            rag_embed_on_npu=npu, with_pre_post=False))
        wl = WorkloadConfig(rate=0.3, n_requests=15, pipeline="rag",
                            postprocess=False, seed=29)
        coord.submit(generate(wl))
        res[npu] = coord.run().summary()["ttft_p50"]
    assert res[True] <= res[False]
