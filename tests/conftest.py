import os

# keep smoke tests on 1 device; the dry-run (and ONLY the dry-run) forces 512
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)
