import importlib.util
import os
import sys

# keep smoke tests on 1 device; the dry-run (and ONLY the dry-run) forces 512
os.environ.setdefault("JAX_PLATFORMS", "cpu")

# Property tests want hypothesis; fall back to the bundled miniature shim
# (seeded random sweeps, same decorator surface) when it isn't installed.
try:
    import hypothesis  # noqa: F401
except ImportError:
    _spec = importlib.util.spec_from_file_location(
        "_hypothesis_fallback",
        os.path.join(os.path.dirname(__file__), "_hypothesis_fallback.py"))
    _fb = importlib.util.module_from_spec(_spec)
    _spec.loader.exec_module(_fb)
    _mod = _fb.build_module()
    sys.modules["hypothesis"] = _mod
    sys.modules["hypothesis.strategies"] = _mod.strategies

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)
