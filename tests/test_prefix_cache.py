"""Shared-prefix radix cache + copy-on-write paged KV blocks: property,
regression and integration tests (paper §IV-A reasoning branch sharing,
RAG/system-prompt prefix reuse)."""
import itertools

import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.core import SystemSpec, WorkloadConfig, build_system, generate
from repro.core.llm_scheduler import LLMScheduler, SchedulerLimits
from repro.core.memory import PagedKVAllocator
from repro.core.request import LLM, Request, Stage
from repro.core.router import PrefixAffinityRouter
from repro.core.workload import TraceSpec
from repro.perfmodel.hardware import ClusterSpec, H100, TIER_HOST_DRAM

MODEL = get_config("llama3_70b")
CLUSTER = ClusterSpec(H100, n_chips=2, tp=2)

SMALL_TRACE = TraceSpec("t", input_mean=300, input_std=0.3, output_mean=48,
                        output_std=0.3, input_max=600, output_max=96)


def _chain(group: int, n_blocks: int):
    """Deterministic hash chain standing in for block-aligned content."""
    out, h = [], 0
    for i in range(n_blocks):
        h = hash((h, group, i))
        out.append(h)
    return out


def _drive(sched, reqs, guard=50_000):
    for r in reqs:
        sched.add(r)
    now, finished, steps = 0.0, [], 0
    while sched.has_work() and steps < guard:
        step = sched.plan_step()
        assert step is not None, "work pending but no step planned"
        now += step.duration
        finished += sched.finish_step(step, now)
        steps += 1
    return finished


# ---------------------------------------------------------------------------
# allocator properties (hypothesis): refcount conservation
# ---------------------------------------------------------------------------

@given(ops=st.lists(st.tuples(st.integers(0, 6), st.integers(0, 9),
                              st.integers(1, 120), st.integers(0, 2)),
                    min_size=1, max_size=100),
       block_tokens=st.sampled_from([4, 16]))
@settings(max_examples=40, deadline=None)
def test_fork_and_release_never_leak_or_double_free(ops, block_tokens):
    """Random allocate-with-prefix / fork / append / free / drop / swap-out /
    swap-in sequences over partial-prefix sharers (same group, different
    lengths -> proper-prefix chains): per-block refcounts always equal the
    number of tables referencing the block, the free list + live + cached
    blocks partition the pool, swapping a chain interior never strands an
    orphaned cached descendant, and releasing everything (cache included)
    refills the pool exactly."""
    kv = PagedKVAllocator(capacity_bytes=300.0 * block_tokens,
                          bytes_per_token=1.0, block_tokens=block_tokens,
                          swap_tiers=(TIER_HOST_DRAM,))
    live = []
    fresh = itertools.count()
    for op, sel, amount, group in ops:
        on_dev = [r for r in live if kv.tables[r].on_device]
        swapped = [r for r in live if not kv.tables[r].on_device]
        if op == 0:
            rid = ("r", next(fresh))
            hashes = _chain(group, kv.blocks_for_tokens(amount))
            if kv.allocate(rid, amount, prefix_hashes=hashes):
                live.append(rid)
        elif op == 1 and on_dev:
            kv.append_tokens(on_dev[sel % len(on_dev)], amount)
        elif op == 2 and live:
            kv.free(live.pop(sel % len(live)))
        elif op == 3 and on_dev:
            child = ("f", next(fresh))
            kv.fork(on_dev[sel % len(on_dev)], child)
            live.append(child)
        elif op == 4 and live:
            kv.drop(live.pop(sel % len(live)))
        elif op == 5 and on_dev:
            kv.swap_out(on_dev[sel % len(on_dev)])   # may refuse (shared)
        elif op == 6 and swapped:
            kv.swap_in(swapped[sel % len(swapped)])  # may refuse (no room)
        kv.check_invariants()       # refcount + partition + overflow checks
        assert kv.used_blocks <= kv.num_blocks
    for rid in live:
        kv.free(rid)
    assert kv.used == 0.0
    kv.clear_cache()
    assert kv.free_blocks == kv.num_blocks
    assert all(t.used == 0.0 for t in kv.tiers)
    kv.check_invariants()


def test_radix_eviction_only_reclaims_refcount_zero_blocks():
    """LRU eviction may only touch cached (refcount-0) blocks: pages still
    referenced by a live table survive any allocation pressure."""
    B = 4
    kv = PagedKVAllocator(capacity_bytes=40.0 * B, bytes_per_token=1.0,
                          block_tokens=B)
    assert kv.num_blocks == 40
    shared = _chain(0, 10)
    assert kv.allocate("a", 40, prefix_hashes=shared)
    assert kv.allocate("b", 40, prefix_hashes=shared)   # full 10-block hit
    assert kv.prefix_hit_blocks == 10 and kv.used_blocks == 10
    kv.free("a")                                         # b keeps every page
    survivor = list(kv.tables["b"].blocks)
    assert kv.cached_blocks == 0                         # still live via b
    assert kv.allocate("c", 80, prefix_hashes=_chain(1, 20))
    kv.free("c")                                         # 20 blocks now cached
    assert kv.cached_blocks == 20
    # demand more than the free list: must evict cached, never b's pages
    assert kv.allocate("d", 100)                         # 25 blocks, 10 free
    assert kv.radix_evictions == 15
    assert kv.tables["b"].blocks == survivor
    assert all(kv.refcount[blk] >= 1 for blk in survivor)
    kv.check_invariants()


def test_cow_append_copies_shared_partial_tail_only():
    """Writing into a shared partial tail block copies that one block; full
    shared prefix blocks stay shared (copy-on-write, not copy-on-fork)."""
    B = 8
    kv = PagedKVAllocator(capacity_bytes=100.0 * B, bytes_per_token=1.0,
                          block_tokens=B)
    assert kv.allocate(1, 20)        # 3 blocks, last holds 4/8 tokens
    kv.fork(1, 2)
    assert kv.used_blocks == 3 and kv.cow_forks == 1
    assert kv.append_tokens(2, 1)    # diverges: copies only the tail block
    assert kv.cow_copied_blocks == 1 and kv.used_blocks == 4
    assert kv.tables[1].blocks[:2] == kv.tables[2].blocks[:2]
    assert kv.tables[1].blocks[2] != kv.tables[2].blocks[2]
    assert kv.append_tokens(1, 1)    # parent's tail now refcount-1: no copy
    assert kv.cow_copied_blocks == 1
    kv.check_invariants()


def test_group_grow_exact_fit_needs_no_spurious_fault():
    """The group capacity plan must charge m-1 COW copies for m siblings
    sharing one tail (the last sibling keeps the original block)."""
    B = 8
    kv = PagedKVAllocator(capacity_bytes=6.0 * B, bytes_per_token=1.0,
                          block_tokens=B)
    assert kv.num_blocks == 6
    assert kv.allocate(1, 20)            # 3 blocks, partial tail
    kv.fork(1, 2)
    kv.fork(1, 3)
    assert kv.free_blocks == 3           # room for exactly the 2 copies
    assert kv.grow_request([1, 2, 3], 1)  # needs 2 copies, not 3
    assert kv.page_faults == 0 and kv.cow_copied_blocks == 2
    kv.check_invariants()


def test_swap_roundtrip_restores_radix_registration():
    """Swap-out unregisters the prefix chain (content leaves the device);
    swap-in re-registers it so later same-prefix admissions hit again."""
    B = 4
    kv = PagedKVAllocator(capacity_bytes=100.0 * B, bytes_per_token=1.0,
                          block_tokens=B, swap_tiers=(TIER_HOST_DRAM,))
    chain = _chain(3, 5)
    assert kv.allocate("a", 20, prefix_hashes=chain)
    assert kv.swap_out("a") is not None
    assert kv.peek_prefix_tokens(chain) == 0
    assert kv.swap_in("a") is not None
    assert kv.peek_prefix_tokens(chain) == 20
    assert kv.allocate("b", 20, prefix_hashes=chain)   # full hit again
    assert kv.prefix_hit_blocks == 5
    kv.check_invariants()


def test_swap_refuses_shared_pages():
    """PR 1 swap preemption composes with sharing: only refcount-1 tables
    may swap (a shared page cannot move without stranding its owners)."""
    kv = PagedKVAllocator(capacity_bytes=1000.0, bytes_per_token=1.0,
                          block_tokens=10, swap_tiers=(TIER_HOST_DRAM,))
    assert kv.allocate(1, 100)
    kv.fork(1, 2)
    assert kv.swap_out(1) is None and kv.swap_out(2) is None
    kv.free(2)
    assert kv.swap_out(1) is not None    # sole owner again: swappable
    kv.check_invariants()


def test_sharing_metrics_unpolluted_by_swap_churn_or_failed_admission():
    """Swap round-trips resume existing logical references, so dedup_ratio
    must not dilute under preemption churn; a failed admission rolls its
    matched-prefix increfs back without recording a phantom sharing peak."""
    B = 4
    kv = PagedKVAllocator(capacity_bytes=10.0 * B, bytes_per_token=1.0,
                          block_tokens=B, swap_tiers=(TIER_HOST_DRAM,))
    assert kv.allocate("a", 5 * B, prefix_hashes=_chain(0, 5))
    refs0, alloc0 = kv.block_refs_total, kv.blocks_allocated_total
    for _ in range(3):
        assert kv.swap_out("a") is not None
        assert kv.swap_in("a") is not None
    assert (kv.block_refs_total, kv.blocks_allocated_total) == (refs0, alloc0)
    assert kv.stats()["dedup_ratio"] == 1.0      # no sharing ever happened
    assert kv.allocate("b", 5 * B)               # pool now full
    assert not kv.allocate("c", 10 * B, prefix_hashes=_chain(0, 5))
    assert kv.shared_blocks_peak == 0 and kv.stats()["shared_blocks"] == 0
    kv.check_invariants()


def test_swap_out_cascades_orphaned_cached_descendants():
    """Regression: swapping out the sole owner of a chain interior must
    cascade-unregister its cached descendants. An orphan surviving under a
    dangling parent hash corrupted the re-registered parent's child links
    after swap-in, leaving a cached block permanently unevictable (counted
    in available_blocks but unreclaimable -> in-budget allocations failed)."""
    B = 4
    kv = PagedKVAllocator(capacity_bytes=10.0 * B, bytes_per_token=1.0,
                          block_tokens=B, swap_tiers=(TIER_HOST_DRAM,))
    h0, h1 = _chain(9, 2)
    assert kv.allocate("t1", 2 * B, prefix_hashes=[h0, h1])
    assert kv.allocate("t2", B, prefix_hashes=[h0])      # shares h0 only
    kv.free("t1")                     # h1's block cached under parent h0
    assert kv.cached_blocks == 1
    assert kv.swap_out("t2") is not None  # h0 leaves: h1 must go with it
    assert kv.cached_blocks == 0 and kv.free_blocks == kv.num_blocks
    kv.check_invariants()
    assert kv.swap_in("t2") is not None   # h0 re-registers as a new node
    kv.check_invariants()
    kv.free("t2")
    assert kv.allocate("t3", kv.num_blocks * B)  # whole pool: cache reclaims
    assert kv.used_blocks == kv.num_blocks
    kv.check_invariants()


# ---------------------------------------------------------------------------
# regression: branches=k shares the prefill 1x, not kx
# ---------------------------------------------------------------------------

def test_branches_share_prefill_pages_once():
    """With branches=4 and prefix sharing on, the shared prefill occupies
    ~1x its pages while each branch owns only divergent decode pages; the
    logical footprint (sum of table lengths) stays ~4x the physical one."""
    sched = LLMScheduler("continuous", MODEL, CLUSTER,
                         limits=SchedulerLimits(max_batch=8))
    r = Request(arrival=0.0, input_tokens=512, output_tokens=40,
                stages=[Stage(LLM)], branches=4)
    _drive(sched, [r])
    kv = sched.kv
    assert r.decoded_tokens == r.output_tokens
    prefill_blocks = kv.blocks_for_tokens(512)
    decode_blocks_per_branch = kv.blocks_for_tokens(40) + 1
    # peak physical: one shared prefill + 4 private decode tails — not 4x
    assert kv.peak_blocks <= prefill_blocks + 4 * decode_blocks_per_branch
    assert kv.peak_blocks < 2 * prefill_blocks
    s = kv.stats()
    assert s["cow_forks"] == 3                   # one fork per extra branch
    assert s["shared_blocks"] >= prefill_blocks  # prefill pages went rc=4
    assert s["dedup_ratio"] > 1.5
    assert kv.used == 0.0
    kv.check_invariants()


def test_branch_sharing_off_reproduces_pr1_footprint():
    """prefix_caching=False must reproduce the pre-radix behavior exactly:
    one table, no forks, no sharing counters."""
    sched = LLMScheduler("continuous", MODEL, CLUSTER,
                         limits=SchedulerLimits(max_batch=8,
                                                prefix_caching=False))
    r = Request(arrival=0.0, input_tokens=512, output_tokens=40,
                stages=[Stage(LLM)], branches=4)
    _drive(sched, [r])
    s = sched.kv.stats()
    assert s["cow_forks"] == 0 and s["shared_blocks"] == 0
    assert s["prefix_hit_tokens"] == 0 and s["dedup_ratio"] == 1.0


def test_sharing_knobs_off_is_behavior_neutral():
    """Workloads without prefix identity produce identical token timelines
    whether the radix cache is enabled or not, and default workload
    generation carries no prefix segments."""
    reqs = generate(WorkloadConfig(trace=SMALL_TRACE, n_requests=10, rate=4.0,
                                   seed=1, postprocess=False))
    assert all(r.prefix_segments == () for r in reqs)

    def timeline(prefix_caching):
        sched = LLMScheduler(
            "continuous", MODEL, CLUSTER,
            limits=SchedulerLimits(max_batch=4, kv_capacity_frac=0.02,
                                   prefix_caching=prefix_caching))
        rs = [Request(arrival=0.0, input_tokens=400, output_tokens=60,
                      stages=[Stage(LLM)]) for _ in range(6)]
        done = _drive(sched, rs)
        assert len(done) == 6
        return {i: list(r.token_times)
                for i, r in enumerate(sorted(done, key=lambda r: r.rid))}

    base = timeline(False)
    got = timeline(True)
    for k in base:
        assert got[k] == pytest.approx(base[k])


# ---------------------------------------------------------------------------
# admission discounts: cached_tokens becomes a real lookup
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("strategy", ["continuous", "chunked", "static",
                                      "mixed"])
def test_second_same_prefix_request_gets_prefill_discount(strategy):
    sched = LLMScheduler(strategy, MODEL, CLUSTER,
                         limits=SchedulerLimits(max_batch=8, chunk_size=256))
    seg = (("sys0", 256),)
    r1 = Request(arrival=0.0, input_tokens=300, output_tokens=8,
                 stages=[Stage(LLM)], prefix_segments=seg)
    _drive(sched, [r1])
    assert r1.cached_tokens == 0          # cold cache: full prefill
    r2 = Request(arrival=0.0, input_tokens=300, output_tokens=8,
                 stages=[Stage(LLM)], prefix_segments=seg)
    _drive(sched, [r2])
    B = sched.kv.block_tokens
    assert r2.cached_tokens == (256 // B) * B   # real, block-aligned lookup
    assert sched.kv.prefix_hit_tokens > 0


def test_kv_pipeline_real_lookup_mode():
    """With a shared-prefix pool the kv pipeline stops granting fiat
    cached_tokens: the first request pays full prefill, repeats hit the
    radix cache and get the discount for real."""
    wl = WorkloadConfig(trace=SMALL_TRACE, n_requests=12, rate=4.0, seed=2,
                        pipeline="kv", kv_cached_tokens=512,
                        shared_prefix_pool=1, postprocess=False)
    reqs = generate(wl)
    assert all(r.cached_tokens == 0 for r in reqs)       # nothing is free
    # the widely-shared system prompt leads; the kv context follows it so
    # both stay inside one shareable block-aligned prefix
    assert all(r.prefix_segments[0][0] == "sys0" for r in reqs)
    assert all(r.prefix_segments[1][0] == "kvctx0" for r in reqs)
    # the retrieval stage still prices fetching the candidate context
    from repro.core.request import KV_RETRIEVAL
    for r in reqs:
        (kv_stage,) = [s for s in r.stages if s.kind == KV_RETRIEVAL]
        assert kv_stage.params["candidate_tokens"] == 512
    spec = SystemSpec(n_llm_clients=1, with_pre_post=False,
                      with_kv_retrieval=True)
    coord = build_system(spec)
    coord.submit(reqs)
    m = coord.run()
    assert len(m.serviced) == 12
    s = m.summary()
    assert s["kv_prefix_hit_tokens"] > 0
    assert sum(r.cached_tokens for r in m.serviced) > 0  # discounts granted


def test_rag_chunk_pool_generates_distinct_shareable_chunks():
    """RAG chunk-identity mode draws k *distinct* pooled chunks (context size
    equals fiat mode's, so enabling the knob measures sharing rather than a
    lighter workload), orders them after the system prompt inside the
    shareable prefix, and produces real radix hits end to end."""
    wl = WorkloadConfig(trace=SMALL_TRACE, n_requests=16, rate=4.0, seed=3,
                        pipeline="rag", rag_added_tokens=1500,
                        rag_chunk_tokens=500, rag_chunk_pool=4,
                        shared_prefix_pool=1, shared_prefix_tokens=256,
                        postprocess=False)
    reqs = generate(wl)
    for r in reqs:
        assert r.rag_tokens == 1500               # 3 distinct chunks, always
        assert r.prefix_segments[0][0] == "sys0"  # system prompt leads
        docs = [seg for seg, _ in r.prefix_segments[1:]]
        assert len(docs) == len(set(docs)) == 3
        assert all(d.startswith("doc") for d in docs)
    spec = SystemSpec(n_llm_clients=1, with_rag=True, with_pre_post=False)
    coord = build_system(spec)
    coord.submit(reqs)
    m = coord.run()
    assert len(m.serviced) == 16
    assert m.summary()["kv_prefix_hit_tokens"] > 0
    # a pool too small for k distinct chunks would silently lighten the
    # workload vs fiat mode: refuse it instead
    with pytest.raises(ValueError, match="distinct chunks"):
        generate(WorkloadConfig(trace=SMALL_TRACE, n_requests=1, rate=1.0,
                                seed=3, pipeline="rag", rag_added_tokens=1500,
                                rag_chunk_tokens=500, rag_chunk_pool=2))


# ---------------------------------------------------------------------------
# end-to-end: acceptance metrics + routing
# ---------------------------------------------------------------------------

def test_end_to_end_branches_and_sharing_metrics():
    spec = SystemSpec(n_llm_clients=2, with_pre_post=False,
                      router_policy="prefix_affinity")
    coord = build_system(spec)
    wl = WorkloadConfig(trace=SMALL_TRACE, n_requests=20, rate=2.0, seed=7,
                        pipeline="reasoning", reasoning_scale=2.0,
                        reasoning_branches=4, shared_prefix_pool=2,
                        shared_prefix_tokens=512, postprocess=False)
    coord.submit(generate(wl))
    m = coord.run()
    assert len(m.serviced) == 20
    s = m.summary()
    assert s["kv_prefix_hit_tokens"] > 0
    assert s["kv_cow_forks"] > 0
    assert s["kv_shared_blocks"] > 0
    assert s["kv_dedup_ratio"] > 1.0
    for c in coord.clients.values():
        c.scheduler.kv.check_invariants()
        assert c.kv_stats()["used_blocks"] == 0


def test_refetch_pricing_dedups_radix_resident_prefix():
    """Decode-side refetch after a recompute preemption prices only the
    non-resident context bytes — the pages the radix lookup maps locally at
    re-admission ride free, consistent with the coordinator's first-handoff
    wire dedup."""
    sched = LLMScheduler("continuous", MODEL, CLUSTER,
                         limits=SchedulerLimits(max_batch=8))
    seg = (("sysR", 256),)
    warm = Request(arrival=0.0, input_tokens=300, output_tokens=8,
                   stages=[Stage(LLM)], prefix_segments=seg)
    _drive(sched, [warm])                        # chain stays radix-cached
    cold = Request(arrival=0.0, input_tokens=300, output_tokens=8,
                   stages=[Stage(LLM)], prefix_segments=seg)
    ctx = cold.total_context
    sched._needs_refetch.add(cold.rid)
    assert sched._admit_decode(cold)
    B = sched.kv.block_tokens
    hit = (256 // B) * B
    assert hit > 0
    expected = (ctx - hit) * sched.kv_per_token
    assert sched._pending_swap_bytes == pytest.approx(expected)


def test_disaggregated_handoff_dedups_warm_prefix_bytes():
    """Prefill->decode KV shipping skips pages the decode client's radix
    cache already holds; the saved wire bytes are counted."""
    def comm(sharing):
        limits = SchedulerLimits(prefix_caching=sharing)
        spec = SystemSpec(strategy="disaggregated", n_prefill=1, n_decode=1,
                          limits=limits, with_pre_post=False)
        coord = build_system(spec)
        wl = WorkloadConfig(trace=SMALL_TRACE, n_requests=15, rate=2.0,
                            seed=4, disaggregated=True, shared_prefix_pool=1,
                            shared_prefix_tokens=512, postprocess=False)
        coord.submit(generate(wl))
        m = coord.run()
        assert len(m.serviced) == 15
        return m
    on, off = comm(True), comm(False)
    assert on.kv_transfer_dedup_bytes > 0
    assert off.kv_transfer_dedup_bytes == 0
    assert on.comm_bytes < off.comm_bytes


def test_prefix_affinity_router_prefers_warm_client():
    spec = SystemSpec(n_llm_clients=2, with_pre_post=False)
    coord = build_system(spec)
    c0, c1 = (coord.clients["llm0"], coord.clients["llm1"])
    seg = (("sys7", 512),)
    warm = Request(arrival=0.0, input_tokens=600, output_tokens=8,
                   stages=[Stage(LLM)], prefix_segments=seg)
    _drive(c0.scheduler, [warm])
    assert c0.prefix_hit_tokens(warm) > 0 and c1.prefix_hit_tokens(warm) == 0
    router = PrefixAffinityRouter(metric="queue")
    probe = Request(arrival=0.0, input_tokens=600, output_tokens=8,
                    stages=[Stage(LLM)], prefix_segments=seg)
    assert router.route(probe, [c1, c0], now=0.0) is c0
    # identity-less requests fall back to pure load balance
    plain = Request(arrival=0.0, input_tokens=600, output_tokens=8,
                    stages=[Stage(LLM)])
    c1.scheduler.waiting.append(plain)       # load c1
    assert router.route(plain, [c1, c0], now=0.0) is c0


def test_router_least_work_uses_effective_prefill_tokens():
    """Satellite: KV-retrieval/RAG requests must not repel the router — the
    input_len load metric counts uncached (effective) prefill tokens."""
    spec = SystemSpec(n_llm_clients=1, with_pre_post=False)
    coord = build_system(spec)
    (client,) = coord.clients.values()
    r = Request(arrival=0.0, input_tokens=1500, output_tokens=8,
                stages=[Stage(LLM)], cached_tokens=1000, rag_tokens=100)
    client.scheduler.waiting.append(r)
    assert client.load("input_len") == 600   # 1500 - 1000 + 100
