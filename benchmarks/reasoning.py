"""Reasoning goodput (paper Fig. 8): SLO-compliant goodput vs injection rate
for conv/code traces with multi-path reasoning branches."""
from __future__ import annotations

import time
from typing import List

from benchmarks.common import row
from repro.core import SLO, SystemSpec, WorkloadConfig, build_system, generate
from repro.core.workload import AZURE_CODE, AZURE_CONV


def run() -> List[str]:
    out = []
    cases = [("conv", AZURE_CONV, 8, 2.0), ("code", AZURE_CODE, 4, 2.0)]
    for tname, trace, branches, scale in cases:
        for strat in ("continuous", "chunked", "disaggregated"):
            for rate in (0.25, 0.5, 1.0):
                t0 = time.perf_counter()
                spec = (SystemSpec(strategy="disaggregated", n_prefill=2,
                                   n_decode=2, with_pre_post=False)
                        if strat == "disaggregated"
                        else SystemSpec(n_llm_clients=4, strategy=strat,
                                        with_pre_post=False))
                coord = build_system(spec)
                wl = WorkloadConfig(trace=trace, rate=rate, n_requests=40,
                                    pipeline="reasoning",
                                    reasoning_scale=scale,
                                    reasoning_branches=branches,
                                    disaggregated=(strat == "disaggregated"),
                                    postprocess=False, seed=5)
                coord.submit(generate(wl))
                m = coord.run()
                horizon = max(r.completion_time for r in m.serviced)
                slo = SLO()
                good = m.goodput(slo, horizon)
                us = (time.perf_counter() - t0) * 1e6
                out.append(row(
                    f"reasoning_{tname}_{strat}_r{rate}", us,
                    f"goodput={good:.0f}tok/s "
                    f"thpt={m.throughput(horizon):.0f} "
                    f"ttft_p90={m.summary()['ttft_p90']*1e3:.0f}ms"))
    return out
