"""Global vs local disaggregation (paper §II-B): local pairs prefill/decode
clients on fast intra-platform links, cutting KV-transfer time at the cost of
load-balancing freedom. Also quantifies full vs layerwise transfer
granularity (paper §III-B2).

Two pricing arms per (mode, granularity) cell, reported side by side:

* **analytical** — the catalog ``LinkSpec`` constants (NVLink / rack
  ethernet) the system builder wires by default.
* **measured** — the prefill->decode links re-priced with the alpha-beta fit
  that ``benchmarks/engine_disagg.py`` extracted from REAL timed KV-page
  handoffs (``BENCH_engine_disagg.json``); emitted only when that artifact
  exists, so this module stays runnable standalone.
"""
from __future__ import annotations

import json
import os
import time
from typing import List, Optional

from benchmarks.common import row
from repro.core import SystemSpec, WorkloadConfig, build_system, generate
from repro.core.workload import AZURE_CODE

MEASURED_JSON = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                             "BENCH_engine_disagg.json")


def _measured_link():
    """The fitted handoff LinkSpec from engine_disagg's artifact, or None
    when it has not been produced on this host."""
    try:
        with open(MEASURED_JSON) as f:
            fl = json.load(f)["results"][0]["fitted_link"]
        bw, alpha = fl["bandwidth_bytes_per_s"], fl["latency_s"]
        if not (bw and bw > 0 and alpha >= 0):
            return None
        from repro.perfmodel.hardware import LinkSpec
        return LinkSpec(fl.get("name", "measured"), bw, alpha)
    except (OSError, KeyError, IndexError, ValueError):
        return None


def _run(mode: str, gran: str, rate: float = 3.0, link=None):
    spec = SystemSpec(strategy="disaggregated", n_prefill=2, n_decode=2,
                      disaggregation=mode, kv_transfer_granularity=gran,
                      with_pre_post=False)
    coord = build_system(spec)
    if link is not None:
        # re-price the prefill->decode fabric only; swap/retrieval PCIe
        # paths keep their catalog constants
        coord.network.override_link("rack", link)
        coord.network.override_link("nvlink", link)
    wl = WorkloadConfig(trace=AZURE_CODE, rate=rate, n_requests=60,
                        disaggregated=True, postprocess=False, seed=31)
    coord.submit(generate(wl))
    m = coord.run()
    horizon = max(r.completion_time for r in m.serviced)
    s = m.summary(horizon=horizon, total_energy=coord.total_energy)
    s["comm_bytes"] = m.comm_bytes
    return s


def run() -> List[str]:
    out = []
    measured = _measured_link()
    arms = [("", None)] + ([("_measured", measured)] if measured else [])
    for mode in ("global", "local"):
        for gran in ("full", "layerwise"):
            for suffix, link in arms:
                t0 = time.perf_counter()
                s = _run(mode, gran, link=link)
                us = (time.perf_counter() - t0) * 1e6
                out.append(row(
                    f"disagg_{mode}_{gran}{suffix}", us,
                    f"ttft_p50={s['ttft_p50']*1e3:.0f}ms "
                    f"ttft_p90={s['ttft_p90']*1e3:.0f}ms "
                    f"tpot_p50={s['tpot_p50']*1e3:.1f}ms "
                    f"kv_transferred={s['comm_bytes']/1e9:.1f}GB"))
    if measured is None:
        out.append("# no BENCH_engine_disagg.json - analytical arm only "
                   "(run benchmarks/engine_disagg.py to calibrate)")
    return out
