"""Global vs local disaggregation (paper §II-B): local pairs prefill/decode
clients on fast intra-platform links, cutting KV-transfer time at the cost of
load-balancing freedom. Also quantifies full vs layerwise transfer
granularity (paper §III-B2)."""
from __future__ import annotations

import time
from typing import List

from benchmarks.common import row
from repro.core import SystemSpec, WorkloadConfig, build_system, generate
from repro.core.workload import AZURE_CODE


def _run(mode: str, gran: str, rate: float = 3.0):
    spec = SystemSpec(strategy="disaggregated", n_prefill=2, n_decode=2,
                      disaggregation=mode, kv_transfer_granularity=gran,
                      with_pre_post=False)
    coord = build_system(spec)
    wl = WorkloadConfig(trace=AZURE_CODE, rate=rate, n_requests=60,
                        disaggregated=True, postprocess=False, seed=31)
    coord.submit(generate(wl))
    m = coord.run()
    horizon = max(r.completion_time for r in m.serviced)
    s = m.summary(horizon=horizon, total_energy=coord.total_energy)
    s["comm_bytes"] = m.comm_bytes
    return s


def run() -> List[str]:
    out = []
    for mode in ("global", "local"):
        for gran in ("full", "layerwise"):
            t0 = time.perf_counter()
            s = _run(mode, gran)
            us = (time.perf_counter() - t0) * 1e6
            out.append(row(
                f"disagg_{mode}_{gran}", us,
                f"ttft_p50={s['ttft_p50']*1e3:.0f}ms "
                f"ttft_p90={s['ttft_p90']*1e3:.0f}ms "
                f"tpot_p50={s['tpot_p50']*1e3:.1f}ms "
                f"kv_transferred={s['comm_bytes']/1e9:.1f}GB"))
    return out
