"""Closed-loop autoscaler benchmark: diurnal + surge traffic vs fleet cost.

ROADMAP item 4's question: can a goodput-driven controller ride a diurnal
trace with a flash surge, matching a peak-provisioned static fleet on
delivered goodput while paying closer to a trough-provisioned one? The
scenario is a two-tier (interactive + batch) workload whose arrival rate
follows a multi-phase schedule (``WorkloadConfig.rate_phases``): overnight
base load, a morning climb, a midday flash surge, an evening trough. Five
arms run the same request population:

- ``static_trough`` / ``static_peak``: fixed fleets at the trough / peak size
- ``autoscale_threshold`` / ``autoscale_target``: closed-loop fleets under
  the two built-in policies, scale-out warmed by prefix migration
- ``autoscale_target_cold``: the target-tracking arm with
  ``warm_on_scale_out`` disabled — the cold-vs-warm TTFT recovery control

Reported per arm: SLO-gated goodput (total and per tier), TTFT p50/p90,
client-seconds cost, makespan, fleet-size trace, scale action log, and TTFT
over the post-scale-out recovery windows (warm vs cold). Emits
``BENCH_autoscale.json`` next to this file.

``--check`` gates (the simulator is deterministic, so these are hard):
- every arm serviced its entire request population (no lost requests)
- goodput(autoscale_target) >= goodput(static_trough): the controller must
  buy real goodput at the surge
- client_seconds(autoscale_target) <= client_seconds(static_peak): and pay
  less than peak provisioning for it
- the warm arm's scaled-out replicas actually serve prefix hits off migrated
  pages (warm hit-tokens > 0); warm recovery TTFT regressing past the cold
  arm's is an advisory warning (wall-clock-free but workload-sensitive)
"""
from __future__ import annotations

import json
import math
import os
import sys
import time
from typing import Dict, List, Optional

if __package__ in (None, ""):                      # `python benchmarks/...`
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))

from benchmarks.common import row
from repro.core import SystemSpec, WorkloadConfig, build_system, generate
from repro.core.autoscaler import (Autoscaler, AutoscalerConfig,
                                   ClientTemplate, make_policy)
from repro.core.llm_scheduler import SchedulerLimits
from repro.core.metrics import SLO, percentile
from repro.core.request import LLM
from repro.core.workload import synthetic_trace

N_REQUESTS = 600
SMOKE_REQUESTS = 360
RATE = 2.0                      # calm (overnight) interactive arrivals/sec
SURGE = 4.0                     # flash-surge rate multiplier
TROUGH_FLEET = 2
PEAK_FLEET = 6
RECOVERY_WINDOW = 2.0           # post-scale-out TTFT observation window

# tier targets an adequately-provisioned fleet can actually meet: TTFT is
# the load-sensitive term (queueing), TPOT is a property of the model/chip
# (~50ms/token here) so its cap sits above that floor — an unachievable
# TPOT target would peg any SLO-aware policy at max fleet forever
TIER_SLOS = {"interactive": SLO(tpot_base=0.075),
             "batch": SLO(ttft_base=2.0, tpot_base=0.100)}

ACFG = AutoscalerConfig(interval=0.25, window=1.0,
                        min_clients=TROUGH_FLEET, max_clients=PEAK_FLEET,
                        cooldown_out=0.25, cooldown_in=1.0)


def _phases(n_requests: int):
    """Diurnal schedule sized to the request population: a calm first third,
    a flash surge over the next eighth of the span, then an evening lull.
    Breakpoints scale with the base span so smoke and full runs see the same
    shape."""
    span = ((2 * n_requests) // 3) / RATE      # interactive-tier base span
    t1 = round(span / 3, 3)
    t2 = round(t1 + span / 8, 3)
    return ((t1, SURGE), (t2, 0.75))


def _workload(n_requests: int) -> List:
    """Two-tier population riding one diurnal phase schedule. Phases are a
    deterministic time-warp, so every arm sees identical requests."""
    phases = _phases(n_requests)
    inter = synthetic_trace(input_mean=256, input_std=0.4, output_mean=64,
                            output_std=0.2, name="interactive")
    batch = synthetic_trace(input_mean=768, input_std=0.5, output_mean=128,
                            output_std=0.2, name="batch")
    n_inter = (2 * n_requests) // 3
    reqs = generate(WorkloadConfig(
        trace=inter, rate=RATE, n_requests=n_inter, process="poisson",
        postprocess=False, seed=31, shared_prefix_pool=6,
        shared_prefix_tokens=256, rate_phases=phases))
    for r in reqs:
        r.tier = "interactive"
    breqs = generate(WorkloadConfig(
        trace=batch, rate=RATE / 2, n_requests=n_requests - n_inter,
        process="poisson", postprocess=False, seed=32,
        shared_prefix_pool=6, shared_prefix_tokens=256, rate_phases=phases))
    for r in breqs:
        r.tier = "batch"
    return reqs + breqs


def _system(n_clients: int) -> "Coordinator":
    spec = SystemSpec(n_llm_clients=n_clients, with_pre_post=False,
                      router_policy="load_based", router_metric="queue",
                      limits=SchedulerLimits(max_batch=16, history_limit=64),
                      prefix_migration=True)
    return build_system(spec)


def _recovery_ttfts(metrics, actions) -> List[float]:
    """TTFTs of requests arriving inside the post-scale-out windows."""
    adds = [t for t, kind, _ in actions if kind == "add"]
    out = []
    for r in metrics.serviced:
        if r.ttft is None:
            continue
        if any(t <= r.arrival <= t + RECOVERY_WINDOW for t in adds):
            out.append(r.ttft)
    return out


def _run_arm(name: str, n_requests: int, n_clients: int,
             policy: Optional[str] = None, warm: bool = True) -> Dict:
    coord = _system(n_clients)
    coord.cfg.warm_on_scale_out = warm
    scaler = None
    if policy is not None:
        base = next(c for c in coord.clients.values() if c.stages == (LLM,))
        scaler = Autoscaler(ClientTemplate.from_client(base),
                            policy=make_policy(policy), cfg=ACFG,
                            slos=TIER_SLOS)
        coord.attach_autoscaler(scaler)
    reqs = _workload(n_requests)
    coord.submit(reqs)
    t0 = time.perf_counter()
    metrics = coord.run()
    wall = time.perf_counter() - t0
    makespan = coord.queue.now
    tiers = metrics.goodput_by_tier(TIER_SLOS, makespan)
    summary = metrics.summary(horizon=makespan, slo=SLO())
    prefix_seen = metrics.kv.get("prefix_tokens_seen", 0)
    return {
        "arm": name,
        "n_requests": len(reqs),
        "n_serviced": len(metrics.serviced),
        "makespan_s": makespan,
        "wall_s": wall,
        "goodput_tok_s": sum(tiers.values()),
        "goodput_by_tier": tiers,
        "throughput_tok_s": summary["throughput_tok_s"],
        "ttft_p50": percentile(metrics.ttfts, 50),
        "ttft_p90": percentile(metrics.ttfts, 90),
        "client_seconds": (scaler.client_seconds if scaler is not None
                           else n_clients * makespan),
        "fleet_trace": (scaler.fleet_trace if scaler is not None
                        else [[0.0, n_clients], [makespan, n_clients]]),
        "actions": (scaler.actions if scaler is not None else []),
        "checks": (scaler.checks if scaler is not None else 0),
        "migration_hit_tokens": metrics.kv.get("migration_hit_tokens", 0),
        "warm_hit_rate": (metrics.kv.get("migration_hit_tokens", 0)
                          / max(prefix_seen, 1)),
        "recovery_ttft_p50": percentile(
            _recovery_ttfts(metrics, scaler.actions if scaler else []), 50),
    }


def _write_json(results: List[Dict], smoke: bool) -> str:
    path = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                        "BENCH_autoscale.json")
    by = {r["arm"]: r for r in results}
    with open(path, "w") as f:
        json.dump({
            "scenario": "two-tier diurnal + flash surge "
                        f"(x{SURGE} surge, 0.75x lull), "
                        f"trough={TROUGH_FLEET} peak={PEAK_FLEET} clients",
            "smoke": smoke,
            "goodput_vs_trough":
                by["autoscale_target"]["goodput_tok_s"]
                / max(by["static_trough"]["goodput_tok_s"], 1e-9),
            "cost_vs_peak":
                by["autoscale_target"]["client_seconds"]
                / max(by["static_peak"]["client_seconds"], 1e-9),
            "results": results,
        }, f, indent=1)
    return path


ARMS = (
    ("static_trough", TROUGH_FLEET, None, True),
    ("static_peak", PEAK_FLEET, None, True),
    ("autoscale_threshold", TROUGH_FLEET, "threshold", True),
    ("autoscale_target", TROUGH_FLEET, "target_tracking", True),
    ("autoscale_target_cold", TROUGH_FLEET, "target_tracking", False),
)


def run(smoke: bool = False) -> List[str]:
    out = []
    n_requests = SMOKE_REQUESTS if smoke else N_REQUESTS
    results = []
    for name, n_clients, policy, warm in ARMS:
        t0 = time.perf_counter()
        r = _run_arm(name, n_requests, n_clients, policy, warm)
        results.append(r)
        us = (time.perf_counter() - t0) * 1e6
        sizes = [n for _, n in r["fleet_trace"]]
        out.append(row(
            f"{name}{'_smoke' if smoke else ''}", us,
            f"goodput={r['goodput_tok_s']:.0f}tok/s "
            f"cost={r['client_seconds']:.1f}cs "
            f"fleet={min(sizes)}..{max(sizes)} "
            f"ttft_p50={r['ttft_p50']:.3f}s "
            f"serviced={r['n_serviced']}/{r['n_requests']}"))
    path = _write_json(results, smoke)
    out.append(row("autoscale_json", 0.0,
                   f"wrote {path} ({len(results)} arms)"))
    return out


def check(results_path: str) -> int:
    """CI gate (see module docstring). The simulator is deterministic, so
    goodput/cost/lost-request gates fail hard; only the warm-vs-cold TTFT
    recovery comparison is advisory."""
    with open(results_path) as f:
        data = json.load(f)
    by = {r["arm"]: r for r in data["results"]}
    errors = []
    for r in data["results"]:
        if r["n_serviced"] != r["n_requests"]:
            errors.append(f"{r['arm']}: lost requests "
                          f"({r['n_serviced']}/{r['n_requests']} serviced)")
    target, trough, peak = (by["autoscale_target"], by["static_trough"],
                            by["static_peak"])
    if target["goodput_tok_s"] < trough["goodput_tok_s"]:
        errors.append(
            f"autoscaled goodput {target['goodput_tok_s']:.0f} tok/s below "
            f"the static trough fleet's {trough['goodput_tok_s']:.0f}")
    if target["client_seconds"] > peak["client_seconds"]:
        errors.append(
            f"autoscaled cost {target['client_seconds']:.1f} client-seconds "
            f"above the static peak fleet's {peak['client_seconds']:.1f}")
    if not target["actions"]:
        errors.append("autoscale_target never scaled: the surge should "
                      "force at least one action")
    if target["migration_hit_tokens"] <= 0:
        errors.append("warm scale-out served no prefix hits off migrated "
                      "pages (migration_hit_tokens == 0)")
    cold = by.get("autoscale_target_cold")
    if cold is not None:
        w, c = target["recovery_ttft_p50"], cold["recovery_ttft_p50"]
        if not math.isnan(w) and not math.isnan(c) and w > c * 1.25:
            print(f"CHECK WARNING: warm recovery TTFT p50 {w:.3f}s exceeds "
                  f"cold arm's {c:.3f}s by >25%", file=sys.stderr)
    for e in errors:
        print(f"CHECK FAILED: {e}", file=sys.stderr)
    return 1 if errors else 0


if __name__ == "__main__":
    smoke = "--smoke" in sys.argv
    for line in run(smoke=smoke):
        print(line)
    if "--check" in sys.argv:
        json_path = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                 "BENCH_autoscale.json")
        raise SystemExit(check(json_path))
