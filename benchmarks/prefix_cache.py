"""Shared-prefix radix cache sweep: reasoning branches x prefix-reuse-rate x
shrinking HBM capacity, sharing on vs off.

The headline number is the *capacity-amplification factor*: how many logical
KV block references the system serves per physical block allocated (the radix
dedup ratio), and the peak-block shrink factor vs the sharing-off baseline.
The paper's reasoning case study (§IV-A) assumes multi-path branches share
the prefill KV and its RAG pipelines repeatedly prepend the same
system-prompt/document chunks — this sweep measures how much batching
capacity that sharing actually buys as ``kv_capacity_frac`` shrinks. Emits
CSV rows for the harness plus a JSON artifact (``prefix_cache.json``,
git-ignored) with the full grid.
"""
from __future__ import annotations

import json
import os
import time
from typing import Dict, List

from benchmarks.common import row
from repro.core import SystemSpec, WorkloadConfig, build_system, generate
from repro.core.llm_scheduler import SchedulerLimits
from repro.core.workload import TraceSpec

BRANCHES = (1, 4)
REUSE_RATES = (0.0, 0.5, 1.0)
CAPACITY_FRACS = (1.0, 0.05, 0.02)
N_REQUESTS = 40
RATE = 3.0
PREFIX_TOKENS = 512
# bounded request sizes so the smallest pools still hold one request and the
# capacity axis maps to batching pressure, not single-request OOM
TRACE = TraceSpec("prefix", input_mean=384, input_std=0.4, output_mean=96,
                  output_std=0.4, input_max=768, output_max=192)


def _run_one(branches: int, reuse: float, frac: float,
             sharing: bool) -> Dict:
    limits = SchedulerLimits(max_batch=32, kv_capacity_frac=frac,
                             prefix_caching=sharing)
    # same router both arms so on-vs-off isolates the radix cache: with
    # sharing off every prefix probe returns 0 and prefix_affinity
    # degenerates to plain load balancing on the same metric
    spec = SystemSpec(n_llm_clients=2, strategy="continuous", limits=limits,
                      with_pre_post=False, router_policy="prefix_affinity")
    coord = build_system(spec)
    wl = WorkloadConfig(trace=TRACE, rate=RATE, n_requests=N_REQUESTS, seed=11,
                        pipeline="reasoning" if branches > 1 else "regular",
                        reasoning_scale=2.0, reasoning_branches=branches,
                        shared_prefix_pool=4,
                        shared_prefix_tokens=PREFIX_TOKENS,
                        prefix_reuse_rate=reuse, postprocess=False)
    coord.submit(generate(wl))
    m = coord.run()
    s = m.summary()
    # fleet-wide physical footprint: sum of per-client allocator peaks.
    # summary()'s kv_peak_blocks max-folds across clients, which under
    # prefix-affinity routing would measure how much the warm client
    # concentrates load, not how many pages sharing saved
    fleet_peak = sum(c.kv_stats().get("peak_blocks", 0)
                     for c in coord.clients.values()
                     if hasattr(c, "kv_stats"))
    return {
        "branches": branches, "prefix_reuse_rate": reuse,
        "capacity_frac": frac, "sharing": sharing,
        "n_serviced": s["n_serviced"],
        "e2e_p50": s["e2e_p50"], "ttft_p90": s["ttft_p90"],
        "prefix_hit_tokens": s["kv_prefix_hit_tokens"],
        "cow_forks": s["kv_cow_forks"],
        "shared_blocks": s["kv_shared_blocks"],
        "radix_evictions": s["kv_radix_evictions"],
        "dedup_ratio": s["kv_dedup_ratio"],
        "fleet_peak_blocks": fleet_peak,
        "page_faults": s["kv_page_faults"],
        "preemptions": s["preemptions"],
    }


def run() -> List[str]:
    out: List[str] = []
    grid: List[Dict] = []
    for branches in BRANCHES:
        for reuse in REUSE_RATES:
            for frac in CAPACITY_FRACS:
                t0 = time.perf_counter()
                on = _run_one(branches, reuse, frac, sharing=True)
                off = _run_one(branches, reuse, frac, sharing=False)
                us = (time.perf_counter() - t0) * 1e6
                # capacity amplification: logical block refs served per
                # physical block (radix dedup), and the fleet-wide
                # peak-pages shrink
                amp = on["dedup_ratio"]
                shrink = (off["fleet_peak_blocks"]
                          / max(1, on["fleet_peak_blocks"]))
                on["capacity_amplification"] = amp
                on["peak_block_shrink_vs_off"] = shrink
                grid.extend((on, off))
                out.append(row(
                    f"prefix_b{branches}_r{reuse}_f{frac}", us,
                    f"amp={amp:.2f}x peak_shrink={shrink:.2f}x "
                    f"hit_tok={on['prefix_hit_tokens']} "
                    f"e2e_p50={on['e2e_p50']:.2f}s "
                    f"(off={off['e2e_p50']:.2f}s)"))
    path = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                        "prefix_cache.json")
    with open(path, "w") as f:
        json.dump({"sweep": "branches x prefix_reuse_rate x "
                            "hbm_capacity_frac x sharing",
                   "n_requests": N_REQUESTS, "rate_rps": RATE,
                   "prefix_tokens": PREFIX_TOKENS,
                   "results": grid}, f, indent=1)
    out.append(row("prefix_cache_json", 0.0,
                   f"wrote {path} ({len(grid)} points)"))
    return out


if __name__ == "__main__":
    for line in run():
        print(line)
