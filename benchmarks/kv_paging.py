"""Paged KV-cache sweep: block size x preemption policy x spill-tier
bandwidth x shrinking HBM capacity.

The headline curve is the swap-vs-recompute latency crossover: with a fast
spill tier (host DRAM over PCIe), swapping beats re-running prefill as HBM
shrinks; over a slow remote tier, recompute wins earlier. Emits CSV rows for
the harness plus a JSON artifact (``kv_paging.json``) with the full grid.
"""
from __future__ import annotations

import json
import os
import time
from typing import Dict, List

from benchmarks.common import row
from repro.core import SystemSpec, WorkloadConfig, build_system, generate
from repro.core.llm_scheduler import SchedulerLimits
from repro.core.workload import TraceSpec
from repro.perfmodel.hardware import (CacheTierSpec, ETH_RACK, PCIE5,
                                      TIER_HOST_DRAM)

# spill-tier bandwidth axis: fast host DRAM vs slow remote-only pool
SWAP_TIERS = {
    "pcie_dram": (TIER_HOST_DRAM,),
    "rack_pool": (CacheTierSpec("rack-pool", 64e12, ETH_RACK.latency,
                                ETH_RACK.bandwidth, 1.0),),
    "slow_pool": (CacheTierSpec("slow-pool", 64e12, 1e-3,
                                PCIE5.bandwidth / 32, 1.0),),
}

BLOCK_TOKENS = (16, 64, 256)
CAPACITY_FRACS = (1.0, 0.08, 0.05, 0.03, 0.02)
N_REQUESTS = 40
RATE = 4.0
# bounded request sizes so the smallest pools still hold one request and the
# capacity axis maps to batching pressure, not single-request OOM
TRACE = TraceSpec("kvpage", input_mean=512, input_std=0.4, output_mean=192,
                  output_std=0.4, input_max=1024, output_max=384)


def _run_one(block_tokens: int, policy: str, tier_name: str,
             frac: float) -> Dict:
    limits = SchedulerLimits(max_batch=32, kv_block_tokens=block_tokens,
                             preemption=policy, kv_capacity_frac=frac,
                             swap_tiers=SWAP_TIERS[tier_name])
    spec = SystemSpec(n_llm_clients=2, strategy="continuous", limits=limits,
                      with_pre_post=False)
    coord = build_system(spec)
    wl = WorkloadConfig(trace=TRACE, rate=RATE, n_requests=N_REQUESTS, seed=5,
                        postprocess=False)
    coord.submit(generate(wl))
    m = coord.run()
    s = m.summary()
    return {
        "block_tokens": block_tokens, "preemption": policy,
        "swap_tier": tier_name, "capacity_frac": frac,
        "n_serviced": s["n_serviced"],
        "e2e_p50": s["e2e_p50"], "e2e_p90": s["e2e_p90"],
        "ttft_p90": s["ttft_p90"], "tpot_p90": s["tpot_p90"],
        "page_faults": s["kv_page_faults"],
        "evictions": s["kv_evictions"],
        "swap_bytes": s["kv_swap_bytes_out"] + s["kv_swap_bytes_in"],
        "recompute_drops": s["kv_recompute_drops"],
        "preemptions": s["preemptions"],
    }


def run() -> List[str]:
    out: List[str] = []
    grid: List[Dict] = []
    for tier_name in SWAP_TIERS:
        for block_tokens in BLOCK_TOKENS:
            for frac in CAPACITY_FRACS:
                per_policy = {}
                t0 = time.perf_counter()
                for policy in ("swap", "recompute"):
                    try:
                        res = _run_one(block_tokens, policy, tier_name, frac)
                    except MemoryError:
                        res = {"block_tokens": block_tokens,
                               "preemption": policy, "swap_tier": tier_name,
                               "capacity_frac": frac, "oom": True}
                    per_policy[policy] = res
                    grid.append(res)
                us = (time.perf_counter() - t0) * 1e6
                sw, rc = per_policy["swap"], per_policy["recompute"]
                if "oom" in sw or "oom" in rc:
                    derived = "oom (pool < one request)"
                else:
                    winner = ("swap" if sw["e2e_p50"] <= rc["e2e_p50"]
                              else "recompute")
                    derived = (f"swap_p50={sw['e2e_p50']:.2f}s "
                               f"rec_p50={rc['e2e_p50']:.2f}s win={winner} "
                               f"faults={sw['page_faults']}")
                out.append(row(
                    f"kvpage_{tier_name}_b{block_tokens}_f{frac}",
                    us, derived))
    path = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                        "kv_paging.json")
    with open(path, "w") as f:
        json.dump({"sweep": "block_tokens x preemption x swap_tier x "
                            "hbm_capacity_frac",
                   "n_requests": N_REQUESTS, "rate_rps": RATE,
                   "results": grid}, f, indent=1)
    out.append(row("kvpage_json", 0.0, f"wrote {path} ({len(grid)} points)"))
    return out


if __name__ == "__main__":
    for line in run():
        print(line)
