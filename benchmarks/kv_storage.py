"""Remote KV-cache storage (paper Fig. 15 / §V-B): per-client LPDDR vs
platform-shared vs rack-shared vs rack+DCN vs recompute, for short (4K) and
long (24K) cached contexts. Metric: end-to-end latency percentiles."""
from __future__ import annotations

import time
from typing import List

from benchmarks.common import row
from repro.core import SystemSpec, WorkloadConfig, build_system, generate
from repro.perfmodel.hardware import (CacheTierSpec, DCN, TIER_LOCAL_LPDDR,
                                      TIER_PLATFORM, TIER_RACK)

TIER_RACK_DCN = CacheTierSpec("rack+dcn", 64e12, DCN.latency, DCN.bandwidth,
                              0.999)

CONFIGS = {
    "A_per_client": (TIER_LOCAL_LPDDR,),
    "B_platform": (TIER_PLATFORM,),
    "C_rack": (TIER_RACK,),
    "C_dcn": (TIER_RACK, TIER_RACK_DCN),
    "recompute": (),
}


def run() -> List[str]:
    out = []
    for cached_tokens, label, rate in ((4_000, "short4k", 2.0),
                                       (24_000, "long24k", 0.8)):
        for cname, tiers in CONFIGS.items():
            t0 = time.perf_counter()
            spec = SystemSpec(n_llm_clients=4, with_kv_retrieval=True,
                              kv_tiers=tiers, with_pre_post=False)
            coord = build_system(spec)
            wl = WorkloadConfig(rate=rate, n_requests=60, pipeline="kv",
                                kv_cached_tokens=cached_tokens,
                                postprocess=False, seed=8)
            coord.submit(generate(wl))
            m = coord.run()
            s = m.summary()
            us = (time.perf_counter() - t0) * 1e6
            out.append(row(
                f"kvstore_{label}_{cname}", us,
                f"e2e_p50={s['e2e_p50']:.2f}s e2e_p90={s['e2e_p90']:.2f}s "
                f"ttft_p90={s['ttft_p90']*1e3:.0f}ms"))
    return out
