"""Chunk-size sweep (the chunk axis of paper Fig. 6 + Sarathi's trade-off):
small chunks protect TPOT (decode piggybacks often), large chunks cut prefill
latency. TTFT/TPOT vs chunk size under a code-like workload, in the
discrete-event simulator — the fleet-scale counterpart of the real-engine
measurement in ``engine_chunked.py``.

The grid is configurable: ``--chunks 128,256,512`` overrides the default
sweep, ``--clients`` / ``--requests`` / ``--rate`` resize the workload.
Emits ``BENCH_chunk_sweep.json``. ``--smoke`` pins a small CI scenario;
with ``--check`` it exits non-zero when the simulated trade-off inverts —
the largest chunk worsening TTFT p50 over the smallest, the smallest chunk
worsening TPOT p90 over the largest — or when any sweep point fails to
service its full request set. The simulator is deterministic, so these are
hard gates, not timing assertions.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
from typing import Dict, List, Optional, Sequence

if __package__ in (None, ""):                      # `python benchmarks/...`
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))

from benchmarks.common import row

JSON_PATH = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                         "BENCH_chunk_sweep.json")

DEFAULT_CHUNKS = (256, 512, 1024, 2048)
DEFAULT_CLIENTS = 4
DEFAULT_REQUESTS = 60
DEFAULT_RATE = 3.0
# gate endpoints only. 2048 is deliberately excluded from the smoke pair:
# at the light smoke load its decode interference also delays first tokens,
# flattening (and slightly inverting) the TTFT side of the trade-off —
# 128 -> 1024 is the monotone region for this pinned workload.
SMOKE_CHUNKS = (128, 1024)
SMOKE_CLIENTS = 2
SMOKE_REQUESTS = 24
SEED = 37


def _point(chunk: int, clients: int, n_requests: int, rate: float) -> Dict:
    from repro.core import SystemSpec, WorkloadConfig, build_system, generate
    from repro.core.llm_scheduler import SchedulerLimits
    from repro.core.workload import AZURE_CODE

    t0 = time.perf_counter()
    spec = SystemSpec(n_llm_clients=clients, strategy="chunked",
                      limits=SchedulerLimits(chunk_size=chunk),
                      with_pre_post=False)
    coord = build_system(spec)
    wl = WorkloadConfig(trace=AZURE_CODE, rate=rate, n_requests=n_requests,
                        postprocess=False, seed=SEED)
    coord.submit(generate(wl))
    m = coord.run()
    s = m.summary()
    return {
        "chunk_size": chunk,
        "n_requests": n_requests,
        "n_serviced": len(m.serviced),
        "wall_s": time.perf_counter() - t0,
        **{k: s[k] for k in ("ttft_p50", "ttft_p90", "tpot_p50", "tpot_p90")
           if k in s},
    }


def run(smoke: bool = False, chunks: Optional[Sequence[int]] = None,
        clients: Optional[int] = None, n_requests: Optional[int] = None,
        rate: Optional[float] = None) -> List[str]:
    chunks = tuple(chunks or (SMOKE_CHUNKS if smoke else DEFAULT_CHUNKS))
    clients = clients or (SMOKE_CLIENTS if smoke else DEFAULT_CLIENTS)
    n_requests = n_requests or (SMOKE_REQUESTS if smoke
                                else DEFAULT_REQUESTS)
    rate = rate or DEFAULT_RATE
    out, results = [], []
    for chunk in chunks:
        r = _point(chunk, clients, n_requests, rate)
        results.append(r)
        out.append(row(f"chunk_{chunk}{'_smoke' if smoke else ''}",
                       r["wall_s"] * 1e6,
                       f"ttft_p50={r['ttft_p50']*1e3:.0f}ms "
                       f"ttft_p90={r['ttft_p90']*1e3:.0f}ms "
                       f"tpot_p50={r['tpot_p50']*1e3:.1f}ms "
                       f"tpot_p90={r['tpot_p90']*1e3:.1f}ms "
                       f"serviced={r['n_serviced']}/{r['n_requests']}"))
    with open(JSON_PATH, "w") as f:
        json.dump({"smoke": smoke, "clients": clients, "rate": rate,
                   "seed": SEED, "results": results}, f, indent=2)
    out.append(f"# wrote {JSON_PATH}")
    return out


def check(path: str) -> int:
    """CI gate: the Sarathi trade-off must hold across the sweep endpoints
    (see module docstring) and every point must drain its workload."""
    with open(path) as f:
        data = json.load(f)
    results = sorted(data["results"], key=lambda r: r["chunk_size"])
    rc = 0
    for r in results:
        if r["n_serviced"] != r["n_requests"]:
            print(f"CHECK FAIL: chunk {r['chunk_size']} serviced "
                  f"{r['n_serviced']}/{r['n_requests']} requests",
                  file=sys.stderr)
            rc = 1
    small, large = results[0], results[-1]
    if large["ttft_p50"] > small["ttft_p50"]:
        print(f"CHECK FAIL: trade-off inverted — chunk {large['chunk_size']} "
              f"TTFT p50 {large['ttft_p50']*1e3:.0f}ms worse than chunk "
              f"{small['chunk_size']}'s {small['ttft_p50']*1e3:.0f}ms",
              file=sys.stderr)
        rc = 1
    if small["tpot_p90"] > large["tpot_p90"]:
        print(f"CHECK FAIL: trade-off inverted — chunk {small['chunk_size']} "
              f"TPOT p90 {small['tpot_p90']*1e3:.1f}ms worse than chunk "
              f"{large['chunk_size']}'s {large['tpot_p90']*1e3:.1f}ms",
              file=sys.stderr)
        rc = 1
    if rc == 0:
        print(f"CHECK OK: chunk {small['chunk_size']}->"
              f"{large['chunk_size']}: TTFT p50 "
              f"{small['ttft_p50']*1e3:.0f}->{large['ttft_p50']*1e3:.0f}ms, "
              f"TPOT p90 {small['tpot_p90']*1e3:.1f}->"
              f"{large['tpot_p90']*1e3:.1f}ms — trade-off holds, all "
              "requests serviced")
    return rc


def _parse(argv) -> argparse.Namespace:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--check", action="store_true")
    ap.add_argument("--chunks", type=lambda s: [int(c) for c in s.split(",")],
                    default=None, help="comma-separated chunk sizes")
    ap.add_argument("--clients", type=int, default=None)
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--rate", type=float, default=None)
    return ap.parse_args(argv)


if __name__ == "__main__":
    ns = _parse(sys.argv[1:])
    for line in run(smoke=ns.smoke, chunks=ns.chunks, clients=ns.clients,
                    n_requests=ns.requests, rate=ns.rate):
        print(line)
    if ns.check:
        raise SystemExit(check(JSON_PATH))
