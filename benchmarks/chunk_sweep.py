"""Chunk-size sweep (the chunk axis of paper Fig. 6 + Sarathi's trade-off):
small chunks protect TPOT (decode piggybacks often), large chunks cut prefill
latency. TTFT/TPOT vs chunk size under a code-like workload."""
from __future__ import annotations

import dataclasses
import time
from typing import List

from benchmarks.common import row
from repro.core import SystemSpec, WorkloadConfig, build_system, generate
from repro.core.llm_scheduler import SchedulerLimits
from repro.core.workload import AZURE_CODE


def run() -> List[str]:
    out = []
    for chunk in (256, 512, 1024, 2048):
        t0 = time.perf_counter()
        spec = SystemSpec(n_llm_clients=4, strategy="chunked",
                          limits=SchedulerLimits(chunk_size=chunk),
                          with_pre_post=False)
        coord = build_system(spec)
        wl = WorkloadConfig(trace=AZURE_CODE, rate=3.0, n_requests=60,
                            postprocess=False, seed=37)
        coord.submit(generate(wl))
        m = coord.run()
        s = m.summary()
        us = (time.perf_counter() - t0) * 1e6
        out.append(row(f"chunk_{chunk}", us,
                       f"ttft_p50={s['ttft_p50']*1e3:.0f}ms "
                       f"ttft_p90={s['ttft_p90']*1e3:.0f}ms "
                       f"tpot_p50={s['tpot_p50']*1e3:.1f}ms "
                       f"tpot_p90={s['tpot_p90']*1e3:.1f}ms"))
    return out
