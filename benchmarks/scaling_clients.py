"""Client scaling (paper Fig. 13): highest per-client rate meeting the SLO as
the client count grows, per strategy — swept over ``kv_capacity_frac`` to
find SLO-preserving consolidation points (how much HBM can be taken away, or
how many requests packed per client, before the SLO breaks)."""
from __future__ import annotations

import time
from typing import List

from benchmarks.common import row
from repro.core import SLO, SystemSpec, WorkloadConfig, build_system, generate
from repro.core.llm_scheduler import SchedulerLimits


# TPOT baseline calibrated to our analytical 2xH100 TP2 model (~32ms/step at
# full batch); the paper's relative strategy ordering is the deliverable.
_SLO = SLO(ttft_base=0.4, tpot_base=0.040)

# 1.0 = full HBM; the small fractions probe the consolidation frontier where
# paging/preemption starts to eat the SLO headroom
CAPACITY_FRACS = (1.0, 0.05)


def _max_rate(strategy: str, n_clients: int, frac: float = 1.0,
              rates=(0.5, 1.0, 2.0, 4.0)) -> float:
    best = 0.0
    limits = SchedulerLimits(kv_capacity_frac=frac)
    for rate in rates:
        if strategy == "disaggregated":
            n_p = max(1, int(n_clients * 0.6))
            spec = SystemSpec(strategy="disaggregated", n_prefill=n_p,
                              n_decode=max(1, n_clients - n_p),
                              limits=limits, with_pre_post=False)
        else:
            spec = SystemSpec(n_llm_clients=n_clients, strategy=strategy,
                              limits=limits, with_pre_post=False)
        coord = build_system(spec)
        wl = WorkloadConfig(rate=rate * n_clients, n_requests=60,
                            disaggregated=(strategy == "disaggregated"),
                            postprocess=False, seed=9)
        coord.submit(generate(wl))
        m = coord.run()
        if m.slo_satisfied(_SLO):
            best = rate
    return best


def run() -> List[str]:
    out = []
    for strategy in ("continuous", "chunked", "disaggregated"):
        for n in (2, 4, 8):
            for frac in CAPACITY_FRACS:
                t0 = time.perf_counter()
                r = _max_rate(strategy, n, frac)
                us = (time.perf_counter() - t0) * 1e6
                # full-HBM rows keep their historical names; only the
                # consolidation points carry the frac suffix
                suffix = "" if frac == 1.0 else f"_f{frac}"
                out.append(row(f"scaling_{strategy}_c{n}{suffix}", us,
                               f"max_rate_per_client={r}req/s"))
    return out
