"""Engine fidelity: replay one request schedule through the *paged
real-execution engine* and through the *simulator*, and compare.

This is the calibration loop the paper's methodology rests on (and what
LLMServingSim/TokenSim argue gives a simulator credibility): the discrete-
event simulator predicts TTFT/TPOT and block-level KV behavior for a
schedule; the paged ``Engine`` actually executes the same schedule with real
JAX prefill/decode over paged KV (CPU here, so kernels run in their
reference/interpret form), measuring the same quantities.

Three arms per scenario, all fed the identical schedule (prompt seeds,
lengths, output budgets, shared system-prefix structure):

1. **paged Engine** (``repro.engine.runner.Engine``) — measured wall-clock
   TTFT/TPOT per request, per-step block-occupancy trace, allocator stats.
2. **SlotEngine** — the seed dense-slot engine; under greedy decoding the
   paged engine must emit **identical token streams** (this is the --check
   gate: if indirection through block tables changed a single token, the
   paged port is wrong).
3. **simulator** (``repro.core``) — one continuous-batching client with the
   same ``max_batch`` and ``kv_block_tokens``, requests with the same
   input/output token counts and prefix segments; predicted TTFT/TPOT and
   ``kv_*`` block counters.

The *measured* arm runs a reduced model on CPU while the *predicted* arm
prices the full model on H100, so absolute times differ by a large constant;
what the emitted JSON exposes is the per-request predicted-vs-measured
ratios (a calibratable scale) and the block-accounting comparison
(prefix-hit blocks, peak blocks), which ARE directly comparable — the
engine's allocator mirrors the simulator's semantics block for block.

Emits ``BENCH_engine_fidelity.json``. ``--smoke`` pins the small CI
scenario; with ``--check`` it exits non-zero when

* any request's paged token stream differs from the slot engine's,
* the paged engine failed to complete the schedule or violated a store
  invariant (refcount/free-list partition, peak over capacity), or
* prompts share a block-aligned prefix but no dedup was observed in either
  the engine or the simulator.
"""
from __future__ import annotations

import json
import os
import sys
import time
from typing import Dict, List

if __package__ in (None, ""):                      # `python benchmarks/...`
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))

import numpy as np

from benchmarks.common import row

JSON_PATH = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                         "BENCH_engine_fidelity.json")

BLOCK_TOKENS = 16
MAX_BATCH = 2
MAX_LEN = 96
SHARED_PREFIX = 32           # block-aligned shared system prompt (2 blocks)
SMOKE_N = 5
FULL_N = 12
OUT_TOKENS = 8


def _schedule(n: int, seed: int, vocab: int):
    """n requests: a shared 32-token system prompt + unique tails."""
    rng = np.random.default_rng(seed)
    sysp = rng.integers(0, vocab, SHARED_PREFIX)
    reqs = []
    for i in range(n):
        tail = rng.integers(0, vocab, int(rng.integers(4, 12)))
        reqs.append(np.concatenate([sysp, tail]).astype(np.int32))
    return reqs


def _run_engine(cls, cfg, prompts, **kw):
    eng = cls(cfg, max_batch=MAX_BATCH, max_len=MAX_LEN, seed=7, **kw)
    handles = []
    t0 = time.perf_counter()
    for p in prompts:
        handles.append(eng.submit(p, max_new_tokens=OUT_TOKENS))
    eng.run()
    wall = time.perf_counter() - t0
    return eng, handles, wall


def _run_simulator(prompts) -> Dict:
    from repro.core import SystemSpec, build_system
    from repro.core.llm_scheduler import SchedulerLimits
    from repro.core.request import LLM, Request, Stage

    spec = SystemSpec(model="gemma-2b", n_llm_clients=1,
                      strategy="continuous", with_pre_post=False,
                      limits=SchedulerLimits(max_batch=MAX_BATCH,
                                             kv_block_tokens=BLOCK_TOKENS))
    coord = build_system(spec)
    reqs = [Request(arrival=0.0, input_tokens=len(p),
                    output_tokens=OUT_TOKENS, model="gemma-2b",
                    stages=[Stage(LLM)],
                    prefix_segments=(("sys", SHARED_PREFIX),))
            for p in prompts]
    coord.submit(reqs)
    m = coord.run()
    s = m.summary()
    per_req = sorted(((r.input_tokens, r.ttft, r.tpot) for r in m.serviced),
                     key=lambda x: x[0])
    return {"summary": {k: v for k, v in s.items()
                        if k.startswith(("ttft", "tpot", "kv_", "e2e"))},
            "per_request": per_req}


def _scenario(n: int) -> Dict:
    from repro.configs import get_reduced_config
    from repro.engine.runner import Engine, SlotEngine

    cfg = get_reduced_config("gemma_2b")
    prompts = _schedule(n, seed=11, vocab=cfg.vocab_size)

    paged, ph, paged_wall = _run_engine(
        Engine, cfg, prompts, block_tokens=BLOCK_TOKENS,
        trace_occupancy=True)
    slot, sh, slot_wall = _run_engine(SlotEngine, cfg, prompts)
    paged.store.check_invariants()

    streams_equal = all(a.tokens == b.tokens for a, b in zip(ph, sh))
    sim = _run_simulator(prompts)
    kv = paged.kv_stats()

    measured = [{"rid": h.rid, "input_tokens": int(len(h.prompt)),
                 "output_tokens": len(h.tokens),
                 "ttft_s": h.ttft, "tpot_s": h.tpot} for h in ph]
    pred_ttft = sim["summary"].get("ttft_mean")
    meas_ttft = float(np.mean([m["ttft_s"] for m in measured]))
    meas_tpot = float(np.mean([m["tpot_s"] for m in measured]))
    pred_tpot = sim["summary"].get("tpot_mean")
    return {
        "n_requests": n,
        "completed": len(ph) == n and all(h.state == "done" for h in ph),
        "token_streams_equal": streams_equal,
        "paged_wall_s": paged_wall,
        "slot_wall_s": slot_wall,
        "measured": measured,
        "measured_ttft_mean_s": meas_ttft,
        "measured_tpot_mean_s": meas_tpot,
        "predicted_ttft_mean_s": pred_ttft,
        "predicted_tpot_mean_s": pred_tpot,
        # calibration scale: one constant per metric maps model-predicted
        # H100 time onto this host's reduced-model wall-clock
        "ttft_calibration_ratio": (meas_ttft / pred_ttft
                                   if pred_ttft else None),
        "tpot_calibration_ratio": (meas_tpot / pred_tpot
                                   if pred_tpot else None),
        "engine_kv": kv,
        "engine_occupancy_trace": paged.occupancy,
        "sim_kv": {k: v for k, v in sim["summary"].items()
                   if k.startswith("kv_")},
        "sim_per_request": sim["per_request"],
    }


def run(smoke: bool = False) -> List[str]:
    out = []
    results = []
    for n in ([SMOKE_N] if smoke else [SMOKE_N, FULL_N]):
        r = _scenario(n)
        results.append(r)
        out.append(row(
            f"engine_fidelity_n{n}{'_smoke' if smoke else ''}",
            r["paged_wall_s"] * 1e6,
            f"streams_equal={r['token_streams_equal']} "
            f"dedup_blocks={r['engine_kv']['prefix_hit_blocks']} "
            f"peak_blocks={r['engine_kv']['peak_blocks']} "
            f"ttft_ratio={r['ttft_calibration_ratio']:.3g}"))
    with open(JSON_PATH, "w") as f:
        json.dump({"smoke": smoke, "block_tokens": BLOCK_TOKENS,
                   "max_batch": MAX_BATCH, "max_len": MAX_LEN,
                   "results": results}, f, indent=2, default=float)
    out.append(f"# wrote {JSON_PATH}")
    return out


def check(path: str) -> int:
    """CI gate (see module docstring)."""
    with open(path) as f:
        data = json.load(f)
    rc = 0
    for r in data["results"]:
        n = r["n_requests"]
        if not r["token_streams_equal"]:
            print(f"CHECK FAIL: n={n} paged token streams diverge from the "
                  "slot engine", file=sys.stderr)
            rc = 1
        if not r["completed"]:
            print(f"CHECK FAIL: n={n} schedule did not complete",
                  file=sys.stderr)
            rc = 1
        kv = r["engine_kv"]
        if kv["peak_blocks"] > kv["num_blocks"]:
            print(f"CHECK FAIL: n={n} peak_blocks {kv['peak_blocks']} over "
                  f"capacity {kv['num_blocks']}", file=sys.stderr)
            rc = 1
        sim_hits = r["sim_kv"].get("kv_prefix_hit_blocks", 0)
        if kv["prefix_hit_blocks"] <= 0 or sim_hits <= 0:
            print(f"CHECK FAIL: n={n} shared-prefix schedule but no dedup "
                  f"(engine={kv['prefix_hit_blocks']}, sim={sim_hits})",
                  file=sys.stderr)
            rc = 1
    if rc == 0:
        print("CHECK OK: paged-engine token streams identical to the slot "
              "engine; block accounting sane; dedup visible in both arms")
    return rc


if __name__ == "__main__":
    smoke = "--smoke" in sys.argv
    for line in run(smoke=smoke):
        print(line)
    if "--check" in sys.argv:
        raise SystemExit(check(JSON_PATH))
