"""Cross-client radix prefix migration sweep: migration bandwidth x
prefix-reuse rate x scale-out timing.

Scenario (paper §V-B remote KV retrieval as an *architectural* lever): one
warm LLM client serves a shared-prefix workload; traffic surges
(``rate_ramp``) and a second, cold replica is scaled out mid-run
(``CLIENT_ADD``). With migration on, the coordinator push-warms the new
replica with the donor's hottest radix chains and the prefix-affinity
router's fetch policy ships prefixes toward it whenever the warm client
overloads — all priced on the ``Network`` rack link. With migration off, the
replica warms only through organic traffic.

The headline numbers per sweep point:

* **cold-replica hit-rate ratio** — the scaled-out client's prefix-hit rate
  as a fraction of the warm client's (the recovery criterion: >= 0.8 within
  the sweep window under --smoke --check);
* **cold-replica TTFT recovery** — time-bucketed TTFT p50 of requests the
  cold replica served after scale-out, vs the migration-off arm;
* **migration wire traffic** — ``kv_migrated_bytes`` (also visible in
  ``Network.stats()`` on the rack link).

Emits CSV rows plus ``prefix_migration.json`` (git-ignored). ``--smoke``
runs the single pinned CI point; with ``--check`` it exits non-zero when the
recovery criterion, the migration-traffic visibility check, or the
hit-ratio improvement over the off arm fails.
"""
from __future__ import annotations

import json
import os
import sys
import time
from typing import Dict, List, Optional

if __package__ in (None, ""):                      # `python benchmarks/...`
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))

from benchmarks.common import row
from repro.core import SystemSpec, WorkloadConfig, build_system, generate
from repro.core.client import LLMClient
from repro.core.llm_scheduler import SchedulerLimits
from repro.core.request import LLM
from repro.core.workload import TraceSpec
from repro.perfmodel.hardware import ETH_RACK, LinkSpec, PCIE4_X4

# migration-BW axis: rack-link bandwidth in bytes/s (paper §V-B: the
# fetch-vs-recompute crossover moves with the interconnect)
MIGRATION_BWS = (16e9, 128e9, 512e9)
REUSE_RATES = (0.5, 1.0)
SCALE_OUT_AT = (3.0, 8.0)
N_REQUESTS = 80
RATE = 4.0
RATE_RAMP = 2.5               # traffic surge at scale-out time
PREFIX_POOL = 4
PREFIX_TOKENS = 512
FETCH_LOAD_FACTOR = 1.5
TTFT_BUCKET_S = 2.0           # cold-replica TTFT recovery resolution
# bounded sizes so capacity pressure comes from batching, not single-request
# OOM, and outputs are long enough for decode windows to be cut mid-flight
TRACE = TraceSpec("mig", input_mean=384, input_std=0.4, output_mean=160,
                  output_std=0.3, input_max=768, output_max=320)

SMOKE_BW = 128e9
SMOKE_REUSE = 1.0
SMOKE_SCALE_AT = 4.0
SMOKE_MIN_HIT_RATIO = 0.8     # acceptance: cold >= 80% of warm hit rate


def _run_one(bw: float, reuse: float, scale_at: float,
             migration: bool) -> Dict:
    limits = SchedulerLimits(max_batch=32)
    spec = SystemSpec(n_llm_clients=1, strategy="continuous", limits=limits,
                      with_pre_post=False, router_policy="prefix_affinity",
                      prefix_migration=migration,
                      fetch_load_factor=FETCH_LOAD_FACTOR)
    coord = build_system(spec)
    # migration-BW axis: replace the rack fabric the chains ride on
    coord.network.add_link("rack", LinkSpec("RackEth", bw, ETH_RACK.latency))
    warm = coord.clients["llm0"]
    cold = LLMClient("llm1", warm.cluster, warm.model_cfg, "continuous",
                     limits, "fcfs", warm.scheduler.perf)
    coord.network.add_link("pcie:llm1", PCIE4_X4)
    coord.network.connect("llm1", "llm1:kvpool", ["pcie:llm1"])
    coord.schedule_add_client(cold, at=scale_at)
    wl = WorkloadConfig(trace=TRACE, rate=RATE, n_requests=N_REQUESTS,
                        seed=11, shared_prefix_pool=PREFIX_POOL,
                        shared_prefix_tokens=PREFIX_TOKENS,
                        prefix_reuse_rate=reuse, postprocess=False,
                        rate_ramp_at=scale_at, rate_ramp=RATE_RAMP)
    coord.submit(generate(wl))
    m = coord.run()
    s = m.summary()
    # cold-replica TTFT recovery: requests whose LLM stage the new replica
    # served, bucketed by arrival time since scale-out
    buckets: Dict[int, List[float]] = {}
    for r in m.serviced:
        llm_st = next((st for st in r.stages if st.kind == LLM), None)
        if llm_st is None or llm_st.client != "llm1" or r.ttft is None:
            continue
        buckets.setdefault(int((r.arrival - scale_at) // TTFT_BUCKET_S),
                           []).append(r.ttft)
    recovery = [{"bucket_s": (k + 1) * TTFT_BUCKET_S,
                 "n": len(v),
                 "ttft_p50": sorted(v)[len(v) // 2]}
                for k, v in sorted(buckets.items())]
    warm_rate = warm.prefix_hit_rate()
    cold_rate = cold.prefix_hit_rate()
    return {
        "migration_bw": bw, "prefix_reuse_rate": reuse,
        "scale_out_at": scale_at, "migration": migration,
        "n_serviced": s["n_serviced"],
        "ttft_p50": s["ttft_p50"], "ttft_p90": s["ttft_p90"],
        "e2e_p50": s["e2e_p50"],
        "warm_hit_rate": warm_rate, "cold_hit_rate": cold_rate,
        "hit_ratio_cold_vs_warm": (cold_rate / warm_rate) if warm_rate else 0.0,
        "kv_migrations": s["kv_migrations"],
        "kv_migrated_bytes": s["kv_migrated_bytes"],
        "kv_migration_hit_tokens": s["kv_migration_hit_tokens"],
        "kv_migrated_in_blocks": s["kv_migrated_in_blocks"],
        "kv_migration_refused_blocks": s["kv_migration_refused_blocks"],
        "rack_bytes": coord.network.stats()["rack"]["bytes"],
        "cold_ttft_recovery": recovery,
        "cold_served": sum(b["n"] for b in recovery),
    }


def _bench_point(bw: float, reuse: float, scale_at: float) -> Dict:
    on = _run_one(bw, reuse, scale_at, migration=True)
    off = _run_one(bw, reuse, scale_at, migration=False)
    on["hit_ratio_off_arm"] = off["hit_ratio_cold_vs_warm"]
    on["cold_ttft_recovery_off"] = off["cold_ttft_recovery"]
    return {"on": on, "off": off}


def _write_json(results: List[Dict], smoke: bool) -> str:
    path = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                        "prefix_migration.json")
    with open(path, "w") as f:
        json.dump({"sweep": "migration_bw x prefix_reuse_rate x "
                            "scale_out_at x migration on/off",
                   "smoke": smoke, "n_requests": N_REQUESTS,
                   "rate_rps": RATE, "rate_ramp": RATE_RAMP,
                   "prefix_pool": PREFIX_POOL,
                   "prefix_tokens": PREFIX_TOKENS,
                   "fetch_load_factor": FETCH_LOAD_FACTOR,
                   "min_hit_ratio": SMOKE_MIN_HIT_RATIO,
                   "results": results}, f, indent=1)
    return path


def run(smoke: bool = False) -> List[str]:
    out: List[str] = []
    if smoke:
        grid = [(SMOKE_BW, SMOKE_REUSE, SMOKE_SCALE_AT)]
    else:
        grid = [(bw, r, t) for bw in MIGRATION_BWS for r in REUSE_RATES
                for t in SCALE_OUT_AT]
    results: List[Dict] = []
    for bw, reuse, scale_at in grid:
        t0 = time.perf_counter()
        pt = _bench_point(bw, reuse, scale_at)
        us = (time.perf_counter() - t0) * 1e6
        results.append(pt)
        on, off = pt["on"], pt["off"]
        out.append(row(
            f"prefix_mig_bw{bw:.0e}_r{reuse}_t{scale_at}"
            f"{'_smoke' if smoke else ''}", us,
            f"cold/warm_hit={on['hit_ratio_cold_vs_warm']:.2f} "
            f"(off={off['hit_ratio_cold_vs_warm']:.2f}) "
            f"migrations={on['kv_migrations']} "
            f"mig_MB={on['kv_migrated_bytes'] / 1e6:.0f} "
            f"cold_ttft_p50="
            f"{on['cold_ttft_recovery'][0]['ttft_p50']:.2f}s"
            if on["cold_ttft_recovery"] else
            f"cold/warm_hit={on['hit_ratio_cold_vs_warm']:.2f} cold_idle"))
    path = _write_json(results, smoke)
    out.append(row("prefix_migration_json", 0.0,
                   f"wrote {path} ({len(results)} points)"))
    return out


def check(results_path: str) -> int:
    """CI gate over the smoke point: the scaled-out cold replica must reach
    >= 80% of the warm client's prefix-hit rate within the sweep window,
    migration traffic must actually ride the Network (rack bytes cover the
    migrated bytes), and the on arm must beat the off arm's ratio."""
    with open(results_path) as f:
        data = json.load(f)
    if not data.get("smoke"):
        # full-sweep artifacts include points (slow BW, low reuse, late
        # scale-out) that sit below the smoke thresholds by design
        print("CHECK SKIPPED: gate is defined over the pinned --smoke "
              "point; re-run with --smoke --check", file=sys.stderr)
        return 0
    errors = []
    for pt in data["results"]:
        on, off = pt["on"], pt["off"]
        tag = (f"bw={on['migration_bw']:.0e} reuse={on['prefix_reuse_rate']} "
               f"t={on['scale_out_at']}")
        if on["hit_ratio_cold_vs_warm"] < SMOKE_MIN_HIT_RATIO:
            errors.append(f"{tag}: cold replica reached only "
                          f"{on['hit_ratio_cold_vs_warm']:.2f} of the warm "
                          f"hit rate (< {SMOKE_MIN_HIT_RATIO})")
        if on["kv_migrations"] <= 0 or on["kv_migrated_bytes"] <= 0:
            errors.append(f"{tag}: no migrations fired")
        if on["rack_bytes"] + 1e-6 < on["kv_migrated_bytes"]:
            errors.append(f"{tag}: migrated bytes not visible on the rack "
                          f"link ({on['rack_bytes']} < "
                          f"{on['kv_migrated_bytes']})")
        if on["hit_ratio_cold_vs_warm"] < off["hit_ratio_cold_vs_warm"]:
            errors.append(f"{tag}: migration arm warmed slower than the "
                          f"organic arm")
    for e in errors:
        print(f"CHECK FAILED: {e}", file=sys.stderr)
    return 1 if errors else 0


if __name__ == "__main__":
    smoke = "--smoke" in sys.argv
    for line in run(smoke=smoke):
        print(line)
    if "--check" in sys.argv:
        json_path = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                 "prefix_migration.json")
        raise SystemExit(check(json_path))
