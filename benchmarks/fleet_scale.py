"""Fleet-scale simulator benchmark: what a 1000-client sweep point costs.

ROADMAP item 4 wants 100s-1000s of clients; the blocker was the event loop's
per-request linear scans (candidate rebuild, O(N) load ``min()``, per-client
radix probes). This benchmark drives a diurnal-surge trace with scheduled
CLIENT_ADD/CLIENT_REMOVE churn through fleets of 10..1000 clients and
measures the *simulator*: wall-clock for ``Coordinator.run()``,
``simulator_stats`` event counts, modeled throughput and per-tier goodput.
Each fleet size runs both arms — ``fleet_index=True`` (incremental indexes,
the default) and ``fleet_index=False`` (linear-scan baseline) — and the two
must produce bit-identical ``MetricsCollector.summary()`` dicts: the indexes
are a pure simulator-cost optimization, never a behavior change.

The request count is FIXED across fleet sizes, so wall-clock growth isolates
per-request dispatch cost: a linear scan grows ~10x from 100 to 1000
clients, the indexed path must stay well below that.

Emits ``BENCH_fleet_scale.json`` next to this file. ``--smoke`` runs the
pinned CI pair (100 and 1000 clients); with ``--check`` it exits non-zero
when any summary diverges between arms, when the smoke event count blows a
2x budget, or when the indexed 1000-vs-100 wall-clock ratio exceeds the
hard sublinearity bound (an advisory warning fires earlier).
"""
from __future__ import annotations

import json
import math
import os
import sys
import time
from typing import Dict, List, Tuple

if __package__ in (None, ""):                      # `python benchmarks/...`
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))

from benchmarks.common import row
from repro.core import SystemSpec, WorkloadConfig, build_system, generate
from repro.core.client import LLMClient
from repro.core.llm_scheduler import SchedulerLimits
from repro.core.metrics import SLO, simulator_stats
from repro.core.workload import synthetic_trace

FLEETS = (10, 50, 100, 250, 500, 1000)
SMOKE_FLEETS = (100, 1000)
N_REQUESTS = 600                # fixed across fleet sizes (see module doc)
SMOKE_REQUESTS = 400
OUT_TOKENS = 96                 # short decodes: the benchmark stresses
RATE = 150.0                    # routing, not decode simulation
SURGE_AT = 1.5                  # diurnal surge: arrivals after this come 3x
SURGE_RAMP = 3.0                # faster (deterministic time compression)

# SLO tiers: interactive chat vs batch/code, looser targets for batch
TIER_SLOS = {"interactive": SLO(),
             "batch": SLO(ttft_base=2.0, tpot_base=0.100)}

# pinned CI budgets for the 1000-client smoke arm (indexed). Events are
# deterministic: fail hard at 2x. Wall-clock ratios on shared runners are
# noisy: warn at the advisory bound, fail only past the hard one (a linear
# scan measures ~10x here, so 6x still separates the regimes cleanly).
SMOKE_EVENTS_PINNED = 12_000
WALL_RATIO_WARN = 3.0
WALL_RATIO_HARD = 6.0
EVENTS_RATIO_HARD = 2.0


def _history_limits() -> SchedulerLimits:
    # ring-buffer step history: a 1000-client run must not hold every step
    # dict in memory (step_events stays exact via the counter)
    return SchedulerLimits(max_batch=32, history_limit=64)


def _workload(n_requests: int) -> List:
    """Two-tier diurnal trace: interactive chat plus heavier batch/code
    requests, interleaved by arrival, surging 3x at SURGE_AT."""
    inter = synthetic_trace(input_mean=256, input_std=0.4,
                            output_mean=OUT_TOKENS, output_std=0.2,
                            name="interactive")
    batch = synthetic_trace(input_mean=1024, input_std=0.5,
                            output_mean=OUT_TOKENS * 2, output_std=0.2,
                            name="batch")
    n_inter = (2 * n_requests) // 3
    reqs = generate(WorkloadConfig(
        trace=inter, rate=RATE, n_requests=n_inter, process="poisson",
        postprocess=False, seed=11, shared_prefix_pool=8,
        shared_prefix_tokens=256, rate_ramp_at=SURGE_AT,
        rate_ramp=SURGE_RAMP))
    for r in reqs:
        r.tier = "interactive"
    breqs = generate(WorkloadConfig(
        trace=batch, rate=RATE / 2, n_requests=n_requests - n_inter,
        process="poisson", postprocess=False, seed=12,
        rate_ramp_at=SURGE_AT, rate_ramp=SURGE_RAMP))
    for r in breqs:
        r.tier = "batch"
    return reqs + breqs


def _schedule_churn(coord) -> None:
    """Deterministic churn, identical in both arms: two replicas scale out
    at the surge, one drains back in later, one client fails and recovers."""
    base = coord.clients["llm0"]
    sched = base.scheduler
    for i in range(2):
        spare = LLMClient(f"spare{i}", base.cluster, base.model_cfg,
                          "continuous", sched.limits, perf=sched.perf)
        coord.schedule_add_client(spare, SURGE_AT + 0.1 * (i + 1))
    coord.schedule_remove_client("spare1", SURGE_AT + 4.0)
    coord.schedule_failure("llm1", SURGE_AT + 0.5,
                           recover_at=SURGE_AT + 2.5)


def _run_arm(n_clients: int, n_requests: int,
             indexed: bool) -> Tuple[Dict, Dict, Dict, float]:
    spec = SystemSpec(n_llm_clients=n_clients, strategy="continuous",
                      router_policy="load_based", router_metric="queue",
                      limits=_history_limits(), with_pre_post=False,
                      fleet_index=indexed)
    coord = build_system(spec)
    coord.submit(_workload(n_requests))
    _schedule_churn(coord)
    t0 = time.perf_counter()
    metrics = coord.run()
    wall = time.perf_counter() - t0
    horizon = max((r.completion_time or 0.0)
                  for r in metrics.serviced) if metrics.serviced else 1.0
    summary = metrics.summary(horizon=horizon, slo=SLO())
    tiers = metrics.goodput_by_tier(TIER_SLOS, horizon)
    return summary, tiers, simulator_stats(coord), wall


def _summaries_equal(a: Dict, b: Dict) -> bool:
    if set(a) != set(b):
        return False
    for k in a:
        x, y = a[k], b[k]
        if x == y:
            continue
        if isinstance(x, float) and isinstance(y, float) \
                and math.isnan(x) and math.isnan(y):
            continue
        return False
    return True


def _bench_fleet(n_clients: int, n_requests: int) -> Dict:
    s_idx, tiers_idx, st_idx, wall_idx = _run_arm(n_clients, n_requests, True)
    s_scan, tiers_scan, st_scan, wall_scan = _run_arm(n_clients, n_requests,
                                                      False)
    return {
        "fleet": n_clients,
        "n_requests": n_requests,
        "wall_s_indexed": wall_idx,
        "wall_s_scan": wall_scan,
        "speedup": wall_scan / max(wall_idx, 1e-9),
        "events_popped": st_idx["events_popped"],
        "events_popped_scan": st_scan["events_popped"],
        "micro_steps": st_idx["micro_steps"],
        "step_events": st_idx["step_events"],
        "throughput_tok_s": s_idx["throughput_tok_s"],
        "goodput_tok_s": s_idx["goodput_tok_s"],
        "goodput_by_tier": tiers_idx,
        "summary_match": (_summaries_equal(s_idx, s_scan)
                          and tiers_idx == tiers_scan),
    }


def _write_json(results: List[Dict], smoke: bool) -> str:
    path = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                        "BENCH_fleet_scale.json")
    small = min(r["fleet"] for r in results)
    big = max(r["fleet"] for r in results)
    by = {r["fleet"]: r for r in results}
    with open(path, "w") as f:
        json.dump({
            "scenario": "two-tier diurnal surge + churn, fixed 600-request "
                        "schedule, load_based(queue) routing",
            "smoke": smoke,
            "pinned_smoke_events": SMOKE_EVENTS_PINNED,
            "wall_ratio_big_vs_small":
                by[big]["wall_s_indexed"] / max(by[small]["wall_s_indexed"],
                                                1e-9),
            "events_ratio_big_vs_small":
                by[big]["events_popped"] / max(by[small]["events_popped"], 1),
            "fleet_ratio": big / small,
            "results": results,
        }, f, indent=1)
    return path


def run(smoke: bool = False) -> List[str]:
    out = []
    fleets = SMOKE_FLEETS if smoke else FLEETS
    n_requests = SMOKE_REQUESTS if smoke else N_REQUESTS
    results = []
    for fleet in fleets:
        t0 = time.perf_counter()
        r = _bench_fleet(fleet, n_requests)
        results.append(r)
        us = (time.perf_counter() - t0) * 1e6
        tiers = " ".join(f"{t}={v:.0f}" for t, v in
                         sorted(r["goodput_by_tier"].items()))
        out.append(row(
            f"fleet{fleet}{'_smoke' if smoke else ''}", us,
            f"wall={r['wall_s_indexed']:.2f}s/{r['wall_s_scan']:.2f}s "
            f"speedup={r['speedup']:.1f}x events={r['events_popped']} "
            f"goodput[{tiers}] match={r['summary_match']}"))
    path = _write_json(results, smoke)
    out.append(row("fleet_json", 0.0, f"wrote {path} ({len(results)} points)"))
    return out


def check(results_path: str) -> int:
    """CI gate: summary divergence and event budgets/ratios fail hard (both
    deterministic); the wall-clock sublinearity ratio warns at the advisory
    bound and fails only past the hard one (timing on shared runners)."""
    with open(results_path) as f:
        data = json.load(f)
    errors = []
    smoke = bool(data.get("smoke"))
    for r in data["results"]:
        if not r["summary_match"]:
            errors.append(f"fleet {r['fleet']}: indexed and scan arms "
                          f"disagree on MetricsCollector.summary()")
        if smoke and r["fleet"] == max(SMOKE_FLEETS) \
                and r["events_popped"] > 2 * SMOKE_EVENTS_PINNED:
            errors.append(f"fleet {r['fleet']}: events popped "
                          f"{r['events_popped']} > 2x pinned budget "
                          f"{SMOKE_EVENTS_PINNED}")
    ev_ratio = data.get("events_ratio_big_vs_small", 1.0)
    if ev_ratio > EVENTS_RATIO_HARD:
        errors.append(f"event count grows {ev_ratio:.2f}x from the small to "
                      f"the big fleet on a fixed request schedule "
                      f"(> {EVENTS_RATIO_HARD}x)")
    wall_ratio = data.get("wall_ratio_big_vs_small", 1.0)
    if wall_ratio > WALL_RATIO_HARD:
        errors.append(f"indexed wall-clock grows {wall_ratio:.2f}x from the "
                      f"small to the big fleet (> {WALL_RATIO_HARD}x hard "
                      f"bound; linear scan measures ~{data['fleet_ratio']:.0f}x)")
    elif wall_ratio > WALL_RATIO_WARN:
        print(f"CHECK WARNING: indexed wall-clock ratio {wall_ratio:.2f}x "
              f"above advisory bound {WALL_RATIO_WARN}x", file=sys.stderr)
    for e in errors:
        print(f"CHECK FAILED: {e}", file=sys.stderr)
    return 1 if errors else 0


if __name__ == "__main__":
    smoke = "--smoke" in sys.argv
    for line in run(smoke=smoke):
        print(line)
    if "--check" in sys.argv:
        json_path = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                 "BENCH_fleet_scale.json")
        raise SystemExit(check(json_path))
