"""RAG placement study (paper Fig. 9 / §IV-B): embedding model x hardware
placement -> TTFT breakdown; shows large embed models need NPU offload and
PCIe transfer is never the bottleneck."""
from __future__ import annotations

import time
from typing import List

from benchmarks.common import row
from repro.configs.base import ModelConfig
from repro.core import SystemSpec, WorkloadConfig, build_system, generate


def _mistral_7b_embed() -> ModelConfig:
    return ModelConfig(name="mistral-7b-embed", family="dense", num_layers=32,
                       d_model=4096, num_heads=32, num_kv_heads=8, d_ff=14336,
                       vocab_size=32000, mlp_type="swiglu", attn_type="gqa",
                       encoder_only=True)


def run() -> List[str]:
    out = []
    from repro.core.system import _embed_model_small
    embeds = [("e5-base", _embed_model_small()),
              ("mistral-7b", _mistral_7b_embed())]
    # paper configs: large CPU, small CPU, A100-for-embed + large CPU
    hw = [("large_cpu", dict(rag_colocated=True)),
          ("small_cpu", dict(rag_colocated=True)),
          ("a100+cpu", dict(rag_colocated=False, rag_embed_on_npu=True))]
    for ename, emodel in embeds:
        for hname, kw in hw:
            t0 = time.perf_counter()
            spec = SystemSpec(n_llm_clients=1, model="llama3_70b",
                              with_rag=True, with_pre_post=False,
                              embed_model=emodel, **kw)
            coord = build_system(spec)
            if hname == "small_cpu":   # swap the RAG cluster to SPR
                from repro.perfmodel.hardware import ClusterSpec, SPR_CPU
                for c in coord.clients.values():
                    if c.kind == "rag":
                        c.cluster = ClusterSpec(SPR_CPU, 1, 1)
            wl = WorkloadConfig(rate=0.5, n_requests=20, pipeline="rag",
                                postprocess=False, seed=6)
            coord.submit(generate(wl))
            m = coord.run()
            s = m.summary()
            # stage breakdown
            rag_time = []
            for r in m.serviced:
                for st in r.stages:
                    if st.kind.startswith("rag") and st.end_time is not None:
                        rag_time.append(st.end_time - st.start_time)
            us = (time.perf_counter() - t0) * 1e6
            import numpy as np
            out.append(row(
                f"rag_{ename}_{hname}", us,
                f"ttft_p50={s['ttft_p50']*1e3:.0f}ms "
                f"rag_stage_mean={np.mean(rag_time)*1e3:.0f}ms "
                f"comm_bytes={m.comm_bytes:.0f}"))
    return out
