"""Fidelity check (paper Figs. 5-6): the event-driven simulator must agree
with a closed-form replay of the same single-client schedule to ~2%.

Closed form: one client, all requests arrive at t=0, continuous batching,
equal output lengths -> total time = prefill(all) + sum of decode steps at
known batch size/context. Any drift is simulator bookkeeping error.
"""
from __future__ import annotations

from typing import List

from benchmarks.common import row, timeit
from repro.configs import get_config
from repro.core import SystemSpec, build_system
from repro.core.request import LLM, Request, Stage
from repro.perfmodel import analytical as ana
from repro.perfmodel.hardware import ClusterSpec, H100


def closed_form(n: int, in_tok: int, out_tok: int, cluster, model) -> float:
    """One batched prefill (within the scheduler's 8192-token budget) emits
    token #1, then out_tok-1 batched decode steps with growing context."""
    t = ana.prefill_time(model, cluster, in_tok * n, 1).time
    ctx = in_tok + 1
    for _ in range(out_tok - 1):
        t += ana.decode_step_time(model, cluster, n, ctx).time
        ctx += 1
    return t


def run() -> List[str]:
    out = []
    model = get_config("llama3_70b")
    for n, in_tok, out_tok in [(4, 512, 16), (8, 1024, 32), (4, 2048, 24)]:
        spec = SystemSpec(n_llm_clients=1, with_pre_post=False)
        coord = build_system(spec)
        cluster = next(iter(coord.clients.values())).cluster
        reqs = [Request(arrival=0.0, input_tokens=in_tok,
                        output_tokens=out_tok, stages=[Stage(LLM)])
                for _ in range(n)]
        def sim():
            c = build_system(spec)
            c.submit([Request(arrival=0.0, input_tokens=in_tok,
                              output_tokens=out_tok, stages=[Stage(LLM)])
                      for _ in range(n)])
            return c.run()
        us = timeit(sim, n=3)
        coord.submit(reqs)
        m = coord.run()
        sim_e2e = max(r.completion_time for r in m.serviced)
        want = closed_form(n, in_tok, out_tok, cluster, model)
        err = abs(sim_e2e - want) / want * 100
        out.append(row(f"fidelity_n{n}_in{in_tok}", us,
                       f"sim={sim_e2e:.3f}s analytic={want:.3f}s err={err:.2f}%"))
        assert err < 2.0, f"fidelity error {err:.2f}% exceeds 2% target"
    return out
