"""ML-assisted modeling (paper §III-E1): polynomial-regression fit quality and
the simulation speedup from replacing per-event analytical evaluation with the
jit/vmap batched predictor (paper claims 20-50x)."""
from __future__ import annotations

import time
from typing import List

import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, timeit
from repro.configs import get_config
from repro.perfmodel import analytical as ana
from repro.perfmodel import regression as reg
from repro.perfmodel.hardware import ClusterSpec, H100


def run() -> List[str]:
    out = []
    model = get_config("llama3_70b")
    cluster = ClusterSpec(H100, n_chips=2, tp=2)

    t0 = time.perf_counter()
    dm = reg.fit_decode_model(model, cluster)
    fit_us = (time.perf_counter() - t0) * 1e6
    # holdout error at unseen points
    errs = []
    for b, c in [(3, 700), (24, 3000), (96, 6000), (48, 10_000)]:
        want = ana.decode_step_time(model, cluster, b, c).time
        got = float(dm.predict([b], [c])[0])
        errs.append(abs(got - want) / want)
    out.append(row("regression_decode_fit", fit_us,
                   f"mse={dm.mse:.2e} holdout_relerr={np.mean(errs)*100:.1f}%"))

    pm = reg.fit_prefill_model(model, cluster)
    out.append(row("regression_prefill_fit", 0.0, f"mse={pm.mse:.2e}"))

    # speedup: 10k predictions, analytical loop vs batched predictor
    bs = np.random.default_rng(0).integers(1, 128, 10_000)
    cs = np.random.default_rng(1).integers(128, 8192, 10_000)

    def analytical_loop():
        for b, c in zip(bs[:200], cs[:200]):
            ana.decode_step_time(model, cluster, int(b), int(c))

    def batched():
        reg.batched_decode_predict(dm, bs, cs).block_until_ready()

    t_ana = timeit(analytical_loop, n=3) / 200       # per prediction
    t_reg = timeit(batched, n=3) / 10_000
    out.append(row("regression_speedup", t_reg,
                   f"analytical_us={t_ana:.2f} regression_us={t_reg:.4f} "
                   f"speedup={t_ana/max(t_reg,1e-9):.0f}x"))
    return out
