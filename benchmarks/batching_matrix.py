"""Batching-strategy study (paper Figs. 10-12, Table III): strategies x
traces x pipelines x injection rates -> throughput, throughput/energy, TTFT;
emits a Table-III-style recommendation per cell. A ``kv_capacity_frac`` axis
probes whether the recommendation survives HBM consolidation (shrunken KV
pools -> paging pressure).
"""
from __future__ import annotations

from typing import Dict, List

from benchmarks.common import row, timeit
from repro.core import (SLO, SystemSpec, WorkloadConfig, build_system,
                        generate)
from repro.core.llm_scheduler import SchedulerLimits
from repro.core.workload import AZURE_CODE, AZURE_CONV

STRATEGIES = ("continuous", "chunked", "disaggregated")
CAPACITY_FRACS = (1.0, 0.05)


def _spec(strategy: str, pipeline: str, n_clients: int = 4,
          frac: float = 1.0) -> SystemSpec:
    kw: Dict = dict(with_pre_post=False,
                    limits=SchedulerLimits(kv_capacity_frac=frac))
    if pipeline == "rag":
        kw.update(with_rag=True, rag_embed_on_npu=True)
    if pipeline == "kv":
        kw.update(with_kv_retrieval=True)
    if strategy == "disaggregated":
        return SystemSpec(strategy="disaggregated",
                          n_prefill=max(1, int(n_clients * 0.6)),
                          n_decode=max(1, n_clients - int(n_clients * 0.6)),
                          **kw)
    return SystemSpec(n_llm_clients=n_clients, strategy=strategy, **kw)


def _run_cell(strategy: str, trace, pipeline: str, rate: float,
              n_requests: int = 80, frac: float = 1.0) -> Dict:
    coord = build_system(_spec(strategy, pipeline, frac=frac))
    wl = WorkloadConfig(trace=trace, rate=rate, n_requests=n_requests,
                        pipeline={"kv": "kv", "rag": "rag"}.get(pipeline,
                                                                "regular"),
                        disaggregated=(strategy == "disaggregated"),
                        postprocess=False, seed=3)
    coord.submit(generate(wl))
    m = coord.run()
    horizon = max(r.completion_time for r in m.serviced)
    slo = SLO(ttft_base=1.0 if pipeline in ("rag", "kv") else 0.25)
    s = m.summary(horizon=horizon, total_energy=coord.total_energy, slo=slo)
    return s


def run() -> List[str]:
    out = []
    best: Dict[str, Dict[str, str]] = {}
    for trace, tname in ((AZURE_CONV, "conv"), (AZURE_CODE, "code")):
        for pipeline in ("regular", "rag", "kv"):
            for frac in CAPACITY_FRACS:
                scores = {}
                for strat in STRATEGIES:
                    import time
                    t0 = time.perf_counter()
                    s = _run_cell(strat, trace, pipeline, rate=3.0, frac=frac)
                    us = (time.perf_counter() - t0) * 1e6
                    scores[strat] = s
                    suffix = "" if frac == 1.0 else f"_f{frac}"
                    out.append(row(
                        f"batching_{tname}_{pipeline}_{strat}{suffix}", us,
                        f"thpt={s['throughput_tok_s']:.0f} "
                        f"ttft_p50={s['ttft_p50']*1e3:.0f}ms "
                        f"tpot_p50={s['tpot_p50']*1e3:.1f}ms "
                        f"tok/J={s.get('tok_per_joule', 0):.4f} "
                        f"slo_ok={s.get('slo_ok')}"))
                cell = f"{tname}/{pipeline}" + (
                    "" if frac == 1.0 else f"/f{frac}")
                best[cell] = {
                    "TTFT": min(scores, key=lambda k: scores[k]["ttft_p50"]),
                    "Throughput": max(
                        scores, key=lambda k: scores[k]["throughput_tok_s"]),
                    "Throughput/Energy": max(
                        scores,
                        key=lambda k: scores[k].get("tok_per_joule", 0)),
                }
    for cell, rec in best.items():
        out.append(row(f"tableIII_{cell.replace('/', '_')}", 0.0,
                       f"ttft_best={rec['TTFT']} thpt_best={rec['Throughput']} "
                       f"energy_best={rec['Throughput/Energy']}"))
    return out
